"""Layer-2 contract tests: the AOT-facing program shape/semantics and the
frozen candidate table shared with rust."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model
from compile.kernels import frag_kernel, ref


class TestCandidateTable:
    def test_arity_and_order(self):
        assert len(ref.CANDIDATES) == 18
        # Table I order: profiles largest-first, anchors ascending.
        names = [c[0] for c in ref.CANDIDATES]
        assert names[0] == "7g.80gb"
        assert names[1] == "4g.40gb"
        assert names[2:4] == ["3g.40gb"] * 2
        assert names[4:7] == ["2g.20gb"] * 3
        assert names[7:11] == ["1g.20gb"] * 4
        assert names[11:] == ["1g.10gb"] * 7

    def test_profile_ranges_partition(self):
        covered = []
        for name, (lo, hi) in ref.PROFILE_RANGES.items():
            for k in range(lo, hi):
                assert ref.CANDIDATES[k][0] == name
                covered.append(k)
        assert sorted(covered) == list(range(18))

    def test_windows_contiguous(self):
        for k, (_, start, size, _) in enumerate(ref.CANDIDATES):
            row = ref.WINDOWS[k]
            assert row.sum() == size
            assert (row[start : start + size] == 1.0).all()

    def test_weights_equal_sizes(self):
        # On the 8-slice model every profile's occupied slices ARE its
        # memory slices (DESIGN.md §2.1).
        assert (ref.SIZES == ref.WEIGHTS).all()

    def test_matches_exported_candidates_json(self):
        # The artifact export must be the same table rust embeds.
        import json
        import os

        path = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts",
                            "candidates.json")
        if not os.path.exists(path):
            pytest.skip("artifacts/candidates.json not built yet (run `make artifacts`)")
        with open(path) as f:
            exported = json.load(f)
        assert len(exported) == 18
        for entry, (name, start, size, weight) in zip(exported, ref.CANDIDATES):
            assert entry["profile"] == name
            assert entry["start"] == start
            assert entry["size"] == size
            assert entry["mem_weight"] == weight
            assert entry["mask"] == ((1 << size) - 1) << start


class TestProgramContract:
    def test_output_shapes(self):
        occ = jnp.zeros((model.DEFAULT_BATCH, 8), dtype=jnp.float32)
        scores, deltas, feasible = model.frag_program(occ)
        assert scores.shape == (model.DEFAULT_BATCH,)
        assert deltas.shape == (model.DEFAULT_BATCH, 18)
        assert feasible.shape == (model.DEFAULT_BATCH, 18)
        for out in (scores, deltas, feasible):
            assert out.dtype == jnp.float32

    def test_pallas_and_reference_paths_agree(self):
        rng = np.random.default_rng(3)
        occ = jnp.array(
            ref.occ_from_masks(rng.integers(0, 256, size=model.DEFAULT_BATCH).tolist())
        )
        a = model.frag_program(occ)
        b = model.frag_program_reference(occ)
        for x, y in zip(a, b):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def test_padding_rows_never_win(self):
        # The rust runtime pads with all-ones rows; they must be infeasible
        # everywhere and score 0.
        occ = jnp.ones((4, 8), dtype=jnp.float32)
        scores, deltas, feasible = model.frag_program(occ)
        assert (np.asarray(scores) == 0.0).all()
        assert (np.asarray(feasible) == 0.0).all()
        assert (np.asarray(deltas) == ref.INFEASIBLE).all()

    def test_example_input_aval(self):
        aval = model.example_input(64)
        assert aval.shape == (64, 8)
        assert aval.dtype == jnp.float32


class TestLowering:
    def test_jit_lowering_succeeds(self):
        lowered = jax.jit(model.frag_program).lower(model.example_input(8))
        text = str(lowered.compiler_ir("stablehlo"))
        assert "stablehlo" in text or "func.func" in text

    def test_hlo_text_roundtrip_format(self):
        from compile import aot

        hlo = aot.lower_frag_program(batch=8, rule="partial")
        # The rust loader requires HLO text with a module header.
        assert hlo.startswith("HloModule")
        assert "f32[8,8]" in hlo  # input layout
        assert "f32[8,18]" in hlo  # delta/feasible outputs

    def test_any_rule_lowering(self):
        from compile import aot

        hlo = aot.lower_frag_program(batch=8, rule="any")
        assert hlo.startswith("HloModule")

    def test_executes_after_roundtrip_via_jax(self):
        # Sanity: the lowered computation is numerically identical when
        # compiled+run by jax itself (the rust-side check happens in
        # rust/tests/runtime_vs_native.rs through PJRT).
        occ = jnp.array(ref.occ_from_masks([0b0010_0011, 0b0010_0000] + [0] * 6))
        compiled = jax.jit(model.frag_program).lower(model.example_input(8)).compile()
        scores, _, _ = compiled(occ)
        assert scores[0] == 16.0 and scores[1] == 8.0


class TestManifest:
    def test_manifest_contents(self):
        import json
        import os

        path = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts",
                            "manifest.json")
        if not os.path.exists(path):
            pytest.skip("artifacts/manifest.json not built yet (run `make artifacts`)")
        with open(path) as f:
            manifest = json.load(f)
        assert manifest["num_candidates"] == 18
        assert manifest["num_slices"] == 8
        assert manifest["batch"] >= 1
        assert manifest["rule"] in ("partial", "any")

    def test_aot_candidates_json_helper(self):
        from compile import aot

        table = aot.candidates_json()
        assert len(table) == 18
        assert table[0] == {
            "profile": "7g.80gb",
            "start": 0,
            "size": 8,
            "mem_weight": 8,
            "mask": 255,
        }
