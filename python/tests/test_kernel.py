"""Layer-1 correctness: the Pallas kernel against the pure-jnp oracle.

Hypothesis sweeps occupancy patterns, batch shapes and block sizes; the
paper's worked examples are pinned explicitly.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import frag_kernel, ref


def assert_program_matches(masks, rule="partial", block=frag_kernel.DEFAULT_BLOCK):
    occ = jnp.array(ref.occ_from_masks(masks))
    es, ed, ef = ref.frag_program(occ, rule=rule)
    ks, kd, kf = frag_kernel.frag_program_pallas(occ, rule=rule, block=block)
    np.testing.assert_allclose(np.asarray(ks), np.asarray(es), rtol=0, atol=0)
    np.testing.assert_allclose(np.asarray(kd), np.asarray(ed), rtol=0, atol=0)
    np.testing.assert_allclose(np.asarray(kf), np.asarray(ef), rtol=0, atol=0)


class TestPaperExamples:
    def test_worked_example_scores(self):
        # GPU2 = {2g.20gb@0, 1g.10gb@5} -> 16; GPU1 = {1g.10gb@5} -> 8.
        occ = jnp.array(ref.occ_from_masks([0b0010_0011, 0b0010_0000]))
        scores = ref.frag_scores(occ)
        assert scores.tolist() == [16.0, 8.0]
        kscores, _, _ = frag_kernel.frag_program_pallas(occ)
        assert kscores.tolist() == [16.0, 8.0]

    def test_empty_and_full_score_zero(self):
        occ = jnp.array(ref.occ_from_masks([0x00, 0xFF]))
        for fn in (ref.frag_scores, lambda o: frag_kernel.frag_program_pallas(o)[0]):
            assert fn(occ).tolist() == [0.0, 0.0]

    def test_repair_delta_negative(self):
        # {1g.10gb@5}: placing 1g.10gb@4 (candidate 15) repairs broken
        # 2-slice windows: delta = -4.
        occ = jnp.array(ref.occ_from_masks([0b0010_0000]))
        _, deltas, feasible = frag_kernel.frag_program_pallas(occ)
        assert feasible[0, 15] == 1.0
        assert deltas[0, 15] == -4.0

    def test_misplaced_1g_delta(self):
        # Empty GPU: 1g.10gb@1 (candidate 12) has delta 12; @6 (cand 17)
        # has delta 6 — the MFI preference the rust tests also pin.
        occ = jnp.zeros((1, 8), dtype=jnp.float32)
        _, deltas, _ = frag_kernel.frag_program_pallas(occ)
        assert deltas[0, 12] == 12.0
        assert deltas[0, 17] == 6.0

    def test_full_gpu_infeasible_everywhere(self):
        occ = jnp.ones((1, 8), dtype=jnp.float32)
        _, deltas, feasible = frag_kernel.frag_program_pallas(occ)
        assert feasible.sum() == 0.0
        assert (deltas == ref.INFEASIBLE).all()


class TestKernelVsOracle:
    @settings(max_examples=60, deadline=None)
    @given(
        masks=st.lists(st.integers(min_value=0, max_value=255), min_size=1, max_size=40),
        rule=st.sampled_from(["partial", "any"]),
    )
    def test_random_masks(self, masks, rule):
        assert_program_matches(masks, rule=rule)

    @settings(max_examples=20, deadline=None)
    @given(
        batch=st.integers(min_value=1, max_value=64),
        seed=st.integers(min_value=0, max_value=2**32 - 1),
    )
    def test_random_shapes(self, batch, seed):
        rng = np.random.default_rng(seed)
        masks = rng.integers(0, 256, size=batch).tolist()
        assert_program_matches(masks)

    @pytest.mark.parametrize("block", [1, 2, 4, 8])
    def test_block_tiling_invariance(self, block):
        rng = np.random.default_rng(7)
        masks = rng.integers(0, 256, size=16).tolist()
        assert_program_matches(masks, block=block)

    def test_exhaustive_all_256_masks(self):
        assert_program_matches(list(range(256)))
        assert_program_matches(list(range(256)), rule="any")

    def test_dtype_robustness(self):
        # The program must accept integer occupancy inputs.
        occ_i = jnp.array(ref.occ_from_masks([0b0010_0011]).astype(np.int32))
        occ_f = jnp.array(ref.occ_from_masks([0b0010_0011]))
        si, _, _ = frag_kernel.frag_program_pallas(occ_i)
        sf, _, _ = frag_kernel.frag_program_pallas(occ_f)
        assert si.tolist() == sf.tolist()


class TestOracleProperties:
    @settings(max_examples=60, deadline=None)
    @given(mask=st.integers(min_value=0, max_value=255))
    def test_any_rule_dominates_partial(self, mask):
        occ = jnp.array(ref.occ_from_masks([mask]))
        assert ref.frag_scores(occ, "any")[0] >= ref.frag_scores(occ, "partial")[0]

    @settings(max_examples=60, deadline=None)
    @given(mask=st.integers(min_value=0, max_value=255))
    def test_scores_bounded(self, mask):
        # Max possible F on A100 is 41 (all anchors blocked while eligible).
        occ = jnp.array(ref.occ_from_masks([mask]))
        s = float(ref.frag_scores(occ, "any")[0])
        assert 0.0 <= s <= 41.0

    @settings(max_examples=40, deadline=None)
    @given(mask=st.integers(min_value=0, max_value=255))
    def test_feasible_iff_window_free(self, mask):
        occ = jnp.array(ref.occ_from_masks([mask]))
        _, _, feasible = ref.frag_program(occ)
        for k, (_, start, size, _) in enumerate(ref.CANDIDATES):
            window_mask = ((1 << size) - 1) << start
            assert bool(feasible[0, k]) == ((mask & window_mask) == 0)

    @settings(max_examples=40, deadline=None)
    @given(mask=st.integers(min_value=0, max_value=255))
    def test_delta_consistency(self, mask):
        # For feasible candidates, delta == F(occ|window) - F(occ).
        occ = jnp.array(ref.occ_from_masks([mask]))
        scores, deltas, feasible = ref.frag_program(occ)
        for k, (_, start, size, _) in enumerate(ref.CANDIDATES):
            if not feasible[0, k]:
                continue
            window_mask = ((1 << size) - 1) << start
            occ2 = jnp.array(ref.occ_from_masks([mask | window_mask]))
            expected = float(ref.frag_scores(occ2)[0] - scores[0])
            assert float(deltas[0, k]) == expected

    def test_rejects_unknown_rule(self):
        with pytest.raises(ValueError):
            ref.frag_scores(jnp.zeros((1, 8)), rule="bogus")


class TestVmemEstimate:
    def test_default_block_fits_vmem(self):
        # DESIGN.md §8: the working set at the default block must fit a
        # 16 MiB VMEM with double buffering (factor 2).
        assert 2 * frag_kernel.vmem_footprint_bytes() < 16 * 1024 * 1024

    def test_footprint_scales_linearly(self):
        a = frag_kernel.vmem_footprint_bytes(128)
        b = frag_kernel.vmem_footprint_bytes(256)
        assert b == 2 * a
