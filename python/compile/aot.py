"""AOT lowering: JAX/Pallas -> HLO text artifacts for the rust runtime.

Run via ``make artifacts`` (or ``cd python && python -m compile.aot``).
Python executes exactly once, at build time; the rust binary loads the
emitted text with ``HloModuleProto::from_text_file`` and never touches
python again.

HLO **text** — not ``lowered.compile()`` output nor a serialized
``HloModuleProto`` — is the interchange format: jax >= 0.5 emits protos
with 64-bit instruction ids which the ``xla`` crate's xla_extension 0.5.1
rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Outputs (under --out-dir, default ../artifacts):

* ``frag.hlo.txt``       -- the batched fragmentation program (Pallas path)
* ``manifest.json``      -- batch size, rule, candidate arity, versions
* ``candidates.json``    -- the frozen candidate table (cross-checked
                            against rust's ``mig::candidates_json()`` and
                            the kernel constants by the test suites)
"""

from __future__ import annotations

import argparse
import json
import os

import jax

from . import model
from .kernels import ref


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-reassigning path).

    ``print_large_constants=True`` is load-bearing: the default printer
    elides big array constants as the literal token ``{...}``, which the
    rust-side text parser silently reads back as zeros — the candidate
    window tables embedded in the fragmentation program would vanish.
    """
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    text = comp.as_hlo_text(print_large_constants=True)
    if "{...}" in text:
        raise RuntimeError(
            "HLO text still contains elided constants ('{...}'); the rust "
            "loader would misread them as zeros"
        )
    return text


def lower_frag_program(batch: int, rule: str, impl: str = "pallas") -> str:
    """Lower the batched fragmentation program.

    ``impl`` selects the Layer-1 body: ``"pallas"`` (the Pallas kernel in
    interpret mode — while-loop + dynamic-slice scaffolding on CPU) or
    ``"jnp"`` (the identical math as straight-line jnp that XLA fuses
    flat). Numerics are bit-identical (pytest + the rust integration suite
    verify both); on the CPU PJRT backend the fused form measures ~15-20%
    faster (EXPERIMENTS.md §Perf, L2 iteration), while on a real TPU the
    Pallas kernel would lower through Mosaic instead of the interpreter.
    """
    if impl == "pallas":
        fn = lambda occ: model.frag_program(occ, rule=rule)  # noqa: E731
    elif impl == "jnp":
        fn = lambda occ: model.frag_program_reference(occ, rule=rule)  # noqa: E731
    else:
        raise ValueError(f"unknown impl {impl!r}")
    lowered = jax.jit(fn).lower(model.example_input(batch))
    return to_hlo_text(lowered)


def candidates_json() -> list[dict]:
    out = []
    for name, start, size, weight in ref.CANDIDATES:
        mask = ((1 << size) - 1) << start
        out.append(
            {
                "profile": name,
                "start": start,
                "size": size,
                "mem_weight": weight,
                "mask": mask,
            }
        )
    return out


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default=os.path.join("..", "artifacts"))
    parser.add_argument("--batch", type=int, default=model.DEFAULT_BATCH)
    parser.add_argument("--rule", choices=["partial", "any"], default="partial")
    parser.add_argument(
        "--impl",
        choices=["pallas", "jnp"],
        default="jnp",
        help="Layer-1 body for the default artifact (frag.hlo.txt). Both "
        "are always emitted; 'jnp' is the CPU-PJRT default because the "
        "interpret-mode pallas scaffolding costs ~15-20%% on this backend.",
    )
    # Back-compat with the scaffold Makefile (`--out path/model.hlo.txt`):
    parser.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = parser.parse_args()

    out_dir = os.path.dirname(args.out) if args.out else args.out_dir
    os.makedirs(out_dir, exist_ok=True)

    # Emit both implementations: the chosen one as frag.hlo.txt (what the
    # rust runtime loads by default) and the other as frag_<impl>.hlo.txt
    # for the perf ablation bench.
    for impl in ("pallas", "jnp"):
        hlo = lower_frag_program(args.batch, args.rule, impl)
        name = "frag.hlo.txt" if impl == args.impl else f"frag_{impl}.hlo.txt"
        hlo_path = os.path.join(out_dir, name)
        with open(hlo_path, "w") as f:
            f.write(hlo)
        print(f"wrote {len(hlo)} chars to {hlo_path} (impl={impl})")

    manifest = {
        "format_version": 1,
        "batch": args.batch,
        "rule": args.rule,
        "impl": args.impl,
        "num_slices": ref.NUM_SLICES,
        "num_candidates": ref.NUM_CANDIDATES,
        "outputs": ["scores[B]", "deltas[B,18]", "feasible[B,18]"],
        "jax_version": jax.__version__,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
        f.write("\n")
    with open(os.path.join(out_dir, "candidates.json"), "w") as f:
        json.dump(candidates_json(), f, indent=2)
        f.write("\n")
    print(f"wrote manifest.json + candidates.json to {out_dir}")


if __name__ == "__main__":
    main()
