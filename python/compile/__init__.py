"""Build-time compilation path: the JAX/Pallas fragmentation program and
its AOT lowering to HLO-text artifacts (`python -m compile.aot`).

Never imported at runtime — the rust binary consumes `artifacts/` only.
"""
