"""Layer-2 JAX program: the batched fragmentation evaluation the rust
coordinator offloads through PJRT.

The "model" of this serving system is not a neural network — the paper's
compute graph is the cluster-wide dry-run evaluation of Algorithm 1/2.
This module assembles the program around the Layer-1 Pallas kernel
(`kernels.frag_kernel`) and is what `aot.py` lowers to HLO text.

The program contract (frozen; rust's `runtime::FragEngine` depends on it):

    inputs : occ f32[B, 8]             -- 0/1 occupancy, bit i == slice i
    outputs: (scores f32[B],
              deltas f32[B, 18],       -- candidate order == Table I order
              feasible f32[B, 18])     -- 1.0 iff window free

Padding convention: callers pad with fully-occupied rows (all ones), which
score 0 and are infeasible for every candidate, so they can never win an
argmin on the rust side.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import frag_kernel, ref

#: Default batch the artifact is lowered for (cluster M=100 pads to 128).
DEFAULT_BATCH = 128


def frag_program(occ: jnp.ndarray, *, rule: str = "partial"):
    """The full L2 program over one occupancy batch (calls the L1 kernel)."""
    scores, deltas, feasible = frag_kernel.frag_program_pallas(occ, rule=rule)
    return scores, deltas, feasible


def frag_program_reference(occ: jnp.ndarray, *, rule: str = "partial"):
    """The same contract built from the pure-jnp oracle (no Pallas), used
    to A/B the kernel inside pytest and as an XLA-fusion baseline."""
    return ref.frag_program(occ, rule=rule)


def example_input(batch: int = DEFAULT_BATCH) -> jax.ShapeDtypeStruct:
    """Input aval used for AOT lowering."""
    return jax.ShapeDtypeStruct((batch, ref.NUM_SLICES), jnp.float32)
