"""Layer-1 kernels: the Pallas fragmentation kernel (`frag_kernel`) and
its pure-jnp correctness oracle (`ref`)."""
