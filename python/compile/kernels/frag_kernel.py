"""Layer-1 Pallas kernel: batched MIG fragmentation scoring.

The paper's scheduling hot spot — evaluating the fragmentation score and
the 18 hypothetical placement deltas for every GPU in the cluster — as a
single tiled kernel.

TPU-oriented structure (DESIGN.md §6, Hardware-Adaptation):

* the window-overlap test is formulated as a dense matmul
  ``occ[Mb, 8] @ WINDOWSᵀ[8, 18]`` so it maps onto the MXU systolic array
  (padded 8→128 on real hardware by Mosaic; on the CPU interpreter it is
  an ordinary dot);
* the hypothetical-occupancy expansion materializes ``[Mb, 18, 8]`` in
  VMEM only — with the default block of 256 rows that is
  256·18·8·4 B ≈ 147 KiB, comfortably inside a TensorCore's 16 MiB VMEM
  with room for double-buffering;
* the candidate tables (windows, sizes, weights) are embedded constants,
  so the kernel reads HBM only for the occupancy tile and writes only the
  three result tiles — the whole computation is one HBM round trip.

The kernel MUST run with ``interpret=True`` here: real-TPU lowering emits
a Mosaic custom-call the CPU PJRT plugin cannot execute (see
/opt/xla-example/README.md). Correctness vs ``ref.py`` is enforced by
``python/tests/test_kernel.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

NUM_SLICES = ref.NUM_SLICES
NUM_CANDIDATES = ref.NUM_CANDIDATES

#: Rows of the occupancy matrix processed per grid step.
DEFAULT_BLOCK = 256


def _kernel(
    occ_ref, windows_ref, sizes_ref, weights_ref, scores_ref, deltas_ref, feasible_ref,
    *, rule: str,
):
    """One grid step: score a [Mb, 8] occupancy tile.

    The candidate tables ride along as (grid-invariant) inputs — Pallas
    kernels cannot capture array constants — with block specs that map
    every grid step to the same full table block.
    """
    occ = occ_ref[...]  # [Mb, 8]
    windows = windows_ref[...]  # [18, 8]
    sizes = sizes_ref[...]  # [18]
    weights = weights_ref[...]  # [18]

    def score(o, overlap, free):
        # o: [..., 8]; overlap: [..., 18]; free: [...]
        blocked = overlap > 0.0
        if rule == "partial":
            blocked = blocked & (overlap < sizes)
        eligible = sizes <= free[..., None]
        return jnp.sum(weights * blocked * eligible, axis=-1)

    free = NUM_SLICES - jnp.sum(occ, axis=-1)  # [Mb]
    overlap = jnp.dot(occ, windows.T)  # [Mb, 18] — MXU-shaped
    scores = score(occ, overlap, free)  # [Mb]

    feasible = (overlap == 0.0).astype(jnp.float32)  # [Mb, 18]

    # Hypothetical occupancy per candidate, kept in VMEM: [Mb, 18, 8].
    occ_hyp = jnp.clip(occ[:, None, :] + windows[None, :, :], 0.0, 1.0)
    free_hyp = NUM_SLICES - jnp.sum(occ_hyp, axis=-1)  # [Mb, 18]
    # Batched window test for every hypothetical: [Mb, 18, 18].
    overlap_hyp = jax.lax.dot_general(
        occ_hyp,
        windows.T,
        dimension_numbers=(((2,), (0,)), ((), ())),
    )
    hyp_scores = score(occ_hyp, overlap_hyp, free_hyp)  # [Mb, 18]

    deltas = hyp_scores - scores[:, None]
    deltas = jnp.where(feasible > 0.0, deltas, jnp.float32(ref.INFEASIBLE))

    scores_ref[...] = scores
    deltas_ref[...] = deltas
    feasible_ref[...] = feasible


@functools.partial(jax.jit, static_argnames=("block", "rule"))
def frag_program_pallas(
    occ: jnp.ndarray, *, block: int = DEFAULT_BLOCK, rule: str = "partial"
):
    """Pallas-kernel version of :func:`ref.frag_program`.

    ``occ`` is [M, 8] float32 0/1 with M divisible by ``block`` (the AOT
    path always passes the padded batch).
    """
    m = occ.shape[0]
    if m % block != 0:
        # Tests call with odd sizes; fall back to a single block.
        block = m
    grid = (m // block,)
    kernel = functools.partial(_kernel, rule=rule)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, NUM_SLICES), lambda i: (i, 0)),
            pl.BlockSpec((NUM_CANDIDATES, NUM_SLICES), lambda i: (0, 0)),
            pl.BlockSpec((NUM_CANDIDATES,), lambda i: (0,)),
            pl.BlockSpec((NUM_CANDIDATES,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block, NUM_CANDIDATES), lambda i: (i, 0)),
            pl.BlockSpec((block, NUM_CANDIDATES), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m,), jnp.float32),
            jax.ShapeDtypeStruct((m, NUM_CANDIDATES), jnp.float32),
            jax.ShapeDtypeStruct((m, NUM_CANDIDATES), jnp.float32),
        ],
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(
        occ.astype(jnp.float32),
        jnp.asarray(ref.WINDOWS),
        jnp.asarray(ref.SIZES),
        jnp.asarray(ref.WEIGHTS),
    )


def vmem_footprint_bytes(block: int = DEFAULT_BLOCK) -> int:
    """Estimated peak VMEM bytes per grid step (DESIGN.md §8 L1 target).

    occ tile + hypothetical expansion + overlap tensors + outputs, f32.
    """
    occ = block * NUM_SLICES
    occ_hyp = block * NUM_CANDIDATES * NUM_SLICES
    overlap = block * NUM_CANDIDATES
    overlap_hyp = block * NUM_CANDIDATES * NUM_CANDIDATES
    outputs = block + 2 * block * NUM_CANDIDATES
    return 4 * (occ + occ_hyp + overlap + overlap_hyp + outputs)
