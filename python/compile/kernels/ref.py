"""Pure-jnp oracle for the batched MIG fragmentation program.

This file is the *specification* the Pallas kernel (``frag_kernel.py``) and,
transitively, the AOT artifact executed from rust are verified against. It
mirrors ``rust/src/frag`` exactly:

* a GPU is eight memory-slice positions; occupancy is a row of 0/1 floats;
* the 18 candidate placements (profile x feasible anchor, paper Table I)
  are frozen in ``CANDIDATE_*`` below in the same order as the rust
  ``mig::CANDIDATES`` table (cross-checked by ``tests/test_model.py``
  against ``artifacts/candidates.json``);
* the fragmentation score follows the paper's Algorithm 1 under the
  "partial overlap" rule pinned by its worked example (F(GPU2)=16,
  F(GPU1)=8) — see ``rust/src/frag/score.rs`` module docs: an anchor
  counts iff its window overlaps occupied slices AND retains a free slice,
  guarded by ``size(p) <= free slices``.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

NUM_SLICES = 8
NUM_CANDIDATES = 18

# Candidate table, Table I order: (profile name, anchor, size, mem weight).
CANDIDATES = [
    ("7g.80gb", 0, 8, 8),
    ("4g.40gb", 0, 4, 4),
    ("3g.40gb", 0, 4, 4),
    ("3g.40gb", 4, 4, 4),
    ("2g.20gb", 0, 2, 2),
    ("2g.20gb", 2, 2, 2),
    ("2g.20gb", 4, 2, 2),
    ("1g.20gb", 0, 2, 2),
    ("1g.20gb", 2, 2, 2),
    ("1g.20gb", 4, 2, 2),
    ("1g.20gb", 6, 2, 2),
    ("1g.10gb", 0, 1, 1),
    ("1g.10gb", 1, 1, 1),
    ("1g.10gb", 2, 1, 1),
    ("1g.10gb", 3, 1, 1),
    ("1g.10gb", 4, 1, 1),
    ("1g.10gb", 5, 1, 1),
    ("1g.10gb", 6, 1, 1),
]

# Column ranges of each profile within the candidate axis.
PROFILE_RANGES = {
    "7g.80gb": (0, 1),
    "4g.40gb": (1, 2),
    "3g.40gb": (2, 4),
    "2g.20gb": (4, 7),
    "1g.20gb": (7, 11),
    "1g.10gb": (11, 18),
}

# Large sentinel marking infeasible deltas.
INFEASIBLE = np.float32(1e9)


def _windows() -> np.ndarray:
    w = np.zeros((NUM_CANDIDATES, NUM_SLICES), dtype=np.float32)
    for k, (_, start, size, _) in enumerate(CANDIDATES):
        w[k, start : start + size] = 1.0
    return w


#: [18, 8] one-hot window masks.
WINDOWS = _windows()
#: [18] occupied-slice counts per candidate.
SIZES = np.array([size for (_, _, size, _) in CANDIDATES], dtype=np.float32)
#: [18] Algorithm 1 memory weights per candidate.
WEIGHTS = np.array([w for (_, _, _, w) in CANDIDATES], dtype=np.float32)


def candidate_indices(profiles=None) -> list[int]:
    """Candidate-table row indices for a profile subset (``None`` = all
    18), in frozen Table I order — mirrors ``mig::candidate_range``."""
    if profiles is None:
        return list(range(NUM_CANDIDATES))
    unknown = set(profiles) - {name for (name, _, _, _) in CANDIDATES}
    if unknown:
        raise ValueError(f"unknown profiles {sorted(unknown)}")
    return [k for k, (name, _, _, _) in enumerate(CANDIDATES) if name in profiles]


def frag_scores(occ: jnp.ndarray, rule: str = "partial", profiles=None) -> jnp.ndarray:
    """Fragmentation score F(m) for each row of ``occ`` ([M, 8] of 0/1).

    ``rule`` is "partial" (default, paper worked example) or "any"
    (literal Algorithm 1 text). ``profiles`` optionally restricts
    Algorithm 1's outer sum to a hardware profile subset (the
    ``HardwareModel::with_profiles`` knob on the rust side); ``None``
    means the full A100 Table I set.
    """
    sel = candidate_indices(profiles)
    windows, sizes, weights = WINDOWS[sel], SIZES[sel], WEIGHTS[sel]
    occ = occ.astype(jnp.float32)
    free = NUM_SLICES - jnp.sum(occ, axis=-1)  # [M]
    overlap = occ @ windows.T  # [M, K] occupied count in each window
    blocked_any = overlap > 0.0
    if rule == "partial":
        blocked = blocked_any & (overlap < sizes[None, :])
    elif rule == "any":
        blocked = blocked_any
    else:
        raise ValueError(f"unknown rule {rule!r}")
    eligible = sizes[None, :] <= free[:, None]
    return jnp.sum(weights[None, :] * blocked * eligible, axis=-1)


def frag_program(occ: jnp.ndarray, rule: str = "partial", profiles=None):
    """The full batched program: scores, deltas and feasibility.

    Returns ``(scores [M], deltas [M, K], feasible [M, K])`` where
    ``deltas[m, k] = F(occ[m] | window_k) - F(occ[m])`` for feasible
    candidates (window entirely free) and ``INFEASIBLE`` otherwise; K is
    the candidate count of the profile subset (18 for ``profiles=None``).
    ``feasible`` is 1.0/0.0.
    """
    sel = candidate_indices(profiles)
    windows = WINDOWS[sel]
    occ = occ.astype(jnp.float32)
    scores = frag_scores(occ, rule, profiles)
    overlap = occ @ windows.T  # [M, K]
    feasible = (overlap == 0.0).astype(jnp.float32)
    # Hypothetical occupancies: [M, K, 8]. For infeasible candidates the
    # union is clamped, producing garbage scores that are masked out below.
    occ_hyp = jnp.clip(occ[:, None, :] + windows[None, :, :], 0.0, 1.0)
    hyp_scores = frag_scores(occ_hyp.reshape(-1, NUM_SLICES), rule, profiles).reshape(
        occ.shape[0], len(sel)
    )
    deltas = hyp_scores - scores[:, None]
    deltas = jnp.where(feasible > 0.0, deltas, INFEASIBLE)
    return scores, deltas, feasible


def occ_from_masks(masks) -> np.ndarray:
    """Expand an iterable of u8 occupancy bitmasks to an [M, 8] 0/1 array
    (bit i == slice i, matching ``rust/src/mig/gpu.rs``)."""
    masks = list(masks)
    out = np.zeros((len(masks), NUM_SLICES), dtype=np.float32)
    for row, mask in enumerate(masks):
        for s in range(NUM_SLICES):
            if mask & (1 << s):
                out[row, s] = 1.0
    return out
