"""Export the golden fragmentation fixture consumed by
``rust/tests/golden_frag.rs``.

Evaluates the pure-jnp oracle (``kernels/ref.py`` — the specification the
Pallas kernel and the AOT artifact are verified against) over **all 256**
GPU occupancy masks and writes scores under both overlap rules, the
partial-rule ΔF matrix (with the 1e9 infeasible sentinel) and the
feasibility matrix, so the rust engines can be held to the python oracle
bit-for-bit without python in the test loop.

Run from the repository root:

    python python/compile/export_golden.py

and commit the regenerated ``rust/tests/golden/frag_golden.json``.
"""

from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import numpy as np

from compile.kernels import ref

OUT = os.path.join(
    os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__)))),
    "rust", "tests", "golden", "frag_golden.json",
)

SENTINEL = 1000000000  # == ref.INFEASIBLE as an exact integer

# Restricted hardware profile set for the ΔF-bucket-bound table: the same
# subset rust pins in ``ScoreTable`` tests via
# ``HardwareModel::with_profiles(&[P1g10gb, P3g40gb])``. The exported max
# score is the bucket offset ``frag::index::FragIndex`` derives for such a
# table, so the index's bucket bounds are held to the python oracle.
RESTRICTED = ("3g.40gb", "1g.10gb")

# Additional profile-subset combos exported under the ``subsets`` key.
# Scores weight candidates in *slice* units, so the same tables pin every
# hardware model sharing the 8-slice geometry (A100-80GB, A100-40GB,
# H100) — the rust side checks them against more than one model.
SUBSETS = (
    ("7g.80gb", "2g.20gb", "1g.10gb"),
    ("4g.40gb", "1g.20gb"),
)


def delta_table(deltas_f, feasible_f):
    deltas_f = np.asarray(deltas_f)
    feasible_f = np.asarray(feasible_f)
    deltas = [
        [int(d) if f > 0.5 else SENTINEL for d, f in zip(drow, frow)]
        for drow, frow in zip(deltas_f, feasible_f)
    ]
    feasible = [[int(f > 0.5) for f in frow] for frow in feasible_f]
    return deltas, feasible


def main() -> None:
    masks = list(range(256))
    occ = ref.occ_from_masks(masks)

    scores_partial = np.asarray(ref.frag_scores(occ, "partial")).astype(int).tolist()
    scores_any = np.asarray(ref.frag_scores(occ, "any")).astype(int).tolist()
    _, deltas_f, feasible_f = ref.frag_program(occ, "partial")
    deltas, feasible = delta_table(deltas_f, feasible_f)

    # Any-rule ΔF: same candidate windows, so feasibility is identical to
    # the partial rule — asserted rather than exported twice.
    _, adeltas_f, afeasible_f = ref.frag_program(occ, "any")
    assert np.array_equal(
        np.asarray(afeasible_f) > 0.5, np.asarray(feasible_f) > 0.5
    ), "feasibility must be overlap-rule independent"
    deltas_any, _ = delta_table(adeltas_f, afeasible_f)

    scores_restricted = (
        np.asarray(ref.frag_scores(occ, "partial", RESTRICTED)).astype(int).tolist()
    )
    _, rdeltas_f, rfeasible_f = ref.frag_program(occ, "partial", RESTRICTED)
    deltas_restricted, feasible_restricted = delta_table(rdeltas_f, rfeasible_f)

    subsets = []
    for profiles in SUBSETS:
        scores_s = np.asarray(ref.frag_scores(occ, "partial", profiles)).astype(int).tolist()
        _, sdeltas_f, sfeasible_f = ref.frag_program(occ, "partial", profiles)
        sdeltas, sfeasible = delta_table(sdeltas_f, sfeasible_f)
        assert scores_s[0x00] == 0 and scores_s[0xFF] == 0
        assert all(s <= f for s, f in zip(scores_s, scores_partial))
        max_s = max(scores_s)
        for drow in sdeltas:
            assert all(abs(d) <= max_s for d in drow if d != SENTINEL)
        subsets.append({
            "profiles": list(profiles),
            "candidates": ref.candidate_indices(profiles),
            "scores": scores_s,
            "deltas": sdeltas,
            "feasible": sfeasible,
            "max_score": int(max_s),
        })

    # The oracle must reproduce the paper's worked examples before we let it
    # pin the rust implementation (Section V-B: F(GPU 2)=16, F(GPU 1)=8).
    assert scores_partial[0b0010_0011] == 16, scores_partial[0b0010_0011]
    assert scores_partial[0b0010_0000] == 8
    assert scores_partial[0x00] == 0 and scores_partial[0xFF] == 0
    assert scores_any[0b0010_0011] == 23
    assert max(scores_any) <= 41  # max_score(A100-80GB)

    # Restricted-set sanity: the subset score can never exceed the full
    # set's (fewer Algorithm 1 summands), every feasible restricted ΔF is
    # bounded by the restricted max score (the index's bucket offset), and
    # an empty/full GPU is never fragmented.
    assert scores_restricted[0x00] == 0 and scores_restricted[0xFF] == 0
    assert all(r <= f for r, f in zip(scores_restricted, scores_partial))
    max_restricted = max(scores_restricted)
    for drow in deltas_restricted:
        assert all(abs(d) <= max_restricted for d in drow if d != SENTINEL)

    fixture = {
        "format": "migsched-golden-frag-v3",
        "source": "python/compile/kernels/ref.py (jnp oracle for Algorithm 1)",
        "num_slices": ref.NUM_SLICES,
        "num_candidates": ref.NUM_CANDIDATES,
        "infeasible_sentinel": SENTINEL,
        "scores_partial": scores_partial,
        "scores_any": scores_any,
        "deltas_partial": deltas,
        "deltas_any": deltas_any,
        "feasible": feasible,
        "restricted_profiles": list(RESTRICTED),
        "restricted_candidates": ref.candidate_indices(RESTRICTED),
        "scores_restricted": scores_restricted,
        "deltas_restricted": deltas_restricted,
        "feasible_restricted": feasible_restricted,
        "max_score_restricted": max_restricted,
        "subsets": subsets,
    }
    with open(OUT, "w") as fh:
        json.dump(fixture, fh, separators=(",", ":"))
    print(f"wrote {OUT}")


if __name__ == "__main__":
    main()
