#!/usr/bin/env bash
# Diff the quick-mode figure CSVs (Fig. 4/5/6 harnesses) against the
# checked-in references under ci/reference/. The figure CSVs are metric
# series (acceptance, utilization, fragmentation — no wall-clock timing),
# fully determined by the seeds and MIGSCHED_BENCH_QUICK=1, so any drift
# is a behavioral change of the scheduler/simulator, not noise.
#
# Bootstrap (or intentionally re-baseline) with:
#
#     MIGSCHED_BENCH_QUICK=1 cargo bench --bench fig4_uniform \
#         --bench fig5_distributions --bench fig6_fragscore
#     ./ci/check_bench_refs.sh --update
#
set -euo pipefail
cd "$(dirname "$0")/.."

REF_DIR=ci/reference
GEN_DIR=""
# cargo runs bench binaries with cwd = the package root (rust/), but allow
# a repo-root results/ too for manual runs.
for d in rust/results results; do
    if compgen -G "$d/fig*.csv" > /dev/null; then
        GEN_DIR="$d"
        break
    fi
done
if [ -z "$GEN_DIR" ]; then
    echo "error: no generated fig*.csv found (run the fig4/fig5/fig6 benches first)" >&2
    exit 1
fi

if [ "${1:-}" = "--update" ]; then
    mkdir -p "$REF_DIR"
    cp "$GEN_DIR"/fig*.csv "$REF_DIR/"
    echo "re-baselined $(ls "$REF_DIR" | wc -l) reference CSVs from $GEN_DIR"
    exit 0
fi

if ! compgen -G "$REF_DIR/fig*.csv" > /dev/null; then
    echo "no references under $REF_DIR yet — bootstrap with: $0 --update"
    echo "(generated CSVs are in $GEN_DIR; passing trivially)"
    exit 0
fi

status=0
for ref in "$REF_DIR"/fig*.csv; do
    name=$(basename "$ref")
    gen="$GEN_DIR/$name"
    if [ ! -f "$gen" ]; then
        echo "MISSING: $name was not regenerated"
        status=1
        continue
    fi
    if ! diff -u "$ref" "$gen"; then
        echo "DRIFT: $name differs from the checked-in reference"
        status=1
    fi
done
if [ "$status" = 0 ]; then
    echo "all figure CSVs match the checked-in references"
fi
exit $status
