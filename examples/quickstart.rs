//! Quickstart: the public API in five minutes.
//!
//! Walks through the paper's core ideas on a 2-GPU cluster:
//! 1. MIG placement rules (Table I) and how fragmentation arises (Fig. 1);
//! 2. the fragmentation score (Algorithm 1) on the paper's worked example;
//! 3. why fit-based baselines reject schedulable workloads (Fig. 3);
//! 4. how MFI (Algorithm 2) picks the minimum-ΔF placement.
//!
//! Run: `cargo run --release --example quickstart`

use migsched::frag::{evaluate_cluster_full, FragScorer, ScoreTable};
use migsched::prelude::*;
use migsched::workload::WorkloadId;

fn main() {
    let hw = HardwareModel::a100_80gb();
    let table = ScoreTable::for_hardware(&hw);

    println!("=== 1. The hardware model (paper Table I) ===\n");
    println!("{}", hw.spec_table().render());

    println!("=== 2. Fragmentation dynamics (paper Fig. 1) ===\n");
    let mut gpu = GpuState::empty();
    gpu.place(Profile::P2g20gb, 0).unwrap();
    gpu.place(Profile::P1g10gb, 5).unwrap();
    println!("GPU after arrivals 2g.20gb@0 + 1g.10gb@5:   [{}]", gpu.diagram());
    println!("  free slices: {}   can host 3g.40gb? {}", gpu.free_slices(),
             gpu.can_host(Profile::P3g40gb));
    println!(
        "  -> fragmented w.r.t. 3g.40gb: {} (enough slices, no feasible anchor)",
        gpu.fragmented_for(Profile::P3g40gb)
    );
    println!(
        "  fragmentation score F = {} (the paper's worked example: 2+2+8+4 = 16)\n",
        table.score(gpu)
    );

    println!("=== 3. Fit-based baselines reject schedulable work (Fig. 3) ===\n");
    let mut cluster = Cluster::new(hw.clone(), 2);
    cluster.allocate(WorkloadId(0), Placement { gpu: 0, profile: Profile::P2g20gb, index: 0 })
        .unwrap();
    cluster.allocate(WorkloadId(1), Placement { gpu: 0, profile: Profile::P1g10gb, index: 5 })
        .unwrap();
    for (i, g) in cluster.gpus().iter().enumerate() {
        println!("  GPU {i}: [{}]  F = {}", g.diagram(), table.score(*g));
    }
    let mut best_fit = BestFit::new(IndexPolicy::BestIndex);
    let mut mfi = Mfi::for_hardware(&hw);
    let request = Profile::P3g40gb;
    println!("\n  request: {request}");
    println!(
        "  BF-BI -> {:?}  (selects busiest GPU 0 on slice counts, fails its anchors)",
        best_fit.schedule(&cluster, request).map(|p| p.to_string())
    );
    let choice = mfi.schedule(&cluster, request);
    println!(
        "  MFI   -> {:?}  (evaluates every feasible placement cluster-wide)",
        choice.map(|p| p.to_string())
    );

    println!("\n=== 4. MFI's dry-run ΔF evaluation (Algorithm 2) ===\n");
    let outcome = evaluate_cluster_full(&table, cluster.gpus(), Profile::P1g10gb);
    println!("  request: 1g.10gb — candidates (gpu, anchor, ΔF):");
    for c in &outcome.candidates {
        let marker = if Some(c) == outcome.best.as_ref() { "  <== argmin" } else { "" };
        println!("    gpu {}  index {}  ΔF {:+}{}", c.gpu, c.index, c.delta, marker);
    }
    let best = outcome.best.unwrap();
    println!(
        "\n  MFI places 1g.10gb at gpu {} index {} (ΔF = {:+}), repairing fragmentation\n",
        best.gpu, best.index, best.delta
    );

    println!("=== 5. Ten requests end-to-end ===\n");
    let mut cluster = Cluster::new(hw.clone(), 2);
    let mut rng = Rng::new(7);
    let gen = WorkloadGenerator::new(Distribution::Uniform);
    let stream = gen.generate_stream(10, 1.0, 20, &mut rng);
    for w in &stream {
        match mfi.schedule(&cluster, w.profile) {
            Some(pl) => {
                cluster.allocate(w.id, pl).unwrap();
                println!("  {}  {}  -> {}", w.id, w.profile, pl);
            }
            None => println!("  {}  {}  -> REJECTED", w.id, w.profile),
        }
    }
    println!(
        "\n  utilization {:.1}%   active GPUs {}/{}   mean F {:.2}",
        cluster.utilization() * 100.0,
        cluster.active_gpus(),
        cluster.num_gpus(),
        table.mean_score(cluster.gpus())
    );
    println!("\nNext: `cargo run --release --example cluster_sim` reproduces the paper's");
    println!("evaluation; `migsched serve` runs the online daemon.");
}
