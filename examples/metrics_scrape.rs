//! Observability demo: boots the serving daemon, replays a multi-tenant
//! workload stream against it from a background thread, and concurrently
//! scrapes `GET /metrics` the way a Prometheus server would — printing a
//! compact dashboard line per scrape, then a final snapshot of the
//! exposition's headline families.
//!
//! Run: `cargo run --release --example metrics_scrape -- [requests]`

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use migsched::prelude::*;
use migsched::server::{Daemon, DaemonConfig, HttpClient};
use migsched::util::json::Json;

/// Sum of every sample of `family` in an exposition (histogram series are
/// excluded by exact-name matching).
fn family_sum(text: &str, family: &str) -> f64 {
    text.lines()
        .filter(|l| !l.starts_with('#'))
        .filter_map(|l| {
            let (name_labels, value) = l.rsplit_once(' ')?;
            let name = name_labels.split('{').next().unwrap();
            (name == family).then(|| value.parse::<f64>().unwrap())
        })
        .sum()
}

fn main() {
    let n_requests: usize =
        std::env::args().nth(1).and_then(|a| a.parse().ok()).unwrap_or(600);

    let daemon = Daemon::new(DaemonConfig {
        num_gpus: 16,
        scheduler: SchedulerKind::MfiIdx,
        workers: 4,
        shards: 2,
        ..DaemonConfig::default()
    });
    let handle = daemon.serve("127.0.0.1:0").expect("bind");
    let addr = handle.addr().to_string();
    println!("daemon up on http://{addr} — scrape target: GET /metrics\n");

    // Load generator: a bursty multi-tenant stream replayed over HTTP in
    // the background, the same way serving_daemon.rs drives the fleet.
    let done = Arc::new(AtomicBool::new(false));
    let load = {
        let addr = addr.clone();
        let done = Arc::clone(&done);
        std::thread::spawn(move || {
            let client = HttpClient::new(&addr);
            let mut rng = Rng::new(7);
            let gen = WorkloadGenerator::new(Distribution::Bimodal).with_tenants(8);
            let stream = gen.generate_stream(n_requests, 1.0, 60, &mut rng);
            let mut clock = 0u64;
            for w in &stream {
                if w.arrival_slot > clock {
                    let delta = w.arrival_slot - clock;
                    client.post_json("/v1/tick", &Json::obj().with("slots", delta)).ok();
                    clock = w.arrival_slot;
                }
                let body = Json::obj()
                    .with("profile", w.profile.canonical_name())
                    .with("tenant", w.tenant.0 as u64)
                    .with("duration_slots", w.duration_slots);
                client.post_json("/v1/workloads", &body).expect("submit");
            }
            done.store(true, Ordering::Relaxed);
        })
    };

    // The "Prometheus server": poll /metrics while the load runs.
    let scraper = HttpClient::new(&addr);
    println!("  scrape   submits  accepted  utilization   decisions");
    let mut scrapes = 0u64;
    while !done.load(Ordering::Relaxed) {
        let r = scraper.get("/metrics").expect("scrape");
        assert_eq!(r.status, 200);
        scrapes += 1;
        println!(
            "{scrapes:>8} {:>9} {:>9} {:>12.3} {:>11}",
            family_sum(&r.body, "migsched_submits_total"),
            family_sum(&r.body, "migsched_accepted_total"),
            family_sum(&r.body, "migsched_utilization"),
            // _count samples of the per-shard decision histogram.
            family_sum(&r.body, "migsched_sched_decision_seconds_count"),
        );
        std::thread::sleep(Duration::from_millis(50));
    }
    load.join().unwrap();

    // Final snapshot: print the headline families verbatim, the way they
    // arrive at a scraper.
    let text = scraper.get("/metrics").expect("final scrape").body;
    println!("\n=== final exposition (headline families) ===");
    for line in text.lines() {
        let keep = [
            "migsched_submits_total",
            "migsched_accepted_total",
            "migsched_http_requests_total",
            "migsched_http_responses_total",
            "migsched_utilization",
            "migsched_mean_frag_score",
            "migsched_uptime_seconds",
        ]
        .iter()
        .any(|f| line.contains(f));
        if keep {
            println!("{line}");
        }
    }
    handle.shutdown();
}
