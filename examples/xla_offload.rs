//! Three-layer composition demo: the rust coordinator driving the
//! AOT-compiled JAX/Pallas fragmentation program through PJRT.
//!
//! Loads `artifacts/frag.hlo.txt` (build with `make artifacts`), validates
//! it numerically against the native engine, then schedules an identical
//! episode with native `Mfi` and `MfiXla` side by side and reports
//! per-decision latency for both paths.
//!
//! Run: `make artifacts && cargo run --release --example xla_offload`

use std::time::Instant;

use migsched::cluster::Cluster;
use migsched::frag::{FragScorer, ScoreTable};
use migsched::mig::{GpuState, HardwareModel, ALL_PROFILES};
use migsched::runtime::{artifacts_dir, FragEngine, PjrtRuntime};
use migsched::sched::{Mfi, MfiXla, Scheduler};
use migsched::util::rng::Rng;
use migsched::util::stats::Sample;
use migsched::workload::WorkloadId;

fn main() {
    let dir = artifacts_dir();
    if !dir.join("frag.hlo.txt").exists() {
        eprintln!("artifacts missing: run `make artifacts` first (looked in {})", dir.display());
        std::process::exit(1);
    }

    // Layer bring-up.
    let runtime = PjrtRuntime::cpu().expect("PJRT CPU client");
    println!(
        "PJRT platform: {} ({} device(s))",
        runtime.platform_name(),
        runtime.device_count()
    );
    let engine = FragEngine::load_default(&runtime).expect("compile artifact");
    println!(
        "artifact: {}  batch={}  rule={}\n",
        dir.join("frag.hlo.txt").display(),
        engine.batch_size(),
        engine.rule()
    );

    // 1. Numeric cross-check over all 256 occupancy patterns.
    let hw = HardwareModel::a100_80gb();
    let table = ScoreTable::for_hardware(&hw);
    let masks: Vec<u8> = (0..=255).collect();
    let batch = engine.evaluate(&masks).expect("evaluate");
    let mut max_diff = 0.0f32;
    for (i, &m) in masks.iter().enumerate() {
        let native = table.score(GpuState::from_mask(m)) as f32;
        max_diff = max_diff.max((batch.scores[i] - native).abs());
    }
    println!("scores vs native over all 256 occupancy masks: max |diff| = {max_diff}");
    assert_eq!(max_diff, 0.0, "artifact numerics must match native engine");

    // 2. Identical episodes through both schedulers, with timing.
    let mut native = Mfi::for_hardware(&hw);
    let mut xla = MfiXla::from_engine(engine);
    let mut rng = Rng::new(0x0FF_10AD);

    let mut native_lat = Sample::new();
    let mut xla_lat = Sample::new();
    let mut divergences = 0usize;
    let mut cluster = Cluster::new(hw.clone(), 100);
    let mut next_id = 0u64;
    let decisions = 300usize;
    for _ in 0..decisions {
        let p = *rng.choose(&ALL_PROFILES);
        let t = Instant::now();
        let a = native.schedule(&cluster, p);
        native_lat.push(t.elapsed().as_secs_f64() * 1e6);
        let t = Instant::now();
        let b = xla.schedule(&cluster, p);
        xla_lat.push(t.elapsed().as_secs_f64() * 1e6);
        if a != b {
            divergences += 1;
        }
        if let Some(pl) = a {
            cluster.allocate(WorkloadId(next_id), pl).unwrap();
            next_id += 1;
        }
        if rng.chance(0.3) && cluster.allocated_workloads() > 0 {
            let ids: Vec<_> = cluster.allocations().map(|(id, _)| id).collect();
            cluster.release(*rng.choose(&ids)).unwrap();
        }
    }
    println!("\n{decisions} scheduling decisions on an M=100 cluster:");
    println!("  decision divergences: {divergences} (must be 0)");
    assert_eq!(divergences, 0);
    println!(
        "  native MFI  per-decision: p50 {:>8.1} µs   p95 {:>8.1} µs",
        native_lat.percentile(50.0),
        native_lat.percentile(95.0)
    );
    println!(
        "  MFI-XLA     per-decision: p50 {:>8.1} µs   p95 {:>8.1} µs",
        xla_lat.percentile(50.0),
        xla_lat.percentile(95.0)
    );
    println!(
        "\n  The native 256-entry-LUT engine wins at this scale — the XLA path\n\
         exists to prove the AOT pipeline and to model learned/heavier scoring\n\
         functions (see DESIGN.md §X3 and benches/xla_offload.rs)."
    );
    println!(
        "\n  final cluster: utilization {:.1}%  mean F {:.2}",
        cluster.utilization() * 100.0,
        table.mean_score(cluster.gpus())
    );
}
