//! End-to-end evaluation driver (the repository's E2E validation run).
//!
//! Reproduces the paper's headline experiment on the full-size cluster:
//! M = 100 A100-80GB GPUs, all five schemes, the four Table II
//! distributions, metrics at every demand checkpoint — then prints the
//! Fig. 4 / Fig. 5 / Fig. 6 tables and the headline comparison ("MFI
//! schedules ~10% more workloads than the baselines under heavy load
//! while using about the same number of GPUs").
//!
//! Run: `cargo run --release --example cluster_sim -- [runs]`
//! Default 60 runs (~paper shape in well under a minute); the paper's full
//! 500-run protocol: `cargo run --release --example cluster_sim -- 500`.
//! Results are also exported as CSV under `results/`.

use migsched::sched::SchedulerKind;
use migsched::sim::experiment::{run_sweep, ExperimentConfig};
use migsched::sim::{fig4_report, fig5_report, fig6_report};
use migsched::workload::Distribution;

fn main() {
    let runs: usize = std::env::args().nth(1).and_then(|a| a.parse().ok()).unwrap_or(60);
    let config = ExperimentConfig { runs, ..ExperimentConfig::paper() };
    eprintln!(
        "running the paper protocol: {} runs x {} schemes x {} distributions, M={} GPUs ...",
        config.runs,
        config.schemes.len(),
        config.distributions.len(),
        config.num_gpus
    );
    let t0 = std::time::Instant::now();
    let sweep = run_sweep(&config);
    let elapsed = t0.elapsed();
    eprintln!("sweep completed in {elapsed:.2?}\n");

    let out_dir = std::path::Path::new("results");
    for report in [
        fig4_report(&sweep, &Distribution::Uniform),
        fig5_report(&sweep, 0.85),
        fig6_report(&sweep),
    ] {
        println!("{}", report.render());
        if let Err(e) = report.save_csvs(out_dir) {
            eprintln!("warning: CSV export failed: {e}");
        }
    }

    // ---- the headline numbers ------------------------------------------
    println!("==== Headline (paper abstract) check ====\n");
    let idx = sweep.checkpoint_index(0.85);
    let mut rows = Vec::new();
    for dist in Distribution::paper_set() {
        let mfi = sweep.series_for(SchedulerKind::Mfi, &dist).unwrap();
        let mfi_accepted = mfi.checkpoints[idx].accepted_workloads.mean();
        let mfi_gpus = mfi.checkpoints[idx].active_gpus.mean();
        let mut best_baseline = f64::MIN;
        let mut mean_baseline = 0.0;
        let mut mean_gpus = 0.0;
        let baselines =
            [SchedulerKind::Ff, SchedulerKind::Rr, SchedulerKind::BfBi, SchedulerKind::WfBi];
        for &b in &baselines {
            let s = sweep.series_for(b, &dist).unwrap();
            let acc = s.checkpoints[idx].accepted_workloads.mean();
            best_baseline = best_baseline.max(acc);
            mean_baseline += acc / baselines.len() as f64;
            mean_gpus += s.checkpoints[idx].active_gpus.mean() / baselines.len() as f64;
        }
        rows.push((
            dist.name().to_string(),
            mfi_accepted,
            mean_baseline,
            (mfi_accepted / mean_baseline - 1.0) * 100.0,
            (mfi_accepted / best_baseline - 1.0) * 100.0,
            mfi_gpus,
            mean_gpus,
        ));
    }
    let mut table = migsched::util::table::Table::new(&[
        "distribution",
        "MFI accepted",
        "baseline mean",
        "gain vs mean %",
        "gain vs best %",
        "MFI GPUs",
        "baseline GPUs",
    ]);
    for (name, a, b, gain_mean, gain_best, g1, g2) in &rows {
        table.row(&[
            name.clone(),
            format!("{a:.1}"),
            format!("{b:.1}"),
            format!("{gain_mean:+.1}"),
            format!("{gain_best:+.1}"),
            format!("{g1:.1}"),
            format!("{g2:.1}"),
        ]);
    }
    println!("{}", table.render());
    let avg_gain: f64 = rows.iter().map(|r| r.3).sum::<f64>() / rows.len() as f64;
    println!(
        "average gain vs baseline mean at 85% demand: {avg_gain:+.1}% \
         (paper: ~+10% in heavy load)\n\
         GPUs used by MFI vs baselines: approximately equal (see table)\n\
         raw CSVs: results/fig*.csv   sweep wall time: {elapsed:.2?}"
    );
}
