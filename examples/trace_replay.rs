//! Trace workflow demo: record a synthetic multi-tenant trace, replay it
//! through every scheduling policy, and print a side-by-side comparison —
//! the workflow an operator would use to evaluate a policy change against
//! production history before rolling it out.
//!
//! Run: `cargo run --release --example trace_replay -- [gpus] [seed]`

use migsched::prelude::*;
use migsched::sim::{SimConfig, SimEngine};
use migsched::workload::Trace;

fn main() {
    let gpus: usize = std::env::args().nth(1).and_then(|a| a.parse().ok()).unwrap_or(50);
    let seed: u64 = std::env::args().nth(2).and_then(|a| a.parse().ok()).unwrap_or(2025);
    let hw = HardwareModel::a100_80gb();
    let capacity = (gpus * hw.num_slices()) as u64;

    // 1. Record: synthesize a skew-small trace (worst case for packing).
    let gen = WorkloadGenerator::new(Distribution::SkewSmall).with_tenants(12);
    let generated = gen.generate(capacity, &mut Rng::new(seed));
    let trace = Trace::from_workloads(
        &format!("skew-small demo (gpus={gpus} seed={seed})"),
        capacity,
        &generated.workloads,
    );
    let path = std::env::temp_dir().join("migsched-demo-trace.jsonl");
    trace.save(&path).expect("save trace");
    println!(
        "recorded {} arrivals (horizon T={}) to {}\n",
        generated.workloads.len(),
        generated.horizon,
        path.display()
    );

    // 2. Replay the SAME trace through every policy.
    let loaded = Trace::load(&path).expect("load trace");
    let config = SimConfig {
        hardware: hw.clone(),
        num_gpus: gpus,
        distribution: Distribution::SkewSmall,
        checkpoints: vec![0.5, 0.85, 1.0],
        seed,
        defrag_every: None,
    };
    let engine = SimEngine::new(config);

    let mut table = migsched::util::table::Table::new(&[
        "scheme",
        "accepted",
        "acceptance %",
        "util@85% %",
        "GPUs@85%",
        "avg frag",
    ]);
    for kind in SchedulerKind::all() {
        let mut sched = kind.build(&hw);
        let result = engine.replay_trace(&mut *sched, &loaded);
        let at85 = result.at_demand(0.85).expect("85% checkpoint");
        table.row(&[
            kind.name().to_string(),
            format!("{}", result.accepted),
            format!("{:.2}", result.acceptance_rate() * 100.0),
            format!("{:.1}", at85.metrics.utilization * 100.0),
            format!("{}", at85.metrics.active_gpus),
            format!("{:.2}", result.time_avg_frag),
        ]);
    }
    println!("{}", table.render());
    println!("(identical arrivals for every scheme — differences are pure policy)");
    std::fs::remove_file(&path).ok();
}
