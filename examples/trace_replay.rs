//! Real-trace workflow demo: ingest the bundled Alibaba- and Philly-style
//! sample job logs, inspect their shape, and replay them open-loop through
//! every scheduling policy — the workflow an operator would use to size a
//! MIG fleet against production history before committing to a policy.
//!
//! Run: `cargo run --release --example trace_replay -- [gpus]`

use std::path::Path;

use migsched::prelude::*;
use migsched::sim::replay::{self, ReplayConfig};
use migsched::workload::ingest::{ingest_path, IngestConfig, TraceFormat};

fn main() {
    let gpus: usize = std::env::args().nth(1).and_then(|a| a.parse().ok()).unwrap_or(4);
    let traces_dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("../examples/traces");
    let hw = HardwareModel::a100_80gb();

    for (file, format) in [
        ("sample_alibaba.csv", TraceFormat::Alibaba),
        ("sample_philly.csv", TraceFormat::Philly),
    ] {
        // 1. Ingest: raw CSV → canonical trace + per-file report.
        let config = IngestConfig::new(format).with_gpus(gpus);
        let (trace, report) =
            ingest_path(&traces_dir.join(file), &config).expect("ingest bundled sample");
        println!("{}", report.render());

        // 2. Stats: what does this workload look like on the slot axis?
        println!("{}", trace.stats().render());

        // 3. Replay: identical open-loop arrivals through every policy.
        let rcfg = ReplayConfig { hardware: hw.clone(), ..ReplayConfig::new(gpus) };
        let mut table = migsched::util::table::Table::new(&[
            "scheme",
            "accepted",
            "rejected",
            "acceptance %",
            "peak GPUs",
            "avg frag",
        ]);
        for kind in SchedulerKind::paper_set() {
            let mut sched = kind.build(&hw);
            let r = replay::run(&trace, &mut *sched, &rcfg);
            assert!(r.conserved());
            table.row(&[
                kind.name().to_string(),
                r.accepted.to_string(),
                r.rejected.to_string(),
                format!("{:.2}", r.acceptance_rate() * 100.0),
                r.peak_active_gpus.to_string(),
                format!("{:.2}", r.time_avg_frag),
            ]);
        }
        println!("replay on M={gpus} GPUs:");
        println!("{}", table.render());
    }
    println!("(identical arrivals for every scheme — differences are pure policy)");
}
