//! Online serving demo: boots the daemon, replays a multi-tenant workload
//! stream against it over HTTP, and reports serving latency/throughput
//! plus the paper's cluster metrics — the "live" counterpart of the
//! Monte Carlo evaluation.
//!
//! Run: `cargo run --release --example serving_daemon -- [requests]`

use std::time::Instant;

use migsched::prelude::*;
use migsched::server::{Daemon, DaemonConfig, HttpClient};
use migsched::util::json::Json;
use migsched::util::stats::Sample;

fn main() {
    let n_requests: usize =
        std::env::args().nth(1).and_then(|a| a.parse().ok()).unwrap_or(400);

    // 1. Boot the daemon on an ephemeral port.
    let config = DaemonConfig {
        num_gpus: 16,
        scheduler: SchedulerKind::Mfi,
        workers: 4,
        ..DaemonConfig::default()
    };
    let daemon = Daemon::new(config);
    let handle = daemon.serve("127.0.0.1:0").expect("bind");
    let addr = handle.addr().to_string();
    println!("daemon up on http://{addr} (16 x A100-80GB, scheduler MFI)\n");

    // 2. Generate a bursty multi-tenant stream.
    let mut rng = Rng::new(42);
    let gen = WorkloadGenerator::new(Distribution::Bimodal).with_tenants(8);
    let stream = gen.generate_stream(n_requests, 1.0, 60, &mut rng);

    // 3. Replay it over HTTP, ticking the logical clock with arrivals.
    let client = HttpClient::new(&addr);
    let mut latencies = Sample::new();
    let mut accepted = 0usize;
    let mut rejected = 0usize;
    let mut clock = 0u64;
    let t0 = Instant::now();
    for w in &stream {
        // Advance the slot clock to this arrival (expires old leases).
        if w.arrival_slot > clock {
            let delta = w.arrival_slot - clock;
            client
                .post_json("/v1/tick", &Json::obj().with("slots", delta))
                .expect("tick");
            clock = w.arrival_slot;
        }
        let body = Json::obj()
            .with("profile", w.profile.canonical_name())
            .with("tenant", w.tenant.0 as u64)
            .with("duration_slots", w.duration_slots);
        let t = Instant::now();
        let resp = client.post_json("/v1/workloads", &body).expect("submit");
        latencies.push(t.elapsed().as_secs_f64() * 1e3);
        match resp.status {
            201 => accepted += 1,
            409 => rejected += 1,
            other => panic!("unexpected status {other}: {}", resp.body),
        }
    }
    let wall = t0.elapsed();

    // 4. Report.
    let stats = client.get("/v1/stats").unwrap().json().unwrap();
    println!("=== load generation finished ===");
    println!("requests: {n_requests}  accepted: {accepted}  rejected: {rejected}");
    println!(
        "acceptance rate: {:.2}%   wall time: {wall:.2?}   throughput: {:.0} req/s",
        accepted as f64 / n_requests as f64 * 100.0,
        n_requests as f64 / wall.as_secs_f64()
    );
    println!(
        "request latency (HTTP round trip, ms): p50 {:.3}  p95 {:.3}  p99 {:.3}  max {:.3}",
        latencies.percentile(50.0),
        latencies.percentile(95.0),
        latencies.percentile(99.0),
        latencies.max()
    );
    println!("\n=== cluster state (GET /v1/stats) ===");
    println!("{}", stats.to_string_pretty());

    let snapshot = client.get("/v1/cluster").unwrap().json().unwrap();
    println!("\n=== occupancy diagrams ===");
    if let Some(diagrams) = snapshot.get("diagrams").and_then(Json::as_arr) {
        for (i, d) in diagrams.iter().enumerate() {
            println!("  gpu {i:>2}: [{}]", d.as_str().unwrap_or("?"));
        }
    }
    handle.shutdown();
    println!("\ndaemon shut down cleanly");
}
