//! Daemon burst throughput (experiment D1): end-to-end requests/sec of
//! the serving daemon over live HTTP at shards ∈ {1, 4, 16} × workers ∈
//! {1, 8}, with 8 concurrent client threads submitting across many
//! tenants and releasing their backlog as they go — the ROADMAP's
//! "profile the daemon's JSON/accept path at burst rates" follow-up.
//!
//! Single-shard numbers measure the old single-mutex daemon (shards = 1
//! is response-identical to it); the multi-shard rows show what tenant
//! routing buys once the per-request work no longer serializes on one
//! lock. The run is recorded machine-readably in `BENCH_daemon.json` at
//! the repository root (schema: `{format, bench, quick_mode, gpus,
//! clients, submits_per_config, hist_record_ns, results: [{shards,
//! workers, requests, wall_ms, reqs_per_sec,
//! latency_us: {p50, p90, p99}}]}`).
//!
//! Client-side per-request latency is recorded into an
//! [`migsched::obs::hist::LatencyHist`] shared across the client threads —
//! the same lock-free structure the daemon itself uses on its hot path, so
//! this run doubles as the observability overhead check: `hist_record_ns`
//! is the measured cost of one `record_ns` call (a bucket-index
//! computation plus two relaxed atomic adds, tens of nanoseconds), which
//! against the ~100µs-scale request latencies below keeps the
//! instrumentation overhead well under the 5% budget.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

use migsched::obs::hist::{HistSnapshot, LatencyHist};
use migsched::sched::SchedulerKind;
use migsched::server::{Daemon, DaemonConfig, HttpClient};
use migsched::util::bench::quick_mode;
use migsched::util::json::Json;

const GPUS: usize = 64;

/// Time ~1M `record_ns` calls: the per-call cost of the daemon's hot-path
/// instrumentation, reported as `hist_record_ns` in the JSON artifact.
fn measure_hist_record_ns() -> f64 {
    let h = LatencyHist::new();
    const N: u64 = 1_000_000;
    let t0 = Instant::now();
    for i in 0..N {
        // Vary the value so the bucket index is not branch-predicted away.
        h.record_ns(1 + (i % 97) * 1_013);
    }
    let elapsed = t0.elapsed().as_nanos() as f64 / N as f64;
    assert_eq!(h.snapshot().count(), N, "every record lands in a bucket");
    elapsed
}

/// Run one configuration; returns (total HTTP requests, wall seconds,
/// client-observed per-request latency histogram).
fn burst(
    shards: usize,
    workers: usize,
    clients: usize,
    submits: usize,
) -> (usize, f64, HistSnapshot) {
    let daemon = Daemon::new(DaemonConfig {
        num_gpus: GPUS,
        scheduler: SchedulerKind::MfiIdx,
        workers,
        shards,
        ..DaemonConfig::default()
    });
    let handle = daemon.serve("127.0.0.1:0").expect("bind ephemeral port");
    let addr = handle.addr().to_string();
    let next = Arc::new(AtomicUsize::new(0));
    let latency = Arc::new(LatencyHist::new());
    let t0 = Instant::now();
    let threads: Vec<_> = (0..clients)
        .map(|c| {
            let addr = addr.clone();
            let next = Arc::clone(&next);
            let latency = Arc::clone(&latency);
            std::thread::spawn(move || -> usize {
                let client = HttpClient::new(&addr);
                let mut ops = 0usize;
                let mut live: Vec<u64> = Vec::new();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= submits {
                        break;
                    }
                    let tenant = (c * 131 + i % 17) as u64;
                    let started = Instant::now();
                    let r = client
                        .post_json(
                            "/v1/workloads",
                            &Json::obj().with("profile", "1g.10gb").with("tenant", tenant),
                        )
                        .expect("submit");
                    latency.record(started.elapsed());
                    ops += 1;
                    match r.status {
                        201 => live.push(r.json().unwrap().req_u64("id").unwrap()),
                        409 => {}
                        other => panic!("unexpected status {other}: {}", r.body),
                    }
                    // Keep the fleet from saturating: drain the oldest of
                    // our backlog so submits keep finding free anchors.
                    if live.len() > 8 {
                        let id = live.remove(0);
                        let started = Instant::now();
                        client.delete(&format!("/v1/workloads/{id}")).expect("release");
                        latency.record(started.elapsed());
                        ops += 1;
                    }
                }
                for id in live {
                    if client.delete(&format!("/v1/workloads/{id}")).is_ok() {
                        ops += 1;
                    }
                }
                ops
            })
        })
        .collect();
    let total_ops: usize = threads.into_iter().map(|t| t.join().unwrap()).sum();
    let wall = t0.elapsed().as_secs_f64();
    handle.shutdown();
    (total_ops, wall, latency.snapshot())
}

fn main() {
    let quick = quick_mode();
    let clients = 8usize;
    let submits = if quick { 400 } else { 3000 };
    println!("== daemon burst throughput ({clients} clients, {submits} submits/config) ==");
    let mut results: Vec<Json> = Vec::new();
    let mut rps_by_key: Vec<(usize, usize, f64)> = Vec::new();
    for &shards in &[1usize, 4, 16] {
        for &workers in &[1usize, 8] {
            let (ops, wall, lat) = burst(shards, workers, clients, submits);
            let rps = ops as f64 / wall;
            // Client-observed request latency percentiles, in microseconds.
            let (p50, p90, p99) = (
                lat.percentile(50.0) * 1e6,
                lat.percentile(90.0) * 1e6,
                lat.percentile(99.0) * 1e6,
            );
            println!(
                "  shards={shards:<2} workers={workers}: {rps:>9.0} req/s \
                 ({ops} requests in {:.0} ms) \
                 p50={p50:.0}us p90={p90:.0}us p99={p99:.0}us",
                wall * 1e3
            );
            rps_by_key.push((shards, workers, rps));
            results.push(
                Json::obj()
                    .with("shards", shards)
                    .with("workers", workers)
                    .with("requests", ops as u64)
                    .with("wall_ms", wall * 1e3)
                    .with("reqs_per_sec", rps)
                    .with(
                        "latency_us",
                        Json::obj().with("p50", p50).with("p90", p90).with("p99", p99),
                    ),
            );
        }
    }
    // Headline: sharding speedup at full worker pool.
    let rps_of = |s: usize, w: usize| {
        rps_by_key.iter().find(|&&(a, b, _)| a == s && b == w).map(|&(_, _, r)| r)
    };
    if let (Some(one), Some(sixteen)) = (rps_of(1, 8), rps_of(16, 8)) {
        println!(
            "\n16-shard daemon vs single mutex (8 workers): {:.2}x",
            sixteen / one
        );
    }

    let hist_record_ns = measure_hist_record_ns();
    println!("hot-path hist record cost: {hist_record_ns:.1} ns/record");

    let doc = Json::obj()
        .with("format", "migsched-bench-daemon-v1")
        .with("bench", "daemon_burst")
        .with("quick_mode", quick)
        .with("gpus", GPUS as u64)
        .with("clients", clients as u64)
        .with("submits_per_config", submits as u64)
        .with("hist_record_ns", hist_record_ns)
        .with("results", Json::Arr(results));
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_daemon.json");
    match std::fs::write(&path, doc.to_string_pretty()) {
        Ok(()) => println!("-- saved {}", path.display()),
        Err(e) => eprintln!("warning: could not save {}: {e}", path.display()),
    }
}
