//! Daemon burst throughput (experiment D1): end-to-end scheduling
//! decisions/sec of the serving daemon over live HTTP, swept across
//! serve model (event-loop reactor vs blocking threadpool) × shards ×
//! batch size × client-connection count — the ROADMAP's "profile the
//! daemon's JSON/accept path at burst rates" follow-up, extended for the
//! non-blocking serving rewrite.
//!
//! Every client thread drives ONE kept-alive connection
//! ([`migsched::server::HttpConn`]), so the numbers measure the serving
//! hot path (parse → dispatch → respond on a live connection), not
//! connection setup. `batch = 1` submits through `POST /v1/workloads`;
//! larger batches go through `POST /v1/submit/batch`, whose placements
//! are bit-identical (pinned by `tests/batch_equiv.rs`) but amortize one
//! shard-lock hold and one HTTP round trip over N decisions. `requests`
//! counts scheduling operations (submitted items + releases), so
//! `reqs_per_sec` is directly comparable across batch sizes; latency
//! percentiles are per HTTP round trip as the client observes them.
//!
//! The run is recorded machine-readably in `BENCH_daemon.json` at the
//! repository root (schema `migsched-bench-daemon-v2`: `{format, bench,
//! quick_mode, gpus, submits_per_config, hist_record_ns, results:
//! [{model, shards, workers, clients, batch, requests, wall_ms,
//! reqs_per_sec, latency_us: {p50, p90, p99}}]}`). The headline ratios —
//! reactor vs threadpool at shards = 16, and best batched reactor vs the
//! sequential threadpool baseline — come from configurations measured in
//! the SAME run.
//!
//! Client-side latency is recorded into an
//! [`migsched::obs::hist::LatencyHist`] shared across the client threads —
//! the same lock-free structure the daemon uses on its hot path, so this
//! run doubles as the observability overhead check: `hist_record_ns` is
//! the measured cost of one `record_ns` call.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

use migsched::obs::hist::{HistSnapshot, LatencyHist};
use migsched::sched::SchedulerKind;
use migsched::server::{Daemon, DaemonConfig, HttpConn, ServeModel};
use migsched::util::bench::quick_mode;
use migsched::util::json::Json;

const GPUS: usize = 64;

/// Time ~1M `record_ns` calls: the per-call cost of the daemon's hot-path
/// instrumentation, reported as `hist_record_ns` in the JSON artifact.
fn measure_hist_record_ns() -> f64 {
    let h = LatencyHist::new();
    const N: u64 = 1_000_000;
    let t0 = Instant::now();
    for i in 0..N {
        // Vary the value so the bucket index is not branch-predicted away.
        h.record_ns(1 + (i % 97) * 1_013);
    }
    let elapsed = t0.elapsed().as_nanos() as f64 / N as f64;
    assert_eq!(h.snapshot().count(), N, "every record lands in a bucket");
    elapsed
}

/// One measured configuration.
#[derive(Clone, Copy)]
struct Cfg {
    model: ServeModel,
    shards: usize,
    workers: usize,
    clients: usize,
    batch: usize,
}

/// Run one configuration; returns (scheduling operations, wall seconds,
/// client-observed per-round-trip latency histogram).
fn burst(cfg: Cfg, submits: usize) -> (usize, f64, HistSnapshot) {
    let daemon = Daemon::new(DaemonConfig {
        num_gpus: GPUS,
        scheduler: SchedulerKind::MfiIdx,
        workers: cfg.workers,
        shards: cfg.shards,
        model: cfg.model,
        ..DaemonConfig::default()
    });
    let handle = daemon.serve("127.0.0.1:0").expect("bind ephemeral port");
    let addr = handle.addr().to_string();
    let next = Arc::new(AtomicUsize::new(0));
    let latency = Arc::new(LatencyHist::new());
    let t0 = Instant::now();
    let threads: Vec<_> = (0..cfg.clients)
        .map(|c| {
            let addr = addr.clone();
            let next = Arc::clone(&next);
            let latency = Arc::clone(&latency);
            std::thread::spawn(move || -> usize {
                let mut conn = HttpConn::connect(&addr);
                let mut ops = 0usize;
                let mut live: Vec<u64> = Vec::new();
                loop {
                    let i = next.fetch_add(cfg.batch, Ordering::Relaxed);
                    if i >= submits {
                        break;
                    }
                    let n = cfg.batch.min(submits - i);
                    if cfg.batch == 1 {
                        let tenant = (c * 131 + i % 17) as u64;
                        let body =
                            Json::obj().with("profile", "1g.10gb").with("tenant", tenant);
                        let started = Instant::now();
                        let r = conn.post_json("/v1/workloads", &body).expect("submit");
                        latency.record(started.elapsed());
                        ops += 1;
                        match r.status {
                            201 => live.push(r.json().unwrap().req_u64("id").unwrap()),
                            409 => {}
                            other => panic!("unexpected status {other}: {}", r.body),
                        }
                    } else {
                        let items: Vec<Json> = (0..n)
                            .map(|k| {
                                Json::obj()
                                    .with("profile", "1g.10gb")
                                    .with("tenant", (c * 131 + (i + k) % 17) as u64)
                            })
                            .collect();
                        let body = Json::obj().with("requests", Json::Arr(items));
                        let started = Instant::now();
                        let r = conn.post_json("/v1/submit/batch", &body).expect("batch");
                        latency.record(started.elapsed());
                        ops += n;
                        assert_eq!(r.status, 200, "{}", r.body);
                        let envelope = r.json().unwrap();
                        for item in envelope.get("results").unwrap().as_arr().unwrap() {
                            if let Ok(id) = item.req_u64("id") {
                                live.push(id);
                            }
                        }
                    }
                    // Keep the fleet from saturating: drain our backlog so
                    // submits keep finding free anchors.
                    while live.len() > cfg.batch.max(8) {
                        let id = live.remove(0);
                        let started = Instant::now();
                        conn.delete(&format!("/v1/workloads/{id}")).expect("release");
                        latency.record(started.elapsed());
                        ops += 1;
                    }
                }
                for id in live {
                    if conn.delete(&format!("/v1/workloads/{id}")).is_ok() {
                        ops += 1;
                    }
                }
                ops
            })
        })
        .collect();
    let total_ops: usize = threads.into_iter().map(|t| t.join().unwrap()).sum();
    let wall = t0.elapsed().as_secs_f64();
    handle.shutdown();
    (total_ops, wall, latency.snapshot())
}

fn main() {
    let quick = quick_mode();
    let submits = if quick { 400 } else { 3000 };
    let reactor = ServeModel::Reactor.effective();
    let pool = ServeModel::Threadpool;
    // Headline model × shards grid, then batch and connection sweeps on
    // the 16-shard reactor. Threadpool rows are the pre-rewrite baseline,
    // measured in the SAME run as everything they are compared against.
    let configs = [
        Cfg { model: pool, shards: 1, workers: 8, clients: 8, batch: 1 },
        Cfg { model: pool, shards: 16, workers: 8, clients: 8, batch: 1 },
        Cfg { model: reactor, shards: 1, workers: 8, clients: 8, batch: 1 },
        Cfg { model: reactor, shards: 16, workers: 1, clients: 8, batch: 1 },
        Cfg { model: reactor, shards: 16, workers: 8, clients: 8, batch: 1 },
        // Batch sweep: one round trip + one shard-lock hold per N items.
        Cfg { model: reactor, shards: 16, workers: 8, clients: 8, batch: 8 },
        Cfg { model: reactor, shards: 16, workers: 8, clients: 8, batch: 32 },
        Cfg { model: reactor, shards: 16, workers: 8, clients: 8, batch: 128 },
        // Connection sweep: few → many kept-alive connections.
        Cfg { model: reactor, shards: 16, workers: 8, clients: 1, batch: 1 },
        Cfg { model: reactor, shards: 16, workers: 8, clients: 32, batch: 1 },
    ];
    println!("== daemon burst throughput ({submits} submits/config) ==");
    let mut results: Vec<Json> = Vec::new();
    let mut measured: Vec<(Cfg, f64)> = Vec::new();
    for &cfg in &configs {
        let (ops, wall, lat) = burst(cfg, submits);
        let rps = ops as f64 / wall;
        // Client-observed round-trip latency percentiles, in microseconds.
        let (p50, p90, p99) = (
            lat.percentile(50.0) * 1e6,
            lat.percentile(90.0) * 1e6,
            lat.percentile(99.0) * 1e6,
        );
        println!(
            "  {:<10} shards={:<2} workers={} clients={:<2} batch={:<3}: \
             {rps:>9.0} req/s ({ops} ops in {:.0} ms) \
             p50={p50:.0}us p90={p90:.0}us p99={p99:.0}us",
            cfg.model.name(),
            cfg.shards,
            cfg.workers,
            cfg.clients,
            cfg.batch,
            wall * 1e3
        );
        measured.push((cfg, rps));
        results.push(
            Json::obj()
                .with("model", cfg.model.name())
                .with("shards", cfg.shards)
                .with("workers", cfg.workers)
                .with("clients", cfg.clients)
                .with("batch", cfg.batch)
                .with("requests", ops as u64)
                .with("wall_ms", wall * 1e3)
                .with("reqs_per_sec", rps)
                .with(
                    "latency_us",
                    Json::obj().with("p50", p50).with("p90", p90).with("p99", p99),
                ),
        );
    }
    let rps_of = |model: ServeModel, shards: usize, batch: usize, clients: usize| {
        measured
            .iter()
            .find(|(c, _)| {
                c.model == model && c.shards == shards && c.batch == batch && c.clients == clients
            })
            .map(|&(_, r)| r)
    };
    // Headlines, all from this run: the rewrite at like-for-like batch=1,
    // and the full win with batching against the threadpool baseline.
    if let (Some(base), Some(evented)) = (rps_of(pool, 16, 1, 8), rps_of(reactor, 16, 1, 8)) {
        println!("\nreactor vs threadpool (shards=16, batch=1): {:.2}x", evented / base);
    }
    if let (Some(base), Some(best)) = (rps_of(pool, 16, 1, 8), rps_of(reactor, 16, 128, 8)) {
        println!("batched reactor vs threadpool baseline (shards=16): {:.2}x", best / base);
    }
    if let (Some(one), Some(sixteen)) = (rps_of(reactor, 1, 1, 8), rps_of(reactor, 16, 1, 8)) {
        println!("16-shard vs single mutex (reactor, batch=1): {:.2}x", sixteen / one);
    }

    let hist_record_ns = measure_hist_record_ns();
    println!("hot-path hist record cost: {hist_record_ns:.1} ns/record");

    let doc = Json::obj()
        .with("format", "migsched-bench-daemon-v2")
        .with("bench", "daemon_burst")
        .with("quick_mode", quick)
        .with("gpus", GPUS as u64)
        .with("submits_per_config", submits as u64)
        .with("hist_record_ns", hist_record_ns)
        .with("results", Json::Arr(results));
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_daemon.json");
    match std::fs::write(&path, doc.to_string_pretty()) {
        Ok(()) => println!("-- saved {}", path.display()),
        Err(e) => eprintln!("warning: could not save {}: {e}", path.display()),
    }
}
