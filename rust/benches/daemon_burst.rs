//! Daemon burst throughput (experiment D1): end-to-end requests/sec of
//! the serving daemon over live HTTP at shards ∈ {1, 4, 16} × workers ∈
//! {1, 8}, with 8 concurrent client threads submitting across many
//! tenants and releasing their backlog as they go — the ROADMAP's
//! "profile the daemon's JSON/accept path at burst rates" follow-up.
//!
//! Single-shard numbers measure the old single-mutex daemon (shards = 1
//! is response-identical to it); the multi-shard rows show what tenant
//! routing buys once the per-request work no longer serializes on one
//! lock. The run is recorded machine-readably in `BENCH_daemon.json` at
//! the repository root (schema: `{format, bench, quick_mode, gpus,
//! clients, submits_per_config, results: [{shards, workers, requests,
//! wall_ms, reqs_per_sec}]}`).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

use migsched::sched::SchedulerKind;
use migsched::server::{Daemon, DaemonConfig, HttpClient};
use migsched::util::bench::quick_mode;
use migsched::util::json::Json;

const GPUS: usize = 64;

/// Run one configuration; returns (total HTTP requests, wall seconds).
fn burst(shards: usize, workers: usize, clients: usize, submits: usize) -> (usize, f64) {
    let daemon = Daemon::new(DaemonConfig {
        num_gpus: GPUS,
        scheduler: SchedulerKind::MfiIdx,
        workers,
        shards,
        ..DaemonConfig::default()
    });
    let handle = daemon.serve("127.0.0.1:0").expect("bind ephemeral port");
    let addr = handle.addr().to_string();
    let next = Arc::new(AtomicUsize::new(0));
    let t0 = Instant::now();
    let threads: Vec<_> = (0..clients)
        .map(|c| {
            let addr = addr.clone();
            let next = Arc::clone(&next);
            std::thread::spawn(move || -> usize {
                let client = HttpClient::new(&addr);
                let mut ops = 0usize;
                let mut live: Vec<u64> = Vec::new();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= submits {
                        break;
                    }
                    let tenant = (c * 131 + i % 17) as u64;
                    let r = client
                        .post_json(
                            "/v1/workloads",
                            &Json::obj().with("profile", "1g.10gb").with("tenant", tenant),
                        )
                        .expect("submit");
                    ops += 1;
                    match r.status {
                        201 => live.push(r.json().unwrap().req_u64("id").unwrap()),
                        409 => {}
                        other => panic!("unexpected status {other}: {}", r.body),
                    }
                    // Keep the fleet from saturating: drain the oldest of
                    // our backlog so submits keep finding free anchors.
                    if live.len() > 8 {
                        let id = live.remove(0);
                        client.delete(&format!("/v1/workloads/{id}")).expect("release");
                        ops += 1;
                    }
                }
                for id in live {
                    if client.delete(&format!("/v1/workloads/{id}")).is_ok() {
                        ops += 1;
                    }
                }
                ops
            })
        })
        .collect();
    let total_ops: usize = threads.into_iter().map(|t| t.join().unwrap()).sum();
    let wall = t0.elapsed().as_secs_f64();
    handle.shutdown();
    (total_ops, wall)
}

fn main() {
    let quick = quick_mode();
    let clients = 8usize;
    let submits = if quick { 400 } else { 3000 };
    println!("== daemon burst throughput ({clients} clients, {submits} submits/config) ==");
    let mut results: Vec<Json> = Vec::new();
    let mut rps_by_key: Vec<(usize, usize, f64)> = Vec::new();
    for &shards in &[1usize, 4, 16] {
        for &workers in &[1usize, 8] {
            let (ops, wall) = burst(shards, workers, clients, submits);
            let rps = ops as f64 / wall;
            println!(
                "  shards={shards:<2} workers={workers}: {rps:>9.0} req/s \
                 ({ops} requests in {:.0} ms)",
                wall * 1e3
            );
            rps_by_key.push((shards, workers, rps));
            results.push(
                Json::obj()
                    .with("shards", shards)
                    .with("workers", workers)
                    .with("requests", ops as u64)
                    .with("wall_ms", wall * 1e3)
                    .with("reqs_per_sec", rps),
            );
        }
    }
    // Headline: sharding speedup at full worker pool.
    let rps_of = |s: usize, w: usize| {
        rps_by_key.iter().find(|&&(a, b, _)| a == s && b == w).map(|&(_, _, r)| r)
    };
    if let (Some(one), Some(sixteen)) = (rps_of(1, 8), rps_of(16, 8)) {
        println!(
            "\n16-shard daemon vs single mutex (8 workers): {:.2}x",
            sixteen / one
        );
    }

    let doc = Json::obj()
        .with("format", "migsched-bench-daemon-v1")
        .with("bench", "daemon_burst")
        .with("quick_mode", quick)
        .with("gpus", GPUS as u64)
        .with("clients", clients as u64)
        .with("submits_per_config", submits as u64)
        .with("results", Json::Arr(results));
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_daemon.json");
    match std::fs::write(&path, doc.to_string_pretty()) {
        Ok(()) => println!("-- saved {}", path.display()),
        Err(e) => eprintln!("warning: could not save {}: {e}", path.display()),
    }
}
