//! Index-policy ablation (experiment X2): how much of the MIG-aware
//! baselines' advantage comes purely from the best-index preference of
//! [21]? Runs BF/WF with both index policies (BI vs FI) plus FF/MFI
//! anchors, across all four distributions at 85% demand.

use migsched::sched::SchedulerKind;
use migsched::sim::experiment::{run_sweep, ExperimentConfig};
use migsched::util::bench;
use migsched::util::table::Table;
use migsched::workload::Distribution;

fn runs() -> usize {
    if let Ok(v) = std::env::var("MIGSCHED_BENCH_RUNS") {
        return v.parse().expect("MIGSCHED_BENCH_RUNS must be an integer");
    }
    if bench::quick_mode() {
        20
    } else {
        200
    }
}

fn main() {
    let schemes = vec![
        SchedulerKind::Mfi,
        SchedulerKind::Ff,
        SchedulerKind::BfBi,
        SchedulerKind::BfFi,
        SchedulerKind::WfBi,
        SchedulerKind::WfFi,
        SchedulerKind::Random,
    ];
    let config = ExperimentConfig { runs: runs(), schemes, ..ExperimentConfig::paper() };
    println!(
        "== index-policy ablation: {} runs, M={}, schemes BF/WF x BI/FI ==",
        config.runs, config.num_gpus
    );
    let t0 = std::time::Instant::now();
    let sweep = run_sweep(&config);
    let idx = sweep.checkpoint_index(0.85);

    let mut table = Table::new(&[
        "scheme", "uniform", "skew-small", "skew-big", "bimodal",
    ])
    .title("acceptance rate at 85% demand (mean over runs)");
    for &k in &config.schemes {
        let vals: Vec<f64> = Distribution::paper_set()
            .iter()
            .map(|d| {
                sweep.series_for(k, d).unwrap().checkpoints[idx].acceptance_rate.mean()
            })
            .collect();
        table.row_keyed(k.name(), &vals, 4);
    }
    println!("{}", table.render());

    // The ablation takeaway: BI − FI gap per fit family.
    println!("== best-index contribution (acceptance delta BI - FI, 85% demand) ==");
    for (bi, fi, family) in [
        (SchedulerKind::BfBi, SchedulerKind::BfFi, "best-fit"),
        (SchedulerKind::WfBi, SchedulerKind::WfFi, "worst-fit"),
    ] {
        for d in Distribution::paper_set() {
            let a = sweep.series_for(bi, &d).unwrap().checkpoints[idx].acceptance_rate.mean();
            let b = sweep.series_for(fi, &d).unwrap().checkpoints[idx].acceptance_rate.mean();
            println!("  {family:<9} {:<12} {:+.4}", d.name(), a - b);
        }
    }
    println!("\nablation finished in {:.2?}", t0.elapsed());
}
