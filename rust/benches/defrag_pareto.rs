//! Defragmentation cost/benefit Pareto sweep (experiment D1): replay the
//! bundled ~2k-row Alibaba-style trace on a deliberately tight fleet and
//! sweep the continuous-defrag cost budget, recording the acceptance
//! uplift each budget buys and what it costs in migrations and copied
//! instance memory.
//!
//! The no-defrag baseline runs first; every run must conserve its
//! counters, and a budgeted run that accepts *fewer* workloads than the
//! baseline is flagged loudly (defrag usually frees capacity, but a
//! migration can also fill a hole a later arrival would have used, so
//! this is a report, not an invariant). The run is recorded
//! machine-readably in
//! `BENCH_defrag.json` at the repository root (schema:
//! `{format, bench, quick_mode, trace: {rows, arrivals, span_slots},
//! gpus, policy: {every, threshold, max_moves}, results: [{budget,
//! accepted, acceptance_rate, migrations, migrated_bytes, defrag_sweeps,
//! time_avg_frag, median_ms}]}`; the baseline row has `budget: null`).

use std::path::Path;

use migsched::defrag::DefragPolicy;
use migsched::sched::SchedulerKind;
use migsched::sim::replay::{self, ReplayConfig};
use migsched::util::bench::{fmt_ns, quick_mode, BenchRunner};
use migsched::util::json::Json;
use migsched::workload::ingest::{ingest_path, IngestConfig, TraceFormat};

/// A small fleet keeps the trace capacity-bound so defrag has rejections
/// to recover (the 16-GPU throughput bench accepts nearly everything).
const GPUS: usize = 8;
/// Sweep cadence in slots; frequent enough to act between arrival bursts.
const EVERY: u64 = 4;
const MAX_MOVES: usize = 16;

fn main() {
    let quick = quick_mode();
    let csv = Path::new(env!("CARGO_MANIFEST_DIR")).join("../examples/traces/bench_alibaba_2k.csv");

    let t0 = std::time::Instant::now();
    let config = IngestConfig::new(TraceFormat::Alibaba).with_gpus(GPUS);
    let (trace, report) = ingest_path(&csv, &config).expect("ingest bundled bench trace");
    let ingest_ns = t0.elapsed().as_nanos() as f64;
    let arrivals = trace.arrivals().len() as u64;
    let stats = trace.stats();
    println!(
        "== defrag pareto bench: {} rows → {} workloads ({} span slots), ingest {} ==",
        report.rows_total,
        arrivals,
        stats.span_slots,
        fmt_ns(ingest_ns)
    );

    let hw = migsched::mig::HardwareModel::a100_80gb();
    let kind = SchedulerKind::Ff; // the packing-blind baseline defrag helps most
    // `None` = defrag disabled; `Some(0)` = unlimited budget; the rest
    // trace the cost/benefit frontier between them.
    let budgets: &[Option<u64>] = if quick {
        &[None, Some(0)]
    } else {
        &[None, Some(40), Some(80), Some(160), Some(320), Some(0)]
    };

    let mut runner = BenchRunner::new("defrag_pareto");
    let mut results: Vec<Json> = Vec::new();
    let mut baseline_accepted = None;
    for &budget in budgets {
        let mut rcfg = ReplayConfig::new(GPUS);
        rcfg.defrag = budget.map(|b| {
            DefragPolicy::every(EVERY)
                .with_max_moves(MAX_MOVES)
                .with_cost_budget(b)
        });
        let label = match budget {
            None => "off".to_string(),
            Some(0) => "unlimited".to_string(),
            Some(b) => format!("budget{b}"),
        };
        let mut sched = kind.build(&hw);
        let mut last = None;
        let reps = if quick { 2 } else { 5 };
        let r = runner
            .bench_once(&format!("pareto/{label}/M{GPUS}"), reps, || {
                last = Some(replay::run(&trace, &mut *sched, &rcfg));
            })
            .clone();
        let outcome = last.expect("at least one rep ran");
        assert!(outcome.conserved(), "{label}: counters must conserve");
        match budget {
            None => baseline_accepted = Some(outcome.accepted),
            Some(_) => {
                let base = baseline_accepted.expect("baseline runs first");
                if outcome.accepted < base {
                    eprintln!(
                        "WARNING {label}: defrag lost acceptance ({} < {base})",
                        outcome.accepted
                    );
                }
            }
        }
        println!(
            "   {label}: acceptance {:.4} ({} / {}), {} migration(s), {} bytes, frag {:.2}",
            outcome.acceptance_rate(),
            outcome.accepted,
            outcome.arrived,
            outcome.migrations,
            outcome.migrated_bytes,
            outcome.time_avg_frag
        );
        results.push(
            Json::obj()
                .with(
                    "budget",
                    budget.map(Json::from).unwrap_or(Json::Null),
                )
                .with("accepted", outcome.accepted)
                .with("acceptance_rate", outcome.acceptance_rate())
                .with("migrations", outcome.migrations)
                .with("migrated_bytes", outcome.migrated_bytes)
                .with("defrag_sweeps", outcome.defrag_sweeps)
                .with("time_avg_frag", outcome.time_avg_frag)
                .with("median_ms", r.median_ns / 1e6),
        );
    }

    runner.save_csv();
    let doc = Json::obj()
        .with("format", "migsched-bench-defrag-v1")
        .with("bench", "defrag_pareto")
        .with("quick_mode", quick)
        .with(
            "trace",
            Json::obj()
                .with("source", "examples/traces/bench_alibaba_2k.csv")
                .with("rows", report.rows_total)
                .with("arrivals", arrivals)
                .with("span_slots", stats.span_slots)
                .with("ingest_ms", ingest_ns / 1e6),
        )
        .with("gpus", GPUS as u64)
        .with("scheme", kind.name())
        .with(
            "policy",
            Json::obj()
                .with("every", EVERY)
                .with("threshold", 0.0)
                .with("max_moves", MAX_MOVES as u64),
        )
        .with("results", Json::Arr(results));
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_defrag.json");
    match std::fs::write(&path, doc.to_string_pretty()) {
        Ok(()) => println!("-- saved {}", path.display()),
        Err(e) => eprintln!("warning: could not save {}: {e}", path.display()),
    }
}
