//! Bench/harness for paper Fig. 5 (a–d): the four evaluation metrics at
//! 85% GPU demand across the four Table II profile distributions.
//!
//! Also prints the paper-abstract headline check: MFI's gain in scheduled
//! workloads over the baselines in heavy load.

use migsched::sched::SchedulerKind;
use migsched::sim::experiment::{run_sweep, ExperimentConfig};
use migsched::sim::fig5_report;
use migsched::util::bench;
use migsched::workload::Distribution;

fn runs() -> usize {
    if let Ok(v) = std::env::var("MIGSCHED_BENCH_RUNS") {
        return v.parse().expect("MIGSCHED_BENCH_RUNS must be an integer");
    }
    if bench::quick_mode() {
        20
    } else {
        500
    }
}

fn main() {
    let config = ExperimentConfig { runs: runs(), ..ExperimentConfig::paper() };
    println!(
        "== fig5: {} runs x {} schemes x {} distributions, M={} ==",
        config.runs,
        config.schemes.len(),
        config.distributions.len(),
        config.num_gpus
    );
    let t0 = std::time::Instant::now();
    let sweep = run_sweep(&config);
    let elapsed = t0.elapsed();
    let report = fig5_report(&sweep, 0.85);
    println!("{}", report.render());
    if let Err(e) = report.save_csvs(std::path::Path::new("results")) {
        eprintln!("warning: CSV export failed: {e}");
    }

    // Headline: MFI vs baseline-mean accepted workloads at 85% demand.
    let idx = sweep.checkpoint_index(0.85);
    println!("== headline: MFI gain in scheduled workloads at 85% demand ==");
    for dist in Distribution::paper_set() {
        let mfi = sweep
            .series_for(SchedulerKind::Mfi, &dist)
            .unwrap()
            .checkpoints[idx]
            .accepted_workloads
            .mean();
        let baselines =
            [SchedulerKind::Ff, SchedulerKind::Rr, SchedulerKind::BfBi, SchedulerKind::WfBi];
        let mean: f64 = baselines
            .iter()
            .map(|&k| {
                sweep.series_for(k, &dist).unwrap().checkpoints[idx].accepted_workloads.mean()
            })
            .sum::<f64>()
            / baselines.len() as f64;
        println!(
            "  {:<12} MFI {:>7.1} vs baseline mean {:>7.1}  ->  {:+.1}%",
            dist.name(),
            mfi,
            mean,
            (mfi / mean - 1.0) * 100.0
        );
    }
    println!(
        "\nfig5 harness: {} simulation runs in {elapsed:.2?}",
        config.runs * config.schemes.len() * config.distributions.len()
    );
}
