//! Complexity validation (experiment X1): the paper claims MFI decides in
//! O(k·M). Sweep the cluster size M from 25 to 1600 and verify the
//! per-decision latency grows linearly (doubling M ≈ doubles the cost),
//! and that end-to-end simulation throughput scales accordingly.

use migsched::cluster::Cluster;
use migsched::mig::{HardwareModel, ALL_PROFILES};
use migsched::sched::SchedulerKind;
use migsched::sim::{SimConfig, SimEngine};
use migsched::util::bench::BenchRunner;
use migsched::util::rng::Rng;
use migsched::workload::{Distribution, WorkloadId};

fn loaded_cluster(num_gpus: usize, target: f64) -> Cluster {
    let hw = HardwareModel::a100_80gb();
    let mut cluster = Cluster::new(hw.clone(), num_gpus);
    let mut sched = SchedulerKind::Random.build(&hw);
    let mut rng = Rng::new(33);
    let mut id = 0u64;
    while cluster.utilization() < target {
        let p = *rng.choose(&ALL_PROFILES);
        match sched.schedule(&cluster, p) {
            Some(pl) => {
                cluster.allocate(WorkloadId(id), pl).unwrap();
                id += 1;
            }
            None => break,
        }
    }
    cluster
}

fn main() {
    let mut runner = BenchRunner::new("scaling");
    let hw = HardwareModel::a100_80gb();

    let sizes = [25usize, 50, 100, 200, 400, 800, 1600];
    let mut medians = Vec::new();
    for &m in &sizes {
        let cluster = loaded_cluster(m, 0.5);
        let mut mfi = SchedulerKind::Mfi.build(&hw);
        let mut rng = Rng::new(1);
        let r = runner.bench(&format!("mfi_decision_M{m}"), || {
            let p = ALL_PROFILES[rng.index(6)];
            mfi.schedule(&cluster, p)
        });
        medians.push((m, r.median_ns));
    }

    println!("\n== O(k·M) check: per-decision cost ratio when doubling M ==");
    for pair in medians.windows(2) {
        let (m1, t1) = pair[0];
        let (m2, t2) = pair[1];
        println!(
            "  M {m1:>5} -> {m2:>5}: cost x{:.2} (linear would be x{:.2})",
            t2 / t1,
            m2 as f64 / m1 as f64
        );
    }

    // End-to-end simulation throughput at two scales.
    for &m in &[100usize, 400] {
        let cfg = SimConfig {
            num_gpus: m,
            ..SimConfig::paper(Distribution::Uniform, 11)
        };
        let engine = SimEngine::new(cfg);
        runner.bench_once(&format!("full_sim_run_M{m}_uniform"), 5, || {
            let mut sched = SchedulerKind::Mfi.build(&hw);
            engine.run(&mut *sched)
        });
    }
    runner.save_csv();
}
