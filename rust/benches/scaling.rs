//! Complexity validation (experiment X1): the paper claims MFI decides in
//! O(k·M); the incremental engine (`MFI-IDX`, `frag::index`) claims
//! amortized O(k) per commit/release and ~O(1) per decision.
//!
//! Two sweeps over the cluster size M:
//!
//! * flat `MFI` decisions M ∈ {25 … 1600}: verify the O(k·M) law
//!   (doubling M ≈ doubles the cost);
//! * flat vs indexed at M ∈ {1000, 10000, 50000}: steady-state decision
//!   latency AND a full churn cycle (release → decide → commit with
//!   hooks), where the indexed engine must stay sublinear in M —
//!   the acceptance bar is ≥5× over flat at M = 10000.
//!
//! Besides the usual CSV, the run is recorded machine-readably in
//! `BENCH_scaling.json` at the repository root so the perf trajectory is
//! tracked across PRs (schema: `{format, bench, quick_mode, results:
//! [{name, m, scheme, median_ns, p05_ns, p95_ns, iterations}], summary:
//! {speedup_decision_m10000, speedup_churn_m10000, ...}}`).

use migsched::cluster::Cluster;
use migsched::mig::{HardwareModel, Placement, Profile, ALL_PROFILES};
use migsched::sched::SchedulerKind;
use migsched::sim::{SimConfig, SimEngine};
use migsched::util::bench::{quick_mode, BenchRunner};
use migsched::util::json::Json;
use migsched::util::rng::Rng;
use migsched::workload::{Distribution, WorkloadId};

/// Fill a cluster to ~`target` utilization with random feasible
/// placements, O(M) (direct per-GPU placement; no scheduler scans).
fn loaded_cluster(num_gpus: usize, target: f64) -> Cluster {
    let hw = HardwareModel::a100_80gb();
    let mut cluster = Cluster::new(hw, num_gpus);
    let mut rng = Rng::new(33);
    let mut id = 0u64;
    for gpu in 0..num_gpus {
        for _ in 0..6 {
            let state = cluster.gpu(gpu).unwrap();
            if f64::from(state.used_slices()) >= 8.0 * target {
                break;
            }
            let profile = *rng.choose(&ALL_PROFILES);
            let feasible: Vec<u8> = state.feasible_indexes(profile).collect();
            if feasible.is_empty() {
                continue;
            }
            let index = *rng.choose(&feasible);
            cluster.allocate(WorkloadId(id), Placement { gpu, profile, index }).unwrap();
            id += 1;
        }
    }
    cluster
}

struct Recorder {
    rows: Vec<Json>,
}

impl Recorder {
    fn push(&mut self, result: &migsched::util::bench::BenchResult, m: usize, scheme: &str) {
        self.rows.push(
            Json::obj()
                .with("name", result.name.as_str())
                .with("m", m as u64)
                .with("scheme", scheme)
                .with("median_ns", result.median_ns)
                .with("p05_ns", result.p05_ns)
                .with("p95_ns", result.p95_ns)
                .with("iterations", result.iterations),
        );
    }

    fn median_of(&self, name: &str) -> Option<f64> {
        self.rows
            .iter()
            .find(|r| r.get("name").and_then(Json::as_str) == Some(name))
            .and_then(|r| r.get("median_ns"))
            .and_then(Json::as_f64)
    }
}

fn main() {
    let mut runner = BenchRunner::new("scaling");
    let mut rec = Recorder { rows: Vec::new() };
    let hw = HardwareModel::a100_80gb();

    // --- O(k·M) law for the flat scan --------------------------------------
    let flat_sizes = [25usize, 50, 100, 200, 400, 800, 1600];
    let mut medians = Vec::new();
    for &m in &flat_sizes {
        let cluster = loaded_cluster(m, 0.5);
        let mut mfi = SchedulerKind::Mfi.build(&hw);
        let mut rng = Rng::new(1);
        let r = runner.bench(&format!("mfi_decision_M{m}"), || {
            let p = ALL_PROFILES[rng.index(6)];
            mfi.schedule(&cluster, p)
        });
        medians.push((m, r.median_ns));
        rec.push(r, m, "MFI");
    }

    println!("\n== O(k·M) check: per-decision cost ratio when doubling M ==");
    for pair in medians.windows(2) {
        let (m1, t1) = pair[0];
        let (m2, t2) = pair[1];
        println!(
            "  M {m1:>5} -> {m2:>5}: cost x{:.2} (linear would be x{:.2})",
            t2 / t1,
            m2 as f64 / m1 as f64
        );
    }

    // --- flat vs indexed at fleet scale ------------------------------------
    println!("\n== flat O(k·M) rescan vs incremental index (frag::index) ==");
    let big_sizes = [1_000usize, 10_000, 50_000];
    for &m in &big_sizes {
        // Steady-state decision latency (no mutations between queries).
        let cluster = loaded_cluster(m, 0.5);
        for kind in [SchedulerKind::Mfi, SchedulerKind::MfiIdx] {
            let mut sched = kind.build(&hw);
            let mut rng = Rng::new(2);
            let r = runner.bench(&format!("decision_M{m}_{}", kind.name()), || {
                let p = ALL_PROFILES[rng.index(6)];
                sched.schedule(&cluster, p)
            });
            rec.push(r, m, kind.name());
        }

        // Full churn cycle: release one workload, schedule the same
        // profile, commit — hooks wired, so the indexed engine pays its
        // O(k) update inside the measured loop.
        for kind in [SchedulerKind::Mfi, SchedulerKind::MfiIdx] {
            let mut cluster = loaded_cluster(m, 0.5);
            // Sorted so the victim cycle (and the recorded medians) are
            // reproducible — HashMap iteration order is per-process random.
            let mut victims: Vec<(WorkloadId, Profile)> =
                cluster.allocations().map(|(id, pl)| (id, pl.profile)).collect();
            victims.sort();
            let mut sched = kind.build(&hw);
            let mut cursor = 0usize;
            let r = runner.bench(&format!("churn_M{m}_{}", kind.name()), || {
                let (id, profile) = victims[cursor % victims.len()];
                cursor += 1;
                let freed = cluster.release(id).unwrap();
                sched.on_release(&cluster, freed);
                let placement =
                    sched.schedule(&cluster, profile).expect("feasible after freeing");
                cluster.allocate(id, placement).unwrap();
                sched.on_commit(&cluster, placement);
            });
            rec.push(r, m, kind.name());
        }
    }

    // --- end-to-end simulation throughput ----------------------------------
    for &m in &[100usize, 400] {
        let cfg = SimConfig { num_gpus: m, ..SimConfig::paper(Distribution::Uniform, 11) };
        let engine = SimEngine::new(cfg);
        let r = runner.bench_once(&format!("full_sim_run_M{m}_uniform"), 5, || {
            let mut sched = SchedulerKind::Mfi.build(&hw);
            engine.run(&mut *sched)
        });
        rec.push(r, m, "MFI");
    }

    // --- machine-readable record -------------------------------------------
    let mut summary = Json::obj();
    for &m in &big_sizes {
        for phase in ["decision", "churn"] {
            let flat = rec.median_of(&format!("{phase}_M{m}_MFI"));
            let idx = rec.median_of(&format!("{phase}_M{m}_MFI-IDX"));
            if let (Some(flat), Some(idx)) = (flat, idx) {
                let speedup = flat / idx;
                summary.set(&format!("speedup_{phase}_m{m}"), speedup);
                println!("  {phase} M={m}: MFI-IDX is {speedup:.1}x faster than flat MFI");
            }
        }
    }
    if let Some(s) =
        summary.get("speedup_decision_m10000").and_then(Json::as_f64)
    {
        let verdict = if s >= 5.0 { "PASS" } else { "FAIL" };
        println!("\nacceptance (>=5x at M=10000): {s:.1}x — {verdict}");
    }

    let doc = Json::obj()
        .with("format", "migsched-bench-scaling-v1")
        .with("bench", "scaling")
        .with("quick_mode", quick_mode())
        .with("results", Json::Arr(rec.rows.clone()))
        .with("summary", summary);
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_scaling.json");
    match std::fs::write(&path, doc.to_string_pretty()) {
        Ok(()) => println!("-- saved {}", path.display()),
        Err(e) => eprintln!("warning: could not save {}: {e}", path.display()),
    }
    runner.save_csv();
}
