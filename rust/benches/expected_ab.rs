//! MFI vs MFI-EXP acceptance A/B (experiment E1): paired-seed Monte
//! Carlo runs over the four Table II mixes plus an open-loop replay of
//! the bundled ~2k-row Alibaba-style trace, pitting the agnostic MFI
//! baseline against the distribution-aware MFI-EXP (online workload
//! estimator + expected-fragmentation scoring). Both arms see identical
//! seeds and identical arrival sequences, so every delta is attributable
//! to the scoring policy alone.
//!
//! The run is recorded machine-readably in `BENCH_expected.json` at the
//! repository root (schema: `{format, bench, quick_mode, gpus, seeds,
//! estimator_decay, mixes: [{distribution, MFI: {...}, "MFI-EXP": {...},
//! delta_accepted, median_ms}], trace: {...}, wins}`).

use std::path::Path;

use migsched::mig::HardwareModel;
use migsched::sched::SchedulerKind;
use migsched::sim::replay::{self, ReplayConfig};
use migsched::sim::{Distribution, SimConfig, SimEngine};
use migsched::util::bench::{quick_mode, BenchRunner};
use migsched::util::json::Json;
use migsched::workload::ingest::{ingest_path, IngestConfig, TraceFormat};
use migsched::workload::EstimatorConfig;

const GPUS: usize = 24;
const TRACE_GPUS: usize = 16;

/// Pooled (accepted, arrived) per arm over `seeds` paired runs of `dist`.
fn run_mix(
    dist: &Distribution,
    seeds: u64,
    hw: &HardwareModel,
    est: &EstimatorConfig,
    arms: &[SchedulerKind; 2],
) -> [(u64, u64); 2] {
    let mut totals = [(0u64, 0u64); 2];
    for s in 0..seeds {
        let config = SimConfig {
            hardware: hw.clone(),
            num_gpus: GPUS,
            fleet: None,
            distribution: dist.clone(),
            checkpoints: vec![1.0],
            seed: 1 + s,
            defrag: None,
            telemetry: false,
        };
        let engine = SimEngine::new(config);
        for (arm, kind) in arms.iter().enumerate() {
            let mut sched = kind.build_with_estimator(hw, Some(est));
            let result = engine.run(&mut *sched);
            totals[arm].0 += result.accepted;
            totals[arm].1 += result.arrived;
        }
    }
    totals
}

fn arm_json(accepted: u64, arrived: u64) -> Json {
    Json::obj().with("accepted", accepted).with("arrived", arrived).with(
        "acceptance_rate",
        if arrived == 0 { 0.0 } else { accepted as f64 / arrived as f64 },
    )
}

fn main() {
    let quick = quick_mode();
    let seeds: u64 = if quick { 3 } else { 10 };
    let hw = HardwareModel::a100_80gb();
    let est = EstimatorConfig::default();
    let arms = [SchedulerKind::Mfi, SchedulerKind::MfiExp];
    println!(
        "== expected-score A/B bench: MFI vs MFI-EXP, M={GPUS}, \
         {seeds} paired seeds x 4 mixes =="
    );

    let mut runner = BenchRunner::new("expected_ab");
    let mut rows: Vec<Json> = Vec::new();
    let mut wins = 0u64;
    for dist in Distribution::paper_set() {
        let mut totals = [(0u64, 0u64); 2];
        let reps = if quick { 1 } else { 2 };
        let r = runner
            .bench_once(&format!("ab/{}/M{GPUS}", dist.name()), reps, || {
                totals = run_mix(&dist, seeds, &hw, &est, &arms);
            })
            .clone();
        let delta = totals[1].0 as i64 - totals[0].0 as i64;
        if delta > 0 {
            wins += 1;
        }
        println!(
            "   {:>10}: MFI {}/{}  MFI-EXP {}/{}  delta {delta:+}",
            dist.name(),
            totals[0].0,
            totals[0].1,
            totals[1].0,
            totals[1].1
        );
        rows.push(
            Json::obj()
                .with("distribution", dist.name())
                .with(arms[0].name(), arm_json(totals[0].0, totals[0].1))
                .with(arms[1].name(), arm_json(totals[1].0, totals[1].1))
                .with("delta_accepted", delta)
                .with("median_ms", r.median_ns / 1e6),
        );
    }
    println!("-- MFI-EXP acceptance wins on {wins}/4 synthetic mixes");

    // Real-shaped arm: the bundled Alibaba-style trace, both schedulers
    // over the identical arrival sequence.
    let csv =
        Path::new(env!("CARGO_MANIFEST_DIR")).join("../examples/traces/bench_alibaba_2k.csv");
    let config = IngestConfig::new(TraceFormat::Alibaba).with_gpus(TRACE_GPUS);
    let (trace, report) = ingest_path(&csv, &config).expect("ingest bundled bench trace");
    let rcfg = ReplayConfig::new(TRACE_GPUS);
    let mut trace_row = Json::obj()
        .with("source", "examples/traces/bench_alibaba_2k.csv")
        .with("rows", report.rows_total)
        .with("gpus", TRACE_GPUS as u64);
    let mut trace_accepted = [0u64; 2];
    for (arm, kind) in arms.iter().enumerate() {
        let mut sched = kind.build_with_estimator(&hw, Some(&est));
        let mut last = None;
        let reps = if quick { 1 } else { 3 };
        runner.bench_once(&format!("ab/alibaba-2k/{kind}/M{TRACE_GPUS}"), reps, || {
            last = Some(replay::run(&trace, &mut *sched, &rcfg));
        });
        let outcome = last.expect("at least one rep ran");
        assert!(outcome.conserved(), "{kind}: counters must conserve");
        trace_accepted[arm] = outcome.accepted;
        println!(
            "   alibaba-2k {kind}: acceptance {:.4} ({} / {})",
            outcome.acceptance_rate(),
            outcome.accepted,
            outcome.arrived
        );
        trace_row.set(kind.name(), arm_json(outcome.accepted, outcome.arrived));
    }
    trace_row.set("delta_accepted", trace_accepted[1] as i64 - trace_accepted[0] as i64);

    runner.save_csv();
    let doc = Json::obj()
        .with("format", "migsched-bench-expected-v1")
        .with("bench", "expected_ab")
        .with("quick_mode", quick)
        .with("baseline", arms[0].name())
        .with("candidate", arms[1].name())
        .with("gpus", GPUS as u64)
        .with("seeds", seeds)
        .with("estimator_decay", est.decay_slots)
        .with("mixes", Json::Arr(rows))
        .with("trace", trace_row)
        .with("wins", wins);
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_expected.json");
    match std::fs::write(&path, doc.to_string_pretty()) {
        Ok(()) => println!("-- saved {}", path.display()),
        Err(e) => eprintln!("warning: could not save {}: {e}", path.display()),
    }
}
