//! Bench/harness for paper Fig. 4 (a–d): allocated workloads, acceptance
//! rate, resource utilization and active GPUs versus GPU demand (10%…100%)
//! under the uniform distribution, all five schemes, M = 100 GPUs.
//!
//! Prints the same series the paper plots and exports CSVs under
//! `results/`. Runs default to the paper's 500 seeds; override with
//! `MIGSCHED_BENCH_RUNS=n` or `MIGSCHED_BENCH_QUICK=1` (20 seeds).

use migsched::sched::SchedulerKind;
use migsched::sim::experiment::{run_sweep, ExperimentConfig};
use migsched::sim::fig4_report;
use migsched::util::bench;
use migsched::workload::Distribution;

fn runs() -> usize {
    if let Ok(v) = std::env::var("MIGSCHED_BENCH_RUNS") {
        return v.parse().expect("MIGSCHED_BENCH_RUNS must be an integer");
    }
    if bench::quick_mode() {
        20
    } else {
        500
    }
}

fn main() {
    let config = ExperimentConfig {
        runs: runs(),
        schemes: SchedulerKind::paper_set().to_vec(),
        distributions: vec![Distribution::Uniform],
        ..ExperimentConfig::paper()
    };
    println!(
        "== fig4: {} runs x {} schemes, M={}, uniform distribution ==",
        config.runs,
        config.schemes.len(),
        config.num_gpus
    );
    let t0 = std::time::Instant::now();
    let sweep = run_sweep(&config);
    let elapsed = t0.elapsed();
    let report = fig4_report(&sweep, &Distribution::Uniform);
    println!("{}", report.render());
    if let Err(e) = report.save_csvs(std::path::Path::new("results")) {
        eprintln!("warning: CSV export failed: {e}");
    }
    println!(
        "fig4 harness: {} simulation runs in {elapsed:.2?} ({:.1} runs/s)",
        config.runs * config.schemes.len(),
        (config.runs * config.schemes.len()) as f64 / elapsed.as_secs_f64()
    );
}
