//! Bench/harness for paper Fig. 6: average (time-averaged, then
//! run-averaged) cluster fragmentation score per scheme per distribution
//! — plus the overlap-rule ablation (Algorithm 1's literal "any overlap"
//! text vs the "partial overlap" semantics of the paper's worked example,
//! see `frag::score` docs).

use migsched::sched::SchedulerKind;
use migsched::sim::experiment::{run_sweep, ExperimentConfig};
use migsched::sim::fig6_report;
use migsched::util::bench;
use migsched::workload::Distribution;

fn runs() -> usize {
    if let Ok(v) = std::env::var("MIGSCHED_BENCH_RUNS") {
        return v.parse().expect("MIGSCHED_BENCH_RUNS must be an integer");
    }
    if bench::quick_mode() {
        20
    } else {
        500
    }
}

fn main() {
    let config = ExperimentConfig { runs: runs(), ..ExperimentConfig::paper() };
    println!(
        "== fig6: {} runs x {} schemes x {} distributions, M={} ==",
        config.runs,
        config.schemes.len(),
        config.distributions.len(),
        config.num_gpus
    );
    let t0 = std::time::Instant::now();
    let sweep = run_sweep(&config);
    let report = fig6_report(&sweep);
    println!("{}", report.render());
    if let Err(e) = report.save_csvs(std::path::Path::new("results")) {
        eprintln!("warning: CSV export failed: {e}");
    }

    // Consistency check the paper narrates: the scheme ordering by
    // fragmentation score is the inverse of the acceptance ordering.
    let idx = sweep.checkpoint_index(0.85);
    println!("== consistency: acceptance rank vs fragmentation rank (uniform) ==");
    let mut rows: Vec<(String, f64, f64)> = SchedulerKind::paper_set()
        .iter()
        .map(|&k| {
            let s = sweep.series_for(k, &Distribution::Uniform).unwrap();
            (
                k.name().to_string(),
                s.checkpoints[idx].acceptance_rate.mean(),
                s.time_avg_frag.mean(),
            )
        })
        .collect();
    rows.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    for (name, acc, frag) in &rows {
        println!("  {name:<8} acceptance {acc:.4}   avg frag {frag:8.3}");
    }
    let mfi_frag = rows.iter().find(|r| r.0 == "MFI").unwrap().2;
    let min_frag = rows.iter().map(|r| r.2).fold(f64::INFINITY, f64::min);
    println!(
        "  MFI has the lowest fragmentation score: {}",
        if (mfi_frag - min_frag).abs() < 1e-9 { "yes" } else { "NO (investigate)" }
    );
    println!("\nfig6 harness finished in {:.2?}", t0.elapsed());
}
