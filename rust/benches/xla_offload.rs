//! ΔF engine ablation (experiment X3): native 256-entry-LUT engine vs the
//! AOT-compiled XLA program through PJRT, at two cluster sizes — both the
//! raw batched evaluation and the end-to-end scheduling decision.
//!
//! Skips (exit 0 with a message) when `make artifacts` has not run.

use migsched::cluster::Cluster;
use migsched::frag::ScoreTable;
use migsched::mig::{HardwareModel, Profile, ALL_PROFILES};
use migsched::runtime::{artifacts_dir, FragEngine, PjrtRuntime};
use migsched::sched::{Mfi, MfiXla, Scheduler, SchedulerKind};
use migsched::util::bench::BenchRunner;
use migsched::util::rng::Rng;
use migsched::workload::WorkloadId;

fn loaded_cluster(num_gpus: usize, target: f64) -> Cluster {
    let hw = HardwareModel::a100_80gb();
    let mut cluster = Cluster::new(hw.clone(), num_gpus);
    let mut sched = SchedulerKind::Random.build(&hw);
    let mut rng = Rng::new(4);
    let mut id = 0u64;
    while cluster.utilization() < target {
        let p = *rng.choose(&ALL_PROFILES);
        match sched.schedule(&cluster, p) {
            Some(pl) => {
                cluster.allocate(WorkloadId(id), pl).unwrap();
                id += 1;
            }
            None => break,
        }
    }
    cluster
}

fn main() {
    let dir = artifacts_dir();
    if !dir.join("frag.hlo.txt").exists() {
        println!(
            "SKIP xla_offload bench: {}/frag.hlo.txt missing (run `make artifacts`)",
            dir.display()
        );
        return;
    }
    let runtime = PjrtRuntime::cpu().expect("PJRT CPU client");
    let engine = FragEngine::load_default(&runtime).expect("artifact");
    // The non-default L1 implementation, if `make artifacts` produced one.
    let (alt_name, alt_engine) = ["pallas", "jnp"]
        .iter()
        .find_map(|impl_name| {
            let path = dir.join(format!("frag_{impl_name}.hlo.txt"));
            path.exists().then(|| {
                (
                    *impl_name,
                    FragEngine::load(&runtime, &path, &dir.join("manifest.json")).ok(),
                )
            })
        })
        .unwrap_or(("none", None));
    let hw = HardwareModel::a100_80gb();
    let table = ScoreTable::for_hardware(&hw);

    let mut runner = BenchRunner::new("xla_offload");
    for &m in &[100usize, 400] {
        let cluster = loaded_cluster(m, 0.5);
        let masks = cluster.occupancy_masks();

        // Raw batched ΔF evaluation.
        runner.bench(&format!("native_eval_all_profiles_M{m}"), || {
            let mut count = 0usize;
            for p in ALL_PROFILES {
                if migsched::frag::evaluate_cluster(&table, cluster.gpus(), p).is_some() {
                    count += 1;
                }
            }
            count
        });
        runner.bench(&format!("xla_eval_batch_M{m}"), || {
            engine.evaluate(&masks).expect("evaluate")
        });
        // L1-impl ablation: the interpret-mode Pallas artifact vs the
        // fused-jnp default (same math; EXPERIMENTS.md §Perf L2 iteration).
        if let Some(alt) = &alt_engine {
            runner.bench(&format!("xla_eval_batch_M{m}_{alt_name}"), || {
                alt.evaluate(&masks).expect("evaluate")
            });
        }

        // End-to-end decision.
        let mut native = Mfi::for_hardware(&hw);
        let mut rng = Rng::new(9);
        runner.bench(&format!("native_mfi_decision_M{m}"), || {
            let p = ALL_PROFILES[rng.index(6)];
            native.schedule(&cluster, p)
        });
    }

    // MfiXla decision (owns the engine, so benched last).
    let cluster = loaded_cluster(100, 0.5);
    let mut xla_sched = MfiXla::from_engine(engine);
    let mut rng = Rng::new(9);
    runner.bench("xla_mfi_decision_M100", || {
        let p = ALL_PROFILES[rng.index(6)];
        xla_sched.schedule(&cluster, p)
    });

    // Sanity: identical decision on a fixed state.
    let mut native = Mfi::for_hardware(&hw);
    assert_eq!(
        native.schedule(&cluster, Profile::P3g40gb),
        xla_sched.schedule(&cluster, Profile::P3g40gb),
        "native and XLA engines diverged"
    );
    println!("\nnative vs XLA decisions agree on the probe state ✔");
    runner.save_csv();
}
