//! Per-decision scheduling latency (experiment X4 in DESIGN.md §4): the
//! L3 hot path. Measures every policy on clusters at three load levels,
//! plus the raw fragmentation-engine primitives.
//!
//! The paper claims O(k·M) per MFI decision; `benches/scaling.rs` sweeps
//! M — this bench pins the absolute cost at the paper's M=100.

use migsched::cluster::Cluster;
use migsched::frag::{FragScorer, ScoreTable};
use migsched::mig::{GpuState, HardwareModel, Profile, ALL_PROFILES};
use migsched::sched::SchedulerKind;
use migsched::util::bench::BenchRunner;
use migsched::util::rng::Rng;
use migsched::workload::WorkloadId;

/// Fill a cluster to roughly `target` utilization with random placements.
fn loaded_cluster(num_gpus: usize, target: f64, seed: u64) -> Cluster {
    let hw = HardwareModel::a100_80gb();
    let mut cluster = Cluster::new(hw.clone(), num_gpus);
    let mut sched = SchedulerKind::Random.build(&hw);
    let mut rng = Rng::new(seed);
    let mut next_id = 0u64;
    while cluster.utilization() < target {
        let p = *rng.choose(&ALL_PROFILES);
        match sched.schedule(&cluster, p) {
            Some(pl) => {
                cluster.allocate(WorkloadId(next_id), pl).unwrap();
                next_id += 1;
            }
            None => break,
        }
    }
    cluster
}

fn main() {
    let mut runner = BenchRunner::new("sched_latency");
    let hw = HardwareModel::a100_80gb();
    let table = ScoreTable::for_hardware(&hw);

    // --- engine primitives --------------------------------------------
    let gpus: Vec<GpuState> = {
        let c = loaded_cluster(100, 0.5, 7);
        c.gpus().to_vec()
    };
    runner.bench("frag_score_single_lookup", || {
        let mut acc = 0u32;
        for g in &gpus {
            acc = acc.wrapping_add(table.score(*g));
        }
        acc
    });
    runner.bench("frag_mean_score_m100", || table.mean_score(&gpus));
    runner.bench("delta_f_single", || {
        table.delta(GpuState::empty(), Profile::P3g40gb, 4)
    });
    runner.bench("evaluate_cluster_m100_1g10gb", || {
        migsched::frag::evaluate_cluster(&table, &gpus, Profile::P1g10gb)
    });
    // The naive Algorithm 2 (recompute Algorithm 1 per dry-run) — the
    // §Perf "before" datum the LUT engine is measured against.
    runner.bench("naive_direct_mfi_decision_m100_1g10gb", || {
        let p = Profile::P1g10gb;
        let mut best: Option<(i32, usize, u8)> = None;
        for (gid, g) in gpus.iter().enumerate() {
            if p.size() > g.free_slices() {
                continue;
            }
            let base = migsched::frag::score_direct(*g, &hw) as i32;
            for &s in p.starts() {
                if !g.fits_at(p, s) {
                    continue;
                }
                let d =
                    migsched::frag::score_direct(g.with_placement(p, s), &hw) as i32 - base;
                if best.is_none() || (d, gid, s) < best.unwrap() {
                    best = Some((d, gid, s));
                }
            }
        }
        best
    });

    // --- per-policy decision latency at three load levels ---------------
    for (label, util) in [("empty", 0.0), ("half", 0.5), ("heavy", 0.85)] {
        let cluster = loaded_cluster(100, util, 99);
        for kind in SchedulerKind::all() {
            let mut sched = kind.build(&hw);
            let mut rng = Rng::new(1);
            let name = format!("decide_{label}_{}", kind.name());
            runner.bench(&name, || {
                let p = ALL_PROFILES[rng.index(6)];
                sched.schedule(&cluster, p)
            });
        }
    }

    // --- decisions per second summary for MFI ---------------------------
    let cluster = loaded_cluster(100, 0.5, 5);
    let mut mfi = SchedulerKind::Mfi.build(&hw);
    let mut rng = Rng::new(2);
    let result = runner.bench("mfi_decision_m100_half_load", || {
        let p = ALL_PROFILES[rng.index(6)];
        mfi.schedule(&cluster, p)
    });
    println!(
        "\nMFI throughput at M=100, 50% load: {:.2} M decisions/s (target >= 1 M/s, DESIGN.md §8)",
        result.throughput(1.0) / 1e6
    );
    runner.save_csv();
}
