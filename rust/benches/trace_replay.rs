//! Trace-replay throughput (experiment T1): end-to-end scheduler
//! performance on a real-shaped workload — ingest the bundled ~2k-row
//! Alibaba-style trace (`examples/traces/bench_alibaba_2k.csv`) and
//! replay it open-loop through each scheduler, recording events/sec and
//! acceptance.
//!
//! Unlike the synthetic benches this measures the full production path
//! (raw CSV → canonical trace → replay with hooks), so it catches
//! regressions in ingest cost as well as decision cost. The run is
//! recorded machine-readably in `BENCH_trace.json` at the repository
//! root (schema: `{format, bench, quick_mode, trace: {rows, arrivals,
//! span_slots}, gpus, results: [{scheme, arrived, accepted,
//! acceptance_rate, median_ms, events_per_sec}]}`).

use std::path::Path;

use migsched::sched::SchedulerKind;
use migsched::sim::replay::{self, ReplayConfig};
use migsched::util::bench::{fmt_ns, quick_mode, BenchRunner};
use migsched::util::json::Json;
use migsched::workload::ingest::{ingest_path, IngestConfig, TraceFormat};

const GPUS: usize = 16;

fn main() {
    let quick = quick_mode();
    let csv = Path::new(env!("CARGO_MANIFEST_DIR")).join("../examples/traces/bench_alibaba_2k.csv");

    // Ingest once up front (also timed — it is part of the pipeline).
    let t0 = std::time::Instant::now();
    let config = IngestConfig::new(TraceFormat::Alibaba).with_gpus(GPUS);
    let (trace, report) = ingest_path(&csv, &config).expect("ingest bundled bench trace");
    let ingest_ns = t0.elapsed().as_nanos() as f64;
    let arrivals = trace.arrivals().len() as u64;
    let stats = trace.stats();
    println!(
        "== trace replay bench: {} rows → {} workloads ({} span slots), ingest {} ==",
        report.rows_total,
        arrivals,
        stats.span_slots,
        fmt_ns(ingest_ns)
    );

    let hw = migsched::mig::HardwareModel::a100_80gb();
    let rcfg = ReplayConfig::new(GPUS);
    let schemes = [
        SchedulerKind::Mfi,
        SchedulerKind::MfiIdx,
        SchedulerKind::Ff,
        SchedulerKind::BfBi,
        SchedulerKind::WfBi,
    ];

    let mut runner = BenchRunner::new("trace_replay");
    let mut results: Vec<Json> = Vec::new();
    let mut acceptance_of = Vec::new();
    for kind in schemes {
        let mut sched = kind.build(&hw);
        let mut last = None;
        let reps = if quick { 2 } else { 7 };
        let r = runner
            .bench_once(&format!("replay/{kind}/M{GPUS}"), reps, || {
                last = Some(replay::run(&trace, &mut *sched, &rcfg));
            })
            .clone();
        let outcome = last.expect("at least one rep ran");
        assert!(outcome.conserved(), "{kind}: counters must conserve");
        let events_per_sec = arrivals as f64 / (r.median_ns * 1e-9);
        println!(
            "   {kind}: acceptance {:.4} ({} / {}), {:.0} events/s",
            outcome.acceptance_rate(),
            outcome.accepted,
            outcome.arrived,
            events_per_sec
        );
        acceptance_of.push((kind, outcome.accepted));
        results.push(
            Json::obj()
                .with("scheme", kind.name())
                .with("arrived", outcome.arrived)
                .with("accepted", outcome.accepted)
                .with("acceptance_rate", outcome.acceptance_rate())
                .with("median_ms", r.median_ns / 1e6)
                .with("events_per_sec", events_per_sec),
        );
    }

    // The index-equivalence invariant, asserted on every bench run.
    let accepted = |k: SchedulerKind| {
        acceptance_of.iter().find(|&&(a, _)| a == k).map(|&(_, n)| n).unwrap()
    };
    assert_eq!(
        accepted(SchedulerKind::Mfi),
        accepted(SchedulerKind::MfiIdx),
        "MFI and MFI-IDX must accept identically on the bench trace"
    );

    runner.save_csv();
    let doc = Json::obj()
        .with("format", "migsched-bench-trace-v1")
        .with("bench", "trace_replay")
        .with("quick_mode", quick)
        .with(
            "trace",
            Json::obj()
                .with("source", "examples/traces/bench_alibaba_2k.csv")
                .with("rows", report.rows_total)
                .with("arrivals", arrivals)
                .with("span_slots", stats.span_slots)
                .with("ingest_ms", ingest_ns / 1e6),
        )
        .with("gpus", GPUS as u64)
        .with("results", Json::Arr(results));
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_trace.json");
    match std::fs::write(&path, doc.to_string_pretty()) {
        Ok(()) => println!("-- saved {}", path.display()),
        Err(e) => eprintln!("warning: could not save {}: {e}", path.display()),
    }
}
