//! # migsched — online fragmentation-aware scheduling for MIG-based GPU clouds
//!
//! A production-shaped reproduction of *"An Online Fragmentation-Aware GPU
//! Scheduler for Multi-Tenant MIG-based Clouds"* (Zambianco, Fasol,
//! Doriguzzi-Corin — CS.DC 2025).
//!
//! The paper's contribution is twofold and both parts are first-class here:
//!
//! 1. a **fragmentation score** for MIG-sliced GPUs (paper Algorithm 1) —
//!    see [`frag`]: a GPU is *fragmented w.r.t. profile p* when enough
//!    slices are free but no feasible placement index exists; the score
//!    weighs every infeasible (profile, index) pair by the profile's
//!    memory-slice footprint;
//! 2. the **Minimum Fragmentation Increment (MFI)** scheduler (Algorithm 2)
//!    — see [`sched::mfi`]: an online greedy policy that dry-runs every
//!    feasible placement of the requested profile and commits the one with
//!    the smallest fragmentation-score growth.
//!
//! Everything the paper's evaluation depends on is implemented as well:
//! the MIG hardware model with Table I placement rules ([`mig`]), the
//! baseline schedulers ([`sched`]), the Table II workload distributions and
//! trace tooling ([`workload`]), the slot-based Monte Carlo simulator and
//! the experiment/figure harness ([`sim`]), an online serving daemon with a
//! JSON-over-HTTP API ([`server`]) with Prometheus-style observability
//! ([`obs`]), and the batched evaluation runtime
//! ([`runtime`]): pure rust by default, or a PJRT runtime executing the
//! AOT-compiled JAX/Pallas fragmentation program behind the `xla` feature.
//!
//! ## Quick start
//!
//! ```no_run
//! use migsched::prelude::*;
//!
//! // A 4-GPU A100-80GB cluster and the MFI scheduler.
//! let mut cluster = Cluster::new(HardwareModel::a100_80gb(), 4);
//! let mut mfi = Mfi::new();
//! let placement = mfi
//!     .schedule(&mut cluster, Profile::P2g20gb)
//!     .expect("empty cluster accepts everything");
//! println!("placed at GPU {} index {}", placement.gpu, placement.index);
//! ```
//!
//! ## Layering
//!
//! Python (JAX + Pallas) exists only at build time: `make artifacts` lowers
//! the batched fragmentation program to HLO text under `artifacts/`, and
//! `runtime::FragEngine` (under `--features xla`) loads + compiles it once
//! through PJRT. The serve and simulation request paths are pure rust, and
//! the default build substitutes [`runtime::NativeFragEngine`] — the same
//! batched contract computed from the 256-entry score table, held to the
//! python oracle bit-for-bit by `tests/golden_frag.rs`.

pub mod cluster;
pub mod defrag;
pub mod frag;
pub mod mig;
pub mod obs;
pub mod runtime;
pub mod sched;
pub mod server;
pub mod sim;
pub mod util;
pub mod workload;

/// Convenience re-exports covering the common public API surface.
pub mod prelude {
    pub use crate::cluster::{ChangeKind, Cluster, ClusterEvent, ClusterMetrics};
    pub use crate::frag::{FragIndex, FragScorer, ScoreTable};
    pub use crate::mig::{GpuState, HardwareModel, Placement, Profile};
    pub use crate::sched::{
        BestFit, FirstFit, IndexPolicy, Mfi, MfiIndexed, RandomFit, RoundRobin, Scheduler,
        SchedulerKind, WorstFit,
    };
    pub use crate::sim::{Distribution, ExperimentConfig, SimConfig, SimEngine};
    pub use crate::util::rng::Rng;
    pub use crate::workload::{Workload, WorkloadGenerator, WorkloadId};
}
