//! Rescheduling-based defragmentation — the paper's stated future work
//! ("we are going to consider rescheduling in a future work to augment
//! the proposed scheduling logic", Section IV).
//!
//! The online scheduler never migrates running workloads (migration
//! disrupts tenants), so fragmentation released by terminations can only
//! be *avoided*, not repaired. This module adds the repair side as an
//! **offline planner**: given the current cluster state it computes a
//! bounded sequence of single-workload migrations that monotonically
//! lowers the total fragmentation score, which an operator can apply
//! during maintenance windows (or the simulator and trace replayer can
//! apply continuously — `SimConfig::defrag` / `ReplayConfig::defrag`).
//!
//! Planning is greedy: at each step consider every (allocated workload ×
//! feasible target placement) pair, simulate the move (release + place),
//! and commit the move with the largest total-F reduction; stop when no
//! move improves F or the migration budget is exhausted. Each step is
//! O(W · M · 18) table lookups — milliseconds at cluster scale.
//!
//! Migration is not free: moving an instance copies its memory footprint
//! and costs the tenant a downtime slot. [`CostModel`] prices each move
//! and [`plan_defrag_budgeted`] maximizes ΔF reduction subject to a total
//! cost budget — with budget 0 (= unlimited) it degenerates bit-for-bit
//! to the pure greedy plan, which is how [`plan_defrag`] is implemented.

use crate::cluster::Cluster;
use crate::frag::{FragScorer, ScoreTable};
use crate::mig::{GpuState, HardwareModel, Placement, Profile};
use crate::workload::WorkloadId;

/// Bytes per reported memory GB (migrated-bytes accounting).
pub const BYTES_PER_GB: u64 = 1 << 30;

/// Prices one migration: the instance's memory footprint (the bytes that
/// have to be copied) plus a flat downtime penalty per move. Costs are
/// unitless; the defaults make a 1g.10gb move cost 20 and a 7g.80gb move
/// cost 90, so a budget knob trades a few big moves against many small
/// ones.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CostModel {
    /// Cost units per GB of instance memory copied.
    pub per_gb: u64,
    /// Flat per-move penalty for the tenant's downtime slot.
    pub downtime_penalty: u64,
}

impl Default for CostModel {
    fn default() -> Self {
        Self { per_gb: 1, downtime_penalty: 10 }
    }
}

impl CostModel {
    /// Cost of migrating one instance of `p` on `hw`.
    pub fn move_cost(&self, hw: &HardwareModel, p: Profile) -> u64 {
        self.per_gb * u64::from(hw.profile_mem_gb(p)) + self.downtime_penalty
    }
}

/// Bytes copied when migrating one instance of `p` on `hw`.
pub fn move_bytes(hw: &HardwareModel, p: Profile) -> u64 {
    u64::from(hw.profile_mem_gb(p)) * BYTES_PER_GB
}

/// A continuous-defrag trigger policy, shared by the simulation engine,
/// the open-loop trace replayer and the CLI: every `every` slots (the
/// daemon interprets it as seconds), when the cluster-mean fragmentation
/// score is at least `threshold`, run one budgeted sweep.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DefragPolicy {
    /// Sweep cadence in slots (daemon: seconds). Must be positive.
    pub every: u64,
    /// Minimum cluster-mean fragmentation score for a sweep to fire
    /// (0.0 = always sweep on cadence).
    pub threshold: f64,
    /// Maximum migrations per sweep.
    pub max_moves: usize,
    /// Migration cost budget per sweep under `cost` (0 = unlimited).
    pub cost_budget: u64,
    pub cost: CostModel,
}

impl DefragPolicy {
    /// Sweep every `every` slots, unconditionally, up to 16 moves,
    /// unlimited cost (builder-style setters refine).
    pub fn every(every: u64) -> Self {
        assert!(every > 0, "defrag cadence must be positive");
        Self {
            every,
            threshold: 0.0,
            max_moves: 16,
            cost_budget: 0,
            cost: CostModel::default(),
        }
    }

    pub fn with_threshold(mut self, threshold: f64) -> Self {
        self.threshold = threshold;
        self
    }

    pub fn with_max_moves(mut self, max_moves: usize) -> Self {
        self.max_moves = max_moves;
        self
    }

    pub fn with_cost_budget(mut self, cost_budget: u64) -> Self {
        self.cost_budget = cost_budget;
        self
    }
}

/// One planned migration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Migration {
    pub workload: WorkloadId,
    pub from: Placement,
    pub to: Placement,
    /// Total-cluster fragmentation-score change of this step (< 0).
    pub delta_f: i32,
    /// Price of this move under the planning [`CostModel`].
    pub cost: u64,
}

/// A defragmentation plan: migrations in application order.
#[derive(Clone, Debug, Default)]
pub struct MigrationPlan {
    pub moves: Vec<Migration>,
    /// Cluster total F before planning.
    pub f_before: u32,
    /// Cluster total F after applying every move.
    pub f_after: u32,
    /// Sum of per-move costs under the planning [`CostModel`].
    pub total_cost: u64,
    /// Instance memory the plan copies ([`move_bytes`] per move).
    pub bytes_moved: u64,
}

impl MigrationPlan {
    pub fn is_empty(&self) -> bool {
        self.moves.is_empty()
    }

    pub fn total_delta(&self) -> i64 {
        self.f_after as i64 - self.f_before as i64
    }
}

/// Total cluster fragmentation score, each GPU under its class's table.
fn total_f(gpus: &[GpuState], class_ids: &[u8], tables: &[ScoreTable]) -> u32 {
    gpus.iter()
        .zip(class_ids)
        .map(|(&g, &c)| tables[c as usize].score(g))
        .sum()
}

/// Compute a greedy defragmentation plan with at most `max_migrations`
/// moves. The cluster is not modified; apply with [`apply_plan`].
pub fn plan_defrag(
    cluster: &Cluster,
    table: &ScoreTable,
    max_migrations: usize,
) -> MigrationPlan {
    plan_defrag_budgeted(cluster, table, max_migrations, &CostModel::default(), 0)
}

/// [`plan_defrag`] with a migration cost budget: moves whose cumulative
/// cost (under `cost`) would exceed `cost_budget` are unaffordable and
/// skipped; the greedy selection among affordable moves is otherwise
/// unchanged, so `cost_budget == 0` (= unlimited) produces the exact
/// pure-greedy plan.
pub fn plan_defrag_budgeted(
    cluster: &Cluster,
    table: &ScoreTable,
    max_migrations: usize,
    cost: &CostModel,
    cost_budget: u64,
) -> MigrationPlan {
    // Per-GPU score tables: single-class clusters use the caller's table
    // for every GPU (preserving custom-rule tables bit-for-bit); mixed
    // fleets derive each class's table under the caller's overlap rule.
    let class_tables: Vec<ScoreTable> = if cluster.is_uniform() {
        vec![table.clone()]
    } else {
        cluster
            .classes()
            .iter()
            .map(|hw| ScoreTable::for_hardware_rule(hw, table.rule()))
            .collect()
    };
    let class_ids = cluster.class_ids();
    // Work on shadow state: occupancies + the allocation list.
    let mut gpus: Vec<GpuState> = cluster.gpus().to_vec();
    let mut allocs: Vec<(WorkloadId, Placement)> = cluster.allocations().collect();
    allocs.sort_by_key(|(id, _)| *id); // determinism

    let f_before = total_f(&gpus, class_ids, &class_tables);
    let mut current_f = f_before as i64;
    let mut plan = MigrationPlan { f_before, f_after: f_before, ..MigrationPlan::default() };

    for _ in 0..max_migrations {
        // Find the single move with the best (most negative) ΔF_total.
        let mut best: Option<(usize, Placement, i64)> = None; // (alloc idx, target, ΔF)
        for (ai, &(_, from)) in allocs.iter().enumerate() {
            let profile = from.profile;
            let src_class = class_ids[from.gpu];
            let src_hw = cluster.hardware_of(from.gpu);
            let src_table = &class_tables[src_class as usize];
            if cost_budget > 0
                && plan.total_cost + cost.move_cost(src_hw, profile) > cost_budget
            {
                continue; // unaffordable this sweep
            }
            // State with the workload lifted out.
            let mut lifted = gpus[from.gpu];
            lifted
                .release(profile, from.index)
                .expect("allocation registry consistent");
            let lifted_delta =
                src_table.score(lifted) as i64 - src_table.score(gpus[from.gpu]) as i64;
            for (gpu_id, &g) in gpus.iter().enumerate() {
                // Migration preserves the workload's physical resources, so
                // only same-class GPUs are targets: on another class the
                // same profile shape has a different memory footprint (a
                // resize, not a move). Single-class clusters are unaffected.
                if class_ids[gpu_id] != src_class {
                    continue;
                }
                let host = if gpu_id == from.gpu { lifted } else { g };
                if profile.size() > host.free_slices() {
                    continue;
                }
                for &start in profile.starts() {
                    if gpu_id == from.gpu && start == from.index {
                        continue; // no-op move
                    }
                    if !host.fits_at(profile, start) {
                        continue;
                    }
                    // ΔF = (remove from source) + (add to target host).
                    // For same-GPU moves `host` IS the lifted state, so
                    // `add_delta` is measured against it and the sum stays
                    // exact in both cases. Source and target share a class,
                    // so one table prices both sides.
                    let placed = host.with_placement(profile, start);
                    let add_delta =
                        src_table.score(placed) as i64 - src_table.score(host) as i64;
                    let delta = lifted_delta + add_delta;
                    let candidate = (ai, Placement { gpu: gpu_id, profile, index: start }, delta);
                    if delta < best.map(|b| b.2).unwrap_or(0) {
                        best = Some(candidate);
                    }
                }
            }
        }
        let Some((ai, to, delta)) = best else { break };
        // Commit the move on the shadow state.
        let (wid, from) = allocs[ai];
        gpus[from.gpu].release(from.profile, from.index).unwrap();
        gpus[to.gpu].place(to.profile, to.index).unwrap();
        allocs[ai].1 = to;
        current_f += delta;
        debug_assert_eq!(
            current_f,
            total_f(&gpus, class_ids, &class_tables) as i64,
            "ΔF accounting"
        );
        let src_hw = cluster.hardware_of(from.gpu);
        let move_cost = cost.move_cost(src_hw, from.profile);
        plan.total_cost += move_cost;
        plan.bytes_moved += move_bytes(src_hw, from.profile);
        plan.moves.push(Migration { workload: wid, from, to, delta_f: delta as i32, cost: move_cost });
    }
    plan.f_after = current_f as u32;
    plan
}

/// Apply a plan to a live cluster (release + allocate per move, in order).
/// Each move is atomic: when it cannot complete — the released placement
/// does not match the plan (stale plan), or the target allocate fails —
/// the workload is put back where it was released from before the error
/// returns, so a live allocation is never dropped. Earlier moves stay
/// applied (callers treat plans as advisory).
pub fn apply_plan(cluster: &mut Cluster, plan: &MigrationPlan) -> Result<usize, String> {
    for (i, mv) in plan.moves.iter().enumerate() {
        let freed = cluster
            .release(mv.workload)
            .map_err(|e| format!("move {i}: release failed: {e}"))?;
        if freed != mv.from {
            restore(cluster, mv.workload, freed);
            return Err(format!(
                "move {i}: plan is stale (expected {}, found {})",
                mv.from, freed
            ));
        }
        if let Err(e) = cluster.allocate(mv.workload, mv.to) {
            restore(cluster, mv.workload, freed);
            return Err(format!("move {i}: allocate failed: {e}"));
        }
    }
    Ok(plan.moves.len())
}

/// Undo a mid-move release: the slices were freed a moment ago under the
/// caller's exclusive access, so re-placing them cannot fail.
fn restore(cluster: &mut Cluster, workload: WorkloadId, placement: Placement) {
    cluster
        .allocate(workload, placement)
        .expect("re-placing a just-released workload");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mig::{HardwareModel, Profile};

    fn setup() -> (Cluster, ScoreTable) {
        let hw = HardwareModel::a100_80gb();
        let table = ScoreTable::for_hardware(&hw);
        (Cluster::new(hw, 3), table)
    }

    fn alloc(c: &mut Cluster, id: u64, gpu: usize, p: Profile, idx: u8) {
        c.allocate(WorkloadId(id), Placement { gpu, profile: p, index: idx }).unwrap();
    }

    #[test]
    fn empty_cluster_needs_no_plan() {
        let (cluster, table) = setup();
        let plan = plan_defrag(&cluster, &table, 10);
        assert!(plan.is_empty());
        assert_eq!(plan.f_before, 0);
        assert_eq!(plan.f_after, 0);
    }

    #[test]
    fn repairs_misplaced_1g() {
        // A 1g.10gb at index 1 (F=12) migrates to a lower-F anchor.
        let (mut cluster, table) = setup();
        alloc(&mut cluster, 0, 0, Profile::P1g10gb, 1);
        assert_eq!(table.score(cluster.gpu(0).unwrap()), 12);
        let plan = plan_defrag(&cluster, &table, 10);
        assert!(!plan.is_empty());
        assert!(plan.f_after < plan.f_before, "{plan:?}");
        apply_plan(&mut cluster, &plan).unwrap();
        let total: u32 = cluster.gpus().iter().map(|&g| table.score(g)).sum();
        assert_eq!(total, plan.f_after);
        // The 4g anchor is usable again.
        assert!(cluster.gpu(0).unwrap().can_host(Profile::P4g40gb));
    }

    #[test]
    fn plan_respects_budget() {
        let (mut cluster, table) = setup();
        // Three badly-placed small profiles across GPUs.
        alloc(&mut cluster, 0, 0, Profile::P1g10gb, 1);
        alloc(&mut cluster, 1, 1, Profile::P1g10gb, 1);
        alloc(&mut cluster, 2, 2, Profile::P1g10gb, 3);
        let plan = plan_defrag(&cluster, &table, 1);
        assert_eq!(plan.moves.len(), 1);
        // The single move is the best available one.
        let unbounded = plan_defrag(&cluster, &table, 16);
        assert_eq!(plan.moves[0].delta_f, unbounded.moves[0].delta_f);
    }

    #[test]
    fn plan_monotonically_improves() {
        let (mut cluster, table) = setup();
        alloc(&mut cluster, 0, 0, Profile::P1g10gb, 1);
        alloc(&mut cluster, 1, 0, Profile::P1g10gb, 5);
        alloc(&mut cluster, 2, 1, Profile::P2g20gb, 2);
        alloc(&mut cluster, 3, 2, Profile::P1g20gb, 2);
        let plan = plan_defrag(&cluster, &table, 16);
        for mv in &plan.moves {
            assert!(mv.delta_f < 0, "every move strictly improves: {mv:?}");
        }
        // Applying reproduces the predicted score exactly.
        apply_plan(&mut cluster, &plan).unwrap();
        let total: u32 = cluster.gpus().iter().map(|&g| table.score(g)).sum();
        assert_eq!(total, plan.f_after);
        // And planning again finds nothing (local optimum).
        let again = plan_defrag(&cluster, &table, 16);
        assert!(again.is_empty());
    }

    #[test]
    fn defrag_restores_schedulability() {
        // Fragmented state rejecting a 7g.80gb; defrag consolidates.
        let (mut cluster, table) = setup();
        alloc(&mut cluster, 0, 0, Profile::P1g10gb, 4);
        alloc(&mut cluster, 1, 1, Profile::P1g10gb, 4);
        alloc(&mut cluster, 2, 2, Profile::P1g10gb, 4);
        assert!(!cluster.can_host(Profile::P7g80gb));
        let plan = plan_defrag(&cluster, &table, 16);
        apply_plan(&mut cluster, &plan).unwrap();
        assert!(
            cluster.can_host(Profile::P7g80gb),
            "defrag should free a whole GPU: {:?}",
            cluster.gpus().iter().map(|g| g.diagram()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn zero_cost_budget_is_unlimited_and_matches_greedy() {
        // The tentpole's bit-identity pin: budget 0 degenerates to the
        // pure greedy plan — same moves, same order, same final score.
        let (mut cluster, table) = setup();
        alloc(&mut cluster, 0, 0, Profile::P1g10gb, 1);
        alloc(&mut cluster, 1, 0, Profile::P1g10gb, 5);
        alloc(&mut cluster, 2, 1, Profile::P2g20gb, 2);
        alloc(&mut cluster, 3, 2, Profile::P1g20gb, 2);
        let greedy = plan_defrag(&cluster, &table, 16);
        let budgeted =
            plan_defrag_budgeted(&cluster, &table, 16, &CostModel::default(), 0);
        assert!(!greedy.is_empty());
        assert_eq!(greedy.moves, budgeted.moves);
        assert_eq!(greedy.f_after, budgeted.f_after);
        assert_eq!(greedy.total_cost, budgeted.total_cost);
        assert_eq!(greedy.bytes_moved, budgeted.bytes_moved);
    }

    #[test]
    fn cost_budget_filters_unaffordable_moves() {
        // Unlimited greedy on this cluster makes exactly two moves
        // (verified against the python-oracle score table): first the
        // 1g.10gb off gpu0's index 1 (cost 10 GB + 10 downtime = 20),
        // then the 2g.20gb off gpu1 into gpu2's free window (cost
        // 20 GB + 10 = 30).
        let (mut cluster, table) = setup();
        alloc(&mut cluster, 0, 0, Profile::P1g10gb, 1);
        alloc(&mut cluster, 1, 0, Profile::P1g10gb, 5);
        alloc(&mut cluster, 2, 1, Profile::P2g20gb, 2);
        alloc(&mut cluster, 3, 2, Profile::P1g20gb, 2);
        let cost = CostModel::default();

        // Budget below the cheapest move: empty plan.
        let none = plan_defrag_budgeted(&cluster, &table, 16, &cost, 19);
        assert!(none.is_empty());
        assert_eq!(none.total_cost, 0);

        // Budget 20 affords only the 1g move; the 2g repair is filtered.
        let one = plan_defrag_budgeted(&cluster, &table, 16, &cost, 20);
        assert_eq!(one.moves.len(), 1);
        assert_eq!(one.moves[0].workload, WorkloadId(0));
        assert_eq!(one.moves[0].cost, 20);
        assert_eq!(one.total_cost, 20);

        // Budget 50 affords both: bit-identical to the unlimited plan.
        let both = plan_defrag_budgeted(&cluster, &table, 16, &cost, 50);
        let unlimited = plan_defrag(&cluster, &table, 16);
        assert_eq!(both.moves.len(), 2);
        assert_eq!(both.moves[1].workload, WorkloadId(2));
        assert_eq!(both.moves[1].cost, 30);
        assert_eq!(both.total_cost, 50);
        assert_eq!(both.moves, unlimited.moves);
        assert_eq!(both.f_after, unlimited.f_after);
    }

    #[test]
    fn plan_accounts_cost_and_bytes_moved() {
        let (mut cluster, table) = setup();
        alloc(&mut cluster, 0, 0, Profile::P1g10gb, 1);
        let plan = plan_defrag(&cluster, &table, 16);
        assert_eq!(plan.moves.len(), 1);
        // Default model on A100-80GB: 1g.10gb move = 10 GB + 10 downtime.
        assert_eq!(plan.moves[0].cost, 20);
        assert_eq!(plan.total_cost, 20);
        assert_eq!(plan.bytes_moved, 10 * BYTES_PER_GB);
    }

    #[test]
    fn mixed_fleet_moves_stay_in_class_and_price_per_class() {
        // 2×A100-80GB (10 GB/slice) + 1×A100-40GB (5 GB/slice). A badly
        // placed 1g on each class: moves must not cross classes, and the
        // A100-40GB move must be priced with 5 GB instance memory.
        let fleet = crate::mig::FleetSpec::parse("a100:2,a100-40gb:1").unwrap();
        let mut cluster = Cluster::from_fleet(&fleet);
        alloc(&mut cluster, 0, 0, Profile::P1g10gb, 1);
        alloc(&mut cluster, 1, 2, Profile::P1g10gb, 1);
        let table = ScoreTable::for_hardware(cluster.hardware());
        let plan = plan_defrag(&cluster, &table, 16);
        assert!(!plan.is_empty());
        for mv in &plan.moves {
            assert_eq!(
                cluster.class_of(mv.from.gpu),
                cluster.class_of(mv.to.gpu),
                "migration crossed device classes: {mv:?}"
            );
            let expected_gb =
                u64::from(cluster.hardware_of(mv.from.gpu).profile_mem_gb(Profile::P1g10gb));
            assert_eq!(mv.cost, expected_gb + 10, "per-class pricing: {mv:?}");
        }
        // Both classes' misplacements get repaired.
        apply_plan(&mut cluster, &plan).unwrap();
        assert!(cluster.gpu(0).unwrap().can_host(Profile::P4g40gb));
        assert!(cluster.gpu(2).unwrap().can_host(Profile::P4g40gb));
        // And the bytes ledger reflects 10 GB + 5 GB instances.
        assert_eq!(plan.bytes_moved, 15 * BYTES_PER_GB);
    }

    #[test]
    fn stale_plan_detected() {
        let (mut cluster, table) = setup();
        alloc(&mut cluster, 0, 0, Profile::P1g10gb, 1);
        let plan = plan_defrag(&cluster, &table, 4);
        assert!(!plan.is_empty());
        // Mutate the cluster behind the plan's back.
        cluster.release(WorkloadId(0)).unwrap();
        alloc(&mut cluster, 0, 0, Profile::P1g10gb, 2);
        let err = apply_plan(&mut cluster, &plan).unwrap_err();
        assert!(err.contains("stale"), "{err}");
        // Regression: the aborted move must not drop the live workload —
        // it stays at the placement it actually occupied.
        assert_eq!(
            cluster.placement_of(WorkloadId(0)),
            Some(Placement { gpu: 0, profile: Profile::P1g10gb, index: 2 })
        );
    }

    #[test]
    fn failed_apply_restores_the_moving_workload() {
        // Regression: apply_plan used to release the workload and then
        // error out of the failing allocate, silently dropping a live
        // allocation from the cluster.
        let (mut cluster, table) = setup();
        alloc(&mut cluster, 0, 0, Profile::P1g10gb, 1);
        let plan = plan_defrag(&cluster, &table, 1);
        assert_eq!(plan.moves.len(), 1);
        let mv = plan.moves[0];
        // Deliberately stale target: occupy it behind the plan's back
        // (the source placement still matches, so the release succeeds
        // and the subsequent allocate is what fails).
        cluster.allocate(WorkloadId(99), mv.to).unwrap();
        let err = apply_plan(&mut cluster, &plan).unwrap_err();
        assert!(err.contains("allocate failed"), "{err}");
        assert_eq!(
            cluster.placement_of(mv.workload),
            Some(mv.from),
            "the moving workload must survive at its source placement"
        );
        assert_eq!(cluster.allocated_workloads(), 2);
        // Accounting stayed intact: both workloads' slices are live.
        assert_eq!(cluster.used_slices(), 2);
    }
}
