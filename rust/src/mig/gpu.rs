//! Per-GPU slice occupancy state machine.
//!
//! A GPU's MIG state is fully captured by which of its 8 memory-slice
//! positions are occupied — a single `u8` bitmask. All placement rules
//! (contiguity + Table I anchor constraints) are enforced here; higher
//! layers (cluster, schedulers) never manipulate raw masks.

use super::placement::Placement;
use super::profile::{Profile, NUM_SLICES};

/// Occupancy state of one GPU.
///
/// The zero value is an empty GPU. `Copy` on purpose: schedulers dry-run
/// placements on copies, which is how the paper's Algorithm 2 "hypothetical
/// allocation" is realized without undo logic.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub struct GpuState {
    occ: u8,
}

impl GpuState {
    /// An empty GPU.
    pub fn empty() -> Self {
        Self { occ: 0 }
    }

    /// Rebuild from a raw occupancy bitmask (snapshots, tests, the XLA
    /// engine's occupancy matrix).
    pub fn from_mask(occ: u8) -> Self {
        Self { occ }
    }

    /// Raw occupancy bitmask; bit `i` ⇔ slice `i` occupied.
    #[inline]
    pub fn mask(self) -> u8 {
        self.occ
    }

    #[inline]
    pub fn is_empty(self) -> bool {
        self.occ == 0
    }

    #[inline]
    pub fn is_full(self) -> bool {
        self.occ == 0xFF
    }

    /// Number of occupied slices.
    #[inline]
    pub fn used_slices(self) -> u8 {
        self.occ.count_ones() as u8
    }

    /// `ΔS` in the paper: number of unused slices.
    #[inline]
    pub fn free_slices(self) -> u8 {
        NUM_SLICES as u8 - self.used_slices()
    }

    #[inline]
    pub fn slice_occupied(self, idx: u8) -> bool {
        debug_assert!((idx as usize) < NUM_SLICES);
        self.occ & (1 << idx) != 0
    }

    /// Can `profile` anchor at `start` right now? (window entirely free —
    /// the paper's feasibility condition at one index).
    #[inline]
    pub fn fits_at(self, profile: Profile, start: u8) -> bool {
        self.occ & profile.mask_at(start) == 0
    }

    /// Feasible anchor indexes for `profile`, ascending.
    pub fn feasible_indexes(self, profile: Profile) -> impl Iterator<Item = u8> + 'static {
        let occ = self.occ;
        profile.starts().iter().copied().filter(move |&s| {
            occ & ((((1u16 << profile.size()) - 1) << s) as u8) == 0
        })
    }

    /// First feasible anchor, ascending index order (the "first available
    /// index" policy the paper's MIG-agnostic baselines use).
    pub fn first_feasible(self, profile: Profile) -> Option<u8> {
        self.feasible_indexes(profile).next()
    }

    /// Last feasible anchor, descending index order (the "best index"
    /// preference policy of the MIG-aware baselines; see
    /// [`crate::sched::IndexPolicy`]).
    pub fn best_feasible(self, profile: Profile) -> Option<u8> {
        self.feasible_indexes(profile).last()
    }

    /// Whether any feasible placement exists.
    #[inline]
    pub fn can_host(self, profile: Profile) -> bool {
        self.first_feasible(profile).is_some()
    }

    /// The paper's *fragmented w.r.t. p* predicate (Section V-B Definition):
    /// enough free slices, yet no feasible anchor.
    pub fn fragmented_for(self, profile: Profile) -> bool {
        profile.size() <= self.free_slices() && !self.can_host(profile)
    }

    /// Hypothetical state after placing `profile` at `start` (dry-run).
    /// Panics (debug) if the window is not free.
    #[inline]
    pub fn with_placement(self, profile: Profile, start: u8) -> GpuState {
        let m = profile.mask_at(start);
        debug_assert_eq!(self.occ & m, 0, "window not free: occ={:08b} mask={m:08b}", self.occ);
        GpuState { occ: self.occ | m }
    }

    /// Commit a placement. Returns an error if the window is not entirely
    /// free (double-allocation is a bug in the caller, but the server layer
    /// surfaces it as a 409 rather than crashing the daemon).
    pub fn place(&mut self, profile: Profile, start: u8) -> Result<(), PlacementError> {
        if !profile.starts().contains(&start) {
            return Err(PlacementError::InfeasibleIndex { profile, start });
        }
        let m = profile.mask_at(start);
        if self.occ & m != 0 {
            return Err(PlacementError::Occupied { profile, start, occ: self.occ });
        }
        self.occ |= m;
        Ok(())
    }

    /// Release a previously committed placement. Errors if those slices are
    /// not currently all occupied (double-free detection).
    pub fn release(&mut self, profile: Profile, start: u8) -> Result<(), PlacementError> {
        if !profile.starts().contains(&start) {
            return Err(PlacementError::InfeasibleIndex { profile, start });
        }
        let m = profile.mask_at(start);
        if self.occ & m != m {
            return Err(PlacementError::NotAllocated { profile, start, occ: self.occ });
        }
        self.occ &= !m;
        Ok(())
    }

    /// Render as an 8-character slice diagram, MSB = slice 7 … LSB = slice 0
    /// reversed so slice 0 prints first: `"##..####"` means slices 0,1 and
    /// 4..=7 occupied.
    pub fn diagram(self) -> String {
        (0..NUM_SLICES as u8)
            .map(|i| if self.slice_occupied(i) { '#' } else { '.' })
            .collect()
    }
}

/// Errors from committing/releasing placements.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlacementError {
    /// The anchor index is not in the profile's Table I feasible set.
    InfeasibleIndex { profile: Profile, start: u8 },
    /// Some slice in the window is already occupied.
    Occupied { profile: Profile, start: u8, occ: u8 },
    /// Release of a window that is not fully allocated.
    NotAllocated { profile: Profile, start: u8, occ: u8 },
}

impl std::fmt::Display for PlacementError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PlacementError::InfeasibleIndex { profile, start } => {
                write!(f, "index {start} is not a feasible anchor for {profile}")
            }
            PlacementError::Occupied { profile, start, occ } => {
                write!(f, "cannot place {profile} at {start}: occupancy {occ:#010b}")
            }
            PlacementError::NotAllocated { profile, start, occ } => {
                write!(f, "cannot release {profile} at {start}: occupancy {occ:#010b}")
            }
        }
    }
}

impl std::error::Error for PlacementError {}

/// Apply a [`Placement`]'s (profile, index) part to a [`GpuState`]
/// — convenience for cluster-level code.
pub fn apply(gpu: &mut GpuState, p: &Placement) -> Result<(), PlacementError> {
    gpu.place(p.profile, p.index)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mig::profile::ALL_PROFILES;

    #[test]
    fn empty_gpu_hosts_everything() {
        let g = GpuState::empty();
        for p in ALL_PROFILES {
            assert!(g.can_host(p), "{p}");
            assert!(!g.fragmented_for(p), "{p}");
            assert_eq!(g.first_feasible(p), Some(p.starts()[0]));
            assert_eq!(g.best_feasible(p), Some(*p.starts().last().unwrap()));
        }
        assert_eq!(g.free_slices(), 8);
        assert!(g.is_empty());
    }

    #[test]
    fn place_and_release_roundtrip() {
        let mut g = GpuState::empty();
        g.place(Profile::P3g40gb, 4).unwrap();
        assert_eq!(g.mask(), 0b1111_0000);
        assert_eq!(g.used_slices(), 4);
        assert_eq!(g.diagram(), "....####");
        g.release(Profile::P3g40gb, 4).unwrap();
        assert!(g.is_empty());
    }

    #[test]
    fn rejects_infeasible_anchor() {
        let mut g = GpuState::empty();
        assert_eq!(
            g.place(Profile::P4g40gb, 4),
            Err(PlacementError::InfeasibleIndex { profile: Profile::P4g40gb, start: 4 })
        );
        assert_eq!(
            g.place(Profile::P2g20gb, 1),
            Err(PlacementError::InfeasibleIndex { profile: Profile::P2g20gb, start: 1 })
        );
    }

    #[test]
    fn rejects_overlap() {
        let mut g = GpuState::empty();
        g.place(Profile::P2g20gb, 2).unwrap();
        let err = g.place(Profile::P3g40gb, 0).unwrap_err();
        assert!(matches!(err, PlacementError::Occupied { .. }));
        // But index 4 is free:
        g.place(Profile::P3g40gb, 4).unwrap();
        assert_eq!(g.mask(), 0b1111_1100);
    }

    #[test]
    fn double_free_detected() {
        let mut g = GpuState::empty();
        g.place(Profile::P1g10gb, 3).unwrap();
        g.release(Profile::P1g10gb, 3).unwrap();
        assert!(matches!(
            g.release(Profile::P1g10gb, 3),
            Err(PlacementError::NotAllocated { .. })
        ));
    }

    #[test]
    fn paper_fig3a_fragmentation_predicate() {
        // The paper's Fig. 3a GPU 2 narrative: slices occupied such that
        // 1g.10gb/2g.20gb still fit but 3g.40gb/4g.40gb are fragmented.
        // Construct: 1g.10gb at 1 and at 5 → occ = 0b0010_0010 (6 free).
        let mut g = GpuState::empty();
        g.place(Profile::P1g10gb, 1).unwrap();
        g.place(Profile::P1g10gb, 5).unwrap();
        assert!(g.can_host(Profile::P1g10gb));
        assert!(g.can_host(Profile::P2g20gb));
        assert!(g.fragmented_for(Profile::P3g40gb), "enough slices but both anchors blocked");
        assert!(g.fragmented_for(Profile::P4g40gb));
        // 7g.80gb is NOT fragmented: not enough free slices at all.
        assert!(!g.fragmented_for(Profile::P7g80gb));
    }

    #[test]
    fn misplaced_small_profile_blocks_big_one() {
        // Paper Section V-B: "scheduling profile 1g.10gb on MIG slice at
        // index 1 prevents the allocation of MIG profile 4g.40gb".
        let g = GpuState::empty().with_placement(Profile::P1g10gb, 1);
        assert!(!g.can_host(Profile::P4g40gb));
        assert!(g.fragmented_for(Profile::P4g40gb));
    }

    #[test]
    fn feasible_indexes_ordering() {
        let mut g = GpuState::empty();
        g.place(Profile::P2g20gb, 2).unwrap();
        let idx: Vec<u8> = g.feasible_indexes(Profile::P1g20gb).collect();
        assert_eq!(idx, vec![0, 4, 6]);
        assert_eq!(g.first_feasible(Profile::P1g20gb), Some(0));
        assert_eq!(g.best_feasible(Profile::P1g20gb), Some(6));
    }

    #[test]
    fn full_gpu() {
        let mut g = GpuState::empty();
        g.place(Profile::P7g80gb, 0).unwrap();
        assert!(g.is_full());
        assert_eq!(g.free_slices(), 0);
        for p in ALL_PROFILES {
            assert!(!g.can_host(p));
            assert!(!g.fragmented_for(p), "full GPU is saturated, not fragmented");
        }
    }

    #[test]
    fn seven_independent_1g_instances() {
        // MIG's headline: up to seven isolated instances per GPU.
        let mut g = GpuState::empty();
        for i in 0..7 {
            g.place(Profile::P1g10gb, i).unwrap();
        }
        assert_eq!(g.used_slices(), 7);
        assert_eq!(g.free_slices(), 1); // slice 7 unreachable for 1g.10gb
        for p in ALL_PROFILES {
            assert!(!g.can_host(p));
        }
    }

    #[test]
    fn with_placement_is_pure() {
        let g = GpuState::empty();
        let h = g.with_placement(Profile::P4g40gb, 0);
        assert!(g.is_empty());
        assert_eq!(h.used_slices(), 4);
    }

    #[test]
    fn diagram_rendering() {
        let g = GpuState::from_mask(0b1100_0011);
        assert_eq!(g.diagram(), "##....##");
    }
}
