//! Placement descriptors and the static candidate table.
//!
//! A *candidate* is a (profile, anchor index) pair; there are exactly 18 of
//! them on the 8-slice model (1+1+2+3+4+7, Table I). The candidate table is
//! the shared vocabulary between the native fragmentation engine
//! ([`crate::frag`]), the XLA-offloaded engine ([`crate::runtime`]) and the
//! python build path (`python/compile/model.py` embeds the same table —
//! asserted equal by `python/tests/test_model.py` against
//! `artifacts/candidates.json` exported from this module).

use super::profile::Profile;
#[cfg(test)]
use super::profile::ALL_PROFILES;

/// A committed or proposed placement of a profile on a specific GPU.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Placement {
    /// GPU id within the cluster.
    pub gpu: usize,
    /// The MIG profile shape placed.
    pub profile: Profile,
    /// Anchor slice index (member of `profile.starts()`).
    pub index: u8,
}

impl std::fmt::Display for Placement {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}@gpu{}[{}]", self.profile, self.gpu, self.index)
    }
}

/// One (profile, anchor) candidate with its precomputed occupancy mask.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Candidate {
    pub profile: Profile,
    pub start: u8,
    /// Bit `i` set ⇔ slice `i` covered by this placement.
    pub mask: u8,
}

/// Total number of (profile, anchor) candidates.
pub const NUM_CANDIDATES: usize = 18;

/// The full candidate table in (Table I profile order, ascending anchor)
/// order. This ordering is frozen: the XLA artifact's `[M, 18]` delta
/// output is indexed by it.
pub static CANDIDATES: [Candidate; NUM_CANDIDATES] = build_candidates();

const fn build_candidates() -> [Candidate; NUM_CANDIDATES] {
    // const-fn construction keeps the table in rodata and lets the python
    // side be checked against an exported copy rather than re-derived.
    const fn cand(profile: Profile, start: u8, size: u8) -> Candidate {
        Candidate { profile, start, mask: (((1u16 << size) - 1) << start) as u8 }
    }
    [
        cand(Profile::P7g80gb, 0, 8),
        cand(Profile::P4g40gb, 0, 4),
        cand(Profile::P3g40gb, 0, 4),
        cand(Profile::P3g40gb, 4, 4),
        cand(Profile::P2g20gb, 0, 2),
        cand(Profile::P2g20gb, 2, 2),
        cand(Profile::P2g20gb, 4, 2),
        cand(Profile::P1g20gb, 0, 2),
        cand(Profile::P1g20gb, 2, 2),
        cand(Profile::P1g20gb, 4, 2),
        cand(Profile::P1g20gb, 6, 2),
        cand(Profile::P1g10gb, 0, 1),
        cand(Profile::P1g10gb, 1, 1),
        cand(Profile::P1g10gb, 2, 1),
        cand(Profile::P1g10gb, 3, 1),
        cand(Profile::P1g10gb, 4, 1),
        cand(Profile::P1g10gb, 5, 1),
        cand(Profile::P1g10gb, 6, 1),
    ]
}

/// Candidate-table range `[lo, hi)` for one profile; the XLA delta vector
/// for profile `p` lives at columns `candidate_range(p)`. Constant-time
/// (the table layout is frozen; the partition is asserted in tests).
#[inline]
pub fn candidate_range(profile: Profile) -> std::ops::Range<usize> {
    match profile {
        Profile::P7g80gb => 0..1,
        Profile::P4g40gb => 1..2,
        Profile::P3g40gb => 2..4,
        Profile::P2g20gb => 4..7,
        Profile::P1g20gb => 7..11,
        Profile::P1g10gb => 11..18,
    }
}

/// Export the candidate table as JSON (consumed by `make artifacts` to
/// cross-check the python copy, and by external tooling).
pub fn candidates_json() -> crate::util::json::Json {
    use crate::util::json::Json;
    Json::Arr(
        CANDIDATES
            .iter()
            .map(|c| {
                Json::obj()
                    .with("profile", c.profile.canonical_name())
                    .with("profile_index", c.profile.index())
                    .with("start", c.start as u64)
                    .with("size", c.profile.size() as u64)
                    .with("mem_weight", c.profile.mem_weight() as u64)
                    .with("mask", c.mask as u64)
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_matches_profile_starts() {
        let mut expect = Vec::new();
        for p in ALL_PROFILES {
            for &s in p.starts() {
                expect.push((p, s, p.mask_at(s)));
            }
        }
        assert_eq!(expect.len(), NUM_CANDIDATES);
        for (c, (p, s, m)) in CANDIDATES.iter().zip(expect) {
            assert_eq!((c.profile, c.start, c.mask), (p, s, m));
        }
    }

    #[test]
    fn ranges_partition_the_table() {
        let mut covered = 0usize;
        for p in ALL_PROFILES {
            let r = candidate_range(p);
            assert_eq!(r.start, covered, "{p}");
            for i in r.clone() {
                assert_eq!(CANDIDATES[i].profile, p);
            }
            covered = r.end;
        }
        assert_eq!(covered, NUM_CANDIDATES);
    }

    #[test]
    fn json_export_is_complete() {
        let j = candidates_json();
        let arr = j.as_arr().unwrap();
        assert_eq!(arr.len(), NUM_CANDIDATES);
        assert_eq!(arr[0].req_str("profile").unwrap(), "7g.80gb");
        assert_eq!(arr[0].req_u64("mask").unwrap(), 255);
        assert_eq!(arr[17].req_u64("mask").unwrap(), 1 << 6);
    }

    #[test]
    fn placement_display() {
        let pl = Placement { gpu: 3, profile: Profile::P2g20gb, index: 4 };
        assert_eq!(pl.to_string(), "2g.20gb@gpu3[4]");
    }
}
