//! The MIG hardware model: profiles, placement rules and per-GPU slice
//! state (paper Section III, Table I).
//!
//! NVIDIA MIG partitions a GPU into up to seven compute-isolated instances
//! built from *slices*. Following the paper we model a GPU as `S = 8`
//! memory-slice positions (indexes `0..=7`); a MIG profile occupies a
//! *contiguous* run of slices anchored at one of a small set of feasible
//! start indexes. The combination of contiguity and anchor constraints is
//! exactly what makes MIG clusters fragment.

pub mod fleet;
pub mod gpu;
pub mod hardware;
pub mod placement;
pub mod profile;

pub use fleet::FleetSpec;
pub use gpu::GpuState;
pub use hardware::HardwareModel;
pub use placement::{candidate_range, candidates_json, Candidate, Placement, CANDIDATES, NUM_CANDIDATES};
pub use profile::{Profile, ALL_PROFILES, NUM_PROFILES, NUM_SLICES};
