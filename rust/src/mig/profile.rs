//! MIG profile shapes and the Table I placement-rule data.
//!
//! The six canonical MIG shapes (compute slices `g`, memory slices, feasible
//! anchor indexes) are identical across MIG-capable parts — A100-40/80GB,
//! H100-80GB, H200-141GB — only the per-slice memory size (and thus the
//! profile *names*) changes; naming lives in [`super::hardware`].
//!
//! One deliberate clarification of the paper's Table I (see DESIGN.md §2.1):
//! `7g.80gb` is modeled as occupying all **8** memory slices. Table I lists
//! it as 7 slices, but slice 7 is not a feasible anchor for any profile and
//! is only ever covered by windows that also cover slice 6, so no reachable
//! allocation pattern distinguishes the two choices; occupy-8 keeps
//! `ΔS = 0` for a saturated GPU. The equivalence is proven exhaustively in
//! `frag::score::tests::occupy7_vs_8_equivalence`.

/// Number of memory-slice positions per GPU.
pub const NUM_SLICES: usize = 8;

/// Number of MIG profile shapes.
pub const NUM_PROFILES: usize = 6;

/// A MIG profile shape, ordered as in the paper's Table I (largest first).
///
/// Names follow the A100-80GB convention `<g>g.<mem>gb`; on other hardware
/// models the same shapes carry different memory sizes (see
/// [`super::HardwareModel::profile_name`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
#[repr(u8)]
pub enum Profile {
    /// 7 compute slices, all 8 memory slices — the whole GPU.
    P7g80gb = 0,
    /// 4 compute slices, 4 memory slices; anchors only at index 0.
    P4g40gb = 1,
    /// 3 compute slices, 4 memory slices; anchors at 0 or 4.
    P3g40gb = 2,
    /// 2 compute slices, 2 memory slices; anchors at 0, 2 or 4.
    P2g20gb = 3,
    /// 1 compute slice, 2 memory slices; anchors at 0, 2, 4 or 6.
    P1g20gb = 4,
    /// 1 compute slice, 1 memory slice; anchors at 0..=6.
    P1g10gb = 5,
}

/// All profiles in Table I order (largest → smallest).
pub const ALL_PROFILES: [Profile; NUM_PROFILES] = [
    Profile::P7g80gb,
    Profile::P4g40gb,
    Profile::P3g40gb,
    Profile::P2g20gb,
    Profile::P1g20gb,
    Profile::P1g10gb,
];

/// Occupied (memory) slices per profile, Table I order.
const SIZES: [u8; NUM_PROFILES] = [8, 4, 4, 2, 2, 1];

/// Compute slices per profile (the `<g>` in the name), Table I order.
const COMPUTE: [u8; NUM_PROFILES] = [7, 4, 3, 2, 1, 1];

/// Feasible anchor indexes per profile (paper Table I "Index" column).
const STARTS: [&[u8]; NUM_PROFILES] =
    [&[0], &[0], &[0, 4], &[0, 2, 4], &[0, 2, 4, 6], &[0, 1, 2, 3, 4, 5, 6]];

impl Profile {
    /// Profile from its Table I row index.
    pub fn from_index(idx: usize) -> Option<Profile> {
        ALL_PROFILES.get(idx).copied()
    }

    /// Table I row index (also the array index used throughout).
    #[inline]
    pub fn index(self) -> usize {
        self as usize
    }

    /// Number of contiguous memory slices the profile occupies.
    #[inline]
    pub fn size(self) -> u8 {
        SIZES[self as usize]
    }

    /// Number of compute (SM) slices.
    #[inline]
    pub fn compute_slices(self) -> u8 {
        COMPUTE[self as usize]
    }

    /// Memory-slice count — the weight `r^mem` in the paper's Algorithm 1.
    ///
    /// Equal to [`Profile::size`] for every shape (memory slices are what a
    /// profile occupies in the 8-position model); kept as a distinct
    /// accessor because the two play different roles in the algorithm.
    #[inline]
    pub fn mem_weight(self) -> u32 {
        SIZES[self as usize] as u32
    }

    /// Feasible anchor indexes `I_p`.
    #[inline]
    pub fn starts(self) -> &'static [u8] {
        STARTS[self as usize]
    }

    /// Occupancy bitmask of a placement anchored at `start`.
    ///
    /// Bit `i` set ⇔ slice `i` occupied. Panics if `start` is not feasible
    /// for the profile (all callers iterate `starts()`).
    #[inline]
    pub fn mask_at(self, start: u8) -> u8 {
        debug_assert!(
            self.starts().contains(&start),
            "{self:?} cannot anchor at index {start}"
        );
        (((1u16 << self.size()) - 1) << start) as u8
    }

    /// Canonical A100-80GB profile name.
    pub fn canonical_name(self) -> &'static str {
        match self {
            Profile::P7g80gb => "7g.80gb",
            Profile::P4g40gb => "4g.40gb",
            Profile::P3g40gb => "3g.40gb",
            Profile::P2g20gb => "2g.20gb",
            Profile::P1g20gb => "1g.20gb",
            Profile::P1g10gb => "1g.10gb",
        }
    }

    /// Parse a canonical A100-80GB name (as used in configs and the API).
    pub fn parse(name: &str) -> Option<Profile> {
        ALL_PROFILES
            .iter()
            .copied()
            .find(|p| p.canonical_name().eq_ignore_ascii_case(name.trim()))
    }

    /// Maximum number of simultaneous instances of this profile on one GPU
    /// (Table I "No. Instances" column).
    pub fn max_instances(self) -> usize {
        // All anchors of one profile are non-overlapping except 1g.10gb,
        // whose 7 anchors are each a single distinct slice — so for every
        // shape the anchor count IS the instance count.
        self.starts().len()
    }
}

impl std::fmt::Display for Profile {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.canonical_name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Table I, asserted verbatim (experiment id T1 in DESIGN.md §4).
    #[test]
    fn table_i_data() {
        let rows: [(Profile, u8, usize, &[u8]); 6] = [
            (Profile::P7g80gb, 8, 1, &[0]),
            (Profile::P4g40gb, 4, 1, &[0]),
            (Profile::P3g40gb, 4, 2, &[0, 4]),
            (Profile::P2g20gb, 2, 3, &[0, 2, 4]),
            (Profile::P1g20gb, 2, 4, &[0, 2, 4, 6]),
            (Profile::P1g10gb, 1, 7, &[0, 1, 2, 3, 4, 5, 6]),
        ];
        for (p, size, n_inst, starts) in rows {
            assert_eq!(p.size(), size, "{p}");
            assert_eq!(p.max_instances(), n_inst, "{p}");
            assert_eq!(p.starts(), starts, "{p}");
        }
    }

    #[test]
    fn compute_slices_match_names() {
        assert_eq!(Profile::P7g80gb.compute_slices(), 7);
        assert_eq!(Profile::P4g40gb.compute_slices(), 4);
        assert_eq!(Profile::P3g40gb.compute_slices(), 3);
        assert_eq!(Profile::P2g20gb.compute_slices(), 2);
        assert_eq!(Profile::P1g20gb.compute_slices(), 1);
        assert_eq!(Profile::P1g10gb.compute_slices(), 1);
    }

    #[test]
    fn mem_weight_matches_paper_example_weights() {
        // Pinned by the paper's worked example F(2) = 2 + 2 + 8 + 4 = 16.
        assert_eq!(Profile::P1g20gb.mem_weight(), 2);
        assert_eq!(Profile::P2g20gb.mem_weight(), 2);
        assert_eq!(Profile::P3g40gb.mem_weight(), 4);
        assert_eq!(Profile::P4g40gb.mem_weight(), 4);
        assert_eq!(Profile::P1g10gb.mem_weight(), 1);
        assert_eq!(Profile::P7g80gb.mem_weight(), 8);
    }

    #[test]
    fn masks_are_contiguous_and_in_range() {
        for p in ALL_PROFILES {
            for &s in p.starts() {
                let m = p.mask_at(s);
                assert_eq!(m.count_ones() as u8, p.size(), "{p}@{s}");
                // Contiguity: m >> trailing_zeros must be 2^size - 1.
                let shifted = m >> m.trailing_zeros();
                assert_eq!(shifted, ((1u16 << p.size()) - 1) as u8, "{p}@{s}");
                // In range: start + size <= 8.
                assert!(s + p.size() <= NUM_SLICES as u8, "{p}@{s}");
            }
        }
    }

    #[test]
    fn anchors_within_a_profile_do_not_overlap_except_none() {
        // For each profile, anchors are spaced >= size apart, so the
        // max_instances() derivation in the Table I test is justified.
        for p in ALL_PROFILES {
            let starts = p.starts();
            for w in starts.windows(2) {
                assert!(w[1] - w[0] >= p.size() || p == Profile::P1g10gb, "{p}");
            }
        }
    }

    #[test]
    fn name_roundtrip() {
        for p in ALL_PROFILES {
            assert_eq!(Profile::parse(p.canonical_name()), Some(p));
            assert_eq!(Profile::parse(&p.canonical_name().to_uppercase()), Some(p));
        }
        assert_eq!(Profile::parse("5g.50gb"), None);
        assert_eq!(Profile::parse(""), None);
    }

    #[test]
    fn from_index_roundtrip() {
        for (i, p) in ALL_PROFILES.iter().enumerate() {
            assert_eq!(Profile::from_index(i), Some(*p));
            assert_eq!(p.index(), i);
        }
        assert_eq!(Profile::from_index(6), None);
    }

    #[test]
    fn display_is_canonical() {
        assert_eq!(format!("{}", Profile::P3g40gb), "3g.40gb");
    }
}
