//! Fleet composition: an ordered list of device classes for heterogeneous
//! clusters.
//!
//! The paper evaluates on a homogeneous A100-80GB fleet; real MIG clouds
//! mix device generations with different memory-per-slice (A100 40/80GB,
//! H100, H200). A [`FleetSpec`] names the cluster's device classes in
//! order — `(HardwareModel, count)` pairs — and is the single source of
//! truth for per-GPU class assignment: GPUs are laid out as consecutive
//! runs, class 0 first, so GPU ids and class ids are both stable and a
//! single-class fleet is indistinguishable from the legacy
//! `(hardware, num_gpus)` pair.
//!
//! The CLI grammar is `model:count[,model:count...]`, e.g.
//! `--fleet "a100:64,h100:32,a100_40gb:16"`; model names are resolved by
//! [`HardwareModel::by_name`] (case-insensitive, `_` and `-` equivalent).

use super::hardware::HardwareModel;

/// An ordered list of `(HardwareModel, count)` device classes.
#[derive(Clone, Debug, PartialEq)]
pub struct FleetSpec {
    classes: Vec<(HardwareModel, usize)>,
}

impl FleetSpec {
    /// Build a fleet from explicit classes. Every class must have a
    /// positive count and at least one class must be present.
    pub fn new(classes: Vec<(HardwareModel, usize)>) -> Result<Self, String> {
        if classes.is_empty() {
            return Err("fleet spec has no device classes".to_string());
        }
        for (hw, count) in &classes {
            if *count == 0 {
                return Err(format!("device class '{}' has a zero GPU count", hw.name()));
            }
        }
        Ok(Self { classes })
    }

    /// The homogeneous special case: one class, `count` GPUs.
    pub fn uniform(hw: HardwareModel, count: usize) -> Self {
        assert!(count > 0, "a fleet needs at least one GPU");
        Self { classes: vec![(hw, count)] }
    }

    /// Parse the CLI grammar `model:count[,model:count...]`.
    ///
    /// Errors are complete sentences naming the offending entry: unknown
    /// model names, non-numeric or zero counts, and malformed entries are
    /// all rejected (the acceptance contract of `--fleet`).
    pub fn parse(spec: &str) -> Result<Self, String> {
        let spec = spec.trim();
        if spec.is_empty() {
            return Err(
                "empty fleet spec (expected \"model:count[,model:count...]\", \
                 e.g. \"a100:64,h100:32\")"
                    .to_string(),
            );
        }
        let mut classes = Vec::new();
        for entry in spec.split(',') {
            let entry = entry.trim();
            let (name, count) = entry.split_once(':').ok_or_else(|| {
                format!("bad fleet entry '{entry}' (expected model:count, e.g. a100:64)")
            })?;
            let name = name.trim();
            let hw = HardwareModel::by_name(name).ok_or_else(|| {
                format!("unknown hardware model '{name}' in fleet spec")
            })?;
            let count: usize = count.trim().parse().map_err(|_| {
                format!("bad GPU count '{}' for fleet class '{name}'", count.trim())
            })?;
            if count == 0 {
                return Err(format!("device class '{name}' has a zero GPU count"));
            }
            classes.push((hw, count));
        }
        Self::new(classes)
    }

    /// The classes in declaration order.
    pub fn classes(&self) -> &[(HardwareModel, usize)] {
        &self.classes
    }

    pub fn num_classes(&self) -> usize {
        self.classes.len()
    }

    /// A single-class fleet — the byte-compatible legacy path.
    pub fn is_uniform(&self) -> bool {
        self.classes.len() == 1
    }

    pub fn total_gpus(&self) -> usize {
        self.classes.iter().map(|(_, n)| n).sum()
    }

    /// The hardware model of class `idx` (panics out of range).
    pub fn class(&self, idx: usize) -> &HardwareModel {
        &self.classes[idx].0
    }

    /// The class models without counts, in class-id order.
    pub fn models(&self) -> Vec<HardwareModel> {
        self.classes.iter().map(|(hw, _)| hw.clone()).collect()
    }

    /// Per-class GPU counts, in class-id order.
    pub fn counts(&self) -> Vec<usize> {
        self.classes.iter().map(|(_, n)| *n).collect()
    }

    /// Canonical spec string (`a100-80gb:64,h100-80gb:32`); parses back to
    /// an equal fleet for the built-in models.
    pub fn spec_string(&self) -> String {
        self.classes
            .iter()
            .map(|(hw, n)| format!("{}:{n}", hw.name().to_ascii_lowercase()))
            .collect::<Vec<_>>()
            .join(",")
    }

    /// Split the fleet across `shards` shards preserving class composition:
    /// each class's count is divided by largest remainder, earlier shards
    /// taking the extra GPU. Returns `[shard][class] -> count`; some shard
    /// rows may be all-zero for tiny classes (callers that need every shard
    /// non-empty must check). For a single-class fleet this reproduces the
    /// legacy even partition (10 GPUs / 3 shards → sizes [4, 3, 3]).
    pub fn partition(&self, shards: usize) -> Vec<Vec<usize>> {
        assert!(shards > 0, "need at least one shard");
        let mut out = vec![vec![0usize; self.classes.len()]; shards];
        for (class, (_, count)) in self.classes.iter().enumerate() {
            let base = count / shards;
            let rem = count % shards;
            for (shard, row) in out.iter_mut().enumerate() {
                row[class] = base + usize::from(shard < rem);
            }
        }
        out
    }
}

impl std::fmt::Display for FleetSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.spec_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_the_issue_example() {
        let f = FleetSpec::parse("a100:64,h100:32,a100_40gb:16").unwrap();
        assert_eq!(f.num_classes(), 3);
        assert_eq!(f.total_gpus(), 112);
        assert_eq!(f.class(0).name(), "A100-80GB");
        assert_eq!(f.class(1).name(), "H100-80GB");
        assert_eq!(f.class(2).name(), "A100-40GB");
        assert!(!f.is_uniform());
        assert_eq!(f.counts(), vec![64, 32, 16]);
    }

    #[test]
    fn parse_tolerates_whitespace_and_case() {
        let f = FleetSpec::parse(" A100 : 2 , H200-141GB : 1 ").unwrap();
        assert_eq!(f.total_gpus(), 3);
        assert_eq!(f.class(1).name(), "H200-141GB");
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        for (spec, needle) in [
            ("", "empty fleet spec"),
            ("a100", "expected model:count"),
            ("v100:4", "unknown hardware model 'v100'"),
            ("a100:zero", "bad GPU count 'zero'"),
            ("a100:0", "zero GPU count"),
            ("a100:2,h100:0", "zero GPU count"),
            ("a100:-1", "bad GPU count"),
        ] {
            let err = FleetSpec::parse(spec).unwrap_err();
            assert!(err.contains(needle), "spec {spec:?}: {err}");
        }
    }

    #[test]
    fn uniform_is_the_single_class_case() {
        let f = FleetSpec::uniform(HardwareModel::a100_80gb(), 10);
        assert!(f.is_uniform());
        assert_eq!(f.total_gpus(), 10);
        assert_eq!(f.spec_string(), "a100-80gb:10");
        // The canonical string parses back to the same fleet.
        assert_eq!(FleetSpec::parse(&f.spec_string()).unwrap(), f);
    }

    #[test]
    fn spec_string_round_trips_mixed_fleets() {
        let f = FleetSpec::parse("a100:3,h100:2,h200:1").unwrap();
        assert_eq!(f.spec_string(), "a100-80gb:3,h100-80gb:2,h200-141gb:1");
        assert_eq!(FleetSpec::parse(&f.spec_string()).unwrap(), f);
    }

    #[test]
    fn partition_preserves_class_composition() {
        let f = FleetSpec::parse("a100:10,h100:5,a100-40gb:2").unwrap();
        let parts = f.partition(3);
        assert_eq!(parts.len(), 3);
        // Per-class totals conserved across shards.
        for class in 0..3 {
            let total: usize = parts.iter().map(|row| row[class]).sum();
            assert_eq!(total, f.counts()[class], "class {class}");
        }
        // Largest remainder, earlier shards first: 10→[4,3,3], 5→[2,2,1],
        // 2→[1,1,0].
        assert_eq!(parts[0], vec![4, 2, 1]);
        assert_eq!(parts[1], vec![3, 2, 1]);
        assert_eq!(parts[2], vec![3, 1, 0]);
    }

    #[test]
    fn partition_matches_legacy_even_split_for_uniform() {
        // The PR 4 pin: 10 GPUs over 3 shards → sizes [4, 3, 3].
        let f = FleetSpec::uniform(HardwareModel::a100_80gb(), 10);
        let parts = f.partition(3);
        let sizes: Vec<usize> = parts.iter().map(|row| row.iter().sum()).collect();
        assert_eq!(sizes, vec![4, 3, 3]);
    }

    #[test]
    fn display_is_the_spec_string() {
        let f = FleetSpec::parse("a100:1,h100:1").unwrap();
        assert_eq!(format!("{f}"), "a100-80gb:1,h100-80gb:1");
    }
}
