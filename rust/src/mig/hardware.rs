//! Hardware models: which MIG-capable part the cluster is built from.
//!
//! All supported parts share the six canonical profile *shapes* of
//! [`super::profile::Profile`]; a hardware model contributes naming (memory
//! GB per slice), the enabled-shape set, and bookkeeping used by reports
//! (total memory, SM count). The paper evaluates on A100-80GB; the rest are
//! provided so downstream users can model their fleets, and the whole stack
//! (scoring, scheduling, simulation) is generic over the model.

use super::profile::{Profile, ALL_PROFILES, NUM_PROFILES, NUM_SLICES};

/// A MIG-capable GPU part.
#[derive(Clone, Debug, PartialEq)]
pub struct HardwareModel {
    name: String,
    /// Memory GB represented by one memory slice (A100-80GB: 10).
    mem_gb_per_slice: u32,
    /// Which profile shapes the part supports (all six on every current
    /// part; kept configurable for restricted fleet policies, e.g. an
    /// operator disabling full-GPU rentals).
    enabled: [bool; NUM_PROFILES],
    /// Total streaming multiprocessors (reports only).
    total_sms: u32,
}

impl HardwareModel {
    /// NVIDIA A100 80GB — the paper's evaluation hardware.
    pub fn a100_80gb() -> Self {
        Self { name: "A100-80GB".into(), mem_gb_per_slice: 10, enabled: [true; 6], total_sms: 108 }
    }

    /// NVIDIA A100 40GB (same shapes, 5GB memory slices).
    pub fn a100_40gb() -> Self {
        Self { name: "A100-40GB".into(), mem_gb_per_slice: 5, enabled: [true; 6], total_sms: 108 }
    }

    /// NVIDIA H100 80GB.
    pub fn h100_80gb() -> Self {
        Self { name: "H100-80GB".into(), mem_gb_per_slice: 10, enabled: [true; 6], total_sms: 132 }
    }

    /// NVIDIA H200 141GB (slices of ~17.6GB, reported rounded to 18).
    pub fn h200_141gb() -> Self {
        Self { name: "H200-141GB".into(), mem_gb_per_slice: 18, enabled: [true; 6], total_sms: 132 }
    }

    /// Look up a model by name (CLI / config).
    pub fn by_name(name: &str) -> Option<Self> {
        match name.to_ascii_lowercase().replace('_', "-").as_str() {
            "a100-80gb" | "a100" => Some(Self::a100_80gb()),
            "a100-40gb" => Some(Self::a100_40gb()),
            "h100-80gb" | "h100" => Some(Self::h100_80gb()),
            "h200-141gb" | "h200" => Some(Self::h200_141gb()),
            _ => None,
        }
    }

    /// Restrict the supported profile set (builder style).
    pub fn with_profiles(mut self, profiles: &[Profile]) -> Self {
        self.enabled = [false; NUM_PROFILES];
        for p in profiles {
            self.enabled[p.index()] = true;
        }
        self
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn num_slices(&self) -> usize {
        NUM_SLICES
    }

    pub fn total_memory_gb(&self) -> u32 {
        self.mem_gb_per_slice * NUM_SLICES as u32
    }

    /// Instance memory of one profile on this part (e.g. 3g → 40 GB on
    /// A100-80GB, 20 GB on A100-40GB) — the migration cost model's
    /// bytes-moved basis.
    pub fn profile_mem_gb(&self, p: Profile) -> u32 {
        p.mem_weight() * self.mem_gb_per_slice
    }

    pub fn total_sms(&self) -> u32 {
        self.total_sms
    }

    #[inline]
    pub fn supports(&self, p: Profile) -> bool {
        self.enabled[p.index()]
    }

    /// Supported profiles in Table I order.
    pub fn profiles(&self) -> impl Iterator<Item = Profile> + '_ {
        ALL_PROFILES.iter().copied().filter(|p| self.supports(*p))
    }

    /// Bitmask over profile indexes of the enabled set; keys the
    /// fragmentation lookup-table cache in [`crate::frag`].
    pub fn profile_set_key(&self) -> u8 {
        let mut key = 0u8;
        for (i, &on) in self.enabled.iter().enumerate() {
            if on {
                key |= 1 << i;
            }
        }
        key
    }

    /// Hardware-specific profile name, e.g. the 3g shape is `3g.40gb` on
    /// A100-80GB but `3g.20gb` on A100-40GB.
    pub fn profile_name(&self, p: Profile) -> String {
        format!("{}g.{}gb", p.compute_slices(), p.mem_weight() * self.mem_gb_per_slice)
    }

    /// Parse a hardware-specific profile name.
    pub fn parse_profile(&self, name: &str) -> Option<Profile> {
        let name = name.trim();
        self.profiles().find(|p| {
            self.profile_name(*p).eq_ignore_ascii_case(name)
                || p.canonical_name().eq_ignore_ascii_case(name)
        })
    }

    /// Render the Table I equivalent for this part (used by
    /// `migsched inspect --hardware`).
    pub fn spec_table(&self) -> crate::util::table::Table {
        let mut t = crate::util::table::Table::new(&[
            "Profile", "Slices", "Compute", "Mem GB", "No. Instances", "Indexes",
        ])
        .title(&format!("MIG specifications for {} GPU", self.name));
        for p in self.profiles() {
            t.row(&[
                self.profile_name(p),
                p.size().to_string(),
                p.compute_slices().to_string(),
                (p.mem_weight() * self.mem_gb_per_slice).to_string(),
                p.max_instances().to_string(),
                format!("{:?}", p.starts()),
            ]);
        }
        t
    }
}

impl Default for HardwareModel {
    fn default() -> Self {
        Self::a100_80gb()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn a100_80gb_names_match_table_i() {
        let hw = HardwareModel::a100_80gb();
        for p in ALL_PROFILES {
            assert_eq!(hw.profile_name(p), p.canonical_name(), "{p:?}");
        }
        assert_eq!(hw.total_memory_gb(), 80);
        assert_eq!(hw.profile_mem_gb(Profile::P7g80gb), 80);
        assert_eq!(hw.profile_mem_gb(Profile::P3g40gb), 40);
        assert_eq!(hw.profile_mem_gb(Profile::P1g10gb), 10);
    }

    #[test]
    fn a100_40gb_names() {
        let hw = HardwareModel::a100_40gb();
        assert_eq!(hw.profile_name(Profile::P7g80gb), "7g.40gb");
        assert_eq!(hw.profile_name(Profile::P3g40gb), "3g.20gb");
        assert_eq!(hw.profile_name(Profile::P1g10gb), "1g.5gb");
        assert_eq!(hw.total_memory_gb(), 40);
    }

    #[test]
    fn by_name_lookup() {
        assert_eq!(HardwareModel::by_name("a100").unwrap().name(), "A100-80GB");
        assert_eq!(HardwareModel::by_name("A100_40GB").unwrap().name(), "A100-40GB");
        assert_eq!(HardwareModel::by_name("h100").unwrap().name(), "H100-80GB");
        assert!(HardwareModel::by_name("v100").is_none());
    }

    #[test]
    fn restricted_profile_set() {
        let hw = HardwareModel::a100_80gb()
            .with_profiles(&[Profile::P1g10gb, Profile::P2g20gb]);
        assert!(hw.supports(Profile::P1g10gb));
        assert!(!hw.supports(Profile::P7g80gb));
        assert_eq!(hw.profiles().count(), 2);
        assert_eq!(
            hw.profile_set_key(),
            (1 << Profile::P1g10gb.index()) | (1 << Profile::P2g20gb.index())
        );
    }

    #[test]
    fn parse_profile_both_namings() {
        let hw = HardwareModel::a100_40gb();
        assert_eq!(hw.parse_profile("3g.20gb"), Some(Profile::P3g40gb));
        assert_eq!(hw.parse_profile("3g.40gb"), Some(Profile::P3g40gb)); // canonical accepted
        assert_eq!(hw.parse_profile("9g.90gb"), None);
    }

    #[test]
    fn spec_table_renders_all_rows() {
        let t = HardwareModel::a100_80gb().spec_table();
        assert_eq!(t.n_rows(), 6);
        let s = t.render();
        assert!(s.contains("7g.80gb"));
        assert!(s.contains("[0, 2, 4, 6]"));
    }
}
