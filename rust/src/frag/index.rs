//! Incremental argmin-ΔF index — the sublinear decision core behind the
//! `MFI-IDX` scheduler.
//!
//! [`evaluate_cluster`](super::evaluate_cluster) re-derives the argmin
//! from scratch on every decision: a flat O(M·k) scan over all GPUs times
//! the profile's candidate anchors, even though a commit or release
//! touches exactly one GPU. [`FragIndex`] turns that around: it keeps, per
//! profile, every GPU bucketed by its best (lowest) ΔF, so that
//!
//! | operation                         | flat scan | `FragIndex`          |
//! |-----------------------------------|-----------|----------------------|
//! | build (once per cluster)          | —         | O(M·k)               |
//! | update (one GPU's mask changed)   | —         | O(k)                 |
//! | argmin-ΔF query (one decision)    | O(M·k)    | ~O(1) amortized      |
//!
//! where k = 18 is the total candidate count (Table I). The bucket key is
//! `ΔF + offset`: ΔF values live in the small bounded range
//! `[-max, +max]` with `max = max(ScoreTable::raw())`, because a ΔF is the
//! difference of two entries of the 256-entry score table. Buckets are
//! hierarchical bitsets over GPU ids, so the argmin query is "first
//! nonempty bucket → lowest GPU id in it → that GPU's cached best anchor"
//! — a handful of word scans, independent of M for all but degenerate
//! distributions.
//!
//! Tie-breaking is **bit-identical** to `evaluate_cluster` (lowest ΔF,
//! then lowest GPU id, then lowest anchor index): the per-GPU cached
//! anchor is the first anchor attaining the GPU's minimum (strict-less
//! updates in candidate-table order, exactly like
//! [`best_delta_on_gpu`](super::best_delta_on_gpu)), and the bucket query
//! returns the lowest GPU id of the lowest bucket. The equivalence is
//! enforced by property tests on random commit/release interleavings
//! (`tests/incremental.rs`) and by the unit tests below.
//!
//! Staleness is detected, never silently tolerated: the index records the
//! [`Cluster::generation`] it has incorporated; [`FragIndex::sync`]
//! catches up from the cluster's bounded change log in O(k) per missed
//! event, or rebuilds in O(M·k) when the log cannot bridge the gap (too
//! far behind, or a `clear()` discontinuity).

use crate::cluster::{ChangeKind, Cluster, ClusterEvent};
use crate::mig::{candidate_range, Placement, Profile, CANDIDATES, NUM_PROFILES, NUM_SLICES};

use super::table::ScoreTable;

/// Per-class profile support mask (index = `Profile::index()`).
type SupportRow = [bool; NUM_PROFILES];

/// Sentinel bucket for "no feasible anchor on this GPU".
const NO_BUCKET: u32 = u32::MAX;

/// Per-GPU, per-profile cached best placement: the bucket currently
/// holding the GPU (ΔF + offset) and the first anchor attaining that ΔF.
#[derive(Clone, Copy, Debug)]
struct Slot {
    bucket: u32,
    anchor: u8,
}

const EMPTY_SLOT: Slot = Slot { bucket: NO_BUCKET, anchor: 0 };

/// A set of GPU ids supporting O(1) insert/remove and near-O(1) min
/// queries: a bitset over ids plus a one-level summary (bit `w` of the
/// summary ⇔ word `w` is nonzero), so `min()` scans M/4096 summary words.
#[derive(Clone, Debug)]
struct GpuSet {
    words: Vec<u64>,
    summary: Vec<u64>,
}

impl GpuSet {
    fn new(num_gpus: usize) -> Self {
        let nw = num_gpus.div_ceil(64);
        Self { words: vec![0; nw], summary: vec![0; nw.div_ceil(64)] }
    }

    #[inline]
    fn insert(&mut self, id: usize) {
        self.words[id / 64] |= 1u64 << (id % 64);
        self.summary[id / 4096] |= 1u64 << ((id / 64) % 64);
    }

    #[inline]
    fn remove(&mut self, id: usize) {
        let w = id / 64;
        self.words[w] &= !(1u64 << (id % 64));
        if self.words[w] == 0 {
            self.summary[w / 64] &= !(1u64 << (w % 64));
        }
    }

    /// Lowest id in the set, `None` when empty.
    fn min(&self) -> Option<usize> {
        for (si, &s) in self.summary.iter().enumerate() {
            if s != 0 {
                let w = si * 64 + s.trailing_zeros() as usize;
                let bits = self.words[w];
                debug_assert_ne!(bits, 0, "summary bit set for empty word");
                return Some(w * 64 + bits.trailing_zeros() as usize);
            }
        }
        None
    }
}

/// One profile's view: GPUs bucketed by best ΔF, plus a bitset over
/// buckets so the lowest nonempty bucket is found by scanning a word or
/// two (bucket count is `2·max+1` ≤ a few dozen for real profile sets).
#[derive(Clone, Debug)]
struct ProfileBuckets {
    buckets: Vec<GpuSet>,
    nonempty: Vec<u64>,
    /// Live GPUs per bucket, to keep `nonempty` exact under removals.
    counts: Vec<u32>,
}

impl ProfileBuckets {
    fn new(num_buckets: usize, num_gpus: usize) -> Self {
        Self {
            buckets: vec![GpuSet::new(num_gpus); num_buckets],
            nonempty: vec![0; num_buckets.div_ceil(64)],
            counts: vec![0; num_buckets],
        }
    }

    #[inline]
    fn insert(&mut self, bucket: usize, gpu: usize) {
        self.buckets[bucket].insert(gpu);
        self.counts[bucket] += 1;
        self.nonempty[bucket / 64] |= 1u64 << (bucket % 64);
    }

    #[inline]
    fn remove(&mut self, bucket: usize, gpu: usize) {
        self.buckets[bucket].remove(gpu);
        self.counts[bucket] -= 1;
        if self.counts[bucket] == 0 {
            self.nonempty[bucket / 64] &= !(1u64 << (bucket % 64));
        }
    }

    /// Lowest nonempty bucket index.
    fn min_bucket(&self) -> Option<usize> {
        for (wi, &w) in self.nonempty.iter().enumerate() {
            if w != 0 {
                return Some(wi * 64 + w.trailing_zeros() as usize);
            }
        }
        None
    }
}

/// The incremental per-profile argmin-ΔF index (see module docs).
///
/// On a heterogeneous fleet the index keeps one [`ScoreTable`] per device
/// class and buckets every GPU by the ΔF computed against *its own*
/// class's table; GPUs whose class does not enable a profile never enter
/// that profile's buckets, matching
/// [`evaluate_fleet`](super::evaluate_fleet)'s skip. The bucket offset is
/// the max raw score across *all* class tables, so every class's ΔF range
/// stays representable in one shared bucket axis.
#[derive(Clone, Debug)]
pub struct FragIndex {
    /// One table per device class; `tables[0]` is the legacy single-table
    /// view exposed by [`FragIndex::score_table`].
    tables: Vec<ScoreTable>,
    /// Per-GPU device class (all zeros on a single-class fleet).
    class_ids: Vec<u8>,
    /// Per-class profile enablement. The single-class constructors use
    /// all-true rows: profile support on uniform clusters is (and was)
    /// enforced by the scheduler's cluster-wide guard, and the index must
    /// stay bit-identical to its pre-fleet behavior there.
    class_supports: Vec<SupportRow>,
    /// Bucket key = ΔF + offset; offset = max score over every class
    /// table, so every feasible ΔF of any class maps into `[0, 2·offset]`.
    offset: i32,
    profiles: Vec<ProfileBuckets>,
    slots: Vec<[Slot; NUM_PROFILES]>,
    /// Shadow occupancy, advanced event by event; equal to the cluster's
    /// masks whenever `generation` matches (debug-asserted in `sync`).
    masks: Vec<u8>,
    generation: u64,
}

impl FragIndex {
    /// Build the index for a cluster's current occupancy — O(M·k).
    ///
    /// On a single-class cluster the passed table is used as-is (callers
    /// may supply a custom rule's table); on a multi-class cluster the
    /// per-class tables are derived from the cluster's hardware models
    /// under the passed table's overlap rule.
    pub fn for_cluster(table: ScoreTable, cluster: &Cluster) -> Self {
        let masks = cluster.occupancy_masks();
        if cluster.is_uniform() {
            return Self::from_masks(table, &masks, cluster.generation());
        }
        let rule = table.rule();
        let tables = cluster
            .classes()
            .iter()
            .map(|hw| ScoreTable::for_hardware_rule(hw, rule))
            .collect();
        let supports = cluster
            .classes()
            .iter()
            .map(|hw| {
                std::array::from_fn(|pi| {
                    hw.supports(Profile::from_index(pi).expect("profile index in range"))
                })
            })
            .collect();
        Self::build(tables, cluster.class_ids().to_vec(), supports, &masks, cluster.generation())
    }

    /// Build from raw occupancy masks at a known generation (single-class).
    pub fn from_masks(table: ScoreTable, masks: &[u8], generation: u64) -> Self {
        Self::build(
            vec![table],
            vec![0; masks.len()],
            vec![[true; NUM_PROFILES]],
            masks,
            generation,
        )
    }

    fn build(
        tables: Vec<ScoreTable>,
        class_ids: Vec<u8>,
        class_supports: Vec<SupportRow>,
        masks: &[u8],
        generation: u64,
    ) -> Self {
        let offset = tables
            .iter()
            .map(|t| *t.raw().iter().max().unwrap_or(&0) as i32)
            .max()
            .unwrap_or(0);
        let num_buckets = (2 * offset + 1) as usize;
        let mut index = Self {
            tables,
            class_ids,
            class_supports,
            offset,
            profiles: (0..NUM_PROFILES)
                .map(|_| ProfileBuckets::new(num_buckets, masks.len()))
                .collect(),
            slots: vec![[EMPTY_SLOT; NUM_PROFILES]; masks.len()],
            masks: masks.to_vec(),
            generation,
        };
        for gpu in 0..masks.len() {
            index.update_gpu(gpu);
        }
        index
    }

    /// The cluster generation the index has incorporated.
    #[inline]
    pub fn generation(&self) -> u64 {
        self.generation
    }

    pub fn num_gpus(&self) -> usize {
        self.masks.len()
    }

    /// The class-0 score table (the only table on single-class fleets).
    pub fn score_table(&self) -> &ScoreTable {
        &self.tables[0]
    }

    /// The score table governing one GPU.
    pub fn score_table_of(&self, gpu: usize) -> &ScoreTable {
        &self.tables[self.class_ids[gpu] as usize]
    }

    /// Re-derive one GPU's per-profile best anchors from its mask and move
    /// it between buckets — O(k) total across all profiles.
    fn update_gpu(&mut self, gpu: usize) {
        let occ = self.masks[gpu];
        let class = self.class_ids[gpu] as usize;
        let scores = self.tables[class].raw();
        let supports = &self.class_supports[class];
        let base = scores[occ as usize] as i32;
        let free = NUM_SLICES as u8 - occ.count_ones() as u8;
        for (pi, pb) in self.profiles.iter_mut().enumerate() {
            let profile = Profile::from_index(pi).expect("profile index in range");
            let mut best: Option<(u8, i32)> = None;
            if supports[pi] && profile.size() <= free {
                for cand in &CANDIDATES[candidate_range(profile)] {
                    if occ & cand.mask != 0 {
                        continue;
                    }
                    let d = scores[(occ | cand.mask) as usize] as i32 - base;
                    match best {
                        Some((_, bd)) if bd <= d => {}
                        _ => best = Some((cand.start, d)),
                    }
                }
            }
            let old = self.slots[gpu][pi];
            if old.bucket != NO_BUCKET {
                pb.remove(old.bucket as usize, gpu);
            }
            self.slots[gpu][pi] = match best {
                Some((anchor, delta)) => {
                    let bucket = (delta + self.offset) as usize;
                    pb.insert(bucket, gpu);
                    Slot { bucket: bucket as u32, anchor }
                }
                None => EMPTY_SLOT,
            };
        }
    }

    /// Incorporate one cluster event — O(k).
    pub fn apply(&mut self, event: &ClusterEvent) {
        let pl = event.placement;
        let mask = pl.profile.mask_at(pl.index);
        match event.kind {
            ChangeKind::Commit => {
                debug_assert_eq!(self.masks[pl.gpu] & mask, 0, "commit over occupied window");
                self.masks[pl.gpu] |= mask;
            }
            ChangeKind::Release => {
                debug_assert_eq!(self.masks[pl.gpu] & mask, mask, "release of free window");
                self.masks[pl.gpu] &= !mask;
            }
        }
        self.update_gpu(pl.gpu);
        self.generation = event.generation;
    }

    /// Bring the index up to date with `cluster`. Returns the number of
    /// events replayed incrementally, or `None` when the change log could
    /// not bridge the gap and the index was rebuilt from scratch.
    pub fn sync(&mut self, cluster: &Cluster) -> Option<usize> {
        let replayed = if cluster.num_gpus() != self.num_gpus()
            || cluster.class_ids() != &self.class_ids[..]
        {
            None
        } else if self.generation == cluster.generation() {
            Some(0)
        } else {
            match cluster.events_since(self.generation) {
                Some(events) => {
                    for e in &events {
                        self.apply(e);
                    }
                    Some(events.len())
                }
                None => None,
            }
        };
        if replayed.is_none() {
            *self = Self::for_cluster(self.tables[0].clone(), cluster);
        }
        debug_assert_eq!(self.generation, cluster.generation());
        debug_assert_eq!(self.masks, cluster.occupancy_masks(), "index diverged from cluster");
        replayed
    }

    /// Argmin-ΔF placement for `profile`, with `evaluate_cluster`'s exact
    /// tie-breaking (lowest ΔF, then lowest GPU id, then lowest anchor).
    /// `None` when no GPU has a feasible window.
    pub fn best(&self, profile: Profile) -> Option<Placement> {
        let pi = profile.index();
        let pb = &self.profiles[pi];
        let bucket = pb.min_bucket()?;
        let gpu = self.buckets_min(pi, bucket);
        Some(Placement { gpu, profile, index: self.slots[gpu][pi].anchor })
    }

    /// ΔF of the current best placement for `profile` (diagnostics).
    pub fn best_delta(&self, profile: Profile) -> Option<i32> {
        let pb = &self.profiles[profile.index()];
        pb.min_bucket().map(|b| b as i32 - self.offset)
    }

    fn buckets_min(&self, profile_idx: usize, bucket: usize) -> usize {
        self.profiles[profile_idx].buckets[bucket]
            .min()
            .expect("nonempty bucket flagged empty")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frag::evaluate_cluster;
    use crate::mig::{GpuState, HardwareModel};
    use crate::util::rng::Rng;
    use crate::workload::WorkloadId;

    fn table() -> ScoreTable {
        ScoreTable::for_hardware(&HardwareModel::a100_80gb())
    }

    #[test]
    fn gpu_set_insert_remove_min() {
        let mut s = GpuSet::new(50_000);
        assert_eq!(s.min(), None);
        for id in [49_999, 4_096, 63, 64, 12_345] {
            s.insert(id);
        }
        assert_eq!(s.min(), Some(63));
        s.remove(63);
        assert_eq!(s.min(), Some(64));
        s.remove(64);
        assert_eq!(s.min(), Some(4_096));
        s.remove(4_096);
        s.remove(12_345);
        assert_eq!(s.min(), Some(49_999));
        s.remove(49_999);
        assert_eq!(s.min(), None);
    }

    #[test]
    fn offset_bounds_every_feasible_delta() {
        // Bucket keys must be in range for EVERY feasible (mask, candidate)
        // pair — the bound the restricted-profile golden fixture also pins.
        let t = table();
        let offset = *t.raw().iter().max().unwrap() as i32;
        for occ in 0u16..=255 {
            let g = GpuState::from_mask(occ as u8);
            for cand in CANDIDATES.iter() {
                if g.fits_at(cand.profile, cand.start) {
                    let d = t.delta(g, cand.profile, cand.start);
                    assert!(d >= -offset && d <= offset, "occ={occ:#010b} ΔF={d}");
                }
            }
        }
    }

    #[test]
    fn fresh_index_matches_flat_scan_on_random_states() {
        let t = table();
        let mut rng = Rng::new(0x1D3);
        for _ in 0..200 {
            let masks: Vec<u8> = (0..1 + rng.index(12)).map(|_| rng.next_u64() as u8).collect();
            let gpus: Vec<GpuState> = masks.iter().map(|&m| GpuState::from_mask(m)).collect();
            let index = FragIndex::from_masks(t.clone(), &masks, 0);
            for p in crate::mig::profile::ALL_PROFILES {
                assert_eq!(index.best(p), evaluate_cluster(&t, &gpus, p), "{p} masks={masks:?}");
            }
        }
    }

    #[test]
    fn incremental_updates_track_cluster_mutations() {
        let hw = HardwareModel::a100_80gb();
        let mut cluster = Cluster::new(hw.clone(), 6);
        let mut index = FragIndex::for_cluster(table(), &cluster);
        let mut rng = Rng::new(0xACE);
        let mut next_id = 0u64;
        for _ in 0..400 {
            if rng.chance(0.6) {
                let p = *rng.choose(&crate::mig::profile::ALL_PROFILES);
                if let Some(pl) = index.best(p) {
                    cluster.allocate(WorkloadId(next_id), pl).expect("index proposed valid");
                    next_id += 1;
                }
            } else if cluster.allocated_workloads() > 0 {
                // Sort: HashMap iteration order would make the episode
                // irreproducible across runs of the same seed.
                let mut ids: Vec<WorkloadId> = cluster.allocations().map(|(id, _)| id).collect();
                ids.sort();
                cluster.release(*rng.choose(&ids)).unwrap();
            }
            let missed = (cluster.generation() - index.generation()) as usize;
            assert_eq!(index.sync(&cluster), Some(missed), "catch-up stays incremental");
            for p in crate::mig::profile::ALL_PROFILES {
                assert_eq!(
                    index.best(p),
                    evaluate_cluster(index.score_table(), cluster.gpus(), p),
                    "{p}"
                );
            }
        }
    }

    #[test]
    fn mixed_fleet_index_matches_fleet_scan() {
        use crate::frag::{evaluate_fleet, FleetTables};
        use crate::mig::FleetSpec;
        let fleet = FleetSpec::new(vec![
            (HardwareModel::a100_80gb(), 2),
            (HardwareModel::h100_80gb().with_profiles(&[Profile::P1g10gb, Profile::P3g40gb]), 2),
            (HardwareModel::a100_40gb(), 1),
        ])
        .unwrap();
        let mut cluster = Cluster::from_fleet(&fleet);
        let tables = FleetTables::for_cluster(&cluster);
        let mut index =
            FragIndex::for_cluster(ScoreTable::for_hardware(cluster.hardware()), &cluster);
        let mut rng = Rng::new(0xBEEF);
        let mut next_id = 0u64;
        for _ in 0..400 {
            if rng.chance(0.6) {
                let p = *rng.choose(&crate::mig::profile::ALL_PROFILES);
                if !cluster.supports(p) {
                    continue;
                }
                if let Some(pl) = index.best(p) {
                    cluster.allocate(WorkloadId(next_id), pl).expect("index proposed valid");
                    next_id += 1;
                }
            } else if cluster.allocated_workloads() > 0 {
                let mut ids: Vec<WorkloadId> = cluster.allocations().map(|(id, _)| id).collect();
                ids.sort();
                cluster.release(*rng.choose(&ids)).unwrap();
            }
            index.sync(&cluster);
            for p in crate::mig::profile::ALL_PROFILES {
                assert_eq!(index.best(p), evaluate_fleet(&tables, &cluster, p), "{p}");
            }
        }
    }

    #[test]
    fn sync_rebuilds_across_discontinuity() {
        let hw = HardwareModel::a100_80gb();
        let mut cluster = Cluster::new(hw.clone(), 3);
        cluster
            .allocate(WorkloadId(0), Placement { gpu: 1, profile: Profile::P2g20gb, index: 2 })
            .unwrap();
        let mut index = FragIndex::for_cluster(table(), &cluster);
        cluster.clear();
        cluster
            .allocate(WorkloadId(1), Placement { gpu: 0, profile: Profile::P7g80gb, index: 0 })
            .unwrap();
        // The clear() broke log continuity: sync must rebuild (None) yet
        // land on the correct state.
        assert_eq!(index.sync(&cluster), None);
        assert_eq!(index.generation(), cluster.generation());
        for p in crate::mig::profile::ALL_PROFILES {
            assert_eq!(index.best(p), evaluate_cluster(index.score_table(), cluster.gpus(), p));
        }
    }
}
