//! The paper's fragmentation metric for MIG (Section V-B, Algorithm 1) and
//! the ΔF machinery behind the MFI scheduler (Algorithm 2).
//!
//! A GPU is *fragmented with respect to profile `p`* when `p`'s slice count
//! fits in the free capacity (`size(p) ≤ ΔS`) yet every feasible anchor
//! window overlaps an occupied slice. The **fragmentation score** `F(m)`
//! sums, over every supported profile in that situation-check, the
//! profile's memory-slice weight for each blocked anchor:
//!
//! ```text
//! F(m) = Σ_{p : size(p) ≤ ΔS_m}  mem(p) · |{ i ∈ I_p : window(p, i) ∩ occ(m) ≠ ∅ }|
//! ```
//!
//! Three engines compute it, all bit-identical (cross-checked exhaustively
//! over all 256 occupancy patterns):
//!
//! * [`score::score_direct`] — a literal transcription of Algorithm 1;
//!   the readable oracle.
//! * [`ScoreTable`] — a 256-entry lookup table per (hardware profile set);
//!   the production hot path: a score is one indexed load, a ΔF is two.
//! * `runtime::FragEngine` — the AOT-compiled JAX/Pallas program executed
//!   through PJRT (built from the same candidate table; see
//!   `python/compile/model.py`).
//!
//! On top of the score engines, [`index::FragIndex`] maintains the
//! cluster-wide argmin-ΔF *incrementally* (O(k) per commit/release, ~O(1)
//! per decision) — the event-driven alternative to the O(M·k)
//! [`evaluate_cluster`] rescan, with identical tie-breaking.

pub mod delta;
pub mod expected;
pub mod index;
pub mod score;
pub mod table;

pub use delta::{
    best_delta_on_gpu, delta_f, evaluate_cluster, evaluate_cluster_full, evaluate_fleet,
    DeltaOutcome, EvaluatedCandidate,
};
pub use expected::{
    evaluate_cluster_expected, evaluate_fleet_expected, ComponentTables, ExpectedFleet,
    ExpectedTable,
};
pub use index::FragIndex;
pub use score::{
    max_score, score_direct, score_direct_rule, DirectScorer, FragScorer, OverlapRule,
};
pub use table::{FleetTables, ScoreTable};
