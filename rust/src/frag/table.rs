//! Lookup-table fragmentation engine — the production hot path.
//!
//! An 8-slice GPU has only 256 possible occupancy masks, so the entire
//! Algorithm 1 computation is precomputed into a 256-entry table per
//! (hardware profile set, overlap rule). A score becomes one indexed load;
//! a dry-run ΔF (Algorithm 2 line 9-10) becomes two loads and a subtract.
//! Tables are built once per hardware model and cached process-wide.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

use super::score::{score_direct_rule, FragScorer, OverlapRule};
use crate::cluster::Cluster;
use crate::mig::{GpuState, HardwareModel, Profile};

/// Precomputed Algorithm 1 scores for all 256 occupancy masks.
#[derive(Clone, Debug)]
pub struct ScoreTable {
    scores: Arc<[u16; 256]>,
    rule: OverlapRule,
    hw_name: String,
}

impl ScoreTable {
    /// Build (or fetch from the process-wide cache) the table for a
    /// hardware model under the default overlap rule.
    pub fn for_hardware(hw: &HardwareModel) -> Self {
        Self::for_hardware_rule(hw, OverlapRule::default())
    }

    /// Build (or fetch) the table for a hardware model and overlap rule.
    pub fn for_hardware_rule(hw: &HardwareModel, rule: OverlapRule) -> Self {
        static CACHE: OnceLock<Mutex<HashMap<(u8, OverlapRule), Arc<[u16; 256]>>>> =
            OnceLock::new();
        let cache = CACHE.get_or_init(|| Mutex::new(HashMap::new()));
        let key = (hw.profile_set_key(), rule);
        let scores = {
            let mut guard = cache.lock().unwrap();
            guard.entry(key).or_insert_with(|| Arc::new(build_table(hw, rule))).clone()
        };
        Self { scores, rule, hw_name: hw.name().to_string() }
    }

    #[inline]
    pub fn score_mask(&self, occ: u8) -> u32 {
        self.scores[occ as usize] as u32
    }

    /// ΔF of hypothetically placing `profile` at `start` on a GPU with the
    /// given state (Algorithm 2 lines 8-10). The window must be free.
    #[inline]
    pub fn delta(&self, gpu: GpuState, profile: Profile, start: u8) -> i32 {
        let occ = gpu.mask();
        let mask = profile.mask_at(start);
        debug_assert_eq!(occ & mask, 0, "delta() requires a free window");
        self.scores[(occ | mask) as usize] as i32 - self.scores[occ as usize] as i32
    }

    pub fn rule(&self) -> OverlapRule {
        self.rule
    }

    pub fn hardware_name(&self) -> &str {
        &self.hw_name
    }

    /// Raw table access (consumed by the python cross-check export and the
    /// runtime's numeric validation).
    pub fn raw(&self) -> &[u16; 256] {
        &self.scores
    }
}

impl FragScorer for ScoreTable {
    #[inline]
    fn score(&self, gpu: GpuState) -> u32 {
        self.score_mask(gpu.mask())
    }
}

/// One [`ScoreTable`] per device class of a heterogeneous fleet.
///
/// Each GPU is scored against its *own* class's table; a single-class
/// fleet degenerates to exactly one `ScoreTable`, so the homogeneous path
/// stays bit-identical. The `classes` Arc is the same one the source
/// [`Cluster`] holds, which makes [`FleetTables::matches`] a pointer
/// compare — cheap enough to revalidate a cached instance on every
/// scheduling call.
#[derive(Clone, Debug)]
pub struct FleetTables {
    tables: Vec<ScoreTable>,
    classes: Arc<[HardwareModel]>,
}

impl FleetTables {
    /// Per-class tables for `cluster` under the default overlap rule.
    pub fn for_cluster(cluster: &Cluster) -> Self {
        Self::with_rule(cluster, OverlapRule::default())
    }

    /// Per-class tables for `cluster` under an explicit overlap rule.
    pub fn with_rule(cluster: &Cluster, rule: OverlapRule) -> Self {
        let classes = cluster.classes_arc().clone();
        let tables =
            classes.iter().map(|hw| ScoreTable::for_hardware_rule(hw, rule)).collect();
        Self { tables, classes }
    }

    /// True when these tables were built from `cluster`'s class set (a
    /// pointer compare on the shared class-table Arc).
    pub fn matches(&self, cluster: &Cluster) -> bool {
        Arc::ptr_eq(&self.classes, cluster.classes_arc())
    }

    pub fn num_classes(&self) -> usize {
        self.tables.len()
    }

    /// The table for device class `class` (panics out of range).
    pub fn table(&self, class: u8) -> &ScoreTable {
        &self.tables[class as usize]
    }

    /// The table governing GPU `gpu` of `cluster`.
    pub fn table_for(&self, cluster: &Cluster, gpu: usize) -> &ScoreTable {
        &self.tables[cluster.class_of(gpu) as usize]
    }

    pub fn rule(&self) -> OverlapRule {
        self.tables[0].rule()
    }

    /// Score one GPU against its own class's table.
    #[inline]
    pub fn score_gpu(&self, cluster: &Cluster, gpu: usize) -> u32 {
        self.tables[cluster.class_of(gpu) as usize].score_mask(cluster.gpus()[gpu].mask())
    }

    /// Mean per-class score across the fleet; replicates
    /// [`FragScorer::mean_score`]'s arithmetic exactly (sum of per-GPU
    /// scores as f64, divided by the GPU count) so a single-class fleet
    /// produces bit-identical means.
    pub fn mean_score(&self, cluster: &Cluster) -> f64 {
        let gpus = cluster.gpus();
        if gpus.is_empty() {
            return 0.0;
        }
        let ids = cluster.class_ids();
        gpus.iter()
            .zip(ids)
            .map(|(g, &c)| self.tables[c as usize].score_mask(g.mask()) as f64)
            .sum::<f64>()
            / gpus.len() as f64
    }

    /// The largest raw score across all class tables — the bucket offset a
    /// fleet-wide [`super::FragIndex`] must use so every ΔF stays
    /// representable.
    pub fn max_raw(&self) -> u32 {
        self.tables
            .iter()
            .map(|t| t.raw().iter().copied().max().unwrap_or(0) as u32)
            .max()
            .unwrap_or(0)
    }
}

fn build_table(hw: &HardwareModel, rule: OverlapRule) -> [u16; 256] {
    let mut t = [0u16; 256];
    for occ in 0..=255u8 {
        t[occ as usize] = score_direct_rule(GpuState::from_mask(occ), hw, rule) as u16;
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mig::profile::ALL_PROFILES;

    #[test]
    fn table_matches_direct_exhaustively() {
        for hw in [
            HardwareModel::a100_80gb(),
            HardwareModel::a100_40gb(),
            HardwareModel::a100_80gb().with_profiles(&[Profile::P1g10gb, Profile::P3g40gb]),
        ] {
            for rule in [OverlapRule::Partial, OverlapRule::Any] {
                let table = ScoreTable::for_hardware_rule(&hw, rule);
                for occ in 0u16..=255 {
                    let g = GpuState::from_mask(occ as u8);
                    assert_eq!(
                        table.score(g),
                        score_direct_rule(g, &hw, rule),
                        "hw={} rule={:?} occ={occ:#010b}",
                        hw.name(),
                        rule
                    );
                }
            }
        }
    }

    #[test]
    fn delta_matches_recompute_exhaustively() {
        let hw = HardwareModel::a100_80gb();
        let table = ScoreTable::for_hardware(&hw);
        for occ in 0u16..=255 {
            let g = GpuState::from_mask(occ as u8);
            for p in ALL_PROFILES {
                for &s in p.starts() {
                    if !g.fits_at(p, s) {
                        continue;
                    }
                    let expect = score_direct_rule(g.with_placement(p, s), &hw, table.rule())
                        as i32
                        - score_direct_rule(g, &hw, table.rule()) as i32;
                    assert_eq!(table.delta(g, p, s), expect, "occ={occ:#010b} {p}@{s}");
                }
            }
        }
    }

    #[test]
    fn cache_shares_backing_storage() {
        let hw = HardwareModel::a100_80gb();
        let a = ScoreTable::for_hardware(&hw);
        let b = ScoreTable::for_hardware(&hw);
        assert!(Arc::ptr_eq(&a.scores, &b.scores));
        // Different rule → different table.
        let c = ScoreTable::for_hardware_rule(&hw, OverlapRule::Any);
        assert!(!Arc::ptr_eq(&a.scores, &c.scores));
    }

    #[test]
    fn paper_examples_via_table() {
        let table = ScoreTable::for_hardware(&HardwareModel::a100_80gb());
        let gpu2 = GpuState::empty()
            .with_placement(Profile::P2g20gb, 0)
            .with_placement(Profile::P1g10gb, 5);
        assert_eq!(table.score(gpu2), 16);
        let gpu1 = GpuState::empty().with_placement(Profile::P1g10gb, 5);
        assert_eq!(table.score(gpu1), 8);
    }

    #[test]
    fn fleet_tables_score_each_gpu_against_its_own_class() {
        use crate::mig::FleetSpec;
        // Class 1 only knows 1g.10gb, so a half-occupied GPU scores
        // differently under the two tables.
        let restricted = HardwareModel::h100_80gb().with_profiles(&[Profile::P1g10gb]);
        let fleet = FleetSpec::new(vec![
            (HardwareModel::a100_80gb(), 1),
            (restricted.clone(), 1),
        ])
        .unwrap();
        let mut cluster = Cluster::from_fleet(&fleet);
        let tables = FleetTables::for_cluster(&cluster);
        assert!(tables.matches(&cluster));
        assert_eq!(tables.num_classes(), 2);

        use crate::mig::Placement;
        use crate::workload::WorkloadId;
        cluster
            .allocate(WorkloadId(1), Placement { gpu: 0, profile: Profile::P1g10gb, index: 5 })
            .unwrap();
        cluster
            .allocate(WorkloadId(2), Placement { gpu: 1, profile: Profile::P1g10gb, index: 5 })
            .unwrap();
        // Same occupancy mask, different class table, different score.
        let s0 = tables.score_gpu(&cluster, 0);
        let s1 = tables.score_gpu(&cluster, 1);
        assert_eq!(s0, 8, "A100-80GB table: paper worked example");
        assert_eq!(s1, score_direct_rule(cluster.gpus()[1], &restricted, OverlapRule::Partial));
        assert_ne!(s0, s1);
        assert!((tables.mean_score(&cluster) - (s0 as f64 + s1 as f64) / 2.0).abs() < 1e-12);
    }

    #[test]
    fn fleet_tables_uniform_mean_matches_frag_scorer() {
        let hw = HardwareModel::a100_80gb();
        let cluster = {
            let mut c = Cluster::new(hw.clone(), 4);
            use crate::mig::Placement;
            use crate::workload::WorkloadId;
            c.allocate(WorkloadId(1), Placement { gpu: 0, profile: Profile::P2g20gb, index: 0 })
                .unwrap();
            c.allocate(WorkloadId(2), Placement { gpu: 2, profile: Profile::P1g10gb, index: 5 })
                .unwrap();
            c
        };
        let table = ScoreTable::for_hardware(&hw);
        let tables = FleetTables::for_cluster(&cluster);
        // Bit-identical f64, not approximately equal: the homogeneous path
        // must not drift by a ULP.
        assert_eq!(tables.mean_score(&cluster).to_bits(), table.mean_score(cluster.gpus()).to_bits());
        assert_eq!(tables.max_raw(), table.raw().iter().copied().max().unwrap() as u32);
    }

    #[test]
    fn fleet_tables_matches_detects_foreign_clusters() {
        let a = Cluster::new(HardwareModel::a100_80gb(), 2);
        let b = Cluster::new(HardwareModel::a100_80gb(), 2);
        let tables = FleetTables::for_cluster(&a);
        assert!(tables.matches(&a));
        // Same composition but a different Arc: conservative mismatch.
        assert!(!tables.matches(&b));
    }

    #[test]
    fn delta_can_be_negative() {
        // Completing a partially-blocked window can REDUCE fragmentation:
        // occ = {1g.10gb@5}: F = 8. Placing 1g.10gb@4 fills the other half
        // of the 2-slice windows at anchor 4: new occ {4,5},
        // F = 3g@4 partial (+4) → scores: windows 2g@4/1g.20@4 now fully
        // occupied → F drops from 8 to 4: ΔF = -4.
        let table = ScoreTable::for_hardware(&HardwareModel::a100_80gb());
        let g = GpuState::empty().with_placement(Profile::P1g10gb, 5);
        assert_eq!(table.delta(g, Profile::P1g10gb, 4), -4);
    }
}
