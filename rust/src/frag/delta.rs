//! ΔF (fragmentation-increment) evaluation — the inner loop of the MFI
//! scheduler (paper Algorithm 2, lines 4-13).
//!
//! Given a cluster's GPU states and a requested profile, evaluate the
//! hypothetical fragmentation-score variation of every feasible placement
//! and select the argmin. Tie-breaking is deterministic: lowest ΔF, then
//! lowest GPU id, then lowest anchor index — the "first" semantics a FIFO
//! scheduler needs for reproducible runs.

use super::table::{FleetTables, ScoreTable};
use crate::cluster::Cluster;
use crate::mig::{GpuState, Placement, Profile};

/// ΔF of placing `profile` at `start` on `gpu` (must be a free window).
#[inline]
pub fn delta_f(table: &ScoreTable, gpu: GpuState, profile: Profile, start: u8) -> i32 {
    table.delta(gpu, profile, start)
}

/// Best (lowest-ΔF) anchor for `profile` on a single GPU, with its ΔF.
/// `None` when no feasible anchor exists.
///
/// Hot-path shape (EXPERIMENTS.md §Perf, L3 iteration 1): iterates the
/// precomputed [`CANDIDATES`] rows for the profile — window mask and
/// anchor come from one static table row, so the inner loop is a mask
/// test plus two score-table loads, with no per-iteration mask
/// recomputation or bounds checks on the anchor list.
pub fn best_delta_on_gpu(
    table: &ScoreTable,
    gpu: GpuState,
    profile: Profile,
) -> Option<(u8, i32)> {
    // Skip early when not even the slice count fits (Algorithm 2 line 5).
    if profile.size() > gpu.free_slices() {
        return None;
    }
    let occ = gpu.mask();
    let scores = table.raw();
    let base = scores[occ as usize] as i32;
    let mut best: Option<(u8, i32)> = None;
    for cand in &crate::mig::CANDIDATES[crate::mig::candidate_range(profile)] {
        if occ & cand.mask != 0 {
            continue;
        }
        let d = scores[(occ | cand.mask) as usize] as i32 - base;
        match best {
            Some((_, bd)) if bd <= d => {}
            _ => best = Some((cand.start, d)),
        }
    }
    best
}

/// One evaluated candidate placement.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct EvaluatedCandidate {
    pub gpu: usize,
    pub index: u8,
    pub delta: i32,
}

/// Full dry-run outcome over a cluster for one request — every feasible
/// (GPU, anchor) pair with its ΔF, plus the selected argmin. Produced by
/// [`evaluate_cluster_full`] for diagnostics/inspection; the scheduler hot
/// path uses [`evaluate_cluster`] which keeps only the running minimum.
#[derive(Clone, Debug, Default)]
pub struct DeltaOutcome {
    pub candidates: Vec<EvaluatedCandidate>,
    pub best: Option<EvaluatedCandidate>,
}

/// Argmin-ΔF placement over the whole cluster (Algorithm 2 lines 14-16).
/// Returns `None` when every GPU rejects the profile (line 18).
///
/// This is the MFI hot loop (EXPERIMENTS.md §Perf, L3 iteration 2): one
/// flat scan over GPUs × the profile's candidate rows, tracking the
/// running (ΔF, gpu, anchor) minimum in scalars. Tie-breaking is
/// strictly-less, so equal-ΔF candidates resolve to the lowest GPU id,
/// then the lowest anchor — identical to the reference implementation
/// (asserted by `full_and_fast_paths_agree`).
pub fn evaluate_cluster(
    table: &ScoreTable,
    gpus: &[GpuState],
    profile: Profile,
) -> Option<Placement> {
    let scores = table.raw();
    let cands = &crate::mig::CANDIDATES[crate::mig::candidate_range(profile)];
    let size = profile.size();
    let mut best_delta = i32::MAX;
    let mut best_gpu = usize::MAX;
    let mut best_start = 0u8;
    for (gpu_id, g) in gpus.iter().enumerate() {
        let occ = g.mask();
        if size > crate::mig::NUM_SLICES as u8 - occ.count_ones() as u8 {
            continue;
        }
        let base = scores[occ as usize] as i32;
        for cand in cands {
            if occ & cand.mask != 0 {
                continue;
            }
            let d = scores[(occ | cand.mask) as usize] as i32 - base;
            if d < best_delta {
                best_delta = d;
                best_gpu = gpu_id;
                best_start = cand.start;
            }
        }
    }
    if best_gpu == usize::MAX {
        None
    } else {
        Some(Placement { gpu: best_gpu, profile, index: best_start })
    }
}

/// [`evaluate_cluster`] generalized to heterogeneous fleets: each GPU's ΔF
/// is computed against its *own* class's score table, and GPUs whose class
/// does not enable `profile` are skipped entirely. The scan order and the
/// strictly-less `(ΔF, gpu, anchor)` tie-break are identical to the flat
/// scan, so on a single-class fleet this returns bit-identical placements
/// to `evaluate_cluster` (pinned by `fleet_scan_matches_flat_scan`).
pub fn evaluate_fleet(
    tables: &FleetTables,
    cluster: &Cluster,
    profile: Profile,
) -> Option<Placement> {
    let cands = &crate::mig::CANDIDATES[crate::mig::candidate_range(profile)];
    let size = profile.size();
    let class_ids = cluster.class_ids();
    let mut best_delta = i32::MAX;
    let mut best_gpu = usize::MAX;
    let mut best_start = 0u8;
    for (gpu_id, g) in cluster.gpus().iter().enumerate() {
        if !cluster.hardware_of(gpu_id).supports(profile) {
            continue;
        }
        let occ = g.mask();
        if size > crate::mig::NUM_SLICES as u8 - occ.count_ones() as u8 {
            continue;
        }
        let scores = tables.table(class_ids[gpu_id]).raw();
        let base = scores[occ as usize] as i32;
        for cand in cands {
            if occ & cand.mask != 0 {
                continue;
            }
            let d = scores[(occ | cand.mask) as usize] as i32 - base;
            if d < best_delta {
                best_delta = d;
                best_gpu = gpu_id;
                best_start = cand.start;
            }
        }
    }
    if best_gpu == usize::MAX {
        None
    } else {
        Some(Placement { gpu: best_gpu, profile, index: best_start })
    }
}

/// Like [`evaluate_cluster`] but retains every candidate (for the
/// `inspect` CLI and the quickstart example's explainability output).
pub fn evaluate_cluster_full(
    table: &ScoreTable,
    gpus: &[GpuState],
    profile: Profile,
) -> DeltaOutcome {
    let mut out = DeltaOutcome::default();
    for (gpu_id, &gpu) in gpus.iter().enumerate() {
        if profile.size() > gpu.free_slices() {
            continue;
        }
        for &start in profile.starts() {
            if !gpu.fits_at(profile, start) {
                continue;
            }
            let c = EvaluatedCandidate {
                gpu: gpu_id,
                index: start,
                delta: table.delta(gpu, profile, start),
            };
            out.candidates.push(c);
            let better = match out.best {
                None => true,
                Some(b) => c.delta < b.delta,
            };
            if better {
                out.best = Some(c);
            }
        }
    }
    out
}

/// Test-only helpers shared by property tests across modules.
#[cfg(test)]
pub(crate) mod tests_support {
    use crate::mig::GpuState;

    /// Build a random *reachable* GPU state by committing random feasible
    /// placements.
    pub(crate) fn random_reachable_state(rng: &mut crate::util::rng::Rng) -> GpuState {
        let mut g = GpuState::empty();
        for _ in 0..rng.index(6) {
            let p = *rng.choose(&crate::mig::profile::ALL_PROFILES);
            let feasible: Vec<u8> = g.feasible_indexes(p).collect();
            if feasible.is_empty() {
                continue;
            }
            let s = *rng.choose(&feasible);
            g = g.with_placement(p, s);
        }
        g
    }
}

#[cfg(test)]
mod tests {
    use super::tests_support::random_reachable_state;
    use super::*;
    use crate::mig::HardwareModel;

    fn table() -> ScoreTable {
        ScoreTable::for_hardware(&HardwareModel::a100_80gb())
    }

    #[test]
    fn empty_gpu_prefers_zero_delta_anchor() {
        // On an empty GPU, 3g.40gb at either anchor gives the same ΔF by
        // symmetry? Not quite: @0 blocks 4g.40gb@0; @4 blocks 1g.20gb@6
        // window partially... evaluate and require determinism + argmin.
        let t = table();
        let g = GpuState::empty();
        let (idx, d) = best_delta_on_gpu(&t, g, Profile::P3g40gb).unwrap();
        // Check against brute force.
        let mut best = i32::MAX;
        let mut best_idx = 0;
        for &s in Profile::P3g40gb.starts() {
            let dd = t.delta(g, Profile::P3g40gb, s);
            if dd < best {
                best = dd;
                best_idx = s;
            }
        }
        assert_eq!((idx, d), (best_idx, best));
    }

    #[test]
    fn no_candidate_on_blocked_gpu() {
        let t = table();
        let g = GpuState::empty().with_placement(Profile::P1g10gb, 1);
        assert!(best_delta_on_gpu(&t, g, Profile::P4g40gb).is_none());
        // ΔS guard: 7g on a GPU with one slice used.
        assert!(best_delta_on_gpu(&t, g, Profile::P7g80gb).is_none());
    }

    #[test]
    fn cluster_argmin_prefers_lower_delta_then_lower_ids() {
        let t = table();
        // GPU 0 empty; GPU 1 has 1g.10gb@5 → placing 1g.10gb@4 there has
        // ΔF = -4 (fills a broken window), strictly better than any anchor
        // on the empty GPU 0 (ΔF >= 0).
        let gpus =
            vec![GpuState::empty(), GpuState::empty().with_placement(Profile::P1g10gb, 5)];
        let p = evaluate_cluster(&t, &gpus, Profile::P1g10gb).unwrap();
        assert_eq!((p.gpu, p.index), (1, 4));
        assert_eq!(t.delta(gpus[1], Profile::P1g10gb, 4), -4);
    }

    #[test]
    fn tie_breaks_are_first_gpu_first_index() {
        let t = table();
        // Two identical empty GPUs: must pick GPU 0 and the lowest-ΔF
        // anchor with the lowest index among equals.
        let gpus = vec![GpuState::empty(), GpuState::empty()];
        let p = evaluate_cluster(&t, &gpus, Profile::P7g80gb).unwrap();
        assert_eq!((p.gpu, p.index), (0, 0));
    }

    #[test]
    fn rejects_when_cluster_full() {
        let t = table();
        let gpus = vec![GpuState::from_mask(0xFF); 4];
        assert!(evaluate_cluster(&t, &gpus, Profile::P1g10gb).is_none());
    }

    #[test]
    fn full_outcome_lists_all_feasible() {
        let t = table();
        let gpus = vec![GpuState::empty(), GpuState::from_mask(0xFF)];
        let out = evaluate_cluster_full(&t, &gpus, Profile::P2g20gb);
        // 3 anchors on the empty GPU, none on the full one.
        assert_eq!(out.candidates.len(), 3);
        assert!(out.candidates.iter().all(|c| c.gpu == 0));
        let best = out.best.unwrap();
        assert_eq!(best.delta, out.candidates.iter().map(|c| c.delta).min().unwrap());
    }

    #[test]
    fn fleet_scan_matches_flat_scan() {
        // Single-class fleet: evaluate_fleet must reproduce evaluate_cluster
        // exactly — same placements, same tie-breaks — over random states.
        use crate::util::rng::Rng;
        use crate::workload::WorkloadId;
        let hw = HardwareModel::a100_80gb();
        let t = ScoreTable::for_hardware(&hw);
        let mut rng = Rng::new(777);
        for round in 0..200 {
            let mut cluster = crate::cluster::Cluster::new(hw.clone(), 6);
            let mut next = 0u64;
            for gpu in 0..6 {
                for _ in 0..rng.index(6) {
                    let p = *rng.choose(&crate::mig::profile::ALL_PROFILES);
                    let feasible: Vec<u8> = cluster.gpus()[gpu].feasible_indexes(p).collect();
                    if feasible.is_empty() {
                        continue;
                    }
                    let s = *rng.choose(&feasible);
                    cluster
                        .allocate(WorkloadId(next), Placement { gpu, profile: p, index: s })
                        .unwrap();
                    next += 1;
                }
            }
            let tables = FleetTables::for_cluster(&cluster);
            for p in crate::mig::profile::ALL_PROFILES {
                let flat = evaluate_cluster(&t, cluster.gpus(), p);
                let fleet = evaluate_fleet(&tables, &cluster, p);
                assert_eq!(flat, fleet, "round {round} profile {p}");
            }
        }
    }

    #[test]
    fn fleet_scan_skips_unsupporting_classes() {
        use crate::mig::FleetSpec;
        use crate::workload::WorkloadId;
        // Class 1 only enables 1g.10gb: a 7g request must land on class 0
        // even though GPU 0 (class 1) is emptier.
        let restricted = HardwareModel::h100_80gb().with_profiles(&[Profile::P1g10gb]);
        let fleet = FleetSpec::new(vec![
            (restricted, 1),
            (HardwareModel::a100_80gb(), 2),
        ])
        .unwrap();
        let mut cluster = crate::cluster::Cluster::from_fleet(&fleet);
        cluster
            .allocate(WorkloadId(1), Placement { gpu: 1, profile: Profile::P1g10gb, index: 0 })
            .unwrap();
        let tables = FleetTables::for_cluster(&cluster);
        let pl = evaluate_fleet(&tables, &cluster, Profile::P7g80gb).unwrap();
        assert_eq!(pl.gpu, 2, "empty class-0 GPU is skipped, partially-used gpu1 can't host 7g");
        // But the restricted GPU still competes for the profile it enables.
        let pl = evaluate_fleet(&tables, &cluster, Profile::P1g10gb).unwrap();
        assert_eq!(pl.gpu, 1, "filling gpu1's broken window beats empty GPUs");
        assert_eq!(pl.index, 1);
    }

    #[test]
    fn full_and_fast_paths_agree() {
        let t = table();
        use crate::util::rng::Rng;
        let mut rng = Rng::new(4242);
        for _ in 0..500 {
            let gpus: Vec<GpuState> =
                (0..8).map(|_| random_reachable_state(&mut rng)).collect();
            for p in crate::mig::profile::ALL_PROFILES {
                let fast = evaluate_cluster(&t, &gpus, p);
                let full = evaluate_cluster_full(&t, &gpus, p);
                match (fast, full.best) {
                    (None, None) => {}
                    (Some(pl), Some(b)) => {
                        assert_eq!((pl.gpu, pl.index), (b.gpu, b.index), "{p}");
                    }
                    (a, b) => panic!("disagreement for {p}: {a:?} vs {b:?}"),
                }
            }
        }
    }
}
