//! Direct (oracle) implementation of the paper's Algorithm 1.
//!
//! This module favors legibility over speed — it is the transcription of
//! the pseudo-code that the optimized engines ([`super::table::ScoreTable`],
//! the XLA artifact) are verified against.
//!
//! ## Overlap semantics ([`OverlapRule`])
//!
//! Algorithm 1's line 7 reads "if Σ_{i∈window} x_{m,i} > 0" — *any*
//! overlap between the hypothetical window and occupied slices counts.
//! The paper's own worked example, however, computes something subtly
//! different: on the Fig. 3a states the literal rule yields F(GPU 2)=22,
//! while the paper reports F(GPU 2)=16 with per-profile contributions
//! {1g.20gb→2, 2g.20gb→2, 3g.40gb→8, 4g.40gb→4, 1g.10gb→0} and
//! F(GPU 1)=8 — the numbers produced exactly by counting only windows
//! that contain **both** occupied and free slices. That "partial overlap"
//! reading is also the semantically right one: a fully-occupied window is
//! *productively used* (no slice wasted) and a fully-free window is
//! schedulable; only the mixed windows represent capacity lost to
//! fragmentation. We therefore support both:
//!
//! * [`OverlapRule::Partial`] (default, reproduces the paper's numbers):
//!   an anchor is counted iff its window overlaps an occupied slice AND
//!   retains at least one free slice;
//! * [`OverlapRule::Any`] (literal pseudo-code): any overlap counts.
//!
//! Exhaustive tests pin the paper's worked examples under `Partial`, and
//! the evaluation harness exposes the rule as an ablation
//! (`benches/fig6_fragscore.rs` reports both).

use crate::mig::{GpuState, HardwareModel};
#[cfg(test)]
use crate::mig::Profile;

/// Which hypothetical windows count as fragmented (see module docs).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum OverlapRule {
    /// Window overlaps occupied slices and still has a free slice — the
    /// semantics of the paper's worked example (F(GPU2)=16, F(GPU1)=8).
    #[default]
    Partial,
    /// Any overlap with occupied slices — the literal Algorithm 1 text.
    Any,
}

impl OverlapRule {
    pub fn parse(s: &str) -> Option<OverlapRule> {
        match s.to_ascii_lowercase().as_str() {
            "partial" => Some(OverlapRule::Partial),
            "any" | "literal" => Some(OverlapRule::Any),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            OverlapRule::Partial => "partial",
            OverlapRule::Any => "any",
        }
    }
}

/// Fragmentation score of one GPU under a hardware model's supported
/// profile set — Algorithm 1.
///
/// For each supported profile `p` (line 3): if enough slices are free
/// (line 5, `r_w(p) ≤ ΔS_m`), walk its feasible anchors `I_p` (line 6) and
/// add `r^mem(p)` for every anchor whose window is blocked per `rule`
/// (lines 7-8).
pub fn score_direct_rule(gpu: GpuState, hw: &HardwareModel, rule: OverlapRule) -> u32 {
    let occ = gpu.mask();
    let mut f = 0u32;
    for p in hw.profiles() {
        // line 5: r_w(p) <= ΔS_m
        if p.size() > gpu.free_slices() {
            continue;
        }
        // lines 6-10: count blocked anchors, weighted by memory slices.
        for &start in p.starts() {
            let w = p.mask_at(start);
            let blocked = match rule {
                OverlapRule::Any => occ & w != 0,
                OverlapRule::Partial => occ & w != 0 && occ & w != w,
            };
            if blocked {
                f += p.mem_weight();
            }
        }
    }
    f
}

/// [`score_direct_rule`] under the default (paper worked-example) rule.
pub fn score_direct(gpu: GpuState, hw: &HardwareModel) -> u32 {
    score_direct_rule(gpu, hw, OverlapRule::Partial)
}

/// Trait over fragmentation-score engines so schedulers, metrics and tests
/// can be generic over the implementation (direct oracle, lookup table,
/// XLA-offloaded).
pub trait FragScorer {
    /// `F(m)` for a single GPU state.
    fn score(&self, gpu: GpuState) -> u32;

    /// Cluster-average fragmentation severity `1/M · Σ F(m)` (paper Fig. 6).
    fn mean_score(&self, gpus: &[GpuState]) -> f64 {
        if gpus.is_empty() {
            return 0.0;
        }
        gpus.iter().map(|&g| self.score(g) as f64).sum::<f64>() / gpus.len() as f64
    }
}

/// The oracle engine: recomputes Algorithm 1 on every call.
#[derive(Clone, Debug)]
pub struct DirectScorer {
    hw: HardwareModel,
    rule: OverlapRule,
}

impl DirectScorer {
    pub fn new(hw: HardwareModel) -> Self {
        Self { hw, rule: OverlapRule::default() }
    }

    pub fn with_rule(hw: HardwareModel, rule: OverlapRule) -> Self {
        Self { hw, rule }
    }

    pub fn hardware(&self) -> &HardwareModel {
        &self.hw
    }

    pub fn rule(&self) -> OverlapRule {
        self.rule
    }
}

impl FragScorer for DirectScorer {
    fn score(&self, gpu: GpuState) -> u32 {
        score_direct_rule(gpu, &self.hw, self.rule)
    }
}

/// Upper bound of the score for a profile set: every anchor of every
/// profile blocked while its size still fits. Used to size integer types
/// and normalize severity plots.
pub fn max_score(hw: &HardwareModel) -> u32 {
    hw.profiles().map(|p| p.mem_weight() * p.starts().len() as u32).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mig::profile::ALL_PROFILES;

    fn a100() -> HardwareModel {
        HardwareModel::a100_80gb()
    }

    #[test]
    fn empty_gpu_scores_zero_both_rules() {
        for rule in [OverlapRule::Partial, OverlapRule::Any] {
            assert_eq!(score_direct_rule(GpuState::empty(), &a100(), rule), 0);
        }
    }

    #[test]
    fn full_gpu_scores_zero_both_rules() {
        // Saturated ≠ fragmented: every profile fails the ΔS guard.
        let g = GpuState::empty().with_placement(Profile::P7g80gb, 0);
        for rule in [OverlapRule::Partial, OverlapRule::Any] {
            assert_eq!(score_direct_rule(g, &a100(), rule), 0);
        }
    }

    /// The paper's worked example, Section V-B: GPU 2 of Fig. 3a scores
    /// F(2) = 2 + 2 + 8 + 4 = 16 with per-profile contributions
    /// 1g.20gb→2 (blocked only at index 4, "the second memory slice is
    /// allocated to profile 1g.10gb"), 2g.20gb→2, 3g.40gb→8, 4g.40gb→4,
    /// and 1g.10gb→0. The state realizing the narrative is
    /// {2g.20gb@0, 1g.10gb@5} (occupied slices 0, 1, 5).
    #[test]
    fn paper_worked_example_gpu2_f16() {
        let g = GpuState::empty()
            .with_placement(Profile::P2g20gb, 0)
            .with_placement(Profile::P1g10gb, 5);
        let hw = a100();

        // Per-profile contributions under the Partial rule:
        let contrib = |p: Profile| -> u32 {
            if p.size() > g.free_slices() {
                return 0;
            }
            p.starts()
                .iter()
                .filter(|&&s| {
                    let w = p.mask_at(s);
                    g.mask() & w != 0 && g.mask() & w != w
                })
                .count() as u32
                * p.mem_weight()
        };
        assert_eq!(contrib(Profile::P1g20gb), 2, "blocked only at index 4");
        assert_eq!(contrib(Profile::P2g20gb), 2);
        assert_eq!(contrib(Profile::P3g40gb), 8, "both anchors blocked");
        assert_eq!(contrib(Profile::P4g40gb), 4);
        assert_eq!(contrib(Profile::P1g10gb), 0);
        assert_eq!(contrib(Profile::P7g80gb), 0, "ΔS guard");

        assert_eq!(score_direct(g, &hw), 16, "paper: F(GPU 2) = 16");
        // The literal any-overlap rule does NOT reproduce the paper's
        // number — documented divergence (module docs):
        // 4g@0 +4, 3g@{0,4} +8, 2g@{0,4} +4, 1g.20@{0,4} +4, 1g.10@{0,1,5} +3.
        assert_eq!(score_direct_rule(g, &hw, OverlapRule::Any), 23);
    }

    /// Companion example: F(GPU 1) = 8, realized by {1g.10gb@5}
    /// (3g.40gb@4 +4, 2g.20gb@4 +2, 1g.20gb@4 +2; the fully-occupied
    /// 1g.10gb@5 window does not count).
    #[test]
    fn paper_worked_example_gpu1_f8() {
        let g = GpuState::empty().with_placement(Profile::P1g10gb, 5);
        assert_eq!(score_direct(g, &a100()), 8, "paper: F(GPU 1) = 8");
        // GPU 2 is more fragmented than GPU 1 — the paper's conclusion.
        let g2 = GpuState::empty()
            .with_placement(Profile::P2g20gb, 0)
            .with_placement(Profile::P1g10gb, 5);
        assert!(score_direct(g2, &a100()) > score_direct(g, &a100()));
    }

    #[test]
    fn misplaced_1g_on_empty_gpu() {
        // Section V-B motivation: a single misplaced 1g.10gb at index 1
        // blocks 4g.40gb@0 (+4), 3g.40gb@0 (+4), 2g.20gb@0 (+2),
        // 1g.20gb@0 (+2); 7g.80gb is guarded out (size 8 > ΔS 7). F = 12.
        let g = GpuState::empty().with_placement(Profile::P1g10gb, 1);
        assert_eq!(score_direct(g, &a100()), 12);
        assert!(!g.can_host(Profile::P4g40gb));
    }

    #[test]
    fn well_placed_1g_scores_less() {
        // The same profile at index 6 blocks only 3g.40gb@4 (+4) and
        // 1g.20gb@6 (+2): F = 6 — the best-index intuition the MIG-aware
        // baselines encode.
        let g6 = GpuState::empty().with_placement(Profile::P1g10gb, 6);
        assert_eq!(score_direct(g6, &a100()), 6);
        let g1 = GpuState::empty().with_placement(Profile::P1g10gb, 1);
        assert!(score_direct(g6, &a100()) < score_direct(g1, &a100()));
    }

    #[test]
    fn perfectly_packed_partial_scores_zero() {
        // Partial rule: a tightly packed GPU (4g@0 + 3g@4) wastes nothing.
        let g = GpuState::empty()
            .with_placement(Profile::P4g40gb, 0)
            .with_placement(Profile::P3g40gb, 4);
        assert!(g.is_full());
        assert_eq!(score_direct(g, &a100()), 0);
    }

    #[test]
    fn any_rule_dominates_partial() {
        // Any-overlap counts a superset of windows, so F_any >= F_partial.
        let hw = a100();
        for occ in 0u16..=255 {
            let g = GpuState::from_mask(occ as u8);
            assert!(
                score_direct_rule(g, &hw, OverlapRule::Any)
                    >= score_direct_rule(g, &hw, OverlapRule::Partial),
                "occ={occ:#010b}"
            );
        }
    }

    #[test]
    fn score_monotone_under_restriction() {
        // Removing profiles from the supported set can only lower F.
        let full = a100();
        let restricted = a100().with_profiles(&[Profile::P1g10gb, Profile::P1g20gb]);
        for occ in 0u16..=255 {
            let g = GpuState::from_mask(occ as u8);
            for rule in [OverlapRule::Partial, OverlapRule::Any] {
                assert!(
                    score_direct_rule(g, &restricted, rule)
                        <= score_direct_rule(g, &full, rule),
                    "occ={occ:#010b}"
                );
            }
        }
    }

    #[test]
    fn max_score_value_a100() {
        // 8·1 + 4·1 + 4·2 + 2·3 + 2·4 + 1·7 = 41.
        assert_eq!(max_score(&a100()), 41);
        for occ in 0u16..=255 {
            let g = GpuState::from_mask(occ as u8);
            assert!(score_direct_rule(g, &a100(), OverlapRule::Any) <= 41);
        }
    }

    /// DESIGN.md §2.1 clarification: modeling 7g.80gb as occupying 8 slices
    /// is indistinguishable from the literal Table I "7 slices" reading —
    /// exhaustively, over all reachable allocation states, every other
    /// profile sees the same feasibility vector.
    #[test]
    fn occupy7_vs_8_equivalence() {
        fn reachable(seven_g_mask: u8) -> std::collections::BTreeSet<u8> {
            let mut masks: Vec<u8> = Vec::new();
            for p in ALL_PROFILES {
                for &s in p.starts() {
                    masks.push(if p == Profile::P7g80gb { seven_g_mask } else { p.mask_at(s) });
                }
            }
            let mut seen = std::collections::BTreeSet::new();
            let mut stack = vec![0u8];
            while let Some(occ) = stack.pop() {
                if !seen.insert(occ) {
                    continue;
                }
                for &m in &masks {
                    if occ & m == 0 {
                        stack.push(occ | m);
                    }
                }
            }
            seen
        }
        let with8 = reachable(0xFF);
        let with7 = reachable(0x7F);
        for occ in with7 {
            let equiv = if occ == 0x7F { 0xFF } else { occ };
            assert!(with8.contains(&equiv), "occ={occ:#010b}");
            for p in ALL_PROFILES {
                if p == Profile::P7g80gb {
                    continue;
                }
                assert_eq!(
                    GpuState::from_mask(occ).can_host(p),
                    GpuState::from_mask(equiv).can_host(p),
                    "profile {p} occ={occ:#010b}"
                );
            }
        }
    }

    #[test]
    fn direct_scorer_mean() {
        let scorer = DirectScorer::new(a100());
        let gpus = vec![
            GpuState::empty(),
            GpuState::empty().with_placement(Profile::P1g10gb, 1), // 12
            GpuState::empty().with_placement(Profile::P1g10gb, 5), // 8
        ];
        let mean = scorer.mean_score(&gpus);
        assert!((mean - 20.0 / 3.0).abs() < 1e-12, "{mean}");
        assert_eq!(scorer.mean_score(&[]), 0.0);
    }

    #[test]
    fn rule_parse() {
        assert_eq!(OverlapRule::parse("partial"), Some(OverlapRule::Partial));
        assert_eq!(OverlapRule::parse("ANY"), Some(OverlapRule::Any));
        assert_eq!(OverlapRule::parse("literal"), Some(OverlapRule::Any));
        assert_eq!(OverlapRule::parse("x"), None);
        assert_eq!(OverlapRule::default().name(), "partial");
    }
}
