//! Expected (distribution-aware) fragmentation scoring.
//!
//! The paper's `F(m)` (Algorithm 1) weights every profile equally. FGD
//! (Weng et al., USENIX ATC '23) instead prices a GPU by the fragmentation
//! *the workload actually experiences*: the mix-weighted expectation of
//! per-profile unallocatable capacity. Algorithm 1 is separable per
//! profile, so we precompute a per-profile **component table** — the
//! contribution of each profile to `F(m)` for each of the 256 occupancy
//! masks — and collapse it into a single expected-score table for any
//! observed mix:
//!
//! ```text
//! E[F(m)] = Σ_p  share(p) · F_p(m)        Σ_p F_p(m) = F(m)
//! ```
//!
//! `share(p)` is the estimator's weight normalized to [`SHARE_SCALE`]
//! fixed-point (pure integer arithmetic → bit-reproducible runs). Two
//! structural facts make the scheduler correct:
//!
//! * **Uniform mix ≡ agnostic.** Equal weights normalize to equal integer
//!   shares, so `E = share · F` — a positive scalar multiple with the
//!   same argmin and the same ties as the agnostic score.
//! * **Empty mix has no signal.** All-zero weights give an all-zero table
//!   (every ΔE = 0 — the argmin would degenerate to first-feasible), so
//!   consumers must fall back to the agnostic scorer ([`super::ScoreTable`])
//!   when the estimator is empty; `sched::MfiExpected` does exactly that.
//!
//! [`ExpectedFleet`] mirrors [`FleetTables`] (one component table per
//! device class, Arc-identity revalidation), so heterogeneous fleets work
//! exactly like they do for the agnostic scorer.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

use super::score::OverlapRule;
use super::table::FleetTables;
use crate::cluster::Cluster;
use crate::mig::{GpuState, HardwareModel, Placement, Profile, NUM_PROFILES};
use crate::workload::ProfileMix;

/// Fixed-point scale of the normalized mix shares inside an
/// [`ExpectedTable`]. Matches the estimator's weight scale so one
/// observation's worth of mass is far above the normalization truncation.
pub const SHARE_SCALE: u64 = 1 << 20;

/// Per-profile contributions to Algorithm 1, for all 256 occupancy masks.
///
/// `components[occ][p]` is profile `p`'s summand of `F(occ)` — its memory
/// weight per blocked anchor while its size still fits — so the row sums
/// reproduce the agnostic [`super::ScoreTable`] exactly (pinned by
/// `components_sum_to_agnostic_table`). Built once per (hardware profile
/// set, overlap rule) and cached process-wide, like the agnostic table.
#[derive(Clone, Debug)]
pub struct ComponentTables {
    components: Arc<[[u16; NUM_PROFILES]; 256]>,
    rule: OverlapRule,
    hw_name: String,
}

impl ComponentTables {
    /// Build (or fetch from the process-wide cache) the component tables
    /// for a hardware model under the default overlap rule.
    pub fn for_hardware(hw: &HardwareModel) -> Self {
        Self::for_hardware_rule(hw, OverlapRule::default())
    }

    /// Build (or fetch) the component tables for a model and overlap rule.
    pub fn for_hardware_rule(hw: &HardwareModel, rule: OverlapRule) -> Self {
        type Cache = Mutex<HashMap<(u8, OverlapRule), Arc<[[u16; NUM_PROFILES]; 256]>>>;
        static CACHE: OnceLock<Cache> = OnceLock::new();
        let cache = CACHE.get_or_init(|| Mutex::new(HashMap::new()));
        let key = (hw.profile_set_key(), rule);
        let components = {
            let mut guard = cache.lock().unwrap();
            guard.entry(key).or_insert_with(|| Arc::new(build_components(hw, rule))).clone()
        };
        Self { components, rule, hw_name: hw.name().to_string() }
    }

    /// Profile `p`'s contribution to `F(occ)`.
    #[inline]
    pub fn component(&self, occ: u8, p: Profile) -> u32 {
        self.components[occ as usize][p.index()] as u32
    }

    pub fn rule(&self) -> OverlapRule {
        self.rule
    }

    pub fn hardware_name(&self) -> &str {
        &self.hw_name
    }

    /// Collapse the components into one expected-score table for a mix.
    ///
    /// Weights are normalized to [`SHARE_SCALE`] fixed-point shares by
    /// integer division, so the table depends only on the mix *ratios* at
    /// that resolution and the arithmetic is reproducible bit for bit. An
    /// all-zero weight vector yields the all-zero table — callers must
    /// fall back to the agnostic scorer instead of using it.
    pub fn weighted(&self, weights: &[u64; NUM_PROFILES]) -> ExpectedTable {
        let total: u64 = weights.iter().sum();
        let mut shares = [0u64; NUM_PROFILES];
        if total > 0 {
            for (s, &w) in shares.iter_mut().zip(weights) {
                *s = w * SHARE_SCALE / total;
            }
        }
        let mut scores = Box::new([0u64; 256]);
        for (occ, row) in self.components.iter().enumerate() {
            scores[occ] =
                row.iter().zip(&shares).map(|(&c, &s)| c as u64 * s).sum::<u64>();
        }
        ExpectedTable { scores }
    }
}

fn build_components(hw: &HardwareModel, rule: OverlapRule) -> [[u16; NUM_PROFILES]; 256] {
    let mut t = [[0u16; NUM_PROFILES]; 256];
    for occ in 0..=255u8 {
        let gpu = GpuState::from_mask(occ);
        for p in hw.profiles() {
            if p.size() > gpu.free_slices() {
                continue;
            }
            let mut f = 0u16;
            for &start in p.starts() {
                let w = p.mask_at(start);
                let blocked = match rule {
                    OverlapRule::Any => occ & w != 0,
                    OverlapRule::Partial => occ & w != 0 && occ & w != w,
                };
                if blocked {
                    f += p.mem_weight() as u16;
                }
            }
            t[occ as usize][p.index()] = f;
        }
    }
    t
}

/// A mix-weighted expected-fragmentation table: 256 fixed-point scores,
/// the distribution-aware analogue of [`super::ScoreTable`].
#[derive(Clone, Debug)]
pub struct ExpectedTable {
    scores: Box<[u64; 256]>,
}

impl ExpectedTable {
    #[inline]
    pub fn score_mask(&self, occ: u8) -> u64 {
        self.scores[occ as usize]
    }

    /// ΔE of hypothetically placing `profile` at `start` (free window).
    #[inline]
    pub fn delta(&self, gpu: GpuState, profile: Profile, start: u8) -> i64 {
        let occ = gpu.mask();
        let mask = profile.mask_at(start);
        debug_assert_eq!(occ & mask, 0, "delta() requires a free window");
        self.scores[(occ | mask) as usize] as i64 - self.scores[occ as usize] as i64
    }

    pub fn raw(&self) -> &[u64; 256] {
        &self.scores
    }
}

/// Argmin-ΔE placement over a uniform cluster — [`super::evaluate_cluster`]
/// with the expected table. The scan order, the feasibility skips and the
/// strictly-less `(ΔE, gpu, anchor)` tie-break are identical, so whenever
/// the expected table is a positive scalar multiple of the agnostic one
/// (uniform mix) the two return bit-identical placements.
pub fn evaluate_cluster_expected(
    table: &ExpectedTable,
    gpus: &[GpuState],
    profile: Profile,
) -> Option<Placement> {
    let scores = table.raw();
    let cands = &crate::mig::CANDIDATES[crate::mig::candidate_range(profile)];
    let size = profile.size();
    let mut best_delta = i64::MAX;
    let mut best_gpu = usize::MAX;
    let mut best_start = 0u8;
    for (gpu_id, g) in gpus.iter().enumerate() {
        let occ = g.mask();
        if size > crate::mig::NUM_SLICES as u8 - occ.count_ones() as u8 {
            continue;
        }
        let base = scores[occ as usize] as i64;
        for cand in cands {
            if occ & cand.mask != 0 {
                continue;
            }
            let d = scores[(occ | cand.mask) as usize] as i64 - base;
            if d < best_delta {
                best_delta = d;
                best_gpu = gpu_id;
                best_start = cand.start;
            }
        }
    }
    if best_gpu == usize::MAX {
        None
    } else {
        Some(Placement { gpu: best_gpu, profile, index: best_start })
    }
}

/// Per-device-class expected tables for a heterogeneous fleet — the
/// distribution-aware analogue of [`FleetTables`]. Component tables are
/// built per class at construction; the collapsed expected tables are
/// cached against the estimator's version counter and rebuilt only when
/// the mix actually changed.
#[derive(Clone, Debug)]
pub struct ExpectedFleet {
    components: Vec<ComponentTables>,
    tables: Vec<ExpectedTable>,
    classes: Arc<[HardwareModel]>,
    mix_version: Option<u64>,
}

impl ExpectedFleet {
    /// Per-class component tables for `cluster` under the default rule.
    pub fn for_cluster(cluster: &Cluster) -> Self {
        Self::with_rule(cluster, OverlapRule::default())
    }

    /// Per-class component tables for `cluster` under an explicit rule.
    pub fn with_rule(cluster: &Cluster, rule: OverlapRule) -> Self {
        let classes = cluster.classes_arc().clone();
        let components: Vec<ComponentTables> =
            classes.iter().map(|hw| ComponentTables::for_hardware_rule(hw, rule)).collect();
        Self { components, tables: Vec::new(), classes, mix_version: None }
    }

    /// True when built from `cluster`'s class set (pointer compare on the
    /// shared class-table Arc, same discipline as [`FleetTables::matches`]).
    pub fn matches(&self, cluster: &Cluster) -> bool {
        Arc::ptr_eq(&self.classes, cluster.classes_arc())
    }

    pub fn num_classes(&self) -> usize {
        self.components.len()
    }

    pub fn rule(&self) -> OverlapRule {
        self.components[0].rule()
    }

    /// Rebuild the per-class expected tables iff `mix` changed since the
    /// last refresh (keyed on [`ProfileMix::version`]).
    pub fn refresh(&mut self, mix: &ProfileMix) {
        if self.mix_version == Some(mix.version()) {
            return;
        }
        self.tables = self.components.iter().map(|c| c.weighted(mix.weights())).collect();
        self.mix_version = Some(mix.version());
    }

    /// The expected table for device class `class`. Panics when called
    /// before the first [`refresh`](Self::refresh).
    pub fn table(&self, class: u8) -> &ExpectedTable {
        &self.tables[class as usize]
    }
}

/// Argmin-ΔE over a heterogeneous fleet — [`super::evaluate_fleet`] with
/// per-class expected tables. Identical scan order, supports/capacity
/// skips and strictly-less tie-break. [`ExpectedFleet::refresh`] must have
/// run for the current mix.
pub fn evaluate_fleet_expected(
    fleet: &ExpectedFleet,
    cluster: &Cluster,
    profile: Profile,
) -> Option<Placement> {
    let cands = &crate::mig::CANDIDATES[crate::mig::candidate_range(profile)];
    let size = profile.size();
    let class_ids = cluster.class_ids();
    let mut best_delta = i64::MAX;
    let mut best_gpu = usize::MAX;
    let mut best_start = 0u8;
    for (gpu_id, g) in cluster.gpus().iter().enumerate() {
        if !cluster.hardware_of(gpu_id).supports(profile) {
            continue;
        }
        let occ = g.mask();
        if size > crate::mig::NUM_SLICES as u8 - occ.count_ones() as u8 {
            continue;
        }
        let scores = fleet.table(class_ids[gpu_id]).raw();
        let base = scores[occ as usize] as i64;
        for cand in cands {
            if occ & cand.mask != 0 {
                continue;
            }
            let d = scores[(occ | cand.mask) as usize] as i64 - base;
            if d < best_delta {
                best_delta = d;
                best_gpu = gpu_id;
                best_start = cand.start;
            }
        }
    }
    if best_gpu == usize::MAX {
        None
    } else {
        Some(Placement { gpu: best_gpu, profile, index: best_start })
    }
}

/// Convenience for call sites that already hold agnostic [`FleetTables`]:
/// an [`ExpectedFleet`] under the same overlap rule.
pub fn expected_fleet_like(tables: &FleetTables, cluster: &Cluster) -> ExpectedFleet {
    ExpectedFleet::with_rule(cluster, tables.rule())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frag::delta::tests_support::random_reachable_state;
    use crate::frag::score::score_direct_rule;
    use crate::frag::{evaluate_cluster, evaluate_fleet, ScoreTable};
    use crate::mig::profile::ALL_PROFILES;
    use crate::util::rng::Rng;

    #[test]
    fn components_sum_to_agnostic_table() {
        for hw in [
            HardwareModel::a100_80gb(),
            HardwareModel::a100_40gb(),
            HardwareModel::h100_80gb(),
            HardwareModel::a100_80gb().with_profiles(&[Profile::P1g10gb, Profile::P3g40gb]),
        ] {
            for rule in [OverlapRule::Partial, OverlapRule::Any] {
                let comp = ComponentTables::for_hardware_rule(&hw, rule);
                let table = ScoreTable::for_hardware_rule(&hw, rule);
                for occ in 0u16..=255 {
                    let sum: u32 =
                        ALL_PROFILES.iter().map(|&p| comp.component(occ as u8, p)).sum();
                    assert_eq!(
                        sum,
                        table.score_mask(occ as u8),
                        "hw={} rule={rule:?} occ={occ:#010b}",
                        hw.name()
                    );
                }
            }
        }
    }

    #[test]
    fn component_is_the_direct_score_of_a_single_profile_model() {
        // Restricting the hardware to one profile makes Algorithm 1 compute
        // exactly that profile's component.
        let hw = HardwareModel::a100_80gb();
        let comp = ComponentTables::for_hardware(&hw);
        for p in ALL_PROFILES {
            let solo = hw.with_profiles(&[p]);
            for occ in 0u16..=255 {
                let g = GpuState::from_mask(occ as u8);
                assert_eq!(
                    comp.component(occ as u8, p),
                    score_direct_rule(g, &solo, OverlapRule::Partial),
                    "{p} occ={occ:#010b}"
                );
            }
        }
    }

    #[test]
    fn uniform_mix_is_a_scalar_multiple_of_the_agnostic_table() {
        let hw = HardwareModel::a100_80gb();
        let comp = ComponentTables::for_hardware(&hw);
        let table = ScoreTable::for_hardware(&hw);
        let expected = comp.weighted(&[10, 10, 10, 10, 10, 10]);
        let share = SHARE_SCALE / 6;
        for occ in 0u16..=255 {
            assert_eq!(
                expected.score_mask(occ as u8),
                table.score_mask(occ as u8) as u64 * share,
                "occ={occ:#010b}"
            );
        }
    }

    #[test]
    fn uniform_mix_argmin_matches_agnostic_argmin_on_random_states() {
        let hw = HardwareModel::a100_80gb();
        let table = ScoreTable::for_hardware(&hw);
        let expected = ComponentTables::for_hardware(&hw).weighted(&[7; NUM_PROFILES]);
        let mut rng = Rng::new(2026);
        for round in 0..300 {
            let gpus: Vec<GpuState> =
                (0..6).map(|_| random_reachable_state(&mut rng)).collect();
            for p in ALL_PROFILES {
                let agnostic = evaluate_cluster(&table, &gpus, p);
                let exp = evaluate_cluster_expected(&expected, &gpus, p);
                assert_eq!(agnostic, exp, "round {round} profile {p}");
            }
        }
    }

    #[test]
    fn zero_weights_produce_the_zero_table() {
        let comp = ComponentTables::for_hardware(&HardwareModel::a100_80gb());
        let t = comp.weighted(&[0; NUM_PROFILES]);
        assert!(t.raw().iter().all(|&s| s == 0));
    }

    #[test]
    fn skewed_mix_prices_only_the_observed_profiles() {
        // A mix of pure 1g.10gb arrivals: the expected score of a state
        // must be exactly share × the 1g.10gb component.
        let comp = ComponentTables::for_hardware(&HardwareModel::a100_80gb());
        let mut weights = [0u64; NUM_PROFILES];
        weights[Profile::P1g10gb.index()] = 1234;
        let t = comp.weighted(&weights);
        for occ in 0u16..=255 {
            assert_eq!(
                t.score_mask(occ as u8),
                comp.component(occ as u8, Profile::P1g10gb) as u64 * SHARE_SCALE,
                "occ={occ:#010b}"
            );
        }
    }

    #[test]
    fn expected_delta_matches_score_difference() {
        let comp = ComponentTables::for_hardware(&HardwareModel::a100_80gb());
        let t = comp.weighted(&[3, 1, 4, 1, 5, 9]);
        let mut rng = Rng::new(99);
        for _ in 0..200 {
            let g = random_reachable_state(&mut rng);
            for p in ALL_PROFILES {
                for &s in p.starts() {
                    if !g.fits_at(p, s) {
                        continue;
                    }
                    let expect = t.score_mask(g.with_placement(p, s).mask()) as i64
                        - t.score_mask(g.mask()) as i64;
                    assert_eq!(t.delta(g, p, s), expect, "{p}@{s}");
                }
            }
        }
    }

    #[test]
    fn fleet_refresh_is_version_keyed_and_matches_uniform_agnostic() {
        use crate::mig::FleetSpec;
        let fleet_spec = FleetSpec::new(vec![
            (HardwareModel::a100_80gb(), 2),
            (HardwareModel::h100_80gb(), 2),
        ])
        .unwrap();
        let cluster = Cluster::from_fleet(&fleet_spec);
        let tables = FleetTables::for_cluster(&cluster);
        let mut exp = ExpectedFleet::for_cluster(&cluster);
        assert!(exp.matches(&cluster));
        assert_eq!(exp.num_classes(), 2);

        let mut mix = ProfileMix::new(0);
        for p in ALL_PROFILES {
            mix.observe(p); // uniform: one observation per profile
        }
        exp.refresh(&mix);
        let v = mix.version();
        exp.refresh(&mix); // no-op on unchanged version
        assert_eq!(v, mix.version());
        for p in ALL_PROFILES {
            assert_eq!(
                evaluate_fleet(&tables, &cluster, p),
                evaluate_fleet_expected(&exp, &cluster, p),
                "uniform-mix fleet argmin must match agnostic for {p}"
            );
        }

        // After the mix shifts, the refresh rebuilds (different version).
        mix.observe(Profile::P1g10gb);
        exp.refresh(&mix);
        let one_sided = exp.table(0).score_mask(0b0000_0001);
        assert!(one_sided > 0, "shifted mix must still price fragmentation");
    }

    #[test]
    fn fleet_expected_skips_unsupporting_classes() {
        use crate::mig::FleetSpec;
        use crate::workload::WorkloadId;
        let restricted = HardwareModel::h100_80gb().with_profiles(&[Profile::P1g10gb]);
        let spec =
            FleetSpec::new(vec![(restricted, 1), (HardwareModel::a100_80gb(), 2)]).unwrap();
        let mut cluster = Cluster::from_fleet(&spec);
        cluster
            .allocate(WorkloadId(1), Placement { gpu: 1, profile: Profile::P1g10gb, index: 0 })
            .unwrap();
        let mut exp = ExpectedFleet::for_cluster(&cluster);
        let mut mix = ProfileMix::new(0);
        for p in ALL_PROFILES {
            mix.observe(p);
        }
        exp.refresh(&mix);
        let pl = evaluate_fleet_expected(&exp, &cluster, Profile::P7g80gb).unwrap();
        assert_eq!(pl.gpu, 2, "class-0 GPU does not support 7g and must be skipped");
    }
}
