//! Streaming statistics for the experiment harness.
//!
//! Every paper figure is an average over 500 independent Monte Carlo runs;
//! we aggregate metric series with Welford's online algorithm (numerically
//! stable, single pass, O(1) memory per series) and report mean, standard
//! deviation and a 95% confidence interval half-width.

/// Welford online mean/variance accumulator.
#[derive(Clone, Debug)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Default for OnlineStats {
    /// Same as [`OnlineStats::new`] — a derived default would start
    /// `min`/`max` at 0.0 and silently poison extrema of accumulators
    /// built via `Default` (e.g. the sweep's `AggregatedCell`s).
    fn default() -> Self {
        Self::new()
    }
}

impl OnlineStats {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn merge(&mut self, other: &OnlineStats) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n1 = self.n as f64;
        let n2 = other.n as f64;
        let delta = other.mean - self.mean;
        let total = n1 + n2;
        self.mean += delta * n2 / total;
        self.m2 += other.m2 + delta * delta * n1 * n2 / total;
        self.n += other.n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 { f64::NAN } else { self.mean }
    }

    /// Sample variance (n-1 denominator).
    pub fn variance(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }

    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Half-width of the 95% confidence interval for the mean (normal
    /// approximation; the sweep sizes here are hundreds of samples).
    pub fn ci95(&self) -> f64 {
        if self.n < 2 {
            return f64::NAN;
        }
        1.96 * self.std_dev() / (self.n as f64).sqrt()
    }
}

/// Exact percentile over a stored sample (used for latency reporting where
/// tails matter and sample counts are modest).
#[derive(Clone, Debug, Default)]
pub struct Sample {
    values: Vec<f64>,
    sorted: bool,
}

impl Sample {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.values.push(x);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.values.is_empty() {
            return f64::NAN;
        }
        self.values.iter().sum::<f64>() / self.values.len() as f64
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.values.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
    }

    /// Linear-interpolated percentile, `q` in [0, 100].
    pub fn percentile(&mut self, q: f64) -> f64 {
        assert!((0.0..=100.0).contains(&q));
        self.ensure_sorted();
        if self.values.is_empty() {
            return f64::NAN;
        }
        let n = self.values.len();
        if n == 1 {
            return self.values[0];
        }
        let rank = q / 100.0 * (n - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        let frac = rank - lo as f64;
        self.values[lo] * (1.0 - frac) + self.values[hi] * frac
    }

    pub fn min(&mut self) -> f64 {
        self.percentile(0.0)
    }

    pub fn max(&mut self) -> f64 {
        self.percentile(100.0)
    }
}

/// Fixed-bucket histogram for distribution summaries in reports.
#[derive(Clone, Debug)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    buckets: Vec<u64>,
    underflow: u64,
    overflow: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbuckets: usize) -> Self {
        assert!(hi > lo && nbuckets > 0);
        Self { lo, hi, buckets: vec![0; nbuckets], underflow: 0, overflow: 0 }
    }

    pub fn push(&mut self, x: f64) {
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let n = self.buckets.len();
            let idx = ((x - self.lo) / (self.hi - self.lo) * n as f64) as usize;
            self.buckets[idx.min(n - 1)] += 1;
        }
    }

    pub fn bucket_counts(&self) -> &[u64] {
        &self.buckets
    }

    pub fn total(&self) -> u64 {
        self.buckets.iter().sum::<u64>() + self.underflow + self.overflow
    }

    /// Compact ASCII sparkline for log output.
    pub fn sparkline(&self) -> String {
        const GLYPHS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        let max = self.buckets.iter().copied().max().unwrap_or(0).max(1);
        self.buckets
            .iter()
            .map(|&c| GLYPHS[(c as usize * (GLYPHS.len() - 1)) / max as usize])
            .collect()
    }
}

/// Normalize a series by its maximum absolute value (the paper normalizes
/// every metric by its maximum to compare schemes). Zero-max series are
/// returned unchanged.
pub fn normalize_by_max(values: &[f64]) -> Vec<f64> {
    let max = values.iter().cloned().fold(0.0_f64, |a, b| a.max(b.abs()));
    if max == 0.0 {
        values.to_vec()
    } else {
        values.iter().map(|v| v / max).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_naive() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut s = OnlineStats::new();
        for &x in &xs {
            s.push(x);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        // Sample variance of this classic sequence is 32/7.
        assert!((s.variance() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn merge_equals_sequential() {
        let mut all = OnlineStats::new();
        let mut a = OnlineStats::new();
        let mut b = OnlineStats::new();
        for i in 0..100 {
            let x = (i as f64).sin() * 10.0;
            all.push(x);
            if i % 2 == 0 { a.push(x) } else { b.push(x) }
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        assert!((a.mean() - all.mean()).abs() < 1e-9);
        assert!((a.variance() - all.variance()).abs() < 1e-9);
    }

    #[test]
    fn merge_with_empty() {
        let mut a = OnlineStats::new();
        a.push(1.0);
        let empty = OnlineStats::new();
        a.merge(&empty);
        assert_eq!(a.count(), 1);
        let mut e2 = OnlineStats::new();
        e2.merge(&a);
        assert_eq!(e2.count(), 1);
        assert_eq!(e2.mean(), 1.0);
    }

    #[test]
    fn ci95_shrinks_with_n() {
        let mut small = OnlineStats::new();
        let mut large = OnlineStats::new();
        for i in 0..10 {
            small.push((i % 3) as f64);
        }
        for i in 0..1000 {
            large.push((i % 3) as f64);
        }
        assert!(large.ci95() < small.ci95());
    }

    #[test]
    fn percentiles() {
        let mut s = Sample::new();
        for i in 1..=100 {
            s.push(i as f64);
        }
        assert!((s.percentile(50.0) - 50.5).abs() < 1e-9);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 100.0);
        assert!((s.percentile(99.0) - 99.01).abs() < 0.011);
    }

    #[test]
    fn percentile_single_value() {
        let mut s = Sample::new();
        s.push(7.0);
        assert_eq!(s.percentile(0.0), 7.0);
        assert_eq!(s.percentile(100.0), 7.0);
        assert_eq!(s.percentile(50.0), 7.0);
    }

    #[test]
    fn histogram_buckets() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.push(i as f64 + 0.5);
        }
        h.push(-1.0);
        h.push(11.0);
        assert_eq!(h.bucket_counts(), &[1u64; 10][..]);
        assert_eq!(h.total(), 12);
        assert_eq!(h.sparkline().chars().count(), 10);
    }

    #[test]
    fn normalization() {
        assert_eq!(normalize_by_max(&[1.0, 2.0, 4.0]), vec![0.25, 0.5, 1.0]);
        assert_eq!(normalize_by_max(&[0.0, 0.0]), vec![0.0, 0.0]);
    }
}
