//! Property-based testing mini-harness (proptest replacement).
//!
//! Runs a property over many seeded random cases; on failure it reports the
//! failing seed and case index so the exact case can be re-run with
//! `MIGSCHED_CHECK_SEED=<seed>`. A light greedy shrinker is provided for
//! integer-vector inputs (the dominant input shape here: occupancy patterns
//! and workload sequences).

use super::rng::Rng;

/// Number of cases per property (override with `MIGSCHED_CHECK_CASES`).
pub fn default_cases() -> usize {
    std::env::var("MIGSCHED_CHECK_CASES").ok().and_then(|v| v.parse().ok()).unwrap_or(256)
}

fn base_seed() -> u64 {
    std::env::var("MIGSCHED_CHECK_SEED").ok().and_then(|v| v.parse().ok()).unwrap_or(0xC0FFEE)
}

/// Run `prop` over `default_cases()` random cases. `gen` builds a case from
/// an RNG; `prop` returns `Err(description)` on failure.
///
/// Panics with the seed + case rendering on the first failure.
pub fn forall<T: std::fmt::Debug>(
    name: &str,
    gen: impl Fn(&mut Rng) -> T,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    let cases = default_cases();
    let seed = base_seed();
    let mut master = Rng::new(seed ^ hash_name(name));
    for case_idx in 0..cases {
        let mut case_rng = master.fork();
        let case = gen(&mut case_rng);
        if let Err(msg) = prop(&case) {
            panic!(
                "property '{name}' failed at case {case_idx}/{cases} (seed {seed}):\n  \
                 case: {case:?}\n  error: {msg}\n  \
                 re-run with MIGSCHED_CHECK_SEED={seed}"
            );
        }
    }
}

/// `forall` with greedy shrinking for `Vec<u64>`-shaped cases: on failure,
/// tries removing elements and decrementing values to find a smaller
/// counterexample before panicking.
pub fn forall_shrink_vec(
    name: &str,
    gen: impl Fn(&mut Rng) -> Vec<u64>,
    prop: impl Fn(&[u64]) -> Result<(), String>,
) {
    let cases = default_cases();
    let seed = base_seed();
    let mut master = Rng::new(seed ^ hash_name(name));
    for case_idx in 0..cases {
        let mut case_rng = master.fork();
        let case = gen(&mut case_rng);
        if let Err(first_msg) = prop(&case) {
            let (shrunk, msg) = shrink_vec(case, &prop, first_msg);
            panic!(
                "property '{name}' failed at case {case_idx}/{cases} (seed {seed}):\n  \
                 shrunk case: {shrunk:?}\n  error: {msg}\n  \
                 re-run with MIGSCHED_CHECK_SEED={seed}"
            );
        }
    }
}

fn shrink_vec(
    mut case: Vec<u64>,
    prop: &impl Fn(&[u64]) -> Result<(), String>,
    mut msg: String,
) -> (Vec<u64>, String) {
    // Pass 1: greedily drop elements while the property still fails.
    let mut improved = true;
    while improved {
        improved = false;
        let mut i = 0;
        while i < case.len() {
            let mut smaller = case.clone();
            smaller.remove(i);
            if let Err(m) = prop(&smaller) {
                case = smaller;
                msg = m;
                improved = true;
            } else {
                i += 1;
            }
        }
        // Pass 2: decrement values toward zero.
        for i in 0..case.len() {
            while case[i] > 0 {
                let mut smaller = case.clone();
                smaller[i] -= 1;
                if let Err(m) = prop(&smaller) {
                    case = smaller;
                    msg = m;
                    improved = true;
                } else {
                    break;
                }
            }
        }
    }
    (case, msg)
}

fn hash_name(name: &str) -> u64 {
    // FNV-1a, enough to decorrelate properties sharing a seed.
    let mut h: u64 = 0xcbf29ce484222325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Assert two f64 values are close (absolute + relative tolerance), with a
/// readable failure message. Used by the runtime-vs-native numeric checks.
pub fn assert_close(a: f64, b: f64, tol: f64, context: &str) {
    let diff = (a - b).abs();
    let scale = a.abs().max(b.abs()).max(1.0);
    assert!(
        diff <= tol * scale,
        "{context}: {a} vs {b} differ by {diff} (tol {tol}, scale {scale})"
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0usize;
        let counter = std::cell::RefCell::new(&mut count);
        forall(
            "sum-commutative",
            |rng| (rng.below(100), rng.below(100)),
            |&(a, b)| {
                **counter.borrow_mut() += 1;
                if a + b == b + a { Ok(()) } else { Err("math broke".into()) }
            },
        );
        assert_eq!(count, default_cases());
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn failing_property_panics_with_seed() {
        forall("always-fails", |rng| rng.below(10), |_| Err("nope".into()));
    }

    #[test]
    #[should_panic(expected = "shrunk case: [3]")]
    fn shrinker_finds_minimal_counterexample() {
        // Property: no element is >= 3. Minimal counterexample is [3].
        forall_shrink_vec(
            "no-threes",
            |rng| (0..rng.index(20)).map(|_| rng.below(10)).collect(),
            |xs| {
                if xs.iter().any(|&x| x >= 3) {
                    Err("found >= 3".into())
                } else {
                    Ok(())
                }
            },
        );
    }

    #[test]
    fn assert_close_accepts_near() {
        assert_close(1.0, 1.0 + 1e-12, 1e-9, "near");
    }

    #[test]
    #[should_panic(expected = "differ")]
    fn assert_close_rejects_far() {
        assert_close(1.0, 2.0, 1e-9, "far");
    }
}
