//! Minimal JSON value type, parser and writer.
//!
//! Used for config files, workload traces, cluster snapshots and the HTTP
//! API. Supports the full JSON grammar (RFC 8259) with the usual practical
//! choices: numbers are `f64`, object key order is preserved (insertion
//! order) so snapshots and traces are diff-stable.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Object with insertion-ordered keys.
    Obj(Vec<(String, Json)>),
}

impl Json {
    // ----- constructors -------------------------------------------------

    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Builder-style insertion for objects. Panics on non-objects.
    pub fn with(mut self, key: &str, value: impl Into<Json>) -> Json {
        match &mut self {
            Json::Obj(pairs) => pairs.push((key.to_string(), value.into())),
            other => panic!("Json::with on non-object {other:?}"),
        }
        self
    }

    pub fn set(&mut self, key: &str, value: impl Into<Json>) {
        match self {
            Json::Obj(pairs) => {
                let value = value.into();
                if let Some(pair) = pairs.iter_mut().find(|(k, _)| k == key) {
                    pair.1 = value;
                } else {
                    pairs.push((key.to_string(), value));
                }
            }
            other => panic!("Json::set on non-object {other:?}"),
        }
    }

    // ----- accessors ----------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn at(&self, idx: usize) -> Option<&Json> {
        match self {
            Json::Arr(items) => items.get(idx),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= u64::MAX as f64 => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|v| v as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    /// Fetch + convert helpers returning descriptive errors; used by the
    /// API layer where malformed input must become a 400, not a panic.
    pub fn req_str(&self, key: &str) -> Result<&str, String> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| format!("missing or non-string field '{key}'"))
    }

    pub fn req_u64(&self, key: &str) -> Result<u64, String> {
        self.get(key)
            .and_then(Json::as_u64)
            .ok_or_else(|| format!("missing or non-integer field '{key}'"))
    }

    // ----- serialization ------------------------------------------------

    /// Compact single-line rendering.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write_compact_into(&mut out);
        out
    }

    /// Compact rendering appended into a caller-owned buffer, so hot
    /// paths (the daemon's batch responses, reused per-connection
    /// scratch) can serialize without a fresh `String` per value.
    pub fn write_compact_into(&self, out: &mut String) {
        self.write(out, None, 0);
    }

    /// Pretty rendering with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Obj(pairs) => {
                if pairs.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }

    // ----- parsing ------------------------------------------------------

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data after JSON value"));
        }
        Ok(v)
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.is_finite() {
        if n.fract() == 0.0 && n.abs() < 1e15 {
            // Render integral values without a trailing ".0" so u64 fields
            // round-trip through the f64 representation textually unchanged.
            let _ = fmt::Write::write_fmt(out, format_args!("{}", n as i64));
        } else {
            let _ = fmt::Write::write_fmt(out, format_args!("{n}"));
        }
    } else {
        // JSON has no NaN/Inf; emit null like most serializers in lenient mode.
        out.push_str("null");
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = fmt::Write::write_fmt(out, format_args!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset for diagnostics.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub message: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { message: msg.to_string(), offset: self.pos }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("invalid literal, expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(&format!("unexpected byte '{}'", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut pairs: Vec<(String, Json)> = Vec::new();
        let mut seen: BTreeMap<String, ()> = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            if seen.insert(key.clone(), ()).is_some() {
                return Err(self.err(&format!("duplicate key '{key}'")));
            }
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            pairs.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(pairs));
                }
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{0008}'),
                        Some(b'f') => out.push('\u{000C}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            self.pos += 1;
                            let cp = self.hex4()?;
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                // Surrogate pair.
                                if self.peek() == Some(b'\\') {
                                    self.pos += 1;
                                    self.expect(b'u')?;
                                    let lo = self.hex4()?;
                                    if !(0xDC00..0xE000).contains(&lo) {
                                        return Err(self.err("invalid low surrogate"));
                                    }
                                    let combined =
                                        0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(combined)
                                        .ok_or_else(|| self.err("bad surrogate pair"))?
                                } else {
                                    return Err(self.err("lone high surrogate"));
                                }
                            } else {
                                char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?
                            };
                            out.push(c);
                            continue; // hex4 already advanced pos
                        }
                        _ => return Err(self.err("invalid escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = &self.bytes[self.pos..];
                    let s = std::str::from_utf8(rest)
                        .map_err(|_| self.err("invalid UTF-8 in string"))?;
                    let c = s.chars().next().unwrap();
                    if (c as u32) < 0x20 {
                        return Err(self.err("unescaped control character"));
                    }
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let s = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("invalid \\u escape"))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| self.err("invalid \\u escape"))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err(&format!("invalid number '{s}'")))
    }
}

// ----- From conversions --------------------------------------------------

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u32> for Json {
    fn from(n: u32) -> Json {
        Json::Num(n as f64)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string_compact())
    }
}

// ----- zero-allocation flat-object scanning -----------------------------
//
// The daemon's submit hot path only ever reads a handful of scalar fields
// out of a small flat object (`{"profile": "...", "tenant": 3, ...}`).
// Building a full `Json` tree for that costs one allocation per key plus
// the value vector; `scan_flat_object` walks the text once and hands out
// borrowed scalars instead. It is deliberately *narrower* than
// `Json::parse`: anything it is not certain about — nested containers,
// escape sequences, duplicate keys, exotic numbers — makes it bail with
// `false` so the caller can fall back to `Json::parse` and reproduce the
// exact error message (or tolerant behavior) of the slow path.

/// A borrowed scalar produced by [`scan_flat_object`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Scalar<'a> {
    Null,
    Bool(bool),
    Num(f64),
    /// A string containing no escape sequences, borrowed verbatim.
    Str(&'a str),
}

impl<'a> Scalar<'a> {
    pub fn as_str(&self) -> Option<&'a str> {
        match *self {
            Scalar::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Same domain as [`Json::as_u64`]: non-negative integral values.
    pub fn as_u64(&self) -> Option<u64> {
        match *self {
            Scalar::Num(n) if n >= 0.0 && n.fract() == 0.0 && n <= u64::MAX as f64 => {
                Some(n as u64)
            }
            _ => None,
        }
    }
}

/// Scan a *flat* JSON object (`{"key": scalar, ...}`) without allocating,
/// calling `visit(key, value)` for each member in document order.
///
/// Returns `false` — with no guarantee about how many `visit` calls
/// already happened — whenever the document is not a flat scalar object
/// this scanner can prove well-formed. Callers MUST treat `false` as
/// "fall back to [`Json::parse`] and discard anything visited", which
/// keeps error messages and edge-case behavior byte-identical to the
/// allocating path.
pub fn scan_flat_object<'a>(src: &'a str, mut visit: impl FnMut(&'a str, Scalar<'a>)) -> bool {
    let b = src.as_bytes();
    let mut pos = 0usize;
    scan_ws(b, &mut pos);
    if b.get(pos).copied() != Some(b'{') {
        return false;
    }
    pos += 1;
    scan_ws(b, &mut pos);
    let mut seen: crate::util::small::SmallVec<&str, 8> = crate::util::small::SmallVec::new();
    if b.get(pos).copied() == Some(b'}') {
        pos += 1;
    } else {
        loop {
            scan_ws(b, &mut pos);
            let Some(key) = scan_simple_string(src, &mut pos) else {
                return false;
            };
            // `Json::parse` rejects duplicate keys with a positioned
            // error; let it do so.
            if seen.iter().any(|&k| k == key) {
                return false;
            }
            seen.push(key);
            scan_ws(b, &mut pos);
            if b.get(pos).copied() != Some(b':') {
                return false;
            }
            pos += 1;
            scan_ws(b, &mut pos);
            let Some(value) = scan_scalar(src, &mut pos) else {
                return false;
            };
            visit(key, value);
            scan_ws(b, &mut pos);
            match b.get(pos).copied() {
                Some(b',') => pos += 1,
                Some(b'}') => {
                    pos += 1;
                    break;
                }
                _ => return false,
            }
        }
    }
    scan_ws(b, &mut pos);
    pos == b.len()
}

fn scan_ws(b: &[u8], pos: &mut usize) {
    while matches!(b.get(*pos).copied(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
        *pos += 1;
    }
}

/// An escape-free string token; boundaries are the quote bytes, so the
/// borrowed slice is always on char boundaries.
fn scan_simple_string<'a>(src: &'a str, pos: &mut usize) -> Option<&'a str> {
    let b = src.as_bytes();
    if b.get(*pos).copied() != Some(b'"') {
        return None;
    }
    let start = *pos + 1;
    let mut i = start;
    while i < b.len() {
        match b[i] {
            b'"' => {
                *pos = i + 1;
                return Some(&src[start..i]);
            }
            // Escapes and raw control bytes go to the full parser.
            b'\\' => return None,
            c if c < 0x20 => return None,
            _ => i += 1,
        }
    }
    None
}

fn scan_scalar<'a>(src: &'a str, pos: &mut usize) -> Option<Scalar<'a>> {
    let b = src.as_bytes();
    match b.get(*pos).copied()? {
        b'"' => scan_simple_string(src, pos).map(Scalar::Str),
        b'n' => scan_lit(b, pos, "null").then_some(Scalar::Null),
        b't' => scan_lit(b, pos, "true").then_some(Scalar::Bool(true)),
        b'f' => scan_lit(b, pos, "false").then_some(Scalar::Bool(false)),
        b'-' | b'0'..=b'9' => scan_simple_int(b, pos),
        _ => None,
    }
}

fn scan_lit(b: &[u8], pos: &mut usize, lit: &str) -> bool {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        true
    } else {
        false
    }
}

/// Plain decimal integers only; fractions, exponents, leading zeros and
/// anything that might overflow go to the full parser. The `u64 → f64`
/// cast rounds to nearest like `str::parse::<f64>`, so accepted values
/// match `Json::parse` bit-for-bit.
fn scan_simple_int(b: &[u8], pos: &mut usize) -> Option<Scalar<'static>> {
    let mut i = *pos;
    let neg = b[i] == b'-';
    if neg {
        i += 1;
    }
    let start = i;
    while i < b.len() && b[i].is_ascii_digit() {
        i += 1;
    }
    let digits = &b[start..i];
    if digits.is_empty() || (digits.len() > 1 && digits[0] == b'0') {
        return None;
    }
    if matches!(b.get(i).copied(), Some(b'.') | Some(b'e') | Some(b'E')) {
        return None;
    }
    let mut v: u64 = 0;
    for &d in digits {
        v = v.checked_mul(10)?.checked_add(u64::from(d - b'0'))?;
    }
    *pos = i;
    let n = v as f64;
    Some(Scalar::Num(if neg { -n } else { n }))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(j.get("a").unwrap().at(2).unwrap().get("b"), Some(&Json::Null));
        assert_eq!(j.get("c").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn escapes_roundtrip() {
        let original = Json::Str("line\nquote\"back\\slash\ttab \u{1F600} end".into());
        let text = original.to_string_compact();
        assert_eq!(Json::parse(&text).unwrap(), original);
    }

    #[test]
    fn unicode_escape_parsing() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
        // Surrogate pair for U+1F600.
        assert_eq!(Json::parse(r#""😀""#).unwrap(), Json::Str("\u{1F600}".into()));
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,", "{\"a\":}", "tru", "1.2.3", "\"unterminated", "{}x",
                    "{\"a\":1,\"a\":2}", "\"\u{0001}\""] {
            assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn object_builder_and_accessors() {
        let j = Json::obj()
            .with("id", 7u64)
            .with("name", "wl-7")
            .with("ok", true)
            .with("sizes", vec![1u64, 2, 4]);
        assert_eq!(j.req_u64("id").unwrap(), 7);
        assert_eq!(j.req_str("name").unwrap(), "wl-7");
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(j.get("sizes").unwrap().as_arr().unwrap().len(), 3);
        assert!(j.req_u64("missing").is_err());
    }

    #[test]
    fn set_replaces_existing() {
        let mut j = Json::obj().with("a", 1u64);
        j.set("a", 2u64);
        j.set("b", 3u64);
        assert_eq!(j.req_u64("a").unwrap(), 2);
        assert_eq!(j.req_u64("b").unwrap(), 3);
    }

    #[test]
    fn integral_numbers_render_without_fraction() {
        assert_eq!(Json::Num(800.0).to_string_compact(), "800");
        assert_eq!(Json::Num(0.85).to_string_compact(), "0.85");
    }

    #[test]
    fn pretty_parses_back() {
        let j = Json::obj().with("xs", vec![1u64, 2]).with("o", Json::obj().with("k", "v"));
        let pretty = j.to_string_pretty();
        assert!(pretty.contains('\n'));
        assert_eq!(Json::parse(&pretty).unwrap(), j);
    }

    #[test]
    fn deep_roundtrip_fuzz() {
        // Deterministic structural fuzz using our own RNG.
        use crate::util::rng::Rng;
        let mut rng = Rng::new(99);
        for _ in 0..200 {
            let v = random_json(&mut rng, 3);
            let s = v.to_string_compact();
            let back = Json::parse(&s).unwrap_or_else(|e| panic!("{e}: {s}"));
            assert_eq!(back, v, "{s}");
        }
    }

    fn random_json(rng: &mut crate::util::rng::Rng, depth: usize) -> Json {
        let pick = if depth == 0 { rng.index(4) } else { rng.index(6) };
        match pick {
            0 => Json::Null,
            1 => Json::Bool(rng.chance(0.5)),
            2 => Json::Num((rng.below(2_000_000) as f64 - 1_000_000.0) / 8.0),
            3 => {
                let n = rng.index(8);
                Json::Str((0..n).map(|_| *rng.choose(&['a', '"', '\\', 'ß', '\n'])).collect())
            }
            4 => Json::Arr((0..rng.index(4)).map(|_| random_json(rng, depth - 1)).collect()),
            _ => {
                let mut obj = Json::obj();
                for i in 0..rng.index(4) {
                    obj.set(&format!("k{i}"), random_json(rng, depth - 1));
                }
                obj
            }
        }
    }

    /// Run the scanner, collecting visits; `None` means it bailed.
    fn scan(src: &str) -> Option<Vec<(String, String)>> {
        let mut out = Vec::new();
        scan_flat_object(src, |k, v| out.push((k.to_string(), format!("{v:?}"))))
            .then_some(out)
    }

    #[test]
    fn scanner_accepts_flat_scalar_objects() {
        let got = scan(r#"{"profile": "1g.10gb", "tenant": 7, "on": true, "x": null}"#)
            .unwrap();
        assert_eq!(
            got,
            vec![
                ("profile".into(), "Str(\"1g.10gb\")".into()),
                ("tenant".into(), "Num(7.0)".into()),
                ("on".into(), "Bool(true)".into()),
                ("x".into(), "Null".into()),
            ]
        );
        assert_eq!(scan("{}").unwrap(), vec![]);
        assert_eq!(scan(" { } ").unwrap(), vec![]);
        assert_eq!(scan(r#"{"n":-42}"#).unwrap(), vec![("n".into(), "Num(-42.0)".into())]);
    }

    #[test]
    fn scanner_bails_to_the_full_parser_on_anything_unusual() {
        // Every bail case must be something Json::parse either also
        // rejects or handles with behavior the fast path can't mirror
        // cheaply — nested values, escapes, floats, duplicates, junk.
        for src in [
            r#"{"a": [1]}"#,
            r#"{"a": {"b": 1}}"#,
            r#"{"a": "e\nsc"}"#,
            r#"{"a": 1.5}"#,
            r#"{"a": 1e3}"#,
            r#"{"a": 007}"#,
            r#"{"a": 1, "a": 2}"#,
            r#"{"a": 1} trailing"#,
            r#"{"a" 1}"#,
            r#"{"a": }"#,
            r#"[1, 2]"#,
            r#"{"a": 99999999999999999999999999}"#,
            "",
        ] {
            assert!(scan(src).is_none(), "scanner should bail on {src:?}");
        }
    }

    #[test]
    fn scanner_matches_json_parse_on_accepted_documents() {
        // Whenever the scanner accepts, Json::parse must agree on both
        // acceptance and content (the fast path may only be narrower).
        use crate::util::rng::Rng;
        let keys = ["profile", "tenant", "duration_slots", "k", "très"];
        let mut rng = Rng::new(4242);
        for _ in 0..300 {
            let n = rng.index(4);
            let mut obj = Json::obj();
            for i in 0..n {
                let key = format!("{}{i}", rng.choose(&keys));
                let val = match rng.index(4) {
                    0 => Json::Null,
                    1 => Json::Bool(rng.chance(0.5)),
                    2 => Json::Num(rng.below(1 << 50) as f64),
                    _ => Json::Str(format!("s{}", rng.below(1000))),
                };
                obj.set(&key, val);
            }
            let src = obj.to_string_compact();
            let mut visited = Vec::new();
            assert!(
                scan_flat_object(&src, |k, v| visited.push((k.to_string(), v))),
                "scanner rejected canonical flat object {src}"
            );
            let parsed = Json::parse(&src).unwrap();
            let Json::Obj(pairs) = parsed else { panic!("not an object: {src}") };
            assert_eq!(visited.len(), pairs.len(), "{src}");
            for ((sk, sv), (pk, pv)) in visited.iter().zip(&pairs) {
                assert_eq!(sk, pk, "{src}");
                match (sv, pv) {
                    (Scalar::Null, Json::Null) => {}
                    (Scalar::Bool(a), Json::Bool(b)) => assert_eq!(a, b, "{src}"),
                    (Scalar::Num(a), Json::Num(b)) => assert_eq!(a, b, "{src}"),
                    (Scalar::Str(a), Json::Str(b)) => assert_eq!(a, b, "{src}"),
                    (s, p) => panic!("scanner {s:?} vs parser {p:?} in {src}"),
                }
            }
        }
    }

    #[test]
    fn scalar_accessors_mirror_json_accessors() {
        assert_eq!(Scalar::Str("x").as_str(), Some("x"));
        assert_eq!(Scalar::Num(3.0).as_str(), None);
        assert_eq!(Scalar::Num(3.0).as_u64(), Some(3));
        assert_eq!(Scalar::Num(-1.0).as_u64(), None);
        assert_eq!(Scalar::Null.as_u64(), None);
        assert_eq!(
            Json::parse("3").unwrap().as_u64(),
            Scalar::Num(3.0).as_u64()
        );
    }

    #[test]
    fn write_compact_into_appends_to_the_buffer() {
        let mut buf = String::from("prefix:");
        Json::obj().with("a", 1u64).write_compact_into(&mut buf);
        assert_eq!(buf, r#"prefix:{"a":1}"#);
    }
}
