//! Deterministic, seedable pseudo-random number generation.
//!
//! The simulator needs reproducible Monte Carlo runs: every experiment is
//! parameterized by a `u64` seed and must produce bit-identical results
//! across runs and machines. We implement xoshiro256** (Blackman/Vigna),
//! seeded through SplitMix64 as the authors recommend, plus the sampling
//! helpers the workload generator and property-test harness need.

/// SplitMix64: used to expand a single `u64` seed into xoshiro state and as
/// a cheap standalone generator for seed derivation.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256**: fast, high-quality, 256-bit state general-purpose PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let mut s = [0u64; 4];
        for slot in s.iter_mut() {
            *slot = sm.next_u64();
        }
        // xoshiro state must not be all-zero; SplitMix64 guarantees this is
        // astronomically unlikely but we guard anyway for seed-hunting tools.
        if s == [0, 0, 0, 0] {
            s = [0x9E3779B97F4A7C15, 1, 2, 3];
        }
        Self { s }
    }

    /// Derive an independent child generator (for per-run seeding in sweeps).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform float in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)` using Lemire's unbiased multiply-shift
    /// rejection method. Panics if `n == 0`.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "Rng::below(0)");
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let l = m as u64;
            if l >= n {
                return (m >> 64) as u64;
            }
            // Rejection zone: only entered with probability < n / 2^64.
            let t = n.wrapping_neg() % n;
            if l >= t {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform integer in the inclusive range `[lo, hi]`.
    #[inline]
    pub fn range_inclusive(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi, "empty range [{lo}, {hi}]");
        lo + self.below(hi - lo + 1)
    }

    /// Uniform usize in `[0, n)`.
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Pick a uniformly random element of a slice. Panics on empty input.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.index(items.len())]
    }

    /// Fisher-Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.index(i + 1);
            items.swap(i, j);
        }
    }

    /// Sample an index according to the given (not necessarily normalized)
    /// non-negative weights via inverse-CDF. Panics if all weights are zero.
    pub fn weighted_index(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(
            total > 0.0 && total.is_finite(),
            "weighted_index: weights must have positive finite sum, got {total}"
        );
        let mut x = self.next_f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            debug_assert!(w >= 0.0, "negative weight {w} at {i}");
            if x < w {
                return i;
            }
            x -= w;
        }
        // Floating-point slop: return the last positively-weighted index.
        weights
            .iter()
            .rposition(|&w| w > 0.0)
            .expect("weighted_index: no positive weight")
    }

    /// Standard normal via Box-Muller (used by arrival-jitter extensions).
    pub fn normal(&mut self, mean: f64, std_dev: f64) -> f64 {
        // Avoid ln(0).
        let u1 = (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        let u1 = if u1 <= f64::MIN_POSITIVE { f64::MIN_POSITIVE } else { u1 };
        let u2 = self.next_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        mean + std_dev * r * theta.cos()
    }

    /// Exponential variate with rate `lambda` (Poisson-process inter-arrival
    /// times for the serving-daemon load generator).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        let u = 1.0 - self.next_f64(); // in (0, 1]
        -u.ln() / lambda
    }
}

/// Precomputed alias table (Vose) for O(1) sampling from a fixed discrete
/// distribution; used by the workload generator where a distribution is
/// sampled millions of times per experiment sweep.
#[derive(Clone, Debug)]
pub struct AliasTable {
    prob: Vec<f64>,
    alias: Vec<usize>,
}

impl AliasTable {
    /// Build from non-negative weights (need not be normalized).
    pub fn new(weights: &[f64]) -> Self {
        let n = weights.len();
        assert!(n > 0, "AliasTable over empty distribution");
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0 && total.is_finite(), "AliasTable: bad weight sum {total}");
        let mut prob: Vec<f64> = weights.iter().map(|w| w * n as f64 / total).collect();
        let mut alias = vec![0usize; n];
        let mut small: Vec<usize> = Vec::with_capacity(n);
        let mut large: Vec<usize> = Vec::with_capacity(n);
        for (i, &p) in prob.iter().enumerate() {
            if p < 1.0 {
                small.push(i);
            } else {
                large.push(i);
            }
        }
        while let (Some(&s), Some(&l)) = (small.last(), large.last()) {
            small.pop();
            alias[s] = l;
            prob[l] = (prob[l] + prob[s]) - 1.0;
            if prob[l] < 1.0 {
                large.pop();
                small.push(l);
            }
        }
        // Remaining entries (numeric slop) take probability 1.
        for &i in small.iter().chain(large.iter()) {
            prob[i] = 1.0;
        }
        Self { prob, alias }
    }

    #[inline]
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let i = rng.index(self.prob.len());
        if rng.next_f64() < self.prob[i] {
            i
        } else {
            self.alias[i]
        }
    }

    pub fn len(&self) -> usize {
        self.prob.len()
    }

    pub fn is_empty(&self) -> bool {
        self.prob.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x), "{x}");
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let x = r.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn below_is_roughly_uniform() {
        let mut r = Rng::new(1234);
        let n = 8u64;
        let trials = 80_000;
        let mut counts = vec![0f64; n as usize];
        for _ in 0..trials {
            counts[r.below(n) as usize] += 1.0;
        }
        let expected = trials as f64 / n as f64;
        // Pearson chi-square, 7 dof; 24.32 is the 0.001 critical value.
        let chi2: f64 = counts.iter().map(|c| (c - expected).powi(2) / expected).sum();
        assert!(chi2 < 24.32, "chi2 = {chi2}");
    }

    #[test]
    fn range_inclusive_hits_both_ends() {
        let mut r = Rng::new(5);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..10_000 {
            match r.range_inclusive(3, 6) {
                3 => lo_seen = true,
                6 => hi_seen = true,
                x => assert!((3..=6).contains(&x)),
            }
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn weighted_index_respects_zeros() {
        let mut r = Rng::new(77);
        for _ in 0..5_000 {
            let i = r.weighted_index(&[0.0, 1.0, 0.0, 3.0]);
            assert!(i == 1 || i == 3);
        }
    }

    #[test]
    fn weighted_index_proportions() {
        let mut r = Rng::new(100);
        let w = [1.0, 2.0, 3.0, 4.0];
        let trials = 100_000;
        let mut counts = [0f64; 4];
        for _ in 0..trials {
            counts[r.weighted_index(&w)] += 1.0;
        }
        for i in 0..4 {
            let p = counts[i] / trials as f64;
            let expect = w[i] / 10.0;
            assert!((p - expect).abs() < 0.01, "i={i} p={p} expect={expect}");
        }
    }

    #[test]
    fn alias_table_matches_weights() {
        let w = [0.05, 0.10, 0.10, 0.20, 0.25, 0.30]; // skew-small from Table II
        let at = AliasTable::new(&w);
        let mut r = Rng::new(2024);
        let trials = 200_000;
        let mut counts = vec![0f64; w.len()];
        for _ in 0..trials {
            counts[at.sample(&mut r)] += 1.0;
        }
        for i in 0..w.len() {
            let p = counts[i] / trials as f64;
            assert!((p - w[i]).abs() < 0.01, "i={i} p={p} w={}", w[i]);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut sum, mut sumsq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal(5.0, 2.0);
            sum += x;
            sumsq += x * x;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!((mean - 5.0).abs() < 0.05, "mean={mean}");
        assert!((var - 4.0).abs() < 0.15, "var={var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(13);
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| r.exponential(4.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn fork_decorrelates() {
        let mut parent = Rng::new(21);
        let mut a = parent.fork();
        let mut b = parent.fork();
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
