//! Aligned plain-text table rendering.
//!
//! The figure/report harnesses print the same rows/series the paper reports;
//! this module renders them as column-aligned tables with optional
//! right-alignment for numeric columns, matching the look of the paper's
//! tabular output in a terminal.

/// Column alignment.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Align {
    Left,
    Right,
}

/// A text table with a header row and uniform column alignment rules.
#[derive(Debug, Clone)]
pub struct Table {
    header: Vec<String>,
    aligns: Vec<Align>,
    rows: Vec<Vec<String>>,
    title: Option<String>,
}

impl Table {
    pub fn new<S: AsRef<str>>(header: &[S]) -> Self {
        let header: Vec<String> = header.iter().map(|s| s.as_ref().to_string()).collect();
        // Default: first column left (labels), the rest right (numbers).
        let aligns = header
            .iter()
            .enumerate()
            .map(|(i, _)| if i == 0 { Align::Left } else { Align::Right })
            .collect();
        Self { header, aligns, rows: Vec::new(), title: None }
    }

    pub fn title(mut self, t: &str) -> Self {
        self.title = Some(t.to_string());
        self
    }

    pub fn aligns(mut self, aligns: &[Align]) -> Self {
        assert_eq!(aligns.len(), self.header.len());
        self.aligns = aligns.to_vec();
        self
    }

    pub fn row<S: AsRef<str>>(&mut self, cells: &[S]) {
        assert_eq!(cells.len(), self.header.len(), "table row arity mismatch");
        self.rows.push(cells.iter().map(|s| s.as_ref().to_string()).collect());
    }

    /// Label + numeric row with fixed precision.
    pub fn row_keyed(&mut self, key: &str, values: &[f64], precision: usize) {
        let mut cells = vec![key.to_string()];
        cells.extend(values.iter().map(|v| format!("{v:.precision$}")));
        self.row(&cells);
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.chars().count());
            }
        }
        let mut out = String::new();
        if let Some(t) = &self.title {
            out.push_str(t);
            out.push('\n');
        }
        let render_row = |out: &mut String, cells: &[String]| {
            for i in 0..ncols {
                if i > 0 {
                    out.push_str("  ");
                }
                let cell = &cells[i];
                let pad = widths[i].saturating_sub(cell.chars().count());
                match self.aligns[i] {
                    Align::Left => {
                        out.push_str(cell);
                        if i + 1 < ncols {
                            out.extend(std::iter::repeat(' ').take(pad));
                        }
                    }
                    Align::Right => {
                        out.extend(std::iter::repeat(' ').take(pad));
                        out.push_str(cell);
                    }
                }
            }
            out.push('\n');
        };
        render_row(&mut out, &self.header);
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncols - 1);
        out.extend(std::iter::repeat('-').take(total));
        out.push('\n');
        for row in &self.rows {
            render_row(&mut out, row);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["scheme", "acc", "gpus"]);
        t.row(&["MFI", "0.99", "93"]);
        t.row(&["first-fit", "0.91", "88"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("scheme"));
        assert!(lines[1].chars().all(|c| c == '-'));
        // Numeric columns right-aligned: both data rows end at same width.
        assert_eq!(lines[2].len(), lines[3].len());
    }

    #[test]
    fn keyed_rows_precision() {
        let mut t = Table::new(&["k", "v"]);
        t.row_keyed("x", &[0.123456], 3);
        assert!(t.render().contains("0.123"));
        assert_eq!(t.n_rows(), 1);
    }

    #[test]
    fn title_prepended() {
        let t = Table::new(&["a"]).title("Fig. 4a");
        assert!(t.render().starts_with("Fig. 4a\n"));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only"]);
    }
}
