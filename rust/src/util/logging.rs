//! Leveled stderr logger controlled by the `MIGSCHED_LOG` environment
//! variable (`error|warn|info|debug|trace`, default `info`).
//!
//! Kept deliberately simple: a global atomic level, timestamped lines, and
//! macros that compile to a level check plus a formatted write. The hot
//! scheduling path logs nothing at `info`.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn from_str(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }

    pub fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX); // MAX = uninitialized

fn init_from_env() -> u8 {
    let lvl = std::env::var("MIGSCHED_LOG")
        .ok()
        .and_then(|v| Level::from_str(&v))
        .unwrap_or(Level::Info) as u8;
    LEVEL.store(lvl, Ordering::Relaxed);
    lvl
}

/// Current level, lazily initialized from the environment.
pub fn level() -> Level {
    let raw = LEVEL.load(Ordering::Relaxed);
    let raw = if raw == u8::MAX { init_from_env() } else { raw };
    match raw {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

/// Override the level programmatically (tests, CLI `--verbose`).
pub fn set_level(lvl: Level) {
    LEVEL.store(lvl as u8, Ordering::Relaxed);
}

pub fn enabled(lvl: Level) -> bool {
    lvl <= level()
}

/// Emit one log line; prefer the macros below.
pub fn log(lvl: Level, module: &str, args: std::fmt::Arguments<'_>) {
    if !enabled(lvl) {
        return;
    }
    let now = SystemTime::now().duration_since(UNIX_EPOCH).unwrap_or_default();
    let secs = now.as_secs();
    let millis = now.subsec_millis();
    eprintln!("[{secs}.{millis:03} {} {module}] {args}", lvl.tag());
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Error, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_trace {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Trace, module_path!(), format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parsing() {
        assert_eq!(Level::from_str("ERROR"), Some(Level::Error));
        assert_eq!(Level::from_str("warning"), Some(Level::Warn));
        assert_eq!(Level::from_str("Info"), Some(Level::Info));
        assert_eq!(Level::from_str("nope"), None);
    }

    #[test]
    fn ordering_gates() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Trace);
        assert!(enabled(Level::Debug));
        set_level(Level::Info); // restore default for other tests
    }
}
