//! General-purpose substrates used across the scheduler, simulator, server
//! and benchmark harness.
//!
//! The build environment is fully offline and the vendored crate set is the
//! transitive closure of the `xla` crate only, so the usual ecosystem crates
//! (`rand`, `serde`/`serde_json`, `tokio`, `criterion`, `clap`, `proptest`)
//! are unavailable. Each submodule here is a small, tested, dependency-free
//! replacement for the subset of functionality this project needs:
//!
//! * [`rng`] — deterministic, seedable PRNG (SplitMix64 / xoshiro256**) and
//!   the sampling distributions used by the workload generator.
//! * [`json`] — a JSON value type with parser and writer (config files,
//!   traces, snapshots, the HTTP API).
//! * [`csv`] — a CSV writer for experiment result exports.
//! * [`stats`] — streaming statistics (Welford), percentiles, confidence
//!   intervals and histograms for the experiment harness.
//! * [`logging`] — compatibility re-export of [`crate::obs::log`], the
//!   leveled RFC3339 stderr logger controlled by `MIGSCHED_LOG`.
//! * [`table`] — aligned plain-text table rendering for figure/report output.
//! * [`bench`] — a micro/macro benchmark harness (criterion replacement) used
//!   by the `harness = false` bench binaries.
//! * [`check`] — a property-based testing mini-harness (proptest replacement)
//!   with seeded case generation and failure reporting.
//! * [`small`] — an inline-first vector (smallvec replacement) keeping the
//!   daemon's short per-request collections off the heap.

pub mod bench;
pub mod check;
pub mod csv;
pub mod json;
pub mod rng;
pub mod small;
pub mod stats;
pub mod table;

/// The logger moved to [`crate::obs::log`] when the observability layer
/// landed; this alias keeps `util::logging::*` paths working.
pub use crate::obs::log as logging;
