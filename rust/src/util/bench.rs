//! Criterion-replacement micro/macro benchmark harness.
//!
//! The offline crate set has no `criterion`, so the `harness = false` bench
//! binaries under `rust/benches/` use this module: calibrated warmup, batched
//! timed iterations, robust statistics (median of batch means), throughput
//! reporting, and a `--quick` mode honored via the `MIGSCHED_BENCH_QUICK`
//! environment variable so CI can smoke-run every bench cheaply.

use std::hint::black_box;
use std::time::{Duration, Instant};

use super::stats::Sample;

/// Benchmark configuration.
#[derive(Clone, Debug)]
pub struct BenchConfig {
    /// Target wall time spent measuring each benchmark.
    pub measure_time: Duration,
    /// Target wall time spent warming up.
    pub warmup_time: Duration,
    /// Number of measurement batches (each batch's mean is one sample).
    pub batches: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        if quick_mode() {
            Self {
                measure_time: Duration::from_millis(200),
                warmup_time: Duration::from_millis(50),
                batches: 10,
            }
        } else {
            Self {
                measure_time: Duration::from_secs(2),
                warmup_time: Duration::from_millis(300),
                batches: 20,
            }
        }
    }
}

/// True when `MIGSCHED_BENCH_QUICK` is set (CI smoke mode).
pub fn quick_mode() -> bool {
    std::env::var("MIGSCHED_BENCH_QUICK").map(|v| v != "0").unwrap_or(false)
}

/// Result of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub median_ns: f64,
    pub p05_ns: f64,
    pub p95_ns: f64,
    pub iterations: u64,
}

impl BenchResult {
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / (self.median_ns * 1e-9)
    }

    pub fn summary(&self) -> String {
        format!(
            "{:<44} {:>12}/iter  (p05 {:>10}, p95 {:>10}, n={})",
            self.name,
            fmt_ns(self.median_ns),
            fmt_ns(self.p05_ns),
            fmt_ns(self.p95_ns),
            self.iterations
        )
    }
}

/// Human duration formatting.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.1} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1_000_000.0)
    } else {
        format!("{:.3} s", ns / 1_000_000_000.0)
    }
}

/// A named group of benchmarks that prints results as it goes and can dump
/// a CSV at the end.
pub struct BenchRunner {
    group: String,
    config: BenchConfig,
    results: Vec<BenchResult>,
}

impl BenchRunner {
    pub fn new(group: &str) -> Self {
        println!("== bench group: {group} ==");
        Self { group: group.to_string(), config: BenchConfig::default(), results: Vec::new() }
    }

    pub fn with_config(group: &str, config: BenchConfig) -> Self {
        println!("== bench group: {group} ==");
        Self { group: group.to_string(), config, results: Vec::new() }
    }

    /// Benchmark `f`, which performs ONE logical iteration per call.
    /// The return value is black-boxed to keep the optimizer honest.
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> &BenchResult {
        // Warmup + per-iteration cost estimate.
        let warmup_start = Instant::now();
        let mut warmup_iters: u64 = 0;
        while warmup_start.elapsed() < self.config.warmup_time {
            black_box(f());
            warmup_iters += 1;
        }
        let est_ns =
            (warmup_start.elapsed().as_nanos() as f64 / warmup_iters.max(1) as f64).max(1.0);

        // Choose a batch size so each batch takes measure_time / batches.
        let per_batch_ns =
            self.config.measure_time.as_nanos() as f64 / self.config.batches as f64;
        let batch_iters = ((per_batch_ns / est_ns).ceil() as u64).max(1);

        let mut sample = Sample::new();
        let mut total_iters = 0u64;
        for _ in 0..self.config.batches {
            let t0 = Instant::now();
            for _ in 0..batch_iters {
                black_box(f());
            }
            let elapsed = t0.elapsed().as_nanos() as f64;
            sample.push(elapsed / batch_iters as f64);
            total_iters += batch_iters;
        }
        let result = BenchResult {
            name: name.to_string(),
            median_ns: sample.percentile(50.0),
            p05_ns: sample.percentile(5.0),
            p95_ns: sample.percentile(95.0),
            iterations: total_iters,
        };
        println!("{}", result.summary());
        self.results.push(result);
        self.results.last().unwrap()
    }

    /// Time a single execution of a long-running scenario (macro-bench):
    /// runs it `reps` times and records per-run wall time.
    pub fn bench_once<T, F: FnMut() -> T>(&mut self, name: &str, reps: usize, mut f: F) -> &BenchResult {
        let reps = if quick_mode() { reps.min(2).max(1) } else { reps };
        let mut sample = Sample::new();
        for _ in 0..reps {
            let t0 = Instant::now();
            black_box(f());
            sample.push(t0.elapsed().as_nanos() as f64);
        }
        let result = BenchResult {
            name: name.to_string(),
            median_ns: sample.percentile(50.0),
            p05_ns: sample.percentile(5.0),
            p95_ns: sample.percentile(95.0),
            iterations: reps as u64,
        };
        println!("{}", result.summary());
        self.results.push(result);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Save `name,median_ns,p05_ns,p95_ns,iters` rows under `results/bench/`.
    pub fn save_csv(&self) {
        use super::csv::Csv;
        let mut csv = Csv::new(&["name", "median_ns", "p05_ns", "p95_ns", "iterations"]);
        for r in &self.results {
            csv.row(&[
                r.name.clone(),
                format!("{:.1}", r.median_ns),
                format!("{:.1}", r.p05_ns),
                format!("{:.1}", r.p95_ns),
                r.iterations.to_string(),
            ]);
        }
        let path = std::path::Path::new("results/bench").join(format!("{}.csv", self.group));
        if let Err(e) = csv.save(&path) {
            eprintln!("warning: could not save {}: {e}", path.display());
        } else {
            println!("-- saved {}", path.display());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        std::env::set_var("MIGSCHED_BENCH_QUICK", "1");
        let cfg = BenchConfig {
            measure_time: Duration::from_millis(20),
            warmup_time: Duration::from_millis(5),
            batches: 4,
        };
        let mut runner = BenchRunner::with_config("selftest", cfg);
        let r = runner.bench("sum", || (0..1000u64).sum::<u64>()).clone();
        assert!(r.median_ns > 0.0);
        assert!(r.iterations > 0);
        assert!(r.p05_ns <= r.p95_ns);
    }

    #[test]
    fn fmt_ns_units() {
        assert_eq!(fmt_ns(12.0), "12.0 ns");
        assert_eq!(fmt_ns(1_500.0), "1.50 µs");
        assert_eq!(fmt_ns(2_500_000.0), "2.50 ms");
        assert_eq!(fmt_ns(3_200_000_000.0), "3.200 s");
    }

    #[test]
    fn bench_once_reps() {
        std::env::set_var("MIGSCHED_BENCH_QUICK", "1");
        let mut runner = BenchRunner::with_config(
            "selftest2",
            BenchConfig {
                measure_time: Duration::from_millis(5),
                warmup_time: Duration::from_millis(1),
                batches: 2,
            },
        );
        let r = runner.bench_once("noop", 3, || 42).clone();
        assert!(r.iterations >= 1);
    }
}
