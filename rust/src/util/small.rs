//! A tiny inline-first vector used on the daemon's request hot path to
//! keep short, bounded collections — path segments, scanned JSON field
//! spans — off the heap.
//!
//! Deliberately minimal and `unsafe`-free: elements live in an inline
//! `[T; N]` until the capacity overflows, at which point everything
//! spills to an ordinary `Vec`. Requiring `T: Copy + Default` keeps the
//! inline array initializable without `MaybeUninit`; the types stored
//! here (string slices, span tuples) all qualify.

use std::fmt;
use std::ops::Deref;

/// A vector with `N` inline slots that spills to the heap past that.
///
/// Invariant: when `heap` is empty the live elements are
/// `inline[..len]`; after a spill they are all in `heap` and `len`
/// mirrors `heap.len()`.
#[derive(Clone)]
pub struct SmallVec<T: Copy + Default, const N: usize> {
    inline: [T; N],
    len: usize,
    heap: Vec<T>,
}

impl<T: Copy + Default, const N: usize> SmallVec<T, N> {
    pub fn new() -> Self {
        Self { inline: [T::default(); N], len: 0, heap: Vec::new() }
    }

    pub fn push(&mut self, value: T) {
        if self.heap.is_empty() && self.len < N {
            self.inline[self.len] = value;
            self.len += 1;
            return;
        }
        if self.heap.is_empty() {
            // Spill: move the inline prefix over, then append.
            self.heap.reserve(N * 2);
            self.heap.extend_from_slice(&self.inline[..self.len]);
        }
        self.heap.push(value);
        self.len = self.heap.len();
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Whether the elements still fit in the inline slots (no heap
    /// allocation has happened).
    pub fn is_inline(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn as_slice(&self) -> &[T] {
        if self.heap.is_empty() {
            &self.inline[..self.len]
        } else {
            &self.heap
        }
    }

    pub fn clear(&mut self) {
        self.len = 0;
        self.heap.clear();
    }
}

impl<T: Copy + Default, const N: usize> Default for SmallVec<T, N> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T: Copy + Default, const N: usize> Deref for SmallVec<T, N> {
    type Target = [T];

    fn deref(&self) -> &[T] {
        self.as_slice()
    }
}

impl<T: Copy + Default + fmt::Debug, const N: usize> fmt::Debug for SmallVec<T, N> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_list().entries(self.as_slice()).finish()
    }
}

impl<T: Copy + Default + PartialEq, const N: usize> PartialEq for SmallVec<T, N> {
    fn eq(&self, other: &Self) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl<T: Copy + Default + Eq, const N: usize> Eq for SmallVec<T, N> {}

impl<'a, T: Copy + Default, const N: usize> IntoIterator for &'a SmallVec<T, N> {
    type Item = &'a T;
    type IntoIter = std::slice::Iter<'a, T>;

    fn into_iter(self) -> Self::IntoIter {
        self.as_slice().iter()
    }
}

impl<T: Copy + Default, const N: usize> FromIterator<T> for SmallVec<T, N> {
    fn from_iter<I: IntoIterator<Item = T>>(iter: I) -> Self {
        let mut v = Self::new();
        for item in iter {
            v.push(item);
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stays_inline_up_to_capacity() {
        let mut v: SmallVec<u32, 4> = SmallVec::new();
        assert!(v.is_empty());
        for i in 0..4 {
            v.push(i);
        }
        assert!(v.is_inline());
        assert_eq!(v.as_slice(), &[0, 1, 2, 3]);
        assert_eq!(v.len(), 4);
    }

    #[test]
    fn spills_to_heap_past_capacity_preserving_order() {
        let mut v: SmallVec<u32, 4> = SmallVec::new();
        for i in 0..10 {
            v.push(i);
        }
        assert!(!v.is_inline());
        assert_eq!(v.as_slice(), &[0, 1, 2, 3, 4, 5, 6, 7, 8, 9]);
        assert_eq!(v.len(), 10);
        // Keeps growing on the heap once spilled.
        v.push(10);
        assert_eq!(v.len(), 11);
        assert_eq!(v[10], 10);
    }

    #[test]
    fn deref_and_iteration_work_in_both_modes() {
        let small: SmallVec<u32, 8> = (0..3).collect();
        let big: SmallVec<u32, 2> = (0..5).collect();
        assert_eq!(small.iter().sum::<u32>(), 3);
        assert_eq!(big.iter().sum::<u32>(), 10);
        assert_eq!(&small[1..], &[1, 2]);
    }

    #[test]
    fn clear_resets_both_modes() {
        let mut v: SmallVec<u32, 2> = (0..5).collect();
        v.clear();
        assert!(v.is_empty());
        v.push(7);
        assert_eq!(v.as_slice(), &[7]);
    }

    #[test]
    fn equality_ignores_storage_mode() {
        let a: SmallVec<u32, 8> = (0..3).collect();
        let mut b: SmallVec<u32, 2> = (0..3).collect();
        assert_eq!(a.as_slice(), b.as_slice());
        b.push(3);
        assert_ne!(a.as_slice(), b.as_slice());
    }

    #[test]
    fn str_slices_work_as_elements() {
        let mut v: SmallVec<&str, 4> = SmallVec::new();
        v.push("v1");
        v.push("workloads");
        assert_eq!(v.as_slice(), &["v1", "workloads"]);
    }
}
