//! Minimal CSV writer (RFC 4180 quoting) for experiment result exports.
//!
//! Every bench/figure harness writes its raw series to `results/*.csv` so
//! the numbers behind EXPERIMENTS.md can be re-plotted externally.

use std::fmt::Write as _;
use std::io::{self, Write};
use std::path::Path;

/// In-memory CSV document builder.
#[derive(Debug, Default, Clone)]
pub struct Csv {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Csv {
    pub fn new<S: AsRef<str>>(header: &[S]) -> Self {
        Self { header: header.iter().map(|s| s.as_ref().to_string()).collect(), rows: Vec::new() }
    }

    /// Append a row; must match the header arity.
    pub fn row<S: AsRef<str>>(&mut self, cells: &[S]) {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "CSV row arity {} != header arity {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells.iter().map(|s| s.as_ref().to_string()).collect());
    }

    /// Append a row of f64 values formatted with 6 significant digits.
    pub fn row_f64(&mut self, cells: &[f64]) {
        let formatted: Vec<String> = cells.iter().map(|v| format_f64(*v)).collect();
        self.row(&formatted);
    }

    /// Mixed convenience: a string key column followed by numeric columns.
    pub fn row_keyed(&mut self, key: &str, cells: &[f64]) {
        let mut formatted = vec![key.to_string()];
        formatted.extend(cells.iter().map(|v| format_f64(*v)));
        self.row(&formatted);
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        write_record(&mut out, &self.header);
        for row in &self.rows {
            write_record(&mut out, row);
        }
        out
    }

    pub fn write_to(&self, w: &mut dyn Write) -> io::Result<()> {
        w.write_all(self.render().as_bytes())
    }

    pub fn save(&self, path: &Path) -> io::Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.render())
    }
}

/// Split one CSV record line into fields (RFC 4180: `"`-quoting with `""`
/// escapes). The inverse of [`Csv::render`]'s row encoding, used by the
/// trace importers ([`crate::workload::ingest`]) — which must tolerate
/// real-world logs, so errors are descriptive values, never panics.
///
/// Embedded newlines inside quoted fields are NOT supported (the record
/// boundary here is the physical line, as in every GPU-cluster job log we
/// import); a quote left open at end-of-line is an error.
pub fn parse_line(line: &str) -> Result<Vec<String>, String> {
    let line = line.strip_suffix('\n').unwrap_or(line);
    let line = line.strip_suffix('\r').unwrap_or(line);
    let mut fields = Vec::new();
    let mut field = String::new();
    let mut chars = line.chars().peekable();
    let mut in_quotes = false;
    let mut was_quoted = false;
    while let Some(c) = chars.next() {
        if in_quotes {
            match c {
                '"' => {
                    if chars.peek() == Some(&'"') {
                        chars.next();
                        field.push('"');
                    } else {
                        in_quotes = false;
                    }
                }
                _ => field.push(c),
            }
        } else {
            match c {
                ',' => {
                    fields.push(std::mem::take(&mut field));
                    was_quoted = false;
                }
                '"' => {
                    if !field.is_empty() || was_quoted {
                        return Err("quote in the middle of an unquoted field".into());
                    }
                    in_quotes = true;
                    was_quoted = true;
                }
                _ if was_quoted => {
                    return Err("data after closing quote".into());
                }
                _ => field.push(c),
            }
        }
    }
    if in_quotes {
        return Err("unterminated quoted field".into());
    }
    fields.push(field);
    Ok(fields)
}

fn format_f64(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        // Fixed 6-decimal precision with trailing zeros trimmed, so values
        // like 0.85 render exactly and diffs stay stable.
        let s = format!("{v:.6}");
        s.trim_end_matches('0').trim_end_matches('.').to_string()
    }
}

fn write_record(out: &mut String, cells: &[String]) {
    for (i, cell) in cells.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        if cell.contains(',') || cell.contains('"') || cell.contains('\n') {
            out.push('"');
            let _ = write!(out, "{}", cell.replace('"', "\"\""));
            out.push('"');
        } else {
            out.push_str(cell);
        }
    }
    out.push('\n');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_basic() {
        let mut c = Csv::new(&["scheme", "demand", "acceptance"]);
        c.row(&["MFI", "0.85", "0.99"]);
        c.row_keyed("FF", &[0.85, 0.91]);
        assert_eq!(c.render(), "scheme,demand,acceptance\nMFI,0.85,0.99\nFF,0.85,0.91\n");
        assert_eq!(c.n_rows(), 2);
    }

    #[test]
    fn quotes_special_cells() {
        let mut c = Csv::new(&["a", "b"]);
        c.row(&["x,y", "he said \"hi\""]);
        assert_eq!(c.render(), "a,b\n\"x,y\",\"he said \"\"hi\"\"\"\n");
    }

    #[test]
    fn f64_formatting() {
        let mut c = Csv::new(&["v"]);
        c.row_f64(&[800.0]);
        c.row_f64(&[0.123456789]);
        assert_eq!(c.render(), "v\n800\n0.123457\n");
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_mismatch_panics() {
        let mut c = Csv::new(&["a", "b"]);
        c.row(&["only-one"]);
    }

    #[test]
    fn parse_line_plain_and_quoted() {
        assert_eq!(parse_line("a,b,c\n").unwrap(), vec!["a", "b", "c"]);
        assert_eq!(parse_line("a,,c").unwrap(), vec!["a", "", "c"]);
        assert_eq!(parse_line("").unwrap(), vec![""]);
        assert_eq!(
            parse_line("\"x,y\",\"he said \"\"hi\"\"\"\r\n").unwrap(),
            vec!["x,y", "he said \"hi\""]
        );
        assert_eq!(parse_line("\"\",b").unwrap(), vec!["", "b"]);
    }

    #[test]
    fn parse_line_roundtrips_render() {
        let mut c = Csv::new(&["a", "b"]);
        c.row(&["x,y", "plain"]);
        let rendered = c.render();
        let mut lines = rendered.lines();
        assert_eq!(parse_line(lines.next().unwrap()).unwrap(), vec!["a", "b"]);
        assert_eq!(parse_line(lines.next().unwrap()).unwrap(), vec!["x,y", "plain"]);
    }

    #[test]
    fn parse_line_rejects_malformed_quoting() {
        assert!(parse_line("\"unterminated").is_err());
        assert!(parse_line("ab\"cd").is_err());
        assert!(parse_line("\"a\"b,c").is_err());
    }

    #[test]
    fn save_creates_dirs() {
        let dir = std::env::temp_dir().join(format!("migsched-csv-{}", std::process::id()));
        let path = dir.join("nested/out.csv");
        let mut c = Csv::new(&["x"]);
        c.row(&["1"]);
        c.save(&path).unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "x\n1\n");
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
