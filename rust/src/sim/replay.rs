//! Open-loop trace replay: drive any [`Scheduler`] with an ingested (or
//! recorded) [`Trace`] and emit the paper's report metrics.
//!
//! This driver differs from the saturation-protocol engine
//! ([`super::engine`]) in exactly the ways real traces differ from the
//! paper's synthetic protocol:
//!
//! * **Open-loop arrivals** — the trace dictates arrivals; rejections do
//!   not slow or stop the stream (no feedback from cluster to workload).
//! * **Bursts and gaps** — any number of arrivals may share a slot, and
//!   slots with no arrivals pass silently; the engine's one-arrival-per-
//!   slot invariant does not hold for wall-clock-normalized traces.
//! * **Slot-indexed records** — metrics are sampled on the trace's time
//!   axis (every `record_every` slots) instead of at demand checkpoints,
//!   since an open trace has no "fraction of capacity requested" notion
//!   that is monotone in time.
//!
//! Semantics shared with the engine (so results are comparable): FIFO
//! within a slot, terminations release at the *start* of their slot
//! before that slot's arrivals, rejected workloads are dropped (never
//! retried), and scheduler hooks ([`Scheduler::on_commit`] /
//! [`Scheduler::on_release`]) fire on every transition — MFI-IDX replays
//! placement-for-placement identically to MFI.

use std::collections::BinaryHeap;

use crate::cluster::{Cluster, ClusterMetrics};
use crate::defrag::DefragPolicy;
use crate::frag::{FleetTables, ScoreTable};
use crate::mig::{FleetSpec, HardwareModel};
use crate::obs::hist::LatencyHist;
use crate::obs::telemetry::{slot_row, SlotStats};
use crate::sched::Scheduler;
use crate::util::json::Json;
use crate::workload::{Trace, WorkloadId};

/// Replay parameters.
#[derive(Clone, Debug)]
pub struct ReplayConfig {
    pub hardware: HardwareModel,
    /// Cluster size `M` to replay against.
    pub num_gpus: usize,
    /// Heterogeneous fleet. When set it defines the cluster (overriding
    /// `hardware`/`num_gpus`) and each GPU is scored against its own
    /// device class's table. `None` = a uniform fleet of `num_gpus` ×
    /// `hardware` — the pre-fleet behavior, bit-identical.
    pub fleet: Option<FleetSpec>,
    /// Sample a [`ReplaySample`] every this many slots along the trace's
    /// span (0 = auto: aim for ~20 samples).
    pub record_every: u64,
    /// Stop after this many arrivals (0 = the whole trace) — the CI smoke
    /// uses a bounded prefix of the bundled trace.
    pub max_events: u64,
    /// Continuous defragmentation policy applied during the replay
    /// (`None` = no migrations, the pre-existing behavior).
    pub defrag: Option<DefragPolicy>,
    /// Capture per-sample telemetry rows ([`ReplayResult::telemetry`], the
    /// `--telemetry PATH` JSONL). Off by default: rows carry wall-clock
    /// decision latency, so untimed replays stay clock-free.
    pub telemetry: bool,
}

impl ReplayConfig {
    pub fn new(num_gpus: usize) -> Self {
        Self {
            hardware: HardwareModel::a100_80gb(),
            num_gpus,
            fleet: None,
            record_every: 0,
            max_events: 0,
            defrag: None,
            telemetry: false,
        }
    }
}

/// Metrics sampled at one slot of the replay.
#[derive(Clone, Copy, Debug)]
pub struct ReplaySample {
    pub slot: u64,
    pub metrics: ClusterMetrics,
}

/// The outcome of one replay.
#[derive(Clone, Debug)]
pub struct ReplayResult {
    pub scheme: String,
    pub arrived: u64,
    pub accepted: u64,
    pub rejected: u64,
    /// Slot-indexed metric trajectory (frag, utilization, GPUs used …).
    pub samples: Vec<ReplaySample>,
    /// State after the last processed event.
    pub final_metrics: ClusterMetrics,
    /// Fragmentation score averaged over wall slots (gap slots carry the
    /// score left by the last event — a piecewise-constant integral).
    pub time_avg_frag: f64,
    /// Most GPUs simultaneously hosting at least one workload.
    pub peak_active_gpus: usize,
    /// First..=last slot touched by the replayed prefix.
    pub span_slots: u64,
    /// Migrations performed by the continuous defragmenter (0 unless
    /// [`ReplayConfig::defrag`] is set).
    pub migrations: u64,
    /// Instance memory copied by those migrations.
    pub migrated_bytes: u64,
    /// Sweeps that fired (cadence reached with fragmentation at or above
    /// the policy threshold), including sweeps that found no moves.
    pub defrag_sweeps: u64,
    /// Whether a defrag policy was configured — gates the migration keys
    /// in [`Self::to_json`] so defrag-disabled output stays byte-identical.
    pub defrag_enabled: bool,
    /// Slot-cadence telemetry rows (one per [`ReplaySample`]; empty unless
    /// [`ReplayConfig::telemetry`]) — see [`crate::obs::telemetry::slot_row`]
    /// for the schema. Deliberately NOT part of [`Self::to_json`], which is
    /// byte-stable; rows go to their own JSONL file.
    pub telemetry: Vec<Json>,
}

impl ReplayResult {
    pub fn acceptance_rate(&self) -> f64 {
        if self.arrived == 0 {
            1.0
        } else {
            self.accepted as f64 / self.arrived as f64
        }
    }

    /// Counter conservation: every arrival was either accepted or
    /// rejected, and migrations only happen when a policy asked for them.
    /// Drivers and CI smoke assert this.
    pub fn conserved(&self) -> bool {
        self.arrived == self.accepted + self.rejected
            && (self.defrag_enabled || self.migrations == 0)
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj()
            .with("scheme", self.scheme.as_str())
            .with("arrived", self.arrived)
            .with("accepted", self.accepted)
            .with("rejected", self.rejected)
            .with("acceptance_rate", self.acceptance_rate())
            .with("conserved", self.conserved())
            .with("time_avg_frag", self.time_avg_frag)
            .with("peak_active_gpus", self.peak_active_gpus)
            .with("span_slots", self.span_slots);
        if self.defrag_enabled {
            j.set("migrations", self.migrations);
            j.set("migrated_bytes", self.migrated_bytes);
            j.set("defrag_sweeps", self.defrag_sweeps);
        }
        j.with("final", self.final_metrics.to_json())
    }
}

/// Replay a trace through a scheduler (reset beforehand). Multiple
/// arrivals per slot, slot gaps and open-loop rejection semantics are all
/// honored; see the module docs for the contract.
pub fn run(trace: &Trace, scheduler: &mut dyn Scheduler, config: &ReplayConfig) -> ReplayResult {
    scheduler.reset();
    let arrivals = trace.arrivals();
    let limit = if config.max_events == 0 {
        arrivals.len()
    } else {
        arrivals.len().min(config.max_events as usize)
    };
    let arrivals = &arrivals[..limit];

    let mut cluster = match &config.fleet {
        Some(fleet) => Cluster::from_fleet(fleet),
        None => {
            assert!(config.num_gpus > 0, "need a non-empty cluster");
            Cluster::new(config.hardware.clone(), config.num_gpus)
        }
    };
    // `scorer` feeds the defrag planner (which derives per-class tables
    // from its rule on mixed fleets); all scoring below goes through
    // `tables`, whose uniform-fleet arithmetic is bit-identical.
    let scorer = ScoreTable::for_hardware(cluster.hardware());
    let tables = FleetTables::for_cluster(&cluster);

    let first_slot = arrivals.first().map(|w| w.arrival_slot).unwrap_or(0);
    let last_slot = arrivals.last().map(|w| w.arrival_slot).unwrap_or(0);
    let span = last_slot - first_slot + u64::from(!arrivals.is_empty());
    let record_every = if config.record_every > 0 {
        config.record_every
    } else {
        (span / 20).max(1)
    };

    let mut departures: BinaryHeap<std::cmp::Reverse<(u64, u64)>> = BinaryHeap::new();
    let mut accepted = 0u64;
    let mut rejected = 0u64;
    let mut arrived = 0u64;
    let mut samples = Vec::new();
    // Piecewise-constant fragmentation integral over [first_slot,
    // last_slot]: `frag_now` holds from `integrated_to` until the next
    // state change — a departure group or an arrival slot — so gap slots
    // carry the score the cluster actually had (departures inside a gap
    // break the integral, they are not smeared to the next arrival).
    let mut frag_weighted_sum = 0.0f64;
    let mut frag_now = 0.0f64;
    let mut integrated_to = first_slot;
    let mut peak_active = 0usize;
    let mut last_recorded: Option<u64> = None;
    let mut migrations = 0u64;
    let mut migrated_bytes = 0u64;
    let mut defrag_sweeps = 0u64;
    let mut last_defrag = first_slot;
    let mut telemetry: Vec<Json> = Vec::new();
    let decision_hist = LatencyHist::new();

    let mut i = 0usize;
    while i < arrivals.len() {
        let t = arrivals[i].arrival_slot;
        // 1. Terminations scheduled at or before this slot release first,
        // one slot group at a time, integrating up to each group.
        while let Some(&std::cmp::Reverse((dep_slot, _))) = departures.peek() {
            if dep_slot > t {
                break;
            }
            frag_weighted_sum += frag_now * dep_slot.saturating_sub(integrated_to) as f64;
            integrated_to = integrated_to.max(dep_slot);
            while let Some(&std::cmp::Reverse((slot, id))) = departures.peek() {
                if slot > dep_slot {
                    break;
                }
                departures.pop();
                let freed = cluster
                    .release(WorkloadId(id))
                    .expect("departure of allocated workload");
                scheduler.on_release(&cluster, freed);
            }
            frag_now = tables.mean_score(&cluster);
        }
        frag_weighted_sum += frag_now * (t - integrated_to) as f64;
        integrated_to = t;
        // 1b. Continuous defrag: once the cadence elapses and the cluster-
        // mean fragmentation is at or above the policy threshold, apply one
        // budgeted sweep before this slot's arrivals. Migration moves go
        // through allocate/release and thus the cluster's change log, so
        // incremental schedulers catch up on their next decision without
        // explicit hook calls here.
        if let Some(policy) = &config.defrag {
            if t >= last_defrag + policy.every && frag_now >= policy.threshold {
                let plan = crate::defrag::plan_defrag_budgeted(
                    &cluster,
                    &scorer,
                    policy.max_moves,
                    &policy.cost,
                    policy.cost_budget,
                );
                if !plan.is_empty() {
                    let live_before = cluster.allocated_workloads();
                    migrations += crate::defrag::apply_plan(&mut cluster, &plan)
                        .expect("fresh plan applies") as u64;
                    migrated_bytes += plan.bytes_moved;
                    debug_assert_eq!(
                        cluster.allocated_workloads(),
                        live_before,
                        "defrag must not create or drop allocations"
                    );
                    frag_now = tables.mean_score(&cluster);
                }
                last_defrag = t;
                defrag_sweeps += 1;
            }
        }
        // 2. Every arrival of this slot, FIFO, open-loop.
        while i < arrivals.len() && arrivals[i].arrival_slot == t {
            let w = &arrivals[i];
            arrived += 1;
            // Wall-clock timing only when telemetry asks for it, so plain
            // replays never touch the clock.
            let decided = if config.telemetry {
                let start = std::time::Instant::now();
                let p = scheduler.schedule(&cluster, w.profile);
                decision_hist.record(start.elapsed());
                p
            } else {
                scheduler.schedule(&cluster, w.profile)
            };
            if let Some(placement) = decided {
                cluster
                    .allocate(w.id, placement)
                    .expect("scheduler proposed valid placement");
                scheduler.on_commit(&cluster, placement);
                accepted += 1;
                departures.push(std::cmp::Reverse((t + w.duration_slots, w.id.0)));
            } else {
                // Counted independently of `arrived` so conserved() is a
                // real invariant, not an identity.
                rejected += 1;
            }
            i += 1;
        }
        frag_now = tables.mean_score(&cluster);
        peak_active = peak_active.max(cluster.active_gpus());
        // 3. Slot-cadence sampling.
        if last_recorded.map(|r| t - r >= record_every).unwrap_or(true) {
            let metrics = ClusterMetrics::capture_fleet(&cluster, &tables, accepted, arrived);
            samples.push(ReplaySample { slot: t, metrics });
            if config.telemetry {
                telemetry.push(slot_row(
                    &SlotStats {
                        slot: t,
                        arrived,
                        accepted,
                        allocated: metrics.allocated_workloads,
                        active_gpus: metrics.active_gpus,
                        utilization: metrics.utilization,
                        mean_frag_score: metrics.mean_frag_score,
                        migrations,
                        migrated_bytes,
                    },
                    &decision_hist.snapshot(),
                ));
            }
            last_recorded = Some(t);
        }
    }
    // Close the integral at the end of the span (the last slot counts).
    if !arrivals.is_empty() {
        frag_weighted_sum += frag_now * (last_slot + 1 - integrated_to) as f64;
    }

    let final_metrics = ClusterMetrics::capture_fleet(&cluster, &tables, accepted, arrived);
    // Always close the trajectory with the final state.
    if samples.last().map(|s| s.slot != last_slot).unwrap_or(false) {
        samples.push(ReplaySample { slot: last_slot, metrics: final_metrics });
        if config.telemetry {
            telemetry.push(slot_row(
                &SlotStats {
                    slot: last_slot,
                    arrived,
                    accepted,
                    allocated: final_metrics.allocated_workloads,
                    active_gpus: final_metrics.active_gpus,
                    utilization: final_metrics.utilization,
                    mean_frag_score: final_metrics.mean_frag_score,
                    migrations,
                    migrated_bytes,
                },
                &decision_hist.snapshot(),
            ));
        }
    }
    ReplayResult {
        scheme: scheduler.name().to_string(),
        arrived,
        accepted,
        rejected,
        samples,
        final_metrics,
        time_avg_frag: if span == 0 { 0.0 } else { frag_weighted_sum / span as f64 },
        peak_active_gpus: peak_active,
        span_slots: span,
        migrations,
        migrated_bytes,
        defrag_sweeps,
        defrag_enabled: config.defrag.is_some(),
        telemetry,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mig::Profile;
    use crate::sched::SchedulerKind;
    use crate::workload::spec::{TenantId, Workload};
    use crate::workload::WorkloadId as Wid;

    fn w(id: u64, profile: Profile, arrival: u64, dur: u64) -> Workload {
        Workload {
            id: Wid(id),
            tenant: TenantId(0),
            profile,
            arrival_slot: arrival,
            duration_slots: dur,
        }
    }

    fn trace_of(workloads: &[Workload]) -> Trace {
        Trace::from_workloads("replay unit", 64, workloads)
    }

    #[test]
    fn open_loop_continues_past_rejections() {
        // A 1-GPU cluster: the second 7g.80gb is rejected, later small
        // requests after the first departs are still served.
        let t = trace_of(&[
            w(0, Profile::P7g80gb, 0, 2),
            w(1, Profile::P7g80gb, 1, 2), // rejected (GPU full)
            w(2, Profile::P1g10gb, 2, 3), // slot 2: w0 departed → accepted
        ]);
        let mut s = SchedulerKind::Mfi.build(&HardwareModel::a100_80gb());
        let r = run(&t, &mut *s, &ReplayConfig::new(1));
        assert_eq!(r.arrived, 3);
        assert_eq!(r.accepted, 2);
        assert_eq!(r.rejected, 1);
        assert!(r.conserved());
        assert_eq!(r.final_metrics.allocated_workloads, 1);
    }

    #[test]
    fn bursts_share_a_slot_and_gaps_are_skipped() {
        // Three arrivals in slot 0, then a long gap, then one more.
        let t = trace_of(&[
            w(0, Profile::P2g20gb, 0, 5),
            w(1, Profile::P2g20gb, 0, 5),
            w(2, Profile::P2g20gb, 0, 5),
            w(3, Profile::P1g10gb, 1000, 1),
        ]);
        let mut s = SchedulerKind::Mfi.build(&HardwareModel::a100_80gb());
        let r = run(&t, &mut *s, &ReplayConfig::new(2));
        assert_eq!(r.arrived, 4);
        assert_eq!(r.accepted, 4);
        assert_eq!(r.span_slots, 1001);
        // By slot 1000 the burst departed: only w3 is left.
        assert_eq!(r.final_metrics.allocated_workloads, 1);
        assert!(r.peak_active_gpus >= 1);
    }

    #[test]
    fn frag_integral_breaks_at_departures_inside_gaps() {
        // One 1-slot workload at slot 0, next arrival at slot 101: the
        // cluster is empty for slots [1, 101), so the time-averaged
        // fragmentation must be ~2/102 of a single-allocation score (≥ 8,
        // the blocked full-GPU window alone), not smeared across the gap.
        let t = trace_of(&[
            w(0, Profile::P1g10gb, 0, 1),
            w(1, Profile::P1g10gb, 101, 1),
        ]);
        let mut s = SchedulerKind::Mfi.build(&HardwareModel::a100_80gb());
        let r = run(&t, &mut *s, &ReplayConfig::new(1));
        assert_eq!(r.accepted, 2);
        assert_eq!(r.span_slots, 102);
        assert!(
            r.time_avg_frag < 1.0,
            "gap slots must integrate the post-departure score, got {}",
            r.time_avg_frag
        );
        assert!(r.time_avg_frag > 0.0);
    }

    #[test]
    fn mfi_and_indexed_mfi_agree_on_open_loop_traces() {
        use crate::util::rng::Rng;
        use crate::workload::{Distribution, WorkloadGenerator};
        // A bursty open stream (not the saturation protocol).
        let gen = WorkloadGenerator::new(Distribution::Bimodal).with_tenants(7);
        let ws = gen.generate_stream(600, 0.35, 40, &mut Rng::new(42));
        let t = trace_of(&ws);
        let hw = HardwareModel::a100_80gb();
        let mut a = SchedulerKind::Mfi.build(&hw);
        let mut b = SchedulerKind::MfiIdx.build(&hw);
        let cfg = ReplayConfig::new(6);
        let ra = run(&t, &mut *a, &cfg);
        let rb = run(&t, &mut *b, &cfg);
        assert_eq!(ra.accepted, rb.accepted);
        assert_eq!(ra.rejected, rb.rejected);
        assert_eq!(ra.time_avg_frag, rb.time_avg_frag);
        assert_eq!(ra.samples.len(), rb.samples.len());
        for (sa, sb) in ra.samples.iter().zip(&rb.samples) {
            assert_eq!(sa.metrics, sb.metrics, "slot {}", sa.slot);
        }
    }

    #[test]
    fn mfi_exp_replays_deterministically_and_conserves() {
        use crate::util::rng::Rng;
        use crate::workload::{Distribution, WorkloadGenerator};
        // Open-loop stream through the distribution-aware scheduler: the
        // estimator updates on every on_commit, yet the replay must stay
        // exactly reproducible (fixed-point weights, no wall clock) and
        // keep counter conservation.
        let gen = WorkloadGenerator::new(Distribution::SkewSmall).with_tenants(7);
        let ws = gen.generate_stream(600, 0.35, 40, &mut Rng::new(44));
        let t = trace_of(&ws);
        let hw = HardwareModel::a100_80gb();
        let cfg = ReplayConfig::new(6);
        let mut a = SchedulerKind::MfiExp.build(&hw);
        let mut b = SchedulerKind::MfiExp.build(&hw);
        let ra = run(&t, &mut *a, &cfg);
        let rb = run(&t, &mut *b, &cfg);
        assert!(ra.conserved());
        assert!(ra.accepted > 0);
        assert_eq!(ra.accepted, rb.accepted);
        assert_eq!(ra.rejected, rb.rejected);
        assert_eq!(ra.time_avg_frag.to_bits(), rb.time_avg_frag.to_bits());
        for (sa, sb) in ra.samples.iter().zip(&rb.samples) {
            assert_eq!(sa.metrics, sb.metrics, "slot {}", sa.slot);
        }
        // `run` resets the scheduler first, so a reused instance replays
        // identically too (the estimator does not leak across runs).
        let rc = run(&t, &mut *a, &cfg);
        assert_eq!(ra.accepted, rc.accepted);
        assert_eq!(ra.time_avg_frag.to_bits(), rc.time_avg_frag.to_bits());
    }

    /// Two A100s under FF, built so that slot-3 departures strand w1+w3 on
    /// GPU 0 and w4 on GPU 1: neither GPU can host the 7g.80gb that
    /// arrives at slot 10 — unless defrag consolidates first. Verified
    /// against the python-oracle mirror of the greedy planner: the slot-10
    /// sweep makes a single move, w4 (2g.20gb) from GPU 1 into GPU 0's
    /// free window at index 0 (ΔF = −20), emptying GPU 1 for the 7g.
    fn fragmenting_trace() -> Trace {
        trace_of(&[
            w(0, Profile::P2g20gb, 0, 3),
            w(1, Profile::P2g20gb, 0, 100),
            w(2, Profile::P2g20gb, 0, 3),
            w(3, Profile::P1g20gb, 0, 100),
            w(4, Profile::P2g20gb, 0, 100),
            w(5, Profile::P2g20gb, 0, 3),
            w(6, Profile::P7g80gb, 10, 5),
        ])
    }

    fn run_ff(cfg: &ReplayConfig) -> ReplayResult {
        let mut s = SchedulerKind::Ff.build(&HardwareModel::a100_80gb());
        run(&fragmenting_trace(), &mut *s, cfg)
    }

    #[test]
    fn defrag_recovers_a_rejected_full_gpu_request() {
        use crate::defrag::{DefragPolicy, BYTES_PER_GB};
        let plain = run_ff(&ReplayConfig::new(2));
        assert_eq!(plain.accepted, 6, "7g must be rejected without defrag");
        assert_eq!(plain.migrations, 0);
        assert!(!plain.defrag_enabled);
        assert!(plain.conserved());

        let cfg = ReplayConfig {
            defrag: Some(DefragPolicy::every(5)),
            ..ReplayConfig::new(2)
        };
        let defragged = run_ff(&cfg);
        assert_eq!(defragged.accepted, 7, "defrag consolidates, 7g fits");
        assert_eq!(defragged.migrations, 1);
        // w4 (2g.20gb): 20 GB on A100-80GB.
        assert_eq!(defragged.migrated_bytes, 20 * BYTES_PER_GB);
        assert_eq!(defragged.defrag_sweeps, 1);
        assert!(defragged.defrag_enabled);
        assert!(defragged.conserved());
    }

    #[test]
    fn defrag_threshold_gates_the_sweep() {
        use crate::defrag::DefragPolicy;
        // Post-departure cluster mean score is (12 + 8) / 2 = 10: a
        // threshold just above it must suppress the sweep entirely.
        let cfg = ReplayConfig {
            defrag: Some(DefragPolicy::every(5).with_threshold(11.0)),
            ..ReplayConfig::new(2)
        };
        let r = run_ff(&cfg);
        assert_eq!(r.defrag_sweeps, 0);
        assert_eq!(r.migrations, 0);
        assert_eq!(r.accepted, 6);
        assert!(r.conserved());
    }

    #[test]
    fn defrag_cost_budget_limits_the_sweep() {
        use crate::defrag::{DefragPolicy, BYTES_PER_GB};
        // Every stranded allocation prices at 20 GB + 10 downtime = 30
        // units. Budget 20 makes all of them unaffordable: the sweep fires
        // but moves nothing, and the 7g stays rejected.
        let starved = run_ff(&ReplayConfig {
            defrag: Some(DefragPolicy::every(5).with_cost_budget(20)),
            ..ReplayConfig::new(2)
        });
        assert_eq!(starved.defrag_sweeps, 1);
        assert_eq!(starved.migrations, 0);
        assert_eq!(starved.migrated_bytes, 0);
        assert_eq!(starved.accepted, 6, "no affordable move, no recovery");
        assert!(starved.conserved());

        // Budget 30 affords exactly the one consolidating move the
        // unlimited planner makes, so the 7g is recovered at cost 30.
        let r = run_ff(&ReplayConfig {
            defrag: Some(DefragPolicy::every(5).with_cost_budget(30)),
            ..ReplayConfig::new(2)
        });
        assert_eq!(r.migrations, 1);
        assert_eq!(r.migrated_bytes, 20 * BYTES_PER_GB);
        assert_eq!(r.accepted, 7);
        assert!(r.conserved());
    }

    #[test]
    fn mfi_and_indexed_mfi_agree_under_interleaved_defrag() {
        use crate::defrag::DefragPolicy;
        use crate::util::rng::Rng;
        use crate::workload::{Distribution, WorkloadGenerator};
        // Migrations flow through the cluster change log; the generation-
        // checked catch-up contract must keep MFI-IDX placement-identical.
        let gen = WorkloadGenerator::new(Distribution::Bimodal).with_tenants(7);
        let ws = gen.generate_stream(600, 0.35, 40, &mut Rng::new(43));
        let t = trace_of(&ws);
        let hw = HardwareModel::a100_80gb();
        let mut a = SchedulerKind::Mfi.build(&hw);
        let mut b = SchedulerKind::MfiIdx.build(&hw);
        let cfg = ReplayConfig {
            defrag: Some(DefragPolicy::every(7).with_max_moves(4)),
            ..ReplayConfig::new(6)
        };
        let ra = run(&t, &mut *a, &cfg);
        let rb = run(&t, &mut *b, &cfg);
        assert_eq!(ra.accepted, rb.accepted);
        assert_eq!(ra.rejected, rb.rejected);
        assert_eq!(ra.migrations, rb.migrations);
        assert_eq!(ra.migrated_bytes, rb.migrated_bytes);
        assert_eq!(ra.time_avg_frag, rb.time_avg_frag);
        for (sa, sb) in ra.samples.iter().zip(&rb.samples) {
            assert_eq!(sa.metrics, sb.metrics, "slot {}", sa.slot);
        }
    }

    #[test]
    fn defrag_json_keys_are_gated_on_the_policy() {
        use crate::defrag::DefragPolicy;
        let plain = run_ff(&ReplayConfig::new(2)).to_json();
        assert!(plain.get("migrations").is_none(), "disabled output unchanged");
        assert!(plain.get("migrated_bytes").is_none());

        let cfg = ReplayConfig {
            defrag: Some(DefragPolicy::every(5)),
            ..ReplayConfig::new(2)
        };
        let j = run_ff(&cfg).to_json();
        assert_eq!(j.req_u64("migrations").unwrap(), 1);
        assert!(j.req_u64("migrated_bytes").unwrap() > 0);
        assert_eq!(j.req_u64("defrag_sweeps").unwrap(), 1);
    }

    #[test]
    fn max_events_bounds_the_prefix() {
        let ws: Vec<Workload> =
            (0..50).map(|i| w(i, Profile::P1g10gb, i, 3)).collect();
        let t = trace_of(&ws);
        let mut s = SchedulerKind::Ff.build(&HardwareModel::a100_80gb());
        let cfg = ReplayConfig { max_events: 10, ..ReplayConfig::new(4) };
        let r = run(&t, &mut *s, &cfg);
        assert_eq!(r.arrived, 10);
        assert!(r.conserved());
    }

    #[test]
    fn empty_trace_replays_to_nothing() {
        let t = Trace::new("empty", 8);
        let mut s = SchedulerKind::Mfi.build(&HardwareModel::a100_80gb());
        let r = run(&t, &mut *s, &ReplayConfig::new(1));
        assert_eq!(r.arrived, 0);
        assert_eq!(r.span_slots, 0);
        assert_eq!(r.time_avg_frag, 0.0);
        assert!(r.conserved());
        assert!(r.samples.is_empty());
        assert_eq!(r.acceptance_rate(), 1.0);
    }

    #[test]
    fn samples_follow_the_requested_cadence() {
        let ws: Vec<Workload> =
            (0..100).map(|i| w(i, Profile::P1g10gb, i * 10, 5)).collect();
        let t = trace_of(&ws);
        let mut s = SchedulerKind::Mfi.build(&HardwareModel::a100_80gb());
        let cfg = ReplayConfig { record_every: 100, ..ReplayConfig::new(20) };
        let r = run(&t, &mut *s, &cfg);
        // Slots 0, 100, 200, … 990: one sample each per 100-slot stride.
        assert!(r.samples.len() >= 10, "{}", r.samples.len());
        for pair in r.samples.windows(2) {
            assert!(pair[1].slot > pair[0].slot);
        }
        // Cumulative counters are monotone along the trajectory.
        for pair in r.samples.windows(2) {
            assert!(pair[1].metrics.arrived_total >= pair[0].metrics.arrived_total);
            assert!(pair[1].metrics.accepted_total >= pair[0].metrics.accepted_total);
        }
        assert_eq!(r.samples.last().unwrap().slot, 990);
    }

    #[test]
    fn telemetry_rows_mirror_samples_and_default_off() {
        let ws: Vec<Workload> =
            (0..40).map(|i| w(i, Profile::P1g10gb, i * 5, 8)).collect();
        let t = trace_of(&ws);
        let hw = HardwareModel::a100_80gb();
        let mut a = SchedulerKind::Mfi.build(&hw);
        let plain = run(&t, &mut *a, &ReplayConfig::new(8));
        assert!(plain.telemetry.is_empty(), "telemetry is opt-in");

        let mut b = SchedulerKind::Mfi.build(&hw);
        let cfg = ReplayConfig { telemetry: true, ..ReplayConfig::new(8) };
        let traced = run(&t, &mut *b, &cfg);
        // Timing must not perturb the replay itself.
        assert_eq!(traced.accepted, plain.accepted);
        assert_eq!(traced.time_avg_frag, plain.time_avg_frag);
        assert_eq!(traced.samples.len(), plain.samples.len());
        // One row per sample, slots aligned, final row carries the totals.
        assert_eq!(traced.telemetry.len(), traced.samples.len());
        for (row, sample) in traced.telemetry.iter().zip(&traced.samples) {
            assert_eq!(row.get("slot").and_then(Json::as_u64), Some(sample.slot));
        }
        let last = traced.telemetry.last().unwrap();
        assert_eq!(last.get("arrived").and_then(Json::as_u64), Some(traced.arrived));
        assert_eq!(last.get("accepted").and_then(Json::as_u64), Some(traced.accepted));
        // Every arrival was timed exactly once.
        assert_eq!(last.get("decisions").and_then(Json::as_u64), Some(traced.arrived));
    }

    /// Byte-stability pin: the observability layer must not change the
    /// serialized defrag-off replay summary at all — same keys, same
    /// order, and telemetry capture must leave the bytes identical.
    #[test]
    fn defrag_off_json_bytes_are_pinned() {
        let plain = run_ff(&ReplayConfig::new(2)).to_json();
        let keys: Vec<&str> = match &plain {
            Json::Obj(pairs) => pairs.iter().map(|(k, _)| k.as_str()).collect(),
            other => panic!("summary must be an object, got {other:?}"),
        };
        assert_eq!(
            keys,
            [
                "scheme",
                "arrived",
                "accepted",
                "rejected",
                "acceptance_rate",
                "conserved",
                "time_avg_frag",
                "peak_active_gpus",
                "span_slots",
                "final",
            ],
            "defrag-off summary keys changed — downstream parsers pin these"
        );
        let traced = run_ff(&ReplayConfig { telemetry: true, ..ReplayConfig::new(2) });
        assert_eq!(
            plain.to_string_compact(),
            traced.to_json().to_string_compact(),
            "telemetry capture must not leak into the summary bytes"
        );
    }

    #[test]
    fn uniform_fleet_replay_json_bytes_match_legacy() {
        // Single-class fleet path must leave the replay summary
        // byte-identical to the pre-fleet uniform constructor.
        let legacy = run_ff(&ReplayConfig::new(2)).to_json().to_string_compact();
        let cfg = ReplayConfig {
            fleet: Some(crate::mig::FleetSpec::parse("a100:2").unwrap()),
            ..ReplayConfig::new(2)
        };
        let fleet = run_ff(&cfg).to_json().to_string_compact();
        assert_eq!(legacy, fleet, "uniform fleet must not perturb replay bytes");
    }

    #[test]
    fn mixed_fleet_replay_conserves_and_indexed_mfi_agrees() {
        use crate::util::rng::Rng;
        use crate::workload::{Distribution, WorkloadGenerator};
        let gen = WorkloadGenerator::new(Distribution::Uniform).with_tenants(5);
        let ws = gen.generate_stream(500, 0.4, 30, &mut Rng::new(77));
        let t = trace_of(&ws);
        let hw = HardwareModel::a100_80gb();
        let cfg = ReplayConfig {
            fleet: Some(crate::mig::FleetSpec::parse("a100:3,h100:2,a100-40gb:2").unwrap()),
            ..ReplayConfig::new(7)
        };
        let mut a = SchedulerKind::Mfi.build(&hw);
        let mut b = SchedulerKind::MfiIdx.build(&hw);
        let ra = run(&t, &mut *a, &cfg);
        let rb = run(&t, &mut *b, &cfg);
        assert!(ra.conserved());
        assert!(ra.accepted > 0);
        assert_eq!(ra.accepted, rb.accepted);
        assert_eq!(ra.rejected, rb.rejected);
        assert_eq!(ra.time_avg_frag.to_bits(), rb.time_avg_frag.to_bits());
        for (sa, sb) in ra.samples.iter().zip(&rb.samples) {
            assert_eq!(sa.metrics, sb.metrics, "slot {}", sa.slot);
        }
    }

    #[test]
    fn json_summary_has_the_headline_fields() {
        let t = trace_of(&[w(0, Profile::P3g40gb, 0, 2)]);
        let mut s = SchedulerKind::Mfi.build(&HardwareModel::a100_80gb());
        let r = run(&t, &mut *s, &ReplayConfig::new(2));
        let j = r.to_json();
        assert_eq!(j.req_u64("arrived").unwrap(), 1);
        assert_eq!(j.req_u64("accepted").unwrap(), 1);
        assert_eq!(j.get("conserved").unwrap().as_bool(), Some(true));
        assert!(j.get("final").unwrap().req_u64("allocated_workloads").is_ok());
    }
}
