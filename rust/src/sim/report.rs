//! Figure regeneration: renders sweep results as the same rows/series the
//! paper's figures plot, plus raw-value CSV exports under `results/`.
//!
//! * **Fig. 4** (a–d): allocated workloads, acceptance rate, resource
//!   utilization, active GPUs as functions of GPU demand (10%…100%) under
//!   the uniform distribution, all five schemes.
//! * **Fig. 5** (a–d): the same four metrics at 85% demand across the four
//!   Table II distributions.
//! * **Fig. 6**: average fragmentation score per scheme per distribution.
//!
//! The paper normalizes each metric by its maximum over the compared
//! schemes; reports include both normalized (paper-comparable) and raw
//! values.

use super::experiment::{SweepResult, SweepSeries};
use crate::sched::SchedulerKind;
use crate::util::csv::Csv;
use crate::util::stats::normalize_by_max;
use crate::util::table::Table;
use crate::workload::Distribution;

/// One rendered figure: titled tables (one per sub-figure) + CSVs.
#[derive(Debug, Default)]
pub struct FigureReport {
    pub title: String,
    pub tables: Vec<Table>,
    pub csvs: Vec<(String, Csv)>,
}

impl FigureReport {
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("==== {} ====\n\n", self.title));
        for t in &self.tables {
            out.push_str(&t.render());
            out.push('\n');
        }
        out
    }

    /// Write the CSVs under `results/<stem>.csv`.
    pub fn save_csvs(&self, dir: &std::path::Path) -> std::io::Result<()> {
        for (stem, csv) in &self.csvs {
            csv.save(&dir.join(format!("{stem}.csv")))?;
        }
        Ok(())
    }
}

/// The four Fig. 4/5 metrics.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Metric {
    AcceptedWorkloads,
    AcceptanceRate,
    Utilization,
    ActiveGpus,
}

impl Metric {
    pub const ALL: [Metric; 4] = [
        Metric::AcceptedWorkloads,
        Metric::AcceptanceRate,
        Metric::Utilization,
        Metric::ActiveGpus,
    ];

    pub fn label(self) -> &'static str {
        match self {
            Metric::AcceptedWorkloads => "allocated workloads",
            Metric::AcceptanceRate => "acceptance rate",
            Metric::Utilization => "resource utilization",
            Metric::ActiveGpus => "active GPUs",
        }
    }

    pub fn subfig(self) -> char {
        match self {
            Metric::AcceptedWorkloads => 'a',
            Metric::AcceptanceRate => 'b',
            Metric::Utilization => 'c',
            Metric::ActiveGpus => 'd',
        }
    }

    fn value(self, series: &SweepSeries, checkpoint: usize) -> f64 {
        let cell = &series.checkpoints[checkpoint];
        match self {
            Metric::AcceptedWorkloads => cell.accepted_workloads.mean(),
            Metric::AcceptanceRate => cell.acceptance_rate.mean(),
            Metric::Utilization => cell.utilization.mean(),
            Metric::ActiveGpus => cell.active_gpus.mean(),
        }
    }
}

/// Fig. 4: metric vs demand under one distribution (paper: uniform).
pub fn fig4_report(sweep: &SweepResult, distribution: &Distribution) -> FigureReport {
    let mut report = FigureReport {
        title: format!(
            "Fig. 4 — scheduling performance vs GPU demand ({} distribution; {})",
            distribution.name(),
            sweep.config_summary
        ),
        ..Default::default()
    };
    let schemes = schemes_in(sweep);
    for metric in Metric::ALL {
        let mut header = vec!["scheme".to_string()];
        header.extend(sweep.demands.iter().map(|d| format!("{:.0}%", d * 100.0)));
        let mut table = Table::new(&header)
            .title(&format!("Fig. 4{} — {} (mean over runs)", metric.subfig(), metric.label()));
        let mut csv_header = vec!["scheme".to_string()];
        csv_header.extend(sweep.demands.iter().map(|d| format!("demand_{d}")));
        let mut csv = Csv::new(&csv_header);
        let mut csv_norm = Csv::new(&csv_header);

        // Collect raw matrix for normalization across schemes+demands.
        let mut rows: Vec<(SchedulerKind, Vec<f64>)> = Vec::new();
        for &scheme in &schemes {
            if let Some(series) = sweep.series_for(scheme, distribution) {
                let vals: Vec<f64> =
                    (0..sweep.demands.len()).map(|c| metric.value(series, c)).collect();
                rows.push((scheme, vals));
            }
        }
        let flat: Vec<f64> = rows.iter().flat_map(|(_, v)| v.iter().copied()).collect();
        let max = flat.iter().cloned().fold(0.0f64, f64::max).max(f64::MIN_POSITIVE);
        for (scheme, vals) in &rows {
            table.row_keyed(scheme.name(), vals, 3);
            csv.row_keyed(scheme.name(), vals);
            let normed: Vec<f64> = vals.iter().map(|v| v / max).collect();
            csv_norm.row_keyed(scheme.name(), &normed);
        }
        report.tables.push(table);
        report.csvs.push((
            format!("fig4{}_{}_{}", metric.subfig(), metric.label().replace(' ', "_"),
                    distribution.name()),
            csv,
        ));
        report.csvs.push((
            format!("fig4{}_{}_{}_normalized", metric.subfig(),
                    metric.label().replace(' ', "_"), distribution.name()),
            csv_norm,
        ));
    }
    report
}

/// Fig. 5: the four metrics at one demand point (paper: 85%) across
/// distributions.
pub fn fig5_report(sweep: &SweepResult, demand: f64) -> FigureReport {
    let idx = sweep.checkpoint_index(demand);
    let mut report = FigureReport {
        title: format!(
            "Fig. 5 — scheduling performance at {:.0}% GPU demand across distributions ({})",
            sweep.demands[idx] * 100.0,
            sweep.config_summary
        ),
        ..Default::default()
    };
    let schemes = schemes_in(sweep);
    let dists = distributions_in(sweep);
    for metric in Metric::ALL {
        let mut header = vec!["scheme".to_string()];
        header.extend(dists.iter().map(|d| d.name().to_string()));
        let mut table = Table::new(&header).title(&format!(
            "Fig. 5{} — {} @ {:.0}% demand (mean over runs; normalized in parentheses)",
            metric.subfig(),
            metric.label(),
            sweep.demands[idx] * 100.0
        ));
        let mut csv = Csv::new(&header);

        let mut rows: Vec<(SchedulerKind, Vec<f64>)> = Vec::new();
        for &scheme in &schemes {
            let vals: Vec<f64> = dists
                .iter()
                .map(|d| {
                    sweep
                        .series_for(scheme, d)
                        .map(|s| metric.value(s, idx))
                        .unwrap_or(f64::NAN)
                })
                .collect();
            rows.push((scheme, vals));
        }
        // Normalize per distribution column (max across schemes), as the
        // paper's bar groups do.
        let ncols = dists.len();
        let mut col_max = vec![f64::MIN_POSITIVE; ncols];
        for (_, vals) in &rows {
            for (j, v) in vals.iter().enumerate() {
                if v.is_finite() {
                    col_max[j] = col_max[j].max(*v);
                }
            }
        }
        for (scheme, vals) in &rows {
            let cells: Vec<String> = vals
                .iter()
                .enumerate()
                .map(|(j, v)| format!("{:.3} ({:.2})", v, v / col_max[j]))
                .collect();
            let mut row = vec![scheme.name().to_string()];
            row.extend(cells);
            table.row(&row);
            csv.row_keyed(scheme.name(), vals);
        }
        report.tables.push(table);
        report.csvs.push((
            format!("fig5{}_{}", metric.subfig(), metric.label().replace(' ', "_")),
            csv,
        ));
    }
    report
}

/// Fig. 6: time-averaged fragmentation score per scheme per distribution.
pub fn fig6_report(sweep: &SweepResult) -> FigureReport {
    let mut report = FigureReport {
        title: format!(
            "Fig. 6 — average fragmentation score by scheme and distribution ({})",
            sweep.config_summary
        ),
        ..Default::default()
    };
    let schemes = schemes_in(sweep);
    let dists = distributions_in(sweep);
    let mut header = vec!["scheme".to_string()];
    header.extend(dists.iter().map(|d| d.name().to_string()));
    let mut table = Table::new(&header)
        .title("mean over runs of the per-run time-averaged cluster fragmentation score");
    let mut csv = Csv::new(&header);
    for &scheme in &schemes {
        let vals: Vec<f64> = dists
            .iter()
            .map(|d| {
                sweep
                    .series_for(scheme, d)
                    .map(|s| s.time_avg_frag.mean())
                    .unwrap_or(f64::NAN)
            })
            .collect();
        table.row_keyed(scheme.name(), &vals, 3);
        csv.row_keyed(scheme.name(), &vals);
    }
    report.tables.push(table);
    report.csvs.push(("fig6_fragmentation_score".to_string(), csv));

    // Normalized companion (paper normalizes by max).
    let mut norm_table = Table::new(&header).title("normalized by column max");
    for &scheme in &schemes {
        let vals: Vec<f64> = dists
            .iter()
            .map(|d| sweep.series_for(scheme, d).map(|s| s.time_avg_frag.mean()).unwrap_or(0.0))
            .collect();
        norm_table.row_keyed(scheme.name(), &normalize_by_max(&vals), 3);
    }
    report.tables.push(norm_table);
    report
}

fn schemes_in(sweep: &SweepResult) -> Vec<SchedulerKind> {
    let mut out = Vec::new();
    for s in &sweep.series {
        if !out.contains(&s.scheme) {
            out.push(s.scheme);
        }
    }
    out
}

fn distributions_in(sweep: &SweepResult) -> Vec<Distribution> {
    let mut out: Vec<Distribution> = Vec::new();
    for s in &sweep.series {
        if !out.contains(&s.distribution) {
            out.push(s.distribution.clone());
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::experiment::{run_sweep, ExperimentConfig};

    fn tiny_sweep() -> SweepResult {
        run_sweep(&ExperimentConfig {
            num_gpus: 8,
            runs: 4,
            schemes: vec![SchedulerKind::Mfi, SchedulerKind::Ff, SchedulerKind::Rr],
            distributions: vec![Distribution::Uniform, Distribution::Bimodal],
            checkpoints: vec![0.5, 0.85, 1.0],
            threads: 2,
            ..ExperimentConfig::paper()
        })
    }

    #[test]
    fn fig4_has_four_subfigures() {
        let sweep = tiny_sweep();
        let r = fig4_report(&sweep, &Distribution::Uniform);
        assert_eq!(r.tables.len(), 4);
        assert_eq!(r.csvs.len(), 8); // raw + normalized per metric
        let text = r.render();
        assert!(text.contains("Fig. 4a"));
        assert!(text.contains("MFI"));
        assert!(text.contains("50%"));
    }

    #[test]
    fn fig5_selects_85_percent() {
        let sweep = tiny_sweep();
        let r = fig5_report(&sweep, 0.85);
        assert!(r.title.contains("85%"));
        assert_eq!(r.tables.len(), 4);
        let text = r.render();
        assert!(text.contains("uniform"));
        assert!(text.contains("bimodal"));
    }

    #[test]
    fn fig6_rows_per_scheme() {
        let sweep = tiny_sweep();
        let r = fig6_report(&sweep);
        assert_eq!(r.tables.len(), 2); // raw + normalized
        assert_eq!(r.tables[0].n_rows(), 3);
        assert_eq!(r.csvs.len(), 1);
    }

    #[test]
    fn csvs_save(){
        let sweep = tiny_sweep();
        let dir = std::env::temp_dir().join(format!("migsched-report-{}", std::process::id()));
        fig6_report(&sweep).save_csvs(&dir).unwrap();
        assert!(dir.join("fig6_fragmentation_score.csv").exists());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
