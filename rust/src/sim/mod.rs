//! Monte Carlo evaluation harness (paper Section VI).
//!
//! * [`engine`] — one slot-based simulation run: arrivals, FIFO scheduling,
//!   terminations, metric capture at demand checkpoints.
//! * [`experiment`] — seed sweeps: N independent runs per (scheme,
//!   distribution) aggregated with mean/CI statistics, parallelized over
//!   OS threads.
//! * [`report`] — regenerates the paper's figures as tables + CSV:
//!   Fig. 4 (metrics vs demand, uniform), Fig. 5 (metrics @85% across
//!   distributions), Fig. 6 (average fragmentation score).
//! * [`replay`] — the open-loop trace-replay driver: runs any scheduler
//!   over an ingested real-cluster trace (bursts, gaps, arrivals that
//!   continue past rejections) and emits the same report metrics.

pub mod engine;
pub mod experiment;
pub mod replay;
pub mod report;

pub use engine::{CheckpointRecord, SimConfig, SimEngine, SimResult};
pub use experiment::{AggregatedCell, ExperimentConfig, SweepResult};
pub use replay::{ReplayConfig, ReplayResult, ReplaySample};
pub use report::{fig4_report, fig5_report, fig6_report, FigureReport};

pub use crate::workload::Distribution;
