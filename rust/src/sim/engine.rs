//! One slot-based Monte Carlo simulation run (paper Section VI protocol).
//!
//! An empty cluster of `M` GPUs is progressively loaded: one workload
//! arrives per scheduling slot with a profile drawn from the configured
//! Table II distribution, until the cumulative request volume reaches
//! cluster capacity (that count is the horizon `T`). Lifespans are
//! `U[1, T]` slots; terminations release slices at the start of their
//! slot. Rejected workloads are dropped, never retried. Metrics are
//! captured when cumulative demand crosses each configured checkpoint
//! (10%…100% for Fig. 4; 85% is Fig. 5's operating point).

use std::collections::BinaryHeap;

use crate::cluster::{Cluster, ClusterMetrics};
use crate::defrag::DefragPolicy;
use crate::frag::{FleetTables, ScoreTable};
use crate::mig::{FleetSpec, HardwareModel};
use crate::obs::hist::LatencyHist;
use crate::obs::telemetry::{slot_row, SlotStats};
use crate::sched::Scheduler;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::workload::{Distribution, Trace, Workload, WorkloadGenerator};

/// Configuration of a single simulation run.
#[derive(Clone, Debug)]
pub struct SimConfig {
    pub hardware: HardwareModel,
    /// Cluster size `M` (paper: 100).
    pub num_gpus: usize,
    /// Heterogeneous fleet. When set it defines the cluster (overriding
    /// `hardware`/`num_gpus`) and every GPU is scored against its own
    /// device class's table. `None` = a uniform fleet of `num_gpus` ×
    /// `hardware` — the pre-fleet behavior, bit-identical.
    pub fleet: Option<FleetSpec>,
    pub distribution: Distribution,
    /// Demand fractions at which metrics are captured, ascending in (0, 1].
    pub checkpoints: Vec<f64>,
    pub seed: u64,
    /// Continuous rescheduling (the paper's future-work extension,
    /// [`crate::defrag`]): on the policy's cadence, apply a budgeted
    /// migration plan. `None` = paper behavior (no migration).
    pub defrag: Option<DefragPolicy>,
    /// Capture per-checkpoint telemetry rows ([`SimResult::telemetry`],
    /// the `--telemetry PATH` JSONL). Off by default: rows carry wall-clock
    /// decision latency, so untimed runs stay clock-free and deterministic.
    pub telemetry: bool,
}

impl SimConfig {
    /// The paper's setup: 100 A100-80GB GPUs, checkpoints at 10%…100%.
    pub fn paper(distribution: Distribution, seed: u64) -> Self {
        Self {
            hardware: HardwareModel::a100_80gb(),
            num_gpus: 100,
            fleet: None,
            distribution,
            checkpoints: (1..=10).map(|i| i as f64 / 10.0).collect(),
            seed,
            defrag: None,
            telemetry: false,
        }
    }

    /// A scaled-down variant for tests and quick CLI runs.
    pub fn small(distribution: Distribution, seed: u64) -> Self {
        Self { num_gpus: 10, ..Self::paper(distribution, seed) }
    }

    /// Simulate a heterogeneous fleet (builder style): the cluster is
    /// built from the fleet's class layout; `hardware`/`num_gpus` are
    /// kept in sync with class 0 / the fleet total for capacity math and
    /// scheduler construction.
    pub fn with_fleet(mut self, fleet: FleetSpec) -> Self {
        self.num_gpus = fleet.total_gpus();
        self.hardware = fleet.classes()[0].0.clone();
        self.fleet = Some(fleet);
        self
    }

    /// Enable periodic defragmentation (builder style): every `interval`
    /// slots, a sweep of at most `budget` moves, unconditionally (no
    /// threshold) and with unlimited cost. Set [`Self::defrag`] directly
    /// for threshold- or cost-gated policies.
    pub fn with_defrag(mut self, interval: u64, budget: usize) -> Self {
        assert!(interval > 0 && budget > 0);
        self.defrag = Some(DefragPolicy::every(interval).with_max_moves(budget));
        self
    }
}

/// Metrics captured at one demand checkpoint.
#[derive(Clone, Copy, Debug)]
pub struct CheckpointRecord {
    /// Demand fraction this checkpoint corresponds to (e.g. 0.85).
    pub demand: f64,
    /// Slot at which the checkpoint fired.
    pub slot: u64,
    pub metrics: ClusterMetrics,
}

/// The outcome of one simulation run.
#[derive(Clone, Debug)]
pub struct SimResult {
    pub scheme: String,
    pub distribution: String,
    pub seed: u64,
    /// Horizon `T` (number of slots == arrivals).
    pub horizon: u64,
    pub records: Vec<CheckpointRecord>,
    /// State at the end of the run (demand = 100%).
    pub final_metrics: ClusterMetrics,
    /// Time-averaged cluster-mean fragmentation score over all slots —
    /// the Fig. 6 quantity.
    pub time_avg_frag: f64,
    /// Total accepted / arrived over the whole run.
    pub accepted: u64,
    pub arrived: u64,
    /// Migrations performed by the periodic defragmenter (0 unless
    /// `SimConfig::defrag` is set).
    pub migrations: u64,
    /// Instance memory copied by those migrations.
    pub migrated_bytes: u64,
    /// Slot-cadence telemetry rows (one per checkpoint; empty unless
    /// [`SimConfig::telemetry`]) — see [`crate::obs::telemetry::slot_row`]
    /// for the schema.
    pub telemetry: Vec<Json>,
}

impl SimResult {
    pub fn acceptance_rate(&self) -> f64 {
        if self.arrived == 0 { 1.0 } else { self.accepted as f64 / self.arrived as f64 }
    }

    /// The record at (or nearest below) a demand fraction.
    pub fn at_demand(&self, demand: f64) -> Option<&CheckpointRecord> {
        self.records
            .iter()
            .filter(|r| r.demand <= demand + 1e-9)
            .max_by(|a, b| a.demand.partial_cmp(&b.demand).unwrap())
    }
}

/// The simulation engine.
pub struct SimEngine {
    config: SimConfig,
}

impl SimEngine {
    pub fn new(config: SimConfig) -> Self {
        assert!(!config.checkpoints.is_empty(), "need at least one checkpoint");
        assert!(
            config.checkpoints.windows(2).all(|w| w[0] < w[1]),
            "checkpoints must be strictly ascending"
        );
        assert!(
            config.checkpoints.iter().all(|&c| c > 0.0 && c <= 1.0),
            "checkpoints must lie in (0, 1]"
        );
        Self { config }
    }

    pub fn config(&self) -> &SimConfig {
        &self.config
    }

    /// GPUs in the simulated cluster (the fleet total when one is set).
    fn total_gpus(&self) -> usize {
        self.config.fleet.as_ref().map(|f| f.total_gpus()).unwrap_or(self.config.num_gpus)
    }

    /// Run one simulation with the given scheduler (reset beforehand).
    pub fn run(&self, scheduler: &mut dyn Scheduler) -> SimResult {
        let mut rng = Rng::new(self.config.seed);
        let gen = WorkloadGenerator::new(self.config.distribution.clone());
        let capacity =
            (self.total_gpus() * self.config.hardware.num_slices()) as u64;
        let generated = gen.generate(capacity, &mut rng);
        self.replay(scheduler, &generated.workloads)
    }

    /// Run one simulation over an explicit arrival sequence (trace replay).
    /// Workloads must be in arrival-slot order, one per slot.
    pub fn replay(&self, scheduler: &mut dyn Scheduler, workloads: &[Workload]) -> SimResult {
        scheduler.reset();
        let capacity =
            (self.total_gpus() * self.config.hardware.num_slices()) as u64;
        let mut cluster = match &self.config.fleet {
            Some(fleet) => Cluster::from_fleet(fleet),
            None => Cluster::new(self.config.hardware.clone(), self.config.num_gpus),
        };
        // `scorer` feeds the defrag planner (which derives per-class tables
        // from its rule on mixed fleets); all scoring below goes through
        // `tables`, whose uniform-fleet arithmetic is bit-identical.
        let scorer = ScoreTable::for_hardware(cluster.hardware());
        let tables = FleetTables::for_cluster(&cluster);

        // Departure queue: min-heap on (slot, workload id).
        let mut departures: BinaryHeap<std::cmp::Reverse<(u64, u64)>> = BinaryHeap::new();

        // Precompute checkpoint slots from cumulative demand.
        let mut cum = 0u64;
        let mut checkpoint_slots: Vec<(u64, f64)> = Vec::new();
        {
            let mut targets = self.config.checkpoints.iter().copied().peekable();
            for w in workloads {
                cum += w.slices() as u64;
                while let Some(&frac) = targets.peek() {
                    if cum as f64 >= capacity as f64 * frac {
                        checkpoint_slots.push((w.arrival_slot, frac));
                        targets.next();
                    } else {
                        break;
                    }
                }
            }
            // Any remaining targets (demand never reached them) fire at the
            // final slot so sweeps stay rectangular.
            let last_slot = workloads.last().map(|w| w.arrival_slot).unwrap_or(0);
            for frac in targets {
                checkpoint_slots.push((last_slot, frac));
            }
        }

        let mut accepted = 0u64;
        let mut arrived = 0u64;
        let mut records = Vec::with_capacity(checkpoint_slots.len());
        let mut frag_sum = 0.0f64;
        let mut next_checkpoint = 0usize;
        let mut migrations = 0u64;
        let mut migrated_bytes = 0u64;
        let mut telemetry: Vec<Json> = Vec::new();
        let decision_hist = LatencyHist::new();

        for w in workloads {
            let t = w.arrival_slot;
            // 1. terminations scheduled at or before this slot.
            while let Some(&std::cmp::Reverse((slot, id))) = departures.peek() {
                if slot > t {
                    break;
                }
                departures.pop();
                let freed = cluster
                    .release(crate::workload::WorkloadId(id))
                    .expect("departure of allocated workload");
                scheduler.on_release(&cluster, freed);
            }
            // 1b. continuous rescheduling (future-work extension). Migration
            // moves go through allocate/release and thus the cluster's
            // change log, so incremental schedulers catch up on their next
            // decision without explicit hook calls here.
            if let Some(policy) = &self.config.defrag {
                if t > 0
                    && t % policy.every == 0
                    && tables.mean_score(&cluster) >= policy.threshold
                {
                    let plan = crate::defrag::plan_defrag_budgeted(
                        &cluster,
                        &scorer,
                        policy.max_moves,
                        &policy.cost,
                        policy.cost_budget,
                    );
                    if !plan.is_empty() {
                        migrations +=
                            crate::defrag::apply_plan(&mut cluster, &plan)
                                .expect("fresh plan applies") as u64;
                        migrated_bytes += plan.bytes_moved;
                    }
                }
            }
            // 2. FIFO arrival → schedule → commit or reject. Decision
            // timing only under telemetry, so plain runs never touch the
            // wall clock.
            arrived += 1;
            let decided = if self.config.telemetry {
                let start = std::time::Instant::now();
                let p = scheduler.schedule(&cluster, w.profile);
                decision_hist.record(start.elapsed());
                p
            } else {
                scheduler.schedule(&cluster, w.profile)
            };
            if let Some(placement) = decided {
                cluster.allocate(w.id, placement).expect("scheduler proposed valid placement");
                scheduler.on_commit(&cluster, placement);
                accepted += 1;
                departures.push(std::cmp::Reverse((t + w.duration_slots, w.id.0)));
            }
            // 3. per-slot fragmentation sample (Fig. 6 time average).
            frag_sum += tables.mean_score(&cluster);
            // 4. checkpoint capture.
            while next_checkpoint < checkpoint_slots.len()
                && checkpoint_slots[next_checkpoint].0 == t
            {
                let (slot, frac) = checkpoint_slots[next_checkpoint];
                let metrics =
                    ClusterMetrics::capture_fleet(&cluster, &tables, accepted, arrived);
                records.push(CheckpointRecord { demand: frac, slot, metrics });
                if self.config.telemetry {
                    telemetry.push(slot_row(
                        &SlotStats {
                            slot,
                            arrived,
                            accepted,
                            allocated: metrics.allocated_workloads,
                            active_gpus: metrics.active_gpus,
                            utilization: metrics.utilization,
                            mean_frag_score: metrics.mean_frag_score,
                            migrations,
                            migrated_bytes,
                        },
                        &decision_hist.snapshot(),
                    ));
                }
                next_checkpoint += 1;
            }
        }

        let horizon = workloads.len() as u64;
        SimResult {
            scheme: scheduler.name().to_string(),
            distribution: self.config.distribution.name().to_string(),
            seed: self.config.seed,
            horizon,
            records,
            final_metrics: ClusterMetrics::capture_fleet(&cluster, &tables, accepted, arrived),
            time_avg_frag: if horizon == 0 { 0.0 } else { frag_sum / horizon as f64 },
            accepted,
            arrived,
            migrations,
            migrated_bytes,
            telemetry,
        }
    }

    /// Run a trace through the engine (checkpoints still demand-based).
    pub fn replay_trace(&self, scheduler: &mut dyn Scheduler, trace: &Trace) -> SimResult {
        let arrivals = trace.arrivals();
        self.replay(scheduler, &arrivals)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::SchedulerKind;

    fn run(kind: SchedulerKind, dist: Distribution, seed: u64) -> SimResult {
        let cfg = SimConfig::small(dist, seed);
        let engine = SimEngine::new(cfg.clone());
        let mut sched = kind.build(&cfg.hardware);
        engine.run(&mut *sched)
    }

    #[test]
    fn produces_all_checkpoints() {
        let r = run(SchedulerKind::Mfi, Distribution::Uniform, 1);
        assert_eq!(r.records.len(), 10);
        for (i, rec) in r.records.iter().enumerate() {
            assert!((rec.demand - (i + 1) as f64 / 10.0).abs() < 1e-9);
        }
        assert!(r.horizon > 0);
        assert_eq!(r.arrived, r.horizon);
    }

    #[test]
    fn accounting_invariants() {
        for kind in SchedulerKind::all() {
            let r = run(kind, Distribution::Uniform, 7);
            assert!(r.accepted <= r.arrived, "{kind}");
            assert!(r.acceptance_rate() <= 1.0 && r.acceptance_rate() >= 0.0);
            // Monotone cumulative counters across checkpoints.
            for w in r.records.windows(2) {
                assert!(w[1].metrics.arrived_total >= w[0].metrics.arrived_total);
                assert!(w[1].metrics.accepted_total >= w[0].metrics.accepted_total);
                assert!(w[1].slot >= w[0].slot);
            }
        }
    }

    #[test]
    fn mfi_indexed_reproduces_mfi_run_exactly() {
        // The incremental engine must be placement-for-placement identical
        // to the flat rescan through the full driver (arrivals, departures,
        // checkpoint capture), not just per isolated decision.
        for (dist, seed) in [
            (Distribution::Uniform, 21u64),
            (Distribution::Bimodal, 99),
            (Distribution::SkewBig, 7),
        ] {
            let a = run(SchedulerKind::Mfi, dist.clone(), seed);
            let b = run(SchedulerKind::MfiIdx, dist, seed);
            assert_eq!(a.accepted, b.accepted);
            assert_eq!(a.arrived, b.arrived);
            assert_eq!(a.time_avg_frag, b.time_avg_frag);
            for (ra, rb) in a.records.iter().zip(&b.records) {
                assert_eq!(ra.metrics, rb.metrics, "checkpoint {}", ra.demand);
            }
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run(SchedulerKind::Mfi, Distribution::Bimodal, 42);
        let b = run(SchedulerKind::Mfi, Distribution::Bimodal, 42);
        assert_eq!(a.accepted, b.accepted);
        assert_eq!(a.horizon, b.horizon);
        assert_eq!(a.time_avg_frag, b.time_avg_frag);
    }

    #[test]
    fn mfi_exp_run_is_deterministic_given_seed() {
        // The estimator's fixed-point weights must make MFI-EXP exactly
        // reproducible: two runs with the same seed are bit-identical,
        // including the floating-point fragmentation averages.
        for dist in [Distribution::Uniform, Distribution::SkewBig] {
            let a = run(SchedulerKind::MfiExp, dist.clone(), 42);
            let b = run(SchedulerKind::MfiExp, dist, 42);
            assert_eq!(a.accepted, b.accepted);
            assert_eq!(a.horizon, b.horizon);
            assert_eq!(a.time_avg_frag.to_bits(), b.time_avg_frag.to_bits());
            for (ra, rb) in a.records.iter().zip(&b.records) {
                assert_eq!(ra.metrics, rb.metrics, "checkpoint {}", ra.demand);
            }
        }
    }

    #[test]
    fn mfi_exp_mixed_fleet_run_conserves() {
        // Distribution-aware scoring on a heterogeneous fleet goes through
        // the per-class ExpectedFleet path; the run must keep the same
        // accounting invariants as every other scheduler.
        let fleet = crate::mig::FleetSpec::parse("a100:4,h100:3,a100-40gb:3").unwrap();
        let cfg = SimConfig::small(Distribution::Uniform, 23).with_fleet(fleet);
        let engine = SimEngine::new(cfg.clone());
        let mut s = SchedulerKind::MfiExp.build(&cfg.hardware);
        let r = engine.run(&mut *s);
        assert_eq!(r.arrived, r.horizon);
        assert!(r.accepted <= r.arrived);
        assert!(r.acceptance_rate() > 0.0);
        for rec in &r.records {
            assert!(rec.metrics.utilization <= 1.0 + 1e-9);
            assert!(rec.metrics.active_gpus <= 10);
        }
    }

    #[test]
    fn mfi_acceptance_at_low_demand_is_perfect() {
        let r = run(SchedulerKind::Mfi, Distribution::Uniform, 3);
        let early = r.at_demand(0.3).unwrap();
        assert!(
            early.metrics.acceptance_rate() > 0.999,
            "MFI should accept everything at low load, got {}",
            early.metrics.acceptance_rate()
        );
    }

    #[test]
    fn mfi_beats_or_matches_baselines_on_acceptance() {
        // Averaged over a few seeds to avoid flakes; the full statistical
        // comparison is the fig5 bench.
        let mut mfi = 0.0;
        let mut ff = 0.0;
        let mut rr = 0.0;
        let seeds = [11u64, 22, 33, 44, 55];
        for &s in &seeds {
            mfi += run(SchedulerKind::Mfi, Distribution::Uniform, s).acceptance_rate();
            ff += run(SchedulerKind::Ff, Distribution::Uniform, s).acceptance_rate();
            rr += run(SchedulerKind::Rr, Distribution::Uniform, s).acceptance_rate();
        }
        assert!(mfi >= ff - 1e-9, "MFI {mfi} vs FF {ff}");
        assert!(mfi >= rr - 1e-9, "MFI {mfi} vs RR {rr}");
    }

    #[test]
    fn replay_trace_equals_generated_run() {
        use crate::util::rng::Rng;
        use crate::workload::{Trace, WorkloadGenerator};
        let cfg = SimConfig::small(Distribution::Uniform, 5);
        let engine = SimEngine::new(cfg.clone());
        let gen = WorkloadGenerator::new(Distribution::Uniform);
        let capacity = (cfg.num_gpus * cfg.hardware.num_slices()) as u64;
        let generated = gen.generate(capacity, &mut Rng::new(5));
        let trace = Trace::from_workloads("t", capacity, &generated.workloads);

        let mut a = SchedulerKind::Mfi.build(&cfg.hardware);
        let direct = engine.run(&mut *a);
        let mut b = SchedulerKind::Mfi.build(&cfg.hardware);
        let replayed = engine.replay_trace(&mut *b, &trace);
        assert_eq!(direct.accepted, replayed.accepted);
        assert_eq!(direct.time_avg_frag, replayed.time_avg_frag);
    }

    #[test]
    fn budgeted_defrag_recovers_a_rejected_full_gpu_request() {
        // Engine twin of the replay-level scenario (one arrival per slot):
        // slot-6/8/9 departures strand w1+w3 on GPU 0 and w4 on GPU 1, so
        // the 7g.80gb arriving at slot 10 is rejected under FF — unless
        // the slot-10 sweep consolidates first. Verified against the
        // python-oracle mirror of the greedy planner: one move, w4
        // (2g.20gb) into GPU 0's free window at index 0, empties GPU 1.
        use crate::defrag::BYTES_PER_GB;
        use crate::mig::Profile;
        use crate::workload::spec::{TenantId, Workload};
        use crate::workload::WorkloadId;
        let mk = |id: u64, profile, arrival: u64, dur: u64| Workload {
            id: WorkloadId(id),
            tenant: TenantId(0),
            profile,
            arrival_slot: arrival,
            duration_slots: dur,
        };
        let ws = [
            mk(0, Profile::P2g20gb, 0, 6),
            mk(1, Profile::P2g20gb, 1, 100),
            mk(2, Profile::P2g20gb, 2, 6),
            mk(3, Profile::P1g20gb, 3, 100),
            mk(4, Profile::P2g20gb, 4, 100),
            mk(5, Profile::P2g20gb, 5, 4),
            mk(6, Profile::P7g80gb, 10, 5),
        ];
        let base = SimConfig { num_gpus: 2, ..SimConfig::paper(Distribution::Uniform, 0) };

        let engine = SimEngine::new(base.clone());
        let mut ff = SchedulerKind::Ff.build(&base.hardware);
        let plain = engine.replay(&mut *ff, &ws);
        assert_eq!(plain.accepted, 6, "7g must be rejected without defrag");
        assert_eq!(plain.migrations, 0);
        assert_eq!(plain.migrated_bytes, 0);

        let engine = SimEngine::new(base.with_defrag(10, 16));
        let mut ff = SchedulerKind::Ff.build(&HardwareModel::a100_80gb());
        let r = engine.replay(&mut *ff, &ws);
        assert_eq!(r.accepted, 7, "slot-10 sweep consolidates, 7g fits");
        assert_eq!(r.migrations, 1);
        // w4 (2g.20gb): 20 GB on A100-80GB.
        assert_eq!(r.migrated_bytes, 20 * BYTES_PER_GB);
    }

    #[test]
    fn utilization_never_exceeds_one() {
        for kind in [SchedulerKind::Mfi, SchedulerKind::Ff, SchedulerKind::WfBi] {
            let r = run(kind, Distribution::SkewBig, 9);
            for rec in &r.records {
                assert!(rec.metrics.utilization <= 1.0 + 1e-9, "{kind}");
                assert!(rec.metrics.active_gpus <= 10, "{kind}");
            }
        }
    }

    #[test]
    fn telemetry_rows_follow_checkpoints_and_default_off() {
        let off = run(SchedulerKind::Mfi, Distribution::Uniform, 4);
        assert!(off.telemetry.is_empty(), "telemetry is opt-in");

        let mut cfg = SimConfig::small(Distribution::Uniform, 4);
        cfg.telemetry = true;
        let engine = SimEngine::new(cfg.clone());
        let mut sched = SchedulerKind::Mfi.build(&cfg.hardware);
        let r = engine.run(&mut *sched);
        assert_eq!(r.telemetry.len(), r.records.len());
        // Telemetry timing must not perturb the simulation itself.
        assert_eq!(r.accepted, off.accepted);
        assert_eq!(r.time_avg_frag, off.time_avg_frag);
        // The last row agrees with the run totals.
        let last = r.telemetry.last().unwrap();
        use crate::util::json::Json;
        assert_eq!(last.get("arrived").and_then(Json::as_u64), Some(r.arrived));
        assert_eq!(last.get("accepted").and_then(Json::as_u64), Some(r.accepted));
        // One decision timed per arrival.
        assert_eq!(last.get("decisions").and_then(Json::as_u64), Some(r.arrived));
        assert!(last.get("decision_seconds_p99").and_then(Json::as_f64).unwrap() >= 0.0);
    }

    #[test]
    fn uniform_fleet_run_is_bit_identical_to_legacy() {
        // A single-class FleetSpec must be a strict special case: same
        // placements, same counters, bit-identical floating-point metrics.
        let legacy_cfg = SimConfig::small(Distribution::Bimodal, 17);
        let legacy_engine = SimEngine::new(legacy_cfg.clone());
        let mut s = SchedulerKind::Mfi.build(&legacy_cfg.hardware);
        let legacy = legacy_engine.run(&mut *s);

        let fleet = crate::mig::FleetSpec::parse("a100:10").unwrap();
        let fleet_cfg = SimConfig::small(Distribution::Bimodal, 17).with_fleet(fleet);
        let fleet_engine = SimEngine::new(fleet_cfg.clone());
        let mut s = SchedulerKind::Mfi.build(&fleet_cfg.hardware);
        let r = fleet_engine.run(&mut *s);

        assert_eq!(legacy.accepted, r.accepted);
        assert_eq!(legacy.horizon, r.horizon);
        assert_eq!(legacy.time_avg_frag.to_bits(), r.time_avg_frag.to_bits());
        for (a, b) in legacy.records.iter().zip(&r.records) {
            assert_eq!(a.metrics, b.metrics, "checkpoint {}", a.demand);
            assert_eq!(
                a.metrics.mean_frag_score.to_bits(),
                b.metrics.mean_frag_score.to_bits()
            );
        }
    }

    #[test]
    fn mixed_fleet_run_conserves_and_indexed_mfi_agrees() {
        let fleet = crate::mig::FleetSpec::parse("a100:4,h100:3,a100-40gb:3").unwrap();
        let cfg = SimConfig::small(Distribution::Uniform, 23).with_fleet(fleet);
        let engine = SimEngine::new(cfg.clone());

        let mut a = SchedulerKind::Mfi.build(&cfg.hardware);
        let ra = engine.run(&mut *a);
        assert_eq!(ra.arrived, ra.horizon);
        assert!(ra.accepted <= ra.arrived);
        assert!(ra.acceptance_rate() > 0.0);
        for rec in &ra.records {
            assert!(rec.metrics.utilization <= 1.0 + 1e-9);
            assert!(rec.metrics.active_gpus <= 10);
        }

        // The incremental index must reproduce the flat fleet scan through
        // the full driver on a heterogeneous cluster too.
        let mut b = SchedulerKind::MfiIdx.build(&cfg.hardware);
        let rb = engine.run(&mut *b);
        assert_eq!(ra.accepted, rb.accepted);
        assert_eq!(ra.time_avg_frag.to_bits(), rb.time_avg_frag.to_bits());
        for (x, y) in ra.records.iter().zip(&rb.records) {
            assert_eq!(x.metrics, y.metrics, "checkpoint {}", x.demand);
        }
    }

    #[test]
    fn mixed_fleet_defrag_keeps_accounting() {
        let fleet = crate::mig::FleetSpec::parse("a100:3,a100-40gb:3").unwrap();
        let cfg = SimConfig::small(Distribution::SkewBig, 31)
            .with_fleet(fleet)
            .with_defrag(5, 8);
        let engine = SimEngine::new(cfg.clone());
        let mut s = SchedulerKind::Ff.build(&cfg.hardware);
        let r = engine.run(&mut *s);
        assert!(r.accepted <= r.arrived);
        // Migration bytes only when migrations happened.
        assert_eq!(r.migrations == 0, r.migrated_bytes == 0);
    }

    #[test]
    #[should_panic(expected = "ascending")]
    fn rejects_bad_checkpoints() {
        let mut cfg = SimConfig::small(Distribution::Uniform, 1);
        cfg.checkpoints = vec![0.5, 0.3];
        let _ = SimEngine::new(cfg);
    }
}
