//! Seed sweeps: many independent simulation runs aggregated into the
//! statistics the paper's figures plot (500 runs per configuration,
//! Section VI), parallelized across OS threads.
//!
//! The hot loop is lock-free: work items are (cell, run-chunk) pairs
//! handed out by one atomic counter, every worker accumulates into
//! *private* partial [`AggregatedCell`]s, and the partials are merged
//! after the join in a fixed (cell, chunk) order via [`merge_cells`].
//! Because chunk boundaries depend only on the config (not on the thread
//! count or scheduling), sweep results are **bit-identical** for any
//! `threads` setting — asserted by `sweep_deterministic_across_thread_counts`.

use super::engine::{SimConfig, SimEngine, SimResult};
use crate::sched::SchedulerKind;
use crate::util::rng::SplitMix64;
use crate::util::stats::OnlineStats;
use crate::workload::Distribution;

/// Sweep configuration.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub hardware: crate::mig::HardwareModel,
    pub num_gpus: usize,
    /// Independent Monte Carlo runs per (scheme, distribution).
    pub runs: usize,
    pub schemes: Vec<SchedulerKind>,
    pub distributions: Vec<Distribution>,
    pub checkpoints: Vec<f64>,
    pub base_seed: u64,
    /// Worker threads (0 = available parallelism).
    pub threads: usize,
}

impl ExperimentConfig {
    /// The paper's full evaluation: M=100, 500 runs, 5 schemes, 4
    /// distributions, checkpoints 10%…100%.
    pub fn paper() -> Self {
        Self {
            hardware: crate::mig::HardwareModel::a100_80gb(),
            num_gpus: 100,
            runs: 500,
            schemes: SchedulerKind::paper_set().to_vec(),
            distributions: Distribution::paper_set().to_vec(),
            // 10%…100% (Fig. 4) plus the 85% operating point of Fig. 5.
            checkpoints: vec![0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.85, 0.9, 1.0],
            base_seed: 0x4D49_4753, // "MIGS"
            threads: 0,
        }
    }

    /// A fast configuration for tests/CI smoke runs.
    pub fn quick() -> Self {
        Self { num_gpus: 20, runs: 20, ..Self::paper() }
    }
}

/// Aggregated statistics for one metric at one checkpoint.
#[derive(Clone, Debug, Default)]
pub struct AggregatedCell {
    pub accepted_workloads: OnlineStats,
    pub acceptance_rate: OnlineStats,
    pub utilization: OnlineStats,
    pub active_gpus: OnlineStats,
    pub mean_frag: OnlineStats,
    pub allocated_workloads: OnlineStats,
}

impl AggregatedCell {
    fn push(&mut self, m: &crate::cluster::ClusterMetrics) {
        self.accepted_workloads.push(m.accepted_total as f64);
        self.acceptance_rate.push(m.acceptance_rate());
        self.utilization.push(m.utilization);
        self.active_gpus.push(m.active_gpus as f64);
        self.mean_frag.push(m.mean_frag_score);
        self.allocated_workloads.push(m.allocated_workloads as f64);
    }

    fn merge(&mut self, other: &AggregatedCell) {
        self.accepted_workloads.merge(&other.accepted_workloads);
        self.acceptance_rate.merge(&other.acceptance_rate);
        self.utilization.merge(&other.utilization);
        self.active_gpus.merge(&other.active_gpus);
        self.mean_frag.merge(&other.mean_frag);
        self.allocated_workloads.merge(&other.allocated_workloads);
    }
}

/// One (scheme, distribution) series across all checkpoints.
#[derive(Clone, Debug)]
pub struct SweepSeries {
    pub scheme: SchedulerKind,
    pub distribution: Distribution,
    /// One cell per configured checkpoint, ascending demand.
    pub checkpoints: Vec<AggregatedCell>,
    /// Fig. 6 quantity: run-level time-averaged fragmentation score.
    pub time_avg_frag: OnlineStats,
    /// Whole-run acceptance.
    pub final_acceptance: OnlineStats,
    pub horizon: OnlineStats,
}

/// Results of a full sweep.
#[derive(Clone, Debug)]
pub struct SweepResult {
    pub config_summary: String,
    pub demands: Vec<f64>,
    pub series: Vec<SweepSeries>,
}

impl SweepResult {
    pub fn series_for(
        &self,
        scheme: SchedulerKind,
        distribution: &Distribution,
    ) -> Option<&SweepSeries> {
        self.series
            .iter()
            .find(|s| s.scheme == scheme && &s.distribution == distribution)
    }

    /// Index of the checkpoint nearest a demand fraction.
    pub fn checkpoint_index(&self, demand: f64) -> usize {
        self.demands
            .iter()
            .enumerate()
            .min_by(|a, b| {
                (a.1 - demand).abs().partial_cmp(&(b.1 - demand).abs()).unwrap()
            })
            .map(|(i, _)| i)
            .unwrap_or(0)
    }
}

/// Runs per work item. Small enough that a tiny test config still spans
/// several chunks (exercising the merge), big enough that chunk-claim
/// overhead is negligible against hundreds of simulated slots per run.
const RUN_CHUNK: usize = 4;

/// One worker's private partial aggregation for one (cell, chunk) item.
struct CellPartial {
    checkpoints: Vec<AggregatedCell>,
    time_avg_frag: OnlineStats,
    final_acceptance: OnlineStats,
    horizon: OnlineStats,
}

impl CellPartial {
    fn new(num_checkpoints: usize) -> Self {
        Self {
            checkpoints: vec![AggregatedCell::default(); num_checkpoints],
            time_avg_frag: OnlineStats::new(),
            final_acceptance: OnlineStats::new(),
            horizon: OnlineStats::new(),
        }
    }
}

/// Run the sweep. Deterministic: seeds are derived from
/// `base_seed × run-index` via SplitMix64, identical for every scheme so
/// all schemes face *the same* workload sequences (paired comparison, as
/// in the paper). Aggregation is bit-identical across thread counts (see
/// module docs).
pub fn run_sweep(config: &ExperimentConfig) -> SweepResult {
    let threads = if config.threads == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
    } else {
        config.threads
    };

    // Per-run seeds shared across schemes (paired workload sequences).
    let mut seed_gen = SplitMix64::new(config.base_seed);
    let run_seeds: Vec<u64> = (0..config.runs).map(|_| seed_gen.next_u64()).collect();

    // Cells in output order; work items are (cell, chunk-of-runs) pairs so
    // the queue scales past a handful of cells.
    let cells: Vec<(Distribution, SchedulerKind)> = config
        .distributions
        .iter()
        .flat_map(|d| config.schemes.iter().map(move |&s| (d.clone(), s)))
        .collect();
    let num_chunks = config.runs.div_ceil(RUN_CHUNK);
    let total_items = cells.len() * num_chunks;

    let next_item = std::sync::atomic::AtomicUsize::new(0);
    let mut partials: Vec<(usize, CellPartial)> = Vec::with_capacity(total_items);
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for _ in 0..threads.min(total_items).max(1) {
            handles.push(scope.spawn(|| {
                let mut out: Vec<(usize, CellPartial)> = Vec::new();
                loop {
                    let item =
                        next_item.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    if item >= total_items {
                        break;
                    }
                    let (distribution, scheme) = &cells[item / num_chunks];
                    let lo = (item % num_chunks) * RUN_CHUNK;
                    let hi = (lo + RUN_CHUNK).min(config.runs);
                    let mut partial = CellPartial::new(config.checkpoints.len());
                    for run in lo..hi {
                        let sim_cfg = SimConfig {
                            hardware: config.hardware.clone(),
                            num_gpus: config.num_gpus,
                            fleet: None,
                            distribution: distribution.clone(),
                            checkpoints: config.checkpoints.clone(),
                            seed: run_seeds[run],
                            defrag: None,
                            telemetry: false,
                        };
                        let engine = SimEngine::new(sim_cfg);
                        let mut sched = scheme.build(&config.hardware);
                        let result = engine.run(&mut *sched);
                        accumulate(&mut partial.checkpoints, &result);
                        partial.time_avg_frag.push(result.time_avg_frag);
                        partial.final_acceptance.push(result.acceptance_rate());
                        partial.horizon.push(result.horizon as f64);
                    }
                    out.push((item, partial));
                }
                out
            }));
        }
        for handle in handles {
            partials.extend(handle.join().expect("sweep worker panicked"));
        }
    });

    // Merge in ascending (cell, chunk) order — independent of which worker
    // produced which partial.
    partials.sort_unstable_by_key(|(item, _)| *item);
    let mut series_out: Vec<SweepSeries> = cells
        .iter()
        .map(|(distribution, scheme)| SweepSeries {
            scheme: *scheme,
            distribution: distribution.clone(),
            checkpoints: vec![AggregatedCell::default(); config.checkpoints.len()],
            time_avg_frag: OnlineStats::new(),
            final_acceptance: OnlineStats::new(),
            horizon: OnlineStats::new(),
        })
        .collect();
    for (item, partial) in &partials {
        let series = &mut series_out[item / num_chunks];
        merge_cells(&mut series.checkpoints, &partial.checkpoints);
        series.time_avg_frag.merge(&partial.time_avg_frag);
        series.final_acceptance.merge(&partial.final_acceptance);
        series.horizon.merge(&partial.horizon);
    }

    SweepResult {
        config_summary: format!(
            "M={} runs={} schemes={} distributions={}",
            config.num_gpus,
            config.runs,
            config.schemes.len(),
            config.distributions.len()
        ),
        demands: config.checkpoints.clone(),
        series: series_out,
    }
}

fn accumulate(cells: &mut [AggregatedCell], result: &SimResult) {
    assert_eq!(cells.len(), result.records.len(), "checkpoint arity mismatch");
    for (cell, rec) in cells.iter_mut().zip(&result.records) {
        cell.push(&rec.metrics);
    }
}

/// Merge per-thread partial aggregations (exposed for the bench harness).
pub fn merge_cells(into: &mut [AggregatedCell], from: &[AggregatedCell]) {
    assert_eq!(into.len(), from.len());
    for (a, b) in into.iter_mut().zip(from) {
        a.merge(b);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_config() -> ExperimentConfig {
        ExperimentConfig {
            num_gpus: 8,
            runs: 6,
            schemes: vec![SchedulerKind::Mfi, SchedulerKind::Ff],
            distributions: vec![Distribution::Uniform],
            checkpoints: vec![0.5, 0.85, 1.0],
            threads: 2,
            ..ExperimentConfig::paper()
        }
    }

    #[test]
    fn sweep_shape() {
        let r = run_sweep(&tiny_config());
        assert_eq!(r.series.len(), 2);
        for s in &r.series {
            assert_eq!(s.checkpoints.len(), 3);
            assert_eq!(s.time_avg_frag.count(), 6);
            for c in &s.checkpoints {
                assert_eq!(c.acceptance_rate.count(), 6);
            }
        }
        assert_eq!(r.checkpoint_index(0.85), 1);
        assert_eq!(r.checkpoint_index(0.1), 0);
    }

    #[test]
    fn sweep_deterministic_across_thread_counts() {
        // Chunk boundaries and the merge order depend only on the config,
        // so results are BIT-identical across thread counts (the tiny
        // config's 6 runs span two RUN_CHUNK=4 chunks, exercising both a
        // full and a ragged chunk).
        let mut c1 = tiny_config();
        c1.threads = 1;
        let mut c4 = tiny_config();
        c4.threads = 4;
        let r1 = run_sweep(&c1);
        let r4 = run_sweep(&c4);
        for (a, b) in r1.series.iter().zip(&r4.series) {
            assert_eq!(a.scheme, b.scheme);
            assert_eq!(a.distribution, b.distribution);
            assert_eq!(a.final_acceptance.mean(), b.final_acceptance.mean(), "{}", a.scheme);
            assert_eq!(a.time_avg_frag.mean(), b.time_avg_frag.mean(), "{}", a.scheme);
            assert_eq!(a.horizon.mean(), b.horizon.mean());
            for (ca, cb) in a.checkpoints.iter().zip(&b.checkpoints) {
                assert_eq!(ca.acceptance_rate.mean(), cb.acceptance_rate.mean());
                assert_eq!(ca.utilization.mean(), cb.utilization.mean());
                assert_eq!(ca.mean_frag.mean(), cb.mean_frag.mean());
            }
        }
    }

    #[test]
    fn paired_seeds_across_schemes() {
        // Both schemes must see identical horizons per run (same workload
        // sequences), so horizon stats match exactly.
        let r = run_sweep(&tiny_config());
        let a = r.series_for(SchedulerKind::Mfi, &Distribution::Uniform).unwrap();
        let b = r.series_for(SchedulerKind::Ff, &Distribution::Uniform).unwrap();
        assert_eq!(a.horizon.mean(), b.horizon.mean());
        assert_eq!(a.horizon.min(), b.horizon.min());
        assert_eq!(a.horizon.max(), b.horizon.max());
    }

    #[test]
    fn mfi_dominates_ff_in_sweep() {
        let r = run_sweep(&tiny_config());
        let mfi = r.series_for(SchedulerKind::Mfi, &Distribution::Uniform).unwrap();
        let ff = r.series_for(SchedulerKind::Ff, &Distribution::Uniform).unwrap();
        assert!(
            mfi.final_acceptance.mean() >= ff.final_acceptance.mean() - 1e-9,
            "MFI {} vs FF {}",
            mfi.final_acceptance.mean(),
            ff.final_acceptance.mean()
        );
    }
}
