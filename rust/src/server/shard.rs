//! Daemon sharding: the GPU fleet is partitioned into disjoint
//! sub-clusters ("shards"), each behind its **own** mutex, so
//! submit/release/tick on different tenants never contend — the
//! multi-tenant scale story the ROADMAP names, built on the per-GPU
//! change feed from the incremental decision core.
//!
//! * **Routing** — tenants map to shards via a consistent-hash ring
//!   ([`ShardRouter`]: 64 virtual nodes per shard, splitmix64), so
//!   resizing the shard count remaps only ~1/S of the tenant space and a
//!   tenant's workloads always land in one sub-cluster.
//! * **Ids** — the wire-visible workload id encodes its shard
//!   (`id ≡ shard (mod num_shards)`), so lookup/release route in O(1)
//!   without any global registry or cross-shard lock.
//! * **GPU numbering** — each shard owns the global GPU range
//!   `gpu_offset .. gpu_offset + size`; responses always report global
//!   ids, so `/v1/cluster` concatenated across shards reads like one
//!   fleet.
//!
//! With `shards = 1` (the default) the daemon collapses to the previous
//! single-mutex design and its responses are byte-for-byte unchanged.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::daemon::{ConnLimits, DaemonConfig};
use super::metrics::ServerMetrics;
use crate::cluster::Cluster;
use crate::defrag::{apply_plan, plan_defrag_budgeted, CostModel, MigrationPlan};
use crate::frag::{FleetTables, ScoreTable};
use crate::mig::FleetSpec;
use crate::sched::Scheduler;
use crate::util::json::Json;
use crate::workload::{TenantId, WorkloadId};

/// A lease attached to an allocated workload (logical-slot expiry).
#[derive(Clone, Copy, Debug)]
pub struct Lease {
    pub tenant: TenantId,
    /// Slot at which the lease expires (None = until explicit release).
    pub expires_at: Option<u64>,
}

/// Per-shard serving state: one mutex' worth of cluster + scheduler +
/// lease registry + counters. With `shards = 1` this is exactly the old
/// whole-daemon state.
pub struct ShardState {
    pub cluster: Cluster,
    pub scheduler: Box<dyn Scheduler + Send>,
    pub scorer: ScoreTable,
    /// Per-class score tables for this shard's sub-cluster; on a uniform
    /// fleet its arithmetic is bit-identical to `scorer` alone.
    pub tables: FleetTables,
    pub leases: HashMap<WorkloadId, Lease>,
    /// Local submission sequence; the wire-visible id is
    /// `seq * num_shards + shard_index` (see [`ShardSet::workload_id`]).
    pub next_seq: u64,
    pub clock_slot: u64,
    pub accepted_total: u64,
    pub arrived_total: u64,
    /// Explicit `DELETE /v1/workloads/{id}` releases only.
    pub released_total: u64,
    /// Lease expiries observed by `tick` only.
    pub expired_total: u64,
    /// Defrag migrations applied on this shard (maintenance endpoint and
    /// the background sweeper both count here).
    pub migrations_total: u64,
    /// Instance memory copied by those migrations.
    pub migrated_bytes_total: u64,
}

impl ShardState {
    /// Advance the logical slot clock, releasing expired leases.
    /// Returns the ids released (ascending).
    pub fn tick(&mut self, slots: u64) -> Vec<WorkloadId> {
        self.clock_slot += slots;
        let now = self.clock_slot;
        let expired: Vec<WorkloadId> = self
            .leases
            .iter()
            .filter(|(_, lease)| lease.expires_at.is_some_and(|t| t <= now))
            .map(|(id, _)| *id)
            .collect();
        let mut released = expired;
        released.sort();
        for id in &released {
            let freed =
                self.cluster.release(*id).expect("lease registry consistent with cluster");
            self.scheduler.on_release(&self.cluster, freed);
            self.leases.remove(id);
            self.expired_total += 1;
        }
        released
    }

    /// One threshold-gated, budgeted defrag sweep over this shard's
    /// sub-cluster. The caller holds the shard lock, so the plan is fresh
    /// by construction and applies atomically from every other handler's
    /// point of view. Returns the applied plan (empty when the threshold
    /// gate held the sweep back or the planner found nothing).
    pub fn defrag_sweep(
        &mut self,
        threshold: f64,
        max_moves: usize,
        cost_budget: u64,
    ) -> Result<MigrationPlan, String> {
        if self.tables.mean_score(&self.cluster) < threshold {
            return Ok(MigrationPlan::default());
        }
        let plan = plan_defrag_budgeted(
            &self.cluster,
            &self.scorer,
            max_moves,
            &CostModel::default(),
            cost_budget,
        );
        if !plan.is_empty() {
            apply_plan(&mut self.cluster, &plan)?;
            self.migrations_total += plan.moves.len() as u64;
            self.migrated_bytes_total += plan.bytes_moved;
        }
        Ok(plan)
    }
}

/// One shard: its state mutex plus the immutable partition geometry.
pub struct Shard {
    /// Position in [`ShardSet::shards`]; also `id mod num_shards` for
    /// every workload this shard owns.
    pub index: usize,
    /// Global id of this shard's first GPU: the sub-cluster's local GPU
    /// `g` is the fleet's GPU `gpu_offset + g`.
    pub gpu_offset: usize,
    pub state: Mutex<ShardState>,
}

/// The daemon's shard collection: disjoint sub-clusters + tenant router.
/// Handlers lock exactly one shard for data-plane requests; scatter-gather
/// endpoints visit shards in index order (one lock at a time, so the lock
/// order is globally consistent and deadlock-free).
pub struct ShardSet {
    shards: Vec<Shard>,
    router: ShardRouter,
    /// The served fleet (uniform when no `--fleet` was given); the source
    /// of truth for class names/ids in `/v1/stats` and `/v1/cluster`.
    fleet: FleetSpec,
    total_gpus: usize,
    scheduler_name: &'static str,
    /// The daemon's metric registry (see [`super::metrics`]); recording is
    /// lock-free, so it lives outside the shard mutexes.
    metrics: ServerMetrics,
    started: Instant,
    /// Per-connection serving limits, shared by both serve models.
    limits: ConnLimits,
    /// `GET /v1/version` body, rendered once at construction — the
    /// response is config-determined, so serving it is a refcount bump.
    version_body: Arc<[u8]>,
}

impl ShardSet {
    /// Partition the fleet into `config.shards` sub-clusters. Each class's
    /// count is split by largest remainder (earlier shards taking the
    /// extra GPU), so every shard preserves the fleet's class composition;
    /// for a uniform fleet this reproduces the legacy even partition
    /// (sizes differing by at most one, larger shards first).
    pub fn new(config: &DaemonConfig) -> Self {
        assert!(config.shards >= 1, "daemon needs at least one shard");
        let fleet = config.fleet.clone().unwrap_or_else(|| {
            FleetSpec::uniform(config.hardware.clone(), config.num_gpus)
        });
        assert_eq!(
            fleet.total_gpus(),
            config.num_gpus,
            "fleet total ({}) disagrees with num_gpus ({})",
            fleet.total_gpus(),
            config.num_gpus
        );
        assert!(
            config.shards <= config.num_gpus,
            "more shards ({}) than GPUs ({})",
            config.shards,
            config.num_gpus
        );
        let parts = fleet.partition(config.shards);
        assert!(
            parts.iter().all(|row| row.iter().sum::<usize>() > 0),
            "fleet {} cannot be split into {} composition-preserving shard(s) \
             (a shard would own no GPUs)",
            fleet.spec_string(),
            config.shards
        );
        let models = fleet.models();
        let mut shards = Vec::with_capacity(config.shards);
        let mut offset = 0usize;
        for (index, row) in parts.iter().enumerate() {
            let size: usize = row.iter().sum();
            let cluster = Cluster::from_classes(models.clone(), row);
            let tables = FleetTables::for_cluster(&cluster);
            let state = ShardState {
                cluster,
                scheduler: config
                    .scheduler
                    .build_with_estimator(&config.hardware, config.estimator.as_ref()),
                scorer: ScoreTable::for_hardware(&config.hardware),
                tables,
                leases: HashMap::new(),
                next_seq: 0,
                clock_slot: 0,
                accepted_total: 0,
                arrived_total: 0,
                released_total: 0,
                expired_total: 0,
                migrations_total: 0,
                migrated_bytes_total: 0,
            };
            shards.push(Shard { index, gpu_offset: offset, state: Mutex::new(state) });
            offset += size;
        }
        let mut features: Vec<Json> = Vec::new();
        if cfg!(feature = "xla") {
            features.push(Json::from("xla"));
        }
        let mut version = Json::obj()
            .with("name", env!("CARGO_PKG_NAME"))
            .with("version", env!("CARGO_PKG_VERSION"))
            .with("features", Json::Arr(features))
            .with("scheduler", config.scheduler.name())
            .with("serve_model", config.model.effective().name())
            .with("idle_timeout_ms", config.idle_timeout.as_millis() as u64)
            .with("max_requests_per_conn", config.max_requests_per_conn as u64);
        if !fleet.is_uniform() {
            // Only on heterogeneous fleets, so single-class `/v1/version`
            // bytes are unchanged.
            version.set("fleet", fleet.spec_string().as_str());
        }
        let version_body: Arc<[u8]> = version.to_string_compact().into_bytes().into();
        Self {
            shards,
            router: ShardRouter::new(config.shards),
            fleet,
            total_gpus: config.num_gpus,
            scheduler_name: config.scheduler.name(),
            metrics: ServerMetrics::new(config.shards),
            started: Instant::now(),
            limits: ConnLimits {
                idle_timeout: config.idle_timeout,
                max_requests_per_conn: config.max_requests_per_conn,
            },
            version_body,
        }
    }

    /// The daemon's metric registry.
    pub fn metrics(&self) -> &ServerMetrics {
        &self.metrics
    }

    /// Per-connection serving limits (config-determined, never change
    /// while serving).
    pub fn limits(&self) -> ConnLimits {
        self.limits
    }

    /// The preserialized `GET /v1/version` body.
    pub fn version_body(&self) -> Arc<[u8]> {
        Arc::clone(&self.version_body)
    }

    /// Time since this state was constructed (serving uptime).
    pub fn uptime(&self) -> Duration {
        self.started.elapsed()
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Fleet size across all shards.
    pub fn total_gpus(&self) -> usize {
        self.total_gpus
    }

    /// The served fleet (a single-class spec when no `--fleet` was given).
    pub fn fleet(&self) -> &FleetSpec {
        &self.fleet
    }

    pub fn scheduler_name(&self) -> &'static str {
        self.scheduler_name
    }

    /// All shards in index order — the stable merge order used by every
    /// scatter-gather endpoint.
    pub fn shards(&self) -> &[Shard] {
        &self.shards
    }

    pub fn shard(&self, index: usize) -> Option<&Shard> {
        self.shards.get(index)
    }

    /// The shard serving `tenant` (consistent-hash routing).
    pub fn route(&self, tenant: TenantId) -> &Shard {
        &self.shards[self.router.route(tenant)]
    }

    /// The shard owning workload `id` (ids encode their shard).
    pub fn shard_of(&self, id: WorkloadId) -> &Shard {
        &self.shards[(id.0 % self.shards.len() as u64) as usize]
    }

    /// Wire-visible workload id for local sequence `seq` on `shard`.
    pub fn workload_id(&self, shard: &Shard, seq: u64) -> WorkloadId {
        WorkloadId(seq * self.shards.len() as u64 + shard.index as u64)
    }
}

/// Virtual nodes per shard on the consistent-hash ring. 64 keeps the
/// worst-case tenant imbalance small without making ring construction or
/// the binary-search lookup noticeable.
const VNODES: usize = 64;

/// SplitMix64 finalizer — a cheap, well-mixed, deterministic 64-bit hash
/// (and a bijection, so distinct vnode seeds never collide on the ring).
fn splitmix64(seed: u64) -> u64 {
    let mut z = seed.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Consistent-hash ring mapping `TenantId → shard index`. Deterministic
/// across processes (no per-process seeding), so a tenant always lands on
/// the same shard for a given shard count.
pub struct ShardRouter {
    /// `(ring point, shard index)`, sorted by point.
    ring: Vec<(u64, usize)>,
}

impl ShardRouter {
    pub fn new(shards: usize) -> Self {
        assert!(shards > 0, "router needs at least one shard");
        let mut ring: Vec<(u64, usize)> = (0..shards)
            .flat_map(|s| {
                (0..VNODES).map(move |v| (splitmix64(((s as u64) << 16) | v as u64), s))
            })
            .collect();
        ring.sort_unstable();
        Self { ring }
    }

    /// Shard index for `tenant`: the first ring point at or after the
    /// tenant's hash, wrapping past the top of the ring.
    pub fn route(&self, tenant: TenantId) -> usize {
        let h = splitmix64(0x7E4A_4E7E ^ u64::from(tenant.0));
        let i = self.ring.partition_point(|&(point, _)| point < h);
        self.ring[i % self.ring.len()].1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mig::Profile;

    fn config(num_gpus: usize, shards: usize) -> DaemonConfig {
        DaemonConfig { num_gpus, shards, workers: 1, ..DaemonConfig::default() }
    }

    #[test]
    fn single_shard_router_routes_everything_to_zero() {
        let router = ShardRouter::new(1);
        for t in 0..100 {
            assert_eq!(router.route(TenantId(t)), 0);
        }
    }

    #[test]
    fn router_is_deterministic_and_covers_all_shards() {
        let a = ShardRouter::new(8);
        let b = ShardRouter::new(8);
        let mut hit = vec![false; 8];
        for t in 0..10_000 {
            let s = a.route(TenantId(t));
            assert_eq!(s, b.route(TenantId(t)), "tenant {t}");
            assert!(s < 8);
            hit[s] = true;
        }
        assert!(hit.iter().all(|&h| h), "10k tenants should touch all 8 shards: {hit:?}");
    }

    #[test]
    fn router_balance_is_reasonable() {
        // Consistent hashing is not perfectly uniform, but 64 vnodes keep
        // every shard within a loose factor of its fair share.
        let router = ShardRouter::new(4);
        let mut counts = [0usize; 4];
        for t in 0..40_000 {
            counts[router.route(TenantId(t))] += 1;
        }
        for (s, &c) in counts.iter().enumerate() {
            assert!(
                (2_000..=25_000).contains(&c),
                "shard {s} got {c} of 40000 tenants: {counts:?}"
            );
        }
    }

    #[test]
    fn resharding_moves_a_minority_of_tenants() {
        // The consistent-ring property: going 4 → 5 shards remaps roughly
        // 1/5 of the tenant space, not all of it (hash-mod would remap ~4/5).
        let four = ShardRouter::new(4);
        let five = ShardRouter::new(5);
        let n = 20_000u32;
        let moved = (0..n)
            .filter(|&t| four.route(TenantId(t)) != five.route(TenantId(t)))
            .count();
        assert!(
            moved < (n as usize) / 2,
            "only a minority may move on reshard, moved {moved}/{n}"
        );
    }

    #[test]
    fn partition_is_disjoint_and_exhaustive() {
        let set = ShardSet::new(&config(10, 3));
        // 10 GPUs over 3 shards: sizes 4, 3, 3 at offsets 0, 4, 7.
        let sizes: Vec<usize> = set
            .shards()
            .iter()
            .map(|s| s.state.lock().unwrap().cluster.num_gpus())
            .collect();
        assert_eq!(sizes, vec![4, 3, 3]);
        let offsets: Vec<usize> = set.shards().iter().map(|s| s.gpu_offset).collect();
        assert_eq!(offsets, vec![0, 4, 7]);
        assert_eq!(set.total_gpus(), 10);
    }

    #[test]
    fn workload_ids_encode_their_shard() {
        let set = ShardSet::new(&config(8, 4));
        for shard in set.shards() {
            for seq in 0..5 {
                let id = set.workload_id(shard, seq);
                assert_eq!(set.shard_of(id).index, shard.index);
                assert_eq!(id.0, seq * 4 + shard.index as u64);
            }
        }
        // shards = 1 reproduces the legacy dense id sequence 0, 1, 2, …
        let set = ShardSet::new(&config(2, 1));
        let shard = set.shard(0).unwrap();
        for seq in 0..5 {
            assert_eq!(set.workload_id(shard, seq).0, seq);
        }
    }

    #[test]
    fn shard_tick_releases_expired_leases() {
        let set = ShardSet::new(&config(2, 1));
        let shard = set.shard(0).unwrap();
        let mut s = shard.state.lock().unwrap();
        let ShardState { scheduler, cluster, .. } = &mut *s;
        let placement = scheduler.schedule(cluster, Profile::P2g20gb).unwrap();
        cluster.allocate(WorkloadId(0), placement).unwrap();
        s.leases
            .insert(WorkloadId(0), Lease { tenant: TenantId(0), expires_at: Some(3) });
        assert!(s.tick(2).is_empty(), "nothing expires at slot 2");
        assert_eq!(s.tick(1), vec![WorkloadId(0)]);
        assert_eq!(s.expired_total, 1);
        assert_eq!(s.cluster.allocated_workloads(), 0);
    }

    #[test]
    fn defrag_sweep_repairs_and_counts() {
        use crate::mig::Placement;
        let set = ShardSet::new(&config(2, 1));
        let shard = set.shard(0).unwrap();
        let mut s = shard.state.lock().unwrap();
        // A 1g.10gb at index 1 blocks the 4g anchor (score 12).
        s.cluster
            .allocate(
                WorkloadId(0),
                Placement { gpu: 0, profile: Profile::P1g10gb, index: 1 },
            )
            .unwrap();
        // Threshold above the current mean: the sweep is gated off.
        let gated = s.defrag_sweep(100.0, 16, 0).unwrap();
        assert!(gated.is_empty());
        assert_eq!(s.migrations_total, 0);
        // Unconditional sweep repairs and bumps both counters.
        let plan = s.defrag_sweep(0.0, 16, 0).unwrap();
        assert_eq!(plan.moves.len(), 1);
        assert_eq!(s.migrations_total, 1);
        assert_eq!(s.migrated_bytes_total, plan.bytes_moved);
        assert!(s.migrated_bytes_total > 0);
        // Nothing left to repair: sweeping again is a counted no-op… of
        // zero moves, so counters are unchanged.
        let again = s.defrag_sweep(0.0, 16, 0).unwrap();
        assert!(again.is_empty());
        assert_eq!(s.migrations_total, 1);
    }

    #[test]
    #[should_panic(expected = "more shards")]
    fn rejects_more_shards_than_gpus() {
        let _ = ShardSet::new(&config(2, 3));
    }

    fn fleet_config(spec: &str, shards: usize) -> DaemonConfig {
        let fleet = FleetSpec::parse(spec).unwrap();
        DaemonConfig {
            num_gpus: fleet.total_gpus(),
            hardware: fleet.classes()[0].0.clone(),
            fleet: Some(fleet),
            shards,
            workers: 1,
            ..DaemonConfig::default()
        }
    }

    #[test]
    fn fleet_partition_preserves_class_composition() {
        // 5 A100 + 3 H100 over 2 shards: each class split by largest
        // remainder → shard 0 gets [3, 2], shard 1 gets [2, 1].
        let set = ShardSet::new(&fleet_config("a100:5,h100:3", 2));
        assert_eq!(set.total_gpus(), 8);
        assert_eq!(set.fleet().spec_string(), "a100-80gb:5,h100-80gb:3");
        let mut per_class_total = [0usize; 2];
        let mut expected_offset = 0usize;
        for shard in set.shards() {
            assert_eq!(shard.gpu_offset, expected_offset);
            let s = shard.state.lock().unwrap();
            assert_eq!(s.cluster.num_classes(), 2, "global class table on every shard");
            for stats in s.cluster.per_class_stats().iter().enumerate() {
                per_class_total[stats.0] += stats.1.gpus;
            }
            expected_offset += s.cluster.num_gpus();
        }
        assert_eq!(per_class_total, [5, 3], "no GPU lost or duplicated per class");
        let sizes: Vec<usize> = set
            .shards()
            .iter()
            .map(|s| s.state.lock().unwrap().cluster.num_gpus())
            .collect();
        assert_eq!(sizes, vec![5, 3]);
    }

    #[test]
    #[should_panic(expected = "composition-preserving")]
    fn rejects_partitions_that_empty_a_shard() {
        // Two 1-GPU classes over 2 shards: both extras land on shard 0,
        // leaving shard 1 with no GPUs at all.
        let _ = ShardSet::new(&fleet_config("a100:1,h100:1", 2));
    }

    #[test]
    fn fleet_defrag_sweep_stays_in_class() {
        use crate::mig::Placement;
        let set = ShardSet::new(&fleet_config("a100:2,a100-40gb:2", 1));
        let shard = set.shard(0).unwrap();
        let mut s = shard.state.lock().unwrap();
        // Misplace a 1g on each class's first GPU (blocking 4g anchors).
        s.cluster
            .allocate(
                WorkloadId(0),
                Placement { gpu: 0, profile: Profile::P1g10gb, index: 1 },
            )
            .unwrap();
        s.cluster
            .allocate(
                WorkloadId(1),
                Placement { gpu: 2, profile: Profile::P1g10gb, index: 1 },
            )
            .unwrap();
        let plan = s.defrag_sweep(0.0, 16, 0).unwrap();
        assert!(!plan.is_empty());
        for mv in &plan.moves {
            assert_eq!(
                s.cluster.class_of(mv.from.gpu),
                s.cluster.class_of(mv.to.gpu),
                "daemon sweep crossed device classes: {mv:?}"
            );
        }
        assert_eq!(s.migrations_total, plan.moves.len() as u64);
    }

    #[test]
    fn default_config_is_single_shard() {
        assert_eq!(DaemonConfig::default().shards, 1);
    }

    #[test]
    fn estimator_config_seeds_every_shard_scheduler() {
        use crate::sched::SchedulerKind;
        use crate::workload::EstimatorConfig;
        // Each shard owns its own estimator instance (shard-local, behind
        // the shard mutex) and all of them start from the CLI seed.
        let mut cfg = config(4, 2);
        cfg.scheduler = SchedulerKind::MfiExp;
        cfg.estimator = Some(EstimatorConfig {
            decay_slots: 128,
            seed_counts: Some([3, 0, 0, 0, 0, 1]),
        });
        let set = ShardSet::new(&cfg);
        for shard in set.shards() {
            let s = shard.state.lock().unwrap();
            let mix = s.scheduler.estimator().expect("MFI-EXP exposes its estimator");
            assert!(!mix.is_empty(), "seeded mix on shard {}", shard.index);
            assert_eq!(mix.decay_slots(), 128);
        }
        // Distribution-agnostic schedulers ignore the config entirely.
        let mut cfg = config(4, 2);
        cfg.estimator = Some(EstimatorConfig::default());
        let set = ShardSet::new(&cfg);
        for shard in set.shards() {
            let s = shard.state.lock().unwrap();
            assert!(s.scheduler.estimator().is_none());
        }
    }
}
