//! Readiness polling for the event-loop serve model, with no
//! dependencies beyond the platform libc that `std` already links.
//!
//! Linux gets an epoll-backed implementation (O(ready) wakeups,
//! level-triggered so the reactor never has to drain-until-WouldBlock to
//! stay correct); every other unix falls back to poll(2), which is
//! O(registered) per wait but behaviorally identical at this API. The
//! reactor is written against this module's [`Poller`] alone and cannot
//! tell the two apart.
//!
//! Level-triggered semantics are a deliberate choice: a socket that
//! still has unread bytes (or writable space) keeps reporting ready, so
//! a reactor bug that forgets to finish a read shows up as a busy loop
//! in profiling rather than as a silently hung connection.

#![allow(clippy::unnecessary_cast)] // libc types differ across platforms

use std::io;
use std::os::unix::io::RawFd;
use std::time::Duration;

/// Caller wants readability notifications.
pub(crate) const INTEREST_READ: u8 = 1;
/// Caller wants writability notifications.
pub(crate) const INTEREST_WRITE: u8 = 2;

/// One readiness notification: the token passed at registration plus
/// what the fd is ready for. Error/hangup conditions are folded into
/// both flags — the reactor discovers the specifics from the subsequent
/// read/write returning 0/`Err`, same as with blocking sockets.
#[derive(Clone, Copy, Debug)]
pub(crate) struct Event {
    pub token: usize,
    pub readable: bool,
    pub writable: bool,
}

/// A level-triggered readiness poller over raw fds.
///
/// Callers register an fd with a `token` and an interest mask, then
/// [`Poller::wait`] for events. Tokens are opaque to the poller; the
/// reactor uses `0` for the listener and `index + 1` for connections.
pub(crate) struct Poller {
    sys: sys::Poller,
}

impl Poller {
    pub fn new() -> io::Result<Self> {
        Ok(Self { sys: sys::Poller::new()? })
    }

    /// Start watching `fd`. One registration per fd; re-registering an
    /// already-watched fd is an error on epoll (EEXIST).
    pub fn register(&mut self, fd: RawFd, token: usize, interest: u8) -> io::Result<()> {
        self.sys.register(fd, token, interest)
    }

    /// Change the interest mask (and token) of a watched fd.
    pub fn reregister(&mut self, fd: RawFd, token: usize, interest: u8) -> io::Result<()> {
        self.sys.reregister(fd, token, interest)
    }

    /// Stop watching `fd`. Must be called before the fd is closed —
    /// closing first leaks the registration on poll(2) (and can misfire
    /// on epoll if the fd number is recycled).
    pub fn deregister(&mut self, fd: RawFd) -> io::Result<()> {
        self.sys.deregister(fd)
    }

    /// Block until at least one event or the timeout (`None` = forever).
    /// Events are appended to `events` (cleared first). EINTR is retried
    /// internally; a timeout expiry is NOT an error — it returns with
    /// `events` empty.
    pub fn wait(&mut self, events: &mut Vec<Event>, timeout: Option<Duration>) -> io::Result<()> {
        events.clear();
        self.sys.wait(events, timeout)
    }
}

/// Round a `Duration` up to whole milliseconds for the syscall timeout
/// arguments. Rounding DOWN would turn sub-millisecond deadlines into a
/// zero timeout — i.e. a busy spin until the deadline actually passes.
fn timeout_ms(timeout: Option<Duration>) -> i32 {
    match timeout {
        None => -1,
        Some(d) => {
            let ms = d.as_millis() + u128::from(d.as_nanos() % 1_000_000 != 0);
            ms.min(i32::MAX as u128) as i32
        }
    }
}

#[cfg(target_os = "linux")]
mod sys {
    //! epoll backend. The fd itself keys the interest table, so the
    //! token rides along in `epoll_event.data` and comes back verbatim.

    use std::io;
    use std::os::unix::io::RawFd;
    use std::time::Duration;

    use super::{Event, INTEREST_READ, INTEREST_WRITE};

    const EPOLL_CLOEXEC: i32 = 0x80000;
    const EPOLL_CTL_ADD: i32 = 1;
    const EPOLL_CTL_DEL: i32 = 2;
    const EPOLL_CTL_MOD: i32 = 3;

    const EPOLLIN: u32 = 0x1;
    const EPOLLOUT: u32 = 0x4;
    const EPOLLERR: u32 = 0x8;
    const EPOLLHUP: u32 = 0x10;
    const EPOLLRDHUP: u32 = 0x2000;

    // x86-64 is the one ABI where the kernel struct is packed.
    #[cfg_attr(target_arch = "x86_64", repr(C, packed))]
    #[cfg_attr(not(target_arch = "x86_64"), repr(C))]
    #[derive(Clone, Copy)]
    struct EpollEvent {
        events: u32,
        data: u64,
    }

    extern "C" {
        fn epoll_create1(flags: i32) -> i32;
        fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
        fn epoll_wait(epfd: i32, events: *mut EpollEvent, maxevents: i32, timeout: i32) -> i32;
        fn close(fd: i32) -> i32;
    }

    fn cvt(ret: i32) -> io::Result<i32> {
        if ret < 0 {
            Err(io::Error::last_os_error())
        } else {
            Ok(ret)
        }
    }

    fn mask(interest: u8) -> u32 {
        let mut m = EPOLLRDHUP;
        if interest & INTEREST_READ != 0 {
            m |= EPOLLIN;
        }
        if interest & INTEREST_WRITE != 0 {
            m |= EPOLLOUT;
        }
        m
    }

    pub(super) struct Poller {
        epfd: RawFd,
        buf: Vec<EpollEvent>,
    }

    impl Poller {
        pub fn new() -> io::Result<Self> {
            // SAFETY: plain syscall, no pointers involved.
            let epfd = cvt(unsafe { epoll_create1(EPOLL_CLOEXEC) })?;
            Ok(Self { epfd, buf: vec![EpollEvent { events: 0, data: 0 }; 256] })
        }

        fn ctl(&self, op: i32, fd: RawFd, token: usize, interest: u8) -> io::Result<()> {
            let mut ev = EpollEvent { events: mask(interest), data: token as u64 };
            // SAFETY: `ev` outlives the call; the kernel copies it out.
            cvt(unsafe { epoll_ctl(self.epfd, op, fd, &mut ev) })?;
            Ok(())
        }

        pub fn register(&mut self, fd: RawFd, token: usize, interest: u8) -> io::Result<()> {
            self.ctl(EPOLL_CTL_ADD, fd, token, interest)
        }

        pub fn reregister(&mut self, fd: RawFd, token: usize, interest: u8) -> io::Result<()> {
            self.ctl(EPOLL_CTL_MOD, fd, token, interest)
        }

        pub fn deregister(&mut self, fd: RawFd) -> io::Result<()> {
            // Pre-2.6.9 kernels dereference the event argument even for
            // DEL, so pass a real (ignored) struct rather than null.
            self.ctl(EPOLL_CTL_DEL, fd, 0, 0)
        }

        pub fn wait(&mut self, events: &mut Vec<Event>, timeout: Option<Duration>) -> io::Result<()> {
            let n = loop {
                // SAFETY: buf is a live allocation of `buf.len()` structs.
                let ret = unsafe {
                    epoll_wait(
                        self.epfd,
                        self.buf.as_mut_ptr(),
                        self.buf.len() as i32,
                        super::timeout_ms(timeout),
                    )
                };
                match cvt(ret) {
                    Ok(n) => break n as usize,
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                    Err(e) => return Err(e),
                }
            };
            for ev in &self.buf[..n] {
                // Copy out of the (possibly packed) struct before use.
                let bits = ev.events;
                let data = ev.data;
                events.push(Event {
                    token: data as usize,
                    readable: bits & (EPOLLIN | EPOLLRDHUP | EPOLLHUP | EPOLLERR) != 0,
                    writable: bits & (EPOLLOUT | EPOLLHUP | EPOLLERR) != 0,
                });
            }
            Ok(())
        }
    }

    impl Drop for Poller {
        fn drop(&mut self) {
            // SAFETY: epfd is owned by this struct and closed once.
            unsafe { close(self.epfd) };
        }
    }
}

#[cfg(all(unix, not(target_os = "linux")))]
mod sys {
    //! poll(2) backend for the other unixes. The registration table is a
    //! flat vec — fine at daemon connection counts, and the API keeps
    //! the door open for kqueue later without touching the reactor.

    use std::io;
    use std::os::unix::io::RawFd;
    use std::time::Duration;

    use super::{Event, INTEREST_READ, INTEREST_WRITE};

    const POLLIN: i16 = 0x1;
    const POLLOUT: i16 = 0x4;

    #[repr(C)]
    #[derive(Clone, Copy)]
    struct PollFd {
        fd: i32,
        events: i16,
        revents: i16,
    }

    type NfdsT = u32;

    extern "C" {
        fn poll(fds: *mut PollFd, nfds: NfdsT, timeout: i32) -> i32;
    }

    fn mask(interest: u8) -> i16 {
        let mut m = 0;
        if interest & INTEREST_READ != 0 {
            m |= POLLIN;
        }
        if interest & INTEREST_WRITE != 0 {
            m |= POLLOUT;
        }
        m
    }

    pub(super) struct Poller {
        /// `(fd, token, interest mask)` per registered fd.
        entries: Vec<(RawFd, usize, i16)>,
        fds: Vec<PollFd>,
    }

    impl Poller {
        pub fn new() -> io::Result<Self> {
            Ok(Self { entries: Vec::new(), fds: Vec::new() })
        }

        fn position(&self, fd: RawFd) -> Option<usize> {
            self.entries.iter().position(|&(f, _, _)| f == fd)
        }

        pub fn register(&mut self, fd: RawFd, token: usize, interest: u8) -> io::Result<()> {
            if self.position(fd).is_some() {
                return Err(io::Error::new(
                    io::ErrorKind::AlreadyExists,
                    "fd already registered",
                ));
            }
            self.entries.push((fd, token, mask(interest)));
            Ok(())
        }

        pub fn reregister(&mut self, fd: RawFd, token: usize, interest: u8) -> io::Result<()> {
            match self.position(fd) {
                Some(i) => {
                    self.entries[i] = (fd, token, mask(interest));
                    Ok(())
                }
                None => Err(io::Error::new(io::ErrorKind::NotFound, "fd not registered")),
            }
        }

        pub fn deregister(&mut self, fd: RawFd) -> io::Result<()> {
            match self.position(fd) {
                Some(i) => {
                    self.entries.swap_remove(i);
                    Ok(())
                }
                None => Err(io::Error::new(io::ErrorKind::NotFound, "fd not registered")),
            }
        }

        pub fn wait(&mut self, events: &mut Vec<Event>, timeout: Option<Duration>) -> io::Result<()> {
            self.fds.clear();
            self.fds.extend(
                self.entries.iter().map(|&(fd, _, m)| PollFd { fd, events: m, revents: 0 }),
            );
            let n = loop {
                // SAFETY: fds is a live allocation of `fds.len()` structs.
                let ret = unsafe {
                    poll(self.fds.as_mut_ptr(), self.fds.len() as NfdsT, super::timeout_ms(timeout))
                };
                if ret >= 0 {
                    break ret as usize;
                }
                let e = io::Error::last_os_error();
                if e.kind() != io::ErrorKind::Interrupted {
                    return Err(e);
                }
            };
            if n == 0 {
                return Ok(());
            }
            for (slot, &(_, token, _)) in self.fds.iter().zip(&self.entries) {
                let r = slot.revents;
                if r == 0 {
                    continue;
                }
                // POLLERR/POLLHUP/POLLNVAL are reported regardless of the
                // requested mask; fold them into both directions so the
                // reactor's next read/write surfaces the real error.
                let exceptional = r & !(POLLIN | POLLOUT) != 0;
                events.push(Event {
                    token,
                    readable: r & POLLIN != 0 || exceptional,
                    writable: r & POLLOUT != 0 || exceptional,
                });
            }
            Ok(())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::{TcpListener, TcpStream};
    use std::os::unix::io::AsRawFd;

    #[test]
    fn listener_becomes_readable_on_connect() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut poller = Poller::new().unwrap();
        poller.register(listener.as_raw_fd(), 7, INTEREST_READ).unwrap();

        let mut events = Vec::new();
        poller.wait(&mut events, Some(Duration::from_millis(10))).unwrap();
        assert!(events.is_empty(), "no events before any client connects");

        let _client = TcpStream::connect(addr).unwrap();
        poller.wait(&mut events, Some(Duration::from_secs(5))).unwrap();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].token, 7);
        assert!(events[0].readable);

        // Level-triggered: the pending connection keeps the fd readable.
        poller.wait(&mut events, Some(Duration::from_millis(100))).unwrap();
        assert_eq!(events.len(), 1, "unaccepted connection stays readable");

        poller.deregister(listener.as_raw_fd()).unwrap();
        let _client2 = TcpStream::connect(addr).unwrap();
        poller.wait(&mut events, Some(Duration::from_millis(50))).unwrap();
        assert!(events.is_empty(), "deregistered fd reports nothing");
    }

    #[test]
    fn connected_stream_reports_writable_and_reregister_narrows() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (served, _) = listener.accept().unwrap();

        let mut poller = Poller::new().unwrap();
        poller
            .register(served.as_raw_fd(), 3, INTEREST_READ | INTEREST_WRITE)
            .unwrap();
        let mut events = Vec::new();
        poller.wait(&mut events, Some(Duration::from_secs(5))).unwrap();
        assert_eq!(events.len(), 1);
        assert!(events[0].writable, "fresh socket has send-buffer space");
        assert!(!events[0].readable, "nothing sent yet");

        // Narrow to read interest: an idle readable-less socket goes quiet.
        poller.reregister(served.as_raw_fd(), 3, INTEREST_READ).unwrap();
        poller.wait(&mut events, Some(Duration::from_millis(50))).unwrap();
        assert!(events.is_empty());

        use std::io::Write as _;
        (&client).write_all(b"ping").unwrap();
        poller.wait(&mut events, Some(Duration::from_secs(5))).unwrap();
        assert_eq!(events.len(), 1);
        assert!(events[0].readable);

        poller.deregister(served.as_raw_fd()).unwrap();
    }

    #[test]
    fn empty_wait_times_out_without_error() {
        let mut poller = Poller::new().unwrap();
        let mut events = vec![Event { token: 0, readable: false, writable: false }];
        let start = std::time::Instant::now();
        poller.wait(&mut events, Some(Duration::from_millis(20))).unwrap();
        assert!(events.is_empty(), "wait() clears stale events");
        assert!(start.elapsed() >= Duration::from_millis(20));
    }

    #[test]
    fn sub_millisecond_timeouts_round_up() {
        assert_eq!(timeout_ms(None), -1);
        assert_eq!(timeout_ms(Some(Duration::ZERO)), 0);
        assert_eq!(timeout_ms(Some(Duration::from_micros(1))), 1);
        assert_eq!(timeout_ms(Some(Duration::from_millis(250))), 250);
        assert_eq!(timeout_ms(Some(Duration::from_nanos(1_000_001))), 2);
        assert_eq!(timeout_ms(Some(Duration::from_secs(1 << 40))), i32::MAX);
    }
}
