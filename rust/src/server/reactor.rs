//! The event-loop serve model: N loops, each owning a [`Poller`] with the
//! shared listener registered, multiplexing every accepted connection
//! through a non-blocking state machine instead of pinning a thread per
//! connection.
//!
//! # Architecture
//!
//! * **No accept thread, no waker pipe.** Each loop registers its own
//!   clone of the (non-blocking) listener at token 0. Readiness is
//!   level-triggered, so whichever loop wakes first accepts; the rest see
//!   `WouldBlock` and move on. At daemon loop counts (≤ 16) the thundering
//!   herd costs less than the cross-thread handoff it replaces. A new
//!   connection lands on the loop that accepted it and never migrates.
//! * **Connection state machine.** `Open` (reading requests, writing
//!   responses, keep-alive) → `Closing` (final response queued, flush
//!   then half-close) → `Draining` (discard whatever the peer pipelined
//!   past the last response until EOF, a deadline, or a byte cap — closing
//!   with unread bytes makes the kernel RST the connection, which can
//!   destroy the final response before the client reads it).
//! * **Allocation discipline.** Each connection carries reusable read and
//!   write buffers. Requests are parsed in place by
//!   [`parse_request_bytes`]; responses are rendered by
//!   [`Response::render_into`] appending onto the write buffer, so a
//!   kept-alive connection reaches a steady state with zero allocation
//!   per request.
//! * **Backpressure.** When a connection's unflushed response backlog
//!   passes [`WRITE_HIGHWATER`], the loop stops reading (and parsing) for
//!   that connection and narrows its interest to writability until the
//!   backlog drains — a slow reader cannot balloon either buffer.
//!
//! The HTTP grammar, dispatch layer, metrics accounting (requests counted
//! at dispatch, responses only after the bytes reach the socket — see
//! [`super::metrics`]) and idle/keep-alive limits are shared with the
//! threadpool model byte for byte; `rust/src/server/http.rs` pins the two
//! request parsers against each other differentially.
//!
//! [`Response::render_into`]: super::http::Response::render_into

use std::collections::VecDeque;
use std::io::{self, Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::api;
use super::daemon::{next_conn_id, ConnLimits, REQUEST_TIMEOUT};
use super::http::{parse_request_bytes, Parse};
use super::metrics;
use super::poller::{Event, Poller, INTEREST_READ, INTEREST_WRITE};
use super::shard::ShardSet;
use crate::obs::log::RateLimited;

/// Poller token of the shared listener; connections get `slot + 1`.
const LISTENER_TOKEN: usize = 0;

/// Upper bound on one poller wait. Deadlines usually wake the loop
/// sooner; this caps how long a lost shutdown wake can linger.
const WAIT_CAP: Duration = Duration::from_millis(250);

/// Unflushed-response backlog at which a connection stops being read.
const WRITE_HIGHWATER: usize = 64 * 1024;

/// Wall-clock bound on the post-close drain of a connection.
const DRAIN_WINDOW: Duration = Duration::from_millis(500);

/// Byte bound on the post-close drain of a connection.
const DRAIN_CAP: usize = 64 * 1024;

/// Stack chunk size for socket reads.
const READ_CHUNK: usize = 16 * 1024;

/// Buffer capacity above which an emptied connection buffer is shrunk,
/// so one oversized request doesn't pin memory for the connection's
/// remaining lifetime.
const SHRINK_ABOVE: usize = 512 * 1024;

/// Spawn `loops` event-loop threads serving `listener` until `shutdown`
/// is raised (each loop rechecks the flag at least every [`WAIT_CAP`];
/// the daemon's wake connection makes that prompt).
pub fn serve(
    listener: TcpListener,
    shards: Arc<ShardSet>,
    shutdown: Arc<AtomicBool>,
    loops: usize,
) -> io::Result<Vec<JoinHandle<()>>> {
    listener.set_nonblocking(true)?;
    let mut handles = Vec::with_capacity(loops);
    for i in 0..loops.max(1) {
        let listener = listener.try_clone()?;
        let shards = Arc::clone(&shards);
        let shutdown = Arc::clone(&shutdown);
        handles.push(std::thread::Builder::new().name(format!("migsched-loop-{i}")).spawn(
            move || {
                if let Err(e) = event_loop(listener, shards, shutdown) {
                    crate::log_warn!("event loop {i} exited: {e}");
                }
            },
        )?);
    }
    Ok(handles)
}

enum State {
    /// Serving requests; keep-alive still possible.
    Open,
    /// Final response queued; flush, then half-close into `Draining`.
    Closing,
    /// Response flushed and write side shut; discarding peer bytes until
    /// EOF, the drain deadline, or [`DRAIN_CAP`].
    Draining,
}

struct Conn {
    stream: TcpStream,
    id: u64,
    state: State,
    /// Unparsed request bytes (reused across requests).
    read_buf: Vec<u8>,
    /// Rendered-but-unflushed response bytes (reused across requests).
    write_buf: Vec<u8>,
    /// Prefix of `write_buf` already written to the socket.
    written: usize,
    /// End offset in `write_buf` of each queued response, in order;
    /// `responses_total` increments as `written` crosses each one.
    pending: VecDeque<usize>,
    served: usize,
    /// Next timeout: first-request deadline at accept, idle deadline
    /// between kept-alive requests, drain deadline while `Draining`.
    deadline: Instant,
    /// Peer sent EOF (their write side is closed).
    read_closed: bool,
    /// Interest mask currently registered with the poller.
    interest: u8,
    /// Bytes discarded so far while `Draining`.
    drained: usize,
}

impl Conn {
    fn new(stream: TcpStream, id: u64) -> Self {
        Self {
            stream,
            id,
            state: State::Open,
            read_buf: Vec::with_capacity(READ_CHUNK),
            write_buf: Vec::with_capacity(4096),
            written: 0,
            pending: VecDeque::new(),
            served: 0,
            deadline: Instant::now() + REQUEST_TIMEOUT,
            read_closed: false,
            interest: INTEREST_READ,
            drained: 0,
        }
    }

    fn backlog(&self) -> usize {
        self.write_buf.len() - self.written
    }

    fn desired_interest(&self) -> u8 {
        match self.state {
            State::Draining => INTEREST_READ,
            // Only reaches interest selection with backlog > 0 (a fully
            // flushed Closing connection transitions out in `drive`).
            State::Closing => INTEREST_WRITE,
            State::Open => {
                if self.backlog() >= WRITE_HIGHWATER {
                    INTEREST_WRITE
                } else if self.backlog() > 0 {
                    INTEREST_READ | INTEREST_WRITE
                } else {
                    INTEREST_READ
                }
            }
        }
    }
}

fn event_loop(
    listener: TcpListener,
    shards: Arc<ShardSet>,
    shutdown: Arc<AtomicBool>,
) -> io::Result<()> {
    let mut poller = Poller::new()?;
    poller.register(listener.as_raw_fd(), LISTENER_TOKEN, INTEREST_READ)?;
    let limits = shards.limits();
    // Connection slots: token = slot + 1. Freed slots are recycled, and a
    // slot's events can only be stale for a connection closed while
    // handling its own (sole) event in the same batch, so no generation
    // counter is needed.
    let mut conns: Vec<Option<Conn>> = Vec::new();
    let mut free: Vec<usize> = Vec::new();
    let mut events: Vec<Event> = Vec::new();
    loop {
        let now = Instant::now();
        let mut timeout = WAIT_CAP;
        for c in conns.iter().flatten() {
            timeout = timeout.min(c.deadline.saturating_duration_since(now));
        }
        poller.wait(&mut events, Some(timeout))?;
        if shutdown.load(Ordering::SeqCst) {
            break;
        }
        let mut i = 0;
        while i < events.len() {
            let ev = events[i];
            i += 1;
            if ev.token == LISTENER_TOKEN {
                accept_burst(&listener, &mut poller, &mut conns, &mut free, &shards);
                continue;
            }
            let slot = ev.token - 1;
            let Some(conn) = conns.get_mut(slot).and_then(Option::as_mut) else {
                continue;
            };
            if !drive(conn, &shards, &limits, &shutdown, ev.readable) {
                close_conn(&mut poller, &mut conns, &mut free, slot, &shards);
                continue;
            }
            let conn = conns[slot].as_mut().expect("slot still live");
            let want = conn.desired_interest();
            if want != conn.interest {
                let fd = conn.stream.as_raw_fd();
                if poller.reregister(fd, ev.token, want).is_err() {
                    close_conn(&mut poller, &mut conns, &mut free, slot, &shards);
                } else {
                    conn.interest = want;
                }
            }
        }
        // Deadline sweep: first-request timeout, keep-alive idle timeout
        // and the drain window all live in `Conn::deadline`.
        let now = Instant::now();
        let mut slot = 0;
        while slot < conns.len() {
            if matches!(&conns[slot], Some(c) if now >= c.deadline) {
                close_conn(&mut poller, &mut conns, &mut free, slot, &shards);
            }
            slot += 1;
        }
    }
    // Shutdown: hard-close everything still open so the open-connection
    // gauge balances. In-flight responses already flushed opportunistically
    // on their last drive.
    let mut slot = 0;
    while slot < conns.len() {
        close_conn(&mut poller, &mut conns, &mut free, slot, &shards);
        slot += 1;
    }
    Ok(())
}

/// Accept until the (shared, level-triggered) listener runs dry.
fn accept_burst(
    listener: &TcpListener,
    poller: &mut Poller,
    conns: &mut Vec<Option<Conn>>,
    free: &mut Vec<usize>,
    shards: &ShardSet,
) {
    loop {
        match listener.accept() {
            Ok((stream, peer)) => {
                if stream.set_nonblocking(true).is_err() {
                    continue; // drop the connection; nothing to undo yet
                }
                let _ = stream.set_nodelay(true);
                let m = shards.metrics();
                m.connections_total.inc();
                m.connections_open.inc();
                let id = next_conn_id();
                crate::log_debug!("conn={id} accepted from {peer}");
                let slot = free.pop().unwrap_or_else(|| {
                    conns.push(None);
                    conns.len() - 1
                });
                let conn = Conn::new(stream, id);
                if let Err(e) = poller.register(conn.stream.as_raw_fd(), slot + 1, INTEREST_READ) {
                    crate::log_warn!("conn={id} register with poller: {e}");
                    m.connections_open.dec();
                    free.push(slot);
                    continue;
                }
                conns[slot] = Some(conn);
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => {
                // A dying listener repeats the same error at poll speed;
                // log once per window (mirrors the threadpool model).
                static ACCEPT_WARN: RateLimited = RateLimited::new(Duration::from_secs(5));
                let msg = format!("accept error: {e}");
                match ACCEPT_WARN.should_log(&msg) {
                    Some(0) => crate::log_warn!("{msg}"),
                    Some(dropped) => {
                        crate::log_warn!("{msg} ({dropped} identical warning(s) suppressed)")
                    }
                    None => {}
                }
                break;
            }
        }
    }
}

fn close_conn(
    poller: &mut Poller,
    conns: &mut [Option<Conn>],
    free: &mut Vec<usize>,
    slot: usize,
    shards: &ShardSet,
) {
    if let Some(conn) = conns[slot].take() {
        let _ = poller.deregister(conn.stream.as_raw_fd());
        let _ = conn.stream.shutdown(Shutdown::Both);
        shards.metrics().connections_open.dec();
        crate::log_debug!("conn={} closed after {} request(s)", conn.id, conn.served);
        free.push(slot);
    }
}

/// Advance one connection as far as current readiness allows: read, then
/// alternate parse/dispatch/render and flush until no further progress.
/// Returns `false` when the connection should be closed now.
fn drive(
    conn: &mut Conn,
    shards: &ShardSet,
    limits: &ConnLimits,
    shutdown: &AtomicBool,
    readable: bool,
) -> bool {
    if matches!(conn.state, State::Draining) {
        return drain(conn, readable);
    }

    if readable && !conn.read_closed && matches!(conn.state, State::Open) {
        let mut chunk = [0u8; READ_CHUNK];
        loop {
            match conn.stream.read(&mut chunk) {
                Ok(0) => {
                    conn.read_closed = true;
                    break;
                }
                Ok(n) => {
                    conn.read_buf.extend_from_slice(&chunk[..n]);
                    // Level-triggered readiness will re-report what the
                    // kernel still holds; give backpressure a chance to
                    // engage rather than inhaling without bound.
                    if conn.read_buf.len() >= WRITE_HIGHWATER {
                        break;
                    }
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => {
                    crate::log_debug!("conn={} read: {e}", conn.id);
                    return false;
                }
            }
        }
    }

    // Alternate pump and flush: flushing can clear backpressure that
    // pump deferred to, so loop until a pass handles no request.
    loop {
        let progressed = match pump(conn, shards, limits, shutdown) {
            Ok(p) => p,
            Err(()) => return false,
        };
        if flush(conn, shards).is_err() {
            return false;
        }
        if !progressed {
            break;
        }
    }

    if conn.backlog() == 0 && matches!(conn.state, State::Closing) {
        // Final response fully delivered to the kernel: half-close and
        // drain (see module docs on why closing with unread bytes loses
        // the response), unless the peer already finished sending.
        let _ = conn.stream.shutdown(Shutdown::Write);
        if conn.read_closed {
            return false;
        }
        conn.state = State::Draining;
        conn.deadline = Instant::now() + DRAIN_WINDOW;
        conn.read_buf.clear();
    }
    true
}

/// `Draining` turn: discard peer bytes. Returns `false` once the peer
/// reaches EOF, errors, or overruns the byte cap.
fn drain(conn: &mut Conn, readable: bool) -> bool {
    if !readable {
        return true;
    }
    let mut sink = [0u8; READ_CHUNK];
    loop {
        match conn.stream.read(&mut sink) {
            Ok(0) => return false,
            Ok(n) => {
                conn.drained += n;
                if conn.drained > DRAIN_CAP {
                    return false;
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => return true,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(_) => return false,
        }
    }
}

/// Parse, dispatch and render every complete request currently buffered
/// (pipelining), respecting write backpressure. `Ok(true)` if at least
/// one request was handled; `Err(())` to close immediately.
fn pump(
    conn: &mut Conn,
    shards: &ShardSet,
    limits: &ConnLimits,
    shutdown: &AtomicBool,
) -> Result<bool, ()> {
    let m = shards.metrics();
    let mut progressed = false;
    while matches!(conn.state, State::Open) && conn.backlog() < WRITE_HIGHWATER {
        match parse_request_bytes(&conn.read_buf, conn.read_closed) {
            Parse::Incomplete => break,
            Parse::Eof => {
                // Peer is done sending and owes us nothing: close as soon
                // as everything queued has been flushed (immediately, if
                // nothing is).
                if conn.backlog() == 0 {
                    return Err(());
                }
                conn.state = State::Closing;
                break;
            }
            Parse::Done { request, consumed } => {
                conn.read_buf.drain(..consumed);
                let started = Instant::now();
                conn.served += 1;
                crate::log_debug!(
                    "conn={} req={} {} {}",
                    conn.id,
                    conn.served,
                    request.method,
                    request.path
                );
                let keep = request.keep_alive
                    && conn.served < limits.max_requests_per_conn
                    && !shutdown.load(Ordering::SeqCst);
                let response = api::dispatch(&request, shards);
                // Counted before the response bytes are queued; together
                // with responses_total counting after the socket write,
                // any concurrent scrape sees requests >= responses.
                let route = metrics::route_index(&request.method, &request.segments());
                m.record_request(route, response.status, started.elapsed());
                response.render_into(&mut conn.write_buf, keep);
                conn.pending.push_back(conn.write_buf.len());
                crate::log_debug!(
                    "conn={} req={} -> {} ({} bytes, {:?})",
                    conn.id,
                    conn.served,
                    response.status,
                    response.body.len(),
                    started.elapsed()
                );
                progressed = true;
                if keep {
                    conn.deadline = Instant::now() + limits.idle_timeout;
                } else {
                    conn.state = State::Closing;
                }
            }
            Parse::Bad(response) => {
                // Malformed input: answer and hang up; whatever follows
                // in the buffer is unframeable. No parsed route or
                // meaningful handling latency exists, so it counts
                // against the catch-all route at zero elapsed.
                m.record_request(metrics::ROUTE_OTHER, response.status, Duration::ZERO);
                response.render_into(&mut conn.write_buf, false);
                conn.pending.push_back(conn.write_buf.len());
                conn.read_buf.clear();
                conn.state = State::Closing;
                progressed = true;
            }
        }
    }
    Ok(progressed)
}

/// Write as much of the response backlog as the socket accepts,
/// crediting `responses_total` for each response fully handed to the
/// kernel. `Err(())` on a dead socket.
fn flush(conn: &mut Conn, shards: &ShardSet) -> Result<(), ()> {
    if conn.backlog() == 0 {
        return Ok(());
    }
    let m = shards.metrics();
    loop {
        match conn.stream.write(&conn.write_buf[conn.written..]) {
            Ok(0) => return Err(()),
            Ok(n) => {
                conn.written += n;
                while conn.pending.front().is_some_and(|&end| conn.written >= end) {
                    conn.pending.pop_front();
                    m.responses_total.inc();
                }
                if conn.backlog() == 0 {
                    break;
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => {
                crate::log_debug!("conn={} write response: {e}", conn.id);
                return Err(());
            }
        }
    }
    if conn.backlog() == 0 {
        conn.write_buf.clear();
        conn.written = 0;
        if conn.write_buf.capacity() > SHRINK_ABOVE {
            conn.write_buf.shrink_to(WRITE_HIGHWATER);
        }
        if conn.read_buf.is_empty() && conn.read_buf.capacity() > SHRINK_ABOVE {
            conn.read_buf.shrink_to(READ_CHUNK);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::daemon::{Daemon, DaemonConfig};

    fn start(loops: usize) -> (std::net::SocketAddr, Arc<AtomicBool>, Vec<JoinHandle<()>>) {
        let daemon = Daemon::new(DaemonConfig {
            num_gpus: 4,
            workers: loops,
            ..DaemonConfig::default()
        });
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let shutdown = Arc::new(AtomicBool::new(false));
        let handles = serve(listener, daemon.shards(), Arc::clone(&shutdown), loops).unwrap();
        (addr, shutdown, handles)
    }

    fn stop(addr: std::net::SocketAddr, shutdown: Arc<AtomicBool>, handles: Vec<JoinHandle<()>>) {
        shutdown.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect_timeout(&addr, Duration::from_secs(1));
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn serves_pipelined_requests_and_honors_connection_close() {
        let (addr, shutdown, handles) = start(2);
        let mut stream = TcpStream::connect(addr).unwrap();
        stream
            .write_all(
                b"GET /v1/healthz HTTP/1.1\r\n\r\n\
                  GET /v1/version HTTP/1.1\r\nConnection: close\r\n\r\n",
            )
            .unwrap();
        let mut out = String::new();
        stream.read_to_string(&mut out).unwrap();
        assert_eq!(out.matches("HTTP/1.1 200 OK").count(), 2, "{out}");
        assert!(out.contains("\"status\":\"ok\""), "{out}");
        assert!(out.contains("\"version\""), "{out}");
        stop(addr, shutdown, handles);
    }

    #[test]
    fn serves_a_request_arriving_one_byte_at_a_time() {
        let (addr, shutdown, handles) = start(1);
        let mut stream = TcpStream::connect(addr).unwrap();
        for b in b"GET /v1/healthz HTTP/1.1\r\nConnection: close\r\n\r\n" {
            stream.write_all(&[*b]).unwrap();
            std::thread::sleep(Duration::from_millis(1));
        }
        let mut out = String::new();
        stream.read_to_string(&mut out).unwrap();
        assert!(out.starts_with("HTTP/1.1 200 OK"), "{out}");
        stop(addr, shutdown, handles);
    }

    #[test]
    fn malformed_request_gets_an_error_response_then_close() {
        let (addr, shutdown, handles) = start(1);
        let mut stream = TcpStream::connect(addr).unwrap();
        stream.write_all(b"BROKEN\r\n\r\n").unwrap();
        let mut out = String::new();
        stream.read_to_string(&mut out).unwrap();
        assert!(out.starts_with("HTTP/1.1 400 Bad Request"), "{out}");
        stop(addr, shutdown, handles);
    }
}
