//! Minimal blocking HTTP/1.1 client for the control-plane API — used by
//! the load-generator example, the `migsched trace-replay --remote` mode
//! and the integration tests.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::util::json::Json;

/// A simple per-request-connection HTTP client.
pub struct HttpClient {
    addr: String,
    timeout: Duration,
}

/// A decoded response.
#[derive(Debug, Clone)]
pub struct ClientResponse {
    pub status: u16,
    pub body: String,
}

impl ClientResponse {
    pub fn json(&self) -> Result<Json> {
        Json::parse(&self.body).map_err(|e| anyhow::anyhow!("response JSON: {e}: {}", self.body))
    }

    pub fn is_success(&self) -> bool {
        (200..300).contains(&self.status)
    }
}

impl HttpClient {
    pub fn new(addr: &str) -> Self {
        Self { addr: addr.to_string(), timeout: Duration::from_secs(10) }
    }

    pub fn with_timeout(mut self, timeout: Duration) -> Self {
        self.timeout = timeout;
        self
    }

    pub fn get(&self, path: &str) -> Result<ClientResponse> {
        self.request("GET", path, None)
    }

    pub fn post_json(&self, path: &str, body: &Json) -> Result<ClientResponse> {
        self.request("POST", path, Some(body.to_string_compact()))
    }

    pub fn delete(&self, path: &str) -> Result<ClientResponse> {
        self.request("DELETE", path, None)
    }

    fn request(&self, method: &str, path: &str, body: Option<String>) -> Result<ClientResponse> {
        let mut stream = TcpStream::connect(&self.addr)
            .with_context(|| format!("connecting to {}", self.addr))?;
        stream.set_read_timeout(Some(self.timeout))?;
        stream.set_write_timeout(Some(self.timeout))?;
        let body = body.unwrap_or_default();
        write!(
            stream,
            "{method} {path} HTTP/1.1\r\nHost: {}\r\nContent-Type: application/json\r\n\
             Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
            self.addr,
            body.len()
        )?;
        stream.flush()?;

        let mut raw = Vec::new();
        stream.read_to_end(&mut raw).context("reading response")?;
        let text = String::from_utf8_lossy(&raw);
        let mut parts = text.splitn(2, "\r\n\r\n");
        let head = parts.next().unwrap_or("");
        let body = parts.next().unwrap_or("").to_string();
        let status: u16 = head
            .lines()
            .next()
            .and_then(|l| l.split_whitespace().nth(1))
            .and_then(|s| s.parse().ok())
            .context("malformed status line")?;
        Ok(ClientResponse { status, body })
    }
}

// Live-socket coverage is in rust/tests/server_api.rs (client + daemon
// round-trips on an ephemeral port).
