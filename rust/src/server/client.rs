//! Minimal blocking HTTP/1.1 clients for the control-plane API — used by
//! the load-generator example, the `migsched trace-replay --remote` mode,
//! the daemon benchmark and the integration tests.
//!
//! [`HttpClient`] opens a fresh connection per request (simple, always
//! correct). [`HttpConn`] holds ONE kept-alive connection and frames
//! responses by `Content-Length`, which is what the daemon benchmark and
//! soak tests use to exercise the persistent-connection serving path.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::util::json::Json;

/// A simple per-request-connection HTTP client.
pub struct HttpClient {
    addr: String,
    timeout: Duration,
}

/// A decoded response.
#[derive(Debug, Clone)]
pub struct ClientResponse {
    pub status: u16,
    pub body: String,
}

impl ClientResponse {
    pub fn json(&self) -> Result<Json> {
        Json::parse(&self.body).map_err(|e| anyhow::anyhow!("response JSON: {e}: {}", self.body))
    }

    pub fn is_success(&self) -> bool {
        (200..300).contains(&self.status)
    }
}

impl HttpClient {
    pub fn new(addr: &str) -> Self {
        Self { addr: addr.to_string(), timeout: Duration::from_secs(10) }
    }

    pub fn with_timeout(mut self, timeout: Duration) -> Self {
        self.timeout = timeout;
        self
    }

    pub fn get(&self, path: &str) -> Result<ClientResponse> {
        self.request("GET", path, None)
    }

    pub fn post_json(&self, path: &str, body: &Json) -> Result<ClientResponse> {
        self.request("POST", path, Some(body.to_string_compact()))
    }

    pub fn delete(&self, path: &str) -> Result<ClientResponse> {
        self.request("DELETE", path, None)
    }

    fn request(&self, method: &str, path: &str, body: Option<String>) -> Result<ClientResponse> {
        let mut stream = TcpStream::connect(&self.addr)
            .with_context(|| format!("connecting to {}", self.addr))?;
        stream.set_read_timeout(Some(self.timeout))?;
        stream.set_write_timeout(Some(self.timeout))?;
        let body = body.unwrap_or_default();
        write!(
            stream,
            "{method} {path} HTTP/1.1\r\nHost: {}\r\nContent-Type: application/json\r\n\
             Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
            self.addr,
            body.len()
        )?;
        stream.flush()?;

        let mut raw = Vec::new();
        stream.read_to_end(&mut raw).context("reading response")?;
        let text = String::from_utf8_lossy(&raw);
        let mut parts = text.splitn(2, "\r\n\r\n");
        let head = parts.next().unwrap_or("");
        let body = parts.next().unwrap_or("").to_string();
        let status: u16 = head
            .lines()
            .next()
            .and_then(|l| l.split_whitespace().nth(1))
            .and_then(|s| s.parse().ok())
            .context("malformed status line")?;
        Ok(ClientResponse { status, body })
    }
}

/// A persistent keep-alive HTTP/1.1 connection. Requests are sent with
/// `Connection: keep-alive`; responses are framed by their
/// `Content-Length` (the daemon always sends one). When the server
/// answers `Connection: close` (request cap reached, shutdown) or the
/// socket dies, the next request transparently reconnects.
pub struct HttpConn {
    addr: String,
    timeout: Duration,
    reader: Option<BufReader<TcpStream>>,
}

impl HttpConn {
    pub fn connect(addr: &str) -> Self {
        Self { addr: addr.to_string(), timeout: Duration::from_secs(10), reader: None }
    }

    pub fn with_timeout(mut self, timeout: Duration) -> Self {
        self.timeout = timeout;
        self
    }

    pub fn get(&mut self, path: &str) -> Result<ClientResponse> {
        self.request("GET", path, None)
    }

    pub fn post_json(&mut self, path: &str, body: &Json) -> Result<ClientResponse> {
        self.request("POST", path, Some(body.to_string_compact()))
    }

    /// POST a preserialized JSON string (the benchmark renders request
    /// bodies once and reuses them).
    pub fn post_raw(&mut self, path: &str, body: &str) -> Result<ClientResponse> {
        self.request("POST", path, Some(body.to_string()))
    }

    pub fn delete(&mut self, path: &str) -> Result<ClientResponse> {
        self.request("DELETE", path, None)
    }

    fn ensure_connected(&mut self) -> Result<&mut BufReader<TcpStream>> {
        if self.reader.is_none() {
            let stream = TcpStream::connect(&self.addr)
                .with_context(|| format!("connecting to {}", self.addr))?;
            stream.set_read_timeout(Some(self.timeout))?;
            stream.set_write_timeout(Some(self.timeout))?;
            stream.set_nodelay(true)?;
            self.reader = Some(BufReader::new(stream));
        }
        Ok(self.reader.as_mut().expect("just connected"))
    }

    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        body: Option<String>,
    ) -> Result<ClientResponse> {
        let body = body.unwrap_or_default();
        // One transparent retry: a kept-alive connection the server has
        // since closed (request cap, idle timeout) surfaces as an error
        // on the NEXT request; that request is re-sent on a fresh
        // connection rather than failed.
        match self.round_trip(method, path, &body) {
            Ok(resp) => Ok(resp),
            Err(_) if self.reader.is_none() => self.round_trip(method, path, &body),
            Err(e) => Err(e),
        }
    }

    fn round_trip(&mut self, method: &str, path: &str, body: &str) -> Result<ClientResponse> {
        let addr = self.addr.clone();
        let reader = self.ensure_connected()?;
        let result = Self::exchange(reader, &addr, method, path, body);
        match result {
            Ok((resp, server_closes)) => {
                if server_closes {
                    self.reader = None;
                }
                Ok(resp)
            }
            Err(e) => {
                // Dead connection: drop it so the caller's retry (or next
                // request) reconnects.
                self.reader = None;
                Err(e)
            }
        }
    }

    /// Send one request and read one `Content-Length`-framed response.
    /// Returns the response plus whether the server announced it will
    /// close the connection.
    fn exchange(
        reader: &mut BufReader<TcpStream>,
        addr: &str,
        method: &str,
        path: &str,
        body: &str,
    ) -> Result<(ClientResponse, bool)> {
        {
            let stream = reader.get_mut();
            write!(
                stream,
                "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\n\
                 Content-Length: {}\r\nConnection: keep-alive\r\n\r\n{body}",
                body.len()
            )?;
            stream.flush()?;
        }
        let mut status_line = String::new();
        if reader.read_line(&mut status_line)? == 0 {
            anyhow::bail!("connection closed before status line");
        }
        let status: u16 = status_line
            .split_whitespace()
            .nth(1)
            .and_then(|s| s.parse().ok())
            .context("malformed status line")?;
        let mut content_length: Option<usize> = None;
        let mut server_closes = false;
        loop {
            let mut line = String::new();
            if reader.read_line(&mut line)? == 0 {
                anyhow::bail!("connection closed mid-headers");
            }
            let line = line.trim_end();
            if line.is_empty() {
                break;
            }
            if let Some((name, value)) = line.split_once(':') {
                let name = name.trim().to_ascii_lowercase();
                let value = value.trim();
                if name == "content-length" {
                    content_length = Some(value.parse().context("bad Content-Length")?);
                } else if name == "connection" && value.eq_ignore_ascii_case("close") {
                    server_closes = true;
                }
            }
        }
        let len = content_length.context("response without Content-Length")?;
        let mut raw = vec![0u8; len];
        reader.read_exact(&mut raw).context("reading response body")?;
        let body = String::from_utf8_lossy(&raw).into_owned();
        Ok((ClientResponse { status, body }, server_closes))
    }
}

// Live-socket coverage is in rust/tests/server_api.rs (client + daemon
// round-trips on an ephemeral port).
