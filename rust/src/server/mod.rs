//! The online serving daemon: a GPU-as-a-Service control-plane front end
//! that schedules live workload requests with any configured policy.
//!
//! The offline crate set has no async runtime, so the daemon is built on
//! `std::net` + a fixed worker [`threadpool`]: an accept loop hands each
//! connection to a worker, which parses HTTP/1.1 ([`http`]), dispatches to
//! the JSON API ([`api`]), and synchronously serves the response.
//!
//! The fleet is partitioned into disjoint **shards** ([`shard`]): each
//! shard owns a sub-cluster, its own scheduler + incremental frag index
//! and its own mutex, and tenants are consistent-hash routed to shards —
//! so the data plane on different tenants never contends on one lock.
//! `shards = 1` (the default) is the original single-mutex daemon,
//! response-identical byte for byte. `benches/daemon_burst.rs` measures
//! the requests/sec across shard × worker configurations.
//!
//! Endpoints (see [`api`] for schemas):
//!
//! | method & path                 | purpose                                   |
//! |-------------------------------|-------------------------------------------|
//! | `POST /v1/workloads`          | submit a workload (profile, tenant, lease)|
//! | `DELETE /v1/workloads/N`      | terminate + release                       |
//! | `GET /v1/workloads/N`         | placement lookup                          |
//! | `POST /v1/tick`               | advance the logical slot clock (leases)   |
//! | `GET /v1/stats`               | paper metrics (acceptance, frag, util…)   |
//! | `GET /v1/cluster`             | full occupancy snapshot                   |
//! | `POST /v1/maintenance/defrag` | plan + apply migrations (per shard)       |
//! | `GET /v1/healthz`             | liveness JSON (status, uptime, shards)    |
//! | `GET /v1/version`             | crate version + enabled features          |
//! | `GET /metrics`                | Prometheus text exposition ([`metrics`])  |
//! | `GET /healthz`                | liveness (legacy plain-text)              |

pub mod api;
pub mod client;
pub mod daemon;
pub mod http;
pub mod metrics;
pub mod shard;
pub mod threadpool;

pub use client::HttpClient;
pub use daemon::{Daemon, DaemonConfig, DaemonDefrag, ServerHandle};
pub use http::{Request, Response};
pub use shard::{Lease, Shard, ShardRouter, ShardSet, ShardState};
pub use threadpool::ThreadPool;
