//! The online serving daemon: a GPU-as-a-Service control-plane front end
//! that schedules live workload requests with any configured policy.
//!
//! The offline crate set has no async runtime, so the daemon is built on
//! `std::net` with two dependency-free serve models
//! ([`daemon::ServeModel`]):
//!
//! * **Reactor** (default on unix) — N event-loop threads ([`reactor`])
//!   over a readiness [`poller`] (epoll on Linux, poll(2) elsewhere on
//!   unix). Connections are non-blocking state machines multiplexed on
//!   one thread each; the hot path parses in place from a reusable read
//!   buffer and renders into a reusable write buffer, so a kept-alive
//!   connection serves requests without per-request allocation.
//! * **Threadpool** — the portable fallback: an accept loop hands each
//!   connection to a fixed worker [`threadpool`], which blocks on it.
//!
//! Both models share the HTTP/1.1 grammar ([`http`], whose two parse
//! entry points are pinned against each other differentially), the JSON
//! API ([`api`]), and per-connection limits (keep-alive request cap,
//! idle timeout — configurable via [`daemon::DaemonConfig`]).
//!
//! The fleet is partitioned into disjoint **shards** ([`shard`]): each
//! shard owns a sub-cluster, its own scheduler + incremental frag index
//! and its own mutex, and tenants are consistent-hash routed to shards —
//! so the data plane on different tenants never contends on one lock.
//! `shards = 1` (the default) is the original single-mutex daemon,
//! response-identical byte for byte. `POST /v1/submit/batch` amortizes
//! shard-lock acquisition over many decisions with placements
//! bit-identical to sequential submits. `benches/daemon_burst.rs`
//! measures requests/sec across serve-model × shard × batch
//! configurations.
//!
//! Endpoints (see [`api`] for schemas):
//!
//! | method & path                 | purpose                                   |
//! |-------------------------------|-------------------------------------------|
//! | `POST /v1/workloads`          | submit a workload (profile, tenant, lease)|
//! | `POST /v1/submit/batch`       | submit many under one shard-lock hold     |
//! | `DELETE /v1/workloads/N`      | terminate + release                       |
//! | `GET /v1/workloads/N`         | placement lookup                          |
//! | `POST /v1/tick`               | advance the logical slot clock (leases)   |
//! | `GET /v1/stats`               | paper metrics (acceptance, frag, util…)   |
//! | `GET /v1/cluster`             | full occupancy snapshot                   |
//! | `POST /v1/maintenance/defrag` | plan + apply migrations (per shard)       |
//! | `GET /v1/healthz`             | liveness JSON (status, uptime, shards)    |
//! | `GET /v1/version`             | version, features, serving configuration  |
//! | `GET /metrics`                | Prometheus text exposition ([`metrics`])  |
//! | `GET /healthz`                | liveness (legacy plain-text)              |

pub mod api;
pub mod client;
pub mod daemon;
pub mod http;
pub mod metrics;
#[cfg(unix)]
pub(crate) mod poller;
#[cfg(unix)]
pub mod reactor;
pub mod shard;
pub mod threadpool;

pub use client::{HttpClient, HttpConn};
pub use daemon::{ConnLimits, Daemon, DaemonConfig, DaemonDefrag, ServeModel, ServerHandle};
pub use http::{Body, Request, Response};
pub use shard::{Lease, Shard, ShardRouter, ShardSet, ShardState};
pub use threadpool::ThreadPool;
