//! Fixed-size worker pool (tokio replacement for the request path).

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed pool of worker threads consuming jobs from a shared queue.
pub struct ThreadPool {
    sender: Option<mpsc::Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn `size` workers (panics if `size == 0`).
    pub fn new(size: usize) -> Self {
        assert!(size > 0, "thread pool needs at least one worker");
        let (sender, receiver) = mpsc::channel::<Job>();
        let receiver = Arc::new(Mutex::new(receiver));
        let workers = (0..size)
            .map(|i| {
                let receiver = Arc::clone(&receiver);
                std::thread::Builder::new()
                    .name(format!("migsched-worker-{i}"))
                    .spawn(move || loop {
                        // Holding the lock only while receiving keeps
                        // dispatch fair across workers.
                        let job = match receiver.lock().unwrap().recv() {
                            Ok(job) => job,
                            Err(_) => break, // sender dropped → shutdown
                        };
                        job();
                    })
                    .expect("spawning worker thread")
            })
            .collect();
        Self { sender: Some(sender), workers }
    }

    /// Submit a job; panics if the pool is shut down.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, job: F) {
        self.sender
            .as_ref()
            .expect("pool is shut down")
            .send(Box::new(job))
            .expect("workers alive");
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        // Close the channel, then join every worker.
        drop(self.sender.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let (done_tx, done_rx) = mpsc::channel();
        for _ in 0..100 {
            let counter = Arc::clone(&counter);
            let done = done_tx.clone();
            pool.execute(move || {
                counter.fetch_add(1, Ordering::SeqCst);
                done.send(()).unwrap();
            });
        }
        for _ in 0..100 {
            done_rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..10 {
            let counter = Arc::clone(&counter);
            pool.execute(move || {
                std::thread::sleep(std::time::Duration::from_millis(5));
                counter.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // must block until all 10 ran
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn parallelism_is_real() {
        let pool = ThreadPool::new(4);
        let (tx, rx) = mpsc::channel();
        let barrier = Arc::new(std::sync::Barrier::new(4));
        for _ in 0..4 {
            let tx = tx.clone();
            let barrier = Arc::clone(&barrier);
            pool.execute(move || {
                // Deadlocks unless 4 jobs run concurrently.
                barrier.wait();
                tx.send(()).unwrap();
            });
        }
        for _ in 0..4 {
            rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
        }
    }
}
