//! The serving daemon: sharded cluster state + schedulers behind an HTTP
//! listener (see [`super::shard`] for the partitioning/routing model).
//!
//! Two serve models share the shard set, the dispatch layer and the HTTP
//! grammar:
//!
//! * [`ServeModel::Reactor`] (the default on unix) — N event-loop
//!   threads, each running a non-blocking readiness poller
//!   ([`super::reactor`]); connections never pin a thread.
//! * [`ServeModel::Threadpool`] — the original accept thread + blocking
//!   worker pool, kept as the portable fallback and as the baseline the
//!   daemon benchmark compares against.

use std::net::{IpAddr, Ipv4Addr, Ipv6Addr, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

use super::api;
use super::http::parse_request_from;
use super::metrics;
use super::shard::ShardSet;
use super::threadpool::ThreadPool;
use crate::mig::{FleetSpec, HardwareModel};
use crate::obs::log::RateLimited;
use crate::sched::SchedulerKind;

/// Default for [`DaemonConfig::max_requests_per_conn`]: requests served
/// over one kept-alive connection before the daemon forces a close —
/// bounds how long a chatty client can pin a worker.
pub const MAX_REQUESTS_PER_CONN: usize = 32;

/// Default for [`DaemonConfig::idle_timeout`]: socket read timeout after
/// the first response — bounds both the idle wait for the next request
/// line and each read while receiving that request (one knob — a
/// kept-alive peer trickling bytes is indistinguishable from an idle one
/// at this layer).
pub const KEEP_ALIVE_IDLE: std::time::Duration = std::time::Duration::from_secs(5);

/// Read timeout while receiving the FIRST request of a connection.
pub(crate) const REQUEST_TIMEOUT: std::time::Duration = std::time::Duration::from_secs(10);

/// How the daemon turns accepted sockets into served requests.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServeModel {
    /// Non-blocking event loops (epoll on Linux, poll(2) elsewhere on
    /// unix). Unavailable off unix; [`ServeModel::effective`] falls back.
    Reactor,
    /// Accept thread handing blocking connections to a worker pool.
    Threadpool,
}

impl ServeModel {
    /// The model that will actually serve on this platform.
    pub fn effective(self) -> ServeModel {
        if cfg!(unix) {
            self
        } else {
            ServeModel::Threadpool
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ServeModel::Reactor => "reactor",
            ServeModel::Threadpool => "threadpool",
        }
    }

    /// Parse a `--serve-model` CLI value (case-insensitive).
    pub fn parse(name: &str) -> Option<ServeModel> {
        match name.to_ascii_lowercase().as_str() {
            "reactor" => Some(ServeModel::Reactor),
            "threadpool" => Some(ServeModel::Threadpool),
            _ => None,
        }
    }
}

impl Default for ServeModel {
    fn default() -> Self {
        ServeModel::Reactor.effective()
    }
}

/// Per-connection serving limits, shared by both serve models and
/// reported by `GET /v1/version`.
#[derive(Clone, Copy, Debug)]
pub struct ConnLimits {
    /// Idle / slow-trickle timeout between kept-alive requests.
    pub idle_timeout: std::time::Duration,
    /// Requests served per connection before a forced close.
    pub max_requests_per_conn: usize,
}

impl Default for ConnLimits {
    fn default() -> Self {
        Self { idle_timeout: KEEP_ALIVE_IDLE, max_requests_per_conn: MAX_REQUESTS_PER_CONN }
    }
}

/// Background continuous-defrag configuration: every `every_secs` the
/// sweeper visits each shard in index order (one lock at a time — the
/// same one-lock-hold discipline as the maintenance endpoint) and runs a
/// threshold-gated, budgeted sweep.
#[derive(Clone, Copy, Debug)]
pub struct DaemonDefrag {
    /// Wall-clock sweep cadence in seconds.
    pub every_secs: u64,
    /// Minimum shard-mean fragmentation score for a sweep to act
    /// (0.0 = always sweep on cadence).
    pub threshold: f64,
    /// Maximum migrations per shard per sweep.
    pub max_moves: usize,
    /// Migration cost budget per shard per sweep (0 = unlimited).
    pub cost_budget: u64,
}

/// Daemon configuration.
#[derive(Clone, Debug)]
pub struct DaemonConfig {
    pub hardware: HardwareModel,
    pub num_gpus: usize,
    /// Heterogeneous fleet (`--fleet`). When set it defines the served
    /// cluster — `hardware`/`num_gpus` must agree with class 0 / the fleet
    /// total (the CLI keeps them in sync) — and the partition across
    /// shards preserves class composition. `None` = a uniform fleet of
    /// `num_gpus` × `hardware`, the byte-compatible legacy path.
    pub fleet: Option<FleetSpec>,
    pub scheduler: SchedulerKind,
    /// Serving threads: event loops under [`ServeModel::Reactor`], HTTP
    /// workers under [`ServeModel::Threadpool`]. Must be ≥ 1.
    pub workers: usize,
    /// Disjoint sub-clusters, each behind its own lock (tenants are
    /// consistent-hash routed). `1` (the default) is the single-mutex
    /// daemon with byte-for-byte identical responses to earlier versions.
    pub shards: usize,
    /// Background continuous defrag (`None` = the pre-existing behavior:
    /// migrations only via `POST /v1/maintenance/defrag`).
    pub defrag: Option<DaemonDefrag>,
    /// Online workload estimator seeding/decay for distribution-aware
    /// schedulers (`--estimator-decay` / `--estimator-seed`; only MFI-EXP
    /// consumes it). The estimator is **per-shard**: each shard's
    /// scheduler lives behind that shard's own mutex, and tenants are
    /// consistent-hash routed, so every shard learns the mix of its own
    /// tenant population from the submits it actually serves — no
    /// cross-shard lock or shared atomic state on the data-plane hot
    /// path, matching the shard-local defrag sweeper discipline.
    /// `None` = build schedulers exactly as before (byte-compatible).
    pub estimator: Option<crate::workload::EstimatorConfig>,
    /// How connections are served; see [`ServeModel`].
    pub model: ServeModel,
    /// Idle timeout between kept-alive requests (`--idle-timeout-ms`).
    pub idle_timeout: std::time::Duration,
    /// Requests per connection before a forced close
    /// (`--max-requests-per-conn`).
    pub max_requests_per_conn: usize,
}

impl Default for DaemonConfig {
    fn default() -> Self {
        Self {
            hardware: HardwareModel::a100_80gb(),
            num_gpus: 100,
            fleet: None,
            scheduler: SchedulerKind::Mfi,
            workers: 8,
            shards: 1,
            defrag: None,
            estimator: None,
            model: ServeModel::default(),
            idle_timeout: KEEP_ALIVE_IDLE,
            max_requests_per_conn: MAX_REQUESTS_PER_CONN,
        }
    }
}

/// The daemon object; create then [`Daemon::serve`].
pub struct Daemon {
    shards: Arc<ShardSet>,
    config: DaemonConfig,
}

impl Daemon {
    pub fn new(config: DaemonConfig) -> Self {
        Self { shards: Arc::new(ShardSet::new(&config)), config }
    }

    /// Shared shard-set handle (used by the API layer and tests).
    pub fn shards(&self) -> Arc<ShardSet> {
        Arc::clone(&self.shards)
    }

    pub fn config(&self) -> &DaemonConfig {
        &self.config
    }

    /// Bind and serve until the returned handle is shut down.
    pub fn serve(&self, addr: &str) -> std::io::Result<ServerHandle> {
        let listener = TcpListener::bind(addr)?;
        let local_addr = listener.local_addr()?;
        let workers = self.config.workers.max(1);
        let shutdown = Arc::new(AtomicBool::new(false));
        let model = self.config.model.effective();

        let threads: Vec<JoinHandle<()>> = match model {
            #[cfg(unix)]
            ServeModel::Reactor => super::reactor::serve(
                listener,
                Arc::clone(&self.shards),
                Arc::clone(&shutdown),
                workers,
            )?,
            _ => {
                listener.set_nonblocking(false)?;
                vec![spawn_accept_loop(
                    listener,
                    Arc::clone(&self.shards),
                    Arc::clone(&shutdown),
                    workers,
                )?]
            }
        };

        let defrag_thread = match self.config.defrag {
            Some(policy) => Some(
                std::thread::Builder::new().name("migsched-defrag".into()).spawn({
                    let shards = Arc::clone(&self.shards);
                    let shutdown = Arc::clone(&shutdown);
                    move || background_defrag(shards, policy, shutdown)
                })?,
            ),
            None => None,
        };

        crate::log_info!(
            "serving on {local_addr} ({} GPUs over {} shard(s), scheduler {}, {} model, {} thread(s))",
            self.config.num_gpus,
            self.config.shards,
            self.config.scheduler.name(),
            model.name(),
            workers
        );
        if let Some(policy) = &self.config.defrag {
            crate::log_info!(
                "background defrag every {}s (threshold {}, max {} move(s), cost budget {})",
                policy.every_secs,
                policy.threshold,
                policy.max_moves,
                policy.cost_budget
            );
        }
        Ok(ServerHandle { addr: local_addr, shutdown, threads, defrag_thread })
    }
}

/// The threadpool serve model: one blocking accept loop feeding a worker
/// pool, one connection pinned per worker while it is being served.
fn spawn_accept_loop(
    listener: TcpListener,
    shards: Arc<ShardSet>,
    shutdown: Arc<AtomicBool>,
    workers: usize,
) -> std::io::Result<JoinHandle<()>> {
    std::thread::Builder::new().name("migsched-accept".into()).spawn(move || {
        let pool = ThreadPool::new(workers);
        for stream in listener.incoming() {
            if shutdown.load(Ordering::SeqCst) {
                break;
            }
            match stream {
                Ok(stream) => {
                    let shards = Arc::clone(&shards);
                    let shutdown = Arc::clone(&shutdown);
                    pool.execute(move || handle_connection(stream, shards, shutdown));
                }
                Err(e) => {
                    // A dying listener repeats the same error at
                    // accept-loop speed; log once per window.
                    static ACCEPT_WARN: RateLimited =
                        RateLimited::new(std::time::Duration::from_secs(5));
                    let msg = format!("accept error: {e}");
                    match ACCEPT_WARN.should_log(&msg) {
                        Some(0) => crate::log_warn!("{msg}"),
                        Some(dropped) => crate::log_warn!(
                            "{msg} ({dropped} identical warning(s) suppressed)"
                        ),
                        None => {}
                    }
                }
            }
        }
    })
}

/// Next connection id: together with the per-connection request sequence
/// it forms the request id (`conn=N req=M`) threaded through every log
/// line from accept to respond. Shared by both serve models so ids stay
/// unique within a process.
pub(crate) fn next_conn_id() -> u64 {
    static NEXT_CONN_ID: AtomicU64 = AtomicU64::new(1);
    NEXT_CONN_ID.fetch_add(1, Ordering::Relaxed)
}

/// The background defrag loop: sleep out the cadence (in short ticks so
/// shutdown stays prompt), then sweep every shard via
/// [`ShardState::defrag_sweep`] — one lock at a time, in index order,
/// exactly the maintenance endpoint's scatter-gather discipline, so the
/// sweeper never deadlocks with data-plane handlers or `/v1/tick`.
///
/// [`ShardState::defrag_sweep`]: super::shard::ShardState::defrag_sweep
fn background_defrag(
    shards: Arc<ShardSet>,
    policy: DaemonDefrag,
    shutdown: Arc<AtomicBool>,
) {
    let tick = std::time::Duration::from_millis(50);
    'outer: loop {
        let deadline = std::time::Instant::now()
            + std::time::Duration::from_secs(policy.every_secs.max(1));
        while std::time::Instant::now() < deadline {
            if shutdown.load(Ordering::SeqCst) {
                break 'outer;
            }
            std::thread::sleep(tick);
        }
        for shard in shards.shards() {
            if shutdown.load(Ordering::SeqCst) {
                break 'outer;
            }
            let sweep_start = std::time::Instant::now();
            let mut s = shard.state.lock().unwrap();
            match s.defrag_sweep(policy.threshold, policy.max_moves, policy.cost_budget) {
                Ok(plan) if !plan.is_empty() => {
                    crate::log_info!(
                        "defrag shard {}: {} move(s), delta_f {}, {} bytes",
                        shard.index,
                        plan.moves.len(),
                        plan.total_delta(),
                        plan.bytes_moved
                    );
                }
                Ok(_) => {}
                // Unreachable (the sweep plans and applies under one lock
                // hold), but a sweep failure must never kill the daemon.
                Err(e) => crate::log_warn!("defrag shard {}: {e}", shard.index),
            }
            drop(s);
            shards.metrics().defrag_sweeps_total.inc();
            shards.metrics().defrag_sweep_duration.record(sweep_start.elapsed());
        }
    }
}

/// Serve one connection (threadpool model): up to
/// `max_requests_per_conn` requests when the client negotiates
/// keep-alive (HTTP/1.1 default), with the configured idle timeout
/// between requests. One `BufReader` lives for the whole connection so
/// pipelined request bytes survive across turns.
///
/// The daemon's shutdown flag is honored between requests (and folded
/// into the keep decision), so an actively-polling kept-alive client
/// cannot stretch `ServerHandle::shutdown` beyond one in-flight request
/// plus one read-timeout window.
fn handle_connection(
    mut stream: TcpStream,
    shards: Arc<ShardSet>,
    shutdown: Arc<AtomicBool>,
) {
    let limits = shards.limits();
    let _ = stream.set_read_timeout(Some(REQUEST_TIMEOUT));
    let reader_stream = match stream.try_clone() {
        Ok(s) => s,
        Err(e) => {
            crate::log_warn!("clone connection for reading: {e}");
            return;
        }
    };
    // Open-connection accounting starts only after the early-return above,
    // so the single decrement at the bottom always balances it.
    let m = shards.metrics();
    m.connections_total.inc();
    m.connections_open.inc();
    let conn_id = next_conn_id();
    if let Ok(peer) = stream.peer_addr() {
        crate::log_debug!("conn={conn_id} accepted from {peer}");
    }
    let mut reader = std::io::BufReader::new(reader_stream);
    let mut served = 0usize;
    loop {
        if shutdown.load(Ordering::SeqCst) {
            break;
        }
        match parse_request_from(&mut reader) {
            Ok(None) => break, // clean EOF / idle timeout between requests
            Ok(Some(request)) => {
                let started = std::time::Instant::now();
                served += 1;
                crate::log_debug!(
                    "conn={conn_id} req={served} {} {}",
                    request.method, request.path
                );
                let keep = request.keep_alive
                    && served < limits.max_requests_per_conn
                    && !shutdown.load(Ordering::SeqCst);
                let response = api::dispatch(&request, &shards);
                // Counted before the response bytes go out; together with
                // responses_total counting after, any concurrent scrape
                // sees requests >= responses (see super::metrics docs).
                let route = metrics::route_index(&request.method, &request.segments());
                m.record_request(route, response.status, started.elapsed());
                if let Err(e) = response.write_conn(&mut stream, keep) {
                    crate::log_debug!("conn={conn_id} req={served} write response: {e}");
                    break;
                }
                m.responses_total.inc();
                crate::log_debug!(
                    "conn={conn_id} req={served} -> {} ({} bytes, {:?})",
                    response.status,
                    response.body.len(),
                    started.elapsed()
                );
                if !keep {
                    break;
                }
                // Idle clock: subsequent requests get the (shorter)
                // keep-alive window. SO_RCVTIMEO lives on the shared
                // socket, so setting it on either handle is enough.
                let _ = stream.set_read_timeout(Some(limits.idle_timeout));
            }
            Err(response) => {
                // Malformed input: answer (best effort) and hang up. No
                // parsed route or meaningful handling latency exists, so
                // it counts against the catch-all route at zero elapsed.
                m.record_request(
                    metrics::ROUTE_OTHER,
                    response.status,
                    std::time::Duration::ZERO,
                );
                if let Err(e) = response.write_conn(&mut stream, false) {
                    crate::log_debug!("conn={conn_id} write error response: {e}");
                } else {
                    m.responses_total.inc();
                }
                break;
            }
        }
    }
    // Graceful close: half-close our side, then briefly drain whatever
    // the peer pipelined past the last served request — closing with
    // unread bytes in the receive queue makes the kernel RST the
    // connection, which can discard the final response before the client
    // reads it. Bounded in volume AND by a wall-clock deadline (the
    // per-read timeout alone would let a byte-trickling peer pin the
    // worker indefinitely).
    let _ = stream.shutdown(std::net::Shutdown::Write);
    let _ = stream.set_read_timeout(Some(std::time::Duration::from_millis(200)));
    let deadline = std::time::Instant::now() + std::time::Duration::from_millis(500);
    let mut sink = [0u8; 4096];
    let mut drained = 0usize;
    while std::time::Instant::now() < deadline {
        match std::io::Read::read(&mut reader, &mut sink) {
            Ok(0) | Err(_) => break,
            Ok(n) => {
                drained += n;
                if drained > 64 * 1024 {
                    break;
                }
            }
        }
    }
    let _ = stream.shutdown(std::net::Shutdown::Both);
    m.connections_open.dec();
    crate::log_debug!("conn={conn_id} closed after {served} request(s)");
}

/// The address to dial when waking the accept loop: `addr` itself, unless
/// the daemon is bound to the unspecified address (`0.0.0.0` / `[::]`),
/// which is not a connectable destination on every platform — then the
/// matching loopback address reaches the same listener.
fn wake_addr(addr: SocketAddr) -> SocketAddr {
    let ip = match addr.ip() {
        IpAddr::V4(ip) if ip.is_unspecified() => IpAddr::V4(Ipv4Addr::LOCALHOST),
        IpAddr::V6(ip) if ip.is_unspecified() => IpAddr::V6(Ipv6Addr::LOCALHOST),
        ip => ip,
    };
    SocketAddr::new(ip, addr.port())
}

/// Handle to a running server; shuts down on `shutdown()` or drop.
pub struct ServerHandle {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    /// The accept thread (threadpool model) or the event-loop threads
    /// (reactor model).
    threads: Vec<JoinHandle<()>>,
    defrag_thread: Option<JoinHandle<()>>,
}

impl ServerHandle {
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Request shutdown and join the serving threads.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // Unblock the accept loop / pollers with a dummy connection (via
        // loopback when bound to 0.0.0.0/[::]; bounded so shutdown never
        // hangs). Every reactor loop polls the same listener, so one
        // pending connection wakes them all; their wait timeout backstops
        // a missed wake.
        let _ = TcpStream::connect_timeout(
            &wake_addr(self.addr),
            std::time::Duration::from_secs(1),
        );
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
        // The sweeper polls the flag every 50ms, so this join is prompt.
        if let Some(t) = self.defrag_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        if !self.threads.is_empty() || self.defrag_thread.is_some() {
            self.shutdown_inner();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mig::Profile;
    use crate::server::shard::{Lease, ShardState};
    use crate::workload::{TenantId, WorkloadId};

    #[test]
    fn tick_releases_expired_leases() {
        let daemon = Daemon::new(DaemonConfig {
            num_gpus: 2,
            workers: 1,
            ..DaemonConfig::default()
        });
        let shards = daemon.shards();
        let mut s = shards.shard(0).unwrap().state.lock().unwrap();
        // Manually admit two workloads, one with a lease of 3 slots.
        let ShardState { scheduler, cluster, .. } = &mut *s;
        let placement = scheduler.schedule(cluster, Profile::P2g20gb).unwrap();
        cluster.allocate(WorkloadId(0), placement).unwrap();
        let placement = scheduler.schedule(cluster, Profile::P1g10gb).unwrap();
        cluster.allocate(WorkloadId(1), placement).unwrap();
        s.leases
            .insert(WorkloadId(0), Lease { tenant: TenantId(0), expires_at: Some(3) });
        s.leases.insert(WorkloadId(1), Lease { tenant: TenantId(0), expires_at: None });

        assert!(s.tick(2).is_empty(), "nothing expires at slot 2");
        let released = s.tick(1); // slot 3
        assert_eq!(released, vec![WorkloadId(0)]);
        assert_eq!(s.cluster.allocated_workloads(), 1);
        assert_eq!(s.expired_total, 1);
        // Permanent lease survives arbitrarily long.
        assert!(s.tick(1000).is_empty());
    }

    #[test]
    fn wake_addr_resolves_unspecified_to_loopback() {
        // Regression: shutdown used to dial the bind address verbatim,
        // which hangs forever on some platforms when bound to 0.0.0.0.
        let w = wake_addr("0.0.0.0:8080".parse().unwrap());
        assert_eq!(w, "127.0.0.1:8080".parse().unwrap());
        let w = wake_addr("[::]:9090".parse().unwrap());
        assert_eq!(w, "[::1]:9090".parse().unwrap());
        // Concrete addresses pass through untouched.
        let w = wake_addr("192.0.2.7:80".parse().unwrap());
        assert_eq!(w, "192.0.2.7:80".parse().unwrap());
        let w = wake_addr("127.0.0.1:81".parse().unwrap());
        assert_eq!(w, "127.0.0.1:81".parse().unwrap());
    }

    #[test]
    fn serve_model_effective_and_names() {
        assert_eq!(ServeModel::Threadpool.effective(), ServeModel::Threadpool);
        assert_eq!(ServeModel::Reactor.name(), "reactor");
        assert_eq!(ServeModel::Threadpool.name(), "threadpool");
        if cfg!(unix) {
            assert_eq!(ServeModel::default(), ServeModel::Reactor);
        } else {
            assert_eq!(ServeModel::default(), ServeModel::Threadpool);
        }
        let limits = ConnLimits::default();
        assert_eq!(limits.idle_timeout, KEEP_ALIVE_IDLE);
        assert_eq!(limits.max_requests_per_conn, MAX_REQUESTS_PER_CONN);
        assert_eq!(ServeModel::parse("reactor"), Some(ServeModel::Reactor));
        assert_eq!(ServeModel::parse("Threadpool"), Some(ServeModel::Threadpool));
        assert_eq!(ServeModel::parse("async"), None);
    }

    // Socket-level serve/shutdown coverage is in rust/tests/server_api.rs.
}
