//! The serving daemon: cluster state + scheduler behind an HTTP listener.

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use super::api;
use super::http::parse_request;
use super::threadpool::ThreadPool;
use crate::cluster::Cluster;
use crate::frag::ScoreTable;
use crate::mig::HardwareModel;
use crate::sched::{Scheduler, SchedulerKind};
use crate::workload::{TenantId, WorkloadId};

/// Daemon configuration.
#[derive(Clone, Debug)]
pub struct DaemonConfig {
    pub hardware: HardwareModel,
    pub num_gpus: usize,
    pub scheduler: SchedulerKind,
    /// HTTP worker threads.
    pub workers: usize,
}

impl Default for DaemonConfig {
    fn default() -> Self {
        Self {
            hardware: HardwareModel::a100_80gb(),
            num_gpus: 100,
            scheduler: SchedulerKind::Mfi,
            workers: 8,
        }
    }
}

/// A lease attached to an allocated workload (logical-slot expiry).
#[derive(Clone, Copy, Debug)]
pub struct Lease {
    pub tenant: TenantId,
    /// Slot at which the lease expires (None = until explicit release).
    pub expires_at: Option<u64>,
}

/// Shared daemon state (single mutex: decisions are microseconds).
pub struct DaemonState {
    pub cluster: Cluster,
    pub scheduler: Box<dyn Scheduler + Send>,
    pub scorer: ScoreTable,
    pub leases: std::collections::HashMap<WorkloadId, Lease>,
    pub next_id: u64,
    pub clock_slot: u64,
    pub accepted_total: u64,
    pub arrived_total: u64,
    pub released_total: u64,
    pub expired_total: u64,
}

impl DaemonState {
    /// Advance the logical slot clock, releasing expired leases.
    /// Returns the ids released.
    pub fn tick(&mut self, slots: u64) -> Vec<WorkloadId> {
        self.clock_slot += slots;
        let now = self.clock_slot;
        let expired: Vec<WorkloadId> = self
            .leases
            .iter()
            .filter(|(_, lease)| lease.expires_at.is_some_and(|t| t <= now))
            .map(|(id, _)| *id)
            .collect();
        let mut released = expired;
        released.sort();
        for id in &released {
            let freed =
                self.cluster.release(*id).expect("lease registry consistent with cluster");
            self.scheduler.on_release(&self.cluster, freed);
            self.leases.remove(id);
            self.expired_total += 1;
        }
        released
    }
}

/// The daemon object; create then [`Daemon::serve`].
pub struct Daemon {
    state: Arc<Mutex<DaemonState>>,
    config: DaemonConfig,
}

impl Daemon {
    pub fn new(config: DaemonConfig) -> Self {
        let state = DaemonState {
            cluster: Cluster::new(config.hardware.clone(), config.num_gpus),
            scheduler: config.scheduler.build(&config.hardware),
            scorer: ScoreTable::for_hardware(&config.hardware),
            leases: std::collections::HashMap::new(),
            next_id: 0,
            clock_slot: 0,
            accepted_total: 0,
            arrived_total: 0,
            released_total: 0,
            expired_total: 0,
        };
        Self { state: Arc::new(Mutex::new(state)), config }
    }

    /// Shared state handle (used by the API layer and tests).
    pub fn state(&self) -> Arc<Mutex<DaemonState>> {
        Arc::clone(&self.state)
    }

    pub fn config(&self) -> &DaemonConfig {
        &self.config
    }

    /// Bind and serve until the returned handle is shut down.
    pub fn serve(&self, addr: &str) -> std::io::Result<ServerHandle> {
        let listener = TcpListener::bind(addr)?;
        let local_addr = listener.local_addr()?;
        listener.set_nonblocking(false)?;
        let state = Arc::clone(&self.state);
        let workers = self.config.workers;
        let shutdown = Arc::new(AtomicBool::new(false));
        let shutdown_flag = Arc::clone(&shutdown);

        let accept_thread = std::thread::Builder::new()
            .name("migsched-accept".into())
            .spawn(move || {
                let pool = ThreadPool::new(workers);
                // Poll with a read timeout so shutdown is prompt.
                for stream in listener.incoming() {
                    if shutdown_flag.load(Ordering::SeqCst) {
                        break;
                    }
                    match stream {
                        Ok(stream) => {
                            let state = Arc::clone(&state);
                            pool.execute(move || handle_connection(stream, state));
                        }
                        Err(e) => {
                            crate::log_warn!("accept error: {e}");
                        }
                    }
                }
            })?;

        crate::log_info!(
            "serving on {local_addr} ({} GPUs, scheduler {})",
            self.config.num_gpus,
            self.config.scheduler.name()
        );
        Ok(ServerHandle { addr: local_addr, shutdown, accept_thread: Some(accept_thread) })
    }
}

fn handle_connection(mut stream: TcpStream, state: Arc<Mutex<DaemonState>>) {
    let _ = stream.set_read_timeout(Some(std::time::Duration::from_secs(10)));
    let response = match parse_request(&mut stream) {
        Ok(request) => {
            crate::log_debug!("{} {}", request.method, request.path);
            api::dispatch(&request, &state)
        }
        Err(resp) => resp,
    };
    if let Err(e) = response.write_to(&mut stream) {
        crate::log_debug!("write response: {e}");
    }
    let _ = stream.shutdown(std::net::Shutdown::Both);
}

/// Handle to a running server; shuts down on `shutdown()` or drop.
pub struct ServerHandle {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
}

impl ServerHandle {
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Request shutdown and join the accept loop.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // Unblock the accept loop with a dummy connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        if self.accept_thread.is_some() {
            self.shutdown_inner();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mig::Profile;

    #[test]
    fn tick_releases_expired_leases() {
        let daemon = Daemon::new(DaemonConfig {
            num_gpus: 2,
            workers: 1,
            ..DaemonConfig::default()
        });
        let state = daemon.state();
        let mut s = state.lock().unwrap();
        // Manually admit two workloads, one with a lease of 3 slots.
        let DaemonState { scheduler, cluster, .. } = &mut *s;
        let placement = scheduler.schedule(cluster, Profile::P2g20gb).unwrap();
        cluster.allocate(WorkloadId(0), placement).unwrap();
        let placement = scheduler.schedule(cluster, Profile::P1g10gb).unwrap();
        cluster.allocate(WorkloadId(1), placement).unwrap();
        s.leases
            .insert(WorkloadId(0), Lease { tenant: TenantId(0), expires_at: Some(3) });
        s.leases.insert(WorkloadId(1), Lease { tenant: TenantId(0), expires_at: None });

        assert!(s.tick(2).is_empty(), "nothing expires at slot 2");
        let released = s.tick(1); // slot 3
        assert_eq!(released, vec![WorkloadId(0)]);
        assert_eq!(s.cluster.allocated_workloads(), 1);
        assert_eq!(s.expired_total, 1);
        // Permanent lease survives arbitrarily long.
        assert!(s.tick(1000).is_empty());
    }

    // Socket-level serve/shutdown coverage is in rust/tests/server_api.rs.
}
