//! Minimal HTTP/1.1 request parsing and response writing over blocking
//! TCP streams — just enough protocol for the JSON control-plane API
//! (no chunked encoding, 1 MiB body cap, 8 KiB request-/header-line cap).
//!
//! Persistent connections ARE supported: [`parse_request_from`] reads
//! sequential requests off one shared `BufRead` (so pipelined bytes
//! buffered past the first request are never dropped), [`Request`]
//! carries the negotiated `keep_alive` flag (HTTP/1.1 default-on,
//! HTTP/1.0 opt-in, `Connection: close` always wins) and
//! [`Response::write_conn`] emits the matching `Connection:` header. The
//! per-connection loop — request cap, idle timeout — lives in
//! [`super::daemon`].

use std::collections::HashMap;
use std::io::{BufRead, Read, Write};

/// Maximum accepted request body (1 MiB — control-plane payloads are tiny).
pub const MAX_BODY: usize = 1 << 20;

/// Maximum accepted request-line / header-line length. Lines are read
/// incrementally, so a client streaming one endless line is cut off at
/// this bound (413) instead of growing the buffer without limit.
pub const MAX_LINE: usize = 8 << 10;

/// A parsed HTTP request.
#[derive(Debug, Clone)]
pub struct Request {
    pub method: String,
    /// Path without query string.
    pub path: String,
    /// Decoded query parameters.
    pub query: HashMap<String, String>,
    pub headers: HashMap<String, String>,
    pub body: Vec<u8>,
    /// Whether the client's version + `Connection` header allow reusing
    /// the connection for another request after the response.
    pub keep_alive: bool,
}

impl Request {
    /// Body as UTF-8 (empty string when absent).
    pub fn body_str(&self) -> Result<&str, String> {
        std::str::from_utf8(&self.body).map_err(|_| "body is not valid UTF-8".to_string())
    }

    /// Split the path into non-empty segments.
    pub fn segments(&self) -> Vec<&str> {
        self.path.split('/').filter(|s| !s.is_empty()).collect()
    }
}

/// An HTTP response under construction.
#[derive(Debug, Clone)]
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: Vec<u8>,
}

impl Response {
    pub fn json(status: u16, body: &crate::util::json::Json) -> Self {
        Self {
            status,
            content_type: "application/json",
            body: body.to_string_compact().into_bytes(),
        }
    }

    pub fn text(status: u16, body: &str) -> Self {
        Self { status, content_type: "text/plain; charset=utf-8", body: body.as_bytes().to_vec() }
    }

    pub fn error(status: u16, message: &str) -> Self {
        Self::json(status, &crate::util::json::Json::obj().with("error", message))
    }

    /// A response with an explicit content type (e.g. the Prometheus
    /// exposition type on `GET /metrics`).
    pub fn with_content_type(status: u16, content_type: &'static str, body: Vec<u8>) -> Self {
        Self { status, content_type, body }
    }

    fn status_text(status: u16) -> &'static str {
        match status {
            200 => "OK",
            201 => "Created",
            204 => "No Content",
            400 => "Bad Request",
            404 => "Not Found",
            405 => "Method Not Allowed",
            409 => "Conflict",
            413 => "Payload Too Large",
            414 => "URI Too Long",
            429 => "Too Many Requests",
            500 => "Internal Server Error",
            501 => "Not Implemented",
            503 => "Service Unavailable",
            _ => "Unknown",
        }
    }

    /// Serialize onto a stream, closing the connection afterwards.
    pub fn write_to(&self, stream: &mut dyn Write) -> std::io::Result<()> {
        self.write_conn(stream, false)
    }

    /// Serialize onto a stream with an explicit connection disposition.
    /// Responses always carry `Content-Length`, so a kept-alive peer
    /// knows exactly where the next response begins.
    pub fn write_conn(&self, stream: &mut dyn Write, keep_alive: bool) -> std::io::Result<()> {
        write!(
            stream,
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: {}\r\n\r\n",
            self.status,
            Self::status_text(self.status),
            self.content_type,
            self.body.len(),
            if keep_alive { "keep-alive" } else { "close" }
        )?;
        stream.write_all(&self.body)?;
        stream.flush()
    }
}

/// Parse one request from a shared buffered reader — the daemon's only
/// parse entry point. `Ok(None)` means the client closed (or went idle
/// past the read timeout) *between* requests: nothing to answer, close
/// quietly. A connection that dies mid-request is still an error.
///
/// The reader must be reused across calls on one connection: pipelined
/// clients send request N+1's bytes before response N, and those bytes
/// live in this reader's buffer.
pub fn parse_request_from<R: BufRead>(reader: &mut R) -> Result<Option<Request>, Response> {
    // RFC 9110: an overlong request target is 414, overlong header
    // fields are 413 (we cap per line rather than per field set).
    // RFC 9112 §2.2 robustness: ignore a couple of empty lines before the
    // request line (clients historically terminate bodies with a stray
    // CRLF not counted in Content-Length).
    let mut request_line = None;
    for _ in 0..3 {
        match read_line_capped(reader, "request line", 414) {
            Ok(line) if line.is_empty() => return Ok(None), // clean EOF
            Ok(line) if line.trim_end().is_empty() => continue, // bare CRLF
            Ok(line) => {
                request_line = Some(line);
                break;
            }
            // Nothing of a request seen yet → idle close, not an error.
            Err(LineError::Io { partial: false, .. }) => return Ok(None),
            Err(e) => return Err(e.into_response()),
        }
    }
    let request_line =
        request_line.ok_or_else(|| Response::error(400, "missing method"))?;
    let mut parts = request_line.split_whitespace();
    let method = parts.next().ok_or_else(|| Response::error(400, "missing method"))?;
    let target = parts.next().ok_or_else(|| Response::error(400, "missing path"))?;
    let version = parts.next().unwrap_or("HTTP/1.1");
    if !version.starts_with("HTTP/1.") {
        return Err(Response::error(400, "unsupported HTTP version"));
    }
    // HTTP/1.1 defaults to persistent connections; 1.0 must opt in.
    let http_11 = version != "HTTP/1.0";

    let (path, query) = split_target(target);

    let mut headers = HashMap::new();
    let mut header_lines = 0usize;
    loop {
        let line = read_line_capped(reader, "headers", 413)
            .map_err(LineError::into_response)?;
        let line = line.trim_end();
        if line.is_empty() {
            break;
        }
        // Count LINES read, not parsed entries: colon-less or
        // duplicate-name lines must also hit the bound, or a client
        // streaming junk lines under the length cap pins a worker forever.
        header_lines += 1;
        if header_lines > 100 {
            return Err(Response::error(400, "too many headers"));
        }
        if let Some((name, value)) = line.split_once(':') {
            let name = name.trim().to_ascii_lowercase();
            let value = value.trim().to_string();
            // RFC 9112 §6.3: conflicting Content-Length values are
            // unrecoverable — last-wins would desync a kept-alive
            // connection from any front proxy honoring the first value
            // (CL.CL request smuggling).
            if name == "content-length" {
                if let Some(prev) = headers.get(&name) {
                    if *prev != value {
                        return Err(Response::error(
                            400,
                            "conflicting Content-Length headers",
                        ));
                    }
                }
            }
            headers.insert(name, value);
        }
    }

    // No chunked decoding here — and with persistent connections an
    // unconsumed chunked body would be re-parsed as the next "request"
    // (request smuggling), so Transfer-Encoding must be refused outright,
    // not ignored.
    if headers.contains_key("transfer-encoding") {
        return Err(Response::error(501, "Transfer-Encoding is not supported"));
    }
    let content_length: usize = headers
        .get("content-length")
        .map(|v| v.parse().map_err(|_| Response::error(400, "bad Content-Length")))
        .transpose()?
        .unwrap_or(0);
    if content_length > MAX_BODY {
        return Err(Response::error(413, "body too large"));
    }
    let mut body = vec![0u8; content_length];
    if content_length > 0 {
        reader
            .read_exact(&mut body)
            .map_err(|e| Response::error(400, &format!("reading body: {e}")))?;
    }

    let keep_alive = match headers.get("connection") {
        Some(v) => {
            let tokens: Vec<String> =
                v.split(',').map(|t| t.trim().to_ascii_lowercase()).collect();
            if tokens.iter().any(|t| t == "close") {
                false
            } else if tokens.iter().any(|t| t == "keep-alive") {
                true
            } else {
                http_11
            }
        }
        None => http_11,
    };

    Ok(Some(Request {
        method: method.to_ascii_uppercase(),
        path: path.to_string(),
        query,
        headers,
        body,
        keep_alive,
    }))
}

/// A failed line read, keeping enough context for the caller to decide
/// between "idle peer went away" (no response owed) and a 4xx.
enum LineError {
    TooLong { what: &'static str, status: u16 },
    Io { what: &'static str, partial: bool, err: std::io::Error },
}

impl LineError {
    fn into_response(self) -> Response {
        match self {
            LineError::TooLong { what, status } => Response::error(
                status,
                &format!("{what} too long (limit {MAX_LINE} bytes)"),
            ),
            LineError::Io { what, err, .. } => {
                Response::error(400, &format!("reading {what}: {err}"))
            }
        }
    }
}

/// Read one newline-terminated line, refusing to buffer more than
/// [`MAX_LINE`] bytes of it: the `take` adapter bounds how much a single
/// line can pull off the socket, and overlong lines become
/// `too_long_status` (414 for the request line, 413 for header lines)
/// without the unread remainder ever being allocated. A clean EOF yields
/// an empty string.
fn read_line_capped<R: BufRead>(
    reader: &mut R,
    what: &'static str,
    too_long_status: u16,
) -> Result<String, LineError> {
    let mut line = String::new();
    let result = reader.take(MAX_LINE as u64 + 1).read_line(&mut line);
    if let Err(err) = result {
        return Err(LineError::Io { what, partial: !line.is_empty(), err });
    }
    if line.len() > MAX_LINE {
        return Err(LineError::TooLong { what, status: too_long_status });
    }
    Ok(line)
}

fn split_target(target: &str) -> (&str, HashMap<String, String>) {
    match target.split_once('?') {
        None => (target, HashMap::new()),
        Some((path, qs)) => {
            let mut query = HashMap::new();
            for pair in qs.split('&').filter(|p| !p.is_empty()) {
                let (k, v) = pair.split_once('=').unwrap_or((pair, ""));
                query.insert(percent_decode(k), percent_decode(v));
            }
            (path, query)
        }
    }
}

/// Percent-decoding for query strings ('+' → space, %XX → byte).
pub fn percent_decode(s: &str) -> String {
    let bytes = s.as_bytes();
    let mut out = Vec::with_capacity(bytes.len());
    let mut i = 0;
    while i < bytes.len() {
        match bytes[i] {
            b'+' => {
                out.push(b' ');
                i += 1;
            }
            b'%' => {
                if let Some(hex) = bytes.get(i + 1..i + 3) {
                    if let Ok(v) =
                        u8::from_str_radix(std::str::from_utf8(hex).unwrap_or("zz"), 16)
                    {
                        out.push(v);
                        i += 3;
                        continue;
                    }
                }
                out.push(b'%');
                i += 1;
            }
            b => {
                out.push(b);
                i += 1;
            }
        }
    }
    String::from_utf8_lossy(&out).into_owned()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn response_serialization() {
        let r = Response::json(200, &crate::util::json::Json::obj().with("ok", true));
        let mut buf = Vec::new();
        r.write_to(&mut buf).unwrap();
        let text = String::from_utf8(buf).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(text.contains("Content-Type: application/json"));
        assert!(text.ends_with("{\"ok\":true}"));
    }

    #[test]
    fn status_texts() {
        assert_eq!(Response::status_text(404), "Not Found");
        assert_eq!(Response::status_text(409), "Conflict");
        assert_eq!(Response::status_text(999), "Unknown");
    }

    #[test]
    fn target_splitting_and_decoding() {
        let (path, q) = split_target("/v1/stats?a=1&name=skew%2Dsmall&b=x+y");
        assert_eq!(path, "/v1/stats");
        assert_eq!(q.get("a").unwrap(), "1");
        assert_eq!(q.get("name").unwrap(), "skew-small");
        assert_eq!(q.get("b").unwrap(), "x y");
    }

    #[test]
    fn percent_decode_edge_cases() {
        assert_eq!(percent_decode("abc"), "abc");
        assert_eq!(percent_decode("%41%42"), "AB");
        assert_eq!(percent_decode("100%"), "100%");
        assert_eq!(percent_decode("%zz"), "%zz");
    }

    #[test]
    fn request_helpers() {
        let r = Request {
            method: "GET".into(),
            path: "/v1/workloads/42".into(),
            query: HashMap::new(),
            headers: HashMap::new(),
            body: b"hello".to_vec(),
            keep_alive: true,
        };
        assert_eq!(r.segments(), vec!["v1", "workloads", "42"]);
        assert_eq!(r.body_str().unwrap(), "hello");
    }

    fn parse_bytes(bytes: &[u8]) -> Result<Option<Request>, Response> {
        parse_request_from(&mut &bytes[..])
    }

    #[test]
    fn keep_alive_negotiation() {
        // HTTP/1.1 defaults to keep-alive.
        let r = parse_bytes(b"GET / HTTP/1.1\r\nHost: x\r\n\r\n").unwrap().unwrap();
        assert!(r.keep_alive);
        // Explicit close wins.
        let r = parse_bytes(b"GET / HTTP/1.1\r\nConnection: close\r\n\r\n")
            .unwrap()
            .unwrap();
        assert!(!r.keep_alive);
        // Token lists are scanned ("keep-alive, TE"), case-insensitive.
        let r = parse_bytes(b"GET / HTTP/1.0\r\nConnection: Keep-Alive, TE\r\n\r\n")
            .unwrap()
            .unwrap();
        assert!(r.keep_alive);
        // HTTP/1.0 without opt-in closes.
        let r = parse_bytes(b"GET / HTTP/1.0\r\n\r\n").unwrap().unwrap();
        assert!(!r.keep_alive);
        // close beats keep-alive if a confused client sends both.
        let r = parse_bytes(b"GET / HTTP/1.1\r\nConnection: keep-alive, close\r\n\r\n")
            .unwrap()
            .unwrap();
        assert!(!r.keep_alive);
    }

    #[test]
    fn eof_between_requests_is_none_not_an_error() {
        assert!(parse_bytes(b"").unwrap().is_none());
    }

    #[test]
    fn transfer_encoding_is_refused_not_desynced() {
        // A chunked body the parser would never consume must close the
        // connection with 501, not linger in the buffer to be smuggled as
        // the next pipelined request.
        let err = parse_bytes(
            b"POST /v1/workloads HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n\
              2A\r\nGET /v1/maintenance/defrag HTTP/1.1\r\n\r\n",
        )
        .unwrap_err();
        assert_eq!(err.status, 501);
    }

    #[test]
    fn conflicting_content_length_is_rejected() {
        let err = parse_bytes(
            b"POST /x HTTP/1.1\r\nContent-Length: 0\r\nContent-Length: 31\r\n\r\n",
        )
        .unwrap_err();
        assert_eq!(err.status, 400);
        // Identical repeated values are tolerated (RFC 9110 §8.6).
        let r = parse_bytes(
            b"POST /x HTTP/1.1\r\nContent-Length: 2\r\nContent-Length: 2\r\n\r\nhi",
        )
        .unwrap()
        .unwrap();
        assert_eq!(r.body, b"hi");
    }

    #[test]
    fn leading_bare_crlf_is_skipped_per_rfc_9112() {
        let r = parse_bytes(b"\r\nGET /x HTTP/1.1\r\n\r\n").unwrap().unwrap();
        assert_eq!(r.path, "/x");
        let r = parse_bytes(b"\r\n\r\nGET /y HTTP/1.1\r\n\r\n").unwrap().unwrap();
        assert_eq!(r.path, "/y");
        // A blank-line-only connection is a clean close, not a 400.
        assert!(parse_bytes(b"\r\n").unwrap().is_none());
        // But an endless stream of blank lines is not tolerated.
        assert!(parse_bytes(b"\r\n\r\n\r\n\r\nGET /z HTTP/1.1\r\n\r\n").is_err());
    }

    #[test]
    fn pipelined_requests_parse_sequentially_from_one_reader() {
        let bytes: &[u8] =
            b"GET /a HTTP/1.1\r\n\r\nPOST /b HTTP/1.1\r\nContent-Length: 2\r\n\r\nhiGET /c HTTP/1.1\r\nConnection: close\r\n\r\n";
        let mut reader = &bytes[..];
        let a = parse_request_from(&mut reader).unwrap().unwrap();
        assert_eq!((a.method.as_str(), a.path.as_str()), ("GET", "/a"));
        assert!(a.keep_alive);
        let b = parse_request_from(&mut reader).unwrap().unwrap();
        assert_eq!((b.method.as_str(), b.path.as_str()), ("POST", "/b"));
        assert_eq!(b.body, b"hi");
        let c = parse_request_from(&mut reader).unwrap().unwrap();
        assert_eq!(c.path, "/c");
        assert!(!c.keep_alive);
        assert!(parse_request_from(&mut reader).unwrap().is_none());
    }

    #[test]
    fn write_conn_sets_the_connection_header() {
        let r = Response::text(200, "ok");
        let mut buf = Vec::new();
        r.write_conn(&mut buf, true).unwrap();
        let text = String::from_utf8(buf).unwrap();
        assert!(text.contains("Connection: keep-alive\r\n"));
        let mut buf = Vec::new();
        r.write_conn(&mut buf, false).unwrap();
        assert!(String::from_utf8(buf).unwrap().contains("Connection: close\r\n"));
    }

    // Socket-level coverage of the daemon's connection loop (keep-alive,
    // pipelining, caps) lives in rust/tests/server_api.rs.
}
