//! Minimal HTTP/1.1 request parsing and response writing over blocking
//! TCP streams — just enough protocol for the JSON control-plane API
//! (no chunked encoding, no keep-alive pipelining, 1 MiB body cap,
//! 8 KiB request-/header-line cap).

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;

/// Maximum accepted request body (1 MiB — control-plane payloads are tiny).
pub const MAX_BODY: usize = 1 << 20;

/// Maximum accepted request-line / header-line length. Lines are read
/// incrementally, so a client streaming one endless line is cut off at
/// this bound (413) instead of growing the buffer without limit.
pub const MAX_LINE: usize = 8 << 10;

/// A parsed HTTP request.
#[derive(Debug, Clone)]
pub struct Request {
    pub method: String,
    /// Path without query string.
    pub path: String,
    /// Decoded query parameters.
    pub query: HashMap<String, String>,
    pub headers: HashMap<String, String>,
    pub body: Vec<u8>,
}

impl Request {
    /// Body as UTF-8 (empty string when absent).
    pub fn body_str(&self) -> Result<&str, String> {
        std::str::from_utf8(&self.body).map_err(|_| "body is not valid UTF-8".to_string())
    }

    /// Split the path into non-empty segments.
    pub fn segments(&self) -> Vec<&str> {
        self.path.split('/').filter(|s| !s.is_empty()).collect()
    }
}

/// An HTTP response under construction.
#[derive(Debug, Clone)]
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: Vec<u8>,
}

impl Response {
    pub fn json(status: u16, body: &crate::util::json::Json) -> Self {
        Self {
            status,
            content_type: "application/json",
            body: body.to_string_compact().into_bytes(),
        }
    }

    pub fn text(status: u16, body: &str) -> Self {
        Self { status, content_type: "text/plain; charset=utf-8", body: body.as_bytes().to_vec() }
    }

    pub fn error(status: u16, message: &str) -> Self {
        Self::json(status, &crate::util::json::Json::obj().with("error", message))
    }

    fn status_text(status: u16) -> &'static str {
        match status {
            200 => "OK",
            201 => "Created",
            204 => "No Content",
            400 => "Bad Request",
            404 => "Not Found",
            405 => "Method Not Allowed",
            409 => "Conflict",
            413 => "Payload Too Large",
            414 => "URI Too Long",
            429 => "Too Many Requests",
            500 => "Internal Server Error",
            503 => "Service Unavailable",
            _ => "Unknown",
        }
    }

    /// Serialize onto a stream.
    pub fn write_to(&self, stream: &mut dyn Write) -> std::io::Result<()> {
        write!(
            stream,
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
            self.status,
            Self::status_text(self.status),
            self.content_type,
            self.body.len()
        )?;
        stream.write_all(&self.body)?;
        stream.flush()
    }
}

/// Parse one request from a stream. Returns `Err(Response)` with the
/// appropriate 4xx for malformed input.
pub fn parse_request(stream: &mut TcpStream) -> Result<Request, Response> {
    let mut reader = BufReader::new(stream);
    // RFC 9110: an overlong request target is 414, overlong header
    // fields are 413 (we cap per line rather than per field set).
    let request_line = read_line_capped(&mut reader, "request line", 414)?;
    let mut parts = request_line.split_whitespace();
    let method = parts.next().ok_or_else(|| Response::error(400, "missing method"))?;
    let target = parts.next().ok_or_else(|| Response::error(400, "missing path"))?;
    let version = parts.next().unwrap_or("HTTP/1.1");
    if !version.starts_with("HTTP/1.") {
        return Err(Response::error(400, "unsupported HTTP version"));
    }

    let (path, query) = split_target(target);

    let mut headers = HashMap::new();
    let mut header_lines = 0usize;
    loop {
        let line = read_line_capped(&mut reader, "headers", 413)?;
        let line = line.trim_end();
        if line.is_empty() {
            break;
        }
        // Count LINES read, not parsed entries: colon-less or
        // duplicate-name lines must also hit the bound, or a client
        // streaming junk lines under the length cap pins a worker forever.
        header_lines += 1;
        if header_lines > 100 {
            return Err(Response::error(400, "too many headers"));
        }
        if let Some((name, value)) = line.split_once(':') {
            headers.insert(name.trim().to_ascii_lowercase(), value.trim().to_string());
        }
    }

    let content_length: usize = headers
        .get("content-length")
        .map(|v| v.parse().map_err(|_| Response::error(400, "bad Content-Length")))
        .transpose()?
        .unwrap_or(0);
    if content_length > MAX_BODY {
        return Err(Response::error(413, "body too large"));
    }
    let mut body = vec![0u8; content_length];
    if content_length > 0 {
        reader
            .read_exact(&mut body)
            .map_err(|e| Response::error(400, &format!("reading body: {e}")))?;
    }

    Ok(Request {
        method: method.to_ascii_uppercase(),
        path: path.to_string(),
        query,
        headers,
        body,
    })
}

/// Read one newline-terminated line, refusing to buffer more than
/// [`MAX_LINE`] bytes of it: the `take` adapter bounds how much a single
/// line can pull off the socket, and overlong lines become
/// `too_long_status` (414 for the request line, 413 for header lines)
/// without the unread remainder ever being allocated.
fn read_line_capped<R: BufRead>(
    reader: &mut R,
    what: &str,
    too_long_status: u16,
) -> Result<String, Response> {
    let mut line = String::new();
    reader
        .take(MAX_LINE as u64 + 1)
        .read_line(&mut line)
        .map_err(|e| Response::error(400, &format!("reading {what}: {e}")))?;
    if line.len() > MAX_LINE {
        return Err(Response::error(
            too_long_status,
            &format!("{what} too long (limit {MAX_LINE} bytes)"),
        ));
    }
    Ok(line)
}

fn split_target(target: &str) -> (&str, HashMap<String, String>) {
    match target.split_once('?') {
        None => (target, HashMap::new()),
        Some((path, qs)) => {
            let mut query = HashMap::new();
            for pair in qs.split('&').filter(|p| !p.is_empty()) {
                let (k, v) = pair.split_once('=').unwrap_or((pair, ""));
                query.insert(percent_decode(k), percent_decode(v));
            }
            (path, query)
        }
    }
}

/// Percent-decoding for query strings ('+' → space, %XX → byte).
pub fn percent_decode(s: &str) -> String {
    let bytes = s.as_bytes();
    let mut out = Vec::with_capacity(bytes.len());
    let mut i = 0;
    while i < bytes.len() {
        match bytes[i] {
            b'+' => {
                out.push(b' ');
                i += 1;
            }
            b'%' => {
                if let Some(hex) = bytes.get(i + 1..i + 3) {
                    if let Ok(v) =
                        u8::from_str_radix(std::str::from_utf8(hex).unwrap_or("zz"), 16)
                    {
                        out.push(v);
                        i += 3;
                        continue;
                    }
                }
                out.push(b'%');
                i += 1;
            }
            b => {
                out.push(b);
                i += 1;
            }
        }
    }
    String::from_utf8_lossy(&out).into_owned()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn response_serialization() {
        let r = Response::json(200, &crate::util::json::Json::obj().with("ok", true));
        let mut buf = Vec::new();
        r.write_to(&mut buf).unwrap();
        let text = String::from_utf8(buf).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(text.contains("Content-Type: application/json"));
        assert!(text.ends_with("{\"ok\":true}"));
    }

    #[test]
    fn status_texts() {
        assert_eq!(Response::status_text(404), "Not Found");
        assert_eq!(Response::status_text(409), "Conflict");
        assert_eq!(Response::status_text(999), "Unknown");
    }

    #[test]
    fn target_splitting_and_decoding() {
        let (path, q) = split_target("/v1/stats?a=1&name=skew%2Dsmall&b=x+y");
        assert_eq!(path, "/v1/stats");
        assert_eq!(q.get("a").unwrap(), "1");
        assert_eq!(q.get("name").unwrap(), "skew-small");
        assert_eq!(q.get("b").unwrap(), "x y");
    }

    #[test]
    fn percent_decode_edge_cases() {
        assert_eq!(percent_decode("abc"), "abc");
        assert_eq!(percent_decode("%41%42"), "AB");
        assert_eq!(percent_decode("100%"), "100%");
        assert_eq!(percent_decode("%zz"), "%zz");
    }

    #[test]
    fn request_helpers() {
        let r = Request {
            method: "GET".into(),
            path: "/v1/workloads/42".into(),
            query: HashMap::new(),
            headers: HashMap::new(),
            body: b"hello".to_vec(),
        };
        assert_eq!(r.segments(), vec!["v1", "workloads", "42"]);
        assert_eq!(r.body_str().unwrap(), "hello");
    }

    // Socket-level parse_request coverage lives in rust/tests/server_api.rs.
}
