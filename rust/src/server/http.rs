//! Minimal HTTP/1.1 request parsing and response writing — just enough
//! protocol for the JSON control-plane API (no chunked encoding, 1 MiB
//! body cap, 8 KiB request-/header-line cap).
//!
//! Two parse entry points share one grammar:
//!
//! * [`parse_request_from`] reads sequential requests off a blocking
//!   `BufRead` (the threadpool serve model; pipelined bytes buffered past
//!   the first request are never dropped).
//! * [`parse_request_bytes`] is the non-blocking form used by the
//!   [`super::reactor`] event loop: it scans a connection's accumulated
//!   read buffer and either yields a request plus its consumed byte
//!   count, asks for more bytes ([`Parse::Incomplete`]), or reports the
//!   same errors the blocking path would. A differential test below pins
//!   the two parsers byte-for-byte against each other.
//!
//! Persistent connections ARE supported: [`Request`] carries the
//! negotiated `keep_alive` flag (HTTP/1.1 default-on, HTTP/1.0 opt-in,
//! `Connection: close` always wins) and [`Response::write_conn`] /
//! [`Response::render_into`] emit the matching `Connection:` header. The
//! per-connection loop — request cap, idle timeout — lives in
//! [`super::daemon`] and [`super::reactor`].

use std::collections::HashMap;
use std::io::{BufRead, Read, Write};
use std::sync::Arc;

use crate::util::small::SmallVec;

/// Maximum accepted request body (1 MiB — control-plane payloads are tiny).
pub const MAX_BODY: usize = 1 << 20;

/// Maximum accepted request-line / header-line length. Lines are read
/// incrementally, so a client streaming one endless line is cut off at
/// this bound (413) instead of growing the buffer without limit.
pub const MAX_LINE: usize = 8 << 10;

/// A parsed HTTP request.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub method: String,
    /// Path without query string.
    pub path: String,
    /// Decoded query parameters.
    pub query: HashMap<String, String>,
    /// Lowercased header names → values, in arrival order. A plain vector
    /// beats a `HashMap` here: requests carry a handful of headers and
    /// the daemon probes at most three of them.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
    /// Whether the client's version + `Connection` header allow reusing
    /// the connection for another request after the response.
    pub keep_alive: bool,
}

impl Request {
    /// Body as UTF-8 (empty string when absent).
    pub fn body_str(&self) -> Result<&str, String> {
        std::str::from_utf8(&self.body).map_err(|_| "body is not valid UTF-8".to_string())
    }

    /// Split the path into non-empty segments. Control-plane paths have
    /// at most three, so the result stays on the stack.
    pub fn segments(&self) -> SmallVec<&str, 8> {
        self.path.split('/').filter(|s| !s.is_empty()).collect()
    }

    /// Header lookup by (lowercased) name.
    pub fn header(&self, name: &str) -> Option<&str> {
        header_get(&self.headers, name)
    }
}

/// A response body: owned bytes for dynamic payloads, or preserialized
/// bytes (`Static` for compile-time constants, `Shared` for startup-time
/// renders like `/v1/version`) so fixed responses serialize without
/// per-request allocation. Derefs to `[u8]`.
#[derive(Debug, Clone)]
pub enum Body {
    Owned(Vec<u8>),
    Static(&'static [u8]),
    Shared(Arc<[u8]>),
}

impl std::ops::Deref for Body {
    type Target = [u8];

    fn deref(&self) -> &[u8] {
        match self {
            Body::Owned(b) => b,
            Body::Static(b) => b,
            Body::Shared(b) => b,
        }
    }
}

impl From<Vec<u8>> for Body {
    fn from(b: Vec<u8>) -> Self {
        Body::Owned(b)
    }
}

impl From<&'static [u8]> for Body {
    fn from(b: &'static [u8]) -> Self {
        Body::Static(b)
    }
}

impl From<Arc<[u8]>> for Body {
    fn from(b: Arc<[u8]>) -> Self {
        Body::Shared(b)
    }
}

/// An HTTP response under construction.
#[derive(Debug, Clone)]
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: Body,
}

impl Response {
    pub fn json(status: u16, body: &crate::util::json::Json) -> Self {
        Self {
            status,
            content_type: "application/json",
            body: Body::Owned(body.to_string_compact().into_bytes()),
        }
    }

    pub fn text(status: u16, body: &str) -> Self {
        Self {
            status,
            content_type: "text/plain; charset=utf-8",
            body: Body::Owned(body.as_bytes().to_vec()),
        }
    }

    pub fn error(status: u16, message: &str) -> Self {
        Self::json(status, &crate::util::json::Json::obj().with("error", message))
    }

    /// A fixed-body JSON response from a preserialized `'static`
    /// fragment. Callers pin the bytes equal to the dynamic form in
    /// tests.
    pub fn static_json(status: u16, body: &'static [u8]) -> Self {
        Self { status, content_type: "application/json", body: Body::Static(body) }
    }

    /// A JSON response sharing bytes rendered once at startup (e.g.
    /// `/v1/version`); serializing it is a refcount bump, not a copy.
    pub fn shared_json(status: u16, body: Arc<[u8]>) -> Self {
        Self { status, content_type: "application/json", body: Body::Shared(body) }
    }

    /// A response with an explicit content type (e.g. the Prometheus
    /// exposition type on `GET /metrics`).
    pub fn with_content_type(status: u16, content_type: &'static str, body: Vec<u8>) -> Self {
        Self { status, content_type, body: Body::Owned(body) }
    }

    fn status_text(status: u16) -> &'static str {
        match status {
            200 => "OK",
            201 => "Created",
            204 => "No Content",
            400 => "Bad Request",
            404 => "Not Found",
            405 => "Method Not Allowed",
            409 => "Conflict",
            413 => "Payload Too Large",
            414 => "URI Too Long",
            429 => "Too Many Requests",
            500 => "Internal Server Error",
            501 => "Not Implemented",
            503 => "Service Unavailable",
            _ => "Unknown",
        }
    }

    /// Serialize onto a stream, closing the connection afterwards.
    pub fn write_to(&self, stream: &mut dyn Write) -> std::io::Result<()> {
        self.write_conn(stream, false)
    }

    /// Serialize onto a stream with an explicit connection disposition.
    /// Responses always carry `Content-Length`, so a kept-alive peer
    /// knows exactly where the next response begins.
    pub fn write_conn(&self, stream: &mut dyn Write, keep_alive: bool) -> std::io::Result<()> {
        write!(
            stream,
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: {}\r\n\r\n",
            self.status,
            Self::status_text(self.status),
            self.content_type,
            self.body.len(),
            if keep_alive { "keep-alive" } else { "close" }
        )?;
        stream.write_all(&self.body)?;
        stream.flush()
    }

    /// Append the full wire form (status line, headers, body) onto a
    /// reusable buffer — the reactor's per-connection write path. The
    /// bytes are identical to [`Response::write_conn`].
    pub fn render_into(&self, out: &mut Vec<u8>, keep_alive: bool) {
        let _ = write!(
            out,
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: {}\r\n\r\n",
            self.status,
            Self::status_text(self.status),
            self.content_type,
            self.body.len(),
            if keep_alive { "keep-alive" } else { "close" }
        );
        out.extend_from_slice(&self.body);
    }
}

/// Parse one request from a shared buffered reader — the threadpool
/// model's parse entry point. `Ok(None)` means the client closed (or went
/// idle past the read timeout) *between* requests: nothing to answer,
/// close quietly. A connection that dies mid-request is still an error.
///
/// The reader must be reused across calls on one connection: pipelined
/// clients send request N+1's bytes before response N, and those bytes
/// live in this reader's buffer.
pub fn parse_request_from<R: BufRead>(reader: &mut R) -> Result<Option<Request>, Response> {
    // RFC 9110: an overlong request target is 414, overlong header
    // fields are 413 (we cap per line rather than per field set).
    // RFC 9112 §2.2 robustness: ignore a couple of empty lines before the
    // request line (clients historically terminate bodies with a stray
    // CRLF not counted in Content-Length).
    let mut request_line = None;
    for _ in 0..3 {
        match read_line_capped(reader, "request line", 414) {
            Ok(line) if line.is_empty() => return Ok(None), // clean EOF
            Ok(line) if line.trim_end().is_empty() => continue, // bare CRLF
            Ok(line) => {
                request_line = Some(line);
                break;
            }
            // Nothing of a request seen yet → idle close, not an error.
            Err(LineError::Io { partial: false, .. }) => return Ok(None),
            Err(e) => return Err(e.into_response()),
        }
    }
    let request_line =
        request_line.ok_or_else(|| Response::error(400, "missing method"))?;
    let (method, target, http_11) = parse_request_line(&request_line)?;
    let (path, query) = split_target(target);

    let mut headers: Vec<(String, String)> = Vec::new();
    let mut header_lines = 0usize;
    loop {
        let line = read_line_capped(reader, "headers", 413)
            .map_err(LineError::into_response)?;
        let line = line.trim_end();
        if line.is_empty() {
            break;
        }
        header_lines += 1;
        accept_header_line(&mut headers, line, header_lines)?;
    }

    let content_length = body_length(&headers)?;
    let mut body = vec![0u8; content_length];
    if content_length > 0 {
        reader
            .read_exact(&mut body)
            .map_err(|e| Response::error(400, &format!("reading body: {e}")))?;
    }

    let keep_alive = negotiate_keep_alive(header_get(&headers, "connection"), http_11);

    Ok(Some(Request {
        method: method.to_ascii_uppercase(),
        path: path.to_string(),
        query,
        headers,
        body,
        keep_alive,
    }))
}

/// Outcome of [`parse_request_bytes`] on a connection's read buffer.
#[derive(Debug)]
pub enum Parse {
    /// Not enough bytes buffered yet — keep reading.
    Incomplete,
    /// One complete request, occupying the first `consumed` bytes of the
    /// buffer. The caller drains those bytes before re-parsing (pipelined
    /// requests follow immediately).
    Done { request: Request, consumed: usize },
    /// Clean end of stream — empty or blank-only buffer at EOF, or bytes
    /// the blocking parser treats as an idle disconnect. Close without
    /// answering.
    Eof,
    /// Malformed request: answer with the response, then close.
    Bad(Response),
}

/// Parse one request from an accumulated read buffer — the reactor's
/// non-blocking parse entry point. `eof` says the peer half-closed, which
/// (matching the blocking parser's `read_line`/`read_exact` semantics)
/// turns "wait for more bytes" into either a final unterminated line or
/// a hard error.
pub fn parse_request_bytes(buf: &[u8], eof: bool) -> Parse {
    let mut pos = 0usize;

    // Request line, skipping up to two bare CRLFs (RFC 9112 §2.2) — the
    // same tolerance window as the blocking parser.
    let mut request_line = None;
    for _ in 0..3 {
        match take_line(buf, pos, eof) {
            LineOutcome::Partial => return Parse::Incomplete,
            LineOutcome::End => return Parse::Eof,
            // The blocking parser treats undecodable bytes before a
            // request line as an idle disconnect (its read_line fails
            // without yielding a partial line) — close quietly.
            LineOutcome::Utf8 => return Parse::Eof,
            LineOutcome::TooLong => {
                return Parse::Bad(
                    LineError::TooLong { what: "request line", status: 414 }.into_response(),
                )
            }
            LineOutcome::Full(line, next) => {
                pos = next;
                if line.trim_end().is_empty() {
                    continue;
                }
                request_line = Some(line);
                break;
            }
        }
    }
    let Some(request_line) = request_line else {
        return Parse::Bad(Response::error(400, "missing method"));
    };
    let (method, target, http_11) = match parse_request_line(request_line) {
        Ok(parts) => parts,
        Err(resp) => return Parse::Bad(resp),
    };
    let (path, query) = split_target(target);

    let mut headers: Vec<(String, String)> = Vec::new();
    let mut header_lines = 0usize;
    loop {
        let line = match take_line(buf, pos, eof) {
            LineOutcome::Partial => return Parse::Incomplete,
            // EOF ends the header block the same way a blank line does
            // (read_line yields "" there).
            LineOutcome::End => break,
            LineOutcome::Utf8 => {
                return Parse::Bad(Response::error(
                    400,
                    "reading headers: stream did not contain valid UTF-8",
                ))
            }
            LineOutcome::TooLong => {
                return Parse::Bad(
                    LineError::TooLong { what: "headers", status: 413 }.into_response(),
                )
            }
            LineOutcome::Full(line, next) => {
                pos = next;
                line
            }
        };
        let line = line.trim_end();
        if line.is_empty() {
            break;
        }
        header_lines += 1;
        if let Err(resp) = accept_header_line(&mut headers, line, header_lines) {
            return Parse::Bad(resp);
        }
    }

    let content_length = match body_length(&headers) {
        Ok(n) => n,
        Err(resp) => return Parse::Bad(resp),
    };
    if buf.len() - pos < content_length {
        if eof {
            // read_exact's UnexpectedEof, verbatim.
            return Parse::Bad(Response::error(
                400,
                "reading body: failed to fill whole buffer",
            ));
        }
        return Parse::Incomplete;
    }
    let body = buf[pos..pos + content_length].to_vec();
    pos += content_length;

    let keep_alive = negotiate_keep_alive(header_get(&headers, "connection"), http_11);

    Parse::Done {
        request: Request {
            method: method.to_ascii_uppercase(),
            path: path.to_string(),
            query,
            headers,
            body,
            keep_alive,
        },
        consumed: pos,
    }
}

/// Split a request line into method, target and the HTTP/1.1-ness of the
/// version token; shared by both parsers so their rejections match.
fn parse_request_line(line: &str) -> Result<(&str, &str, bool), Response> {
    let mut parts = line.split_whitespace();
    let method = parts.next().ok_or_else(|| Response::error(400, "missing method"))?;
    let target = parts.next().ok_or_else(|| Response::error(400, "missing path"))?;
    let version = parts.next().unwrap_or("HTTP/1.1");
    if !version.starts_with("HTTP/1.") {
        return Err(Response::error(400, "unsupported HTTP version"));
    }
    // HTTP/1.1 defaults to persistent connections; 1.0 must opt in.
    Ok((method, target, version != "HTTP/1.0"))
}

/// Fold one non-blank header line into `headers`, enforcing the line cap
/// and the anti-smuggling Content-Length conflict check.
fn accept_header_line(
    headers: &mut Vec<(String, String)>,
    line: &str,
    header_lines: usize,
) -> Result<(), Response> {
    // Count LINES read, not parsed entries: colon-less or duplicate-name
    // lines must also hit the bound, or a client streaming junk lines
    // under the length cap pins a connection forever.
    if header_lines > 100 {
        return Err(Response::error(400, "too many headers"));
    }
    if let Some((name, value)) = line.split_once(':') {
        let name = name.trim().to_ascii_lowercase();
        let value = value.trim();
        // RFC 9112 §6.3: conflicting Content-Length values are
        // unrecoverable — last-wins would desync a kept-alive connection
        // from any front proxy honoring the first value (CL.CL request
        // smuggling).
        if name == "content-length" {
            if let Some(prev) = header_get(headers, "content-length") {
                if prev != value {
                    return Err(Response::error(400, "conflicting Content-Length headers"));
                }
            }
        }
        if let Some(slot) = headers.iter_mut().find(|(n, _)| *n == name) {
            // Repeated names keep map semantics: last value wins.
            slot.1.clear();
            slot.1.push_str(value);
        } else {
            headers.push((name, value.to_string()));
        }
    }
    Ok(())
}

fn header_get<'a>(headers: &'a [(String, String)], name: &str) -> Option<&'a str> {
    headers.iter().find(|(n, _)| n == name).map(|(_, v)| v.as_str())
}

/// Validate Transfer-Encoding / Content-Length and return the body size.
fn body_length(headers: &[(String, String)]) -> Result<usize, Response> {
    // No chunked decoding here — and with persistent connections an
    // unconsumed chunked body would be re-parsed as the next "request"
    // (request smuggling), so Transfer-Encoding must be refused outright,
    // not ignored.
    if header_get(headers, "transfer-encoding").is_some() {
        return Err(Response::error(501, "Transfer-Encoding is not supported"));
    }
    let content_length: usize = header_get(headers, "content-length")
        .map(|v| v.parse().map_err(|_| Response::error(400, "bad Content-Length")))
        .transpose()?
        .unwrap_or(0);
    if content_length > MAX_BODY {
        return Err(Response::error(413, "body too large"));
    }
    Ok(content_length)
}

fn negotiate_keep_alive(connection: Option<&str>, http_11: bool) -> bool {
    match connection {
        Some(v) => {
            let mut close = false;
            let mut keep = false;
            for token in v.split(',') {
                let token = token.trim();
                if token.eq_ignore_ascii_case("close") {
                    close = true;
                } else if token.eq_ignore_ascii_case("keep-alive") {
                    keep = true;
                }
            }
            if close {
                false
            } else {
                keep || http_11
            }
        }
        None => http_11,
    }
}

/// One line extracted from the read buffer.
enum LineOutcome<'a> {
    /// A complete line including its terminator (or the final
    /// unterminated line at EOF); `usize` is the offset just past it.
    Full(&'a str, usize),
    /// No terminator buffered yet and the stream is still open.
    Partial,
    /// `pos` is exactly the end of the buffer at EOF.
    End,
    /// Line exceeds [`MAX_LINE`].
    TooLong,
    /// The capped chunk is not valid UTF-8 (mirrors `read_line`'s
    /// error, including its check running *before* the length cap).
    Utf8,
}

/// Buffer-based equivalent of [`read_line_capped`]: examine at most
/// `MAX_LINE + 1` bytes from `pos`, classifying exactly like the
/// blocking reader (UTF-8 validation of the capped chunk first, then the
/// length bound; EOF turns a partial tail into a final line).
fn take_line(buf: &[u8], pos: usize, eof: bool) -> LineOutcome<'_> {
    let rest = &buf[pos..];
    let window = &rest[..rest.len().min(MAX_LINE + 1)];
    let chunk = match window.iter().position(|&b| b == b'\n') {
        Some(i) => &window[..=i],
        None if rest.len() > MAX_LINE => window, // cap hit with no terminator
        None if !eof => return LineOutcome::Partial,
        None if rest.is_empty() => return LineOutcome::End,
        None => window, // final unterminated line at EOF
    };
    let Ok(line) = std::str::from_utf8(chunk) else {
        return LineOutcome::Utf8;
    };
    if chunk.len() > MAX_LINE {
        return LineOutcome::TooLong;
    }
    LineOutcome::Full(line, pos + chunk.len())
}

/// A failed line read, keeping enough context for the caller to decide
/// between "idle peer went away" (no response owed) and a 4xx.
enum LineError {
    TooLong { what: &'static str, status: u16 },
    Io { what: &'static str, partial: bool, err: std::io::Error },
}

impl LineError {
    fn into_response(self) -> Response {
        match self {
            LineError::TooLong { what, status } => Response::error(
                status,
                &format!("{what} too long (limit {MAX_LINE} bytes)"),
            ),
            LineError::Io { what, err, .. } => {
                Response::error(400, &format!("reading {what}: {err}"))
            }
        }
    }
}

/// Read one newline-terminated line, refusing to buffer more than
/// [`MAX_LINE`] bytes of it: the `take` adapter bounds how much a single
/// line can pull off the socket, and overlong lines become
/// `too_long_status` (414 for the request line, 413 for header lines)
/// without the unread remainder ever being allocated. A clean EOF yields
/// an empty string.
fn read_line_capped<R: BufRead>(
    reader: &mut R,
    what: &'static str,
    too_long_status: u16,
) -> Result<String, LineError> {
    let mut line = String::new();
    let result = reader.take(MAX_LINE as u64 + 1).read_line(&mut line);
    if let Err(err) = result {
        return Err(LineError::Io { what, partial: !line.is_empty(), err });
    }
    if line.len() > MAX_LINE {
        return Err(LineError::TooLong { what, status: too_long_status });
    }
    Ok(line)
}

fn split_target(target: &str) -> (&str, HashMap<String, String>) {
    match target.split_once('?') {
        None => (target, HashMap::new()),
        Some((path, qs)) => {
            let mut query = HashMap::new();
            for pair in qs.split('&').filter(|p| !p.is_empty()) {
                let (k, v) = pair.split_once('=').unwrap_or((pair, ""));
                query.insert(percent_decode(k), percent_decode(v));
            }
            (path, query)
        }
    }
}

/// Percent-decoding for query strings ('+' → space, %XX → byte).
pub fn percent_decode(s: &str) -> String {
    let bytes = s.as_bytes();
    let mut out = Vec::with_capacity(bytes.len());
    let mut i = 0;
    while i < bytes.len() {
        match bytes[i] {
            b'+' => {
                out.push(b' ');
                i += 1;
            }
            b'%' => {
                if let Some(hex) = bytes.get(i + 1..i + 3) {
                    if let Ok(v) =
                        u8::from_str_radix(std::str::from_utf8(hex).unwrap_or("zz"), 16)
                    {
                        out.push(v);
                        i += 3;
                        continue;
                    }
                }
                out.push(b'%');
                i += 1;
            }
            b => {
                out.push(b);
                i += 1;
            }
        }
    }
    String::from_utf8_lossy(&out).into_owned()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn response_serialization() {
        let r = Response::json(200, &crate::util::json::Json::obj().with("ok", true));
        let mut buf = Vec::new();
        r.write_to(&mut buf).unwrap();
        let text = String::from_utf8(buf).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(text.contains("Content-Type: application/json"));
        assert!(text.ends_with("{\"ok\":true}"));
    }

    #[test]
    fn render_into_matches_write_conn_bytes() {
        for keep in [true, false] {
            for r in [
                Response::json(201, &crate::util::json::Json::obj().with("id", 7u64)),
                Response::static_json(400, br#"{"error":"missing JSON body"}"#),
                Response::text(200, "ok\n"),
            ] {
                let mut streamed = Vec::new();
                r.write_conn(&mut streamed, keep).unwrap();
                let mut rendered = Vec::new();
                r.render_into(&mut rendered, keep);
                assert_eq!(streamed, rendered);
            }
        }
    }

    #[test]
    fn render_into_appends_without_clearing() {
        let mut buf = b"previous".to_vec();
        Response::text(200, "x").render_into(&mut buf, true);
        assert!(buf.starts_with(b"previous"));
        assert!(buf.ends_with(b"x"));
    }

    #[test]
    fn body_variants_deref_to_the_same_bytes() {
        let owned = Body::Owned(b"abc".to_vec());
        let fixed = Body::Static(b"abc");
        let shared = Body::Shared(Arc::from(&b"abc"[..]));
        assert_eq!(&*owned, b"abc");
        assert_eq!(&*fixed, b"abc");
        assert_eq!(&*shared, b"abc");
        assert_eq!(owned.len(), 3);
    }

    #[test]
    fn status_texts() {
        assert_eq!(Response::status_text(404), "Not Found");
        assert_eq!(Response::status_text(409), "Conflict");
        assert_eq!(Response::status_text(999), "Unknown");
    }

    #[test]
    fn target_splitting_and_decoding() {
        let (path, q) = split_target("/v1/stats?a=1&name=skew%2Dsmall&b=x+y");
        assert_eq!(path, "/v1/stats");
        assert_eq!(q.get("a").unwrap(), "1");
        assert_eq!(q.get("name").unwrap(), "skew-small");
        assert_eq!(q.get("b").unwrap(), "x y");
    }

    #[test]
    fn percent_decode_edge_cases() {
        assert_eq!(percent_decode("abc"), "abc");
        assert_eq!(percent_decode("%41%42"), "AB");
        assert_eq!(percent_decode("100%"), "100%");
        assert_eq!(percent_decode("%zz"), "%zz");
    }

    #[test]
    fn request_helpers() {
        let r = Request {
            method: "GET".into(),
            path: "/v1/workloads/42".into(),
            query: HashMap::new(),
            headers: vec![("host".into(), "x".into())],
            body: b"hello".to_vec(),
            keep_alive: true,
        };
        assert_eq!(r.segments().as_slice(), &["v1", "workloads", "42"][..]);
        assert!(r.segments().is_inline());
        assert_eq!(r.body_str().unwrap(), "hello");
        assert_eq!(r.header("host"), Some("x"));
        assert_eq!(r.header("missing"), None);
    }

    fn parse_bytes(bytes: &[u8]) -> Result<Option<Request>, Response> {
        parse_request_from(&mut &bytes[..])
    }

    #[test]
    fn keep_alive_negotiation() {
        // HTTP/1.1 defaults to keep-alive.
        let r = parse_bytes(b"GET / HTTP/1.1\r\nHost: x\r\n\r\n").unwrap().unwrap();
        assert!(r.keep_alive);
        // Explicit close wins.
        let r = parse_bytes(b"GET / HTTP/1.1\r\nConnection: close\r\n\r\n")
            .unwrap()
            .unwrap();
        assert!(!r.keep_alive);
        // Token lists are scanned ("keep-alive, TE"), case-insensitive.
        let r = parse_bytes(b"GET / HTTP/1.0\r\nConnection: Keep-Alive, TE\r\n\r\n")
            .unwrap()
            .unwrap();
        assert!(r.keep_alive);
        // HTTP/1.0 without opt-in closes.
        let r = parse_bytes(b"GET / HTTP/1.0\r\n\r\n").unwrap().unwrap();
        assert!(!r.keep_alive);
        // close beats keep-alive if a confused client sends both.
        let r = parse_bytes(b"GET / HTTP/1.1\r\nConnection: keep-alive, close\r\n\r\n")
            .unwrap()
            .unwrap();
        assert!(!r.keep_alive);
    }

    #[test]
    fn eof_between_requests_is_none_not_an_error() {
        assert!(parse_bytes(b"").unwrap().is_none());
    }

    #[test]
    fn transfer_encoding_is_refused_not_desynced() {
        // A chunked body the parser would never consume must close the
        // connection with 501, not linger in the buffer to be smuggled as
        // the next pipelined request.
        let err = parse_bytes(
            b"POST /v1/workloads HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n\
              2A\r\nGET /v1/maintenance/defrag HTTP/1.1\r\n\r\n",
        )
        .unwrap_err();
        assert_eq!(err.status, 501);
    }

    #[test]
    fn conflicting_content_length_is_rejected() {
        let err = parse_bytes(
            b"POST /x HTTP/1.1\r\nContent-Length: 0\r\nContent-Length: 31\r\n\r\n",
        )
        .unwrap_err();
        assert_eq!(err.status, 400);
        // Identical repeated values are tolerated (RFC 9110 §8.6).
        let r = parse_bytes(
            b"POST /x HTTP/1.1\r\nContent-Length: 2\r\nContent-Length: 2\r\n\r\nhi",
        )
        .unwrap()
        .unwrap();
        assert_eq!(r.body, b"hi");
    }

    #[test]
    fn leading_bare_crlf_is_skipped_per_rfc_9112() {
        let r = parse_bytes(b"\r\nGET /x HTTP/1.1\r\n\r\n").unwrap().unwrap();
        assert_eq!(r.path, "/x");
        let r = parse_bytes(b"\r\n\r\nGET /y HTTP/1.1\r\n\r\n").unwrap().unwrap();
        assert_eq!(r.path, "/y");
        // A blank-line-only connection is a clean close, not a 400.
        assert!(parse_bytes(b"\r\n").unwrap().is_none());
        // But an endless stream of blank lines is not tolerated.
        assert!(parse_bytes(b"\r\n\r\n\r\n\r\nGET /z HTTP/1.1\r\n\r\n").is_err());
    }

    #[test]
    fn pipelined_requests_parse_sequentially_from_one_reader() {
        let bytes: &[u8] =
            b"GET /a HTTP/1.1\r\n\r\nPOST /b HTTP/1.1\r\nContent-Length: 2\r\n\r\nhiGET /c HTTP/1.1\r\nConnection: close\r\n\r\n";
        let mut reader = &bytes[..];
        let a = parse_request_from(&mut reader).unwrap().unwrap();
        assert_eq!((a.method.as_str(), a.path.as_str()), ("GET", "/a"));
        assert!(a.keep_alive);
        let b = parse_request_from(&mut reader).unwrap().unwrap();
        assert_eq!((b.method.as_str(), b.path.as_str()), ("POST", "/b"));
        assert_eq!(b.body, b"hi");
        let c = parse_request_from(&mut reader).unwrap().unwrap();
        assert_eq!(c.path, "/c");
        assert!(!c.keep_alive);
        assert!(parse_request_from(&mut reader).unwrap().is_none());
    }

    #[test]
    fn write_conn_sets_the_connection_header() {
        let r = Response::text(200, "ok");
        let mut buf = Vec::new();
        r.write_conn(&mut buf, true).unwrap();
        let text = String::from_utf8(buf).unwrap();
        assert!(text.contains("Connection: keep-alive\r\n"));
        let mut buf = Vec::new();
        r.write_conn(&mut buf, false).unwrap();
        assert!(String::from_utf8(buf).unwrap().contains("Connection: close\r\n"));
    }

    // ----- differential coverage: buffer parser vs blocking parser -----

    /// One step of either parser, normalized for comparison.
    #[derive(Debug, PartialEq)]
    enum Step {
        Req(Request),
        Close,
        Err(u16, Vec<u8>),
    }

    /// Drive the blocking parser over the whole byte string.
    fn blocking_steps(bytes: &[u8]) -> Vec<Step> {
        let mut reader = &bytes[..];
        let mut steps = Vec::new();
        loop {
            match parse_request_from(&mut reader) {
                Ok(Some(req)) => steps.push(Step::Req(req)),
                Ok(None) => {
                    steps.push(Step::Close);
                    return steps;
                }
                Err(resp) => {
                    steps.push(Step::Err(resp.status, resp.body.to_vec()));
                    return steps;
                }
            }
        }
    }

    /// Drive the buffer parser the way the reactor does: whole buffer
    /// available, EOF known, consumed prefix drained between requests.
    fn buffered_steps(bytes: &[u8]) -> Vec<Step> {
        let mut pos = 0usize;
        let mut steps = Vec::new();
        loop {
            match parse_request_bytes(&bytes[pos..], true) {
                Parse::Done { request, consumed } => {
                    pos += consumed;
                    steps.push(Step::Req(request));
                }
                Parse::Eof => {
                    steps.push(Step::Close);
                    return steps;
                }
                Parse::Bad(resp) => {
                    steps.push(Step::Err(resp.status, resp.body.to_vec()));
                    return steps;
                }
                Parse::Incomplete => panic!("Incomplete with eof=true"),
            }
        }
    }

    fn assert_parsers_agree(bytes: &[u8]) {
        assert_eq!(
            blocking_steps(bytes),
            buffered_steps(bytes),
            "parsers diverge on {:?}",
            String::from_utf8_lossy(bytes)
        );
    }

    #[test]
    fn buffer_parser_matches_blocking_parser_on_corpus() {
        let long_line = [b'a'; MAX_LINE + 10];
        let mut overlong_request = b"GET /".to_vec();
        overlong_request.extend_from_slice(&long_line);
        let mut overlong_header = b"GET /x HTTP/1.1\r\nX-Pad: ".to_vec();
        overlong_header.extend_from_slice(&long_line);
        let mut many_headers = b"GET /x HTTP/1.1\r\n".to_vec();
        for i in 0..120 {
            many_headers.extend_from_slice(format!("H{i}: v\r\n").as_bytes());
        }
        many_headers.extend_from_slice(b"\r\n");
        let corpus: Vec<Vec<u8>> = vec![
            b"".to_vec(),
            b"\r\n".to_vec(),
            b"\r\n\r\nGET /y HTTP/1.1\r\n\r\n".to_vec(),
            b"\r\n\r\n\r\n\r\nGET /z HTTP/1.1\r\n\r\n".to_vec(),
            b"GET / HTTP/1.1\r\nHost: x\r\n\r\n".to_vec(),
            b"GET / HTTP/1.0\r\nConnection: Keep-Alive, TE\r\n\r\n".to_vec(),
            b"GET / HTTP/1.1\r\nConnection: keep-alive, close\r\n\r\n".to_vec(),
            b"GET /a HTTP/1.1\r\n\r\nPOST /b HTTP/1.1\r\nContent-Length: 2\r\n\r\nhiGET /c HTTP/1.1\r\nConnection: close\r\n\r\n".to_vec(),
            b"POST /x HTTP/1.1\r\nContent-Length: 0\r\nContent-Length: 31\r\n\r\n".to_vec(),
            b"POST /x HTTP/1.1\r\nContent-Length: 2\r\nContent-Length: 2\r\n\r\nhi".to_vec(),
            b"POST /v1/workloads HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n2A\r\n".to_vec(),
            b"POST /x HTTP/1.1\r\nContent-Length: abc\r\n\r\n".to_vec(),
            b"POST /x HTTP/1.1\r\nContent-Length: 9999999999\r\n\r\n".to_vec(),
            b"POST /x HTTP/1.1\r\nContent-Length: 3\r\n\r\nab".to_vec(),
            b"GET\r\n\r\n".to_vec(),
            b"GET /x FTP/1.0\r\n\r\n".to_vec(),
            b"GET /x HTT".to_vec(),
            b"GET /s?a=1&b=x+y HTTP/1.1\r\n\r\n".to_vec(),
            b"get /lower http/1.1\r\n\r\n".to_vec(),
            b"GET /x HTTP/1.1\r\nNoColonLine\r\nHost: y\r\n\r\n".to_vec(),
            b"GET /x HTTP/1.1\r\nDup: a\r\nDup: b\r\n\r\n".to_vec(),
            b"GET /x HTTP/1.1\r\nHost: x".to_vec(),
            b"GET /x HTTP/1.1\r\nHost: x\r\n".to_vec(),
            b"   \r\nGET /ws HTTP/1.1\r\n\r\n".to_vec(),
            b"\xff\xfe nonsense".to_vec(),
            b"GET /x HTTP/1.1\r\nBad: \xff\xfe\r\n\r\n".to_vec(),
            overlong_request,
            overlong_header,
            many_headers,
        ];
        for bytes in &corpus {
            assert_parsers_agree(bytes);
        }
    }

    #[test]
    fn buffer_parser_matches_blocking_parser_under_fuzz() {
        // Splice random fragments together; whatever comes out, both
        // parsers must classify it identically.
        use crate::util::rng::Rng;
        let fragments: &[&[u8]] = &[
            b"GET ",
            b"POST ",
            b"/v1/workloads",
            b"/x?q=1",
            b" HTTP/1.1",
            b" HTTP/1.0",
            b"\r\n",
            b"\n",
            b"Content-Length: 2",
            b"Content-Length: 5",
            b"Connection: close",
            b"Connection: keep-alive",
            b"Transfer-Encoding: chunked",
            b"Host: example",
            b"hi",
            b"hello",
            b" ",
            b":",
            b"\xff",
        ];
        let mut rng = Rng::new(0x9A7C);
        for _ in 0..400 {
            let mut bytes = Vec::new();
            for _ in 0..rng.index(12) {
                bytes.extend_from_slice(rng.choose(fragments));
            }
            assert_parsers_agree(&bytes);
        }
    }

    #[test]
    fn buffer_parser_is_incremental_over_every_split_point() {
        // For every prefix of a pipelined stream, the parser either asks
        // for more bytes or yields exactly what the full buffer yields.
        let bytes: &[u8] =
            b"POST /b HTTP/1.1\r\nContent-Length: 2\r\n\r\nhiGET /c HTTP/1.1\r\nConnection: close\r\n\r\n";
        let Parse::Done { request: full, consumed } = parse_request_bytes(bytes, false)
        else {
            panic!("full buffer must parse");
        };
        for cut in 0..bytes.len() {
            match parse_request_bytes(&bytes[..cut], false) {
                Parse::Incomplete => assert!(cut < consumed, "stuck at {cut}"),
                Parse::Done { request, consumed: c } => {
                    assert_eq!(c, consumed, "at {cut}");
                    assert_eq!(request, full, "at {cut}");
                }
                other => panic!("unexpected {other:?} at cut {cut}"),
            }
        }
        // And with eof=false an empty buffer just waits.
        assert!(matches!(parse_request_bytes(b"", false), Parse::Incomplete));
    }

    // Socket-level coverage of the daemon's connection loop (keep-alive,
    // pipelining, caps) lives in rust/tests/server_api.rs.
}
