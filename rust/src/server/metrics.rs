//! The daemon's metric registry and the `GET /metrics` renderer.
//!
//! One [`ServerMetrics`] instance hangs off the [`ShardSet`]; the daemon's
//! connection loop, the API handlers and the defrag sweepers record into
//! it lock-free (see [`crate::obs::hist`]), and [`render`] serializes the
//! whole registry — plus the `/v1/stats` gauges re-read from the shards —
//! as Prometheus text exposition with a fixed family order.
//!
//! **Scrape-time invariant**: `migsched_http_responses_total` is rendered
//! *before* the request families, and a response is counted only after its
//! bytes hit the socket while requests are counted at dispatch, so any
//! single scrape observes `requests >= responses`. After quiescence the
//! two are exactly equal — the conservation law the soak test checks under
//! concurrent load.

use std::time::Duration;

use super::shard::ShardSet;
use crate::obs::expo::{Expo, Labels};
use crate::obs::hist::{Counter, DeltaHist, Gauge, LatencyHist};

/// The routes the daemon serves, as `(method, normalized path)` — the
/// label vocabulary of the HTTP families. Path parameters are collapsed
/// (`/v1/workloads/{id}`), so label cardinality is fixed no matter how
/// many workloads exist.
pub const ROUTES: [(&str, &str); 13] = [
    ("GET", "/healthz"),
    ("GET", "/metrics"),
    ("POST", "/v1/workloads"),
    ("GET", "/v1/workloads/{id}"),
    ("DELETE", "/v1/workloads/{id}"),
    ("POST", "/v1/tick"),
    ("GET", "/v1/stats"),
    ("GET", "/v1/cluster"),
    ("GET", "/v1/hardware"),
    ("GET", "/v1/healthz"),
    ("GET", "/v1/version"),
    ("POST", "/v1/maintenance/defrag"),
    ("POST", "/v1/submit/batch"),
];

/// Index of the catch-all route label (`other`): unknown paths, bad
/// methods, unparseable requests.
pub const ROUTE_OTHER: usize = ROUTES.len();
pub const NROUTES: usize = ROUTES.len() + 1;

/// Route label for index `i`.
pub fn route_label(i: usize) -> (&'static str, &'static str) {
    if i < ROUTES.len() {
        ROUTES[i]
    } else {
        ("", "other")
    }
}

/// Map a request to its route index. `segments` is the parsed path as in
/// [`super::http::Request::segments`].
pub fn route_index(method: &str, segments: &[&str]) -> usize {
    match (method, segments) {
        ("GET", ["healthz"]) => 0,
        ("GET", ["metrics"]) => 1,
        ("POST", ["v1", "workloads"]) => 2,
        ("GET", ["v1", "workloads", _]) => 3,
        ("DELETE", ["v1", "workloads", _]) => 4,
        ("POST", ["v1", "tick"]) => 5,
        ("GET", ["v1", "stats"]) => 6,
        ("GET", ["v1", "cluster"]) => 7,
        ("GET", ["v1", "hardware"]) => 8,
        ("GET", ["v1", "healthz"]) => 9,
        ("GET", ["v1", "version"]) => 10,
        ("POST", ["v1", "maintenance", "defrag"]) => 11,
        ("POST", ["v1", "submit", "batch"]) => 12,
        _ => ROUTE_OTHER,
    }
}

/// Status-class labels; `class_index` clamps anything outside 1xx–5xx
/// into the nearest class.
pub const CLASSES: [&str; 5] = ["1xx", "2xx", "3xx", "4xx", "5xx"];

pub fn class_index(status: u16) -> usize {
    (status / 100).clamp(1, 5) as usize - 1
}

/// Per-route HTTP metrics: one counter + latency histogram per status
/// class.
pub struct RouteMetrics {
    pub requests: [Counter; CLASSES.len()],
    pub latency: [LatencyHist; CLASSES.len()],
}

impl RouteMetrics {
    fn new() -> Self {
        Self {
            requests: std::array::from_fn(|_| Counter::new()),
            latency: std::array::from_fn(|_| LatencyHist::new()),
        }
    }
}

/// The whole registry. Everything is pre-allocated at daemon construction
/// (fixed routes × classes, one decision/ΔF histogram per shard), so
/// recording never allocates or takes a lock.
pub struct ServerMetrics {
    /// Indexed by [`route_index`]; `NROUTES` entries.
    pub http: Vec<RouteMetrics>,
    /// Connections accepted since start.
    pub connections_total: Counter,
    /// Connections currently open (keep-alive sessions in flight).
    pub connections_open: Gauge,
    /// Responses fully written to a socket. Incremented after the write
    /// succeeds, so it trails `requests` by the in-flight window.
    pub responses_total: Counter,
    /// Scheduler decision latency (accept and reject), one per shard.
    pub decision: Vec<LatencyHist>,
    /// Fragmentation-score delta per committed placement, one per shard.
    pub delta_f: Vec<DeltaHist>,
    /// Defrag sweeps executed (background sweeper + maintenance endpoint;
    /// threshold-gated no-op sweeps count too).
    pub defrag_sweeps_total: Counter,
    /// Wall-clock duration of those sweeps.
    pub defrag_sweep_duration: LatencyHist,
}

impl ServerMetrics {
    pub fn new(num_shards: usize) -> Self {
        Self {
            http: (0..NROUTES).map(|_| RouteMetrics::new()).collect(),
            connections_total: Counter::new(),
            connections_open: Gauge::new(),
            responses_total: Counter::new(),
            decision: (0..num_shards).map(|_| LatencyHist::new()).collect(),
            delta_f: (0..num_shards).map(|_| DeltaHist::new()).collect(),
            defrag_sweeps_total: Counter::new(),
            defrag_sweep_duration: LatencyHist::new(),
        }
    }

    /// Count one dispatched request: increments the (route, class) counter
    /// and records its handling latency. Called after dispatch, before the
    /// response bytes are written.
    pub fn record_request(&self, route: usize, status: u16, elapsed: Duration) {
        let c = class_index(status);
        self.http[route].requests[c].inc();
        self.http[route].latency[c].record(elapsed);
    }
}

/// Render the full exposition for `GET /metrics`. Families appear in a
/// fixed registration order; shard gauges are sampled one shard lock at a
/// time in index order (the same scatter-gather discipline as
/// `/v1/stats`).
pub fn render(shards: &ShardSet) -> String {
    let mut out = String::new();
    render_into(shards, &mut out);
    out
}

/// [`render`], writing into a caller-owned buffer (cleared first). The
/// `/metrics` handler keeps one scratch buffer per serving thread so
/// steady-state scrapes reuse a warm allocation instead of growing a
/// fresh multi-kilobyte `String` each time.
pub fn render_into(shards: &ShardSet, out: &mut String) {
    let m = shards.metrics();
    let mut e = Expo::with_buffer(std::mem::take(out));

    // --- HTTP plane. Responses BEFORE requests (see module docs). -------
    e.counter(
        "migsched_http_responses_total",
        "Responses fully written to a client socket.",
        &[(Labels::new(), m.responses_total.get())],
    );
    let mut req_samples = Vec::new();
    let mut lat_samples = Vec::new();
    for (r, rm) in m.http.iter().enumerate() {
        let (method, endpoint) = route_label(r);
        for (c, class) in CLASSES.iter().enumerate() {
            let n = rm.requests[c].get();
            if n == 0 {
                continue; // unexercised (route, class) pairs stay silent
            }
            let labels = Labels::new()
                .with("method", method)
                .with("endpoint", endpoint)
                .with("class", class);
            req_samples.push((labels.clone(), n));
            lat_samples.push((labels, rm.latency[c].snapshot()));
        }
    }
    e.counter(
        "migsched_http_requests_total",
        "Requests dispatched, by method, normalized endpoint and status class.",
        &req_samples,
    );
    e.histogram(
        "migsched_http_request_duration_seconds",
        "Request handling latency (parse to response ready), by route and status class.",
        &lat_samples,
    );
    e.counter(
        "migsched_http_connections_total",
        "Connections accepted since start.",
        &[(Labels::new(), m.connections_total.get())],
    );
    e.gauge(
        "migsched_http_connections_open",
        "Connections currently open (keep-alive sessions).",
        &[(Labels::new(), m.connections_open.get() as f64)],
    );

    // --- Scheduler plane: per-shard decision latency and ΔF. ------------
    let shard_label = |i: usize| Labels::new().with("shard", &i.to_string());
    e.histogram(
        "migsched_sched_decision_seconds",
        "Scheduler decision latency per shard (accepts and rejects).",
        &m.decision
            .iter()
            .enumerate()
            .map(|(i, h)| (shard_label(i), h.snapshot()))
            .collect::<Vec<_>>(),
    );
    e.histogram(
        "migsched_sched_delta_f_per_commit",
        "Fragmentation-score increase per committed placement, per shard.",
        &m.delta_f
            .iter()
            .enumerate()
            .map(|(i, h)| (shard_label(i), h.snapshot()))
            .collect::<Vec<_>>(),
    );

    // --- Defrag plane. ---------------------------------------------------
    e.counter(
        "migsched_defrag_sweeps_total",
        "Defrag sweeps executed (background sweeper and maintenance endpoint).",
        &[(Labels::new(), m.defrag_sweeps_total.get())],
    );
    e.histogram(
        "migsched_defrag_sweep_duration_seconds",
        "Wall-clock duration of defrag sweeps.",
        &[(Labels::new(), m.defrag_sweep_duration.snapshot())],
    );

    // --- Cluster gauges: the /v1/stats surface re-exported so the two can
    // be cross-checked sample for sample. One shard lock at a time.
    let mut allocated = 0u64;
    let mut accepted = 0u64;
    let mut arrived = 0u64;
    let mut released = 0u64;
    let mut expired = 0u64;
    let mut migrations = 0u64;
    let mut migrated_bytes = 0u64;
    let mut active = 0u64;
    let mut used = 0u64;
    let mut capacity = 0u64;
    let mut score_total = 0u64;
    let mut clock = 0u64;
    let num_classes = shards.fleet().num_classes();
    let mut per_class = vec![crate::cluster::ClassStats::default(); num_classes];
    let mut has_est = false;
    let mut est_weights = [0u64; crate::mig::NUM_PROFILES];
    for shard in shards.shards() {
        let s = shard.state.lock().unwrap();
        if let Some(mix) = s.scheduler.estimator() {
            // Shard-local estimators merge by summing their fixed-point
            // weights (integers, so the merge is exact).
            has_est = true;
            for (acc, w) in est_weights.iter_mut().zip(mix.weights().iter()) {
                *acc += *w;
            }
        }
        allocated += s.cluster.allocated_workloads() as u64;
        accepted += s.accepted_total;
        arrived += s.arrived_total;
        released += s.released_total;
        expired += s.expired_total;
        migrations += s.migrations_total;
        migrated_bytes += s.migrated_bytes_total;
        active += s.cluster.active_gpus() as u64;
        used += s.cluster.used_slices();
        capacity += s.cluster.capacity_slices();
        // Each GPU scores against its own class's table (identical to the
        // flat scorer on uniform fleets).
        score_total += (0..s.cluster.num_gpus())
            .map(|g| u64::from(s.tables.score_gpu(&s.cluster, g)))
            .sum::<u64>();
        if num_classes > 1 {
            for (acc, stats) in per_class.iter_mut().zip(s.cluster.per_class_stats()) {
                acc.gpus += stats.gpus;
                acc.active_gpus += stats.active_gpus;
                acc.used_slices += stats.used_slices;
                acc.allocated_workloads += stats.allocated_workloads;
            }
        }
        clock = s.clock_slot;
    }
    let one = |v: u64| vec![(Labels::new(), v)];
    let oneg = |v: f64| vec![(Labels::new(), v)];
    e.counter(
        "migsched_submits_total",
        "Workload submissions (accepted or rejected).",
        &one(arrived),
    );
    e.counter("migsched_accepted_total", "Workload submissions accepted.", &one(accepted));
    e.counter("migsched_released_total", "Explicit workload releases.", &one(released));
    e.counter("migsched_expired_total", "Lease expiries observed by tick.", &one(expired));
    e.counter("migsched_defrag_migrations_total", "Defrag migrations applied.", &one(migrations));
    e.counter(
        "migsched_defrag_migrated_bytes_total",
        "Instance memory copied by defrag migrations.",
        &one(migrated_bytes),
    );
    e.gauge("migsched_allocated_workloads", "Workloads currently placed.", &oneg(allocated as f64));
    e.gauge("migsched_active_gpus", "GPUs with at least one instance.", &oneg(active as f64));
    e.gauge(
        "migsched_utilization",
        "Fraction of memory slices in use.",
        &oneg(if capacity > 0 { used as f64 / capacity as f64 } else { 0.0 }),
    );
    e.gauge(
        "migsched_mean_frag_score",
        "Mean fragmentation score per GPU (paper Algorithm 1).",
        &oneg(score_total as f64 / shards.total_gpus() as f64),
    );
    e.gauge("migsched_clock_slot", "Logical slot clock.", &oneg(clock as f64));
    e.gauge("migsched_num_gpus", "Fleet size in GPUs.", &oneg(shards.total_gpus() as f64));
    e.gauge("migsched_capacity_slices", "Fleet memory-slice capacity.", &oneg(capacity as f64));
    // Per-class gauges, heterogeneous fleets only — a single-class scrape
    // stays byte-identical to the legacy exposition.
    if num_classes > 1 {
        let models = shards.fleet().models();
        let labeled = |pick: fn(&crate::cluster::ClassStats) -> u64| {
            models
                .iter()
                .zip(&per_class)
                .map(|(hw, stats)| (Labels::new().with("model", hw.name()), pick(stats) as f64))
                .collect::<Vec<_>>()
        };
        e.gauge(
            "migsched_class_gpus",
            "GPUs per device class.",
            &labeled(|s| s.gpus as u64),
        );
        e.gauge(
            "migsched_class_active_gpus",
            "GPUs with at least one instance, per device class.",
            &labeled(|s| s.active_gpus as u64),
        );
        e.gauge(
            "migsched_class_used_slices",
            "Memory slices in use, per device class.",
            &labeled(|s| s.used_slices),
        );
        e.gauge(
            "migsched_class_allocated_workloads",
            "Workloads currently placed, per device class.",
            &labeled(|s| s.allocated_workloads as u64),
        );
    }
    // Estimator gauges, distribution-aware schedulers only — an agnostic
    // daemon's scrape stays byte-identical to the legacy exposition.
    if has_est {
        let total: u64 = est_weights.iter().sum();
        let samples: Vec<_> = crate::mig::ALL_PROFILES
            .iter()
            .map(|p| {
                let w = est_weights[p.index()];
                (
                    Labels::new().with("profile", p.canonical_name()),
                    if total > 0 { w as f64 / total as f64 } else { 0.0 },
                )
            })
            .collect();
        e.gauge(
            "migsched_estimator_profile_weight",
            "Estimated workload-mix share per profile (decayed, normalized).",
            &samples,
        );
    }
    e.gauge("migsched_shards", "Shard count.", &oneg(shards.num_shards() as f64));
    e.gauge(
        "migsched_uptime_seconds",
        "Seconds since the daemon state was constructed.",
        &oneg(shards.uptime().as_secs_f64()),
    );
    *out = e.finish();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::daemon::{Daemon, DaemonConfig};

    #[test]
    fn route_index_covers_every_route_and_falls_through() {
        for (i, (method, path)) in ROUTES.iter().enumerate() {
            // Rebuild segments from the normalized path, substituting a
            // concrete id for the parameter.
            let segs: Vec<&str> = path
                .split('/')
                .filter(|s| !s.is_empty())
                .map(|s| if s == "{id}" { "42" } else { s })
                .collect();
            assert_eq!(route_index(method, &segs), i, "{method} {path}");
        }
        assert_eq!(route_index("GET", &["v1", "nope"]), ROUTE_OTHER);
        assert_eq!(route_index("PUT", &["v1", "workloads"]), ROUTE_OTHER);
        assert_eq!(route_index("GET", &[]), ROUTE_OTHER);
    }

    #[test]
    fn render_into_reuses_the_buffer_and_matches_render() {
        let shards = Daemon::new(DaemonConfig {
            num_gpus: 2,
            shards: 1,
            workers: 1,
            ..DaemonConfig::default()
        })
        .shards();
        // Drop the wall-clock uptime sample before comparing.
        let stable = |text: &str| {
            text.lines()
                .filter(|l| !l.starts_with("migsched_uptime_seconds "))
                .collect::<Vec<_>>()
                .join("\n")
        };
        let fresh = render(&shards);
        let mut buf = String::from("stale content from a previous scrape");
        render_into(&shards, &mut buf);
        assert_eq!(stable(&fresh), stable(&buf));
        // The reused buffer keeps its grown capacity for the next scrape.
        let grown = buf.capacity();
        render_into(&shards, &mut buf);
        assert!(buf.capacity() >= grown);
    }

    #[test]
    fn per_class_gauges_appear_only_on_mixed_fleets() {
        let uniform = Daemon::new(DaemonConfig {
            num_gpus: 2,
            shards: 1,
            workers: 1,
            ..DaemonConfig::default()
        })
        .shards();
        assert!(!render(&uniform).contains("migsched_class_"));

        let fleet = crate::mig::FleetSpec::parse("a100:2,h100:1").unwrap();
        let mixed = Daemon::new(DaemonConfig {
            num_gpus: fleet.total_gpus(),
            hardware: fleet.classes()[0].0.clone(),
            fleet: Some(fleet),
            shards: 1,
            workers: 1,
            ..DaemonConfig::default()
        })
        .shards();
        let text = render(&mixed);
        assert!(text.contains("# TYPE migsched_class_gpus gauge"));
        assert!(text.contains("migsched_class_gpus{model=\"A100-80GB\"} 2\n"));
        assert!(text.contains("migsched_class_gpus{model=\"H100-80GB\"} 1\n"));
        for family in [
            "migsched_class_active_gpus",
            "migsched_class_used_slices",
            "migsched_class_allocated_workloads",
        ] {
            assert!(
                text.contains(&format!("{family}{{model=\"A100-80GB\"}} 0\n")),
                "missing idle sample for {family}"
            );
        }
    }

    #[test]
    fn estimator_gauges_appear_only_with_distribution_aware_schedulers() {
        use crate::server::api::dispatch;
        use crate::server::http::Request;
        // Agnostic daemons must not grow the family — byte-discipline as
        // with the per-class gauges.
        let plain = Daemon::new(DaemonConfig {
            num_gpus: 2,
            shards: 1,
            workers: 1,
            ..DaemonConfig::default()
        })
        .shards();
        assert!(!render(&plain).contains("migsched_estimator_profile_weight"));

        let aware = Daemon::new(DaemonConfig {
            num_gpus: 2,
            shards: 1,
            workers: 1,
            scheduler: crate::sched::SchedulerKind::MfiExp,
            ..DaemonConfig::default()
        })
        .shards();
        let idle = render(&aware);
        // Exposed from startup (all-zero shares before any commit).
        assert!(idle.contains("# TYPE migsched_estimator_profile_weight gauge"));
        assert!(idle.contains("migsched_estimator_profile_weight{profile=\"3g.40gb\"} 0\n"));
        let submit = Request {
            method: "POST".into(),
            path: "/v1/workloads".into(),
            query: std::collections::HashMap::new(),
            headers: Vec::new(),
            body: br#"{"profile":"3g.40gb"}"#.to_vec(),
            keep_alive: false,
        };
        assert_eq!(dispatch(&submit, &aware).status, 201);
        let text = render(&aware);
        // One observed profile holds the whole normalized mass.
        assert!(text.contains("migsched_estimator_profile_weight{profile=\"3g.40gb\"} 1\n"));
        assert!(text.contains("migsched_estimator_profile_weight{profile=\"1g.10gb\"} 0\n"));
    }

    #[test]
    fn class_index_clamps() {
        assert_eq!(class_index(200), 1);
        assert_eq!(class_index(201), 1);
        assert_eq!(class_index(404), 3);
        assert_eq!(class_index(500), 4);
        assert_eq!(class_index(99), 0);
        assert_eq!(class_index(700), 4);
    }

    #[test]
    fn render_produces_the_required_families_and_orders_responses_first() {
        let shards = Daemon::new(DaemonConfig {
            num_gpus: 4,
            shards: 2,
            workers: 1,
            ..DaemonConfig::default()
        })
        .shards();
        let m = shards.metrics();
        m.record_request(route_index("POST", &["v1", "workloads"]), 201, Duration::from_micros(30));
        m.responses_total.inc();
        m.decision[0].record(Duration::from_micros(5));
        m.delta_f[1].record(3);
        let text = render(&shards);
        for family in [
            "migsched_http_requests_total",
            "migsched_http_request_duration_seconds",
            "migsched_http_responses_total",
            "migsched_http_connections_open",
            "migsched_sched_decision_seconds",
            "migsched_sched_delta_f_per_commit",
            "migsched_defrag_sweeps_total",
            "migsched_defrag_sweep_duration_seconds",
            "migsched_submits_total",
            "migsched_uptime_seconds",
        ] {
            assert!(text.contains(&format!("# TYPE {family} ")), "missing family {family}");
        }
        assert!(text.contains(
            "migsched_http_requests_total{method=\"POST\",endpoint=\"/v1/workloads\",class=\"2xx\"} 1\n"
        ));
        // Per-shard series exist for both shards.
        assert!(text.contains("migsched_sched_decision_seconds_count{shard=\"0\"} 1\n"));
        assert!(text.contains("migsched_sched_decision_seconds_count{shard=\"1\"} 0\n"));
        assert!(text.contains("migsched_sched_delta_f_per_commit_sum{shard=\"1\"} 3\n"));
        // The scrape-consistency ordering: responses family renders first.
        let responses = text.find("# TYPE migsched_http_responses_total").unwrap();
        let requests = text.find("# TYPE migsched_http_requests_total").unwrap();
        assert!(responses < requests);
        assert!(text.contains("migsched_shards 2\n"));
        assert!(text.contains("migsched_num_gpus 4\n"));
    }
}
