//! The JSON control-plane API: request routing + schemas.

use std::sync::{Arc, Mutex};

use super::daemon::{DaemonState, Lease};
use super::http::{Request, Response};
use crate::cluster::ClusterMetrics;
use crate::util::json::Json;
use crate::workload::{TenantId, WorkloadId};

/// Route a parsed request to its handler.
pub fn dispatch(request: &Request, state: &Arc<Mutex<DaemonState>>) -> Response {
    let segments = request.segments();
    match (request.method.as_str(), segments.as_slice()) {
        ("GET", ["healthz"]) => Response::text(200, "ok\n"),
        ("POST", ["v1", "workloads"]) => submit(request, state),
        ("GET", ["v1", "workloads", id]) => lookup(id, state),
        ("DELETE", ["v1", "workloads", id]) => release(id, state),
        ("POST", ["v1", "tick"]) => tick(request, state),
        ("GET", ["v1", "stats"]) => stats(state),
        ("GET", ["v1", "cluster"]) => cluster_snapshot(state),
        ("GET", ["v1", "hardware"]) => hardware(state),
        (method, _) if !matches!(method, "GET" | "POST" | "DELETE") => {
            Response::error(405, "method not allowed")
        }
        _ => Response::error(404, &format!("no route for {} {}", request.method, request.path)),
    }
}

/// `POST /v1/workloads` — body `{"profile": "2g.20gb", "tenant": 3,
/// "duration_slots": 10}` (tenant and duration optional). 201 on success
/// with the placement, 409 when rejected by the scheduler.
fn submit(request: &Request, state: &Arc<Mutex<DaemonState>>) -> Response {
    let body = match request.body_str() {
        Ok(b) if !b.trim().is_empty() => b,
        Ok(_) => return Response::error(400, "missing JSON body"),
        Err(e) => return Response::error(400, &e),
    };
    let j = match Json::parse(body) {
        Ok(j) => j,
        Err(e) => return Response::error(400, &format!("invalid JSON: {e}")),
    };
    let profile_name = match j.req_str("profile") {
        Ok(p) => p,
        Err(e) => return Response::error(400, &e),
    };
    let tenant = TenantId(j.get("tenant").and_then(Json::as_u64).unwrap_or(0) as u32);
    let duration = j.get("duration_slots").and_then(Json::as_u64);

    let mut s = state.lock().unwrap();
    let profile = match s.cluster.hardware().parse_profile(profile_name) {
        Some(p) => p,
        None => return Response::error(400, &format!("unknown profile '{profile_name}'")),
    };
    s.arrived_total += 1;
    let DaemonState { scheduler, cluster, .. } = &mut *s;
    let placement = match scheduler.schedule(cluster, profile) {
        Some(p) => p,
        None => {
            return Response::json(
                409,
                &Json::obj()
                    .with("rejected", true)
                    .with("reason", "no feasible MIG placement (cluster fragmented or full)")
                    .with("profile", profile.canonical_name()),
            )
        }
    };
    let id = WorkloadId(s.next_id);
    s.next_id += 1;
    if let Err(e) = s.cluster.allocate(id, placement) {
        return Response::error(500, &format!("commit failed: {e}"));
    }
    {
        let DaemonState { scheduler, cluster, .. } = &mut *s;
        scheduler.on_commit(cluster, placement);
    }
    s.accepted_total += 1;
    let expires_at = duration.map(|d| s.clock_slot + d);
    s.leases.insert(id, Lease { tenant, expires_at });
    Response::json(
        201,
        &Json::obj()
            .with("id", id.0)
            .with("tenant", tenant.0 as u64)
            .with("profile", profile.canonical_name())
            .with("gpu", placement.gpu)
            .with("index", placement.index as u64)
            .with(
                "expires_at_slot",
                expires_at.map(Json::from).unwrap_or(Json::Null),
            ),
    )
}

/// `GET /v1/workloads/{id}`.
fn lookup(id: &str, state: &Arc<Mutex<DaemonState>>) -> Response {
    let id = match id.parse::<u64>() {
        Ok(n) => WorkloadId(n),
        Err(_) => return Response::error(400, "workload id must be an integer"),
    };
    let s = state.lock().unwrap();
    match (s.cluster.placement_of(id), s.leases.get(&id)) {
        (Some(p), Some(lease)) => Response::json(
            200,
            &Json::obj()
                .with("id", id.0)
                .with("tenant", lease.tenant.0 as u64)
                .with("profile", p.profile.canonical_name())
                .with("gpu", p.gpu)
                .with("index", p.index as u64)
                .with(
                    "expires_at_slot",
                    lease.expires_at.map(Json::from).unwrap_or(Json::Null),
                ),
        ),
        _ => Response::error(404, &format!("workload {} not found", id.0)),
    }
}

/// `DELETE /v1/workloads/{id}` — explicit release.
fn release(id: &str, state: &Arc<Mutex<DaemonState>>) -> Response {
    let id = match id.parse::<u64>() {
        Ok(n) => WorkloadId(n),
        Err(_) => return Response::error(400, "workload id must be an integer"),
    };
    let mut s = state.lock().unwrap();
    match s.cluster.release(id) {
        Ok(p) => {
            {
                let DaemonState { scheduler, cluster, .. } = &mut *s;
                scheduler.on_release(cluster, p);
            }
            s.leases.remove(&id);
            s.released_total += 1;
            Response::json(
                200,
                &Json::obj()
                    .with("released", id.0)
                    .with("gpu", p.gpu)
                    .with("profile", p.profile.canonical_name()),
            )
        }
        Err(e) => Response::error(404, &e.to_string()),
    }
}

/// `POST /v1/tick` — body `{"slots": 1}` (default 1). Advances the logical
/// clock, expiring leases.
fn tick(request: &Request, state: &Arc<Mutex<DaemonState>>) -> Response {
    let slots = match request.body_str() {
        Ok(b) if !b.trim().is_empty() => match Json::parse(b) {
            Ok(j) => j.get("slots").and_then(Json::as_u64).unwrap_or(1),
            Err(e) => return Response::error(400, &format!("invalid JSON: {e}")),
        },
        _ => 1,
    };
    let mut s = state.lock().unwrap();
    let released = s.tick(slots);
    Response::json(
        200,
        &Json::obj()
            .with("clock_slot", s.clock_slot)
            .with("released", Json::Arr(released.iter().map(|id| Json::from(id.0)).collect())),
    )
}

/// `GET /v1/stats` — the paper's metrics plus daemon counters.
fn stats(state: &Arc<Mutex<DaemonState>>) -> Response {
    let s = state.lock().unwrap();
    let metrics =
        ClusterMetrics::capture(&s.cluster, &s.scorer, s.accepted_total, s.arrived_total);
    let mut j = metrics.to_json();
    j.set("clock_slot", s.clock_slot);
    j.set("released_total", s.released_total);
    j.set("expired_total", s.expired_total);
    j.set("num_gpus", s.cluster.num_gpus());
    j.set("capacity_slices", s.cluster.capacity_slices());
    j.set("scheduler", s.scheduler.name());
    Response::json(200, &j)
}

/// `GET /v1/cluster` — full occupancy snapshot.
fn cluster_snapshot(state: &Arc<Mutex<DaemonState>>) -> Response {
    let s = state.lock().unwrap();
    let mut j = crate::cluster::snapshot::to_json(&s.cluster);
    j.set(
        "diagrams",
        Json::Arr(s.cluster.gpus().iter().map(|g| Json::from(g.diagram())).collect()),
    );
    Response::json(200, &j)
}

/// `GET /v1/hardware` — the Table I data for this deployment.
fn hardware(state: &Arc<Mutex<DaemonState>>) -> Response {
    let s = state.lock().unwrap();
    let hw = s.cluster.hardware();
    let profiles: Vec<Json> = hw
        .profiles()
        .map(|p| {
            Json::obj()
                .with("name", hw.profile_name(p))
                .with("canonical", p.canonical_name())
                .with("slices", p.size() as u64)
                .with("compute_slices", p.compute_slices() as u64)
                .with("mem_weight", p.mem_weight() as u64)
                .with(
                    "indexes",
                    Json::Arr(p.starts().iter().map(|&s| Json::from(s as u64)).collect()),
                )
        })
        .collect();
    Response::json(
        200,
        &Json::obj()
            .with("model", hw.name())
            .with("num_slices", hw.num_slices())
            .with("total_memory_gb", hw.total_memory_gb() as u64)
            .with("profiles", Json::Arr(profiles)),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::daemon::{Daemon, DaemonConfig};
    use std::collections::HashMap;

    fn daemon_state() -> Arc<Mutex<DaemonState>> {
        Daemon::new(DaemonConfig { num_gpus: 2, workers: 1, ..DaemonConfig::default() }).state()
    }

    fn req(method: &str, path: &str, body: &str) -> Request {
        Request {
            method: method.into(),
            path: path.into(),
            query: HashMap::new(),
            headers: HashMap::new(),
            body: body.as_bytes().to_vec(),
        }
    }

    fn json_of(r: &Response) -> Json {
        Json::parse(std::str::from_utf8(&r.body).unwrap()).unwrap()
    }

    #[test]
    fn submit_lookup_release_cycle() {
        let state = daemon_state();
        let r = dispatch(
            &req("POST", "/v1/workloads", r#"{"profile":"3g.40gb","tenant":7}"#),
            &state,
        );
        assert_eq!(r.status, 201, "{:?}", String::from_utf8_lossy(&r.body));
        let j = json_of(&r);
        let id = j.req_u64("id").unwrap();
        assert_eq!(j.req_str("profile").unwrap(), "3g.40gb");

        let r = dispatch(&req("GET", &format!("/v1/workloads/{id}"), ""), &state);
        assert_eq!(r.status, 200);
        assert_eq!(json_of(&r).req_u64("tenant").unwrap(), 7);

        let r = dispatch(&req("DELETE", &format!("/v1/workloads/{id}"), ""), &state);
        assert_eq!(r.status, 200);
        let r = dispatch(&req("GET", &format!("/v1/workloads/{id}"), ""), &state);
        assert_eq!(r.status, 404);
    }

    #[test]
    fn submit_rejects_when_full() {
        let state = daemon_state();
        // Fill both GPUs.
        for _ in 0..2 {
            let r =
                dispatch(&req("POST", "/v1/workloads", r#"{"profile":"7g.80gb"}"#), &state);
            assert_eq!(r.status, 201);
        }
        let r = dispatch(&req("POST", "/v1/workloads", r#"{"profile":"1g.10gb"}"#), &state);
        assert_eq!(r.status, 409);
        assert_eq!(json_of(&r).get("rejected").unwrap().as_bool(), Some(true));
        // Stats reflect 3 arrived / 2 accepted.
        let stats = json_of(&dispatch(&req("GET", "/v1/stats", ""), &state));
        assert_eq!(stats.req_u64("arrived_total").unwrap(), 3);
        assert_eq!(stats.req_u64("accepted_total").unwrap(), 2);
    }

    #[test]
    fn lease_expiry_via_tick() {
        let state = daemon_state();
        let r = dispatch(
            &req("POST", "/v1/workloads", r#"{"profile":"2g.20gb","duration_slots":2}"#),
            &state,
        );
        let id = json_of(&r).req_u64("id").unwrap();
        let r = dispatch(&req("POST", "/v1/tick", r#"{"slots":2}"#), &state);
        let j = json_of(&r);
        assert_eq!(j.req_u64("clock_slot").unwrap(), 2);
        assert_eq!(j.get("released").unwrap().as_arr().unwrap().len(), 1);
        let r = dispatch(&req("GET", &format!("/v1/workloads/{id}"), ""), &state);
        assert_eq!(r.status, 404);
    }

    #[test]
    fn bad_requests() {
        let state = daemon_state();
        assert_eq!(dispatch(&req("POST", "/v1/workloads", ""), &state).status, 400);
        assert_eq!(dispatch(&req("POST", "/v1/workloads", "{not json"), &state).status, 400);
        assert_eq!(
            dispatch(&req("POST", "/v1/workloads", r#"{"profile":"9g.90gb"}"#), &state).status,
            400
        );
        assert_eq!(dispatch(&req("GET", "/v1/workloads/abc", ""), &state).status, 400);
        assert_eq!(dispatch(&req("DELETE", "/v1/workloads/42", ""), &state).status, 404);
        assert_eq!(dispatch(&req("GET", "/v1/nope", ""), &state).status, 404);
        assert_eq!(dispatch(&req("PUT", "/v1/workloads", ""), &state).status, 405);
    }

    #[test]
    fn hardware_and_cluster_endpoints() {
        let state = daemon_state();
        let hw = json_of(&dispatch(&req("GET", "/v1/hardware", ""), &state));
        assert_eq!(hw.req_str("model").unwrap(), "A100-80GB");
        assert_eq!(hw.get("profiles").unwrap().as_arr().unwrap().len(), 6);

        dispatch(&req("POST", "/v1/workloads", r#"{"profile":"1g.10gb"}"#), &state);
        let snap = json_of(&dispatch(&req("GET", "/v1/cluster", ""), &state));
        assert_eq!(snap.req_u64("num_gpus").unwrap(), 2);
        assert_eq!(snap.get("diagrams").unwrap().as_arr().unwrap().len(), 2);

        let health = dispatch(&req("GET", "/healthz", ""), &state);
        assert_eq!(health.status, 200);
    }

    #[test]
    fn indexed_daemon_places_like_mfi_daemon() {
        // The serving daemon's allocate/release/tick paths drive the
        // incremental scheduler through its hooks; every placement must
        // match the flat-MFI daemon on the same request sequence.
        use crate::sched::SchedulerKind;
        let mk = |kind| {
            Daemon::new(DaemonConfig {
                num_gpus: 3,
                workers: 1,
                scheduler: kind,
                ..DaemonConfig::default()
            })
            .state()
        };
        let flat = mk(SchedulerKind::Mfi);
        let indexed = mk(SchedulerKind::MfiIdx);
        let sequence = [
            r#"{"profile":"2g.20gb","duration_slots":2}"#,
            r#"{"profile":"1g.10gb","duration_slots":5}"#,
            r#"{"profile":"3g.40gb"}"#,
            r#"{"profile":"1g.20gb","duration_slots":1}"#,
            r#"{"profile":"7g.80gb"}"#,
            r#"{"profile":"1g.10gb","duration_slots":3}"#,
            r#"{"profile":"4g.40gb"}"#,
            r#"{"profile":"2g.20gb"}"#,
        ];
        for (i, body) in sequence.iter().enumerate() {
            let ra = dispatch(&req("POST", "/v1/workloads", body), &flat);
            let rb = dispatch(&req("POST", "/v1/workloads", body), &indexed);
            assert_eq!(ra.status, rb.status, "request {i}");
            if ra.status == 201 {
                let (ja, jb) = (json_of(&ra), json_of(&rb));
                assert_eq!(ja.req_u64("gpu").unwrap(), jb.req_u64("gpu").unwrap(), "request {i}");
                assert_eq!(
                    ja.req_u64("index").unwrap(),
                    jb.req_u64("index").unwrap(),
                    "request {i}"
                );
            }
            if i == 3 {
                // Expire some leases mid-sequence (exercises tick's
                // on_release plumbing) and explicitly release a live one.
                for state in [&flat, &indexed] {
                    dispatch(&req("POST", "/v1/tick", r#"{"slots":2}"#), state);
                    dispatch(&req("DELETE", "/v1/workloads/1", ""), state);
                }
            }
        }
        let sa = json_of(&dispatch(&req("GET", "/v1/stats", ""), &flat));
        let sb = json_of(&dispatch(&req("GET", "/v1/stats", ""), &indexed));
        assert_eq!(sa.req_u64("accepted_total").unwrap(), sb.req_u64("accepted_total").unwrap());
        assert_eq!(
            sa.get("utilization").and_then(Json::as_f64),
            sb.get("utilization").and_then(Json::as_f64)
        );
    }

    #[test]
    fn profile_hardware_specific_names_accepted() {
        // A100-40GB deployment accepts "3g.20gb".
        let daemon = Daemon::new(DaemonConfig {
            hardware: crate::mig::HardwareModel::a100_40gb(),
            num_gpus: 1,
            workers: 1,
            ..DaemonConfig::default()
        });
        let state = daemon.state();
        let r = dispatch(&req("POST", "/v1/workloads", r#"{"profile":"3g.20gb"}"#), &state);
        assert_eq!(r.status, 201);
    }
}
