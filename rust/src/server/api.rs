//! The JSON control-plane API: request routing + schemas.
//!
//! Data-plane requests (submit/lookup/release) lock exactly one shard —
//! chosen by tenant hash on submit, decoded from the workload id
//! otherwise. Fleet-wide endpoints (`/v1/stats`, `/v1/cluster`,
//! `/v1/tick`, `/v1/maintenance/defrag`) scatter-gather over the shards
//! in index order, one lock at a time, merging with a stable order so the
//! single-shard daemon's responses are byte-for-byte those of the old
//! single-mutex implementation.

use std::borrow::Cow;

use super::http::{Request, Response};
use super::shard::{Lease, Shard, ShardSet, ShardState};
use crate::cluster::{snapshot, ClusterMetrics};
use crate::util::json::{scan_flat_object, Json};
use crate::workload::{TenantId, WorkloadId};

/// Largest accepted `POST /v1/submit/batch` request count.
pub const MAX_BATCH: usize = 4096;

/// Preserialized fixed error bodies (pinned byte-equal to their dynamic
/// [`Response::error`] forms in tests) — the hot path's rejections
/// serialize without allocating.
const MISSING_BODY: &[u8] = br#"{"error":"missing JSON body"}"#;
const MISSING_REQUESTS: &[u8] = br#"{"error":"missing or non-array field 'requests'"}"#;

/// Route a parsed request to its handler.
pub fn dispatch(request: &Request, shards: &ShardSet) -> Response {
    let segments = request.segments();
    match (request.method.as_str(), segments.as_slice()) {
        ("GET", ["healthz"]) => Response::text(200, "ok\n"),
        ("GET", ["metrics"]) => metrics_exposition(shards),
        ("GET", ["v1", "healthz"]) => healthz(shards),
        ("GET", ["v1", "version"]) => version(shards),
        ("POST", ["v1", "workloads"]) => submit(request, shards),
        ("POST", ["v1", "submit", "batch"]) => submit_batch(request, shards),
        ("GET", ["v1", "workloads", id]) => lookup(id, shards),
        ("DELETE", ["v1", "workloads", id]) => release(id, shards),
        ("POST", ["v1", "tick"]) => tick(request, shards),
        ("GET", ["v1", "stats"]) => stats(shards),
        ("GET", ["v1", "cluster"]) => cluster_snapshot(shards),
        ("GET", ["v1", "hardware"]) => hardware(shards),
        ("POST", ["v1", "maintenance", "defrag"]) => defrag(request, shards),
        (method, _) if !matches!(method, "GET" | "POST" | "DELETE") => {
            Response::error(405, "method not allowed")
        }
        _ => Response::error(404, &format!("no route for {} {}", request.method, request.path)),
    }
}

/// A decoded submit request, ready for [`submit_one`]. The profile stays
/// a borrowed string on the fast parse path (zero-allocation) and is
/// resolved against the hardware model under the shard lock, exactly
/// where the pre-batch handler resolved it.
struct SubmitReq<'a> {
    profile: Cow<'a, str>,
    tenant: TenantId,
    duration: Option<u64>,
}

/// Decode a submit body. The flat-object scanner handles the common
/// machine-generated shape without building a JSON tree; anything it
/// isn't sure about falls back to [`Json::parse`] so every error message
/// stays byte-identical to the pre-scanner handler's.
fn decode_submit(body: &str) -> Result<SubmitReq<'_>, Json> {
    let mut profile: Option<&str> = None;
    let mut tenant: u64 = 0;
    let mut duration: Option<u64> = None;
    let mut clean = true;
    let scanned = scan_flat_object(body, |key, value| match key {
        // A non-string profile must produce req_str's exact error:
        // defer to the fallback rather than duplicating the message.
        "profile" => match value.as_str() {
            Some(p) => profile = Some(p),
            None => clean = false,
        },
        "tenant" => tenant = value.as_u64().unwrap_or(0),
        "duration_slots" => duration = value.as_u64(),
        _ => {}
    });
    if scanned && clean {
        if let Some(profile) = profile {
            return Ok(SubmitReq {
                profile: Cow::Borrowed(profile),
                tenant: TenantId(tenant as u32),
                duration,
            });
        }
        // Missing profile: fall through for the canonical error message.
    }
    let j = Json::parse(body)
        .map_err(|e| Json::obj().with("error", format!("invalid JSON: {e}")))?;
    let decoded = decode_submit_json(&j)?;
    Ok(SubmitReq {
        profile: Cow::Owned(decoded.profile.into_owned()),
        tenant: decoded.tenant,
        duration: decoded.duration,
    })
}

/// Decode one already-parsed submit object (a batch element). Errors are
/// returned as the body object of the 400 the single-submit endpoint
/// would serve.
fn decode_submit_json(j: &Json) -> Result<SubmitReq<'_>, Json> {
    let profile = j.req_str("profile").map_err(|e| Json::obj().with("error", e))?;
    Ok(SubmitReq {
        profile: Cow::Borrowed(profile),
        tenant: TenantId(j.get("tenant").and_then(Json::as_u64).unwrap_or(0) as u32),
        duration: j.get("duration_slots").and_then(Json::as_u64),
    })
}

/// The submit decision under a held shard lock: profile resolution,
/// arrival accounting, scheduler dry run, commit, lease. Returns the
/// status and the response body object — shared verbatim by the single
/// and batch endpoints, which is what makes batch placements, counters
/// and tie-breaking bit-identical to sequential submits.
fn submit_one(
    s: &mut ShardState,
    shard: &Shard,
    shards: &ShardSet,
    req: &SubmitReq<'_>,
) -> (u16, Json) {
    // Resolved against every device class in the shard's fleet, so
    // hardware-specific names (A100-40GB's "3g.20gb", H200's "1g.18gb")
    // are accepted whenever some class serves them.
    let profile = match s.cluster.parse_profile(&req.profile) {
        Some(p) => p,
        None => {
            // Rejected before it counts as an arrival (unchanged from the
            // pre-batch handler: an unparseable request never reached the
            // scheduler's arrival stream).
            return (400, Json::obj().with("error", format!("unknown profile '{}'", req.profile)));
        }
    };
    s.arrived_total += 1;
    let metrics = shards.metrics();
    // Decision latency covers the scheduler's dry-run search only (accepts
    // AND rejects — tail latency on a full cluster matters just as much).
    let decision_start = std::time::Instant::now();
    let decided = {
        let ShardState { scheduler, cluster, .. } = &mut *s;
        scheduler.schedule(cluster, profile)
    };
    metrics.decision[shard.index].record(decision_start.elapsed());
    let placement = match decided {
        Some(p) => p,
        None => {
            return (
                409,
                Json::obj()
                    .with("rejected", true)
                    .with("reason", "no feasible MIG placement (cluster fragmented or full)")
                    .with("profile", profile.canonical_name()),
            )
        }
    };
    // ΔF per commit: only the target GPU's score changes on allocate, so
    // the delta is two table lookups (against the GPU's own class's
    // table), not a fleet rescore.
    let f_before = i64::from(s.tables.score_gpu(&s.cluster, placement.gpu));
    let seq = s.next_seq;
    s.next_seq += 1;
    let id = shards.workload_id(shard, seq);
    if let Err(e) = s.cluster.allocate(id, placement) {
        return (500, Json::obj().with("error", format!("commit failed: {e}")));
    }
    {
        let ShardState { scheduler, cluster, .. } = &mut *s;
        scheduler.on_commit(cluster, placement);
    }
    let f_after = i64::from(s.tables.score_gpu(&s.cluster, placement.gpu));
    metrics.delta_f[shard.index].record(f_after - f_before);
    s.accepted_total += 1;
    let expires_at = req.duration.map(|d| s.clock_slot + d);
    s.leases.insert(id, Lease { tenant: req.tenant, expires_at });
    (
        201,
        Json::obj()
            .with("id", id.0)
            .with("tenant", req.tenant.0 as u64)
            .with("profile", profile.canonical_name())
            .with("gpu", shard.gpu_offset + placement.gpu)
            .with("index", placement.index as u64)
            .with(
                "expires_at_slot",
                expires_at.map(Json::from).unwrap_or(Json::Null),
            ),
    )
}

/// `POST /v1/workloads` — body `{"profile": "2g.20gb", "tenant": 3,
/// "duration_slots": 10}` (tenant and duration optional). 201 on success
/// with the placement, 409 when rejected by the scheduler. The tenant
/// picks the shard (consistent hash), so one tenant's workloads always
/// compete inside one sub-cluster.
fn submit(request: &Request, shards: &ShardSet) -> Response {
    let body = match request.body_str() {
        Ok(b) if !b.trim().is_empty() => b,
        Ok(_) => return Response::static_json(400, MISSING_BODY),
        Err(e) => return Response::error(400, &e),
    };
    let sub = match decode_submit(body) {
        Ok(s) => s,
        Err(err_body) => return Response::json(400, &err_body),
    };
    let shard = shards.route(sub.tenant);
    let mut s = shard.state.lock().unwrap();
    let (status, body) = submit_one(&mut s, shard, shards, &sub);
    Response::json(status, &body)
}

/// `POST /v1/submit/batch` — body `{"requests": [<submit body>, …]}`.
/// Decodes every element up front (no locks held), then visits each
/// involved shard once in index order, running that shard's elements in
/// arrival order under ONE lock hold — amortizing N lock acquisitions
/// down to the number of distinct shards. Placements, counters and
/// tie-breaking are bit-identical to submitting the same bodies
/// sequentially (pinned by `rust/tests/batch_equiv.rs`): the per-shard
/// order is preserved and shards share no state.
///
/// Response: `{"accepted": n, "rejected": m, "results": [...]}` (200),
/// where `results[i]` is exactly the body `POST /v1/workloads` would
/// have returned for element `i` (201-created, 409-rejected or
/// 400-invalid), in request order. `rejected` counts everything that
/// did not place.
fn submit_batch(request: &Request, shards: &ShardSet) -> Response {
    let body = match request.body_str() {
        Ok(b) if !b.trim().is_empty() => b,
        Ok(_) => return Response::static_json(400, MISSING_BODY),
        Err(e) => return Response::error(400, &e),
    };
    let j = match Json::parse(body) {
        Ok(j) => j,
        Err(e) => return Response::error(400, &format!("invalid JSON: {e}")),
    };
    let items = match j.get("requests").and_then(Json::as_arr) {
        Some(items) => items,
        None => return Response::static_json(400, MISSING_REQUESTS),
    };
    if items.len() > MAX_BATCH {
        return Response::error(
            413,
            &format!("batch too large: {} requests (limit {MAX_BATCH})", items.len()),
        );
    }
    // Decode before locking; invalid elements resolve to their 400 body
    // without ever touching a shard.
    let mut results: Vec<Option<Json>> = (0..items.len()).map(|_| None).collect();
    let mut decoded: Vec<Option<SubmitReq<'_>>> = Vec::with_capacity(items.len());
    let mut by_shard: Vec<Vec<usize>> = (0..shards.num_shards()).map(|_| Vec::new()).collect();
    for (i, item) in items.iter().enumerate() {
        match decode_submit_json(item) {
            Ok(req) => {
                by_shard[shards.route(req.tenant).index].push(i);
                decoded.push(Some(req));
            }
            Err(err_body) => {
                results[i] = Some(err_body);
                decoded.push(None);
            }
        }
    }
    let mut accepted = 0u64;
    for shard in shards.shards() {
        let indices = &by_shard[shard.index];
        if indices.is_empty() {
            continue;
        }
        let mut s = shard.state.lock().unwrap();
        for &i in indices {
            let req = decoded[i].as_ref().expect("decoded for every routed index");
            let (status, body) = submit_one(&mut s, shard, shards, req);
            if status == 201 {
                accepted += 1;
            }
            results[i] = Some(body);
        }
    }
    let rejected = items.len() as u64 - accepted;
    Response::json(
        200,
        &Json::obj().with("accepted", accepted).with("rejected", rejected).with(
            "results",
            Json::Arr(results.into_iter().map(|r| r.expect("every element resolved")).collect()),
        ),
    )
}

/// `GET /v1/workloads/{id}`.
fn lookup(id: &str, shards: &ShardSet) -> Response {
    let id = match id.parse::<u64>() {
        Ok(n) => WorkloadId(n),
        Err(_) => return Response::error(400, "workload id must be an integer"),
    };
    let shard = shards.shard_of(id);
    let s = shard.state.lock().unwrap();
    match (s.cluster.placement_of(id), s.leases.get(&id)) {
        (Some(p), Some(lease)) => Response::json(
            200,
            &Json::obj()
                .with("id", id.0)
                .with("tenant", lease.tenant.0 as u64)
                .with("profile", p.profile.canonical_name())
                .with("gpu", shard.gpu_offset + p.gpu)
                .with("index", p.index as u64)
                .with(
                    "expires_at_slot",
                    lease.expires_at.map(Json::from).unwrap_or(Json::Null),
                ),
        ),
        _ => Response::error(404, &format!("workload {} not found", id.0)),
    }
}

/// `DELETE /v1/workloads/{id}` — explicit release (counted in
/// `released_total`; lease expiries count in `expired_total` instead).
fn release(id: &str, shards: &ShardSet) -> Response {
    let id = match id.parse::<u64>() {
        Ok(n) => WorkloadId(n),
        Err(_) => return Response::error(400, "workload id must be an integer"),
    };
    let shard = shards.shard_of(id);
    let mut s = shard.state.lock().unwrap();
    match s.cluster.release(id) {
        Ok(p) => {
            {
                let ShardState { scheduler, cluster, .. } = &mut *s;
                scheduler.on_release(cluster, p);
            }
            s.leases.remove(&id);
            s.released_total += 1;
            Response::json(
                200,
                &Json::obj()
                    .with("released", id.0)
                    .with("gpu", shard.gpu_offset + p.gpu)
                    .with("profile", p.profile.canonical_name()),
            )
        }
        Err(e) => Response::error(404, &e.to_string()),
    }
}

/// `POST /v1/tick` — body `{"slots": 1}` (default 1). Advances the logical
/// clock on every shard atomically — all shard locks are held (acquired in
/// index order, the only multi-lock path, so no deadlock) while the sweep
/// runs, keeping shard clocks in lockstep even under concurrent ticks —
/// expiring leases; released ids are merged ascending.
fn tick(request: &Request, shards: &ShardSet) -> Response {
    let slots = match request.body_str() {
        Ok(b) if !b.trim().is_empty() => match Json::parse(b) {
            Ok(j) => j.get("slots").and_then(Json::as_u64).unwrap_or(1),
            Err(e) => return Response::error(400, &format!("invalid JSON: {e}")),
        },
        _ => 1,
    };
    let mut guards: Vec<_> =
        shards.shards().iter().map(|shard| shard.state.lock().unwrap()).collect();
    let mut released: Vec<WorkloadId> = Vec::new();
    for s in &mut guards {
        released.extend(s.tick(slots));
    }
    let clock = guards[0].clock_slot;
    drop(guards);
    released.sort();
    Response::json(
        200,
        &Json::obj()
            .with("clock_slot", clock)
            .with("released", Json::Arr(released.iter().map(|id| Json::from(id.0)).collect())),
    )
}

/// `GET /v1/stats` — the paper's metrics plus daemon counters,
/// scatter-gathered across shards. The merge sums the integer gauges and
/// derives the ratios from the sums, so for any fixed fleet state the
/// result is bit-identical to one unsharded cluster's report
/// (fragmentation scores and slice counts are integers). Shards are
/// sampled one lock at a time in index order; concurrent mutations may
/// land between samples, as with any scatter-gather gauge read.
fn stats(shards: &ShardSet) -> Response {
    let mut allocated = 0usize;
    let mut accepted = 0u64;
    let mut arrived = 0u64;
    let mut released = 0u64;
    let mut expired = 0u64;
    let mut active = 0usize;
    let mut used = 0u64;
    let mut capacity = 0u64;
    let mut score_total = 0u64;
    let mut clock = 0u64;
    let mut migrations = 0u64;
    let mut migrated_bytes = 0u64;
    let num_classes = shards.fleet().num_classes();
    let mut per_class = vec![crate::cluster::ClassStats::default(); num_classes];
    let mut has_est = false;
    let mut est_decay = 0u64;
    let mut est_arrivals = 0u64;
    let mut est_weights = [0u64; crate::mig::NUM_PROFILES];
    for shard in shards.shards() {
        let s = shard.state.lock().unwrap();
        if let Some(mix) = s.scheduler.estimator() {
            // Estimators are shard-local; the report sums their raw
            // fixed-point weights (integers, so the merge is exact).
            has_est = true;
            est_decay = mix.decay_slots();
            est_arrivals += mix.arrivals();
            for (acc, w) in est_weights.iter_mut().zip(mix.weights().iter()) {
                *acc += *w;
            }
        }
        allocated += s.cluster.allocated_workloads();
        accepted += s.accepted_total;
        arrived += s.arrived_total;
        released += s.released_total;
        expired += s.expired_total;
        migrations += s.migrations_total;
        migrated_bytes += s.migrated_bytes_total;
        active += s.cluster.active_gpus();
        used += s.cluster.used_slices();
        capacity += s.cluster.capacity_slices();
        score_total += (0..s.cluster.num_gpus())
            .map(|g| u64::from(s.tables.score_gpu(&s.cluster, g)))
            .sum::<u64>();
        if num_classes > 1 {
            for (acc, stats) in per_class.iter_mut().zip(s.cluster.per_class_stats()) {
                acc.gpus += stats.gpus;
                acc.active_gpus += stats.active_gpus;
                acc.used_slices += stats.used_slices;
                acc.allocated_workloads += stats.allocated_workloads;
            }
        }
        clock = s.clock_slot;
    }
    let metrics = ClusterMetrics {
        allocated_workloads: allocated,
        accepted_total: accepted,
        arrived_total: arrived,
        utilization: used as f64 / capacity as f64,
        active_gpus: active,
        mean_frag_score: score_total as f64 / shards.total_gpus() as f64,
    };
    let mut j = metrics.to_json();
    j.set("clock_slot", clock);
    j.set("released_total", released);
    j.set("expired_total", expired);
    j.set("num_gpus", shards.total_gpus());
    j.set("capacity_slices", capacity);
    j.set("scheduler", shards.scheduler_name());
    // Only distribution-aware schedulers expose an estimator, so agnostic
    // daemons keep the legacy byte-identical serialization.
    if has_est {
        let total: u64 = est_weights.iter().sum();
        let mut weights = Json::obj();
        let mut mix = Json::obj();
        for p in crate::mig::ALL_PROFILES {
            let w = est_weights[p.index()];
            weights.set(p.canonical_name(), w);
            mix.set(
                p.canonical_name(),
                if total == 0 { 0.0 } else { w as f64 / total as f64 },
            );
        }
        j.set(
            "estimator",
            Json::obj()
                .with("decay_slots", est_decay)
                .with("arrivals", est_arrivals)
                .with("weights", weights)
                .with("mix", mix),
        );
    }
    // Emitted only once maintenance has actually migrated something, so a
    // migration-free daemon's stats stay byte-identical to the legacy
    // single-mutex serialization (the PR 4 compatibility pin).
    if migrations > 0 {
        j.set("migrations_total", migrations);
        j.set("migrated_bytes_total", migrated_bytes);
    }
    if shards.num_shards() > 1 {
        j.set("shards", shards.num_shards());
    }
    // Per-class breakdown, heterogeneous fleets only — single-class stats
    // stay byte-identical to the legacy serialization.
    if num_classes > 1 {
        let classes: Vec<Json> = shards
            .fleet()
            .classes()
            .iter()
            .zip(&per_class)
            .map(|((hw, _), stats)| {
                Json::obj()
                    .with("model", hw.name())
                    .with("gpus", stats.gpus)
                    .with("active_gpus", stats.active_gpus)
                    .with("used_slices", stats.used_slices)
                    .with("allocated_workloads", stats.allocated_workloads)
            })
            .collect();
        j.set("classes", Json::Arr(classes));
    }
    Response::json(200, &j)
}

/// `GET /v1/cluster` — full occupancy snapshot, concatenated across shards
/// in index order (global GPU ids; allocations sorted by workload id).
/// The wire format is [`snapshot::parts_to_json`] — the same definition
/// the persistence/inspect snapshot uses — plus the `diagrams` array.
fn cluster_snapshot(shards: &ShardSet) -> Response {
    let mut hardware_name = String::new();
    let mut masks: Vec<u8> = Vec::new();
    let mut gpu_classes: Vec<u8> = Vec::new();
    let mut diagrams: Vec<Json> = Vec::new();
    let mut allocs: Vec<(WorkloadId, usize, crate::mig::Profile, u8)> = Vec::new();
    for shard in shards.shards() {
        let s = shard.state.lock().unwrap();
        hardware_name = s.cluster.hardware().name().to_string();
        masks.extend(s.cluster.occupancy_masks());
        gpu_classes.extend_from_slice(s.cluster.class_ids());
        for (id, p) in s.cluster.allocations() {
            allocs.push((id, shard.gpu_offset + p.gpu, p.profile, p.index));
        }
        diagrams.extend(s.cluster.gpus().iter().map(|g| Json::from(g.diagram())));
    }
    allocs.sort_by_key(|&(id, ..)| id);
    let fleet = shards.fleet();
    let mut j = if fleet.is_uniform() {
        snapshot::parts_to_json(&hardware_name, shards.total_gpus(), &masks, &allocs)
    } else {
        // v2: global class table + the concatenated per-shard class
        // assignment (class runs interleave across shards, which the v2
        // loader supports).
        let models = fleet.models();
        let names: Vec<&str> = models.iter().map(|hw| hw.name()).collect();
        snapshot::parts_to_json_fleet(&names, &gpu_classes, &masks, &allocs)
    };
    j.set("diagrams", Json::Arr(diagrams));
    Response::json(200, &j)
}

/// `GET /v1/hardware` — the Table I data for this deployment (identical on
/// every shard, so shard 0 answers).
fn hardware(shards: &ShardSet) -> Response {
    let s = shards.shards()[0].state.lock().unwrap();
    let hw = s.cluster.hardware();
    let profiles: Vec<Json> = hw
        .profiles()
        .map(|p| {
            Json::obj()
                .with("name", hw.profile_name(p))
                .with("canonical", p.canonical_name())
                .with("slices", p.size() as u64)
                .with("compute_slices", p.compute_slices() as u64)
                .with("mem_weight", p.mem_weight() as u64)
                .with(
                    "indexes",
                    Json::Arr(p.starts().iter().map(|&s| Json::from(s as u64)).collect()),
                )
        })
        .collect();
    let mut j = Json::obj()
        .with("model", hw.name())
        .with("num_slices", hw.num_slices())
        .with("total_memory_gb", hw.total_memory_gb() as u64)
        .with("profiles", Json::Arr(profiles));
    let fleet = shards.fleet();
    if !fleet.is_uniform() {
        // Heterogeneous fleet: `model`/`profiles` above describe class 0;
        // name every class so clients know to consult `/v1/stats` and
        // `/v1/cluster` for the per-class picture. Absent on uniform
        // fleets, keeping those bytes unchanged.
        j.set(
            "classes",
            Json::Arr(
                fleet
                    .classes()
                    .iter()
                    .map(|(hw, n)| {
                        Json::obj()
                            .with("model", hw.name())
                            .with("gpus", *n)
                            .with("total_memory_gb", hw.total_memory_gb() as u64)
                    })
                    .collect(),
            ),
        );
    }
    Response::json(200, &j)
}

/// `GET /metrics` — the whole registry as Prometheus text exposition
/// (see [`super::metrics::render`] for the family inventory and the
/// requests ≥ responses scrape invariant). Rendering goes through a
/// per-thread scratch buffer, so steady-state scrapes cost one
/// exact-size copy into the response instead of a growth-realloc chain.
fn metrics_exposition(shards: &ShardSet) -> Response {
    thread_local! {
        static SCRATCH: std::cell::RefCell<String> = std::cell::RefCell::new(String::new());
    }
    SCRATCH.with(|scratch| {
        let mut buf = scratch.borrow_mut();
        super::metrics::render_into(shards, &mut buf);
        Response::with_content_type(
            200,
            crate::obs::expo::CONTENT_TYPE,
            buf.as_bytes().to_vec(),
        )
    })
}

/// `GET /v1/healthz` — structured liveness: the daemon is up, for how
/// long, and over what fleet. (The bare `/healthz` plain-text probe
/// predates this and stays for compatibility.)
fn healthz(shards: &ShardSet) -> Response {
    Response::json(
        200,
        &Json::obj()
            .with("status", "ok")
            .with("uptime_seconds", shards.uptime().as_secs_f64())
            .with("shards", shards.num_shards())
            .with("num_gpus", shards.total_gpus()),
    )
}

/// `GET /v1/version` — crate version, compile-time feature set, and the
/// effective serving configuration (serve model, idle timeout, requests
/// per connection), so operators can tell which binary is answering and
/// how it was launched. The body is rendered once at startup
/// ([`ShardSet::version_body`]); serving it is a refcount bump.
fn version(shards: &ShardSet) -> Response {
    Response::shared_json(200, shards.version_body())
}

/// `POST /v1/maintenance/defrag` — body `{"shard": 0, "max_migrations": 8,
/// "cost_budget": 100}` (all optional: default every shard, 16 moves per
/// shard, unlimited cost). Runs the budgeted greedy planner
/// ([`crate::defrag::plan_defrag_budgeted`]) under each target shard's
/// lock and applies it immediately via [`crate::defrag::apply_plan`] —
/// plan and application happen under the same lock acquisition, so the
/// plan can never be stale. Returns the move list (global GPU ids) and the
/// fragmentation-score delta per shard; applied migrations bump the
/// shard's `migrations_total` / `migrated_bytes_total` gauges in
/// `/v1/stats`.
///
/// Leases and arrival counters are untouched (migration is not an arrival
/// or a release); the shard's incremental scheduler observes the moves
/// through the cluster change log on its next decision (generation-checked
/// catch-up), so no hook calls are needed here.
fn defrag(request: &Request, shards: &ShardSet) -> Response {
    let (target, budget, cost_budget) = match request.body_str() {
        Ok(b) if !b.trim().is_empty() => match Json::parse(b) {
            Ok(j) => {
                let target = match j.get("shard") {
                    None => None,
                    Some(v) => match v.as_u64() {
                        Some(n) if (n as usize) < shards.num_shards() => Some(n as usize),
                        _ => {
                            return Response::error(
                                400,
                                &format!(
                                    "shard must be an integer below {}",
                                    shards.num_shards()
                                ),
                            )
                        }
                    },
                };
                let budget =
                    j.get("max_migrations").and_then(Json::as_u64).unwrap_or(16) as usize;
                let cost_budget =
                    j.get("cost_budget").and_then(Json::as_u64).unwrap_or(0);
                (target, budget, cost_budget)
            }
            Err(e) => return Response::error(400, &format!("invalid JSON: {e}")),
        },
        _ => (None, 16usize, 0u64),
    };
    let plan_for = |s: &ShardState, budget: usize, cost_budget: u64| {
        crate::defrag::plan_defrag_budgeted(
            &s.cluster,
            &s.scorer,
            budget,
            &crate::defrag::CostModel::default(),
            cost_budget,
        )
    };
    run_defrag(shards, target, budget, cost_budget, &plan_for)
}

/// The defrag scatter-gather, with the planner injectable so tests can
/// force a stale plan mid-gather and pin the partial-failure report shape.
fn run_defrag(
    shards: &ShardSet,
    target: Option<usize>,
    budget: usize,
    cost_budget: u64,
    plan_for: &dyn Fn(&ShardState, usize, u64) -> crate::defrag::MigrationPlan,
) -> Response {
    let mut reports: Vec<Json> = Vec::new();
    let mut total_delta = 0i64;
    let mut total_moves = 0u64;
    let mut total_bytes = 0u64;
    for shard in shards.shards() {
        if target.is_some_and(|t| t != shard.index) {
            continue;
        }
        let sweep_start = std::time::Instant::now();
        let mut s = shard.state.lock().unwrap();
        let plan = plan_for(&s, budget, cost_budget);
        if let Err(e) = crate::defrag::apply_plan(&mut s.cluster, &plan) {
            // Unreachable with the real planner (planned and applied under
            // one lock hold) — but when a plan does fail, the shards
            // visited before it HAVE been defragged: report that applied
            // work alongside the error instead of discarding it.
            return Response::json(
                500,
                &Json::obj()
                    .with("error", format!("shard {}: applying plan: {e}", shard.index))
                    .with("budget", budget as u64)
                    .with("migrations", total_moves)
                    .with("migrated_bytes", total_bytes)
                    .with("delta_f", total_delta)
                    .with("shards", Json::Arr(reports)),
            );
        }
        s.migrations_total += plan.moves.len() as u64;
        s.migrated_bytes_total += plan.bytes_moved;
        shards.metrics().defrag_sweeps_total.inc();
        shards.metrics().defrag_sweep_duration.record(sweep_start.elapsed());
        total_delta += plan.total_delta();
        total_moves += plan.moves.len() as u64;
        total_bytes += plan.bytes_moved;
        let moves: Vec<Json> = plan
            .moves
            .iter()
            .map(|mv| {
                Json::obj()
                    .with("workload", mv.workload.0)
                    .with("profile", mv.from.profile.canonical_name())
                    .with("from_gpu", shard.gpu_offset + mv.from.gpu)
                    .with("from_index", mv.from.index as u64)
                    .with("to_gpu", shard.gpu_offset + mv.to.gpu)
                    .with("to_index", mv.to.index as u64)
                    .with("delta_f", i64::from(mv.delta_f))
                    .with("cost", mv.cost)
            })
            .collect();
        reports.push(
            Json::obj()
                .with("shard", shard.index)
                .with("f_before", plan.f_before)
                .with("f_after", plan.f_after)
                .with("delta_f", plan.total_delta())
                .with("cost", plan.total_cost)
                .with("bytes_moved", plan.bytes_moved)
                .with("moves", Json::Arr(moves)),
        );
    }
    Response::json(
        200,
        &Json::obj()
            .with("budget", budget as u64)
            .with("migrations", total_moves)
            .with("migrated_bytes", total_bytes)
            .with("delta_f", total_delta)
            .with("shards", Json::Arr(reports)),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::snapshot;
    use crate::server::daemon::{Daemon, DaemonConfig};
    use std::collections::HashMap;
    use std::sync::Arc;

    fn shard_set() -> Arc<ShardSet> {
        Daemon::new(DaemonConfig { num_gpus: 2, workers: 1, ..DaemonConfig::default() })
            .shards()
    }

    fn req(method: &str, path: &str, body: &str) -> Request {
        Request {
            method: method.into(),
            path: path.into(),
            query: HashMap::new(),
            headers: Vec::new(),
            body: body.as_bytes().to_vec(),
            keep_alive: false,
        }
    }

    fn json_of(r: &Response) -> Json {
        Json::parse(std::str::from_utf8(&r.body).unwrap()).unwrap()
    }

    #[test]
    fn submit_lookup_release_cycle() {
        let state = shard_set();
        let r = dispatch(
            &req("POST", "/v1/workloads", r#"{"profile":"3g.40gb","tenant":7}"#),
            &state,
        );
        assert_eq!(r.status, 201, "{:?}", String::from_utf8_lossy(&r.body));
        let j = json_of(&r);
        let id = j.req_u64("id").unwrap();
        assert_eq!(j.req_str("profile").unwrap(), "3g.40gb");

        let r = dispatch(&req("GET", &format!("/v1/workloads/{id}"), ""), &state);
        assert_eq!(r.status, 200);
        assert_eq!(json_of(&r).req_u64("tenant").unwrap(), 7);

        let r = dispatch(&req("DELETE", &format!("/v1/workloads/{id}"), ""), &state);
        assert_eq!(r.status, 200);
        let r = dispatch(&req("GET", &format!("/v1/workloads/{id}"), ""), &state);
        assert_eq!(r.status, 404);
    }

    #[test]
    fn submit_rejects_when_full() {
        let state = shard_set();
        // Fill both GPUs.
        for _ in 0..2 {
            let r =
                dispatch(&req("POST", "/v1/workloads", r#"{"profile":"7g.80gb"}"#), &state);
            assert_eq!(r.status, 201);
        }
        let r = dispatch(&req("POST", "/v1/workloads", r#"{"profile":"1g.10gb"}"#), &state);
        assert_eq!(r.status, 409);
        assert_eq!(json_of(&r).get("rejected").unwrap().as_bool(), Some(true));
        // Stats reflect 3 arrived / 2 accepted.
        let stats = json_of(&dispatch(&req("GET", "/v1/stats", ""), &state));
        assert_eq!(stats.req_u64("arrived_total").unwrap(), 3);
        assert_eq!(stats.req_u64("accepted_total").unwrap(), 2);
    }

    #[test]
    fn lease_expiry_via_tick() {
        let state = shard_set();
        let r = dispatch(
            &req("POST", "/v1/workloads", r#"{"profile":"2g.20gb","duration_slots":2}"#),
            &state,
        );
        let id = json_of(&r).req_u64("id").unwrap();
        let r = dispatch(&req("POST", "/v1/tick", r#"{"slots":2}"#), &state);
        let j = json_of(&r);
        assert_eq!(j.req_u64("clock_slot").unwrap(), 2);
        assert_eq!(j.get("released").unwrap().as_arr().unwrap().len(), 1);
        let r = dispatch(&req("GET", &format!("/v1/workloads/{id}"), ""), &state);
        assert_eq!(r.status, 404);
    }

    #[test]
    fn bad_requests() {
        let state = shard_set();
        assert_eq!(dispatch(&req("POST", "/v1/workloads", ""), &state).status, 400);
        assert_eq!(dispatch(&req("POST", "/v1/workloads", "{not json"), &state).status, 400);
        assert_eq!(
            dispatch(&req("POST", "/v1/workloads", r#"{"profile":"9g.90gb"}"#), &state).status,
            400
        );
        assert_eq!(dispatch(&req("GET", "/v1/workloads/abc", ""), &state).status, 400);
        assert_eq!(dispatch(&req("DELETE", "/v1/workloads/42", ""), &state).status, 404);
        assert_eq!(dispatch(&req("GET", "/v1/nope", ""), &state).status, 404);
        assert_eq!(dispatch(&req("PUT", "/v1/workloads", ""), &state).status, 405);
        // Defrag validation: shard index out of range.
        let r = dispatch(&req("POST", "/v1/maintenance/defrag", r#"{"shard":5}"#), &state);
        assert_eq!(r.status, 400);
    }

    #[test]
    fn hardware_and_cluster_endpoints() {
        let state = shard_set();
        let hw = json_of(&dispatch(&req("GET", "/v1/hardware", ""), &state));
        assert_eq!(hw.req_str("model").unwrap(), "A100-80GB");
        assert_eq!(hw.get("profiles").unwrap().as_arr().unwrap().len(), 6);

        dispatch(&req("POST", "/v1/workloads", r#"{"profile":"1g.10gb"}"#), &state);
        let snap = json_of(&dispatch(&req("GET", "/v1/cluster", ""), &state));
        assert_eq!(snap.req_u64("num_gpus").unwrap(), 2);
        assert_eq!(snap.get("diagrams").unwrap().as_arr().unwrap().len(), 2);

        let health = dispatch(&req("GET", "/healthz", ""), &state);
        assert_eq!(health.status, 200);
    }

    #[test]
    fn healthz_and_version_endpoints() {
        let state = shard_set();
        let r = dispatch(&req("GET", "/v1/healthz", ""), &state);
        assert_eq!(r.status, 200);
        let j = json_of(&r);
        assert_eq!(j.req_str("status").unwrap(), "ok");
        assert!(j.get("uptime_seconds").and_then(Json::as_f64).unwrap() >= 0.0);
        assert_eq!(j.req_u64("shards").unwrap(), 1);
        assert_eq!(j.req_u64("num_gpus").unwrap(), 2);

        let r = dispatch(&req("GET", "/v1/version", ""), &state);
        assert_eq!(r.status, 200);
        assert_eq!(r.content_type, "application/json");
        let j = json_of(&r);
        assert_eq!(j.req_str("version").unwrap(), env!("CARGO_PKG_VERSION"));
        assert!(j.get("features").unwrap().as_arr().is_some());
        assert_eq!(j.req_str("scheduler").unwrap(), state.scheduler_name());
        // The serving knobs are reported (defaults here).
        let model = crate::server::daemon::ServeModel::default();
        assert_eq!(j.req_str("serve_model").unwrap(), model.name());
        assert_eq!(
            j.req_u64("idle_timeout_ms").unwrap(),
            crate::server::daemon::KEEP_ALIVE_IDLE.as_millis() as u64
        );
        assert_eq!(
            j.req_u64("max_requests_per_conn").unwrap(),
            crate::server::daemon::MAX_REQUESTS_PER_CONN as u64
        );
    }

    #[test]
    fn metrics_endpoint_tracks_decisions_and_stats_gauges() {
        let state = shard_set();
        // Two accepts fill the cluster; the third submit is rejected.
        for _ in 0..2 {
            dispatch(&req("POST", "/v1/workloads", r#"{"profile":"7g.80gb"}"#), &state);
        }
        dispatch(&req("POST", "/v1/workloads", r#"{"profile":"1g.10gb"}"#), &state);
        let r = dispatch(&req("GET", "/metrics", ""), &state);
        assert_eq!(r.status, 200);
        assert_eq!(r.content_type, crate::obs::expo::CONTENT_TYPE);
        let text = String::from_utf8(r.body.to_vec()).unwrap();
        // The /v1/stats gauges re-exported, matching the scripted sequence.
        assert!(text.contains("migsched_submits_total 3\n"), "{text}");
        assert!(text.contains("migsched_accepted_total 2\n"));
        assert!(text.contains("migsched_allocated_workloads 2\n"));
        // Decision latency was recorded for accepts AND the reject; ΔF
        // only for the two commits.
        assert!(text.contains("migsched_sched_decision_seconds_count{shard=\"0\"} 3\n"));
        assert!(text.contains("migsched_sched_delta_f_per_commit_count{shard=\"0\"} 2\n"));
    }

    #[test]
    fn indexed_daemon_places_like_mfi_daemon() {
        // The serving daemon's allocate/release/tick paths drive the
        // incremental scheduler through its hooks; every placement must
        // match the flat-MFI daemon on the same request sequence.
        use crate::sched::SchedulerKind;
        let mk = |kind| {
            Daemon::new(DaemonConfig {
                num_gpus: 3,
                workers: 1,
                scheduler: kind,
                ..DaemonConfig::default()
            })
            .shards()
        };
        let flat = mk(SchedulerKind::Mfi);
        let indexed = mk(SchedulerKind::MfiIdx);
        let sequence = [
            r#"{"profile":"2g.20gb","duration_slots":2}"#,
            r#"{"profile":"1g.10gb","duration_slots":5}"#,
            r#"{"profile":"3g.40gb"}"#,
            r#"{"profile":"1g.20gb","duration_slots":1}"#,
            r#"{"profile":"7g.80gb"}"#,
            r#"{"profile":"1g.10gb","duration_slots":3}"#,
            r#"{"profile":"4g.40gb"}"#,
            r#"{"profile":"2g.20gb"}"#,
        ];
        for (i, body) in sequence.iter().enumerate() {
            let ra = dispatch(&req("POST", "/v1/workloads", body), &flat);
            let rb = dispatch(&req("POST", "/v1/workloads", body), &indexed);
            assert_eq!(ra.status, rb.status, "request {i}");
            if ra.status == 201 {
                let (ja, jb) = (json_of(&ra), json_of(&rb));
                assert_eq!(ja.req_u64("gpu").unwrap(), jb.req_u64("gpu").unwrap(), "request {i}");
                assert_eq!(
                    ja.req_u64("index").unwrap(),
                    jb.req_u64("index").unwrap(),
                    "request {i}"
                );
            }
            if i == 3 {
                // Expire some leases mid-sequence (exercises tick's
                // on_release plumbing) and explicitly release a live one.
                for state in [&flat, &indexed] {
                    dispatch(&req("POST", "/v1/tick", r#"{"slots":2}"#), state);
                    dispatch(&req("DELETE", "/v1/workloads/1", ""), state);
                }
            }
        }
        let sa = json_of(&dispatch(&req("GET", "/v1/stats", ""), &flat));
        let sb = json_of(&dispatch(&req("GET", "/v1/stats", ""), &indexed));
        assert_eq!(sa.req_u64("accepted_total").unwrap(), sb.req_u64("accepted_total").unwrap());
        assert_eq!(
            sa.get("utilization").and_then(Json::as_f64),
            sb.get("utilization").and_then(Json::as_f64)
        );
    }

    #[test]
    fn profile_hardware_specific_names_accepted() {
        // A100-40GB deployment accepts "3g.20gb".
        let daemon = Daemon::new(DaemonConfig {
            hardware: crate::mig::HardwareModel::a100_40gb(),
            num_gpus: 1,
            workers: 1,
            ..DaemonConfig::default()
        });
        let state = daemon.shards();
        let r = dispatch(&req("POST", "/v1/workloads", r#"{"profile":"3g.20gb"}"#), &state);
        assert_eq!(r.status, 201);
    }

    #[test]
    fn shard1_responses_match_legacy_single_mutex_construction() {
        // The byte-for-byte contract: with shards = 1, /v1/stats and
        // /v1/cluster must serialize exactly what the old single-mutex
        // handlers produced (ClusterMetrics::capture + snapshot::to_json
        // on the one cluster), and submit ids must be the dense 0,1,2,…
        // sequence.
        let state = shard_set();
        for (i, body) in [
            r#"{"profile":"3g.40gb","tenant":7}"#,
            r#"{"profile":"1g.10gb","duration_slots":2}"#,
            r#"{"profile":"2g.20gb"}"#,
        ]
        .iter()
        .enumerate()
        {
            let r = dispatch(&req("POST", "/v1/workloads", body), &state);
            assert_eq!(r.status, 201);
            assert_eq!(json_of(&r).req_u64("id").unwrap(), i as u64, "dense legacy ids");
        }
        dispatch(&req("POST", "/v1/tick", r#"{"slots":3}"#), &state);
        dispatch(&req("DELETE", "/v1/workloads/2", ""), &state);

        // Legacy construction, straight from the (single) shard's state.
        let (expect_stats, expect_cluster) = {
            let shard = state.shard(0).unwrap();
            let s = shard.state.lock().unwrap();
            let metrics = ClusterMetrics::capture(
                &s.cluster,
                &s.scorer,
                s.accepted_total,
                s.arrived_total,
            );
            let mut stats = metrics.to_json();
            stats.set("clock_slot", s.clock_slot);
            stats.set("released_total", s.released_total);
            stats.set("expired_total", s.expired_total);
            stats.set("num_gpus", s.cluster.num_gpus());
            stats.set("capacity_slices", s.cluster.capacity_slices());
            stats.set("scheduler", s.scheduler.name());
            let mut cluster = snapshot::to_json(&s.cluster);
            cluster.set(
                "diagrams",
                Json::Arr(s.cluster.gpus().iter().map(|g| Json::from(g.diagram())).collect()),
            );
            (stats.to_string_compact(), cluster.to_string_compact())
        };

        let got = dispatch(&req("GET", "/v1/stats", ""), &state);
        assert_eq!(String::from_utf8(got.body.to_vec()).unwrap(), expect_stats);
        let got = dispatch(&req("GET", "/v1/cluster", ""), &state);
        assert_eq!(String::from_utf8(got.body.to_vec()).unwrap(), expect_cluster);
    }

    // Sharded routing, id-encoding, and cross-shard merge assertions live
    // at two layers: shard-geometry unit tests in `server::shard` and the
    // end-to-end socket test `sharded_daemon_serves_disjoint_subclusters`
    // in rust/tests/server_api.rs.

    #[test]
    fn stats_estimator_block_is_gated_on_the_scheduler() {
        use crate::sched::SchedulerKind;
        // Agnostic daemons never grow the key — the legacy byte pin in
        // shard1_responses_match_legacy_single_mutex_construction covers
        // the full serialization.
        let plain = json_of(&dispatch(&req("GET", "/v1/stats", ""), &shard_set()));
        assert!(plain.get("estimator").is_none());

        let state = Daemon::new(DaemonConfig {
            num_gpus: 2,
            workers: 1,
            scheduler: SchedulerKind::MfiExp,
            ..DaemonConfig::default()
        })
        .shards();
        let before = json_of(&dispatch(&req("GET", "/v1/stats", ""), &state));
        let est = before.get("estimator").expect("MFI-EXP daemons expose the estimator");
        assert_eq!(est.req_u64("arrivals").unwrap(), 0);
        assert_eq!(est.req_u64("decay_slots").unwrap(), 512);
        // Each accepted submit feeds the estimator through on_commit.
        for body in [r#"{"profile":"3g.40gb"}"#, r#"{"profile":"1g.10gb"}"#] {
            assert_eq!(dispatch(&req("POST", "/v1/workloads", body), &state).status, 201);
        }
        let after = json_of(&dispatch(&req("GET", "/v1/stats", ""), &state));
        let est = after.get("estimator").unwrap();
        assert_eq!(est.req_u64("arrivals").unwrap(), 2);
        let weights = est.get("weights").unwrap();
        assert!(weights.req_u64("3g.40gb").unwrap() > 0);
        assert!(weights.req_u64("1g.10gb").unwrap() > 0);
        assert_eq!(weights.req_u64("7g.80gb").unwrap(), 0);
        let mix = est.get("mix").unwrap();
        let sum: f64 = crate::mig::ALL_PROFILES
            .iter()
            .map(|p| mix.get(p.canonical_name()).and_then(Json::as_f64).unwrap())
            .sum();
        assert!((sum - 1.0).abs() < 1e-9, "mix shares must sum to 1, got {sum}");
    }

    #[test]
    fn defrag_endpoint_on_clean_cluster_is_a_noop() {
        let state = shard_set();
        let r = dispatch(&req("POST", "/v1/maintenance/defrag", ""), &state);
        assert_eq!(r.status, 200);
        let j = json_of(&r);
        assert_eq!(j.req_u64("migrations").unwrap(), 0);
        assert_eq!(j.req_u64("migrated_bytes").unwrap(), 0);
        assert_eq!(j.get("delta_f").unwrap().as_f64(), Some(0.0));
        assert_eq!(j.get("shards").unwrap().as_arr().unwrap().len(), 1);
    }

    #[test]
    fn maintenance_defrag_bumps_stats_counters() {
        use crate::mig::{Placement, Profile};
        let state = shard_set();
        // Before any migration the gauges are absent entirely (the legacy
        // byte-for-byte stats pin).
        let before = json_of(&dispatch(&req("GET", "/v1/stats", ""), &state));
        assert!(before.get("migrations_total").is_none());
        assert!(before.get("migrated_bytes_total").is_none());
        // A misplaced 1g.10gb (index 1 blocks the 4g anchor, score 12).
        {
            let mut s = state.shard(0).unwrap().state.lock().unwrap();
            s.cluster
                .allocate(
                    WorkloadId(0),
                    Placement { gpu: 0, profile: Profile::P1g10gb, index: 1 },
                )
                .unwrap();
        }
        let r = dispatch(&req("POST", "/v1/maintenance/defrag", ""), &state);
        assert_eq!(r.status, 200);
        let j = json_of(&r);
        let migrations = j.req_u64("migrations").unwrap();
        let bytes = j.req_u64("migrated_bytes").unwrap();
        assert!(migrations >= 1);
        assert!(bytes > 0);
        // /v1/stats now carries exactly what maintenance applied.
        let stats = json_of(&dispatch(&req("GET", "/v1/stats", ""), &state));
        assert_eq!(stats.req_u64("migrations_total").unwrap(), migrations);
        assert_eq!(stats.req_u64("migrated_bytes_total").unwrap(), bytes);
    }

    #[test]
    fn defrag_failure_reports_already_applied_shards() {
        // Regression: a mid-scatter-gather apply failure used to return a
        // bare 500, discarding the reports of shards already defragged —
        // applied migrations were misreported as not-happened.
        use crate::defrag::{plan_defrag_budgeted, CostModel, Migration, MigrationPlan};
        use crate::mig::{Placement, Profile};
        let state = Daemon::new(DaemonConfig {
            num_gpus: 4,
            shards: 2,
            workers: 1,
            ..DaemonConfig::default()
        })
        .shards();
        // Shard 0 gets a genuinely fragmented sub-cluster.
        {
            let mut s = state.shard(0).unwrap().state.lock().unwrap();
            s.cluster
                .allocate(
                    WorkloadId(0),
                    Placement { gpu: 0, profile: Profile::P1g10gb, index: 1 },
                )
                .unwrap();
        }
        // Injected planner: the real plan on shard 0 (it hosts workload 0),
        // a stale plan referencing a never-allocated workload on shard 1.
        let plan_for = |s: &ShardState, budget: usize, cost_budget: u64| {
            if s.cluster.placement_of(WorkloadId(0)).is_some() {
                plan_defrag_budgeted(
                    &s.cluster,
                    &s.scorer,
                    budget,
                    &CostModel::default(),
                    cost_budget,
                )
            } else {
                MigrationPlan {
                    moves: vec![Migration {
                        workload: WorkloadId(7777),
                        from: Placement { gpu: 0, profile: Profile::P1g10gb, index: 0 },
                        to: Placement { gpu: 0, profile: Profile::P1g10gb, index: 2 },
                        delta_f: -1,
                        cost: 0,
                    }],
                    ..MigrationPlan::default()
                }
            }
        };
        let r = run_defrag(&state, None, 16, 0, &plan_for);
        assert_eq!(r.status, 500);
        let j = json_of(&r);
        // The error names the failing shard…
        assert!(j.req_str("error").unwrap().contains("shard 1"), "{:?}", j);
        // …while the work already applied on shard 0 is reported, not lost.
        let reports = j.get("shards").unwrap().as_arr().unwrap();
        assert_eq!(reports.len(), 1);
        assert_eq!(reports[0].req_u64("shard").unwrap(), 0);
        assert!(j.req_u64("migrations").unwrap() >= 1);
        assert!(j.req_u64("migrated_bytes").unwrap() > 0);
        // Shard 0's gauges agree with the partial report.
        let s0 = state.shard(0).unwrap().state.lock().unwrap();
        assert_eq!(s0.migrations_total, j.req_u64("migrations").unwrap());
    }

    fn fleet_set(spec: &str, shards: usize) -> Arc<ShardSet> {
        let fleet = crate::mig::FleetSpec::parse(spec).unwrap();
        Daemon::new(DaemonConfig {
            num_gpus: fleet.total_gpus(),
            hardware: fleet.classes()[0].0.clone(),
            fleet: Some(fleet),
            shards,
            workers: 1,
            ..DaemonConfig::default()
        })
        .shards()
    }

    #[test]
    fn hetero_submit_resolves_profiles_from_any_class() {
        // "3g.20gb" is the A100-40GB's name for the 3g shape; a mixed
        // fleet accepts it even though class 0 (A100-80GB) calls it
        // "3g.40gb".
        let state = fleet_set("a100:1,a100-40gb:1", 1);
        let r = dispatch(&req("POST", "/v1/workloads", r#"{"profile":"3g.20gb"}"#), &state);
        assert_eq!(r.status, 201, "{:?}", String::from_utf8_lossy(&r.body));
        assert_eq!(json_of(&r).req_str("profile").unwrap(), "3g.40gb");
        // Still a real vocabulary: unknown names stay 400.
        let r = dispatch(&req("POST", "/v1/workloads", r#"{"profile":"9g.90gb"}"#), &state);
        assert_eq!(r.status, 400);
    }

    #[test]
    fn hetero_stats_carry_a_conserving_class_breakdown() {
        let state = fleet_set("a100:2,h100:2", 1);
        for body in [
            r#"{"profile":"7g.80gb"}"#,
            r#"{"profile":"2g.20gb"}"#,
            r#"{"profile":"1g.10gb"}"#,
        ] {
            assert_eq!(dispatch(&req("POST", "/v1/workloads", body), &state).status, 201);
        }
        let stats = json_of(&dispatch(&req("GET", "/v1/stats", ""), &state));
        let classes = stats.get("classes").unwrap().as_arr().unwrap();
        assert_eq!(classes.len(), 2);
        assert_eq!(classes[0].req_str("model").unwrap(), "A100-80GB");
        assert_eq!(classes[1].req_str("model").unwrap(), "H100-80GB");
        // Per-class gauges sum to the fleet-wide gauges.
        for (key, want) in [
            ("gpus", stats.req_u64("num_gpus").unwrap()),
            ("active_gpus", stats.req_u64("active_gpus").unwrap()),
            ("allocated_workloads", stats.req_u64("allocated_workloads").unwrap()),
        ] {
            let sum: u64 = classes.iter().map(|c| c.req_u64(key).unwrap()).sum();
            assert_eq!(sum, want, "per-class '{key}' must conserve the total");
        }
        let used: u64 = classes.iter().map(|c| c.req_u64("used_slices").unwrap()).sum();
        assert_eq!(used as f64 / stats.req_u64("capacity_slices").unwrap() as f64, {
            stats.get("utilization").and_then(Json::as_f64).unwrap()
        });
        // Uniform daemons never grow the key.
        let uniform = json_of(&dispatch(&req("GET", "/v1/stats", ""), &shard_set()));
        assert!(uniform.get("classes").is_none());
    }

    #[test]
    fn hetero_cluster_snapshot_is_v2_and_loadable() {
        // Two shards over a 2-class fleet: the merged snapshot interleaves
        // class runs, and the v2 loader must rebuild the exact layout.
        let state = fleet_set("a100:3,a100-40gb:3", 2);
        for body in [
            r#"{"profile":"3g.40gb","tenant":1}"#,
            r#"{"profile":"1g.10gb","tenant":2}"#,
            r#"{"profile":"2g.20gb","tenant":3}"#,
        ] {
            assert_eq!(dispatch(&req("POST", "/v1/workloads", body), &state).status, 201);
        }
        let snap = json_of(&dispatch(&req("GET", "/v1/cluster", ""), &state));
        assert!(snap.get("hardware").is_none(), "v2 must not carry the v1 key");
        assert_eq!(snap.req_u64("num_gpus").unwrap(), 6);
        let classes = snap.get("classes").unwrap().as_arr().unwrap();
        assert_eq!(classes.len(), 2);
        // Shards own [a100:2, a40:2] and [a100:1, a40:1] → global ids
        // interleave: [0,0,1,1,0,1].
        let ids: Vec<u64> = snap
            .get("gpu_classes")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_u64().unwrap())
            .collect();
        assert_eq!(ids, vec![0, 0, 1, 1, 0, 1]);
        let restored = snapshot::from_json(&snap).unwrap();
        assert_eq!(restored.num_gpus(), 6);
        assert_eq!(restored.allocated_workloads(), 3);
        assert_eq!(
            restored.class_ids(),
            &[0, 0, 1, 1, 0, 1],
            "merged interleaved class runs survive the round-trip"
        );
        // /v1/hardware names every class (and only then).
        let hw = json_of(&dispatch(&req("GET", "/v1/hardware", ""), &state));
        assert_eq!(hw.get("classes").unwrap().as_arr().unwrap().len(), 2);
        let hw = json_of(&dispatch(&req("GET", "/v1/hardware", ""), &shard_set()));
        assert!(hw.get("classes").is_none());
    }

    #[test]
    fn preserialized_error_bodies_match_their_dynamic_forms() {
        // The static fragments the hot path serves must stay byte-equal
        // to what Response::error would render.
        assert_eq!(
            MISSING_BODY,
            &*Response::error(400, "missing JSON body").body,
        );
        assert_eq!(
            MISSING_REQUESTS,
            &*Response::error(400, "missing or non-array field 'requests'").body,
        );
    }

    #[test]
    fn batch_submit_mixes_placements_rejections_and_errors() {
        let state = shard_set(); // 2 GPUs
        let r = dispatch(
            &req(
                "POST",
                "/v1/submit/batch",
                r#"{"requests":[
                    {"profile":"7g.80gb","tenant":1},
                    {"profile":"7g.80gb"},
                    {"profile":"1g.10gb","duration_slots":2},
                    {"tenant":3},
                    {"profile":"9g.90gb"}
                ]}"#,
            ),
            &state,
        );
        assert_eq!(r.status, 200, "{:?}", String::from_utf8_lossy(&r.body));
        let j = json_of(&r);
        assert_eq!(j.req_u64("accepted").unwrap(), 2);
        assert_eq!(j.req_u64("rejected").unwrap(), 3);
        let results = j.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 5);
        // Two placements, ids in arrival order.
        assert_eq!(results[0].req_u64("id").unwrap(), 0);
        assert_eq!(results[1].req_u64("id").unwrap(), 1);
        // Full cluster: the 1g is scheduler-rejected, like a lone submit.
        assert_eq!(results[2].get("rejected").unwrap().as_bool(), Some(true));
        // Missing / unknown profile resolve to the single-submit 400 bodies.
        assert_eq!(
            results[3].req_str("error").unwrap(),
            "missing or non-string field 'profile'"
        );
        assert_eq!(results[4].req_str("error").unwrap(), "unknown profile '9g.90gb'");
        // Only the three schedulable elements count as arrivals (the
        // decode error never reached a shard; the unknown profile was
        // rejected before arrival accounting, as on the single endpoint).
        let stats = json_of(&dispatch(&req("GET", "/v1/stats", ""), &state));
        assert_eq!(stats.req_u64("arrived_total").unwrap(), 3);
        assert_eq!(stats.req_u64("accepted_total").unwrap(), 2);
    }

    #[test]
    fn batch_submit_matches_sequential_submits() {
        // The bit-identity contract at the dispatch layer (the randomized
        // multi-shard version lives in rust/tests/batch_equiv.rs).
        let bodies = [
            r#"{"profile":"2g.20gb","tenant":4,"duration_slots":3}"#,
            r#"{"profile":"1g.10gb","tenant":9}"#,
            r#"{"profile":"3g.40gb"}"#,
            r#"{"profile":"7g.80gb","tenant":2}"#,
        ];
        let sequential = shard_set();
        let mut expect = Vec::new();
        for body in &bodies {
            let r = dispatch(&req("POST", "/v1/workloads", body), &sequential);
            expect.push(String::from_utf8(r.body.to_vec()).unwrap());
        }
        let batched = shard_set();
        let batch_body =
            format!(r#"{{"requests":[{}]}}"#, bodies.join(","));
        let r = dispatch(&req("POST", "/v1/submit/batch", &batch_body), &batched);
        assert_eq!(r.status, 200);
        let j = json_of(&r);
        let results = j.get("results").unwrap().as_arr().unwrap();
        let got: Vec<String> = results.iter().map(|b| b.to_string_compact()).collect();
        assert_eq!(got, expect, "batch bodies must equal sequential bodies");
        // And the end state agrees byte-for-byte.
        let a = dispatch(&req("GET", "/v1/cluster", ""), &sequential);
        let b = dispatch(&req("GET", "/v1/cluster", ""), &batched);
        assert_eq!(a.body.to_vec(), b.body.to_vec());
        let a = dispatch(&req("GET", "/v1/stats", ""), &sequential);
        let b = dispatch(&req("GET", "/v1/stats", ""), &batched);
        assert_eq!(a.body.to_vec(), b.body.to_vec());
    }

    #[test]
    fn batch_submit_validates_the_envelope() {
        let state = shard_set();
        let r = dispatch(&req("POST", "/v1/submit/batch", ""), &state);
        assert_eq!(r.status, 400);
        assert_eq!(&*r.body, MISSING_BODY);
        let r = dispatch(&req("POST", "/v1/submit/batch", "{nope"), &state);
        assert_eq!(r.status, 400);
        let r = dispatch(&req("POST", "/v1/submit/batch", r#"{"requests":3}"#), &state);
        assert_eq!(r.status, 400);
        assert_eq!(&*r.body, MISSING_REQUESTS);
        // Over the element cap: 413 without touching any shard.
        let huge = format!(
            r#"{{"requests":[{}]}}"#,
            vec![r#"{"profile":"1g.10gb"}"#; MAX_BATCH + 1].join(",")
        );
        let r = dispatch(&req("POST", "/v1/submit/batch", &huge), &state);
        assert_eq!(r.status, 413);
        let stats = json_of(&dispatch(&req("GET", "/v1/stats", ""), &state));
        assert_eq!(stats.req_u64("arrived_total").unwrap(), 0);
        // An empty batch is legal and a no-op.
        let r = dispatch(&req("POST", "/v1/submit/batch", r#"{"requests":[]}"#), &state);
        assert_eq!(r.status, 200);
        let j = json_of(&r);
        assert_eq!(j.req_u64("accepted").unwrap(), 0);
        assert_eq!(j.req_u64("rejected").unwrap(), 0);
    }

    #[test]
    fn submit_fast_path_and_fallback_agree() {
        // Each pair is (scanner-friendly body, semantically identical body
        // that forces the Json::parse fallback). Responses must match
        // byte-for-byte on twin daemons.
        let pairs = [
            // Nesting makes the scanner bail.
            (
                r#"{"profile":"2g.20gb","tenant":5}"#,
                r#"{"profile":"2g.20gb","tenant":5,"note":{"a":1}}"#,
            ),
            // Escapes make the scanner bail (value is irrelevant junk).
            (
                r#"{"profile":"1g.10gb","duration_slots":4}"#,
                r#"{"profile":"1g.10gb","duration_slots":4,"x":"\n"}"#,
            ),
            // Float tenant is ignored (as_u64 fails) on both paths.
            (
                r#"{"profile":"3g.40gb","tenant":1.5}"#,
                r#"{"profile":"3g.40gb","tenant":1.5,"y":[1]}"#,
            ),
        ];
        for (fast, slow) in pairs {
            let a = shard_set();
            let b = shard_set();
            let ra = dispatch(&req("POST", "/v1/workloads", fast), &a);
            let rb = dispatch(&req("POST", "/v1/workloads", slow), &b);
            assert_eq!(ra.status, rb.status, "{fast} vs {slow}");
            assert_eq!(ra.body.to_vec(), rb.body.to_vec(), "{fast} vs {slow}");
        }
        // Error shapes keep the pre-scanner messages on every path.
        let state = shard_set();
        let r = dispatch(&req("POST", "/v1/workloads", r#"{"tenant":1}"#), &state);
        assert_eq!(r.status, 400);
        assert_eq!(
            json_of(&r).req_str("error").unwrap(),
            "missing or non-string field 'profile'"
        );
        let r = dispatch(&req("POST", "/v1/workloads", r#"{"profile":7}"#), &state);
        assert_eq!(r.status, 400);
        assert_eq!(
            json_of(&r).req_str("error").unwrap(),
            "missing or non-string field 'profile'"
        );
        let r = dispatch(&req("POST", "/v1/workloads", ""), &state);
        assert_eq!(r.status, 400);
        assert_eq!(&*r.body, MISSING_BODY);
    }
}
