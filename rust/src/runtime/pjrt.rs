//! Thin wrapper over the `xla` crate's PJRT client: compile HLO-text
//! artifacts once, execute many times.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

/// Directory holding AOT artifacts; `MIGSCHED_ARTIFACTS` overrides the
/// default `artifacts/` (relative to the working directory).
pub fn artifacts_dir() -> PathBuf {
    std::env::var("MIGSCHED_ARTIFACTS").map(PathBuf::from).unwrap_or_else(|_| {
        // Prefer the crate root (where `make artifacts` writes) so tests
        // and benches work from any cargo working directory.
        let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if manifest.exists() {
            manifest
        } else {
            PathBuf::from("artifacts")
        }
    })
}

/// A PJRT client (CPU). Create once per process; compiling executables
/// through it is cheap relative to client construction.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
}

impl PjrtRuntime {
    /// Connect to the CPU PJRT backend.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client })
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Load an HLO **text** artifact and compile it for this client.
    pub fn load_hlo_text(&self, path: &Path) -> Result<CompiledModule> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(CompiledModule { exe, source: path.to_path_buf() })
    }
}

/// A compiled, executable HLO module.
pub struct CompiledModule {
    exe: xla::PjRtLoadedExecutable,
    source: PathBuf,
}

impl CompiledModule {
    pub fn source(&self) -> &Path {
        &self.source
    }

    /// Execute with literal inputs; returns the flattened tuple elements.
    ///
    /// The AOT path lowers with `return_tuple=True`, so the single device
    /// output is a tuple literal; we decompose it for the caller.
    pub fn execute(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let outputs = self
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing {}", self.source.display()))?;
        let first = outputs
            .first()
            .and_then(|replica| replica.first())
            .context("executable produced no output buffer")?;
        let literal = first.to_literal_sync().context("device → host transfer")?;
        literal.to_tuple().context("decomposing output tuple")
    }
}

/// Build an `f32` input literal of the given shape from host data.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let expected: i64 = dims.iter().product();
    anyhow::ensure!(
        expected as usize == data.len(),
        "shape {dims:?} needs {expected} elements, got {}",
        data.len()
    );
    xla::Literal::vec1(data).reshape(dims).context("reshaping input literal")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifacts_dir_env_override() {
        std::env::set_var("MIGSCHED_ARTIFACTS", "/tmp/custom-artifacts");
        assert_eq!(artifacts_dir(), PathBuf::from("/tmp/custom-artifacts"));
        std::env::remove_var("MIGSCHED_ARTIFACTS");
        // Default ends with "artifacts".
        assert!(artifacts_dir().to_string_lossy().ends_with("artifacts"));
    }

    #[test]
    fn literal_f32_shape_checked() {
        assert!(literal_f32(&[1.0, 2.0, 3.0], &[2, 2]).is_err());
        let l = literal_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(l.element_count(), 4);
    }

    // Client-dependent tests live in rust/tests/runtime_vs_native.rs so a
    // missing artifacts/ directory (pre-`make artifacts`) skips cleanly.
}
