//! The PJRT runtime bridge: load AOT-compiled HLO artifacts produced by the
//! python build path (`make artifacts`) and execute them from rust.
//!
//! Python/JAX/Pallas never runs on the request path — `python/compile/aot.py`
//! lowers the batched fragmentation program to **HLO text** once, and this
//! module compiles it with the PJRT CPU client at startup. HLO text (not a
//! serialized `HloModuleProto`) is the interchange format because jax ≥ 0.5
//! emits protos with 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids (see `/opt/xla-example/README.md`
//! and DESIGN.md §1).

pub mod frag_engine;
pub mod pjrt;

pub use frag_engine::{FragBatch, FragEngine};
pub use pjrt::{artifacts_dir, CompiledModule, PjrtRuntime};
