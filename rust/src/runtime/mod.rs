//! The batched fragmentation-evaluation runtime.
//!
//! Two interchangeable engines implement the same contract (for a batch of
//! GPU occupancy masks: Algorithm 1 scores, per-candidate ΔF with an
//! infeasible sentinel, and feasibility flags — see [`FragBatch`]):
//!
//! * [`NativeFragEngine`] (always available) — pure rust, built on the
//!   256-entry [`crate::frag::ScoreTable`]; this is the default build's
//!   engine and the numeric reference.
//! * `FragEngine` (behind the off-by-default `xla` cargo feature) — loads
//!   the AOT-compiled HLO artifact produced by the python build path
//!   (`python/compile/aot.py`, `make artifacts`) and executes it through
//!   the PJRT CPU client. Python/JAX/Pallas never runs on the request
//!   path: the program is lowered to **HLO text** once and compiled at
//!   startup (HLO text rather than a serialized `HloModuleProto` because
//!   jax ≥ 0.5 emits protos with 64-bit instruction ids that
//!   xla_extension 0.5.1 rejects; the text parser reassigns ids).
//!
//! `rust/tests/runtime_vs_native.rs` pins the contract: the native engine
//! against the score table exhaustively, and (under `--features xla`) the
//! artifact against the native engine bit-for-bit.

pub mod frag_engine;
#[cfg(feature = "xla")]
pub mod pjrt;

pub use frag_engine::{FragBatch, NativeFragEngine, INFEASIBLE_DELTA};

#[cfg(feature = "xla")]
pub use frag_engine::FragEngine;
#[cfg(feature = "xla")]
pub use pjrt::{artifacts_dir, CompiledModule, PjrtRuntime};
