//! Batched fragmentation engines: the pure-rust [`NativeFragEngine`]
//! (always available) and the XLA-offloaded `FragEngine` (behind the
//! `xla` feature).
//!
//! Both compute, for a batch of GPU occupancy vectors:
//!
//! * `scores  f32[B]`      — Algorithm 1 fragmentation score per GPU;
//! * `deltas  f32[B, 18]`  — hypothetical ΔF for every candidate placement
//!   (Table I (profile, anchor) pairs in frozen [`crate::mig::CANDIDATES`]
//!   order); infeasible candidates carry the [`INFEASIBLE_DELTA`] sentinel;
//! * `feasible bool[B, 18]` — true where the candidate's window is free.
//!
//! The XLA artifact's batch size `B` is baked at lowering time and recorded
//! in `artifacts/manifest.json`; clusters larger than `B` are evaluated in
//! chunks, smaller ones are padded with fully-occupied rows (which are
//! infeasible everywhere and score 0, so padding never influences argmins).

use anyhow::Result;

use crate::frag::{OverlapRule, ScoreTable};
use crate::mig::{GpuState, HardwareModel, CANDIDATES, NUM_CANDIDATES};

/// Sentinel ΔF for infeasible candidates (mirrors `INFEASIBLE` in
/// `python/compile/kernels/ref.py`).
pub const INFEASIBLE_DELTA: f32 = 1e9;

/// Result of one batched evaluation over `m` GPUs.
#[derive(Clone, Debug)]
pub struct FragBatch {
    /// `F(m)` per GPU.
    pub scores: Vec<f32>,
    /// ΔF per GPU per candidate ([`crate::mig::CANDIDATES`] order).
    pub deltas: Vec<[f32; NUM_CANDIDATES]>,
    /// Feasibility per GPU per candidate.
    pub feasible: Vec<[bool; NUM_CANDIDATES]>,
}

/// Pure-rust engine implementing the batched contract on top of the
/// 256-entry score table — the default build's `FragEngine` stand-in and
/// the oracle the XLA artifact is validated against.
#[derive(Clone, Debug)]
pub struct NativeFragEngine {
    table: ScoreTable,
}

impl NativeFragEngine {
    /// Engine for a hardware model under the default overlap rule.
    pub fn new(hw: &HardwareModel) -> Self {
        Self { table: ScoreTable::for_hardware(hw) }
    }

    /// Engine under an explicit overlap rule (ablations).
    pub fn with_rule(hw: &HardwareModel, rule: OverlapRule) -> Self {
        Self { table: ScoreTable::for_hardware_rule(hw, rule) }
    }

    /// Wrap an existing score table.
    pub fn from_table(table: ScoreTable) -> Self {
        Self { table }
    }

    pub fn score_table(&self) -> &ScoreTable {
        &self.table
    }

    /// Overlap rule name ("partial" / "any"), matching the artifact
    /// manifest vocabulary.
    pub fn rule(&self) -> &str {
        self.table.rule().name()
    }

    /// Evaluate scores + deltas + feasibility for `masks` (one occupancy
    /// byte per GPU). Infallible in practice; returns `Result` so callers
    /// are engine-agnostic with the PJRT-backed implementation.
    pub fn evaluate(&self, masks: &[u8]) -> Result<FragBatch> {
        let scores_tab = self.table.raw();
        let mut out = FragBatch {
            scores: Vec::with_capacity(masks.len()),
            deltas: Vec::with_capacity(masks.len()),
            feasible: Vec::with_capacity(masks.len()),
        };
        for &mask in masks {
            let base = scores_tab[mask as usize] as i32;
            out.scores.push(base as f32);
            let mut drow = [INFEASIBLE_DELTA; NUM_CANDIDATES];
            let mut frow = [false; NUM_CANDIDATES];
            for (c, cand) in CANDIDATES.iter().enumerate() {
                if mask & cand.mask == 0 {
                    frow[c] = true;
                    drow[c] = (scores_tab[(mask | cand.mask) as usize] as i32 - base) as f32;
                }
            }
            out.deltas.push(drow);
            out.feasible.push(frow);
        }
        Ok(out)
    }

    /// Cluster-mean fragmentation score straight off the table (parity
    /// helper with the batched path).
    pub fn mean_score(&self, gpus: &[GpuState]) -> f64 {
        use crate::frag::FragScorer;
        self.table.mean_score(gpus)
    }
}

// ---------------------------------------------------------------------------
// XLA-offloaded engine (requires the `xla` PJRT-binding crate).
// ---------------------------------------------------------------------------

#[cfg(feature = "xla")]
pub use xla_impl::FragEngine;

#[cfg(feature = "xla")]
mod xla_impl {
    use std::path::Path;

    use anyhow::{Context, Result};

    use super::super::pjrt::{literal_f32, CompiledModule, PjrtRuntime};
    use super::FragBatch;
    use crate::mig::{NUM_CANDIDATES, NUM_SLICES};
    use crate::util::json::Json;

    /// The compiled batched fragmentation program.
    pub struct FragEngine {
        module: CompiledModule,
        batch: usize,
        rule: String,
    }

    impl FragEngine {
        /// Load `frag.hlo.txt` + `manifest.json` from the artifacts
        /// directory (see [`super::super::artifacts_dir`]) and compile it.
        pub fn load_default(runtime: &PjrtRuntime) -> Result<Self> {
            let dir = super::super::artifacts_dir();
            Self::load(runtime, &dir.join("frag.hlo.txt"), &dir.join("manifest.json"))
        }

        /// Load an explicit artifact + manifest pair.
        pub fn load(
            runtime: &PjrtRuntime,
            hlo_path: &Path,
            manifest_path: &Path,
        ) -> Result<Self> {
            let manifest_text = std::fs::read_to_string(manifest_path)
                .with_context(|| format!("reading {}", manifest_path.display()))?;
            let manifest = Json::parse(&manifest_text)
                .map_err(|e| anyhow::anyhow!("parsing {}: {e}", manifest_path.display()))?;
            let batch = manifest
                .get("batch")
                .and_then(Json::as_usize)
                .context("manifest missing 'batch'")?;
            let rule = manifest
                .get("rule")
                .and_then(Json::as_str)
                .unwrap_or("partial")
                .to_string();
            let n_candidates = manifest
                .get("num_candidates")
                .and_then(Json::as_usize)
                .context("manifest missing 'num_candidates'")?;
            anyhow::ensure!(
                n_candidates == NUM_CANDIDATES,
                "artifact candidate table arity {n_candidates} != rust {NUM_CANDIDATES}; \
                 re-run `make artifacts`"
            );
            let module = runtime.load_hlo_text(hlo_path)?;
            Ok(Self { module, batch, rule })
        }

        /// The artifact's baked batch size.
        pub fn batch_size(&self) -> usize {
            self.batch
        }

        /// Overlap rule the artifact was built with ("partial" / "any").
        pub fn rule(&self) -> &str {
            &self.rule
        }

        /// Evaluate scores + deltas + feasibility for `masks` (one byte per
        /// GPU), chunking/padding to the artifact batch size.
        pub fn evaluate(&self, masks: &[u8]) -> Result<FragBatch> {
            let m = masks.len();
            let mut out = FragBatch {
                scores: Vec::with_capacity(m),
                deltas: Vec::with_capacity(m),
                feasible: Vec::with_capacity(m),
            };
            for chunk in masks.chunks(self.batch) {
                self.evaluate_chunk(chunk, &mut out)?;
            }
            Ok(out)
        }

        fn evaluate_chunk(&self, masks: &[u8], out: &mut FragBatch) -> Result<()> {
            let b = self.batch;
            // Expand masks to the f32 occupancy matrix, padding with 0xFF.
            let mut occ = vec![1.0f32; b * NUM_SLICES];
            for (row, &mask) in masks.iter().enumerate() {
                for s in 0..NUM_SLICES {
                    occ[row * NUM_SLICES + s] =
                        if mask & (1 << s) != 0 { 1.0 } else { 0.0 };
                }
            }
            let input = literal_f32(&occ, &[b as i64, NUM_SLICES as i64])?;
            let outputs = self.module.execute(&[input])?;
            anyhow::ensure!(outputs.len() == 3, "expected 3 outputs, got {}", outputs.len());
            let scores: Vec<f32> = outputs[0].to_vec().context("scores output")?;
            let deltas: Vec<f32> = outputs[1].to_vec().context("deltas output")?;
            let feasible: Vec<f32> = outputs[2].to_vec().context("feasible output")?;
            anyhow::ensure!(scores.len() == b, "scores arity {}", scores.len());
            anyhow::ensure!(
                deltas.len() == b * NUM_CANDIDATES,
                "deltas arity {}",
                deltas.len()
            );
            anyhow::ensure!(
                feasible.len() == b * NUM_CANDIDATES,
                "feasible arity {}",
                feasible.len()
            );
            for row in 0..masks.len() {
                out.scores.push(scores[row]);
                let mut drow = [0.0f32; NUM_CANDIDATES];
                let mut frow = [false; NUM_CANDIDATES];
                for c in 0..NUM_CANDIDATES {
                    drow[c] = deltas[row * NUM_CANDIDATES + c];
                    frow[c] = feasible[row * NUM_CANDIDATES + c] > 0.5;
                }
                out.deltas.push(drow);
                out.feasible.push(frow);
            }
            Ok(())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mig::ALL_PROFILES;

    fn engine() -> NativeFragEngine {
        NativeFragEngine::new(&HardwareModel::a100_80gb())
    }

    // The exhaustive 256-mask scores/deltas/feasibility check against the
    // score table lives in rust/tests/runtime_vs_native.rs
    // (`native_engine_matches_table_exhaustively`); unit tests here cover
    // the properties that test does not.

    #[test]
    fn full_mask_is_infeasible_everywhere_and_scores_zero() {
        // The XLA chunk path pads with fully-occupied rows; this pins the
        // property that makes the padding harmless.
        let e = engine();
        let batch = e.evaluate(&[0xFF]).unwrap();
        assert_eq!(batch.scores[0], 0.0);
        assert!(batch.feasible[0].iter().all(|&f| !f));
        assert!(batch.deltas[0].iter().all(|&d| d == INFEASIBLE_DELTA));
    }

    #[test]
    fn rule_names() {
        assert_eq!(engine().rule(), "partial");
        let any = NativeFragEngine::with_rule(
            &HardwareModel::a100_80gb(),
            crate::frag::OverlapRule::Any,
        );
        assert_eq!(any.rule(), "any");
    }

    #[test]
    fn empty_batch_is_fine() {
        let batch = engine().evaluate(&[]).unwrap();
        assert!(batch.scores.is_empty());
    }

    #[test]
    fn argmin_over_native_batch_matches_evaluate_cluster() {
        // The batched contract must support the MFI argmin exactly like
        // the direct evaluate_cluster hot path.
        let e = engine();
        let table = ScoreTable::for_hardware(&HardwareModel::a100_80gb());
        let mut rng = crate::util::rng::Rng::new(0xBA7C);
        for _ in 0..200 {
            let masks: Vec<u8> = (0..6).map(|_| rng.next_u64() as u8).collect();
            let batch = e.evaluate(&masks).unwrap();
            for p in ALL_PROFILES {
                let range = crate::mig::candidate_range(p);
                let mut best: Option<(f32, usize, usize)> = None;
                for gpu in 0..masks.len() {
                    for c in range.clone() {
                        if !batch.feasible[gpu][c] {
                            continue;
                        }
                        let d = batch.deltas[gpu][c];
                        if best.is_none() || d < best.unwrap().0 {
                            best = Some((d, gpu, c));
                        }
                    }
                }
                let gpus: Vec<GpuState> =
                    masks.iter().map(|&m| GpuState::from_mask(m)).collect();
                let direct = crate::frag::evaluate_cluster(&table, &gpus, p);
                match (best, direct) {
                    (None, None) => {}
                    (Some((_, gpu, c)), Some(pl)) => {
                        assert_eq!((gpu, CANDIDATES[c].start), (pl.gpu, pl.index), "{p}");
                    }
                    (a, b) => panic!("{p}: {a:?} vs {b:?}"),
                }
            }
        }
    }

    #[test]
    fn infeasible_sentinel_matches_python_reference() {
        // python/compile/kernels/ref.py pins INFEASIBLE = 1e9.
        assert_eq!(INFEASIBLE_DELTA, 1e9);
    }
}
