//! The XLA-offloaded fragmentation engine.
//!
//! Wraps the AOT artifact produced by `python/compile/aot.py` — a single
//! fused program computing, for a batch of GPU occupancy vectors:
//!
//! * `scores  f32[B]`      — Algorithm 1 fragmentation score per GPU;
//! * `deltas  f32[B, 18]`  — hypothetical ΔF for every candidate placement
//!   (Table I (profile, anchor) pairs in frozen [`CANDIDATES`] order);
//! * `feasible f32[B, 18]` — 1.0 where the candidate's window is free and
//!   the size guard holds (infeasible deltas carry a large sentinel).
//!
//! The artifact's batch size `B` is baked at lowering time and recorded in
//! `artifacts/manifest.json`; clusters larger than `B` are evaluated in
//! chunks, smaller ones are padded with fully-occupied rows (which are
//! infeasible everywhere and score 0, so padding never influences argmins).

use std::path::Path;

use anyhow::{Context, Result};

use super::pjrt::{literal_f32, CompiledModule, PjrtRuntime};
use crate::mig::{NUM_CANDIDATES, NUM_SLICES};
use crate::util::json::Json;

/// Result of one batched evaluation over `m` GPUs.
#[derive(Clone, Debug)]
pub struct FragBatch {
    /// `F(m)` per GPU.
    pub scores: Vec<f32>,
    /// ΔF per GPU per candidate ([`crate::mig::CANDIDATES`] order).
    pub deltas: Vec<[f32; NUM_CANDIDATES]>,
    /// Feasibility per GPU per candidate.
    pub feasible: Vec<[bool; NUM_CANDIDATES]>,
}

/// The compiled batched fragmentation program.
pub struct FragEngine {
    module: CompiledModule,
    batch: usize,
    rule: String,
}

impl FragEngine {
    /// Load `frag.hlo.txt` + `manifest.json` from the artifacts directory
    /// (see [`super::artifacts_dir`]) and compile it.
    pub fn load_default(runtime: &PjrtRuntime) -> Result<Self> {
        let dir = super::artifacts_dir();
        Self::load(runtime, &dir.join("frag.hlo.txt"), &dir.join("manifest.json"))
    }

    /// Load an explicit artifact + manifest pair.
    pub fn load(runtime: &PjrtRuntime, hlo_path: &Path, manifest_path: &Path) -> Result<Self> {
        let manifest_text = std::fs::read_to_string(manifest_path)
            .with_context(|| format!("reading {}", manifest_path.display()))?;
        let manifest = Json::parse(&manifest_text)
            .map_err(|e| anyhow::anyhow!("parsing {}: {e}", manifest_path.display()))?;
        let batch = manifest
            .get("batch")
            .and_then(Json::as_usize)
            .context("manifest missing 'batch'")?;
        let rule = manifest
            .get("rule")
            .and_then(Json::as_str)
            .unwrap_or("partial")
            .to_string();
        let n_candidates = manifest
            .get("num_candidates")
            .and_then(Json::as_usize)
            .context("manifest missing 'num_candidates'")?;
        anyhow::ensure!(
            n_candidates == NUM_CANDIDATES,
            "artifact candidate table arity {n_candidates} != rust {NUM_CANDIDATES}; \
             re-run `make artifacts`"
        );
        let module = runtime.load_hlo_text(hlo_path)?;
        Ok(Self { module, batch, rule })
    }

    /// The artifact's baked batch size.
    pub fn batch_size(&self) -> usize {
        self.batch
    }

    /// Overlap rule the artifact was built with ("partial" / "any").
    pub fn rule(&self) -> &str {
        &self.rule
    }

    /// Evaluate scores + deltas + feasibility for `masks` (one byte per
    /// GPU), chunking/padding to the artifact batch size.
    pub fn evaluate(&self, masks: &[u8]) -> Result<FragBatch> {
        let m = masks.len();
        let mut out = FragBatch {
            scores: Vec::with_capacity(m),
            deltas: Vec::with_capacity(m),
            feasible: Vec::with_capacity(m),
        };
        for chunk in masks.chunks(self.batch) {
            self.evaluate_chunk(chunk, &mut out)?;
        }
        Ok(out)
    }

    fn evaluate_chunk(&self, masks: &[u8], out: &mut FragBatch) -> Result<()> {
        let b = self.batch;
        // Expand masks to the f32 occupancy matrix, padding with 0xFF.
        let mut occ = vec![1.0f32; b * NUM_SLICES];
        for (row, &mask) in masks.iter().enumerate() {
            for s in 0..NUM_SLICES {
                occ[row * NUM_SLICES + s] =
                    if mask & (1 << s) != 0 { 1.0 } else { 0.0 };
            }
        }
        let input = literal_f32(&occ, &[b as i64, NUM_SLICES as i64])?;
        let outputs = self.module.execute(&[input])?;
        anyhow::ensure!(outputs.len() == 3, "expected 3 outputs, got {}", outputs.len());
        let scores: Vec<f32> = outputs[0].to_vec().context("scores output")?;
        let deltas: Vec<f32> = outputs[1].to_vec().context("deltas output")?;
        let feasible: Vec<f32> = outputs[2].to_vec().context("feasible output")?;
        anyhow::ensure!(scores.len() == b, "scores arity {}", scores.len());
        anyhow::ensure!(deltas.len() == b * NUM_CANDIDATES, "deltas arity {}", deltas.len());
        anyhow::ensure!(
            feasible.len() == b * NUM_CANDIDATES,
            "feasible arity {}",
            feasible.len()
        );
        for row in 0..masks.len() {
            out.scores.push(scores[row]);
            let mut drow = [0.0f32; NUM_CANDIDATES];
            let mut frow = [false; NUM_CANDIDATES];
            for c in 0..NUM_CANDIDATES {
                drow[c] = deltas[row * NUM_CANDIDATES + c];
                frow[c] = feasible[row * NUM_CANDIDATES + c] > 0.5;
            }
            out.deltas.push(drow);
            out.feasible.push(frow);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    // FragEngine needs the compiled artifact; end-to-end coverage lives in
    // rust/tests/runtime_vs_native.rs (skips gracefully when artifacts are
    // absent). Here we only test the pure helpers.

    #[test]
    fn padding_mask_is_all_occupied() {
        // The chunk path pads with 1.0 (occupied) — verified indirectly by
        // the integration test; this pins the constant used above.
        let pad = 0xFFu8;
        assert_eq!(pad.count_ones(), 8);
    }
}
