//! Point-in-time cluster metrics — the five quantities the paper's
//! evaluation tracks (Section VI).

use super::state::Cluster;
use crate::frag::{FleetTables, FragScorer};
use crate::util::json::Json;

/// A snapshot of the paper's evaluation metrics at one instant.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ClusterMetrics {
    /// Workloads currently allocated (paper: "allocated workloads").
    pub allocated_workloads: usize,
    /// Workloads accepted since reset (cumulative; the acceptance-rate
    /// numerator — maintained by the simulation/serving loop).
    pub accepted_total: u64,
    /// Workloads arrived since reset (the acceptance-rate denominator).
    pub arrived_total: u64,
    /// Allocated slices / capacity.
    pub utilization: f64,
    /// GPUs hosting at least one workload.
    pub active_gpus: usize,
    /// Cluster-average fragmentation score (paper Fig. 6).
    pub mean_frag_score: f64,
}

impl ClusterMetrics {
    /// Capture the instantaneous gauges from a cluster; the cumulative
    /// counters (`accepted_total`, `arrived_total`) are supplied by the
    /// owning loop.
    pub fn capture(
        cluster: &Cluster,
        scorer: &dyn FragScorer,
        accepted_total: u64,
        arrived_total: u64,
    ) -> Self {
        Self {
            allocated_workloads: cluster.allocated_workloads(),
            accepted_total,
            arrived_total,
            utilization: cluster.utilization(),
            active_gpus: cluster.active_gpus(),
            mean_frag_score: scorer.mean_score(cluster.gpus()),
        }
    }

    /// Like [`ClusterMetrics::capture`] but scoring each GPU against its
    /// own device class's table. On a single-class fleet the mean is
    /// bit-identical to `capture` with that class's table (see
    /// [`FleetTables::mean_score`]).
    pub fn capture_fleet(
        cluster: &Cluster,
        tables: &FleetTables,
        accepted_total: u64,
        arrived_total: u64,
    ) -> Self {
        Self {
            allocated_workloads: cluster.allocated_workloads(),
            accepted_total,
            arrived_total,
            utilization: cluster.utilization(),
            active_gpus: cluster.active_gpus(),
            mean_frag_score: tables.mean_score(cluster),
        }
    }

    /// Acceptance rate in [0, 1]; 1.0 when nothing has arrived yet.
    pub fn acceptance_rate(&self) -> f64 {
        if self.arrived_total == 0 {
            1.0
        } else {
            self.accepted_total as f64 / self.arrived_total as f64
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .with("allocated_workloads", self.allocated_workloads)
            .with("accepted_total", self.accepted_total)
            .with("arrived_total", self.arrived_total)
            .with("acceptance_rate", self.acceptance_rate())
            .with("utilization", self.utilization)
            .with("active_gpus", self.active_gpus)
            .with("mean_frag_score", self.mean_frag_score)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frag::ScoreTable;
    use crate::mig::{HardwareModel, Placement, Profile};
    use crate::workload::WorkloadId;

    #[test]
    fn capture_reads_cluster_gauges() {
        let hw = HardwareModel::a100_80gb();
        let mut c = Cluster::new(hw.clone(), 2);
        let table = ScoreTable::for_hardware(&hw);
        c.allocate(
            WorkloadId(0),
            Placement { gpu: 0, profile: Profile::P1g10gb, index: 5 },
        )
        .unwrap();
        let m = ClusterMetrics::capture(&c, &table, 1, 2);
        assert_eq!(m.allocated_workloads, 1);
        assert_eq!(m.active_gpus, 1);
        assert!((m.utilization - 1.0 / 16.0).abs() < 1e-12);
        // GPU 0 scores 8 (paper worked example), GPU 1 scores 0.
        assert!((m.mean_frag_score - 4.0).abs() < 1e-12);
        assert!((m.acceptance_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn capture_fleet_is_bit_identical_on_uniform_clusters() {
        let hw = HardwareModel::a100_80gb();
        let mut c = Cluster::new(hw.clone(), 3);
        c.allocate(WorkloadId(0), Placement { gpu: 1, profile: Profile::P2g20gb, index: 2 })
            .unwrap();
        let table = ScoreTable::for_hardware(&hw);
        let tables = crate::frag::FleetTables::for_cluster(&c);
        let a = ClusterMetrics::capture(&c, &table, 3, 4);
        let b = ClusterMetrics::capture_fleet(&c, &tables, 3, 4);
        assert_eq!(a, b);
        assert_eq!(a.mean_frag_score.to_bits(), b.mean_frag_score.to_bits());
    }

    #[test]
    fn acceptance_rate_empty() {
        let m = ClusterMetrics::default();
        assert_eq!(m.acceptance_rate(), 1.0);
    }

    #[test]
    fn json_contains_all_fields() {
        let m = ClusterMetrics {
            allocated_workloads: 3,
            accepted_total: 5,
            arrived_total: 10,
            utilization: 0.25,
            active_gpus: 2,
            mean_frag_score: 1.5,
        };
        let j = m.to_json();
        assert_eq!(j.req_u64("accepted_total").unwrap(), 5);
        assert!((j.get("acceptance_rate").unwrap().as_f64().unwrap() - 0.5).abs() < 1e-12);
        assert_eq!(j.req_u64("active_gpus").unwrap(), 2);
    }
}
