//! Cluster state management: the authoritative view of every GPU's
//! occupancy plus the workload → placement registry, with point-in-time
//! metrics, JSON snapshots, and an event-driven change feed (generation
//! counter + bounded commit/release log) that lets incremental consumers
//! track "which GPU changed" without rescanning the occupancy vector.

pub mod metrics;
pub mod snapshot;
pub mod state;

pub use metrics::ClusterMetrics;
pub use state::{
    AllocError, ChangeKind, ClassStats, Cluster, ClusterEvent, CHANGE_LOG_CAPACITY,
};
