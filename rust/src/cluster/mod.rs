//! Cluster state management: the authoritative view of every GPU's
//! occupancy plus the workload → placement registry, with point-in-time
//! metrics and JSON snapshots.

pub mod metrics;
pub mod snapshot;
pub mod state;

pub use metrics::ClusterMetrics;
pub use state::{AllocError, Cluster};
