//! The cluster state: GPU occupancy vector + workload allocation registry.

use std::collections::{HashMap, VecDeque};

use crate::mig::{GpuState, HardwareModel, Placement, Profile};
use crate::workload::WorkloadId;

/// Direction of one cluster mutation.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ChangeKind {
    /// A placement was committed ([`Cluster::allocate`]).
    Commit,
    /// A placement was released ([`Cluster::release`]).
    Release,
}

/// One entry of the cluster's change log: which GPU changed, how, and the
/// generation the cluster reached by applying it.
///
/// A commit or release touches exactly one GPU, so incremental consumers
/// (the [`crate::frag::FragIndex`] behind `MFI-IDX`) can re-derive just
/// that GPU's state in O(k) instead of rescanning all `M` GPUs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ClusterEvent {
    /// Generation counter value AFTER this event was applied.
    pub generation: u64,
    pub kind: ChangeKind,
    /// The placement committed or released (carries the GPU id).
    pub placement: Placement,
}

/// How many events the change log retains. Consumers further behind than
/// this must rebuild from the occupancy vector (`events_since` → `None`).
pub const CHANGE_LOG_CAPACITY: usize = 4096;

/// A homogeneous MIG GPU cluster (paper Section IV: set `M` of GPUs of the
/// same hardware model).
///
/// `Cluster` owns the authoritative occupancy state. Schedulers *propose*
/// placements ([`crate::sched::Scheduler::schedule`]); the owner (simulator
/// or serving daemon) *commits* them here, which keeps dry-run logic free
/// of undo bookkeeping and makes double-commit/double-free programming
/// errors detectable at this single choke point.
#[derive(Clone, Debug)]
pub struct Cluster {
    hw: HardwareModel,
    gpus: Vec<GpuState>,
    allocations: HashMap<WorkloadId, Placement>,
    /// Slices currently allocated (kept incrementally; equals the sum of
    /// per-GPU used slices — asserted in debug builds).
    used_slices: u64,
    /// Monotone mutation counter: bumped by every successful allocate /
    /// release / clear. Lets consumers detect staleness in O(1).
    generation: u64,
    /// Bounded log of the most recent mutations, consecutive generations
    /// ending at `generation`. Emptied (discontinuity) by `clear()`.
    log: VecDeque<ClusterEvent>,
}

/// Errors from committing or releasing allocations.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum AllocError {
    UnknownGpu { gpu: usize, cluster_size: usize },
    DuplicateWorkload(WorkloadId),
    UnknownWorkload(WorkloadId),
    UnsupportedProfile(Profile),
    Placement(crate::mig::gpu::PlacementError),
}

impl std::fmt::Display for AllocError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AllocError::UnknownGpu { gpu, cluster_size } => {
                write!(f, "gpu {gpu} out of range (cluster has {cluster_size})")
            }
            AllocError::DuplicateWorkload(id) => write!(f, "workload {id} already allocated"),
            AllocError::UnknownWorkload(id) => write!(f, "workload {id} not allocated"),
            AllocError::UnsupportedProfile(p) => {
                write!(f, "profile {p} not supported by this hardware model")
            }
            AllocError::Placement(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for AllocError {}

impl Cluster {
    /// A cluster of `num_gpus` empty GPUs.
    pub fn new(hw: HardwareModel, num_gpus: usize) -> Self {
        assert!(num_gpus > 0, "cluster needs at least one GPU");
        Self {
            gpus: vec![GpuState::empty(); num_gpus],
            hw,
            allocations: HashMap::new(),
            used_slices: 0,
            generation: 0,
            log: VecDeque::new(),
        }
    }

    // ----- change observation ----------------------------------------------

    /// Monotone mutation counter (0 for a fresh cluster). Two clusters (or
    /// one cluster at two points in time) with equal generation and shared
    /// history have identical occupancy.
    #[inline]
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// The events that advanced the cluster from `generation` to the
    /// current state, oldest first. `None` when the consumer is too far
    /// behind (more than [`CHANGE_LOG_CAPACITY`] events, or a `clear()`
    /// discontinuity) — then the consumer must rebuild from
    /// [`Cluster::gpus`].
    ///
    /// Generations are meaningful only within ONE cluster's timeline: a
    /// generation obtained from an unrelated `Cluster` is indistinguishable
    /// from a legitimate one, so consumers tracking multiple clusters must
    /// key their state per cluster (see `sched::mfi_indexed` module docs).
    pub fn events_since(&self, generation: u64) -> Option<Vec<ClusterEvent>> {
        if generation > self.generation {
            return None;
        }
        let missed = (self.generation - generation) as usize;
        if missed > self.log.len() {
            return None;
        }
        Some(self.log.iter().skip(self.log.len() - missed).copied().collect())
    }

    fn record(&mut self, kind: ChangeKind, placement: Placement) {
        self.generation += 1;
        if self.log.len() == CHANGE_LOG_CAPACITY {
            self.log.pop_front();
        }
        self.log.push_back(ClusterEvent { generation: self.generation, kind, placement });
    }

    // ----- read access ----------------------------------------------------

    pub fn hardware(&self) -> &HardwareModel {
        &self.hw
    }

    pub fn num_gpus(&self) -> usize {
        self.gpus.len()
    }

    pub fn gpu(&self, id: usize) -> Option<GpuState> {
        self.gpus.get(id).copied()
    }

    /// The occupancy vector — the scheduler-facing view.
    pub fn gpus(&self) -> &[GpuState] {
        &self.gpus
    }

    /// Total slice capacity (M × 8).
    pub fn capacity_slices(&self) -> u64 {
        (self.gpus.len() * self.hw.num_slices()) as u64
    }

    /// Currently allocated slices.
    pub fn used_slices(&self) -> u64 {
        debug_assert_eq!(
            self.used_slices,
            self.gpus.iter().map(|g| g.used_slices() as u64).sum::<u64>()
        );
        self.used_slices
    }

    pub fn free_slices(&self) -> u64 {
        self.capacity_slices() - self.used_slices()
    }

    /// Fraction of slices allocated (paper Fig. 4c/5c "resource utilization").
    pub fn utilization(&self) -> f64 {
        self.used_slices() as f64 / self.capacity_slices() as f64
    }

    /// GPUs hosting at least one workload (paper Fig. 4d/5d "active GPUs").
    pub fn active_gpus(&self) -> usize {
        self.gpus.iter().filter(|g| !g.is_empty()).count()
    }

    /// Number of currently allocated workloads.
    pub fn allocated_workloads(&self) -> usize {
        self.allocations.len()
    }

    pub fn placement_of(&self, id: WorkloadId) -> Option<Placement> {
        self.allocations.get(&id).copied()
    }

    /// Iterate over current allocations in unspecified order.
    pub fn allocations(&self) -> impl Iterator<Item = (WorkloadId, Placement)> + '_ {
        self.allocations.iter().map(|(k, v)| (*k, *v))
    }

    /// Raw occupancy masks, one byte per GPU — the XLA engine's input.
    pub fn occupancy_masks(&self) -> Vec<u8> {
        self.gpus.iter().map(|g| g.mask()).collect()
    }

    /// Whether any GPU can host `profile` right now.
    pub fn can_host(&self, profile: Profile) -> bool {
        self.hw.supports(profile) && self.gpus.iter().any(|g| g.can_host(profile))
    }

    // ----- mutation ---------------------------------------------------------

    /// Commit a placement for a workload.
    pub fn allocate(&mut self, id: WorkloadId, placement: Placement) -> Result<(), AllocError> {
        if !self.hw.supports(placement.profile) {
            return Err(AllocError::UnsupportedProfile(placement.profile));
        }
        if placement.gpu >= self.gpus.len() {
            return Err(AllocError::UnknownGpu {
                gpu: placement.gpu,
                cluster_size: self.gpus.len(),
            });
        }
        if self.allocations.contains_key(&id) {
            return Err(AllocError::DuplicateWorkload(id));
        }
        self.gpus[placement.gpu]
            .place(placement.profile, placement.index)
            .map_err(AllocError::Placement)?;
        self.used_slices += placement.profile.size() as u64;
        self.allocations.insert(id, placement);
        self.record(ChangeKind::Commit, placement);
        Ok(())
    }

    /// Release a workload's slices; returns the freed placement.
    pub fn release(&mut self, id: WorkloadId) -> Result<Placement, AllocError> {
        let placement =
            self.allocations.remove(&id).ok_or(AllocError::UnknownWorkload(id))?;
        self.gpus[placement.gpu]
            .release(placement.profile, placement.index)
            .map_err(AllocError::Placement)?;
        self.used_slices -= placement.profile.size() as u64;
        self.record(ChangeKind::Release, placement);
        Ok(placement)
    }

    /// Drop every allocation (simulation reset without reallocating).
    /// This is a change-log discontinuity: incremental consumers observe a
    /// generation bump with no replayable events and must rebuild.
    pub fn clear(&mut self) {
        for g in &mut self.gpus {
            *g = GpuState::empty();
        }
        self.allocations.clear();
        self.used_slices = 0;
        self.generation += 1;
        self.log.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mig::gpu::PlacementError;

    fn cluster() -> Cluster {
        Cluster::new(HardwareModel::a100_80gb(), 3)
    }

    fn wid(n: u64) -> WorkloadId {
        WorkloadId(n)
    }

    fn pl(gpu: usize, profile: Profile, index: u8) -> Placement {
        Placement { gpu, profile, index }
    }

    #[test]
    fn fresh_cluster_counts() {
        let c = cluster();
        assert_eq!(c.capacity_slices(), 24);
        assert_eq!(c.used_slices(), 0);
        assert_eq!(c.free_slices(), 24);
        assert_eq!(c.active_gpus(), 0);
        assert_eq!(c.allocated_workloads(), 0);
        assert_eq!(c.utilization(), 0.0);
        assert!(c.can_host(Profile::P7g80gb));
    }

    #[test]
    fn allocate_release_roundtrip() {
        let mut c = cluster();
        c.allocate(wid(1), pl(0, Profile::P3g40gb, 4)).unwrap();
        c.allocate(wid(2), pl(1, Profile::P1g10gb, 6)).unwrap();
        assert_eq!(c.used_slices(), 5);
        assert_eq!(c.active_gpus(), 2);
        assert_eq!(c.allocated_workloads(), 2);
        assert_eq!(c.placement_of(wid(1)), Some(pl(0, Profile::P3g40gb, 4)));

        let freed = c.release(wid(1)).unwrap();
        assert_eq!(freed, pl(0, Profile::P3g40gb, 4));
        assert_eq!(c.used_slices(), 1);
        assert_eq!(c.active_gpus(), 1);
        assert_eq!(c.placement_of(wid(1)), None);
    }

    #[test]
    fn rejects_duplicates_and_unknowns() {
        let mut c = cluster();
        c.allocate(wid(1), pl(0, Profile::P1g10gb, 0)).unwrap();
        assert_eq!(
            c.allocate(wid(1), pl(1, Profile::P1g10gb, 0)),
            Err(AllocError::DuplicateWorkload(wid(1)))
        );
        assert_eq!(c.release(wid(9)), Err(AllocError::UnknownWorkload(wid(9))));
        assert_eq!(
            c.allocate(wid(2), pl(7, Profile::P1g10gb, 0)),
            Err(AllocError::UnknownGpu { gpu: 7, cluster_size: 3 })
        );
    }

    #[test]
    fn rejects_overlapping_commit() {
        let mut c = cluster();
        c.allocate(wid(1), pl(0, Profile::P4g40gb, 0)).unwrap();
        let err = c.allocate(wid(2), pl(0, Profile::P3g40gb, 0)).unwrap_err();
        assert!(matches!(err, AllocError::Placement(PlacementError::Occupied { .. })));
        // Failed commit must not corrupt accounting.
        assert_eq!(c.used_slices(), 4);
        assert_eq!(c.allocated_workloads(), 1);
    }

    #[test]
    fn rejects_unsupported_profile() {
        let hw = HardwareModel::a100_80gb().with_profiles(&[Profile::P1g10gb]);
        let mut c = Cluster::new(hw, 1);
        assert_eq!(
            c.allocate(wid(1), pl(0, Profile::P7g80gb, 0)),
            Err(AllocError::UnsupportedProfile(Profile::P7g80gb))
        );
        assert!(!c.can_host(Profile::P7g80gb));
        assert!(c.can_host(Profile::P1g10gb));
    }

    #[test]
    fn occupancy_masks_reflect_state() {
        let mut c = cluster();
        c.allocate(wid(1), pl(1, Profile::P2g20gb, 2)).unwrap();
        assert_eq!(c.occupancy_masks(), vec![0, 0b0000_1100, 0]);
    }

    #[test]
    fn clear_resets_everything() {
        let mut c = cluster();
        c.allocate(wid(1), pl(0, Profile::P7g80gb, 0)).unwrap();
        c.clear();
        assert_eq!(c.used_slices(), 0);
        assert_eq!(c.allocated_workloads(), 0);
        assert_eq!(c.active_gpus(), 0);
    }

    #[test]
    fn generation_counts_mutations_only() {
        let mut c = cluster();
        assert_eq!(c.generation(), 0);
        c.allocate(wid(1), pl(0, Profile::P2g20gb, 0)).unwrap();
        assert_eq!(c.generation(), 1);
        // Failed mutations must not advance the generation.
        assert!(c.allocate(wid(1), pl(0, Profile::P2g20gb, 2)).is_err());
        assert!(c.allocate(wid(2), pl(0, Profile::P2g20gb, 0)).is_err());
        assert!(c.release(wid(9)).is_err());
        assert_eq!(c.generation(), 1);
        c.release(wid(1)).unwrap();
        assert_eq!(c.generation(), 2);
    }

    #[test]
    fn change_log_replays_missed_events() {
        let mut c = cluster();
        c.allocate(wid(1), pl(0, Profile::P3g40gb, 4)).unwrap();
        let observed = c.generation();
        c.allocate(wid(2), pl(1, Profile::P1g10gb, 6)).unwrap();
        c.release(wid(1)).unwrap();

        assert_eq!(c.events_since(c.generation()), Some(vec![]));
        let events = c.events_since(observed).unwrap();
        assert_eq!(events.len(), 2);
        assert_eq!(events[0].kind, ChangeKind::Commit);
        assert_eq!(events[0].placement, pl(1, Profile::P1g10gb, 6));
        assert_eq!(events[0].generation, observed + 1);
        assert_eq!(events[1].kind, ChangeKind::Release);
        assert_eq!(events[1].placement, pl(0, Profile::P3g40gb, 4));
        assert_eq!(events[1].generation, c.generation());
        // Replaying the events over the old occupancy reproduces the new.
        let mut masks = vec![0b1111_0000u8, 0, 0];
        for e in &events {
            let m = e.placement.profile.mask_at(e.placement.index);
            match e.kind {
                ChangeKind::Commit => masks[e.placement.gpu] |= m,
                ChangeKind::Release => masks[e.placement.gpu] &= !m,
            }
        }
        assert_eq!(masks, c.occupancy_masks());
    }

    #[test]
    fn events_since_rejects_unreachable_generations() {
        let mut c = cluster();
        c.allocate(wid(1), pl(0, Profile::P1g10gb, 0)).unwrap();
        // From the future (e.g. a different cluster's generation).
        assert_eq!(c.events_since(c.generation() + 1), None);
        // Across a clear() discontinuity.
        let observed = c.generation();
        c.clear();
        assert!(c.generation() > observed);
        assert_eq!(c.events_since(observed), None);
        // Too far behind: more than the log capacity.
        let mut c = cluster();
        let observed = c.generation();
        for _ in 0..=(CHANGE_LOG_CAPACITY / 2) {
            c.allocate(wid(7), pl(0, Profile::P1g10gb, 0)).unwrap();
            c.release(wid(7)).unwrap();
        }
        assert_eq!(c.events_since(observed), None);
        // But a consumer within the window can still catch up.
        assert!(c.events_since(c.generation() - CHANGE_LOG_CAPACITY as u64).is_some());
    }

    #[test]
    fn utilization_fraction() {
        let mut c = cluster();
        c.allocate(wid(1), pl(0, Profile::P7g80gb, 0)).unwrap();
        assert!((c.utilization() - 8.0 / 24.0).abs() < 1e-12);
    }
}
