//! The cluster state: GPU occupancy vector + workload allocation registry.

use std::collections::{HashMap, VecDeque};
use std::sync::Arc;

use crate::mig::{FleetSpec, GpuState, HardwareModel, Placement, Profile};
use crate::workload::WorkloadId;

/// Direction of one cluster mutation.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ChangeKind {
    /// A placement was committed ([`Cluster::allocate`]).
    Commit,
    /// A placement was released ([`Cluster::release`]).
    Release,
}

/// One entry of the cluster's change log: which GPU changed, how, and the
/// generation the cluster reached by applying it.
///
/// A commit or release touches exactly one GPU, so incremental consumers
/// (the [`crate::frag::FragIndex`] behind `MFI-IDX`) can re-derive just
/// that GPU's state in O(k) instead of rescanning all `M` GPUs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ClusterEvent {
    /// Generation counter value AFTER this event was applied.
    pub generation: u64,
    pub kind: ChangeKind,
    /// The placement committed or released (carries the GPU id).
    pub placement: Placement,
}

/// How many events the change log retains. Consumers further behind than
/// this must rebuild from the occupancy vector (`events_since` → `None`).
pub const CHANGE_LOG_CAPACITY: usize = 4096;

/// A MIG GPU cluster (paper Section IV: a set `M` of GPUs), optionally
/// heterogeneous: every GPU carries a compact class id into a small table
/// of [`HardwareModel`] device classes. The paper's homogeneous cluster is
/// the single-class special case ([`Cluster::new`]), and all legacy
/// accessors ([`Cluster::hardware`] = class 0) keep their meaning there.
///
/// `Cluster` owns the authoritative occupancy state. Schedulers *propose*
/// placements ([`crate::sched::Scheduler::schedule`]); the owner (simulator
/// or serving daemon) *commits* them here, which keeps dry-run logic free
/// of undo bookkeeping and makes double-commit/double-free programming
/// errors detectable at this single choke point.
#[derive(Clone, Debug)]
pub struct Cluster {
    /// Device-class table, class id = index. Non-empty; shared so
    /// consumers (schedulers, indexes) can cache per-class derived state
    /// keyed on pointer identity.
    classes: Arc<[HardwareModel]>,
    /// Per-GPU class id, parallel to `gpus`. Immutable after construction.
    class_ids: Arc<[u8]>,
    gpus: Vec<GpuState>,
    allocations: HashMap<WorkloadId, Placement>,
    /// Slices currently allocated (kept incrementally; equals the sum of
    /// per-GPU used slices — asserted in debug builds).
    used_slices: u64,
    /// Monotone mutation counter: bumped by every successful allocate /
    /// release / clear. Lets consumers detect staleness in O(1).
    generation: u64,
    /// Bounded log of the most recent mutations, consecutive generations
    /// ending at `generation`. Emptied (discontinuity) by `clear()`.
    log: VecDeque<ClusterEvent>,
}

/// Errors from committing or releasing allocations.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum AllocError {
    UnknownGpu { gpu: usize, cluster_size: usize },
    DuplicateWorkload(WorkloadId),
    UnknownWorkload(WorkloadId),
    UnsupportedProfile(Profile),
    Placement(crate::mig::gpu::PlacementError),
}

impl std::fmt::Display for AllocError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AllocError::UnknownGpu { gpu, cluster_size } => {
                write!(f, "gpu {gpu} out of range (cluster has {cluster_size})")
            }
            AllocError::DuplicateWorkload(id) => write!(f, "workload {id} already allocated"),
            AllocError::UnknownWorkload(id) => write!(f, "workload {id} not allocated"),
            AllocError::UnsupportedProfile(p) => {
                write!(f, "profile {p} not supported by this hardware model")
            }
            AllocError::Placement(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for AllocError {}

/// Per-class instantaneous gauges (see [`Cluster::per_class_stats`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ClassStats {
    /// GPUs of this class in the cluster.
    pub gpus: usize,
    /// GPUs of this class hosting at least one workload.
    pub active_gpus: usize,
    /// Slices allocated on this class's GPUs.
    pub used_slices: u64,
    /// Workloads placed on this class's GPUs.
    pub allocated_workloads: usize,
}

impl Cluster {
    /// A homogeneous cluster of `num_gpus` empty GPUs — the single-class
    /// special case.
    pub fn new(hw: HardwareModel, num_gpus: usize) -> Self {
        Self::from_classes(vec![hw], &[num_gpus])
    }

    /// A cluster laid out from a fleet spec: GPUs of class 0 first, then
    /// class 1, … (consecutive runs, so GPU ids are stable per class).
    pub fn from_fleet(fleet: &FleetSpec) -> Self {
        Self::from_classes(fleet.models(), &fleet.counts())
    }

    /// A cluster from an explicit class table + per-class GPU counts.
    /// Unlike [`FleetSpec`], zero counts are allowed here (a shard of a
    /// partitioned fleet may hold none of some class while still sharing
    /// the fleet-wide class table, keeping class ids globally consistent).
    pub fn from_classes(models: Vec<HardwareModel>, counts: &[usize]) -> Self {
        assert!(!models.is_empty(), "cluster needs at least one device class");
        assert_eq!(models.len(), counts.len(), "one count per device class");
        assert!(models.len() <= u8::MAX as usize + 1, "at most 256 device classes");
        let num_gpus: usize = counts.iter().sum();
        assert!(num_gpus > 0, "cluster needs at least one GPU");
        let mut class_ids = Vec::with_capacity(num_gpus);
        for (class, &count) in counts.iter().enumerate() {
            class_ids.extend(std::iter::repeat(class as u8).take(count));
        }
        Self::from_class_layout(models, class_ids)
    }

    /// A cluster from an explicit class table + an arbitrary per-GPU class
    /// assignment (GPU `i` is of class `class_ids[i]`). This is the fully
    /// general layout — a fleet-global view merged from per-shard slices
    /// interleaves class runs, so snapshot restore cannot assume
    /// consecutive runs.
    pub fn from_class_layout(models: Vec<HardwareModel>, class_ids: Vec<u8>) -> Self {
        assert!(!models.is_empty(), "cluster needs at least one device class");
        assert!(models.len() <= u8::MAX as usize + 1, "at most 256 device classes");
        assert!(!class_ids.is_empty(), "cluster needs at least one GPU");
        assert!(
            class_ids.iter().all(|&c| (c as usize) < models.len()),
            "class id out of range of the class table"
        );
        let num_gpus = class_ids.len();
        Self {
            classes: models.into(),
            class_ids: class_ids.into(),
            gpus: vec![GpuState::empty(); num_gpus],
            allocations: HashMap::new(),
            used_slices: 0,
            generation: 0,
            log: VecDeque::new(),
        }
    }

    // ----- change observation ----------------------------------------------

    /// Monotone mutation counter (0 for a fresh cluster). Two clusters (or
    /// one cluster at two points in time) with equal generation and shared
    /// history have identical occupancy.
    #[inline]
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// The events that advanced the cluster from `generation` to the
    /// current state, oldest first. `None` when the consumer is too far
    /// behind (more than [`CHANGE_LOG_CAPACITY`] events, or a `clear()`
    /// discontinuity) — then the consumer must rebuild from
    /// [`Cluster::gpus`].
    ///
    /// Generations are meaningful only within ONE cluster's timeline: a
    /// generation obtained from an unrelated `Cluster` is indistinguishable
    /// from a legitimate one, so consumers tracking multiple clusters must
    /// key their state per cluster (see `sched::mfi_indexed` module docs).
    pub fn events_since(&self, generation: u64) -> Option<Vec<ClusterEvent>> {
        if generation > self.generation {
            return None;
        }
        let missed = (self.generation - generation) as usize;
        if missed > self.log.len() {
            return None;
        }
        Some(self.log.iter().skip(self.log.len() - missed).copied().collect())
    }

    fn record(&mut self, kind: ChangeKind, placement: Placement) {
        self.generation += 1;
        if self.log.len() == CHANGE_LOG_CAPACITY {
            self.log.pop_front();
        }
        self.log.push_back(ClusterEvent { generation: self.generation, kind, placement });
    }

    // ----- read access ----------------------------------------------------

    /// Class 0's hardware model — THE hardware model on the single-class
    /// clusters every pre-fleet caller builds. On mixed fleets, prefer
    /// [`Cluster::hardware_of`] / [`Cluster::classes`].
    pub fn hardware(&self) -> &HardwareModel {
        &self.classes[0]
    }

    /// The device-class table (class id = index). Length 1 ⇔ homogeneous.
    pub fn classes(&self) -> &[HardwareModel] {
        &self.classes
    }

    /// Shared handle to the class table; pointer identity keys per-class
    /// derived caches (score tables, ΔF buckets).
    pub fn classes_arc(&self) -> &Arc<[HardwareModel]> {
        &self.classes
    }

    pub fn num_classes(&self) -> usize {
        self.classes.len()
    }

    /// Whether every GPU is of the same device class.
    pub fn is_uniform(&self) -> bool {
        self.classes.len() == 1
    }

    /// Per-GPU class ids, parallel to [`Cluster::gpus`].
    pub fn class_ids(&self) -> &[u8] {
        &self.class_ids
    }

    /// The class id of one GPU (panics out of range).
    #[inline]
    pub fn class_of(&self, gpu: usize) -> u8 {
        self.class_ids[gpu]
    }

    /// The hardware model of one GPU (panics out of range).
    #[inline]
    pub fn hardware_of(&self, gpu: usize) -> &HardwareModel {
        &self.classes[self.class_ids[gpu] as usize]
    }

    /// Whether at least one device class supports `profile`.
    pub fn supports(&self, profile: Profile) -> bool {
        self.classes.iter().any(|hw| hw.supports(profile))
    }

    /// Whether GPU `gpu`'s device class supports `profile`.
    #[inline]
    pub fn supports_on(&self, gpu: usize, profile: Profile) -> bool {
        self.hardware_of(gpu).supports(profile)
    }

    /// Parse a profile name against every class (class 0 first, so
    /// single-class clusters behave exactly like
    /// [`HardwareModel::parse_profile`]). Canonical names always work;
    /// hardware-specific names (e.g. `3g.20gb` on A100-40GB) resolve via
    /// the first class that knows them.
    pub fn parse_profile(&self, name: &str) -> Option<Profile> {
        self.classes.iter().find_map(|hw| hw.parse_profile(name))
    }

    /// Per-class GPU counts, class id order.
    pub fn class_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.classes.len()];
        for &c in self.class_ids.iter() {
            counts[c as usize] += 1;
        }
        counts
    }

    /// Instantaneous per-class gauges (GPUs, active GPUs, used slices,
    /// allocated workloads), class id order — the `/v1/stats` and
    /// `/metrics` per-class breakdown.
    pub fn per_class_stats(&self) -> Vec<ClassStats> {
        let mut out = vec![ClassStats::default(); self.classes.len()];
        for (i, g) in self.gpus.iter().enumerate() {
            let s = &mut out[self.class_ids[i] as usize];
            s.gpus += 1;
            if !g.is_empty() {
                s.active_gpus += 1;
            }
            s.used_slices += g.used_slices() as u64;
        }
        for placement in self.allocations.values() {
            out[self.class_ids[placement.gpu] as usize].allocated_workloads += 1;
        }
        out
    }

    pub fn num_gpus(&self) -> usize {
        self.gpus.len()
    }

    pub fn gpu(&self, id: usize) -> Option<GpuState> {
        self.gpus.get(id).copied()
    }

    /// The occupancy vector — the scheduler-facing view.
    pub fn gpus(&self) -> &[GpuState] {
        &self.gpus
    }

    /// Total slice capacity (M × 8; every supported part has 8 slices).
    pub fn capacity_slices(&self) -> u64 {
        (self.gpus.len() * self.classes[0].num_slices()) as u64
    }

    /// Currently allocated slices.
    pub fn used_slices(&self) -> u64 {
        debug_assert_eq!(
            self.used_slices,
            self.gpus.iter().map(|g| g.used_slices() as u64).sum::<u64>()
        );
        self.used_slices
    }

    pub fn free_slices(&self) -> u64 {
        self.capacity_slices() - self.used_slices()
    }

    /// Fraction of slices allocated (paper Fig. 4c/5c "resource utilization").
    pub fn utilization(&self) -> f64 {
        self.used_slices() as f64 / self.capacity_slices() as f64
    }

    /// GPUs hosting at least one workload (paper Fig. 4d/5d "active GPUs").
    pub fn active_gpus(&self) -> usize {
        self.gpus.iter().filter(|g| !g.is_empty()).count()
    }

    /// Number of currently allocated workloads.
    pub fn allocated_workloads(&self) -> usize {
        self.allocations.len()
    }

    pub fn placement_of(&self, id: WorkloadId) -> Option<Placement> {
        self.allocations.get(&id).copied()
    }

    /// Iterate over current allocations in unspecified order.
    pub fn allocations(&self) -> impl Iterator<Item = (WorkloadId, Placement)> + '_ {
        self.allocations.iter().map(|(k, v)| (*k, *v))
    }

    /// Raw occupancy masks, one byte per GPU — the XLA engine's input.
    pub fn occupancy_masks(&self) -> Vec<u8> {
        self.gpus.iter().map(|g| g.mask()).collect()
    }

    /// Whether any GPU can host `profile` right now (its class must
    /// support the profile AND a feasible anchor must be free).
    pub fn can_host(&self, profile: Profile) -> bool {
        self.gpus
            .iter()
            .enumerate()
            .any(|(i, g)| self.supports_on(i, profile) && g.can_host(profile))
    }

    // ----- mutation ---------------------------------------------------------

    /// Commit a placement for a workload.
    pub fn allocate(&mut self, id: WorkloadId, placement: Placement) -> Result<(), AllocError> {
        if !self.supports(placement.profile) {
            return Err(AllocError::UnsupportedProfile(placement.profile));
        }
        if placement.gpu >= self.gpus.len() {
            return Err(AllocError::UnknownGpu {
                gpu: placement.gpu,
                cluster_size: self.gpus.len(),
            });
        }
        if !self.supports_on(placement.gpu, placement.profile) {
            return Err(AllocError::UnsupportedProfile(placement.profile));
        }
        if self.allocations.contains_key(&id) {
            return Err(AllocError::DuplicateWorkload(id));
        }
        self.gpus[placement.gpu]
            .place(placement.profile, placement.index)
            .map_err(AllocError::Placement)?;
        self.used_slices += placement.profile.size() as u64;
        self.allocations.insert(id, placement);
        self.record(ChangeKind::Commit, placement);
        Ok(())
    }

    /// Release a workload's slices; returns the freed placement.
    pub fn release(&mut self, id: WorkloadId) -> Result<Placement, AllocError> {
        let placement =
            self.allocations.remove(&id).ok_or(AllocError::UnknownWorkload(id))?;
        self.gpus[placement.gpu]
            .release(placement.profile, placement.index)
            .map_err(AllocError::Placement)?;
        self.used_slices -= placement.profile.size() as u64;
        self.record(ChangeKind::Release, placement);
        Ok(placement)
    }

    /// Drop every allocation (simulation reset without reallocating).
    /// This is a change-log discontinuity: incremental consumers observe a
    /// generation bump with no replayable events and must rebuild.
    pub fn clear(&mut self) {
        for g in &mut self.gpus {
            *g = GpuState::empty();
        }
        self.allocations.clear();
        self.used_slices = 0;
        self.generation += 1;
        self.log.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mig::gpu::PlacementError;

    fn cluster() -> Cluster {
        Cluster::new(HardwareModel::a100_80gb(), 3)
    }

    fn wid(n: u64) -> WorkloadId {
        WorkloadId(n)
    }

    fn pl(gpu: usize, profile: Profile, index: u8) -> Placement {
        Placement { gpu, profile, index }
    }

    #[test]
    fn fresh_cluster_counts() {
        let c = cluster();
        assert_eq!(c.capacity_slices(), 24);
        assert_eq!(c.used_slices(), 0);
        assert_eq!(c.free_slices(), 24);
        assert_eq!(c.active_gpus(), 0);
        assert_eq!(c.allocated_workloads(), 0);
        assert_eq!(c.utilization(), 0.0);
        assert!(c.can_host(Profile::P7g80gb));
    }

    #[test]
    fn allocate_release_roundtrip() {
        let mut c = cluster();
        c.allocate(wid(1), pl(0, Profile::P3g40gb, 4)).unwrap();
        c.allocate(wid(2), pl(1, Profile::P1g10gb, 6)).unwrap();
        assert_eq!(c.used_slices(), 5);
        assert_eq!(c.active_gpus(), 2);
        assert_eq!(c.allocated_workloads(), 2);
        assert_eq!(c.placement_of(wid(1)), Some(pl(0, Profile::P3g40gb, 4)));

        let freed = c.release(wid(1)).unwrap();
        assert_eq!(freed, pl(0, Profile::P3g40gb, 4));
        assert_eq!(c.used_slices(), 1);
        assert_eq!(c.active_gpus(), 1);
        assert_eq!(c.placement_of(wid(1)), None);
    }

    #[test]
    fn rejects_duplicates_and_unknowns() {
        let mut c = cluster();
        c.allocate(wid(1), pl(0, Profile::P1g10gb, 0)).unwrap();
        assert_eq!(
            c.allocate(wid(1), pl(1, Profile::P1g10gb, 0)),
            Err(AllocError::DuplicateWorkload(wid(1)))
        );
        assert_eq!(c.release(wid(9)), Err(AllocError::UnknownWorkload(wid(9))));
        assert_eq!(
            c.allocate(wid(2), pl(7, Profile::P1g10gb, 0)),
            Err(AllocError::UnknownGpu { gpu: 7, cluster_size: 3 })
        );
    }

    #[test]
    fn rejects_overlapping_commit() {
        let mut c = cluster();
        c.allocate(wid(1), pl(0, Profile::P4g40gb, 0)).unwrap();
        let err = c.allocate(wid(2), pl(0, Profile::P3g40gb, 0)).unwrap_err();
        assert!(matches!(err, AllocError::Placement(PlacementError::Occupied { .. })));
        // Failed commit must not corrupt accounting.
        assert_eq!(c.used_slices(), 4);
        assert_eq!(c.allocated_workloads(), 1);
    }

    #[test]
    fn rejects_unsupported_profile() {
        let hw = HardwareModel::a100_80gb().with_profiles(&[Profile::P1g10gb]);
        let mut c = Cluster::new(hw, 1);
        assert_eq!(
            c.allocate(wid(1), pl(0, Profile::P7g80gb, 0)),
            Err(AllocError::UnsupportedProfile(Profile::P7g80gb))
        );
        assert!(!c.can_host(Profile::P7g80gb));
        assert!(c.can_host(Profile::P1g10gb));
    }

    #[test]
    fn occupancy_masks_reflect_state() {
        let mut c = cluster();
        c.allocate(wid(1), pl(1, Profile::P2g20gb, 2)).unwrap();
        assert_eq!(c.occupancy_masks(), vec![0, 0b0000_1100, 0]);
    }

    #[test]
    fn clear_resets_everything() {
        let mut c = cluster();
        c.allocate(wid(1), pl(0, Profile::P7g80gb, 0)).unwrap();
        c.clear();
        assert_eq!(c.used_slices(), 0);
        assert_eq!(c.allocated_workloads(), 0);
        assert_eq!(c.active_gpus(), 0);
    }

    #[test]
    fn generation_counts_mutations_only() {
        let mut c = cluster();
        assert_eq!(c.generation(), 0);
        c.allocate(wid(1), pl(0, Profile::P2g20gb, 0)).unwrap();
        assert_eq!(c.generation(), 1);
        // Failed mutations must not advance the generation.
        assert!(c.allocate(wid(1), pl(0, Profile::P2g20gb, 2)).is_err());
        assert!(c.allocate(wid(2), pl(0, Profile::P2g20gb, 0)).is_err());
        assert!(c.release(wid(9)).is_err());
        assert_eq!(c.generation(), 1);
        c.release(wid(1)).unwrap();
        assert_eq!(c.generation(), 2);
    }

    #[test]
    fn change_log_replays_missed_events() {
        let mut c = cluster();
        c.allocate(wid(1), pl(0, Profile::P3g40gb, 4)).unwrap();
        let observed = c.generation();
        c.allocate(wid(2), pl(1, Profile::P1g10gb, 6)).unwrap();
        c.release(wid(1)).unwrap();

        assert_eq!(c.events_since(c.generation()), Some(vec![]));
        let events = c.events_since(observed).unwrap();
        assert_eq!(events.len(), 2);
        assert_eq!(events[0].kind, ChangeKind::Commit);
        assert_eq!(events[0].placement, pl(1, Profile::P1g10gb, 6));
        assert_eq!(events[0].generation, observed + 1);
        assert_eq!(events[1].kind, ChangeKind::Release);
        assert_eq!(events[1].placement, pl(0, Profile::P3g40gb, 4));
        assert_eq!(events[1].generation, c.generation());
        // Replaying the events over the old occupancy reproduces the new.
        let mut masks = vec![0b1111_0000u8, 0, 0];
        for e in &events {
            let m = e.placement.profile.mask_at(e.placement.index);
            match e.kind {
                ChangeKind::Commit => masks[e.placement.gpu] |= m,
                ChangeKind::Release => masks[e.placement.gpu] &= !m,
            }
        }
        assert_eq!(masks, c.occupancy_masks());
    }

    #[test]
    fn events_since_rejects_unreachable_generations() {
        let mut c = cluster();
        c.allocate(wid(1), pl(0, Profile::P1g10gb, 0)).unwrap();
        // From the future (e.g. a different cluster's generation).
        assert_eq!(c.events_since(c.generation() + 1), None);
        // Across a clear() discontinuity.
        let observed = c.generation();
        c.clear();
        assert!(c.generation() > observed);
        assert_eq!(c.events_since(observed), None);
        // Too far behind: more than the log capacity.
        let mut c = cluster();
        let observed = c.generation();
        for _ in 0..=(CHANGE_LOG_CAPACITY / 2) {
            c.allocate(wid(7), pl(0, Profile::P1g10gb, 0)).unwrap();
            c.release(wid(7)).unwrap();
        }
        assert_eq!(c.events_since(observed), None);
        // But a consumer within the window can still catch up.
        assert!(c.events_since(c.generation() - CHANGE_LOG_CAPACITY as u64).is_some());
    }

    #[test]
    fn utilization_fraction() {
        let mut c = cluster();
        c.allocate(wid(1), pl(0, Profile::P7g80gb, 0)).unwrap();
        assert!((c.utilization() - 8.0 / 24.0).abs() < 1e-12);
    }

    fn mixed() -> Cluster {
        Cluster::from_fleet(
            &FleetSpec::new(vec![
                (HardwareModel::a100_80gb(), 2),
                (HardwareModel::h100_80gb(), 1),
                (HardwareModel::a100_40gb(), 2),
            ])
            .unwrap(),
        )
    }

    #[test]
    fn fleet_layout_is_consecutive_class_runs() {
        let c = mixed();
        assert_eq!(c.num_gpus(), 5);
        assert_eq!(c.num_classes(), 3);
        assert!(!c.is_uniform());
        assert_eq!(c.class_ids(), &[0, 0, 1, 2, 2]);
        assert_eq!(c.class_counts(), vec![2, 1, 2]);
        assert_eq!(c.hardware().name(), "A100-80GB", "class 0 is the legacy view");
        assert_eq!(c.hardware_of(2).name(), "H100-80GB");
        assert_eq!(c.hardware_of(4).name(), "A100-40GB");
        assert_eq!(c.capacity_slices(), 40);
    }

    #[test]
    fn uniform_cluster_is_the_single_class_case() {
        let c = cluster();
        assert!(c.is_uniform());
        assert_eq!(c.num_classes(), 1);
        assert_eq!(c.class_ids(), &[0, 0, 0]);
        assert_eq!(c.classes()[0], HardwareModel::a100_80gb());
    }

    #[test]
    fn per_gpu_class_gates_support() {
        // Class 1 supports only 1g.10gb: placements of bigger profiles on
        // its GPU are rejected even though class 0 supports them.
        let restricted = HardwareModel::h100_80gb().with_profiles(&[Profile::P1g10gb]);
        let mut c = Cluster::from_classes(
            vec![HardwareModel::a100_80gb(), restricted],
            &[1, 1],
        );
        assert!(c.supports(Profile::P7g80gb), "class 0 supports it");
        assert!(!c.supports_on(1, Profile::P7g80gb));
        assert_eq!(
            c.allocate(wid(1), pl(1, Profile::P7g80gb, 0)),
            Err(AllocError::UnsupportedProfile(Profile::P7g80gb))
        );
        c.allocate(wid(1), pl(0, Profile::P7g80gb, 0)).unwrap();
        // GPU 0 is now full and GPU 1's class cannot host a 7g: can_host
        // must consult the per-GPU class, not just class 0.
        assert!(!c.can_host(Profile::P7g80gb));
        assert!(c.can_host(Profile::P1g10gb));
    }

    #[test]
    fn parse_profile_tries_every_class() {
        let c = mixed();
        // Canonical name resolves via class 0.
        assert_eq!(c.parse_profile("3g.40gb"), Some(Profile::P3g40gb));
        // A100-40GB-specific name resolves via class 2.
        assert_eq!(c.parse_profile("3g.20gb"), Some(Profile::P3g40gb));
        assert_eq!(c.parse_profile("9g.90gb"), None);
    }

    #[test]
    fn per_class_stats_partition_the_gauges() {
        let mut c = mixed();
        c.allocate(wid(1), pl(0, Profile::P3g40gb, 0)).unwrap();
        c.allocate(wid(2), pl(3, Profile::P2g20gb, 0)).unwrap();
        c.allocate(wid(3), pl(4, Profile::P1g10gb, 6)).unwrap();
        let stats = c.per_class_stats();
        assert_eq!(stats.len(), 3);
        assert_eq!(
            stats[0],
            ClassStats { gpus: 2, active_gpus: 1, used_slices: 4, allocated_workloads: 1 }
        );
        assert_eq!(
            stats[1],
            ClassStats { gpus: 1, active_gpus: 0, used_slices: 0, allocated_workloads: 0 }
        );
        assert_eq!(
            stats[2],
            ClassStats { gpus: 2, active_gpus: 2, used_slices: 3, allocated_workloads: 2 }
        );
        // The per-class breakdown conserves the cluster-wide gauges.
        assert_eq!(stats.iter().map(|s| s.used_slices).sum::<u64>(), c.used_slices());
        assert_eq!(
            stats.iter().map(|s| s.allocated_workloads).sum::<usize>(),
            c.allocated_workloads()
        );
    }

    #[test]
    fn zero_count_classes_keep_global_class_ids() {
        // A shard holding none of class 1 still shares the 3-class table.
        let c = Cluster::from_classes(
            vec![
                HardwareModel::a100_80gb(),
                HardwareModel::h100_80gb(),
                HardwareModel::a100_40gb(),
            ],
            &[2, 0, 1],
        );
        assert_eq!(c.num_classes(), 3);
        assert_eq!(c.class_ids(), &[0, 0, 2]);
        assert_eq!(c.class_counts(), vec![2, 0, 1]);
        assert_eq!(c.per_class_stats()[1], ClassStats::default());
    }
}
