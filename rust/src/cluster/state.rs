//! The cluster state: GPU occupancy vector + workload allocation registry.

use std::collections::HashMap;

use crate::mig::{GpuState, HardwareModel, Placement, Profile};
use crate::workload::WorkloadId;

/// A homogeneous MIG GPU cluster (paper Section IV: set `M` of GPUs of the
/// same hardware model).
///
/// `Cluster` owns the authoritative occupancy state. Schedulers *propose*
/// placements ([`crate::sched::Scheduler::schedule`]); the owner (simulator
/// or serving daemon) *commits* them here, which keeps dry-run logic free
/// of undo bookkeeping and makes double-commit/double-free programming
/// errors detectable at this single choke point.
#[derive(Clone, Debug)]
pub struct Cluster {
    hw: HardwareModel,
    gpus: Vec<GpuState>,
    allocations: HashMap<WorkloadId, Placement>,
    /// Slices currently allocated (kept incrementally; equals the sum of
    /// per-GPU used slices — asserted in debug builds).
    used_slices: u64,
}

/// Errors from committing or releasing allocations.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum AllocError {
    UnknownGpu { gpu: usize, cluster_size: usize },
    DuplicateWorkload(WorkloadId),
    UnknownWorkload(WorkloadId),
    UnsupportedProfile(Profile),
    Placement(crate::mig::gpu::PlacementError),
}

impl std::fmt::Display for AllocError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AllocError::UnknownGpu { gpu, cluster_size } => {
                write!(f, "gpu {gpu} out of range (cluster has {cluster_size})")
            }
            AllocError::DuplicateWorkload(id) => write!(f, "workload {id} already allocated"),
            AllocError::UnknownWorkload(id) => write!(f, "workload {id} not allocated"),
            AllocError::UnsupportedProfile(p) => {
                write!(f, "profile {p} not supported by this hardware model")
            }
            AllocError::Placement(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for AllocError {}

impl Cluster {
    /// A cluster of `num_gpus` empty GPUs.
    pub fn new(hw: HardwareModel, num_gpus: usize) -> Self {
        assert!(num_gpus > 0, "cluster needs at least one GPU");
        Self {
            gpus: vec![GpuState::empty(); num_gpus],
            hw,
            allocations: HashMap::new(),
            used_slices: 0,
        }
    }

    // ----- read access ----------------------------------------------------

    pub fn hardware(&self) -> &HardwareModel {
        &self.hw
    }

    pub fn num_gpus(&self) -> usize {
        self.gpus.len()
    }

    pub fn gpu(&self, id: usize) -> Option<GpuState> {
        self.gpus.get(id).copied()
    }

    /// The occupancy vector — the scheduler-facing view.
    pub fn gpus(&self) -> &[GpuState] {
        &self.gpus
    }

    /// Total slice capacity (M × 8).
    pub fn capacity_slices(&self) -> u64 {
        (self.gpus.len() * self.hw.num_slices()) as u64
    }

    /// Currently allocated slices.
    pub fn used_slices(&self) -> u64 {
        debug_assert_eq!(
            self.used_slices,
            self.gpus.iter().map(|g| g.used_slices() as u64).sum::<u64>()
        );
        self.used_slices
    }

    pub fn free_slices(&self) -> u64 {
        self.capacity_slices() - self.used_slices()
    }

    /// Fraction of slices allocated (paper Fig. 4c/5c "resource utilization").
    pub fn utilization(&self) -> f64 {
        self.used_slices() as f64 / self.capacity_slices() as f64
    }

    /// GPUs hosting at least one workload (paper Fig. 4d/5d "active GPUs").
    pub fn active_gpus(&self) -> usize {
        self.gpus.iter().filter(|g| !g.is_empty()).count()
    }

    /// Number of currently allocated workloads.
    pub fn allocated_workloads(&self) -> usize {
        self.allocations.len()
    }

    pub fn placement_of(&self, id: WorkloadId) -> Option<Placement> {
        self.allocations.get(&id).copied()
    }

    /// Iterate over current allocations in unspecified order.
    pub fn allocations(&self) -> impl Iterator<Item = (WorkloadId, Placement)> + '_ {
        self.allocations.iter().map(|(k, v)| (*k, *v))
    }

    /// Raw occupancy masks, one byte per GPU — the XLA engine's input.
    pub fn occupancy_masks(&self) -> Vec<u8> {
        self.gpus.iter().map(|g| g.mask()).collect()
    }

    /// Whether any GPU can host `profile` right now.
    pub fn can_host(&self, profile: Profile) -> bool {
        self.hw.supports(profile) && self.gpus.iter().any(|g| g.can_host(profile))
    }

    // ----- mutation ---------------------------------------------------------

    /// Commit a placement for a workload.
    pub fn allocate(&mut self, id: WorkloadId, placement: Placement) -> Result<(), AllocError> {
        if !self.hw.supports(placement.profile) {
            return Err(AllocError::UnsupportedProfile(placement.profile));
        }
        if placement.gpu >= self.gpus.len() {
            return Err(AllocError::UnknownGpu {
                gpu: placement.gpu,
                cluster_size: self.gpus.len(),
            });
        }
        if self.allocations.contains_key(&id) {
            return Err(AllocError::DuplicateWorkload(id));
        }
        self.gpus[placement.gpu]
            .place(placement.profile, placement.index)
            .map_err(AllocError::Placement)?;
        self.used_slices += placement.profile.size() as u64;
        self.allocations.insert(id, placement);
        Ok(())
    }

    /// Release a workload's slices; returns the freed placement.
    pub fn release(&mut self, id: WorkloadId) -> Result<Placement, AllocError> {
        let placement =
            self.allocations.remove(&id).ok_or(AllocError::UnknownWorkload(id))?;
        self.gpus[placement.gpu]
            .release(placement.profile, placement.index)
            .map_err(AllocError::Placement)?;
        self.used_slices -= placement.profile.size() as u64;
        Ok(placement)
    }

    /// Drop every allocation (simulation reset without reallocating).
    pub fn clear(&mut self) {
        for g in &mut self.gpus {
            *g = GpuState::empty();
        }
        self.allocations.clear();
        self.used_slices = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mig::gpu::PlacementError;

    fn cluster() -> Cluster {
        Cluster::new(HardwareModel::a100_80gb(), 3)
    }

    fn wid(n: u64) -> WorkloadId {
        WorkloadId(n)
    }

    fn pl(gpu: usize, profile: Profile, index: u8) -> Placement {
        Placement { gpu, profile, index }
    }

    #[test]
    fn fresh_cluster_counts() {
        let c = cluster();
        assert_eq!(c.capacity_slices(), 24);
        assert_eq!(c.used_slices(), 0);
        assert_eq!(c.free_slices(), 24);
        assert_eq!(c.active_gpus(), 0);
        assert_eq!(c.allocated_workloads(), 0);
        assert_eq!(c.utilization(), 0.0);
        assert!(c.can_host(Profile::P7g80gb));
    }

    #[test]
    fn allocate_release_roundtrip() {
        let mut c = cluster();
        c.allocate(wid(1), pl(0, Profile::P3g40gb, 4)).unwrap();
        c.allocate(wid(2), pl(1, Profile::P1g10gb, 6)).unwrap();
        assert_eq!(c.used_slices(), 5);
        assert_eq!(c.active_gpus(), 2);
        assert_eq!(c.allocated_workloads(), 2);
        assert_eq!(c.placement_of(wid(1)), Some(pl(0, Profile::P3g40gb, 4)));

        let freed = c.release(wid(1)).unwrap();
        assert_eq!(freed, pl(0, Profile::P3g40gb, 4));
        assert_eq!(c.used_slices(), 1);
        assert_eq!(c.active_gpus(), 1);
        assert_eq!(c.placement_of(wid(1)), None);
    }

    #[test]
    fn rejects_duplicates_and_unknowns() {
        let mut c = cluster();
        c.allocate(wid(1), pl(0, Profile::P1g10gb, 0)).unwrap();
        assert_eq!(
            c.allocate(wid(1), pl(1, Profile::P1g10gb, 0)),
            Err(AllocError::DuplicateWorkload(wid(1)))
        );
        assert_eq!(c.release(wid(9)), Err(AllocError::UnknownWorkload(wid(9))));
        assert_eq!(
            c.allocate(wid(2), pl(7, Profile::P1g10gb, 0)),
            Err(AllocError::UnknownGpu { gpu: 7, cluster_size: 3 })
        );
    }

    #[test]
    fn rejects_overlapping_commit() {
        let mut c = cluster();
        c.allocate(wid(1), pl(0, Profile::P4g40gb, 0)).unwrap();
        let err = c.allocate(wid(2), pl(0, Profile::P3g40gb, 0)).unwrap_err();
        assert!(matches!(err, AllocError::Placement(PlacementError::Occupied { .. })));
        // Failed commit must not corrupt accounting.
        assert_eq!(c.used_slices(), 4);
        assert_eq!(c.allocated_workloads(), 1);
    }

    #[test]
    fn rejects_unsupported_profile() {
        let hw = HardwareModel::a100_80gb().with_profiles(&[Profile::P1g10gb]);
        let mut c = Cluster::new(hw, 1);
        assert_eq!(
            c.allocate(wid(1), pl(0, Profile::P7g80gb, 0)),
            Err(AllocError::UnsupportedProfile(Profile::P7g80gb))
        );
        assert!(!c.can_host(Profile::P7g80gb));
        assert!(c.can_host(Profile::P1g10gb));
    }

    #[test]
    fn occupancy_masks_reflect_state() {
        let mut c = cluster();
        c.allocate(wid(1), pl(1, Profile::P2g20gb, 2)).unwrap();
        assert_eq!(c.occupancy_masks(), vec![0, 0b0000_1100, 0]);
    }

    #[test]
    fn clear_resets_everything() {
        let mut c = cluster();
        c.allocate(wid(1), pl(0, Profile::P7g80gb, 0)).unwrap();
        c.clear();
        assert_eq!(c.used_slices(), 0);
        assert_eq!(c.allocated_workloads(), 0);
        assert_eq!(c.active_gpus(), 0);
    }

    #[test]
    fn utilization_fraction() {
        let mut c = cluster();
        c.allocate(wid(1), pl(0, Profile::P7g80gb, 0)).unwrap();
        assert!((c.utilization() - 8.0 / 24.0).abs() < 1e-12);
    }
}
