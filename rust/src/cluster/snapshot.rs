//! JSON snapshots of the full cluster state (daemon persistence,
//! `inspect` CLI, postmortem debugging).

use super::state::Cluster;
use crate::mig::{HardwareModel, Placement, Profile};
use crate::util::json::Json;
use crate::workload::WorkloadId;

/// Serialize the cluster: hardware name, occupancy masks, allocations.
pub fn to_json(cluster: &Cluster) -> Json {
    let mut allocs: Vec<(WorkloadId, usize, Profile, u8)> = cluster
        .allocations()
        .map(|(id, p)| (id, p.gpu, p.profile, p.index))
        .collect();
    allocs.sort_by_key(|&(id, ..)| id);
    parts_to_json(
        cluster.hardware().name(),
        cluster.num_gpus(),
        &cluster.occupancy_masks(),
        &allocs,
    )
}

/// The canonical snapshot wire format from raw parts — the single
/// definition shared by [`to_json`] and the daemon's sharded
/// `/v1/cluster` merge (which concatenates per-shard masks and rebases
/// GPU ids to fleet-global before calling this). `allocs` entries are
/// `(workload, global gpu, profile, index)` and must be pre-sorted by
/// workload id.
pub fn parts_to_json(
    hardware: &str,
    num_gpus: usize,
    masks: &[u8],
    allocs: &[(WorkloadId, usize, Profile, u8)],
) -> Json {
    Json::obj()
        .with("hardware", hardware)
        .with("num_gpus", num_gpus)
        .with(
            "gpu_masks",
            Json::Arr(masks.iter().map(|&m| Json::Num(f64::from(m))).collect()),
        )
        .with(
            "allocations",
            Json::Arr(
                allocs
                    .iter()
                    .map(|&(id, gpu, profile, index)| {
                        Json::obj()
                            .with("workload", id.0)
                            .with("gpu", gpu)
                            .with("profile", profile.canonical_name())
                            .with("index", index as u64)
                    })
                    .collect(),
            ),
        )
}

/// Restore a cluster from a snapshot. The occupancy is rebuilt from the
/// allocation list (the mask array is redundant and cross-checked).
pub fn from_json(j: &Json) -> Result<Cluster, String> {
    let hw_name = j.req_str("hardware")?;
    let hw = HardwareModel::by_name(hw_name)
        .ok_or_else(|| format!("unknown hardware model '{hw_name}'"))?;
    let num_gpus = j.req_u64("num_gpus")? as usize;
    if num_gpus == 0 {
        return Err("num_gpus must be positive".into());
    }
    let mut cluster = Cluster::new(hw, num_gpus);
    let allocs = j
        .get("allocations")
        .and_then(Json::as_arr)
        .ok_or("missing 'allocations' array")?;
    for a in allocs {
        let profile_name = a.req_str("profile")?;
        let profile = Profile::parse(profile_name)
            .ok_or_else(|| format!("unknown profile '{profile_name}'"))?;
        let placement = Placement {
            gpu: a.req_u64("gpu")? as usize,
            profile,
            index: a.req_u64("index")? as u8,
        };
        cluster
            .allocate(WorkloadId(a.req_u64("workload")?), placement)
            .map_err(|e| format!("allocation replay failed: {e}"))?;
    }
    // Cross-check the stored masks when present.
    if let Some(masks) = j.get("gpu_masks").and_then(Json::as_arr) {
        if masks.len() != cluster.num_gpus() {
            return Err("gpu_masks arity mismatch".into());
        }
        for (i, m) in masks.iter().enumerate() {
            let stored = m.as_u64().ok_or("bad mask value")? as u8;
            let rebuilt = cluster.gpu(i).unwrap().mask();
            if stored != rebuilt {
                return Err(format!(
                    "gpu {i}: stored mask {stored:#010b} != rebuilt {rebuilt:#010b}"
                ));
            }
        }
    }
    Ok(cluster)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn populated() -> Cluster {
        let mut c = Cluster::new(HardwareModel::a100_80gb(), 4);
        c.allocate(
            WorkloadId(0),
            Placement { gpu: 0, profile: Profile::P4g40gb, index: 0 },
        )
        .unwrap();
        c.allocate(
            WorkloadId(1),
            Placement { gpu: 2, profile: Profile::P1g20gb, index: 6 },
        )
        .unwrap();
        c
    }

    #[test]
    fn roundtrip() {
        let c = populated();
        let j = to_json(&c);
        let back = from_json(&j).unwrap();
        assert_eq!(back.occupancy_masks(), c.occupancy_masks());
        assert_eq!(back.allocated_workloads(), 2);
        assert_eq!(back.placement_of(WorkloadId(1)), c.placement_of(WorkloadId(1)));
    }

    #[test]
    fn detects_mask_tampering() {
        let c = populated();
        let mut j = to_json(&c);
        j.set("gpu_masks", vec![0u64, 0, 0, 0]);
        let err = from_json(&j).unwrap_err();
        assert!(err.contains("stored mask"), "{err}");
    }

    #[test]
    fn rejects_unknown_hardware() {
        let mut j = to_json(&populated());
        j.set("hardware", "TPU-v5");
        assert!(from_json(&j).is_err());
    }

    #[test]
    fn rejects_conflicting_allocations() {
        let text = r#"{
            "hardware": "A100-80GB", "num_gpus": 1,
            "allocations": [
                {"workload": 0, "gpu": 0, "profile": "4g.40gb", "index": 0},
                {"workload": 1, "gpu": 0, "profile": "3g.40gb", "index": 0}
            ]
        }"#;
        let j = Json::parse(text).unwrap();
        assert!(from_json(&j).unwrap_err().contains("replay failed"));
    }
}
