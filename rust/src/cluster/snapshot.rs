//! JSON snapshots of the full cluster state (daemon persistence,
//! `inspect` CLI, postmortem debugging).

use super::state::Cluster;
use crate::mig::{HardwareModel, Placement, Profile};
use crate::util::json::Json;
use crate::workload::WorkloadId;

/// Serialize the cluster: hardware name, occupancy masks, allocations.
///
/// Single-class clusters emit the legacy v1 form (a single `hardware`
/// string) byte-for-byte; heterogeneous clusters emit the v2 form with a
/// `classes` name table and a per-GPU `gpu_classes` array (and no
/// `hardware` key, so pre-fleet readers fail loudly instead of silently
/// flattening the fleet).
pub fn to_json(cluster: &Cluster) -> Json {
    let mut allocs: Vec<(WorkloadId, usize, Profile, u8)> = cluster
        .allocations()
        .map(|(id, p)| (id, p.gpu, p.profile, p.index))
        .collect();
    allocs.sort_by_key(|&(id, ..)| id);
    if cluster.is_uniform() {
        return parts_to_json(
            cluster.hardware().name(),
            cluster.num_gpus(),
            &cluster.occupancy_masks(),
            &allocs,
        );
    }
    let classes: Vec<&str> = cluster.classes().iter().map(|hw| hw.name()).collect();
    parts_to_json_fleet(
        &classes,
        cluster.class_ids(),
        &cluster.occupancy_masks(),
        &allocs,
    )
}

/// The canonical snapshot wire format from raw parts — the single
/// definition shared by [`to_json`] and the daemon's sharded
/// `/v1/cluster` merge (which concatenates per-shard masks and rebases
/// GPU ids to fleet-global before calling this). `allocs` entries are
/// `(workload, global gpu, profile, index)` and must be pre-sorted by
/// workload id.
pub fn parts_to_json(
    hardware: &str,
    num_gpus: usize,
    masks: &[u8],
    allocs: &[(WorkloadId, usize, Profile, u8)],
) -> Json {
    Json::obj()
        .with("hardware", hardware)
        .with("num_gpus", num_gpus)
        .with(
            "gpu_masks",
            Json::Arr(masks.iter().map(|&m| Json::Num(f64::from(m))).collect()),
        )
        .with(
            "allocations",
            Json::Arr(
                allocs
                    .iter()
                    .map(|&(id, gpu, profile, index)| {
                        Json::obj()
                            .with("workload", id.0)
                            .with("gpu", gpu)
                            .with("profile", profile.canonical_name())
                            .with("index", index as u64)
                    })
                    .collect(),
            ),
        )
}

/// The v2 (heterogeneous) snapshot wire format: class-name table +
/// per-GPU class ids, same masks/allocations layout as v1. Shared by
/// [`to_json`] and the daemon's sharded `/v1/cluster` merge on mixed
/// fleets (where per-shard class runs interleave in the global view).
pub fn parts_to_json_fleet(
    classes: &[&str],
    gpu_classes: &[u8],
    masks: &[u8],
    allocs: &[(WorkloadId, usize, Profile, u8)],
) -> Json {
    Json::obj()
        .with(
            "classes",
            Json::Arr(classes.iter().map(|&n| Json::Str(n.to_string())).collect()),
        )
        .with(
            "gpu_classes",
            Json::Arr(gpu_classes.iter().map(|&c| Json::Num(f64::from(c))).collect()),
        )
        .with("num_gpus", gpu_classes.len())
        .with(
            "gpu_masks",
            Json::Arr(masks.iter().map(|&m| Json::Num(f64::from(m))).collect()),
        )
        .with(
            "allocations",
            Json::Arr(
                allocs
                    .iter()
                    .map(|&(id, gpu, profile, index)| {
                        Json::obj()
                            .with("workload", id.0)
                            .with("gpu", gpu)
                            .with("profile", profile.canonical_name())
                            .with("index", index as u64)
                    })
                    .collect(),
            ),
        )
}

/// Restore a cluster from a snapshot (v1 single-`hardware` or v2
/// `classes`/`gpu_classes`). The occupancy is rebuilt from the allocation
/// list (the mask array is redundant and cross-checked).
pub fn from_json(j: &Json) -> Result<Cluster, String> {
    let mut cluster = if let Some(class_arr) = j.get("classes").and_then(Json::as_arr) {
        // v2: explicit class table + per-GPU assignment.
        let mut models = Vec::with_capacity(class_arr.len());
        for c in class_arr {
            let name = c.as_str().ok_or("bad class name in 'classes'")?;
            models.push(
                HardwareModel::by_name(name)
                    .ok_or_else(|| format!("unknown hardware model '{name}'"))?,
            );
        }
        if models.is_empty() {
            return Err("'classes' must be non-empty".into());
        }
        let ids_arr = j
            .get("gpu_classes")
            .and_then(Json::as_arr)
            .ok_or("missing 'gpu_classes' array")?;
        let mut class_ids = Vec::with_capacity(ids_arr.len());
        for v in ids_arr {
            let id = v.as_u64().ok_or("bad class id in 'gpu_classes'")?;
            if id as usize >= models.len() {
                return Err(format!("gpu class id {id} out of range"));
            }
            class_ids.push(id as u8);
        }
        if class_ids.is_empty() {
            return Err("'gpu_classes' must be non-empty".into());
        }
        if let Some(n) = j.get("num_gpus").and_then(Json::as_u64) {
            if n as usize != class_ids.len() {
                return Err("num_gpus does not match gpu_classes arity".into());
            }
        }
        Cluster::from_class_layout(models, class_ids)
    } else {
        // v1 (legacy): one hardware model for the whole cluster.
        let hw_name = j.req_str("hardware")?;
        let hw = HardwareModel::by_name(hw_name)
            .ok_or_else(|| format!("unknown hardware model '{hw_name}'"))?;
        let num_gpus = j.req_u64("num_gpus")? as usize;
        if num_gpus == 0 {
            return Err("num_gpus must be positive".into());
        }
        Cluster::new(hw, num_gpus)
    };
    let allocs = j
        .get("allocations")
        .and_then(Json::as_arr)
        .ok_or("missing 'allocations' array")?;
    for a in allocs {
        let profile_name = a.req_str("profile")?;
        let profile = Profile::parse(profile_name)
            .ok_or_else(|| format!("unknown profile '{profile_name}'"))?;
        let placement = Placement {
            gpu: a.req_u64("gpu")? as usize,
            profile,
            index: a.req_u64("index")? as u8,
        };
        cluster
            .allocate(WorkloadId(a.req_u64("workload")?), placement)
            .map_err(|e| format!("allocation replay failed: {e}"))?;
    }
    // Cross-check the stored masks when present.
    if let Some(masks) = j.get("gpu_masks").and_then(Json::as_arr) {
        if masks.len() != cluster.num_gpus() {
            return Err("gpu_masks arity mismatch".into());
        }
        for (i, m) in masks.iter().enumerate() {
            let stored = m.as_u64().ok_or("bad mask value")? as u8;
            let rebuilt = cluster.gpu(i).unwrap().mask();
            if stored != rebuilt {
                return Err(format!(
                    "gpu {i}: stored mask {stored:#010b} != rebuilt {rebuilt:#010b}"
                ));
            }
        }
    }
    Ok(cluster)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn populated() -> Cluster {
        let mut c = Cluster::new(HardwareModel::a100_80gb(), 4);
        c.allocate(
            WorkloadId(0),
            Placement { gpu: 0, profile: Profile::P4g40gb, index: 0 },
        )
        .unwrap();
        c.allocate(
            WorkloadId(1),
            Placement { gpu: 2, profile: Profile::P1g20gb, index: 6 },
        )
        .unwrap();
        c
    }

    #[test]
    fn roundtrip() {
        let c = populated();
        let j = to_json(&c);
        let back = from_json(&j).unwrap();
        assert_eq!(back.occupancy_masks(), c.occupancy_masks());
        assert_eq!(back.allocated_workloads(), 2);
        assert_eq!(back.placement_of(WorkloadId(1)), c.placement_of(WorkloadId(1)));
    }

    #[test]
    fn detects_mask_tampering() {
        let c = populated();
        let mut j = to_json(&c);
        j.set("gpu_masks", vec![0u64, 0, 0, 0]);
        let err = from_json(&j).unwrap_err();
        assert!(err.contains("stored mask"), "{err}");
    }

    #[test]
    fn rejects_unknown_hardware() {
        let mut j = to_json(&populated());
        j.set("hardware", "TPU-v5");
        assert!(from_json(&j).is_err());
    }

    #[test]
    fn uniform_snapshot_stays_legacy_v1() {
        // Single-class fleets must keep the pre-fleet wire format
        // byte-for-byte: a `hardware` string and no class arrays.
        let c = populated();
        let j = to_json(&c);
        assert_eq!(j.req_str("hardware").unwrap(), "A100-80GB");
        assert!(j.get("classes").is_none());
        assert!(j.get("gpu_classes").is_none());
        let via_fleet = Cluster::from_fleet(
            &crate::mig::FleetSpec::uniform(HardwareModel::a100_80gb(), 4),
        );
        assert_eq!(
            to_json(&via_fleet).to_string_compact(),
            to_json(&Cluster::new(HardwareModel::a100_80gb(), 4)).to_string_compact()
        );
    }

    fn populated_mixed() -> Cluster {
        let fleet = crate::mig::FleetSpec::parse("a100:2,a100-40gb:1,h100:1").unwrap();
        let mut c = Cluster::from_fleet(&fleet);
        c.allocate(WorkloadId(3), Placement { gpu: 2, profile: Profile::P3g40gb, index: 4 })
            .unwrap();
        c.allocate(WorkloadId(1), Placement { gpu: 0, profile: Profile::P7g80gb, index: 0 })
            .unwrap();
        c
    }

    #[test]
    fn mixed_snapshot_roundtrip_preserves_classes() {
        let c = populated_mixed();
        let j = to_json(&c);
        assert!(j.get("hardware").is_none(), "v2 must not masquerade as v1");
        let back = from_json(&j).unwrap();
        assert_eq!(back.class_ids(), c.class_ids());
        assert_eq!(back.occupancy_masks(), c.occupancy_masks());
        assert_eq!(back.classes().len(), 3);
        assert_eq!(back.hardware_of(2).name(), "A100-40GB");
        assert_eq!(back.placement_of(WorkloadId(3)), c.placement_of(WorkloadId(3)));
        // Allocations are sorted by workload id in the wire format.
        let allocs = j.get("allocations").unwrap().as_arr().unwrap();
        assert_eq!(allocs[0].req_u64("workload").unwrap(), 1);
    }

    #[test]
    fn mixed_snapshot_survives_interleaved_class_runs() {
        // A fleet-global view merged from shards interleaves classes; the
        // layout must round-trip exactly, not be re-sorted into runs.
        let models = vec![HardwareModel::a100_80gb(), HardwareModel::h100_80gb()];
        let c = Cluster::from_class_layout(models, vec![0, 1, 0, 1, 0]);
        let j = to_json(&c);
        let back = from_json(&j).unwrap();
        assert_eq!(back.class_ids(), &[0, 1, 0, 1, 0]);
    }

    #[test]
    fn v2_rejects_malformed_class_data() {
        let mut j = to_json(&populated_mixed());
        j.set("gpu_classes", vec![0u64, 1, 2, 9]);
        assert!(from_json(&j).unwrap_err().contains("out of range"));
        let mut j = to_json(&populated_mixed());
        j.set("num_gpus", 7u64);
        assert!(from_json(&j).unwrap_err().contains("arity"));
    }

    #[test]
    fn legacy_v1_snapshot_still_loads() {
        // A pre-fleet snapshot (no class arrays) loads as a uniform fleet.
        let text = r#"{
            "hardware": "A100-40GB", "num_gpus": 2,
            "gpu_masks": [15, 0],
            "allocations": [
                {"workload": 7, "gpu": 0, "profile": "3g.40gb", "index": 0}
            ]
        }"#;
        let c = from_json(&Json::parse(text).unwrap()).unwrap();
        assert!(c.is_uniform());
        assert_eq!(c.hardware().name(), "A100-40GB");
        assert_eq!(c.num_gpus(), 2);
        assert_eq!(c.gpus()[0].mask(), 15);
    }

    #[test]
    fn rejects_conflicting_allocations() {
        let text = r#"{
            "hardware": "A100-80GB", "num_gpus": 1,
            "allocations": [
                {"workload": 0, "gpu": 0, "profile": "4g.40gb", "index": 0},
                {"workload": 1, "gpu": 0, "profile": "3g.40gb", "index": 0}
            ]
        }"#;
        let j = Json::parse(text).unwrap();
        assert!(from_json(&j).unwrap_err().contains("replay failed"));
    }
}
