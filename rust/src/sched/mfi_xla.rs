//! MFI with the ΔF evaluation offloaded to the AOT-compiled XLA program —
//! the full three-layer composition (rust coordinator → HLO artifact →
//! Pallas kernel) on the scheduling hot path.
//!
//! Semantically identical to [`super::Mfi`]: the artifact computes the same
//! Algorithm 1 scores/deltas (from the same frozen candidate table), and
//! the argmin tie-breaking here mirrors the native path (lowest ΔF, then
//! lowest GPU id, then lowest anchor). `rust/tests/runtime_vs_native.rs`
//! asserts decision-for-decision equality on random clusters.
//!
//! When is this worth it? The native engine is a table lookup — far faster
//! at M=100 (see `benches/xla_offload.rs`). The XLA path exists to (a)
//! prove the AOT pipeline end-to-end, and (b) model deployments where the
//! scoring function is a *learned* or much heavier model that genuinely
//! needs an accelerator — the paper's O(k·M) dry-run loop is exactly the
//! shape that batches onto one.

use anyhow::Result;

use super::Scheduler;
use crate::cluster::Cluster;
use crate::mig::{candidate_range, Placement, Profile, CANDIDATES};
use crate::runtime::{FragEngine, PjrtRuntime};

/// MFI scheduling via the PJRT-compiled fragmentation program.
pub struct MfiXla {
    engine: FragEngine,
}

impl MfiXla {
    /// Load the default artifact (`artifacts/frag.hlo.txt`).
    pub fn load_default(runtime: &PjrtRuntime) -> Result<Self> {
        Ok(Self { engine: FragEngine::load_default(runtime)? })
    }

    pub fn from_engine(engine: FragEngine) -> Self {
        Self { engine }
    }

    pub fn engine(&self) -> &FragEngine {
        &self.engine
    }

    /// Fallible scheduling (PJRT execution can fail); the `Scheduler` impl
    /// maps errors to rejection after logging.
    pub fn try_schedule(
        &mut self,
        cluster: &Cluster,
        profile: Profile,
    ) -> Result<Option<Placement>> {
        if !cluster.is_uniform() {
            // The AOT artifact bakes in ONE hardware model's score table;
            // scoring a mixed fleet with it would silently misprice every
            // non-class-0 GPU. Fail loudly instead.
            anyhow::bail!(
                "MFI-XLA evaluates against a single compiled hardware table and does not \
                 support heterogeneous fleets ({} device classes)",
                cluster.num_classes()
            );
        }
        if !cluster.hardware().supports(profile) {
            return Ok(None);
        }
        let masks = cluster.occupancy_masks();
        let batch = self.engine.evaluate(&masks)?;
        let range = candidate_range(profile);
        let mut best: Option<(f32, usize, usize)> = None; // (delta, gpu, cand)
        for gpu in 0..masks.len() {
            for c in range.clone() {
                if !batch.feasible[gpu][c] {
                    continue;
                }
                let d = batch.deltas[gpu][c];
                if best.is_none() || d < best.unwrap().0 {
                    best = Some((d, gpu, c));
                }
            }
        }
        Ok(best.map(|(_, gpu, c)| Placement { gpu, profile, index: CANDIDATES[c].start }))
    }
}

impl Scheduler for MfiXla {
    fn name(&self) -> &str {
        "MFI-XLA"
    }

    fn schedule(&mut self, cluster: &Cluster, profile: Profile) -> Option<Placement> {
        match self.try_schedule(cluster, profile) {
            Ok(p) => p,
            Err(e) => {
                crate::log_error!("MFI-XLA evaluation failed, rejecting request: {e:#}");
                None
            }
        }
    }
}

// Integration coverage (artifact-dependent) lives in
// rust/tests/runtime_vs_native.rs.
