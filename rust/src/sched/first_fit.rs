//! First Fit (FF) — MIG-agnostic paper baseline.
//!
//! Selects the first GPU (by id) with enough *free slices* — a pure
//! resource-count check, blind to MIG anchor constraints — then tries the
//! first available index on that GPU. If the chosen GPU's free slices are
//! arranged infeasibly, the request is rejected even though another GPU
//! might have hosted it: that is the fragmentation-agnostic failure mode
//! the paper illustrates in Fig. 3, and it is what produces the paper's
//! acceptance gaps (a baseline that retried every GPU would reject only
//! truly-infeasible requests and the reported ~10% heavy-load gap could
//! not exist).
//!
//! The retrying reading ships as the `FF-R` ablation so the semantics gap
//! itself is measurable (`benches/ablation_index_policy.rs`).

use super::Scheduler;
use crate::cluster::Cluster;
use crate::mig::{Placement, Profile};

/// The FF baseline.
#[derive(Clone, Debug)]
pub struct FirstFit {
    strict: bool,
    name: &'static str,
}

impl FirstFit {
    /// Paper First Fit: commit to the first GPU passing the slice-count
    /// check (the evaluation default).
    pub fn new() -> Self {
        Self { strict: true, name: "FF" }
    }

    /// Retrying variant (`FF-R`): falls through to the next GPU when the
    /// resource-selected one has no feasible anchor — semantics ablation.
    pub fn retry() -> Self {
        Self { strict: false, name: "FF-R" }
    }

    pub fn is_strict(&self) -> bool {
        self.strict
    }
}

impl Default for FirstFit {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheduler for FirstFit {
    fn name(&self) -> &str {
        self.name
    }

    fn schedule(&mut self, cluster: &Cluster, profile: Profile) -> Option<Placement> {
        if !cluster.supports(profile) {
            return None;
        }
        if self.strict {
            // Commit to the first GPU passing the resource-count check.
            // GPUs whose device class does not enable the profile are not
            // candidates at all (a capability fact, not a fragmentation
            // one), so the count check only ranges over eligible classes.
            let gpu_id = cluster
                .gpus()
                .iter()
                .enumerate()
                .find(|(id, g)| {
                    cluster.supports_on(*id, profile) && g.free_slices() >= profile.size()
                })
                .map(|(id, _)| id)?;
            let index = cluster.gpus()[gpu_id].first_feasible(profile)?;
            return Some(Placement { gpu: gpu_id, profile, index });
        }
        for (gpu_id, g) in cluster.gpus().iter().enumerate() {
            if !cluster.supports_on(gpu_id, profile) || g.free_slices() < profile.size() {
                continue;
            }
            if let Some(index) = g.first_feasible(profile) {
                return Some(Placement { gpu: gpu_id, profile, index });
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mig::{GpuState, HardwareModel};
    use crate::workload::WorkloadId;

    fn commit(c: &mut Cluster, id: u64, pl: Placement) {
        c.allocate(WorkloadId(id), pl).unwrap();
    }

    #[test]
    fn picks_first_gpu_first_index() {
        let mut s = FirstFit::new();
        let cluster = Cluster::new(HardwareModel::a100_80gb(), 3);
        let pl = s.schedule(&cluster, Profile::P2g20gb).unwrap();
        assert_eq!((pl.gpu, pl.index), (0, 0));
    }

    #[test]
    fn skips_gpus_without_capacity() {
        let mut s = FirstFit::new();
        let mut c = Cluster::new(HardwareModel::a100_80gb(), 3);
        commit(&mut c, 0, Placement { gpu: 0, profile: Profile::P7g80gb, index: 0 });
        let pl = s.schedule(&c, Profile::P4g40gb).unwrap();
        assert_eq!(pl.gpu, 1);
    }

    #[test]
    fn fig3_pathology_rejects_despite_feasible_elsewhere() {
        // GPU 0: a misplaced 1g.10gb@1 leaves 7 free slices but blocks
        // 4g.40gb's only anchor. GPU 1 is empty. FF's resource check picks
        // GPU 0 (7 >= 4) and fails on the index constraint → reject.
        let mut s = FirstFit::new();
        let mut c = Cluster::new(HardwareModel::a100_80gb(), 2);
        commit(&mut c, 0, Placement { gpu: 0, profile: Profile::P1g10gb, index: 1 });
        assert!(c.gpu(1).unwrap().can_host(Profile::P4g40gb));
        assert_eq!(s.schedule(&c, Profile::P4g40gb), None);
    }

    #[test]
    fn retry_variant_falls_through() {
        let mut s = FirstFit::retry();
        let mut c = Cluster::new(HardwareModel::a100_80gb(), 2);
        commit(&mut c, 0, Placement { gpu: 0, profile: Profile::P1g10gb, index: 1 });
        assert_eq!(s.schedule(&c, Profile::P4g40gb).unwrap().gpu, 1);
        assert_eq!(s.name(), "FF-R");
        assert!(!s.is_strict());
    }

    #[test]
    fn first_index_is_ascending() {
        let mut s = FirstFit::new();
        let mut c = Cluster::new(HardwareModel::a100_80gb(), 1);
        commit(&mut c, 0, Placement { gpu: 0, profile: Profile::P1g10gb, index: 0 });
        let pl = s.schedule(&c, Profile::P1g10gb).unwrap();
        assert_eq!(pl.index, 1);
    }

    #[test]
    fn retry_ff_is_complete() {
        // Retrying FF rejects only when NO GPU can host the profile.
        let mut s = FirstFit::retry();
        let mut c = Cluster::new(HardwareModel::a100_80gb(), 2);
        commit(&mut c, 0, Placement { gpu: 0, profile: Profile::P1g10gb, index: 1 });
        commit(&mut c, 1, Placement { gpu: 1, profile: Profile::P1g10gb, index: 1 });
        assert!(!c.can_host(Profile::P4g40gb));
        assert_eq!(s.schedule(&c, Profile::P4g40gb), None);
        assert!(c.can_host(Profile::P3g40gb));
        assert_eq!(s.schedule(&c, Profile::P3g40gb).unwrap().index, 4);
    }

    #[test]
    fn rejects_unsupported_profile() {
        let hw = HardwareModel::a100_80gb().with_profiles(&[Profile::P1g10gb]);
        let mut s = FirstFit::new();
        let c = Cluster::new(hw, 1);
        assert_eq!(s.schedule(&c, Profile::P7g80gb), None);
    }

    #[test]
    fn rejects_on_saturated_cluster() {
        let mut s = FirstFit::new();
        let mut c = Cluster::new(HardwareModel::a100_80gb(), 1);
        commit(&mut c, 0, Placement { gpu: 0, profile: Profile::P7g80gb, index: 0 });
        assert_eq!(c.gpus()[0], GpuState::from_mask(0xFF));
        for p in crate::mig::profile::ALL_PROFILES {
            assert_eq!(s.schedule(&c, p), None, "{p}");
        }
    }
}
