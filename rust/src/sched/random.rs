//! Random feasible placement — a sanity floor for the evaluation.
//!
//! Not a paper baseline: it exists to calibrate how much of each scheme's
//! performance is real policy rather than luck. It is *feasibility-aware*
//! (uniform over all feasible (GPU, anchor) pairs, rejecting only when
//! none exists), so it bounds what "no policy at all" achieves.

use super::Scheduler;
use crate::cluster::Cluster;
use crate::mig::{Placement, Profile};
use crate::util::rng::Rng;

/// Uniform-random feasible placement.
#[derive(Clone, Debug)]
pub struct RandomFit {
    rng: Rng,
    seed: u64,
}

impl RandomFit {
    pub fn new(seed: u64) -> Self {
        Self { rng: Rng::new(seed), seed }
    }
}

impl Scheduler for RandomFit {
    fn name(&self) -> &str {
        "RANDOM"
    }

    fn schedule(&mut self, cluster: &Cluster, profile: Profile) -> Option<Placement> {
        if !cluster.supports(profile) {
            return None;
        }
        // Reservoir-sample uniformly over feasible placements in one pass.
        let mut chosen: Option<Placement> = None;
        let mut count = 0u64;
        for (gpu_id, g) in cluster.gpus().iter().enumerate() {
            if !cluster.supports_on(gpu_id, profile) || g.free_slices() < profile.size() {
                continue;
            }
            for idx in g.feasible_indexes(profile) {
                count += 1;
                if self.rng.below(count) == 0 {
                    chosen = Some(Placement { gpu: gpu_id, profile, index: idx });
                }
            }
        }
        chosen
    }

    fn reset(&mut self) {
        self.rng = Rng::new(self.seed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mig::HardwareModel;
    use crate::workload::WorkloadId;

    #[test]
    fn uniform_over_feasible_placements() {
        let mut s = RandomFit::new(1);
        let c = Cluster::new(HardwareModel::a100_80gb(), 2);
        // 2 GPUs × 3 anchors for 2g.20gb = 6 equally likely placements.
        let mut counts = std::collections::HashMap::new();
        for _ in 0..12_000 {
            let pl = s.schedule(&c, Profile::P2g20gb).unwrap();
            *counts.entry((pl.gpu, pl.index)).or_insert(0usize) += 1;
        }
        assert_eq!(counts.len(), 6);
        for (&k, &v) in &counts {
            let freq = v as f64 / 12_000.0;
            assert!((freq - 1.0 / 6.0).abs() < 0.02, "{k:?}: {freq}");
        }
    }

    #[test]
    fn rejects_only_when_truly_infeasible() {
        let mut s = RandomFit::new(2);
        let mut c = Cluster::new(HardwareModel::a100_80gb(), 1);
        c.allocate(WorkloadId(0), Placement { gpu: 0, profile: Profile::P1g10gb, index: 1 })
            .unwrap();
        // 4g is infeasible on the single GPU → reject.
        assert_eq!(s.schedule(&c, Profile::P4g40gb), None);
        // 3g still fits at 4.
        assert_eq!(s.schedule(&c, Profile::P3g40gb).unwrap().index, 4);
    }

    #[test]
    fn reset_restores_determinism() {
        let mut s = RandomFit::new(42);
        let c = Cluster::new(HardwareModel::a100_80gb(), 4);
        let first: Vec<_> = (0..10).map(|_| s.schedule(&c, Profile::P1g10gb)).collect();
        s.reset();
        let second: Vec<_> = (0..10).map(|_| s.schedule(&c, Profile::P1g10gb)).collect();
        assert_eq!(first, second);
    }
}
