//! Anchor-index selection policies.
//!
//! The paper distinguishes MIG-agnostic baselines (first available index)
//! from MIG-aware ones, which adopt the preference policy of Turkkan et
//! al. [21]: place profiles on indexes that do not restrict profiles with
//! fewer anchoring options — e.g. a 1g.10gb goes to index 6 rather than 0
//! whenever possible, keeping index 0 free for a 4g.40gb which can anchor
//! *only* there.
//!
//! Because every profile's feasible index set is sorted ascending and the
//! scarcest anchors are the low ones (index 0 serves 7g/4g/3g/2g/1g…),
//! the [21] preference is realized exactly by scanning anchors in
//! *descending* order: 1g.10gb tries 6,5,…,0; 1g.20gb tries 6,4,2,0;
//! 3g.40gb tries 4 before 0; 4g/7g have a single anchor either way.

use crate::mig::{GpuState, Profile};

/// How a scheduler picks an anchor among the feasible ones on a chosen GPU.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum IndexPolicy {
    /// First (lowest) feasible index — the MIG-agnostic baselines.
    FirstIndex,
    /// Highest feasible index — the MIG-aware "best index" policy of [21].
    #[default]
    BestIndex,
}

impl IndexPolicy {
    /// Select an anchor for `profile` on `gpu` under this policy.
    #[inline]
    pub fn select(self, gpu: GpuState, profile: Profile) -> Option<u8> {
        match self {
            IndexPolicy::FirstIndex => gpu.first_feasible(profile),
            IndexPolicy::BestIndex => gpu.best_feasible(profile),
        }
    }

    /// Short suffix used in scheme names ("FI" / "BI").
    pub fn tag(self) -> &'static str {
        match self {
            IndexPolicy::FirstIndex => "FI",
            IndexPolicy::BestIndex => "BI",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn best_index_reserves_low_anchors() {
        let g = GpuState::empty();
        // The paper's example: 1g.10gb goes to 6 instead of 0.
        assert_eq!(IndexPolicy::BestIndex.select(g, Profile::P1g10gb), Some(6));
        assert_eq!(IndexPolicy::FirstIndex.select(g, Profile::P1g10gb), Some(0));
        // ... thereby keeping 4g.40gb's unique anchor available.
        let g2 = g.with_placement(Profile::P1g10gb, 6);
        assert!(g2.can_host(Profile::P4g40gb));
        let g3 = g.with_placement(Profile::P1g10gb, 0);
        assert!(!g3.can_host(Profile::P4g40gb));
    }

    #[test]
    fn single_anchor_profiles_unaffected() {
        let g = GpuState::empty();
        for p in [Profile::P7g80gb, Profile::P4g40gb] {
            assert_eq!(IndexPolicy::BestIndex.select(g, p), Some(0));
            assert_eq!(IndexPolicy::FirstIndex.select(g, p), Some(0));
        }
    }

    #[test]
    fn respects_occupancy() {
        let g = GpuState::empty().with_placement(Profile::P1g20gb, 6);
        assert_eq!(IndexPolicy::BestIndex.select(g, Profile::P1g20gb), Some(4));
        assert_eq!(IndexPolicy::FirstIndex.select(g, Profile::P1g20gb), Some(0));
        let full = GpuState::from_mask(0xFF);
        for p in crate::mig::profile::ALL_PROFILES {
            assert_eq!(IndexPolicy::BestIndex.select(full, p), None);
            assert_eq!(IndexPolicy::FirstIndex.select(full, p), None);
        }
    }

    #[test]
    fn tags() {
        assert_eq!(IndexPolicy::FirstIndex.tag(), "FI");
        assert_eq!(IndexPolicy::BestIndex.tag(), "BI");
    }
}
