//! Worst Fit (WF-BI / WF-FI) — MIG-aware load-balancing paper baseline.
//!
//! Selects the single GPU maximizing remaining free slices after the
//! allocation (the emptiest GPU, ties by id) and applies the configured
//! [`IndexPolicy`] there. Committing to the fit-selected GPU means the
//! Fig. 3b rejection pathology applies; spreading keeps early acceptance
//! high but saturates many GPUs and accumulates fragmentation everywhere
//! at once.
//!
//! `WF-*-R` are the retrying ablations (see `first_fit.rs`).

use super::{IndexPolicy, Scheduler};
use crate::cluster::Cluster;
use crate::mig::{Placement, Profile};

/// The WF baseline, parameterized by index policy.
#[derive(Clone, Debug)]
pub struct WorstFit {
    policy: IndexPolicy,
    strict: bool,
    name: String,
}

impl WorstFit {
    /// Paper Worst Fit (single-GPU commit, the evaluation default).
    pub fn new(policy: IndexPolicy) -> Self {
        Self { policy, strict: true, name: format!("WF-{}", policy.tag()) }
    }

    /// Retrying variant — semantics ablation.
    pub fn retry(policy: IndexPolicy) -> Self {
        Self { policy, strict: false, name: format!("WF-{}-R", policy.tag()) }
    }

    pub fn policy(&self) -> IndexPolicy {
        self.policy
    }
}

impl Scheduler for WorstFit {
    fn name(&self) -> &str {
        &self.name
    }

    fn schedule(&mut self, cluster: &Cluster, profile: Profile) -> Option<Placement> {
        if !cluster.supports(profile) {
            return None;
        }
        if self.strict {
            // Max free slices among capability-eligible GPUs with capacity;
            // ties → lowest id (reverse-id key because max_by_key keeps the
            // LAST maximum).
            let gpu_id = cluster
                .gpus()
                .iter()
                .enumerate()
                .filter(|(id, g)| {
                    cluster.supports_on(*id, profile) && g.free_slices() >= profile.size()
                })
                .max_by_key(|(id, g)| (g.free_slices(), usize::MAX - *id))
                .map(|(id, _)| id)?;
            let index = self.policy.select(cluster.gpus()[gpu_id], profile)?;
            return Some(Placement { gpu: gpu_id, profile, index });
        }
        let mut ranked: Vec<(std::cmp::Reverse<u8>, usize)> = cluster
            .gpus()
            .iter()
            .enumerate()
            .filter(|(id, g)| {
                cluster.supports_on(*id, profile) && g.free_slices() >= profile.size()
            })
            .map(|(id, g)| (std::cmp::Reverse(g.free_slices()), id))
            .collect();
        ranked.sort_unstable();
        for &(_, gpu_id) in &ranked {
            if let Some(index) = self.policy.select(cluster.gpus()[gpu_id], profile) {
                return Some(Placement { gpu: gpu_id, profile, index });
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mig::HardwareModel;
    use crate::workload::WorkloadId;

    fn commit(c: &mut Cluster, id: u64, gpu: usize, profile: Profile, index: u8) {
        c.allocate(WorkloadId(id), Placement { gpu, profile, index }).unwrap();
    }

    #[test]
    fn prefers_emptiest_gpu() {
        let mut s = WorstFit::new(IndexPolicy::BestIndex);
        let mut c = Cluster::new(HardwareModel::a100_80gb(), 3);
        commit(&mut c, 0, 0, Profile::P4g40gb, 0);
        commit(&mut c, 1, 1, Profile::P2g20gb, 0);
        // GPU 2 empty → selected.
        assert_eq!(s.schedule(&c, Profile::P2g20gb).unwrap().gpu, 2);
    }

    #[test]
    fn tie_breaks_to_lowest_id() {
        let mut s = WorstFit::new(IndexPolicy::BestIndex);
        let c = Cluster::new(HardwareModel::a100_80gb(), 3);
        assert_eq!(s.schedule(&c, Profile::P1g10gb).unwrap().gpu, 0);
    }

    #[test]
    fn fig3b_rejection() {
        // Load-balancing pathology: the emptiest GPU by slice count has
        // infeasibly-arranged holes → reject despite a feasible busier GPU.
        let mut s = WorstFit::new(IndexPolicy::BestIndex);
        let mut c = Cluster::new(HardwareModel::a100_80gb(), 2);
        // GPU 0: 1g.10gb at 1 and 5 → 6 free slices, 3g/4g infeasible.
        commit(&mut c, 0, 0, Profile::P1g10gb, 1);
        commit(&mut c, 1, 0, Profile::P1g10gb, 5);
        // GPU 1: 4g.40gb at 0 → 4 free, 3g.40gb@4 feasible.
        commit(&mut c, 2, 1, Profile::P4g40gb, 0);
        assert!(c.gpu(1).unwrap().can_host(Profile::P3g40gb));
        // WF picks GPU 0 (6 > 4 free) and fails its anchors.
        assert_eq!(s.schedule(&c, Profile::P3g40gb), None);
    }

    #[test]
    fn retry_variant_falls_through() {
        let mut s = WorstFit::retry(IndexPolicy::BestIndex);
        let mut c = Cluster::new(HardwareModel::a100_80gb(), 2);
        commit(&mut c, 0, 0, Profile::P1g10gb, 1);
        commit(&mut c, 1, 0, Profile::P1g10gb, 5);
        commit(&mut c, 2, 1, Profile::P4g40gb, 0);
        let pl = s.schedule(&c, Profile::P3g40gb).unwrap();
        assert_eq!((pl.gpu, pl.index), (1, 4));
        assert_eq!(s.name(), "WF-BI-R");
    }

    #[test]
    fn index_policy_applied() {
        let c = Cluster::new(HardwareModel::a100_80gb(), 1);
        assert_eq!(
            WorstFit::new(IndexPolicy::BestIndex).schedule(&c, Profile::P1g20gb).unwrap().index,
            6
        );
        assert_eq!(
            WorstFit::new(IndexPolicy::FirstIndex)
                .schedule(&c, Profile::P1g20gb)
                .unwrap()
                .index,
            0
        );
    }

    #[test]
    fn names() {
        assert_eq!(WorstFit::new(IndexPolicy::BestIndex).name(), "WF-BI");
        assert_eq!(WorstFit::new(IndexPolicy::FirstIndex).name(), "WF-FI");
    }
}
