//! MFI on the incremental argmin-ΔF index (`MFI-IDX`).
//!
//! Placement-for-placement identical to [`Mfi`](super::Mfi) — same
//! ΔF values (one [`ScoreTable`]), same tie-breaking — but the decision
//! is a ~O(1) amortized index query instead of [`Mfi`]'s O(M·k) rescan
//! (see [`crate::frag::index`] for the complexity table).
//!
//! The scheduler stays correct under **any** driver discipline:
//!
//! * Drivers that call the [`Scheduler::on_commit`]/[`Scheduler::on_release`]
//!   hooks after every cluster mutation (the simulation engine and the
//!   serving daemon) pay O(k) per event and the next decision is a pure
//!   query.
//! * Drivers that drop some or all hooks are detected on the next
//!   `schedule` call via the cluster's generation counter: the index
//!   catches up from the bounded change log (O(k) per missed event) or,
//!   when the log cannot bridge the gap, rebuilds from the occupancy
//!   vector (O(M·k)) — never silently diverging. The
//!   [`MfiIndexed::rebuilds`]/[`MfiIndexed::replayed_events`] counters
//!   expose which path ran (used by the stale-index tests).
//!
//! One scheduler instance tracks ONE cluster's timeline: generations of
//! unrelated `Cluster` values are not comparable, so call
//! [`Scheduler::reset`] when switching clusters (the simulation engine
//! does this at the start of every run; a size mismatch is additionally
//! detected and rebuilt, and any divergence panics in debug builds).

use super::Scheduler;
use crate::cluster::Cluster;
use crate::frag::{FragIndex, OverlapRule, ScoreTable};
use crate::mig::{HardwareModel, Placement, Profile};

/// The incremental MFI scheduler (see module docs).
#[derive(Clone, Debug)]
pub struct MfiIndexed {
    table: ScoreTable,
    index: Option<FragIndex>,
    name: String,
    rebuilds: u64,
    replayed_events: u64,
}

impl MfiIndexed {
    /// MFI-IDX for the default hardware model (A100-80GB).
    pub fn new() -> Self {
        Self::for_hardware(&HardwareModel::a100_80gb())
    }

    /// MFI-IDX for a specific hardware model, default overlap rule.
    pub fn for_hardware(hw: &HardwareModel) -> Self {
        Self::with_table(ScoreTable::for_hardware(hw), "MFI-IDX".to_string())
    }

    /// MFI-IDX under an explicit fragmentation overlap rule (ablation).
    pub fn with_rule(hw: &HardwareModel, rule: OverlapRule) -> Self {
        let name = if rule == OverlapRule::default() {
            "MFI-IDX".to_string()
        } else {
            format!("MFI-IDX-{}", rule.name())
        };
        Self::with_table(ScoreTable::for_hardware_rule(hw, rule), name)
    }

    fn with_table(table: ScoreTable, name: String) -> Self {
        Self { table, index: None, name, rebuilds: 0, replayed_events: 0 }
    }

    pub fn score_table(&self) -> &ScoreTable {
        &self.table
    }

    /// Full index (re)builds performed, including the initial one.
    pub fn rebuilds(&self) -> u64 {
        self.rebuilds
    }

    /// Change-log events replayed incrementally (hook calls count one
    /// each; a dropped hook shows up here when `schedule` catches up).
    pub fn replayed_events(&self) -> u64 {
        self.replayed_events
    }

    /// Bring the index in line with `cluster` (build, catch up, or
    /// rebuild as needed).
    fn sync(&mut self, cluster: &Cluster) {
        match &mut self.index {
            None => {
                self.index = Some(FragIndex::for_cluster(self.table.clone(), cluster));
                self.rebuilds += 1;
            }
            Some(index) => match index.sync(cluster) {
                Some(replayed) => self.replayed_events += replayed as u64,
                None => self.rebuilds += 1,
            },
        }
    }
}

impl Default for MfiIndexed {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheduler for MfiIndexed {
    fn name(&self) -> &str {
        &self.name
    }

    fn schedule(&mut self, cluster: &Cluster, profile: Profile) -> Option<Placement> {
        // Cluster-wide guard: on a uniform cluster this is the legacy
        // single-model check; on a mixed fleet a profile no class enables
        // is rejected without touching the index (per-class enablement is
        // enforced inside `FragIndex` bucketing).
        if !cluster.supports(profile) {
            return None;
        }
        self.sync(cluster);
        self.index.as_ref().expect("index built by sync").best(profile)
    }

    fn on_commit(&mut self, cluster: &Cluster, _placement: Placement) {
        if self.index.is_some() {
            self.sync(cluster);
        }
    }

    fn on_release(&mut self, cluster: &Cluster, _placement: Placement) {
        if self.index.is_some() {
            self.sync(cluster);
        }
    }

    fn reset(&mut self) {
        self.index = None;
        self.rebuilds = 0;
        self.replayed_events = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::Mfi;
    use crate::util::rng::Rng;
    use crate::workload::WorkloadId;

    /// Drive both schedulers through the same random interleaving with
    /// hooks wired; placements must be identical at every step.
    #[test]
    fn hooked_interleaving_matches_mfi_exactly() {
        let hw = HardwareModel::a100_80gb();
        let mut flat = Mfi::for_hardware(&hw);
        let mut indexed = MfiIndexed::for_hardware(&hw);
        let mut cluster = Cluster::new(hw.clone(), 5);
        let mut rng = Rng::new(0x1DE8);
        let mut next_id = 0u64;
        for step in 0..800 {
            if rng.chance(0.6) {
                let p = *rng.choose(&crate::mig::profile::ALL_PROFILES);
                let a = flat.schedule(&cluster, p);
                let b = indexed.schedule(&cluster, p);
                assert_eq!(a, b, "step {step}: {p}");
                if let Some(pl) = a {
                    cluster.allocate(WorkloadId(next_id), pl).unwrap();
                    indexed.on_commit(&cluster, pl);
                    next_id += 1;
                }
            } else if cluster.allocated_workloads() > 0 {
                // Sort: HashMap iteration order would make the episode
                // irreproducible across runs of the same seed.
                let mut ids: Vec<WorkloadId> = cluster.allocations().map(|(id, _)| id).collect();
                ids.sort();
                let freed = cluster.release(*rng.choose(&ids)).unwrap();
                indexed.on_release(&cluster, freed);
            }
        }
        assert_eq!(indexed.rebuilds(), 1, "hooked driver never forces a rebuild");
    }

    #[test]
    fn unsupported_profile_rejected_without_index_work() {
        let hw = HardwareModel::a100_80gb().with_profiles(&[Profile::P1g10gb]);
        let mut s = MfiIndexed::for_hardware(&hw);
        let cluster = Cluster::new(hw, 2);
        assert_eq!(s.schedule(&cluster, Profile::P7g80gb), None);
        assert_eq!(s.rebuilds(), 0);
        assert!(s.schedule(&cluster, Profile::P1g10gb).is_some());
        assert_eq!(s.rebuilds(), 1);
    }

    #[test]
    fn reset_drops_the_index() {
        let hw = HardwareModel::a100_80gb();
        let mut s = MfiIndexed::for_hardware(&hw);
        let cluster = Cluster::new(hw.clone(), 2);
        s.schedule(&cluster, Profile::P1g10gb);
        assert_eq!(s.rebuilds(), 1);
        s.reset();
        assert_eq!(s.rebuilds(), 0);
        // A different cluster after reset: index rebuilt cleanly.
        let other = Cluster::new(hw, 7);
        assert!(s.schedule(&other, Profile::P7g80gb).is_some());
        assert_eq!(s.rebuilds(), 1);
    }

    #[test]
    fn names_and_rules() {
        assert_eq!(MfiIndexed::new().name(), "MFI-IDX");
        let any = MfiIndexed::with_rule(&HardwareModel::a100_80gb(), OverlapRule::Any);
        assert_eq!(any.name(), "MFI-IDX-any");
    }
}
