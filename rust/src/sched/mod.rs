//! Scheduling policies: the paper's MFI algorithm and every baseline it is
//! evaluated against (Section VI), behind a single [`Scheduler`] trait.
//!
//! Schedulers are *proposers*: `schedule` inspects the read-only cluster
//! state and returns a placement (or `None` = reject); the owning loop
//! commits it via [`crate::cluster::Cluster::allocate`]. Internal policy
//! state (the round-robin cursor, score tables, the PJRT executable) lives
//! inside the scheduler, which is why `schedule` takes `&mut self`.
//!
//! | scheme | MIG-awareness | GPU choice | index choice |
//! |--------|---------------|------------|--------------|
//! | [`FirstFit`]   | agnostic | first with a feasible index | first |
//! | [`RoundRobin`] | agnostic | rotating cursor             | first |
//! | [`BestFit`]    | aware    | min free slices after alloc | best (policy) |
//! | [`WorstFit`]   | aware    | max free slices after alloc | best (policy) |
//! | [`RandomFit`]  | agnostic | uniform among feasible      | uniform |
//! | [`Mfi`]        | aware    | argmin ΔF (Algorithm 2)     | argmin ΔF |
//! | [`MfiIndexed`] | aware    | argmin ΔF via incremental index | argmin ΔF |
//! | [`MfiExpected`]| aware + distribution | argmin ΔE[F] under the observed mix | argmin ΔE[F] |
//! | [`MfiXla`]     | aware    | argmin ΔF via PJRT artifact | argmin ΔF |
//!
//! [`MfiIndexed`] is placement-for-placement identical to [`Mfi`] but
//! decides in ~O(1) amortized instead of O(M·k), consuming the cluster's
//! change feed through the [`Scheduler::on_commit`]/[`Scheduler::on_release`]
//! hooks (see [`crate::frag::index`]).

pub mod best_fit;
pub mod first_fit;
pub mod index_policy;
pub mod mfi;
pub mod mfi_expected;
pub mod mfi_indexed;
#[cfg(feature = "xla")]
pub mod mfi_xla;
pub mod random;
pub mod round_robin;
pub mod worst_fit;

pub use best_fit::BestFit;
pub use first_fit::FirstFit;
pub use index_policy::IndexPolicy;
pub use mfi::Mfi;
pub use mfi_expected::MfiExpected;
pub use mfi_indexed::MfiIndexed;
#[cfg(feature = "xla")]
pub use mfi_xla::MfiXla;
pub use random::RandomFit;
pub use round_robin::RoundRobin;
pub use worst_fit::WorstFit;

use crate::cluster::Cluster;
use crate::mig::{Placement, Profile};

/// A scheduling policy: propose a placement for one profile request.
pub trait Scheduler {
    /// Stable name used in reports/CSV (e.g. `"MFI"`, `"BF-BI"`).
    fn name(&self) -> &str;

    /// Propose a placement for `profile` on `cluster`, or `None` to reject.
    /// Must NOT mutate the cluster (the caller commits).
    fn schedule(&mut self, cluster: &Cluster, profile: Profile) -> Option<Placement>;

    /// Observe a committed placement, called by the owning loop right
    /// after [`crate::cluster::Cluster::allocate`] succeeds. Default
    /// no-op; incremental schedulers ([`MfiIndexed`]) use it to update
    /// their index in O(k) instead of rescanning on the next decision.
    ///
    /// Hooks are an optimization, never a correctness requirement: a
    /// driver that drops them only costs the scheduler a change-log
    /// catch-up (or index rebuild) on its next `schedule` call — the
    /// cluster's generation counter makes staleness detectable.
    fn on_commit(&mut self, _cluster: &Cluster, _placement: Placement) {}

    /// Observe a released placement, called right after
    /// [`crate::cluster::Cluster::release`] succeeds. Default no-op.
    fn on_release(&mut self, _cluster: &Cluster, _placement: Placement) {}

    /// Reset internal policy state between simulation runs (cursors, RNG).
    ///
    /// Schedulers with a construction-time estimator seed restore *that*
    /// state, not an empty one, so seeded runs stay reproducible.
    fn reset(&mut self) {}

    /// The scheduler's online workload estimator, when it has one
    /// ([`MfiExpected`]). Observability surfaces (`/v1/stats`, `/metrics`)
    /// use this to report the learned mix; `None` (the default) keeps
    /// estimator-free schedulers' output unchanged.
    fn estimator(&self) -> Option<&crate::workload::ProfileMix> {
        None
    }
}

/// Constructible scheduler kinds (CLI/config/benches).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SchedulerKind {
    /// First Fit (MIG-agnostic) — paper baseline "FF".
    Ff,
    /// Round Robin (MIG-agnostic) — paper baseline "RR".
    Rr,
    /// Best Fit + Best Index (MIG-aware) — paper baseline "BF-BI".
    BfBi,
    /// Best Fit + First Index — index-policy ablation (not in the paper).
    BfFi,
    /// Worst Fit + Best Index (MIG-aware) — paper baseline "WF-BI".
    WfBi,
    /// Worst Fit + First Index — index-policy ablation (not in the paper).
    WfFi,
    /// Minimum Fragmentation Increment — the paper's contribution.
    Mfi,
    /// MFI on the incremental argmin-ΔF index — same placements as
    /// [`SchedulerKind::Mfi`], sublinear per decision (not in the paper).
    MfiIdx,
    /// MFI pricing candidates by *expected* fragmentation under the
    /// online-estimated workload mix (FGD-style; not in the paper).
    /// Bit-identical to [`SchedulerKind::Mfi`] while the estimator is
    /// empty or uniform.
    MfiExp,
    /// Random feasible placement — sanity floor (not in the paper).
    Random,
    /// Retrying FF: falls through to the next GPU when the
    /// resource-selected one has blocked anchors — semantics ablation
    /// quantifying how much of the paper's gap is Fig. 3 commitment.
    FfRetry,
    /// Retrying RR — semantics ablation.
    RrRetry,
    /// Retrying BF-BI — semantics ablation.
    BfBiRetry,
    /// Retrying WF-BI — semantics ablation.
    WfBiRetry,
}

impl SchedulerKind {
    /// The five schemes of the paper's evaluation, in figure-legend order.
    pub fn paper_set() -> [SchedulerKind; 5] {
        [
            SchedulerKind::Mfi,
            SchedulerKind::Ff,
            SchedulerKind::Rr,
            SchedulerKind::BfBi,
            SchedulerKind::WfBi,
        ]
    }

    /// Everything, for exhaustive sweeps/ablations.
    pub fn all() -> [SchedulerKind; 14] {
        [
            SchedulerKind::Mfi,
            SchedulerKind::MfiIdx,
            SchedulerKind::MfiExp,
            SchedulerKind::Ff,
            SchedulerKind::Rr,
            SchedulerKind::BfBi,
            SchedulerKind::BfFi,
            SchedulerKind::WfBi,
            SchedulerKind::WfFi,
            SchedulerKind::Random,
            SchedulerKind::FfRetry,
            SchedulerKind::RrRetry,
            SchedulerKind::BfBiRetry,
            SchedulerKind::WfBiRetry,
        ]
    }

    /// Does the scheme reject only when no feasible placement exists
    /// cluster-wide? The paper baselines commit to a single
    /// resource-selected GPU (Fig. 3) and are deliberately incomplete;
    /// MFI, RandomFit and the `-R` ablations are complete.
    pub fn is_complete(self) -> bool {
        matches!(
            self,
            SchedulerKind::Mfi
                | SchedulerKind::MfiIdx
                | SchedulerKind::MfiExp
                | SchedulerKind::Random
                | SchedulerKind::FfRetry
                | SchedulerKind::RrRetry
                | SchedulerKind::BfBiRetry
                | SchedulerKind::WfBiRetry
        )
    }

    pub fn name(self) -> &'static str {
        match self {
            SchedulerKind::Ff => "FF",
            SchedulerKind::Rr => "RR",
            SchedulerKind::BfBi => "BF-BI",
            SchedulerKind::BfFi => "BF-FI",
            SchedulerKind::WfBi => "WF-BI",
            SchedulerKind::WfFi => "WF-FI",
            SchedulerKind::Mfi => "MFI",
            SchedulerKind::MfiIdx => "MFI-IDX",
            SchedulerKind::MfiExp => "MFI-EXP",
            SchedulerKind::Random => "RANDOM",
            SchedulerKind::FfRetry => "FF-R",
            SchedulerKind::RrRetry => "RR-R",
            SchedulerKind::BfBiRetry => "BF-BI-R",
            SchedulerKind::WfBiRetry => "WF-BI-R",
        }
    }

    pub fn parse(s: &str) -> Option<SchedulerKind> {
        match s.to_ascii_uppercase().replace('_', "-").as_str() {
            "FF" | "FIRST-FIT" => Some(SchedulerKind::Ff),
            "RR" | "ROUND-ROBIN" => Some(SchedulerKind::Rr),
            "BF-BI" | "BEST-FIT" => Some(SchedulerKind::BfBi),
            "BF-FI" => Some(SchedulerKind::BfFi),
            "WF-BI" | "WORST-FIT" => Some(SchedulerKind::WfBi),
            "WF-FI" => Some(SchedulerKind::WfFi),
            "MFI" => Some(SchedulerKind::Mfi),
            "MFI-IDX" | "MFI-INDEXED" => Some(SchedulerKind::MfiIdx),
            "MFI-EXP" | "MFI-EXPECTED" => Some(SchedulerKind::MfiExp),
            "RANDOM" | "RAND" => Some(SchedulerKind::Random),
            "FF-R" => Some(SchedulerKind::FfRetry),
            "RR-R" => Some(SchedulerKind::RrRetry),
            "BF-BI-R" => Some(SchedulerKind::BfBiRetry),
            "WF-BI-R" => Some(SchedulerKind::WfBiRetry),
            _ => None,
        }
    }

    /// Instantiate the scheduler for a hardware model.
    pub fn build(self, hw: &crate::mig::HardwareModel) -> Box<dyn Scheduler + Send> {
        match self {
            SchedulerKind::Ff => Box::new(FirstFit::new()),
            SchedulerKind::Rr => Box::new(RoundRobin::new()),
            SchedulerKind::BfBi => Box::new(BestFit::new(IndexPolicy::BestIndex)),
            SchedulerKind::BfFi => Box::new(BestFit::new(IndexPolicy::FirstIndex)),
            SchedulerKind::WfBi => Box::new(WorstFit::new(IndexPolicy::BestIndex)),
            SchedulerKind::WfFi => Box::new(WorstFit::new(IndexPolicy::FirstIndex)),
            SchedulerKind::Mfi => Box::new(Mfi::for_hardware(hw)),
            SchedulerKind::MfiIdx => Box::new(MfiIndexed::for_hardware(hw)),
            SchedulerKind::MfiExp => Box::new(MfiExpected::for_hardware(hw)),
            SchedulerKind::Random => Box::new(RandomFit::new(0x5EED)),
            SchedulerKind::FfRetry => Box::new(FirstFit::retry()),
            SchedulerKind::RrRetry => Box::new(RoundRobin::retry()),
            SchedulerKind::BfBiRetry => Box::new(BestFit::retry(IndexPolicy::BestIndex)),
            SchedulerKind::WfBiRetry => Box::new(WorstFit::retry(IndexPolicy::BestIndex)),
        }
    }

    /// [`build`](Self::build), threading an estimator configuration into
    /// the schedulers that have one. Only [`SchedulerKind::MfiExp`]
    /// consumes it; every other kind builds exactly as `build` does, so
    /// call sites can pass the config through unconditionally.
    pub fn build_with_estimator(
        self,
        hw: &crate::mig::HardwareModel,
        estimator: Option<&crate::workload::EstimatorConfig>,
    ) -> Box<dyn Scheduler + Send> {
        match (self, estimator) {
            (SchedulerKind::MfiExp, Some(config)) => {
                Box::new(MfiExpected::with_config(hw, config))
            }
            _ => self.build(hw),
        }
    }
}

impl std::fmt::Display for SchedulerKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mig::HardwareModel;

    #[test]
    fn kind_parse_roundtrip() {
        for k in SchedulerKind::all() {
            assert_eq!(SchedulerKind::parse(k.name()), Some(k), "{k}");
        }
        assert_eq!(SchedulerKind::parse("first_fit"), Some(SchedulerKind::Ff));
        assert_eq!(SchedulerKind::parse("mfi"), Some(SchedulerKind::Mfi));
        assert_eq!(SchedulerKind::parse("slurm"), None);
    }

    #[test]
    fn build_produces_named_schedulers() {
        let hw = HardwareModel::a100_80gb();
        for k in SchedulerKind::all() {
            let s = k.build(&hw);
            assert_eq!(s.name(), k.name());
        }
    }

    #[test]
    fn build_with_estimator_seeds_only_mfi_exp() {
        use crate::mig::NUM_PROFILES;
        use crate::workload::EstimatorConfig;
        let hw = HardwareModel::a100_80gb();
        let cfg = EstimatorConfig { decay_slots: 8, seed_counts: Some([1; NUM_PROFILES]) };
        let s = SchedulerKind::MfiExp.build_with_estimator(&hw, Some(&cfg));
        assert_eq!(s.name(), "MFI-EXP");
        assert!(!s.estimator().expect("MFI-EXP has an estimator").is_empty());
        // Every other kind ignores the config and reports no estimator.
        let s = SchedulerKind::Mfi.build_with_estimator(&hw, Some(&cfg));
        assert!(s.estimator().is_none());
        // MFI-EXP without a config still carries an (empty) estimator.
        let s = SchedulerKind::MfiExp.build(&hw);
        assert!(s.estimator().expect("estimator present").is_empty());
    }

    #[test]
    fn paper_set_is_five_schemes() {
        let set = SchedulerKind::paper_set();
        assert_eq!(set.len(), 5);
        assert!(set.contains(&SchedulerKind::Mfi));
    }

    /// Shared behavioural contract: every scheduler only proposes valid
    /// (free-window, feasible-anchor) placements and preserves the
    /// requested profile. Strict variants MAY reject feasible requests —
    /// that is precisely the paper's Fig. 3 pathology (committing to one
    /// GPU chosen on resource counts and failing on its index
    /// constraints) — every other scheme must reject only when no
    /// feasible placement exists cluster-wide.
    #[test]
    fn all_schedulers_respect_feasibility() {
        use crate::cluster::Cluster;
        use crate::util::rng::Rng;
        use crate::workload::WorkloadId;
        let hw = HardwareModel::a100_80gb();
        let mut rng = Rng::new(77);
        for k in SchedulerKind::all() {
            let complete = k.is_complete();
            let mut s = k.build(&hw);
            let mut cluster = Cluster::new(hw.clone(), 4);
            let mut next_id = 0u64;
            for step in 0..600 {
                let p = *rng.choose(&crate::mig::profile::ALL_PROFILES);
                match s.schedule(&cluster, p) {
                    Some(pl) => {
                        assert_eq!(pl.profile, p, "{k} changed the profile");
                        cluster
                            .allocate(WorkloadId(next_id), pl)
                            .unwrap_or_else(|e| panic!("{k} proposed invalid {pl}: {e}"));
                        next_id += 1;
                    }
                    None => {
                        if complete {
                            assert!(
                                !cluster.can_host(p),
                                "{k} rejected {p} at step {step} though feasible"
                            );
                        }
                    }
                }
                // Random releases keep the cluster in flux.
                if rng.chance(0.35) && cluster.allocated_workloads() > 0 {
                    let ids: Vec<WorkloadId> =
                        cluster.allocations().map(|(id, _)| id).collect();
                    let id = *rng.choose(&ids);
                    cluster.release(id).unwrap();
                }
            }
        }
    }
}
