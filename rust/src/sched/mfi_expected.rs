//! MFI-EXP — MFI with distribution-aware (expected-fragmentation) pricing.
//!
//! Identical decision procedure to [`Mfi`](super::Mfi) — dry-run every
//! feasible placement, commit the strict `(Δ, gpu, anchor)` argmin — but
//! the score each candidate is priced against is the *expected*
//! fragmentation under the observed workload mix
//! ([`crate::frag::expected`]), learned online by a [`ProfileMix`]
//! estimator that [`Scheduler::on_commit`] feeds with every accepted
//! arrival.
//!
//! Two exact-equivalence guarantees (property-tested):
//!
//! * **Empty estimator ≡ MFI.** With no observed or seeded mass there is
//!   no signal, and the expected table would be all-zero (degenerate
//!   first-feasible argmin) — so the scheduler falls back to the agnostic
//!   [`ScoreTable`]/[`FleetTables`] path, bit-identical to `Mfi`.
//! * **Uniform estimator ≡ MFI.** Equal weights collapse to a positive
//!   scalar multiple of the agnostic score, preserving the argmin and
//!   every tie.
//!
//! The estimator state is part of the policy: [`Scheduler::reset`]
//! restores the *construction-time* mix (empty, or the `--estimator-seed`
//! histogram), so repeated simulation runs stay reproducible.

use super::Scheduler;
use crate::cluster::Cluster;
use crate::frag::expected::{
    evaluate_cluster_expected, evaluate_fleet_expected, ComponentTables, ExpectedFleet,
    ExpectedTable,
};
use crate::frag::{evaluate_cluster, evaluate_fleet, FleetTables, OverlapRule, ScoreTable};
use crate::mig::{HardwareModel, Placement, Profile};
use crate::workload::{EstimatorConfig, ProfileMix};

/// The MFI-EXP scheduler.
#[derive(Clone, Debug)]
pub struct MfiExpected {
    /// Agnostic table — the empty-estimator fallback path.
    table: ScoreTable,
    /// Agnostic per-class tables for the mixed-fleet fallback path.
    fleet: Option<FleetTables>,
    /// The live estimator, fed by `on_commit`.
    mix: ProfileMix,
    /// Construction-time mix, restored by `reset()` so seeded runs are
    /// reproducible across engine restarts.
    initial_mix: ProfileMix,
    /// Per-profile components for the construction hardware (uniform path).
    components: ComponentTables,
    /// Cached collapsed table for the uniform path, keyed on mix version.
    expected: Option<ExpectedTable>,
    expected_version: u64,
    /// Per-class expected tables for mixed fleets (lazily built, Arc-identity
    /// revalidated like the agnostic `FleetTables`).
    expected_fleet: Option<ExpectedFleet>,
    name: String,
}

impl MfiExpected {
    /// MFI-EXP for the default hardware model (A100-80GB), empty estimator.
    pub fn new() -> Self {
        Self::for_hardware(&HardwareModel::a100_80gb())
    }

    /// MFI-EXP for a hardware model with the default estimator config
    /// (empty mix, default decay).
    pub fn for_hardware(hw: &HardwareModel) -> Self {
        Self::with_config(hw, &EstimatorConfig::default())
    }

    /// MFI-EXP under an explicit fragmentation overlap rule (ablation).
    pub fn with_rule(hw: &HardwareModel, rule: OverlapRule) -> Self {
        let mut s = Self::with_config(hw, &EstimatorConfig::default());
        s.table = ScoreTable::for_hardware_rule(hw, rule);
        s.components = ComponentTables::for_hardware_rule(hw, rule);
        s.name = if rule == OverlapRule::default() {
            "MFI-EXP".into()
        } else {
            format!("MFI-EXP-{}", rule.name())
        };
        s
    }

    /// MFI-EXP with an explicit estimator configuration (decay + optional
    /// seed histogram) — the CLI/daemon construction path.
    pub fn with_config(hw: &HardwareModel, config: &EstimatorConfig) -> Self {
        let mix = config.build_mix();
        Self {
            table: ScoreTable::for_hardware(hw),
            fleet: None,
            initial_mix: mix.clone(),
            mix,
            components: ComponentTables::for_hardware(hw),
            expected: None,
            expected_version: 0,
            expected_fleet: None,
            name: "MFI-EXP".to_string(),
        }
    }

    /// The live estimator (read-only).
    pub fn mix(&self) -> &ProfileMix {
        &self.mix
    }

    /// Replace the live *and* initial mix (snapshot restore / seeding after
    /// construction).
    pub fn set_mix(&mut self, mix: ProfileMix) {
        self.initial_mix = mix.clone();
        self.mix = mix;
        self.expected = None;
        self.expected_fleet = None;
    }
}

impl Default for MfiExpected {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheduler for MfiExpected {
    fn name(&self) -> &str {
        &self.name
    }

    fn schedule(&mut self, cluster: &Cluster, profile: Profile) -> Option<Placement> {
        if cluster.is_uniform() {
            if !cluster.hardware().supports(profile) {
                return None;
            }
            if self.mix.is_empty() {
                // No signal: agnostic path, bit-identical to MFI.
                return evaluate_cluster(&self.table, cluster.gpus(), profile);
            }
            if self.expected.is_none() || self.expected_version != self.mix.version() {
                self.expected = Some(self.components.weighted(self.mix.weights()));
                self.expected_version = self.mix.version();
            }
            let expected = self.expected.as_ref().expect("expected table built");
            return evaluate_cluster_expected(expected, cluster.gpus(), profile);
        }
        if !cluster.supports(profile) {
            return None;
        }
        if self.mix.is_empty() {
            let fresh = !matches!(&self.fleet, Some(t) if t.matches(cluster));
            if fresh {
                self.fleet = Some(FleetTables::with_rule(cluster, self.table.rule()));
            }
            let tables = self.fleet.as_ref().expect("fleet tables built");
            return evaluate_fleet(tables, cluster, profile);
        }
        let fresh = !matches!(&self.expected_fleet, Some(t) if t.matches(cluster));
        if fresh {
            self.expected_fleet = Some(ExpectedFleet::with_rule(cluster, self.table.rule()));
        }
        let fleet = self.expected_fleet.as_mut().expect("expected fleet built");
        fleet.refresh(&self.mix);
        evaluate_fleet_expected(fleet, cluster, profile)
    }

    fn on_commit(&mut self, _cluster: &Cluster, placement: Placement) {
        self.mix.observe(placement.profile);
    }

    fn reset(&mut self) {
        self.fleet = None;
        self.expected = None;
        self.expected_fleet = None;
        self.mix = self.initial_mix.clone();
        self.expected_version = 0;
    }

    fn estimator(&self) -> Option<&ProfileMix> {
        Some(&self.mix)
    }
}

#[cfg(test)]
mod tests {
    use super::super::Mfi;
    use super::*;
    use crate::frag::delta::tests_support::random_reachable_state;
    use crate::mig::profile::ALL_PROFILES;
    use crate::mig::{GpuState, NUM_PROFILES};
    use crate::util::rng::Rng;
    use crate::workload::WorkloadId;

    fn random_cluster(rng: &mut Rng, gpus: usize) -> Cluster {
        let hw = HardwareModel::a100_80gb();
        let mut cluster = Cluster::new(hw, gpus);
        let mut next = 0u64;
        for gpu in 0..gpus {
            for _ in 0..rng.index(6) {
                let p = *rng.choose(&ALL_PROFILES);
                let feasible: Vec<u8> = cluster.gpus()[gpu].feasible_indexes(p).collect();
                if feasible.is_empty() {
                    continue;
                }
                let s = *rng.choose(&feasible);
                cluster
                    .allocate(WorkloadId(next), Placement { gpu, profile: p, index: s })
                    .unwrap();
                next += 1;
            }
        }
        cluster
    }

    #[test]
    fn empty_estimator_is_bit_identical_to_mfi() {
        let hw = HardwareModel::a100_80gb();
        let mut mfi = Mfi::for_hardware(&hw);
        let mut exp = MfiExpected::for_hardware(&hw);
        assert!(exp.mix().is_empty());
        let mut rng = Rng::new(4021);
        for round in 0..200 {
            let cluster = random_cluster(&mut rng, 5);
            for p in ALL_PROFILES {
                let a = mfi.schedule(&cluster, p);
                let b = exp.schedule(&cluster, p);
                assert_eq!(a, b, "round {round} profile {p}");
            }
        }
    }

    #[test]
    fn uniform_estimator_is_bit_identical_to_mfi() {
        let hw = HardwareModel::a100_80gb();
        let mut mfi = Mfi::for_hardware(&hw);
        let cfg = EstimatorConfig { decay_slots: 0, seed_counts: Some([11; NUM_PROFILES]) };
        let mut exp = MfiExpected::with_config(&hw, &cfg);
        assert!(!exp.mix().is_empty());
        let mut rng = Rng::new(555);
        for round in 0..200 {
            let cluster = random_cluster(&mut rng, 4);
            for p in ALL_PROFILES {
                let a = mfi.schedule(&cluster, p);
                let b = exp.schedule(&cluster, p);
                assert_eq!(a, b, "round {round} profile {p} (uniform mix)");
            }
        }
    }

    #[test]
    fn skewed_mix_argmin_matches_brute_force_over_the_expected_table() {
        let hw = HardwareModel::a100_80gb();
        let cfg = EstimatorConfig {
            decay_slots: 0,
            seed_counts: Some([1, 2, 30, 4, 5, 60]),
        };
        let mut exp = MfiExpected::with_config(&hw, &cfg);
        let table = ComponentTables::for_hardware(&hw).weighted(exp.mix().weights());
        let mut rng = Rng::new(808);
        for _ in 0..150 {
            let gpus: Vec<GpuState> =
                (0..5).map(|_| random_reachable_state(&mut rng)).collect();
            for p in ALL_PROFILES {
                let fast = evaluate_cluster_expected(&table, &gpus, p);
                let mut best: Option<(i64, usize, u8)> = None;
                for (gid, g) in gpus.iter().enumerate() {
                    if p.size() > g.free_slices() {
                        continue;
                    }
                    for &a in p.starts() {
                        if !g.fits_at(p, a) {
                            continue;
                        }
                        let d = table.delta(*g, p, a);
                        if best.is_none() || (d, gid, a) < best.unwrap() {
                            best = Some((d, gid, a));
                        }
                    }
                }
                match (fast, best) {
                    (None, None) => {}
                    (Some(pl), Some((_, gid, a))) => {
                        assert_eq!((pl.gpu, pl.index), (gid, a), "{p}");
                    }
                    (a, b) => panic!("mismatch for {p}: {a:?} vs {b:?}"),
                }
            }
        }
        // The scheduler's own decision agrees with the standalone table.
        let cluster = Cluster::new(hw, 3);
        let via_sched = exp.schedule(&cluster, Profile::P2g20gb);
        let via_table = evaluate_cluster_expected(&table, cluster.gpus(), Profile::P2g20gb);
        assert_eq!(via_sched, via_table);
    }

    #[test]
    fn on_commit_feeds_the_estimator_and_reset_restores_the_seed() {
        let hw = HardwareModel::a100_80gb();
        let mut counts = [0u64; NUM_PROFILES];
        counts[Profile::P3g40gb.index()] = 4;
        let cfg = EstimatorConfig { decay_slots: 64, seed_counts: Some(counts) };
        let mut exp = MfiExpected::with_config(&hw, &cfg);
        let seeded_weights = *exp.mix().weights();

        let cluster = Cluster::new(hw, 2);
        let pl = exp.schedule(&cluster, Profile::P1g10gb).unwrap();
        exp.on_commit(&cluster, pl);
        assert_eq!(exp.mix().arrivals(), 1);
        assert!(exp.mix().weights()[Profile::P1g10gb.index()] > 0);
        assert_ne!(exp.mix().weights(), &seeded_weights);
        assert_eq!(exp.estimator().unwrap().arrivals(), 1);

        exp.reset();
        assert_eq!(exp.mix().weights(), &seeded_weights, "reset restores the seeded mix");
        assert_eq!(exp.mix().arrivals(), 0);
    }

    #[test]
    fn never_rejects_feasible_requests_with_any_mix() {
        // Completeness: like MFI, the expected scan visits every feasible
        // candidate, so rejection implies infeasibility — regardless of mix.
        let hw = HardwareModel::a100_80gb();
        let cfg =
            EstimatorConfig { decay_slots: 16, seed_counts: Some([9, 0, 0, 0, 0, 1]) };
        let mut exp = MfiExpected::with_config(&hw, &cfg);
        let mut rng = Rng::new(31337);
        let mut cluster = Cluster::new(hw, 3);
        let mut next = 0u64;
        for _ in 0..400 {
            let p = *rng.choose(&ALL_PROFILES);
            match exp.schedule(&cluster, p) {
                Some(pl) => {
                    cluster.allocate(WorkloadId(next), pl).expect("proposed placement valid");
                    exp.on_commit(&cluster, pl);
                    next += 1;
                }
                None => assert!(!cluster.can_host(p), "rejected feasible {p}"),
            }
            if rng.chance(0.4) && cluster.allocated_workloads() > 0 {
                let ids: Vec<WorkloadId> = cluster.allocations().map(|(id, _)| id).collect();
                let id = *rng.choose(&ids);
                cluster.release(id).unwrap();
            }
        }
    }

    #[test]
    fn names_and_rules() {
        assert_eq!(MfiExpected::new().name(), "MFI-EXP");
        let any = MfiExpected::with_rule(&HardwareModel::a100_80gb(), OverlapRule::Any);
        assert_eq!(any.name(), "MFI-EXP-any");
    }
}
