//! Round Robin (RR) — MIG-agnostic paper baseline.
//!
//! Distributes requests across GPUs with a rotating cursor: starting from
//! the GPU after the previously selected one, commit to the first
//! *non-full* GPU, then try the first available index there — rejecting
//! when the profile does not fit (the Fig. 3 pathology). Unlike FF, whose
//! description in the paper checks for "*enough* available resources", RR
//! merely walks "the available GPUs", so the commit target is the next
//! GPU with any free slice at all. This is what makes RR's acceptance
//! "sharply deteriorate" at heavy load in the paper: once spreading has
//! put some load on every GPU, the cursor GPU almost never has the 8/4
//! contiguous slices a big profile needs.
//!
//! `RR-R` is the retrying ablation (see `first_fit.rs`).

use super::Scheduler;
use crate::cluster::Cluster;
use crate::mig::{Placement, Profile};

/// The RR baseline.
#[derive(Clone, Debug)]
pub struct RoundRobin {
    cursor: usize,
    strict: bool,
    name: &'static str,
}

impl RoundRobin {
    /// Paper Round Robin (single-GPU commit, the evaluation default).
    pub fn new() -> Self {
        Self { cursor: 0, strict: true, name: "RR" }
    }

    /// Retrying variant (`RR-R`) — semantics ablation.
    pub fn retry() -> Self {
        Self { cursor: 0, strict: false, name: "RR-R" }
    }
}

impl Default for RoundRobin {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheduler for RoundRobin {
    fn name(&self) -> &str {
        self.name
    }

    fn schedule(&mut self, cluster: &Cluster, profile: Profile) -> Option<Placement> {
        if !cluster.supports(profile) {
            return None;
        }
        let n = cluster.num_gpus();
        for off in 0..n {
            let gpu_id = (self.cursor + off) % n;
            let g = cluster.gpus()[gpu_id];
            // A GPU whose device class does not enable the profile is not
            // an available GPU for this request: the cursor walks past it
            // without committing (capability, not fragmentation).
            if !cluster.supports_on(gpu_id, profile) {
                continue;
            }
            if self.strict {
                // Commit to the first non-full GPU; the cursor advances
                // past it whether or not the placement succeeds.
                if g.is_full() {
                    continue;
                }
                self.cursor = (gpu_id + 1) % n;
                if g.free_slices() < profile.size() {
                    return None;
                }
                let index = g.first_feasible(profile)?;
                return Some(Placement { gpu: gpu_id, profile, index });
            }
            if g.free_slices() < profile.size() {
                continue;
            }
            if let Some(index) = g.first_feasible(profile) {
                self.cursor = (gpu_id + 1) % n;
                return Some(Placement { gpu: gpu_id, profile, index });
            }
        }
        None
    }

    fn reset(&mut self) {
        self.cursor = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mig::HardwareModel;
    use crate::workload::WorkloadId;

    #[test]
    fn rotates_across_gpus() {
        let mut s = RoundRobin::new();
        let mut c = Cluster::new(HardwareModel::a100_80gb(), 3);
        for i in 0..3 {
            let pl = s.schedule(&c, Profile::P2g20gb).unwrap();
            assert_eq!(pl.gpu, i, "request {i} should land on GPU {i}");
            c.allocate(WorkloadId(i as u64), pl).unwrap();
        }
        // Fourth request wraps to GPU 0 again.
        let pl = s.schedule(&c, Profile::P2g20gb).unwrap();
        assert_eq!(pl.gpu, 0);
    }

    #[test]
    fn skips_saturated_gpus() {
        let mut s = RoundRobin::new();
        let mut c = Cluster::new(HardwareModel::a100_80gb(), 2);
        c.allocate(WorkloadId(0), Placement { gpu: 0, profile: Profile::P7g80gb, index: 0 })
            .unwrap();
        let pl = s.schedule(&c, Profile::P1g10gb).unwrap();
        assert_eq!(pl.gpu, 1);
    }

    #[test]
    fn commits_to_cursor_gpu_and_rejects_on_index_miss() {
        let mut s = RoundRobin::new();
        let mut c = Cluster::new(HardwareModel::a100_80gb(), 2);
        // GPU 0 blocked for 4g (1g.10gb@1), GPU 1 empty.
        c.allocate(WorkloadId(0), Placement { gpu: 0, profile: Profile::P1g10gb, index: 1 })
            .unwrap();
        assert_eq!(s.schedule(&c, Profile::P4g40gb), None, "committed to GPU 0");
        // The cursor advanced past GPU 0, so the NEXT attempt succeeds.
        assert_eq!(s.schedule(&c, Profile::P4g40gb).unwrap().gpu, 1);
    }

    #[test]
    fn retry_variant_falls_through() {
        let mut s = RoundRobin::retry();
        let mut c = Cluster::new(HardwareModel::a100_80gb(), 2);
        c.allocate(WorkloadId(0), Placement { gpu: 0, profile: Profile::P1g10gb, index: 1 })
            .unwrap();
        assert_eq!(s.schedule(&c, Profile::P4g40gb).unwrap().gpu, 1);
        assert_eq!(s.name(), "RR-R");
    }

    #[test]
    fn reset_rewinds_cursor() {
        let mut s = RoundRobin::new();
        let c = Cluster::new(HardwareModel::a100_80gb(), 4);
        let _ = s.schedule(&c, Profile::P1g10gb);
        let _ = s.schedule(&c, Profile::P1g10gb);
        s.reset();
        assert_eq!(s.schedule(&c, Profile::P1g10gb).unwrap().gpu, 0);
    }

    #[test]
    fn rejects_when_no_gpu_has_capacity() {
        let mut s = RoundRobin::new();
        let mut c = Cluster::new(HardwareModel::a100_80gb(), 2);
        for g in 0..2 {
            c.allocate(
                WorkloadId(g as u64),
                Placement { gpu: g, profile: Profile::P7g80gb, index: 0 },
            )
            .unwrap();
        }
        assert_eq!(s.schedule(&c, Profile::P1g10gb), None);
    }
}
