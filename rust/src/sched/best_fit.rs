//! Best Fit (BF-BI / BF-FI) — MIG-aware bin-packing paper baseline.
//!
//! Selects the single GPU minimizing remaining free slices after the
//! allocation (the busiest GPU with capacity, ties by id) and applies the
//! configured [`IndexPolicy`] there — BestIndex per Turkkan et al. [21]
//! in the paper's "BF-BI", FirstIndex as the "BF-FI" ablation. Committing
//! to the fit-selected GPU means a blocked anchor set rejects the request
//! (the paper's Fig. 3a example) even when capacity exists elsewhere —
//! the mechanism behind the paper's heavy-load acceptance gaps.
//!
//! `BF-*-R` are the retrying ablations (see `first_fit.rs`).

use super::{IndexPolicy, Scheduler};
use crate::cluster::Cluster;
use crate::mig::{Placement, Profile};

/// The BF baseline, parameterized by index policy.
#[derive(Clone, Debug)]
pub struct BestFit {
    policy: IndexPolicy,
    strict: bool,
    name: String,
}

impl BestFit {
    /// Paper Best Fit (single-GPU commit, the evaluation default).
    pub fn new(policy: IndexPolicy) -> Self {
        Self { policy, strict: true, name: format!("BF-{}", policy.tag()) }
    }

    /// Retrying variant — semantics ablation.
    pub fn retry(policy: IndexPolicy) -> Self {
        Self { policy, strict: false, name: format!("BF-{}-R", policy.tag()) }
    }

    pub fn policy(&self) -> IndexPolicy {
        self.policy
    }
}

impl Scheduler for BestFit {
    fn name(&self) -> &str {
        &self.name
    }

    fn schedule(&mut self, cluster: &Cluster, profile: Profile) -> Option<Placement> {
        if !cluster.supports(profile) {
            return None;
        }
        if self.strict {
            // Min free slices among capability-eligible GPUs with capacity;
            // ties → lowest id.
            let gpu_id = cluster
                .gpus()
                .iter()
                .enumerate()
                .filter(|(id, g)| {
                    cluster.supports_on(*id, profile) && g.free_slices() >= profile.size()
                })
                .min_by_key(|(id, g)| (g.free_slices(), *id))
                .map(|(id, _)| id)?;
            let index = self.policy.select(cluster.gpus()[gpu_id], profile)?;
            return Some(Placement { gpu: gpu_id, profile, index });
        }
        let mut ranked: Vec<(u8, usize)> = cluster
            .gpus()
            .iter()
            .enumerate()
            .filter(|(id, g)| {
                cluster.supports_on(*id, profile) && g.free_slices() >= profile.size()
            })
            .map(|(id, g)| (g.free_slices(), id))
            .collect();
        ranked.sort_unstable();
        for &(_, gpu_id) in &ranked {
            if let Some(index) = self.policy.select(cluster.gpus()[gpu_id], profile) {
                return Some(Placement { gpu: gpu_id, profile, index });
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mig::HardwareModel;
    use crate::workload::WorkloadId;

    fn commit(c: &mut Cluster, id: u64, gpu: usize, profile: Profile, index: u8) {
        c.allocate(WorkloadId(id), Placement { gpu, profile, index }).unwrap();
    }

    #[test]
    fn prefers_busiest_gpu_with_capacity() {
        let mut s = BestFit::new(IndexPolicy::BestIndex);
        let mut c = Cluster::new(HardwareModel::a100_80gb(), 3);
        commit(&mut c, 0, 1, Profile::P4g40gb, 0); // GPU 1: 4 free
        commit(&mut c, 1, 2, Profile::P2g20gb, 0); // GPU 2: 6 free
        let pl = s.schedule(&c, Profile::P3g40gb).unwrap();
        assert_eq!(pl.gpu, 1, "GPU 1 has the least free slices that still fit");
    }

    #[test]
    fn best_index_policy_applied() {
        let mut s = BestFit::new(IndexPolicy::BestIndex);
        let c = Cluster::new(HardwareModel::a100_80gb(), 1);
        assert_eq!(s.schedule(&c, Profile::P1g10gb).unwrap().index, 6);
        let mut s_fi = BestFit::new(IndexPolicy::FirstIndex);
        assert_eq!(s_fi.schedule(&c, Profile::P1g10gb).unwrap().index, 0);
    }

    #[test]
    fn fig3a_rejection() {
        // Paper Fig. 3a: best-fit picks the fullest GPU whose remaining
        // slices cannot anchor the profile → reject despite capacity
        // elsewhere.
        let mut s = BestFit::new(IndexPolicy::BestIndex);
        let mut c = Cluster::new(HardwareModel::a100_80gb(), 2);
        // GPU 0: occupied {0,1,5} (2g@0 + 1g.10@5) → 5 free, 3g infeasible.
        commit(&mut c, 0, 0, Profile::P2g20gb, 0);
        commit(&mut c, 1, 0, Profile::P1g10gb, 5);
        // GPU 1 empty (8 free) → best-fit selects GPU 0 (5 < 8).
        assert!(c.gpu(1).unwrap().can_host(Profile::P3g40gb));
        assert_eq!(s.schedule(&c, Profile::P3g40gb), None);
    }

    #[test]
    fn retry_variant_falls_through() {
        let mut s = BestFit::retry(IndexPolicy::BestIndex);
        let mut c = Cluster::new(HardwareModel::a100_80gb(), 2);
        commit(&mut c, 0, 0, Profile::P2g20gb, 0);
        commit(&mut c, 1, 0, Profile::P1g10gb, 5);
        assert_eq!(s.schedule(&c, Profile::P3g40gb).unwrap().gpu, 1);
        assert_eq!(s.name(), "BF-BI-R");
    }

    #[test]
    fn tie_breaks_to_lowest_id() {
        let mut s = BestFit::new(IndexPolicy::BestIndex);
        let c = Cluster::new(HardwareModel::a100_80gb(), 3);
        assert_eq!(s.schedule(&c, Profile::P1g10gb).unwrap().gpu, 0);
    }

    #[test]
    fn names() {
        assert_eq!(BestFit::new(IndexPolicy::BestIndex).name(), "BF-BI");
        assert_eq!(BestFit::new(IndexPolicy::FirstIndex).name(), "BF-FI");
        assert_eq!(BestFit::retry(IndexPolicy::FirstIndex).name(), "BF-FI-R");
    }
}
