//! Minimum Fragmentation Increment (MFI) — the paper's Algorithm 2.
//!
//! For each request, MFI dry-runs every feasible placement of the profile
//! on every GPU, computes the hypothetical fragmentation-score variation
//! `ΔF^(i)(m) = F^(i)(m) − F(m)`, and commits the global argmin. Because it
//! considers *all* feasible anchors cluster-wide, it never rejects a
//! request that any scheme could have placed — rejection happens only when
//! MIG constraints leave no feasible window at all (Algorithm 2 line 18).
//!
//! The per-candidate ΔF is two lookups in the 256-entry
//! [`ScoreTable`](crate::frag::ScoreTable) (DESIGN.md §8), giving O(k·M)
//! per decision with a very small k — the complexity the paper claims.

use super::Scheduler;
use crate::cluster::Cluster;
use crate::frag::{evaluate_cluster, evaluate_fleet, FleetTables, OverlapRule, ScoreTable};
use crate::mig::{HardwareModel, Placement, Profile};

/// The MFI scheduler.
#[derive(Clone, Debug)]
pub struct Mfi {
    table: ScoreTable,
    /// Per-class tables, built lazily on the first mixed-fleet decision and
    /// revalidated by Arc identity on every call (see [`FleetTables::matches`]).
    fleet: Option<FleetTables>,
    name: String,
}

impl Mfi {
    /// MFI for the default hardware model (A100-80GB).
    pub fn new() -> Self {
        Self::for_hardware(&HardwareModel::a100_80gb())
    }

    /// MFI for a specific hardware model, default overlap rule.
    pub fn for_hardware(hw: &HardwareModel) -> Self {
        Self { table: ScoreTable::for_hardware(hw), fleet: None, name: "MFI".to_string() }
    }

    /// MFI under an explicit fragmentation overlap rule (ablation).
    pub fn with_rule(hw: &HardwareModel, rule: OverlapRule) -> Self {
        let name =
            if rule == OverlapRule::default() { "MFI".into() } else { format!("MFI-{}", rule.name()) };
        Self { table: ScoreTable::for_hardware_rule(hw, rule), fleet: None, name }
    }

    pub fn score_table(&self) -> &ScoreTable {
        &self.table
    }
}

impl Default for Mfi {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheduler for Mfi {
    fn name(&self) -> &str {
        &self.name
    }

    fn schedule(&mut self, cluster: &Cluster, profile: Profile) -> Option<Placement> {
        if cluster.is_uniform() {
            // Homogeneous hot path: untouched by the fleet refactor.
            if !cluster.hardware().supports(profile) {
                return None;
            }
            return evaluate_cluster(&self.table, cluster.gpus(), profile);
        }
        if !cluster.supports(profile) {
            return None;
        }
        let fresh = !matches!(&self.fleet, Some(t) if t.matches(cluster));
        if fresh {
            self.fleet = Some(FleetTables::with_rule(cluster, self.table.rule()));
        }
        evaluate_fleet(self.fleet.as_ref().expect("fleet tables built"), cluster, profile)
    }

    fn reset(&mut self) {
        self.fleet = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mig::GpuState;
    use crate::util::rng::Rng;
    use crate::workload::WorkloadId;

    fn commit(c: &mut Cluster, id: u64, gpu: usize, profile: Profile, index: u8) {
        c.allocate(WorkloadId(id), Placement { gpu, profile, index }).unwrap();
    }

    #[test]
    fn accepts_wherever_feasible() {
        // MFI must place the Fig. 3 workloads the fit-based schemes reject.
        let mut s = Mfi::new();
        let mut c = Cluster::new(HardwareModel::a100_80gb(), 2);
        commit(&mut c, 0, 0, Profile::P2g20gb, 0);
        commit(&mut c, 1, 0, Profile::P1g10gb, 5);
        // BF-BI rejects 3g.40gb here (see best_fit tests); MFI places it
        // on GPU 1.
        let pl = s.schedule(&c, Profile::P3g40gb).unwrap();
        assert_eq!(pl.gpu, 1);
    }

    #[test]
    fn prefers_fragmentation_repair() {
        // Completing a broken 2-slice window has ΔF = -4, strictly better
        // than opening a fresh GPU.
        let mut s = Mfi::new();
        let mut c = Cluster::new(HardwareModel::a100_80gb(), 2);
        commit(&mut c, 0, 1, Profile::P1g10gb, 5);
        let pl = s.schedule(&c, Profile::P1g10gb).unwrap();
        assert_eq!((pl.gpu, pl.index), (1, 4));
    }

    #[test]
    fn avoids_anchor_zero_for_small_profiles_on_empty_gpu() {
        // On an empty GPU the lowest-ΔF 1g.10gb anchor avoids breaking the
        // big profiles' windows: anchors {0..5} each break ≥2 big windows;
        // anchor 6 breaks only 3g@4 (+4) and 1g.20@6 (+2). MFI must find it.
        let mut s = Mfi::new();
        let c = Cluster::new(HardwareModel::a100_80gb(), 1);
        let pl = s.schedule(&c, Profile::P1g10gb).unwrap();
        assert_eq!(pl.index, 6, "MFI discovers the best-index rule by itself");
    }

    #[test]
    fn never_rejects_when_feasible_random_states() {
        let s = Mfi::new();
        let mut rng = Rng::new(0xF00D);
        for _ in 0..300 {
            let gpus: Vec<GpuState> = (0..6)
                .map(|_| crate::frag::delta::tests_support::random_reachable_state(&mut rng))
                .collect();
            for p in crate::mig::profile::ALL_PROFILES {
                let feasible = gpus.iter().any(|g| g.can_host(p));
                let got = evaluate_cluster(s.score_table(), &gpus, p);
                assert_eq!(got.is_some(), feasible, "{p}");
            }
        }
    }

    #[test]
    fn argmin_matches_brute_force() {
        let mut rng = Rng::new(0xBEEF);
        let table = ScoreTable::for_hardware(&HardwareModel::a100_80gb());
        for _ in 0..300 {
            let gpus: Vec<GpuState> = (0..5)
                .map(|_| crate::frag::delta::tests_support::random_reachable_state(&mut rng))
                .collect();
            for p in crate::mig::profile::ALL_PROFILES {
                let got = evaluate_cluster(&table, &gpus, p);
                // Brute force over all (gpu, anchor).
                let mut best: Option<(i32, usize, u8)> = None;
                for (gid, g) in gpus.iter().enumerate() {
                    if p.size() > g.free_slices() {
                        continue;
                    }
                    for &a in p.starts() {
                        if !g.fits_at(p, a) {
                            continue;
                        }
                        let d = table.delta(*g, p, a);
                        if best.is_none() || (d, gid, a) < best.unwrap() {
                            best = Some((d, gid, a));
                        }
                    }
                }
                match (got, best) {
                    (None, None) => {}
                    (Some(pl), Some((d, gid, a))) => {
                        assert_eq!((pl.gpu, pl.index), (gid, a), "{p} d={d}");
                    }
                    (a, b) => panic!("mismatch {a:?} vs {b:?}"),
                }
            }
        }
    }

    #[test]
    fn names_and_rules() {
        assert_eq!(Mfi::new().name(), "MFI");
        let any = Mfi::with_rule(&HardwareModel::a100_80gb(), OverlapRule::Any);
        assert_eq!(any.name(), "MFI-any");
    }
}
