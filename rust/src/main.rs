//! `migsched` — the command-line launcher for the fragmentation-aware MIG
//! scheduling framework.
//!
//! Subcommands:
//!
//! * `sim`          — one Monte Carlo run, metrics to stdout
//! * `sweep`        — full multi-seed experiment, prints Figs. 4/5/6
//! * `figures`      — regenerate one paper figure (`--fig 4|5|6`)
//! * `ab`           — paired A/B comparison of MFI vs MFI-EXP
//! * `serve`        — run the online serving daemon (JSON over HTTP)
//! * `inspect`      — hardware spec tables / Table II / candidate table
//! * `trace ingest` — import an Alibaba/Philly-style CSV job log
//! * `trace stats`  — profile histogram + arrival/lifespan percentiles
//! * `trace replay` — open-loop replay of a trace through a scheduler
//! * `trace-record` — generate + save a synthetic workload trace
//! * `trace-replay` — replay a trace through the saturation-protocol engine
//!
//! `migsched help` prints usage. Flags are `--key value` pairs.

use std::collections::HashMap;
use std::process::ExitCode;

use migsched::defrag::DefragPolicy;
use migsched::mig::FleetSpec;
use migsched::prelude::*;
use migsched::sim::{fig4_report, fig5_report, fig6_report};
use migsched::sim::experiment::run_sweep;
use migsched::sim::replay::{self, ReplayConfig};
use migsched::util::json::Json;
use migsched::workload::ingest::{self, IngestConfig, MappingPolicy, TraceFormat};
use migsched::workload::{EstimatorConfig, Trace};

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (command, flags) = match parse_args(&args) {
        Ok(x) => x,
        Err(e) => {
            eprintln!("error: {e}\n");
            print_usage();
            return ExitCode::from(2);
        }
    };
    let result = match command.as_str() {
        "sim" => cmd_sim(&flags),
        "sweep" => cmd_sweep(&flags),
        "figures" => cmd_figures(&flags),
        "ab" => cmd_ab(&flags),
        "serve" => cmd_serve(&flags),
        "inspect" => cmd_inspect(&flags),
        "trace ingest" => cmd_trace_ingest(&flags),
        "trace stats" => cmd_trace_stats(&flags),
        "trace replay" => cmd_trace_open_replay(&flags),
        "trace" => Err(
            "trace needs a subcommand: ingest, stats or replay (see `migsched help`)".into()
        ),
        "trace-record" => cmd_trace_record(&flags),
        "trace-replay" => cmd_trace_replay(&flags),
        "help" | "--help" | "-h" | "" => {
            print_usage();
            Ok(())
        }
        other => Err(format!("unknown command '{other}'")),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn print_usage() {
    println!(
        "migsched — online fragmentation-aware GPU scheduler for MIG-based clouds

USAGE:
  migsched <command> [--flag value]...

COMMANDS:
  sim           one Monte Carlo run
                  --scheduler MFI|MFI-IDX|MFI-EXP|FF|RR|BF-BI|...  (default MFI)
                  --distribution uniform|skew-small|skew-big|bimodal
                  --gpus N (default 100)   --seed N   --hardware a100-80gb
                  [--fleet a100:64,h100:32,a100-40gb:16] (heterogeneous
                   fleet; excludes --gpus/--hardware)
                  [--estimator-decay N] [--estimator-seed stats.json]
                   (workload estimator knobs, MFI-EXP only)
                  [--defrag-every N] [--defrag-threshold F]
                  [--defrag-moves N] [--defrag-budget COST]
                  [--telemetry rows.jsonl] (per-checkpoint run telemetry)
  sweep         full experiment (paper setup: 500 runs x 5 schemes x 4 dists)
                  --runs N   --gpus N   --quick (20 runs, M=20)
                  --out DIR (CSV exports, default results/)
  figures       regenerate a paper figure: --fig 4|5|6 [sweep flags]
  ab            paired A/B: agnostic MFI vs distribution-aware MFI-EXP,
                same seeds on both arms, JSON report of acceptance deltas
                  --gpus N (default 20)   --seeds N (default 5)   --seed N
                  [--estimator-decay N] [--estimator-seed stats.json]
                  [--trace trace.jsonl | --in jobs.csv --format F]
                  [--replay-gpus N] [--max-events N] [--out report.json]
  serve         online serving daemon
                  --addr 127.0.0.1:8080   --gpus N
                  --scheduler MFI|MFI-IDX|MFI-EXP
                  [--estimator-decay N] [--estimator-seed stats.json]
                   (per-shard workload estimator, MFI-EXP only)
                  [--fleet a100:64,h100:32] (heterogeneous fleet)
                  --shards N (disjoint sub-clusters, default 1)   --workers N
                  [--serve-model reactor|threadpool] (default reactor on unix)
                  [--idle-timeout-ms N (default 5000)]
                  [--max-requests-per-conn N (default 32)]
                  [--defrag-every SECS] [--defrag-threshold F]
                  [--defrag-moves N] [--defrag-budget COST]  (background sweep)
  inspect       --hardware a100-80gb | --distributions | --candidates
  trace ingest  import a real-cluster CSV job log as a canonical trace
                  --format alibaba|philly   --in jobs.csv   --out trace.jsonl
                  [--policy nearest-up|strict] [--slot-secs 300] [--gpus N]
                  [--max-duration-slots N] [--report report.json]
  trace stats   profile histogram, inter-arrival + lifespan percentiles
                  --trace trace.jsonl | --in jobs.csv --format F [ingest flags]
  trace replay  open-loop replay (arrivals continue past rejections)
                  --trace trace.jsonl | --in jobs.csv --format F [ingest flags]
                  [--sched MFI|MFI-IDX|MFI-EXP|...] [--gpus N] [--every N]
                  [--estimator-decay N] [--estimator-seed stats.json]
                  [--fleet a100:4,h100:2] (heterogeneous fleet)
                  [--max-events N] [--csv out.csv] [--json]
                  [--defrag-every N] [--defrag-threshold F]
                  [--defrag-moves N] [--defrag-budget COST]
                  [--telemetry rows.jsonl] (slot-cadence run telemetry)
  trace-record  --out trace.jsonl [--distribution D] [--gpus N] [--seed N]
  trace-replay  --trace trace.jsonl [--scheduler S] [--gpus N] [--defrag-every N]
                  [--telemetry rows.jsonl]
  help          this message

Environment:
  MIGSCHED_LOG=error|warn|info|debug|trace|off   log filter (default info)
  MIGSCHED_LOG_FORMAT=json                       JSON-lines log records
  MIGSCHED_ARTIFACTS=dir                         artifact output directory

The serving daemon exposes Prometheus metrics at GET /metrics and liveness
at GET /v1/healthz; see the README \"Observability\" section."
    );
}

type Flags = HashMap<String, String>;

fn parse_args(args: &[String]) -> Result<(String, Flags), String> {
    let mut flags = HashMap::new();
    // The command is every leading bare word ("trace ingest" is one
    // command of two words); flags start at the first `--`.
    let mut i = 0;
    let mut words: Vec<&str> = Vec::new();
    while i < args.len() && !args[i].starts_with("--") {
        words.push(&args[i]);
        i += 1;
    }
    let command = words.join(" ");
    while i < args.len() {
        let a = &args[i];
        if let Some(key) = a.strip_prefix("--") {
            // Boolean flags (no value or next is another flag).
            if i + 1 >= args.len() || args[i + 1].starts_with("--") {
                flags.insert(key.to_string(), "true".to_string());
                i += 1;
            } else {
                flags.insert(key.to_string(), args[i + 1].clone());
                i += 2;
            }
        } else {
            return Err(format!("unexpected argument '{a}'"));
        }
    }
    Ok((command, flags))
}

fn flag_usize(flags: &Flags, key: &str, default: usize) -> Result<usize, String> {
    match flags.get(key) {
        None => Ok(default),
        Some(v) => v.parse().map_err(|_| format!("--{key} must be an integer, got '{v}'")),
    }
}

fn flag_u64(flags: &Flags, key: &str, default: u64) -> Result<u64, String> {
    match flags.get(key) {
        None => Ok(default),
        Some(v) => v.parse().map_err(|_| format!("--{key} must be an integer, got '{v}'")),
    }
}

fn flag_f64(flags: &Flags, key: &str, default: f64) -> Result<f64, String> {
    match flags.get(key) {
        None => Ok(default),
        Some(v) => v.parse().map_err(|_| format!("--{key} must be a number, got '{v}'")),
    }
}

/// Parse the shared `--defrag-*` flags into a continuous-defrag policy.
/// `--defrag-every N` turns it on; the refinement knobs are rejected
/// without it (a silently inert flag would let users attribute results to
/// a configuration that never ran).
fn flag_defrag(flags: &Flags) -> Result<Option<DefragPolicy>, String> {
    let every = flag_u64(flags, "defrag-every", 0)?;
    if every == 0 {
        for knob in ["defrag-threshold", "defrag-moves", "defrag-budget"] {
            if flags.contains_key(knob) {
                return Err(format!("--{knob} requires --defrag-every N"));
            }
        }
        return Ok(None);
    }
    Ok(Some(
        DefragPolicy::every(every)
            .with_threshold(flag_f64(flags, "defrag-threshold", 0.0)?)
            .with_max_moves(flag_usize(flags, "defrag-moves", 16)?)
            .with_cost_budget(flag_u64(flags, "defrag-budget", 0)?),
    ))
}

fn flag_scheduler(flags: &Flags) -> Result<SchedulerKind, String> {
    // `--sched` is the short form used by the trace subcommands.
    let name = flags
        .get("scheduler")
        .or_else(|| flags.get("sched"))
        .map(String::as_str)
        .unwrap_or("MFI");
    SchedulerKind::parse(name).ok_or_else(|| format!("unknown scheduler '{name}'"))
}

/// Parse the `--estimator-*` flags into the online workload-estimator
/// wiring. Only the distribution-aware MFI-EXP consumes an estimator, so
/// the knobs are rejected under any other scheduler (a silently inert
/// flag would let users attribute results to a configuration that never
/// ran). `--estimator-seed` takes a `migsched trace stats --json` report.
fn flag_estimator(
    flags: &Flags,
    kind: SchedulerKind,
) -> Result<Option<EstimatorConfig>, String> {
    if kind != SchedulerKind::MfiExp {
        for knob in ["estimator-decay", "estimator-seed"] {
            if flags.contains_key(knob) {
                return Err(format!("--{knob} requires --sched mfi-exp"));
            }
        }
        return Ok(None);
    }
    let mut config = EstimatorConfig {
        decay_slots: flag_u64(
            flags,
            "estimator-decay",
            migsched::workload::estimator::DEFAULT_DECAY_SLOTS,
        )?,
        seed_counts: None,
    };
    if let Some(path) = flags.get("estimator-seed") {
        if path == "true" {
            return Err("--estimator-seed requires a stats-report path \
                        (write one with `migsched trace stats --json`)"
                .into());
        }
        let text =
            std::fs::read_to_string(path).map_err(|e| format!("reading {path}: {e}"))?;
        let stats = Json::parse(&text).map_err(|e| format!("parsing {path}: {e}"))?;
        // Reuse the estimator's own stats-report parser, then recover the
        // raw counts (seeding a fresh mix is exactly count x WEIGHT_SCALE).
        let mut mix = migsched::workload::ProfileMix::new(0);
        mix.seed_from_stats_json(&stats).map_err(|e| format!("{path}: {e}"))?;
        let mut counts = [0u64; migsched::mig::NUM_PROFILES];
        for (count, w) in counts.iter_mut().zip(mix.weights().iter()) {
            *count = w / migsched::workload::estimator::WEIGHT_SCALE;
        }
        config.seed_counts = Some(counts);
    }
    Ok(Some(config))
}

fn flag_distribution(flags: &Flags) -> Result<Distribution, String> {
    let name = flags.get("distribution").map(String::as_str).unwrap_or("uniform");
    Distribution::parse(name).ok_or_else(|| format!("unknown distribution '{name}'"))
}

fn flag_hardware(flags: &Flags) -> Result<HardwareModel, String> {
    let name = flags.get("hardware").map(String::as_str).unwrap_or("a100-80gb");
    HardwareModel::by_name(name).ok_or_else(|| format!("unknown hardware model '{name}'"))
}

/// `--fleet "a100:64,h100:32,a100-40gb:16"` — a heterogeneous fleet of
/// per-GPU device classes. The spec fixes both the GPU count and the
/// per-GPU hardware, so combining it with `--gpus` or `--hardware` is
/// rejected rather than silently overridden.
fn flag_fleet(flags: &Flags) -> Result<Option<FleetSpec>, String> {
    let Some(spec) = flags.get("fleet") else {
        return Ok(None);
    };
    if spec == "true" {
        return Err("--fleet requires a spec like 'a100:64,h100:32'".into());
    }
    for conflicting in ["gpus", "hardware"] {
        if flags.contains_key(conflicting) {
            return Err(format!(
                "--fleet and --{conflicting} are mutually exclusive \
                 (the fleet spec already fixes the GPU count and per-GPU hardware)"
            ));
        }
    }
    FleetSpec::parse(spec).map(Some)
}

/// `--telemetry PATH` (the bare flag without a path is rejected — a file
/// literally named "true" is never what anyone wants).
fn flag_telemetry(flags: &Flags) -> Result<Option<&str>, String> {
    match flags.get("telemetry").map(String::as_str) {
        Some("true") => Err("--telemetry requires a file path".into()),
        other => Ok(other),
    }
}

/// Write a run's telemetry rows as JSONL and note where they went
/// (stderr: stdout carries the run's own report).
fn save_telemetry(path: &str, rows: &[Json]) -> Result<(), String> {
    migsched::obs::telemetry::write_jsonl(path, rows)
        .map_err(|e| format!("saving telemetry {path}: {e}"))?;
    eprintln!("telemetry saved to {path} ({} rows)", rows.len());
    Ok(())
}

fn cmd_sim(flags: &Flags) -> Result<(), String> {
    let kind = flag_scheduler(flags)?;
    let estimator = flag_estimator(flags, kind)?;
    let fleet = flag_fleet(flags)?;
    let hw = match &fleet {
        Some(f) => f.classes()[0].0.clone(),
        None => flag_hardware(flags)?,
    };
    let telemetry_path = flag_telemetry(flags)?;
    let mut config = SimConfig {
        hardware: hw.clone(),
        num_gpus: flag_usize(flags, "gpus", 100)?,
        fleet: None,
        distribution: flag_distribution(flags)?,
        checkpoints: (1..=10).map(|i| i as f64 / 10.0).collect(),
        seed: flag_u64(flags, "seed", 1)?,
        defrag: flag_defrag(flags)?,
        telemetry: telemetry_path.is_some(),
    };
    if let Some(f) = fleet {
        config = config.with_fleet(f);
    }
    let engine = SimEngine::new(config.clone());
    let mut sched = kind.build_with_estimator(&hw, estimator.as_ref());
    let t0 = std::time::Instant::now();
    let result = engine.run(&mut *sched);
    let elapsed = t0.elapsed();
    println!(
        "scheme={} distribution={} M={} seed={} horizon={} ({} arrivals) [{elapsed:.2?}]",
        result.scheme,
        result.distribution,
        config.num_gpus,
        config.seed,
        result.horizon,
        result.arrived
    );
    let mut table = migsched::util::table::Table::new(&[
        "demand", "accepted", "acceptance", "allocated", "utilization", "active GPUs", "frag",
    ]);
    for r in &result.records {
        table.row(&[
            format!("{:.0}%", r.demand * 100.0),
            r.metrics.accepted_total.to_string(),
            format!("{:.4}", r.metrics.acceptance_rate()),
            r.metrics.allocated_workloads.to_string(),
            format!("{:.4}", r.metrics.utilization),
            r.metrics.active_gpus.to_string(),
            format!("{:.2}", r.metrics.mean_frag_score),
        ]);
    }
    println!("{}", table.render());
    println!(
        "whole-run acceptance: {:.4}   time-averaged fragmentation score: {:.3}",
        result.acceptance_rate(),
        result.time_avg_frag
    );
    if config.defrag.is_some() {
        println!(
            "defrag: migrations={} migrated_bytes={}",
            result.migrations, result.migrated_bytes
        );
    }
    if let Some(path) = telemetry_path {
        save_telemetry(path, &result.telemetry)?;
    }
    Ok(())
}

fn sweep_config(flags: &Flags) -> Result<ExperimentConfig, String> {
    let mut config = if flags.contains_key("quick") {
        ExperimentConfig::quick()
    } else {
        ExperimentConfig::paper()
    };
    config.hardware = flag_hardware(flags)?;
    config.num_gpus = flag_usize(flags, "gpus", config.num_gpus)?;
    config.runs = flag_usize(flags, "runs", config.runs)?;
    config.threads = flag_usize(flags, "threads", 0)?;
    config.base_seed = flag_u64(flags, "seed", config.base_seed)?;
    if let Some(s) = flags.get("schemes") {
        config.schemes = s
            .split(',')
            .map(|name| {
                SchedulerKind::parse(name).ok_or_else(|| format!("unknown scheduler '{name}'"))
            })
            .collect::<Result<Vec<_>, _>>()?;
    }
    Ok(config)
}

fn cmd_sweep(flags: &Flags) -> Result<(), String> {
    let config = sweep_config(flags)?;
    eprintln!(
        "running sweep: {} runs x {} schemes x {} distributions on M={} ...",
        config.runs,
        config.schemes.len(),
        config.distributions.len(),
        config.num_gpus
    );
    let t0 = std::time::Instant::now();
    let sweep = run_sweep(&config);
    eprintln!("sweep finished in {:.2?}", t0.elapsed());
    let out_dir = std::path::PathBuf::from(
        flags.get("out").cloned().unwrap_or_else(|| "results".to_string()),
    );
    for report in [
        fig4_report(&sweep, &Distribution::Uniform),
        fig5_report(&sweep, 0.85),
        fig6_report(&sweep),
    ] {
        println!("{}", report.render());
        report.save_csvs(&out_dir).map_err(|e| format!("saving CSVs: {e}"))?;
    }
    println!("raw CSVs saved under {}", out_dir.display());
    Ok(())
}

fn cmd_figures(flags: &Flags) -> Result<(), String> {
    let fig = flags.get("fig").map(String::as_str).unwrap_or("4");
    let config = sweep_config(flags)?;
    let sweep = run_sweep(&config);
    let report = match fig {
        "4" => fig4_report(&sweep, &Distribution::Uniform),
        "5" => fig5_report(&sweep, 0.85),
        "6" => fig6_report(&sweep),
        other => return Err(format!("unknown figure '{other}' (use 4, 5 or 6)")),
    };
    println!("{}", report.render());
    let out_dir = std::path::PathBuf::from(
        flags.get("out").cloned().unwrap_or_else(|| "results".to_string()),
    );
    report.save_csvs(&out_dir).map_err(|e| format!("saving CSVs: {e}"))?;
    Ok(())
}

/// Summarize one A/B arm for the `ab` report.
fn ab_arm_json(accepted: u64, arrived: u64, frag_sum: f64, runs: u64) -> Json {
    Json::obj()
        .with("accepted", accepted)
        .with("arrived", arrived)
        .with(
            "acceptance_rate",
            if arrived == 0 { 0.0 } else { accepted as f64 / arrived as f64 },
        )
        .with("mean_time_avg_frag", frag_sum / runs.max(1) as f64)
}

/// Paired A/B harness: the agnostic MFI baseline against the
/// distribution-aware MFI-EXP, run over the synthetic mixes (and, with
/// `--trace`/`--in`, an open-loop replay of a recorded trace) with the
/// same seeds on both arms. Prints and optionally saves a JSON report of
/// per-mix acceptance deltas; a conservation violation on either replay
/// arm fails the command.
fn cmd_ab(flags: &Flags) -> Result<(), String> {
    let gpus = flag_usize(flags, "gpus", 20)?;
    if gpus == 0 {
        return Err("--gpus must be positive".into());
    }
    let seeds = flag_u64(flags, "seeds", 5)?;
    if seeds == 0 {
        return Err("--seeds must be positive".into());
    }
    let base_seed = flag_u64(flags, "seed", 1)?;
    let hw = flag_hardware(flags)?;
    // The harness compares against MFI-EXP by construction, so the
    // estimator knobs always apply here.
    let estimator = flag_estimator(flags, SchedulerKind::MfiExp)?
        .expect("MFI-EXP always carries an estimator configuration");
    let arms = [SchedulerKind::Mfi, SchedulerKind::MfiExp];

    let t0 = std::time::Instant::now();
    let mut mix_rows = Vec::new();
    for dist in [
        Distribution::Uniform,
        Distribution::SkewSmall,
        Distribution::SkewBig,
        Distribution::Bimodal,
    ] {
        // (accepted, arrived, time-avg-frag sum) per arm, pooled over seeds.
        let mut totals = [(0u64, 0u64, 0.0f64); 2];
        for s in 0..seeds {
            let config = SimConfig {
                hardware: hw.clone(),
                num_gpus: gpus,
                fleet: None,
                distribution: dist.clone(),
                checkpoints: vec![1.0],
                seed: base_seed + s,
                defrag: None,
                telemetry: false,
            };
            let engine = SimEngine::new(config);
            for (arm, kind) in arms.iter().enumerate() {
                let mut sched = kind.build_with_estimator(&hw, Some(&estimator));
                let result = engine.run(&mut *sched);
                totals[arm].0 += result.accepted;
                totals[arm].1 += result.arrived;
                totals[arm].2 += result.time_avg_frag;
            }
        }
        let mut row = Json::obj().with("distribution", dist.name());
        for (arm, kind) in arms.iter().enumerate() {
            let (accepted, arrived, frag) = totals[arm];
            row.set(kind.name(), ab_arm_json(accepted, arrived, frag, seeds));
        }
        row.set("delta_accepted", totals[1].0 as i64 - totals[0].0 as i64);
        row.set(
            "delta_acceptance_rate",
            totals[1].0 as f64 / totals[1].1.max(1) as f64
                - totals[0].0 as f64 / totals[0].1.max(1) as f64,
        );
        eprintln!(
            "mix {:>10}: MFI {}/{}  MFI-EXP {}/{}  delta {:+}",
            dist.name(),
            totals[0].0,
            totals[0].1,
            totals[1].0,
            totals[1].1,
            totals[1].0 as i64 - totals[0].0 as i64
        );
        mix_rows.push(row);
    }

    let mut report = Json::obj()
        .with("format", "migsched-ab-v1")
        .with("baseline", arms[0].name())
        .with("candidate", arms[1].name())
        .with("gpus", gpus)
        .with("seeds", seeds)
        .with("base_seed", base_seed)
        .with("estimator_decay", estimator.decay_slots)
        .with("mixes", Json::Arr(mix_rows));

    // Optional third surface: open-loop replay of a recorded trace, both
    // arms over the identical arrival sequence.
    if flags.contains_key("trace") || flags.contains_key("in") {
        let trace = load_or_ingest_trace(flags)?;
        let num_gpus = flag_usize(
            flags,
            "replay-gpus",
            (trace.capacity_slices as usize / hw.num_slices()).max(1),
        )?;
        let config = ReplayConfig {
            hardware: hw.clone(),
            num_gpus,
            fleet: None,
            record_every: 0,
            max_events: flag_u64(flags, "max-events", 0)?,
            defrag: None,
            telemetry: false,
        };
        let mut row = Json::obj()
            .with("description", trace.description.as_str())
            .with("gpus", num_gpus);
        let mut accepted = [0u64; 2];
        for (arm, kind) in arms.iter().enumerate() {
            let mut sched = kind.build_with_estimator(&hw, Some(&estimator));
            let result = replay::run(&trace, &mut *sched, &config);
            if !result.conserved() {
                return Err(format!(
                    "{} replay violated counter conservation: \
                     arrived={} accepted={} rejected={}",
                    kind.name(),
                    result.arrived,
                    result.accepted,
                    result.rejected
                ));
            }
            accepted[arm] = result.accepted;
            row.set(
                kind.name(),
                Json::obj()
                    .with("accepted", result.accepted)
                    .with("arrived", result.arrived)
                    .with("acceptance_rate", result.acceptance_rate())
                    .with("time_avg_frag", result.time_avg_frag),
            );
        }
        row.set("delta_accepted", accepted[1] as i64 - accepted[0] as i64);
        report.set("trace", row);
    }

    eprintln!("ab finished in {:.2?}", t0.elapsed());
    println!("{}", report.to_string_pretty());
    if let Some(out) = flags.get("out") {
        std::fs::write(out, report.to_string_pretty())
            .map_err(|e| format!("saving {out}: {e}"))?;
        eprintln!("report saved to {out}");
    }
    Ok(())
}

/// Build and validate the daemon configuration from `serve` flags.
/// Every knob is checked up front so a misconfigured daemon fails with a
/// clear message before a socket ever binds.
fn serve_config(flags: &Flags) -> Result<migsched::server::DaemonConfig, String> {
    use migsched::server::daemon::{KEEP_ALIVE_IDLE, MAX_REQUESTS_PER_CONN};
    use migsched::server::{DaemonConfig, DaemonDefrag, ServeModel};
    let workers = flag_usize(flags, "workers", 8)?;
    if workers == 0 {
        return Err("--workers must be at least 1 (got 0): \
                    the daemon needs at least one serving thread"
            .into());
    }
    let idle_timeout_ms = flag_u64(flags, "idle-timeout-ms", KEEP_ALIVE_IDLE.as_millis() as u64)?;
    if idle_timeout_ms == 0 {
        return Err("--idle-timeout-ms must be at least 1 (got 0): \
                    a zero timeout would close every connection immediately"
            .into());
    }
    let max_requests = flag_usize(flags, "max-requests-per-conn", MAX_REQUESTS_PER_CONN)?;
    if max_requests == 0 {
        return Err("--max-requests-per-conn must be at least 1 (got 0): \
                    a zero cap could never serve a request"
            .into());
    }
    let model = match flags.get("serve-model") {
        None => ServeModel::default(),
        Some(name) => ServeModel::parse(name)
            .ok_or_else(|| format!("unknown serve model '{name}' (use reactor or threadpool)"))?,
    };
    let fleet = flag_fleet(flags)?;
    let (hardware, num_gpus) = match &fleet {
        Some(f) => (f.classes()[0].0.clone(), f.total_gpus()),
        None => (flag_hardware(flags)?, flag_usize(flags, "gpus", 100)?),
    };
    let scheduler = flag_scheduler(flags)?;
    let estimator = flag_estimator(flags, scheduler)?;
    let config = DaemonConfig {
        hardware,
        num_gpus,
        fleet,
        scheduler,
        estimator,
        workers,
        shards: flag_usize(flags, "shards", 1)?,
        model,
        idle_timeout: std::time::Duration::from_millis(idle_timeout_ms),
        max_requests_per_conn: max_requests,
        // The daemon interprets the cadence as wall-clock seconds.
        defrag: flag_defrag(flags)?.map(|p| DaemonDefrag {
            every_secs: p.every,
            threshold: p.threshold,
            max_moves: p.max_moves,
            cost_budget: p.cost_budget,
        }),
    };
    if config.num_gpus == 0 {
        return Err("--gpus must be positive".into());
    }
    if config.shards == 0 || config.shards > config.num_gpus {
        return Err(format!(
            "--shards must be in 1..={} (got {})",
            config.num_gpus.max(1),
            config.shards
        ));
    }
    // Shards partition the fleet preserving its class composition; a spec
    // whose per-class counts cannot reach every shard is unservable.
    if let Some(f) = &config.fleet {
        let parts = f.partition(config.shards);
        if parts.iter().any(|row| row.iter().sum::<usize>() == 0) {
            return Err(format!(
                "fleet '{}' cannot be split into {} composition-preserving \
                 shards (a shard would own no GPUs); use fewer --shards",
                f.spec_string(),
                config.shards
            ));
        }
    }
    Ok(config)
}

fn cmd_serve(flags: &Flags) -> Result<(), String> {
    use migsched::server::Daemon;
    let config = serve_config(flags)?;
    let addr = flags.get("addr").cloned().unwrap_or_else(|| "127.0.0.1:8080".to_string());
    let daemon = Daemon::new(config);
    let handle = daemon.serve(&addr).map_err(|e| format!("binding {addr}: {e}"))?;
    println!("migsched daemon listening on http://{}", handle.addr());
    println!("try: curl -s http://{}/v1/stats", handle.addr());
    // Serve until killed.
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_inspect(flags: &Flags) -> Result<(), String> {
    let mut shown = false;
    if flags.contains_key("hardware") {
        let hw = flag_hardware(flags)?;
        println!("{}", hw.spec_table().render());
        shown = true;
    }
    if flags.contains_key("distributions") {
        println!("{}", migsched::workload::distribution::table_ii().render());
        shown = true;
    }
    if flags.contains_key("candidates") {
        println!("{}", migsched::mig::candidates_json().to_string_pretty());
        shown = true;
    }
    if !shown {
        return Err("inspect needs --hardware MODEL, --distributions or --candidates".into());
    }
    Ok(())
}

fn cmd_trace_record(flags: &Flags) -> Result<(), String> {
    let out = flags.get("out").ok_or("trace-record requires --out FILE")?;
    let hw = flag_hardware(flags)?;
    let num_gpus = flag_usize(flags, "gpus", 100)?;
    let distribution = flag_distribution(flags)?;
    let seed = flag_u64(flags, "seed", 1)?;
    let capacity = (num_gpus * hw.num_slices()) as u64;
    let gen = WorkloadGenerator::new(distribution.clone());
    let generated = gen.generate(capacity, &mut Rng::new(seed));
    let trace = Trace::from_workloads(
        &format!("distribution={} gpus={num_gpus} seed={seed}", distribution.name()),
        capacity,
        &generated.workloads,
    );
    trace.save(std::path::Path::new(out)).map_err(|e| format!("saving {out}: {e}"))?;
    println!(
        "wrote {} arrivals (horizon T={}) to {out}",
        generated.workloads.len(),
        generated.horizon
    );
    Ok(())
}

/// Build an [`IngestConfig`] from the shared `trace` flags.
fn ingest_config(flags: &Flags) -> Result<IngestConfig, String> {
    let format_name = flags
        .get("format")
        .ok_or("ingesting a CSV requires --format alibaba|philly")?;
    let format = TraceFormat::parse(format_name)
        .ok_or_else(|| format!("unknown trace format '{format_name}'"))?;
    let policy_name = flags.get("policy").map(String::as_str).unwrap_or("nearest-up");
    let policy = MappingPolicy::parse(policy_name)
        .ok_or_else(|| format!("unknown mapping policy '{policy_name}'"))?;
    let slot_secs = flag_u64(flags, "slot-secs", 300)?;
    if slot_secs == 0 {
        return Err("--slot-secs must be positive".into());
    }
    let gpus = flag_usize(flags, "gpus", 100)?;
    if gpus == 0 {
        return Err("--gpus must be positive".into());
    }
    let mut config = IngestConfig::new(format)
        .with_policy(policy)
        .with_gpus(gpus)
        .with_slot_secs(slot_secs)
        .with_max_duration_slots(flag_u64(flags, "max-duration-slots", 0)?);
    config.hardware = flag_hardware(flags)?;
    Ok(config)
}

/// Load the trace named by `--trace`, or ingest `--in` + `--format`.
/// Ingest reports go to stderr so stdout stays machine-readable.
fn load_or_ingest_trace(flags: &Flags) -> Result<Trace, String> {
    match (flags.get("trace"), flags.get("in")) {
        (Some(path), None) => {
            // Ingest knobs cannot apply to an already-normalized trace —
            // silently dropping them would let users attribute results to
            // a configuration that never ran.
            for knob in ["format", "policy", "slot-secs", "max-duration-slots", "report"] {
                if flags.contains_key(knob) {
                    return Err(format!(
                        "--{knob} applies to CSV ingestion (--in); \
                         it has no effect on an existing --trace"
                    ));
                }
            }
            Trace::load(std::path::Path::new(path))
        }
        (None, Some(path)) => {
            let config = ingest_config(flags)?;
            let (trace, report) = ingest::ingest_path(std::path::Path::new(path), &config)?;
            eprintln!("{}", report.render());
            if let Some(report_path) = flags.get("report") {
                std::fs::write(report_path, report.to_json().to_string_pretty())
                    .map_err(|e| format!("saving {report_path}: {e}"))?;
                eprintln!("report saved to {report_path}");
            }
            Ok(trace)
        }
        (Some(_), Some(_)) => Err("--trace and --in are mutually exclusive".into()),
        (None, None) => Err("need --trace trace.jsonl or --in jobs.csv --format F".into()),
    }
}

fn cmd_trace_ingest(flags: &Flags) -> Result<(), String> {
    let input = flags.get("in").ok_or("trace ingest requires --in FILE")?;
    let out = flags.get("out").ok_or("trace ingest requires --out FILE")?;
    let config = ingest_config(flags)?;
    let (trace, report) =
        ingest::ingest_path(std::path::Path::new(input), &config)?;
    trace
        .save(std::path::Path::new(out))
        .map_err(|e| format!("saving {out}: {e}"))?;
    println!("{}", report.render());
    println!(
        "wrote {} workloads ({} events) to {out}",
        trace.arrivals().len(),
        trace.events.len()
    );
    if let Some(report_path) = flags.get("report") {
        std::fs::write(report_path, report.to_json().to_string_pretty())
            .map_err(|e| format!("saving {report_path}: {e}"))?;
        println!("report saved to {report_path}");
    }
    Ok(())
}

fn cmd_trace_stats(flags: &Flags) -> Result<(), String> {
    let trace = load_or_ingest_trace(flags)?;
    let stats = trace.stats();
    if flags.contains_key("json") {
        println!("{}", stats.to_json().to_string_pretty());
    } else {
        println!("{}", stats.render());
    }
    Ok(())
}

fn cmd_trace_open_replay(flags: &Flags) -> Result<(), String> {
    let trace = load_or_ingest_trace(flags)?;
    let kind = flag_scheduler(flags)?;
    let estimator = flag_estimator(flags, kind)?;
    let fleet = flag_fleet(flags)?;
    let hw = match &fleet {
        Some(f) => f.classes()[0].0.clone(),
        None => flag_hardware(flags)?,
    };
    let num_gpus = match &fleet {
        Some(f) => f.total_gpus(),
        None => flag_usize(
            flags,
            "gpus",
            (trace.capacity_slices as usize / hw.num_slices()).max(1),
        )?,
    };
    if num_gpus == 0 {
        return Err("--gpus must be positive".into());
    }
    let telemetry_path = flag_telemetry(flags)?;
    let config = ReplayConfig {
        hardware: hw.clone(),
        num_gpus,
        fleet,
        record_every: flag_u64(flags, "every", 0)?,
        max_events: flag_u64(flags, "max-events", 0)?,
        defrag: flag_defrag(flags)?,
        telemetry: telemetry_path.is_some(),
    };
    let mut sched = kind.build_with_estimator(&hw, estimator.as_ref());
    let t0 = std::time::Instant::now();
    let result = replay::run(&trace, &mut *sched, &config);
    let elapsed = t0.elapsed();

    if !flags.contains_key("json") {
        let mut table = migsched::util::table::Table::new(&[
            "slot", "arrived", "accepted", "acceptance", "utilization", "active GPUs", "frag",
        ]);
        for s in &result.samples {
            table.row(&[
                s.slot.to_string(),
                s.metrics.arrived_total.to_string(),
                s.metrics.accepted_total.to_string(),
                format!("{:.4}", s.metrics.acceptance_rate()),
                format!("{:.4}", s.metrics.utilization),
                s.metrics.active_gpus.to_string(),
                format!("{:.2}", s.metrics.mean_frag_score),
            ]);
        }
        println!(
            "scheme={} M={num_gpus} events={} span={} slots [{elapsed:.2?}]",
            result.scheme, result.arrived, result.span_slots
        );
        println!("{}", table.render());
    }
    println!("{}", result.to_json().to_string_pretty());

    if let Some(csv_path) = flags.get("csv") {
        let mut csv = migsched::util::csv::Csv::new(&[
            "slot", "arrived", "accepted", "acceptance", "utilization", "active_gpus", "frag",
        ]);
        for s in &result.samples {
            csv.row(&[
                s.slot.to_string(),
                s.metrics.arrived_total.to_string(),
                s.metrics.accepted_total.to_string(),
                format!("{:.6}", s.metrics.acceptance_rate()),
                format!("{:.6}", s.metrics.utilization),
                s.metrics.active_gpus.to_string(),
                format!("{:.6}", s.metrics.mean_frag_score),
            ]);
        }
        csv.save(std::path::Path::new(csv_path))
            .map_err(|e| format!("saving {csv_path}: {e}"))?;
        // stderr: stdout carries the machine-readable summary JSON.
        eprintln!("trajectory saved to {csv_path}");
    }
    if let Some(path) = telemetry_path {
        save_telemetry(path, &result.telemetry)?;
    }

    // Conservation is the smoke-level invariant CI relies on.
    if !result.conserved() {
        return Err(format!(
            "counter conservation violated: arrived={} accepted={} rejected={}",
            result.arrived, result.accepted, result.rejected
        ));
    }
    Ok(())
}

fn cmd_trace_replay(flags: &Flags) -> Result<(), String> {
    let path = flags.get("trace").ok_or("trace-replay requires --trace FILE")?;
    let trace = Trace::load(std::path::Path::new(path))?;
    let kind = flag_scheduler(flags)?;
    let estimator = flag_estimator(flags, kind)?;
    let hw = flag_hardware(flags)?;
    let num_gpus = flag_usize(
        flags,
        "gpus",
        (trace.capacity_slices as usize / hw.num_slices()).max(1),
    )?;
    let defrag = flag_defrag(flags)?;
    let telemetry_path = flag_telemetry(flags)?;
    let config = SimConfig {
        hardware: hw.clone(),
        num_gpus,
        fleet: None,
        distribution: Distribution::Uniform, // informational only on replay
        checkpoints: (1..=10).map(|i| i as f64 / 10.0).collect(),
        seed: 0,
        defrag,
        telemetry: telemetry_path.is_some(),
    };
    let engine = SimEngine::new(config.clone());
    let mut sched = kind.build_with_estimator(&hw, estimator.as_ref());
    let result = engine.replay_trace(&mut *sched, &trace);
    let mut summary = Json::obj()
        .with("trace", path.as_str())
        .with("scheme", result.scheme.as_str())
        .with("accepted", result.accepted)
        .with("arrived", result.arrived)
        .with("acceptance_rate", result.acceptance_rate())
        .with("time_avg_frag", result.time_avg_frag);
    if config.defrag.is_some() {
        summary.set("migrations", result.migrations);
        summary.set("migrated_bytes", result.migrated_bytes);
    }
    println!("{}", summary.to_string_pretty());
    if let Some(path) = telemetry_path {
        save_telemetry(path, &result.telemetry)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use migsched::server::ServeModel;

    use super::*;

    fn flags_of(pairs: &[(&str, &str)]) -> Flags {
        pairs.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect()
    }

    #[test]
    fn serve_config_defaults_are_sane() {
        let config = serve_config(&Flags::new()).expect("default serve config");
        assert_eq!(config.shards, 1);
        assert_eq!(config.workers, 8);
        assert_eq!(config.model, ServeModel::default());
        assert_eq!(config.idle_timeout, migsched::server::daemon::KEEP_ALIVE_IDLE);
        assert_eq!(
            config.max_requests_per_conn,
            migsched::server::daemon::MAX_REQUESTS_PER_CONN
        );
    }

    #[test]
    fn serve_config_rejects_zero_shards_and_workers() {
        let err = serve_config(&flags_of(&[("shards", "0")])).unwrap_err();
        assert!(err.contains("--shards must be in 1..=100 (got 0)"), "{err}");
        let err = serve_config(&flags_of(&[("workers", "0")])).unwrap_err();
        assert!(err.contains("--workers must be at least 1"), "{err}");
        // Shards above the fleet size are as unservable as zero.
        let err = serve_config(&flags_of(&[("gpus", "4"), ("shards", "5")])).unwrap_err();
        assert!(err.contains("--shards must be in 1..=4 (got 5)"), "{err}");
    }

    #[test]
    fn serve_config_rejects_zero_connection_limits() {
        let err = serve_config(&flags_of(&[("idle-timeout-ms", "0")])).unwrap_err();
        assert!(err.contains("--idle-timeout-ms must be at least 1"), "{err}");
        let err = serve_config(&flags_of(&[("max-requests-per-conn", "0")])).unwrap_err();
        assert!(err.contains("--max-requests-per-conn must be at least 1"), "{err}");
        let err = serve_config(&flags_of(&[("idle-timeout-ms", "abc")])).unwrap_err();
        assert!(err.contains("--idle-timeout-ms must be an integer"), "{err}");
    }

    #[test]
    fn serve_config_accepts_a_fleet_spec() {
        let config = serve_config(&flags_of(&[("fleet", "a100:2,h100:2")])).unwrap();
        assert_eq!(config.num_gpus, 4);
        assert_eq!(config.hardware.name(), "A100-80GB");
        let fleet = config.fleet.expect("fleet spec threaded through");
        assert_eq!(fleet.spec_string(), "a100-80gb:2,h100-80gb:2");
    }

    #[test]
    fn fleet_flag_rejects_bad_specs_and_conflicts() {
        let err = flag_fleet(&flags_of(&[("fleet", "b200:4")])).unwrap_err();
        assert!(err.contains("unknown hardware model 'b200'"), "{err}");
        let err = flag_fleet(&flags_of(&[("fleet", "a100:0")])).unwrap_err();
        assert!(err.contains("zero GPU count"), "{err}");
        let err = flag_fleet(&flags_of(&[("fleet", "a100")])).unwrap_err();
        assert!(err.contains("expected model:count"), "{err}");
        let err = flag_fleet(&flags_of(&[("fleet", "true")])).unwrap_err();
        assert!(err.contains("requires a spec"), "{err}");
        for conflicting in ["gpus", "hardware"] {
            let err = flag_fleet(&flags_of(&[("fleet", "a100:4"), (conflicting, "h100")]))
                .unwrap_err();
            assert!(err.contains("mutually exclusive"), "{err}");
        }
        // A fleet that cannot reach every shard is caught before binding.
        let err = serve_config(&flags_of(&[("fleet", "a100:1,h100:1"), ("shards", "2")]))
            .unwrap_err();
        assert!(err.contains("composition-preserving"), "{err}");
    }

    #[test]
    fn estimator_flags_require_the_distribution_aware_scheduler() {
        // Inert knobs are rejected, not silently dropped.
        let err = flag_estimator(&flags_of(&[("estimator-decay", "64")]), SchedulerKind::Mfi)
            .unwrap_err();
        assert!(err.contains("--estimator-decay requires --sched mfi-exp"), "{err}");
        let err = serve_config(&flags_of(&[("estimator-seed", "stats.json")])).unwrap_err();
        assert!(err.contains("--estimator-seed requires --sched mfi-exp"), "{err}");
        assert!(flag_estimator(&Flags::new(), SchedulerKind::Mfi).unwrap().is_none());
        // The bare flag without a path is rejected like --telemetry.
        let err = flag_estimator(&flags_of(&[("estimator-seed", "true")]), SchedulerKind::MfiExp)
            .unwrap_err();
        assert!(err.contains("requires a stats-report path"), "{err}");
    }

    #[test]
    fn serve_config_builds_a_per_shard_estimator_for_mfi_exp() {
        let config =
            serve_config(&flags_of(&[("scheduler", "mfi-exp"), ("estimator-decay", "128")]))
                .unwrap();
        assert_eq!(config.scheduler, SchedulerKind::MfiExp);
        let est = config.estimator.expect("estimator wired through");
        assert_eq!(est.decay_slots, 128);
        assert_eq!(est.seed_counts, None);
        // Default decay when the flag is omitted; no estimator at all for
        // agnostic schedulers (the daemon stays byte-compatible).
        let config = serve_config(&flags_of(&[("scheduler", "mfi-exp")])).unwrap();
        assert_eq!(config.estimator.unwrap().decay_slots, EstimatorConfig::default().decay_slots);
        let config = serve_config(&Flags::new()).unwrap();
        assert!(config.estimator.is_none());
    }

    #[test]
    fn estimator_seed_flag_reads_a_trace_stats_report() {
        let path = std::env::temp_dir().join("migsched_main_estimator_seed.json");
        std::fs::write(&path, r#"{"arrivals":10,"profiles":{"1g.10gb":6,"3g.40gb":4}}"#)
            .unwrap();
        let flags = flags_of(&[("estimator-seed", path.to_str().unwrap())]);
        let est = flag_estimator(&flags, SchedulerKind::MfiExp).unwrap().unwrap();
        let counts = est.seed_counts.expect("seed counts recovered from the report");
        assert_eq!(counts[migsched::mig::Profile::P1g10gb.index()], 6);
        assert_eq!(counts[migsched::mig::Profile::P3g40gb.index()], 4);
        assert_eq!(counts[migsched::mig::Profile::P7g80gb.index()], 0);
        std::fs::remove_file(&path).ok();
        // A missing file is a clear error, not a silent empty seed.
        let err = flag_estimator(
            &flags_of(&[("estimator-seed", "/nonexistent/stats.json")]),
            SchedulerKind::MfiExp,
        )
        .unwrap_err();
        assert!(err.contains("reading /nonexistent/stats.json"), "{err}");
    }

    #[test]
    fn serve_config_parses_connection_knobs_and_model() {
        let config = serve_config(&flags_of(&[
            ("idle-timeout-ms", "250"),
            ("max-requests-per-conn", "7"),
            ("serve-model", "threadpool"),
        ]))
        .expect("custom serve config");
        assert_eq!(config.idle_timeout, std::time::Duration::from_millis(250));
        assert_eq!(config.max_requests_per_conn, 7);
        assert_eq!(config.model, ServeModel::Threadpool);
        let err = serve_config(&flags_of(&[("serve-model", "tokio")])).unwrap_err();
        assert!(err.contains("unknown serve model 'tokio'"), "{err}");
    }
}
