//! Online workload estimator: an exponentially-decaying per-profile demand
//! histogram ([`ProfileMix`]) learned from the arrival stream.
//!
//! The paper's fragmentation metric is workload-agnostic; FGD (Weng et
//! al., USENIX ATC '23) shows that weighting fragmentation by the
//! *observed* profile distribution recovers additional acceptance. The
//! estimator is the online half of that idea: every committed arrival
//! bumps its profile's weight, and all weights decay geometrically so the
//! mix tracks the recent stream rather than the full history.
//!
//! **Determinism.** Weights are pure integers. One observation applies
//! `w[i] -= w[i] / D` to every profile (retention `1 - 1/D`) and then adds
//! [`WEIGHT_SCALE`] to the observed profile, so two runs fed the same
//! arrival sequence hold bit-identical state — no floats, no wall clock.
//! `D` ([`ProfileMix::decay_slots`]) is expressed in *slots* under the
//! paper's one-arrival-per-slot protocol; in open-loop replay and the
//! daemon the decay advances per observed arrival, which keeps the
//! estimator a function of the arrival sequence alone. `D = 0` disables
//! decay (plain counting). After `n` observations of a shifted mix the
//! old mass retains a factor `(1 - 1/D)^n ≈ e^(-n/D)`, so the estimator
//! re-converges within a few multiples of `D` — the drift bound the
//! tests pin.
//!
//! The mix can be *seeded* before a run — from raw per-profile counts, a
//! replay prefix ([`ProfileMix::seed_from_trace`]), or a saved
//! `migsched trace stats` JSON report ([`ProfileMix::seed_from_stats_json`])
//! — and snapshotted/restored losslessly through the same integer state.

use crate::mig::{Profile, ALL_PROFILES, NUM_PROFILES};
use crate::util::json::Json;
use crate::workload::{Trace, TraceEvent};

/// Fixed-point weight added per observation. Large enough that the
/// geometric decay's integer truncation is far below one observation's
/// worth of mass.
pub const WEIGHT_SCALE: u64 = 1 << 20;

/// Default decay time constant in slots: long enough to smooth burst
/// noise, short enough to track a mid-trace mix shift within a few
/// thousand arrivals.
pub const DEFAULT_DECAY_SLOTS: u64 = 512;

/// An exponentially-decaying per-profile demand histogram.
///
/// All state is integer, so observation sequences map to bit-identical
/// weights across runs and platforms. The `version` counter bumps on
/// every mutation; consumers that derive expensive state from the mix
/// (the expected-fragmentation tables) key their caches on it.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ProfileMix {
    weights: [u64; NUM_PROFILES],
    decay_slots: u64,
    arrivals: u64,
    version: u64,
}

impl Default for ProfileMix {
    fn default() -> Self {
        Self::new(DEFAULT_DECAY_SLOTS)
    }
}

impl ProfileMix {
    /// An empty mix with decay time constant `decay_slots` (0 = no decay).
    pub fn new(decay_slots: u64) -> Self {
        Self { weights: [0; NUM_PROFILES], decay_slots, arrivals: 0, version: 0 }
    }

    /// Record one arrival: decay every weight by `1/decay_slots`, then add
    /// [`WEIGHT_SCALE`] to the observed profile.
    pub fn observe(&mut self, profile: Profile) {
        if self.decay_slots > 0 {
            for w in &mut self.weights {
                *w -= *w / self.decay_slots;
            }
        }
        self.weights[profile.index()] += WEIGHT_SCALE;
        self.arrivals += 1;
        self.version += 1;
    }

    /// Raw fixed-point weights, indexed by [`Profile::index`].
    pub fn weights(&self) -> &[u64; NUM_PROFILES] {
        &self.weights
    }

    /// True when no observation or seed has contributed any mass — the
    /// condition under which distribution-aware scoring falls back to the
    /// agnostic scorer.
    pub fn is_empty(&self) -> bool {
        self.weights.iter().all(|&w| w == 0)
    }

    /// Monotone mutation counter; bumps on observe/seed/restore.
    pub fn version(&self) -> u64 {
        self.version
    }

    /// Observations recorded via [`observe`](Self::observe) (seeding does
    /// not count).
    pub fn arrivals(&self) -> u64 {
        self.arrivals
    }

    pub fn decay_slots(&self) -> u64 {
        self.decay_slots
    }

    /// Normalized shares (sum 1.0), for reporting only — decisions use the
    /// integer weights. All zeros when the mix is empty.
    pub fn normalized(&self) -> [f64; NUM_PROFILES] {
        let total: u64 = self.weights.iter().sum();
        if total == 0 {
            return [0.0; NUM_PROFILES];
        }
        let mut out = [0.0; NUM_PROFILES];
        for (share, &w) in out.iter_mut().zip(&self.weights) {
            *share = w as f64 / total as f64;
        }
        out
    }

    /// Seed from per-profile arrival counts (e.g. a trace histogram):
    /// each count contributes `count × WEIGHT_SCALE` undecayed mass.
    pub fn seed_from_counts(&mut self, counts: &[u64; NUM_PROFILES]) {
        for (w, &count) in self.weights.iter_mut().zip(counts) {
            *w += count * WEIGHT_SCALE;
        }
        self.version += 1;
    }

    /// Seed from the first `prefix` arrivals of a trace (0 = all),
    /// replaying them through [`observe`](Self::observe) so the decay
    /// semantics match a live run over the same prefix.
    pub fn seed_from_trace(&mut self, trace: &Trace, prefix: usize) {
        let take = if prefix == 0 { usize::MAX } else { prefix };
        let arrivals = self.arrivals;
        for event in trace
            .events
            .iter()
            .filter_map(|e| match e {
                TraceEvent::Arrival(w) => Some(w.profile),
                TraceEvent::Departure(..) => None,
            })
            .take(take)
        {
            self.observe(event);
        }
        self.arrivals = arrivals; // seeding is not live observation
    }

    /// Seed from a saved `migsched trace stats` report (the JSON written
    /// by `trace stats --json`): reads the `profiles` object mapping
    /// canonical profile names to arrival counts.
    pub fn seed_from_stats_json(&mut self, stats: &Json) -> Result<(), String> {
        let profiles = stats
            .get("profiles")
            .ok_or_else(|| "stats report has no \"profiles\" object".to_string())?;
        let pairs = match profiles {
            Json::Obj(pairs) => pairs,
            _ => return Err("\"profiles\" must be an object of per-profile counts".to_string()),
        };
        let mut counts = [0u64; NUM_PROFILES];
        for (name, value) in pairs {
            let profile = Profile::parse(name)
                .ok_or_else(|| format!("unknown profile {name:?} in stats report"))?;
            let count = value
                .as_u64()
                .ok_or_else(|| format!("profile {name:?} count must be a non-negative integer"))?;
            counts[profile.index()] += count;
        }
        self.seed_from_counts(&counts);
        Ok(())
    }

    /// Serialize the full integer state (weights keyed by canonical
    /// profile name, decay constant, arrival count).
    pub fn snapshot(&self) -> Json {
        let mut weights = Json::obj();
        for p in ALL_PROFILES {
            weights.set(p.canonical_name(), self.weights[p.index()]);
        }
        Json::obj()
            .with("decay_slots", self.decay_slots)
            .with("arrivals", self.arrivals)
            .with("weights", weights)
    }

    /// Restore from a [`snapshot`](Self::snapshot). Replaces weights,
    /// decay constant and arrival count; bumps the version.
    pub fn restore(&mut self, snapshot: &Json) -> Result<(), String> {
        let decay = snapshot.req_u64("decay_slots")?;
        let arrivals = snapshot.req_u64("arrivals")?;
        let weights_obj = snapshot
            .get("weights")
            .ok_or_else(|| "snapshot has no \"weights\" object".to_string())?;
        let pairs = match weights_obj {
            Json::Obj(pairs) => pairs,
            _ => return Err("\"weights\" must be an object".to_string()),
        };
        let mut weights = [0u64; NUM_PROFILES];
        for (name, value) in pairs {
            let profile = Profile::parse(name)
                .ok_or_else(|| format!("unknown profile {name:?} in snapshot"))?;
            let w = value
                .as_u64()
                .ok_or_else(|| format!("weight for {name:?} must be a non-negative integer"))?;
            weights[profile.index()] = w;
        }
        self.decay_slots = decay;
        self.arrivals = arrivals;
        self.weights = weights;
        self.version += 1;
        Ok(())
    }
}

/// Construction-time estimator wiring for CLI/daemon surfaces: the decay
/// constant plus an optional seed histogram (from `--estimator-seed`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct EstimatorConfig {
    /// Decay time constant in slots (0 = no decay).
    pub decay_slots: u64,
    /// Initial per-profile counts seeded before the run.
    pub seed_counts: Option<[u64; NUM_PROFILES]>,
}

impl Default for EstimatorConfig {
    fn default() -> Self {
        Self { decay_slots: DEFAULT_DECAY_SLOTS, seed_counts: None }
    }
}

impl EstimatorConfig {
    /// Build the initial mix this configuration describes.
    pub fn build_mix(&self) -> ProfileMix {
        let mut mix = ProfileMix::new(self.decay_slots);
        if let Some(counts) = &self.seed_counts {
            mix.seed_from_counts(counts);
        }
        mix
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_mix_reports_empty_and_uniform_zero_shares() {
        let mix = ProfileMix::new(64);
        assert!(mix.is_empty());
        assert_eq!(mix.arrivals(), 0);
        assert_eq!(mix.normalized(), [0.0; NUM_PROFILES]);
    }

    #[test]
    fn observations_are_deterministic_and_order_sensitive_state_is_integer() {
        let feed = [
            Profile::P1g10gb,
            Profile::P3g40gb,
            Profile::P1g10gb,
            Profile::P7g80gb,
            Profile::P1g10gb,
        ];
        let mut a = ProfileMix::new(32);
        let mut b = ProfileMix::new(32);
        for p in feed {
            a.observe(p);
            b.observe(p);
        }
        assert_eq!(a, b, "same feed must produce bit-identical state");
        assert_eq!(a.arrivals(), 5);
        assert_eq!(a.version(), 5);
        let shares = a.normalized();
        assert!(shares[Profile::P1g10gb.index()] > shares[Profile::P3g40gb.index()]);
        assert!((shares.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn zero_decay_counts_plainly() {
        let mut mix = ProfileMix::new(0);
        for _ in 0..10 {
            mix.observe(Profile::P2g20gb);
        }
        assert_eq!(mix.weights()[Profile::P2g20gb.index()], 10 * WEIGHT_SCALE);
    }

    #[test]
    fn drift_reconverges_within_a_bounded_number_of_observations() {
        // Phase 1: saturate on 1g.10gb. Phase 2: switch to 7g.80gb. After
        // 8·D observations of the new mix, the old mass retains at most
        // (1 - 1/D)^(8D) ≈ e^-8 < 0.04% — the estimator must be dominated
        // by the new profile.
        let decay = 32u64;
        let mut mix = ProfileMix::new(decay);
        for _ in 0..(8 * decay) {
            mix.observe(Profile::P1g10gb);
        }
        let old_share_before = mix.normalized()[Profile::P1g10gb.index()];
        assert!(old_share_before > 0.99);
        for _ in 0..(8 * decay) {
            mix.observe(Profile::P7g80gb);
        }
        let shares = mix.normalized();
        assert!(
            shares[Profile::P7g80gb.index()] > 0.99,
            "estimator did not re-converge: {shares:?}"
        );
        assert!(shares[Profile::P1g10gb.index()] < 0.01);
    }

    #[test]
    fn seed_from_counts_matches_manual_weights() {
        let mut mix = ProfileMix::new(128);
        let mut counts = [0u64; NUM_PROFILES];
        counts[Profile::P3g40gb.index()] = 7;
        counts[Profile::P1g10gb.index()] = 3;
        mix.seed_from_counts(&counts);
        assert!(!mix.is_empty());
        assert_eq!(mix.weights()[Profile::P3g40gb.index()], 7 * WEIGHT_SCALE);
        assert_eq!(mix.weights()[Profile::P1g10gb.index()], 3 * WEIGHT_SCALE);
        assert_eq!(mix.arrivals(), 0, "seeding is not observation");
    }

    #[test]
    fn seed_from_trace_prefix_matches_observing_the_prefix() {
        use crate::workload::{Workload, WorkloadId};
        let profiles =
            [Profile::P1g10gb, Profile::P2g20gb, Profile::P1g10gb, Profile::P7g80gb];
        let ws: Vec<Workload> = profiles
            .iter()
            .enumerate()
            .map(|(i, &p)| Workload {
                id: WorkloadId(i as u64),
                tenant: crate::workload::TenantId(0),
                profile: p,
                arrival_slot: i as u64,
                duration_slots: 5,
            })
            .collect();
        let trace = Trace::from_workloads("estimator seed test", 64, &ws);

        let mut seeded = ProfileMix::new(16);
        seeded.seed_from_trace(&trace, 3);
        let mut observed = ProfileMix::new(16);
        for &p in profiles.iter().take(3) {
            observed.observe(p);
        }
        assert_eq!(seeded.weights(), observed.weights());
        assert_eq!(seeded.arrivals(), 0);
        // prefix 0 = the whole trace.
        let mut full = ProfileMix::new(16);
        full.seed_from_trace(&trace, 0);
        let mut full_observed = ProfileMix::new(16);
        for &p in &profiles {
            full_observed.observe(p);
        }
        assert_eq!(full.weights(), full_observed.weights());
    }

    #[test]
    fn seed_from_stats_json_reads_the_trace_stats_report() {
        let stats = Json::parse(
            r#"{"arrivals":10,"profiles":{"1g.10gb":6,"3g.40gb":4},"tenants":1}"#,
        )
        .unwrap();
        let mut mix = ProfileMix::new(256);
        mix.seed_from_stats_json(&stats).unwrap();
        assert_eq!(mix.weights()[Profile::P1g10gb.index()], 6 * WEIGHT_SCALE);
        assert_eq!(mix.weights()[Profile::P3g40gb.index()], 4 * WEIGHT_SCALE);

        let missing = Json::parse(r#"{"arrivals":10}"#).unwrap();
        assert!(ProfileMix::new(1).seed_from_stats_json(&missing).is_err());
        let bad_name = Json::parse(r#"{"profiles":{"9g.999gb":1}}"#).unwrap();
        assert!(ProfileMix::new(1).seed_from_stats_json(&bad_name).is_err());
        let bad_count = Json::parse(r#"{"profiles":{"1g.10gb":-3}}"#).unwrap();
        assert!(ProfileMix::new(1).seed_from_stats_json(&bad_count).is_err());
    }

    #[test]
    fn snapshot_restore_roundtrips_exactly() {
        let mut mix = ProfileMix::new(48);
        for p in [Profile::P1g10gb, Profile::P4g40gb, Profile::P1g10gb] {
            mix.observe(p);
        }
        let snap = mix.snapshot();
        let mut restored = ProfileMix::new(7);
        restored.restore(&snap).unwrap();
        assert_eq!(restored.weights(), mix.weights());
        assert_eq!(restored.decay_slots(), mix.decay_slots());
        assert_eq!(restored.arrivals(), mix.arrivals());
        // And the round-trip survives serialization to text.
        let reparsed = Json::parse(&snap.to_string_compact()).unwrap();
        let mut again = ProfileMix::new(0);
        again.restore(&reparsed).unwrap();
        assert_eq!(again.weights(), mix.weights());
    }

    #[test]
    fn estimator_config_builds_the_seeded_mix() {
        let empty = EstimatorConfig::default().build_mix();
        assert!(empty.is_empty());
        assert_eq!(empty.decay_slots(), DEFAULT_DECAY_SLOTS);
        let mut counts = [0u64; NUM_PROFILES];
        counts[Profile::P2g20gb.index()] = 5;
        let cfg = EstimatorConfig { decay_slots: 99, seed_counts: Some(counts) };
        let mix = cfg.build_mix();
        assert_eq!(mix.decay_slots(), 99);
        assert_eq!(mix.weights()[Profile::P2g20gb.index()], 5 * WEIGHT_SCALE);
    }
}
