//! Workloads: requests for MIG profiles with arrival times and lifespans
//! (paper Section IV system model), the Table II request distributions,
//! the synthetic generator behind the Monte Carlo evaluation, a JSON-lines
//! trace format for record/replay, and the [`ingest`] subsystem importing
//! real GPU-cluster job logs (Alibaba/Philly-style) into that format.

pub mod distribution;
pub mod estimator;
pub mod generator;
pub mod ingest;
pub mod spec;
pub mod trace;

pub use distribution::Distribution;
pub use estimator::{EstimatorConfig, ProfileMix};
pub use generator::{GeneratedWorkloads, WorkloadGenerator};
pub use ingest::{IngestConfig, IngestReport, MappingPolicy, ProfileMapper, TraceFormat};
pub use spec::{TenantId, Workload, WorkloadId};
pub use trace::{Trace, TraceEvent, TraceStats};
