//! Workloads: requests for MIG profiles with arrival times and lifespans
//! (paper Section IV system model), the Table II request distributions,
//! the synthetic generator behind the Monte Carlo evaluation, and a
//! JSON-lines trace format for record/replay.

pub mod distribution;
pub mod generator;
pub mod spec;
pub mod trace;

pub use distribution::Distribution;
pub use generator::{GeneratedWorkloads, WorkloadGenerator};
pub use spec::{TenantId, Workload, WorkloadId};
pub use trace::{Trace, TraceEvent};
